//! Quickstart: load an AOT artifact, train the CIFAR-10 proxy for a couple
//! of epochs with DANA-Slim on 8 simulated asynchronous workers, and
//! evaluate — the whole public API in ~30 lines.
//!
//! Run with:  cargo run --release --example quickstart

use dana::config::{default_artifacts_dir, TrainConfig, Workload};
use dana::optim::AlgorithmKind;
use dana::runtime::Engine;
use dana::train::sim_trainer;

fn main() -> anyhow::Result<()> {
    // 1. Open the artifacts directory produced by `make artifacts`.
    let engine = Engine::cpu(&default_artifacts_dir())?;
    println!("PJRT platform: {}", engine.platform());

    // 2. Describe the experiment: workload proxy, algorithm, cluster size.
    let mut cfg = TrainConfig::preset(
        Workload::C10,           // ResNet-20/CIFAR-10 proxy
        AlgorithmKind::DanaSlim, // the paper's zero-overhead variant
        8,                       // asynchronous workers
        4.0,                     // epochs
    );
    cfg.eval_every_epochs = 1.0;

    // 3. Train on the simulated asynchronous cluster (real gradients via
    //    the PJRT runtime; gamma-distributed execution times).
    let report = sim_trainer::run(&cfg, &engine)?;

    // 4. Inspect results.
    for p in &report.curve {
        println!(
            "epoch {:4.1}  test error {:5.2}%  test loss {:.4}",
            p.epoch, p.test_error, p.test_loss
        );
    }
    println!("final: {}", report.summary());
    anyhow::ensure!(report.final_test_error < 20.0, "quickstart failed to learn");
    println!("quickstart OK");
    Ok(())
}

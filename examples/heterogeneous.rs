//! Heterogeneous-cluster demo (paper §5.1/Appendix D): machines with
//! persistently different speeds (gamma CVB model, V_mach = 0.6).
//!
//! Shows the two headline effects:
//!  1. SSGD pays the straggler penalty — async is several times faster at
//!     the same batch budget (Fig 12's right panel).
//!  2. Asynchronous accuracy *survives* heterogeneity (Fig 6/13): fast
//!     workers dominate updates, so stale gradients from slow machines
//!     matter less — DANA stays near the baseline.
//!
//! Run with:  cargo run --release --example heterogeneous

use dana::config::{default_artifacts_dir, TrainConfig, Workload};
use dana::optim::AlgorithmKind;
use dana::runtime::Engine;
use dana::sim::speedup;
use dana::sim::Environment;
use dana::train::sim_trainer;

fn main() -> anyhow::Result<()> {
    let engine = Engine::cpu(&default_artifacts_dir())?;
    let n = 16usize;

    // --- timing: async vs sync on the same heterogeneous cluster ---
    println!("timing (gamma CVB model, V_mach=0.6, N={n}):");
    let pts = speedup::speedup_sweep(Environment::Heterogeneous, &[n], 128, 50, 6);
    let p = &pts[0];
    println!(
        "  async speedup {:.2}x | sync speedup {:.2}x | async/sync = {:.2}x",
        p.async_speedup,
        p.sync_speedup,
        p.async_speedup / p.sync_speedup
    );

    // --- accuracy: momentum algorithms under heterogeneity ---
    println!("\naccuracy (CIFAR-10 proxy, 8 epochs, N={n}, hetero):");
    for alg in [
        AlgorithmKind::DanaDc,
        AlgorithmKind::DanaSlim,
        AlgorithmKind::MultiAsgd,
        AlgorithmKind::NagAsgd,
    ] {
        let mut cfg = TrainConfig::preset(Workload::C10, alg, n, 8.0);
        cfg.env = Environment::Heterogeneous;
        cfg.metrics_every = 10;
        let rep = sim_trainer::run(&cfg, &engine)?;
        println!(
            "  {:<11} err {:6.2}%  mean gap {:.2e}  mean lag {:.1}",
            alg.name(),
            rep.final_test_error,
            rep.mean_gap,
            rep.mean_lag
        );
    }
    let ratio = p.async_speedup / p.sync_speedup;
    anyhow::ensure!(ratio > 1.5, "hetero async advantage did not reproduce");
    println!("\nheterogeneous OK");
    Ok(())
}

//! END-TO-END DRIVER: asynchronous training of a char-level transformer LM
//! with real OS-thread workers — every layer of the stack composing:
//!
//!   Pallas kernels (L1)  →  JAX transformer fwd/bwd (L2, AOT to HLO text)
//!   →  PJRT CPU runtime  →  rust parameter server + DANA-Slim (L3)
//!   →  N worker threads, each with its own PJRT client, training
//!      asynchronously against a Markov char corpus.
//!
//! Runs a few hundred master steps and logs the loss curve; the reference
//! run is recorded in EXPERIMENTS.md §E2E.  Python is never involved — the
//! binary consumes only `artifacts/`.
//!
//! Run with:  cargo run --release --example train_async [-- --workers 4 --steps 400 --mode real]

use dana::config::{default_artifacts_dir, TrainConfig, Workload};
use dana::optim::AlgorithmKind;
use dana::runtime::Engine;
use dana::train::{real_async, sim_trainer};
use dana::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let mut args = Args::parse_env(false)?;
    let workers = args.parse_or::<usize>("workers", 4)?;
    let steps = args.parse_or::<u64>("steps", 600)?;
    // "real" = OS threads + one PJRT client per worker (wall-clock async);
    // "sim"  = gamma-clock simulation (deterministic, single-threaded).
    let mode = args.str_or("mode", "real");
    args.finish()?;

    let engine = Engine::cpu(&default_artifacts_dir())?;
    let mut cfg = TrainConfig::preset(Workload::LmSmall, AlgorithmKind::DanaSlim, workers, 1.0);
    cfg.epochs = steps as f64 / cfg.schedule.steps_per_epoch as f64;
    cfg.schedule.decay_epochs = vec![cfg.epochs * 0.75];
    cfg.eval_every_epochs = cfg.epochs / 8.0;

    let v = engine.manifest().variant(&cfg.variant_name())?;
    println!(
        "end-to-end: {} ({} params) | DANA-Slim | {workers} async workers | {steps} master steps | mode={mode}",
        v.name, v.param_count
    );
    println!("corpus: seeded 2nd-order Markov chain, 64-char vocab (entropy floor ~1.2 nats)\n");

    let t0 = std::time::Instant::now();
    let report = match mode.as_str() {
        "real" => real_async::run(&cfg, &engine)?,
        "sim" => sim_trainer::run(&cfg, &engine)?,
        other => anyhow::bail!("mode {other:?} (real|sim)"),
    };

    println!("loss curve (train, sampled):");
    for (step, loss) in report.loss_curve.iter().step_by(4) {
        println!("  step {step:>5}  loss {loss:.4}");
    }
    println!("\neval curve:");
    for p in &report.curve {
        println!(
            "  epoch {:5.2}  token loss {:.4}  token err {:5.2}%",
            p.epoch, p.test_loss, p.test_error
        );
    }
    let throughput = report.steps as f64 / report.wall_secs;
    println!(
        "\nfinal token loss {:.4} (started ~4.16 = ln 64) | {:.1} master steps/s | {:.1}s wall",
        report.final_test_loss, throughput, t0.elapsed().as_secs_f64()
    );
    anyhow::ensure!(!report.diverged, "training diverged");
    // ln(64) = 4.159 is the no-skill starting point; the momentum-safe
    // async η descends steadily but needs ~2k steps to approach the ~1.2
    // nat Markov floor — the default 600-step demo must clear 4.0.
    let bar = if steps >= 2000 { 2.5 } else { 4.159 - 0.00025 * steps as f64 };
    anyhow::ensure!(
        report.final_test_loss < bar,
        "loss did not descend enough: {} (bar {bar})",
        report.final_test_loss
    );
    println!("train_async OK");
    Ok(())
}

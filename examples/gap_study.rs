//! Gap study (paper Section 3, Fig 2): train the same schedule under every
//! algorithm and watch the gap — the RMSE distance between the parameters a
//! gradient was computed on and the parameters it is applied to.
//!
//! Demonstrates the paper's central claim directly: all algorithms share
//! the identical lag, but the momentum algorithms' *gap* differs by an
//! order of magnitude, and the gap (not the lag) predicts final accuracy.
//!
//! Run with:  cargo run --release --example gap_study

use dana::config::{default_artifacts_dir, TrainConfig, Workload};
use dana::optim::AlgorithmKind;
use dana::runtime::Engine;
use dana::train::sim_trainer;

fn main() -> anyhow::Result<()> {
    let engine = Engine::cpu(&default_artifacts_dir())?;
    let algorithms = [
        AlgorithmKind::Asgd,
        AlgorithmKind::NagAsgd,
        AlgorithmKind::Lwp,
        AlgorithmKind::MultiAsgd,
        AlgorithmKind::DanaZero,
        AlgorithmKind::DanaSlim,
    ];
    println!("training the CIFAR-10 proxy on 8 async workers, 6 epochs each\n");
    println!(
        "{:<11} {:>10} {:>9} {:>10} {:>8}",
        "algorithm", "mean gap", "mean lag", "final err", "diverged"
    );
    let mut rows = Vec::new();
    for alg in algorithms {
        let mut cfg = TrainConfig::preset(Workload::C10, alg, 8, 6.0);
        cfg.metrics_every = 5;
        let rep = sim_trainer::run(&cfg, &engine)?;
        println!(
            "{:<11} {:>10.3e} {:>9.1} {:>9.2}% {:>8}",
            alg.name(),
            rep.mean_gap,
            rep.mean_lag,
            rep.final_test_error,
            rep.diverged
        );
        rows.push((alg, rep.mean_gap, rep.final_test_error));
    }
    // The paper's Fig 2(b)/§5.3 ordering: identical lag, but
    // gap(NAG-ASGD) >> gap(DANA) ~ gap(ASGD), and small gap <-> low error.
    let gap = |k: AlgorithmKind| rows.iter().find(|r| r.0 == k).unwrap().1;
    let ratio = gap(AlgorithmKind::NagAsgd) / gap(AlgorithmKind::DanaZero);
    println!("\nNAG-ASGD / DANA-Zero gap ratio: {ratio:.1}x (paper: ~an order of magnitude)");
    anyhow::ensure!(ratio > 3.0, "gap ordering did not reproduce");
    println!("gap_study OK");
    Ok(())
}

//! Parameter-server throughput: full pull→push cycles per second per
//! algorithm, including schedule evaluation, sent-copy bookkeeping and the
//! metrics tap.  The paper reports the master saturating around ~20 workers
//! (§C.1); this bench gives the per-update master cost that bounds it.
//!
//! The second half is the sharded-vs-monolithic sweep: the same cycle at
//! several parameter counts k and shard counts S, reporting effective
//! memory bandwidth.  Small k is dominated by the scoped-thread fan-out
//! (monolithic wins); past the crossover the sharded apply's parallel
//! memory streams win — the table makes the crossover visible.
//!
//! The `churn/...` rows measure the elastic-membership operations: the
//! cost of one leave+rejoin cycle (per-worker state retire/alloc at k
//! coordinates, fanned across shards in the sharded layout) and of a
//! pull→push cycle running interleaved with continuous churn.
//!
//! The `concurrent/...` rows are the ISSUE 4 scaling sweep: W serving
//! threads hammering one striped master (workers × shards grid), plus
//! the pulls-under-push duel that shows reads no longer queue behind an
//! in-flight apply on the striped backend.  Results land in
//! `BENCH_serve.json` at the repo root so the perf trajectory is tracked
//! in-tree from this PR on (CI refreshes the 2-worker smoke rows).
//!
//! Run: cargo bench --bench server [-- <filter>]

use dana::math::{self, KernelBackend};
use dana::optim::{make_algorithm, AlgorithmKind, LeavePolicy, LrSchedule, ScheduleConfig};
use dana::server::{
    make_serving_master, Master, ParameterServer, ServingMaster, ShardedParameterServer,
};
use dana::util::bench::{BenchSuite, CaseResult, NoCaseMatched};
use dana::util::parallel::{self, WorkerPool};
use dana::util::rng::Rng;

const K: usize = 101_386;
const N: usize = 8;

/// True when a suite's [`BenchSuite::finish_json`] failed only because
/// the `cargo bench -- <filter>` filter emptied it.
fn no_match(r: &anyhow::Result<Vec<CaseResult>>) -> bool {
    matches!(r, Err(e) if e.downcast_ref::<NoCaseMatched>().is_some())
}

fn schedule() -> LrSchedule {
    LrSchedule::new(ScheduleConfig {
        steps_per_epoch: 100,
        n_workers: N,
        ..ScheduleConfig::default()
    })
}

fn main() {
    let mut rng = Rng::new(2);
    let theta0: Vec<f32> = (0..K).map(|_| rng.normal() as f32).collect();
    let grad: Vec<f32> = (0..K).map(|_| 0.01 * rng.normal() as f32).collect();

    let mut b = BenchSuite::new("server");
    for kind in [
        AlgorithmKind::Asgd,
        AlgorithmKind::DanaSlim,
        AlgorithmKind::DanaZero,
        AlgorithmKind::DanaDc,
        AlgorithmKind::DcAsgd,
        AlgorithmKind::YellowFin,
    ] {
        let mut ps = ParameterServer::new(make_algorithm(kind, &theta0, N), schedule(), N);
        for w in 0..N {
            ps.pull(w);
        }
        let mut w = 0usize;
        b.bench(&format!("pull_push/{}", kind.name()), || {
            ps.push(w, &grad).unwrap();
            std::hint::black_box(ps.pull(w));
            w = (w + 1) % N;
        });
    }

    // metrics tap cost (gap = one fused norm pass over k)
    {
        let mut ps = ParameterServer::new(
            make_algorithm(AlgorithmKind::DanaZero, &theta0, N),
            schedule(),
            N,
        );
        ps.metrics.set_every(1);
        for w in 0..N {
            ps.pull(w);
        }
        let mut w = 0usize;
        b.bench("pull_push/dana-zero+metrics", || {
            ps.push(w, &grad).unwrap();
            std::hint::black_box(ps.pull(w));
            w = (w + 1) % N;
        });
    }

    // Elastic membership: cost of one leave + rejoin cycle (retire the
    // leaver's O(k) momentum slot, reallocate it for the joiner), and a
    // pull→push cycle with a membership change every 64 cycles — the
    // steady-state overhead a churning cluster pays on the master.
    for kind in [AlgorithmKind::DanaZero, AlgorithmKind::Easgd] {
        let mut ps = ParameterServer::new(make_algorithm(kind, &theta0, N), schedule(), N);
        for w in 0..N {
            ps.pull(w);
        }
        b.bench(&format!("churn/leave_rejoin/{}", kind.name()), || {
            ps.remove_worker(N - 1, LeavePolicy::Retire).unwrap();
            let slot = ps.add_worker();
            std::hint::black_box(slot);
        });
    }
    {
        let mut ps = ShardedParameterServer::new(
            AlgorithmKind::DanaZero,
            &theta0,
            schedule(),
            N,
            8,
        );
        for w in 0..N {
            ps.pull(w);
        }
        b.bench("churn/leave_rejoin/dana-zero/S=8", || {
            ps.remove_worker(N - 1, LeavePolicy::Fold).unwrap();
            let slot = ps.add_worker();
            std::hint::black_box(slot);
        });
    }
    {
        let mut ps = ParameterServer::new(
            make_algorithm(AlgorithmKind::DanaZero, &theta0, N),
            schedule(),
            N,
        );
        for w in 0..N {
            ps.pull(w);
        }
        let mut w = 0usize;
        let mut cycle = 0u64;
        b.bench("churn/pull_push_with_churn/dana-zero", || {
            cycle += 1;
            if cycle % 64 == 0 {
                ps.remove_worker(w, LeavePolicy::Retire).unwrap();
                let slot = ps.add_worker();
                ps.pull(slot);
            }
            ps.push(w, &grad).unwrap();
            std::hint::black_box(ps.pull(w));
            w = (w + 1) % N;
        });
    }

    // Sharded vs. monolithic sweep: same pull→push cycle, k × S grid.
    // DANA-Zero touches 4 streams on push (θ, vᶦ, v⁰, g) and 3 on pull
    // (θ, v⁰, sent), ~28 bytes/coordinate per cycle — the bytes figure
    // makes the bandwidth ceiling comparable across rows.
    let sweep_n = 4usize;
    let sweep_schedule = || {
        LrSchedule::new(ScheduleConfig {
            steps_per_epoch: 100,
            n_workers: sweep_n,
            ..ScheduleConfig::default()
        })
    };
    for &k in &[65_536usize, 1_048_576, 4_194_304] {
        let mut rng = Rng::new(3);
        let theta0: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
        let grad: Vec<f32> = (0..k).map(|_| 0.01 * rng.normal() as f32).collect();
        let bytes = Some((k * 4 * 7) as u64);
        let label_k = if k >= 1_048_576 {
            format!("{}m", k / 1_048_576)
        } else {
            format!("{}k", k / 1024)
        };

        {
            let mut ps = ParameterServer::new(
                make_algorithm(AlgorithmKind::DanaZero, &theta0, sweep_n),
                sweep_schedule(),
                sweep_n,
            );
            for w in 0..sweep_n {
                ps.pull(w);
            }
            let mut w = 0usize;
            b.bench_with_bytes(&format!("sweep/dana-zero/k={label_k}/mono"), bytes, || {
                ps.push(w, &grad).unwrap();
                std::hint::black_box(ps.pull(w));
                w = (w + 1) % sweep_n;
            });
        }

        for &shards in &[2usize, 4, 8] {
            let mut ps = ShardedParameterServer::new(
                AlgorithmKind::DanaZero,
                &theta0,
                sweep_schedule(),
                sweep_n,
                shards,
            )
            .with_threads(shards);
            // retained pull buffer: measure the server's own memory traffic,
            // not a per-cycle 4k-byte allocation the mono row doesn't pay
            let mut buf = vec![0.0f32; k];
            for w in 0..sweep_n {
                ps.pull_into_buf(w, &mut buf);
            }
            let mut w = 0usize;
            b.bench_with_bytes(
                &format!("sweep/dana-zero/k={label_k}/S={shards}"),
                bytes,
                || {
                    ps.push(w, &grad).unwrap();
                    ps.pull_into_buf(w, &mut buf);
                    std::hint::black_box(&buf);
                    w = (w + 1) % sweep_n;
                },
            );
        }
    }

    // Loopback transport: the same pull→push cycle through `NetServer` +
    // `RemoteMaster` over 127.0.0.1, vs the in-process rows above — the
    // framing/syscall overhead a real deployment pays per master cycle
    // (2 frames ≈ 2·4k bytes each way at k=101386).
    for &k in &[4_096usize, K] {
        let theta0: Vec<f32> = (0..k).map(|i| (i as f32 * 0.7).sin()).collect();
        let grad: Vec<f32> = vec![0.01; k];
        let label_k = if k >= 100_000 { "101k".to_string() } else { format!("{}k", k / 1024) };
        let master: Box<dyn dana::server::Master> = Box::new(ParameterServer::new(
            make_algorithm(AlgorithmKind::DanaZero, &theta0, 0),
            schedule(),
            0,
        ));
        let mut srv = dana::net::NetServer::start(
            master,
            "127.0.0.1:0",
            dana::net::ServeOptions::default(),
        )
        .expect("bind loopback");
        let mut rm =
            dana::net::RemoteMaster::connect(&srv.url(), N).expect("connect loopback");
        let mut buf = vec![0.0f32; k];
        for w in 0..N {
            rm.pull_into(w, &mut buf);
        }
        let mut w = 0usize;
        b.bench_with_bytes(
            &format!("loopback/dana-zero/k={label_k}"),
            Some((k * 4 * 2) as u64),
            || {
                rm.push_update(w, &grad).unwrap();
                rm.pull_into(w, &mut buf);
                std::hint::black_box(&buf);
                w = (w + 1) % N;
            },
        );
        drop(rm);
        srv.stop();
    }

    // Concurrent scaling sweep (workers × shards): W threads each run a
    // full pull→push cycle per iteration against ONE striped master
    // through the `&self` serving API — the thread interleaving the TCP
    // server produces, minus the sockets.  Scoped-thread setup is part of
    // each iteration (identical across rows, so the W/S trends stand).
    {
        let kc = 1_048_576usize;
        let mut rng = Rng::new(5);
        let theta0: Vec<f32> = (0..kc).map(|_| rng.normal() as f32).collect();
        let grad: Vec<f32> = (0..kc).map(|_| 0.01 * rng.normal() as f32).collect();
        for &shards in &[1usize, 4, 8] {
            for &workers in &[1usize, 2, 4, 8] {
                let ps = ShardedParameterServer::new(
                    AlgorithmKind::DanaZero,
                    &theta0,
                    schedule(),
                    workers,
                    shards,
                )
                .with_threads(1);
                for w in 0..workers {
                    ps.pull_concurrent(w).unwrap();
                }
                // retained per-worker pull buffers: measure the server's
                // memory traffic, not a per-cycle 4 MiB allocation
                let bufs: Vec<std::sync::Mutex<Vec<f32>>> =
                    (0..workers).map(|_| std::sync::Mutex::new(vec![0.0f32; kc])).collect();
                // 7 streams/coordinate per cycle (see the sweep above),
                // times W concurrent workers per iteration
                let bytes = Some((kc * 4 * 7 * workers) as u64);
                b.bench_with_bytes(
                    &format!("concurrent/pull_push/w={workers}/S={shards}"),
                    bytes,
                    || {
                        std::thread::scope(|s| {
                            for w in 0..workers {
                                let ps = &ps;
                                let grad = &grad;
                                let bufs = &bufs;
                                s.spawn(move || {
                                    ps.push_concurrent(w, grad).unwrap();
                                    let mut buf = bufs[w].lock().unwrap();
                                    ps.pull_into_concurrent(w, &mut buf).unwrap();
                                    std::hint::black_box(&*buf);
                                });
                            }
                        });
                    },
                );
            }
        }

        // Pulls under a continuous push load: 3 readers + 1 writer per
        // iteration.  On the global-lock backend every pull queues behind
        // the writer's O(k) apply; on the striped backend pulls take
        // per-shard read locks and only ever wait for the one shard
        // currently being written.
        for striped in [false, true] {
            let mut sm = make_serving_master(
                AlgorithmKind::DanaZero,
                &theta0,
                schedule(),
                4,
                8,
                1,
                striped,
            );
            sm.set_metrics_every(0);
            let sm: &dyn ServingMaster = &*sm;
            for w in 0..4 {
                sm.pull(w).unwrap();
            }
            let label = if striped { "striped" } else { "locked" };
            b.bench_with_bytes(
                &format!("concurrent/pulls_under_push/{label}"),
                Some((kc * 4 * 7 * 4) as u64),
                || {
                    std::thread::scope(|s| {
                        let grad = &grad;
                        s.spawn(move || {
                            sm.push(0, grad).unwrap();
                            sm.push(0, grad).unwrap();
                        });
                        for w in 1..4usize {
                            s.spawn(move || {
                                std::hint::black_box(sm.pull(w).unwrap());
                                std::hint::black_box(sm.pull(w).unwrap());
                            });
                        }
                    });
                },
            );
        }
    }

    // Kernel microbenches (PR 10): each dispatched hot kernel under the
    // scalar reference and the widest SIMD backend this host can run, at
    // k ∈ {1e4, 1e5, 1e6}.  The scalar-vs-SIMD ratio per row is the
    // dispatch layer's whole payoff; the committed rows in
    // BENCH_serve.json gate regressions in CI.  On a host whose widest
    // backend IS scalar (no AVX2/NEON), only the scalar rows appear.
    {
        let widest = *math::available_backends().last().unwrap();
        let mut backends = vec![KernelBackend::Scalar];
        if widest != KernelBackend::Scalar {
            backends.push(widest);
        }
        for &k in &[10_000usize, 100_000, 1_000_000] {
            let label_k = match k {
                10_000 => "10k",
                100_000 => "100k",
                _ => "1m",
            };
            let mut rng = Rng::new(7);
            let g: Vec<f32> = (0..k).map(|_| 0.01 * rng.normal() as f32).collect();
            let sent: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
            let mut theta: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
            let mut vel = vec![0.0f32; k];
            let mut vsum = vec![0.0f32; k];
            let mut hat = vec![0.0f32; k];
            let mut halves: Vec<u8> = Vec::new();
            math::f16_encode_into(&mut halves, &theta); // decode-row fixture
            for &backend in &backends {
                let row = |name: &str| format!("kernels/{name}/k={label_k}/{backend}");
                math::with_backend(backend, || {
                    b.bench_with_bytes(&row("axpy"), Some((k * 4 * 3) as u64), || {
                        math::axpy(&mut theta, -1e-6, &g);
                        std::hint::black_box(&theta);
                    });
                    b.bench_with_bytes(&row("momentum_step"), Some((k * 4 * 5) as u64), || {
                        math::momentum_step(&mut theta, &mut vel, &g, 0.9, 1e-4);
                        std::hint::black_box(&theta);
                    });
                    b.bench_with_bytes(
                        &row("dana_fused_update"),
                        Some((k * 4 * 7) as u64),
                        || {
                            math::dana_fused_update(
                                &mut theta, &mut vel, &mut vsum, &g, 0.9, 1e-4,
                            );
                            std::hint::black_box(&theta);
                        },
                    );
                    b.bench_with_bytes(
                        &row("dc_dana_fused_update"),
                        Some((k * 4 * 9) as u64),
                        || {
                            math::dc_dana_fused_update(
                                &mut theta, &mut vel, &mut vsum, &g, &sent, 0.9, 1e-4, 0.1,
                            );
                            std::hint::black_box(&theta);
                        },
                    );
                    b.bench_with_bytes(&row("lookahead"), Some((k * 4 * 3) as u64), || {
                        math::lookahead(&mut hat, &theta, &vsum, 0.9, 1e-4);
                        std::hint::black_box(&hat);
                    });
                    b.bench_with_bytes(&row("dc_adjust"), Some((k * 4 * 4) as u64), || {
                        let mut gg = std::hint::black_box(&g).clone();
                        math::dc_adjust(&mut gg, &theta, &sent, 0.1);
                        std::hint::black_box(&gg);
                    });
                    b.bench_with_bytes(
                        &row("slim_worker_update_inplace"),
                        Some((k * 4 * 4) as u64),
                        || {
                            let mut gg = std::hint::black_box(&g).clone();
                            math::slim_worker_update_inplace(&mut vel, &mut gg, 0.9);
                            std::hint::black_box(&gg);
                        },
                    );
                    b.bench_with_bytes(&row("dot"), Some((k * 4 * 2) as u64), || {
                        std::hint::black_box(math::dot(&theta, &g));
                    });
                    b.bench_with_bytes(&row("sub_norm_sq"), Some((k * 4 * 2) as u64), || {
                        std::hint::black_box(math::sub_norm_sq(&theta, &sent));
                    });
                    b.bench_with_bytes(&row("f16_encode"), Some((k * 6) as u64), || {
                        let mut out = Vec::with_capacity(2 * k);
                        math::f16_encode_into(&mut out, &theta);
                        std::hint::black_box(&out);
                    });
                    b.bench_with_bytes(&row("f16_decode"), Some((k * 6) as u64), || {
                        let mut out = Vec::with_capacity(k);
                        math::f16_decode_into(&mut out, &halves);
                        std::hint::black_box(&out);
                    });
                    b.bench_with_bytes(&row("bf16_encode"), Some((k * 6) as u64), || {
                        let mut out = Vec::with_capacity(2 * k);
                        math::bf16_encode_into(&mut out, &theta);
                        std::hint::black_box(&out);
                    });
                    b.bench_with_bytes(&row("bf16_decode"), Some((k * 6) as u64), || {
                        let mut out = Vec::with_capacity(k);
                        math::bf16_decode_into(&mut out, &halves);
                        std::hint::black_box(&out);
                    });
                });
            }
        }
    }

    // Apply fan-out duel (PR 10): the same chunked elementwise apply at
    // k=1e6, fanned out by spawn-per-call scoped threads vs the
    // persistent parked `WorkerPool` — the pooled row should shed the
    // per-apply thread spawn/teardown cost while the chunk boundaries
    // (and therefore results) are identical.
    {
        let ka = 1_048_576usize;
        let threads = parallel::default_threads().clamp(2, 8);
        let mut rng = Rng::new(9);
        let g: Vec<f32> = (0..ka).map(|_| 0.01 * rng.normal() as f32).collect();
        let mut theta: Vec<f32> = (0..ka).map(|_| rng.normal() as f32).collect();
        let chunk = ka.div_ceil(threads);
        let body = |i: usize, c: &mut [f32]| {
            let off = i * chunk;
            math::axpy(c, -1e-6, &g[off..off + c.len()]);
        };
        let bytes = Some((ka * 4 * 3) as u64);
        b.bench_with_bytes(
            &format!("concurrent/apply_pool/scoped/T={threads}"),
            bytes,
            || {
                parallel::par_chunks_mut(&mut theta, threads, &body);
                std::hint::black_box(&theta);
            },
        );
        let pool = WorkerPool::new(threads);
        b.bench_with_bytes(
            &format!("concurrent/apply_pool/pooled/T={threads}"),
            bytes,
            || {
                pool.par_chunks_mut(&mut theta, &body);
                std::hint::black_box(&theta);
            },
        );
    }

    let serve_written = b.finish_json("BENCH_serve.json");

    // ---------------------------------------------------------- train
    // Worker-cycle rows (BENCH_train.json): one full pipelined worker
    // cycle — push + pull — against a loopback TCP master, sync (D=0,
    // blocking round trips) vs pipelined (D∈{1,2}, deferred-ack sends:
    // the push frame goes out, the following pull harvests its ack, so a
    // cycle costs ONE combined round trip).  The in-process row prices
    // the master work alone, bounding what the transport adds.
    let mut bt = BenchSuite::new("train");
    let kt = 65_536usize;
    let theta0: Vec<f32> = (0..kt).map(|i| (i as f32 * 0.7).sin()).collect();
    let grad: Vec<f32> = vec![0.01; kt];
    {
        let mut ps = ParameterServer::new(
            make_algorithm(AlgorithmKind::DanaZero, &theta0, 1),
            schedule(),
            1,
        );
        ps.pull(0);
        bt.bench_with_bytes("cycle/in_process/dana-zero", Some((kt * 4 * 7) as u64), || {
            ps.push(0, &grad).unwrap();
            std::hint::black_box(ps.pull(0));
        });
    }
    // encoding axis (wire v4): exact f32 frames vs f16-quantized payloads
    // — the f16 rows show the framing overhead at half the payload bytes.
    for &enc in &[dana::net::Encoding::None, dana::net::Encoding::F16] {
        for &depth in &[0usize, 1, 2] {
            let master: Box<dyn Master> = Box::new(ParameterServer::new(
                make_algorithm(AlgorithmKind::DanaZero, &theta0, 0),
                schedule(),
                0,
            ));
            let opts = dana::net::ServeOptions { pipeline_depth: depth, ..Default::default() };
            let mut srv =
                dana::net::NetServer::start(master, "127.0.0.1:0", opts).expect("bind loopback");
            let mut rm = dana::net::RemoteMaster::connect_with(&srv.url(), 1, None, enc)
                .expect("connect loopback");
            rm.set_pipeline_depth(depth);
            let mut buf = vec![0.0f32; kt];
            for _ in 0..=depth {
                rm.pull_into(0, &mut buf); // prime the pipeline window
            }
            // bytes/step from the client's own wire counters over a short
            // calibration run — the JSON row carries measured two-way
            // traffic per cycle, not a nominal payload estimate
            let calib = 16u64;
            let (tx0, rx0) = rm.wire_bytes();
            for _ in 0..calib {
                rm.push_update(0, &grad).unwrap();
                rm.pull_into(0, &mut buf);
            }
            rm.drain_inflight().unwrap();
            let (tx1, rx1) = rm.wire_bytes();
            let bytes = Some(((tx1 - tx0) + (rx1 - rx0)) / calib);
            let label = if depth == 0 { "sync" } else { "pipelined" };
            bt.bench_with_bytes(
                &format!("cycle/loopback/{label}/{enc}/D={depth}"),
                bytes,
                || {
                    rm.push_update(0, &grad).unwrap();
                    rm.pull_into(0, &mut buf);
                    std::hint::black_box(&buf);
                },
            );
            rm.drain_inflight().unwrap();
            drop(rm);
            srv.stop();
        }
    }
    let train_written = bt.finish_json("BENCH_train.json");

    // A filter legitimately empties ONE suite (CI runs `-- w=2` and
    // `-- cycle` against this binary, each hitting a single suite); a
    // filter that matched nothing ANYWHERE is a typo and must fail the
    // run, not leave CI green with stale tracked files.
    if no_match(&serve_written) && no_match(&train_written) {
        eprintln!("bench filter matched no case in any suite");
        std::process::exit(1);
    }
    for r in [serve_written, train_written] {
        match r {
            Ok(_) => {}
            Err(e) if e.downcast_ref::<NoCaseMatched>().is_some() => {
                println!("{e}; the filter ran in the other suite");
            }
            Err(e) => {
                eprintln!("bench error: {e:#}");
                std::process::exit(1);
            }
        }
    }
}

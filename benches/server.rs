//! Parameter-server throughput: full pull→push cycles per second per
//! algorithm, including schedule evaluation, sent-copy bookkeeping and the
//! metrics tap.  The paper reports the master saturating around ~20 workers
//! (§C.1); this bench gives the per-update master cost that bounds it.
//!
//! Run: cargo bench --bench server [-- <filter>]

use dana::optim::{make_algorithm, AlgorithmKind, LrSchedule, ScheduleConfig};
use dana::server::ParameterServer;
use dana::util::bench::BenchSuite;
use dana::util::rng::Rng;

const K: usize = 101_386;
const N: usize = 8;

fn schedule() -> LrSchedule {
    LrSchedule::new(ScheduleConfig {
        steps_per_epoch: 100,
        n_workers: N,
        ..ScheduleConfig::default()
    })
}

fn main() {
    let mut rng = Rng::new(2);
    let theta0: Vec<f32> = (0..K).map(|_| rng.normal() as f32).collect();
    let grad: Vec<f32> = (0..K).map(|_| 0.01 * rng.normal() as f32).collect();

    let mut b = BenchSuite::new("server");
    for kind in [
        AlgorithmKind::Asgd,
        AlgorithmKind::DanaSlim,
        AlgorithmKind::DanaZero,
        AlgorithmKind::DanaDc,
        AlgorithmKind::DcAsgd,
        AlgorithmKind::YellowFin,
    ] {
        let mut ps = ParameterServer::new(make_algorithm(kind, &theta0, N), schedule(), N);
        for w in 0..N {
            ps.pull(w);
        }
        let mut w = 0usize;
        b.bench(&format!("pull_push/{}", kind.name()), || {
            ps.push(w, &grad);
            std::hint::black_box(ps.pull(w));
            w = (w + 1) % N;
        });
    }

    // metrics tap cost (gap = one fused norm pass over k)
    {
        let mut ps = ParameterServer::new(
            make_algorithm(AlgorithmKind::DanaZero, &theta0, N),
            schedule(),
            N,
        );
        ps.metrics.set_every(1);
        for w in 0..N {
            ps.pull(w);
        }
        let mut w = 0usize;
        b.bench("pull_push/dana-zero+metrics", || {
            ps.push(w, &grad);
            std::hint::black_box(ps.pull(w));
            w = (w + 1) % N;
        });
    }
    b.finish();
}

//! End-to-end experiment throughput — one timed miniature of each paper
//! table/figure family, so `cargo bench` tracks the cost of the full
//! reproduction harness (the actual figures are regenerated with
//! `dana experiment <id>`, see DESIGN.md §5).
//!
//! Run: cargo bench --bench tables [-- <filter>]   (needs `make artifacts`)

use dana::config::{default_artifacts_dir, TrainConfig, Workload};
use dana::optim::AlgorithmKind;
use dana::runtime::Engine;
use dana::sim::gamma::Environment;
use dana::sim::speedup;
use dana::train::{sim_trainer, ssgd};
use dana::util::bench::BenchSuite;

fn main() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("tables bench skipped: run `make artifacts` first");
        return;
    }
    std::env::set_var("BENCH_SAMPLE_MS", "200");
    std::env::set_var("BENCH_SAMPLES", "3");
    let engine = Engine::cpu(&dir).unwrap();
    let mut b = BenchSuite::new("tables");

    // Fig 2 / 11 family: one instrumented gap run (1 epoch, N=8)
    b.bench("fig2_gap_run_1epoch", || {
        let mut cfg = TrainConfig::preset(Workload::C10, AlgorithmKind::DanaZero, 8, 1.0);
        cfg.metrics_every = 10;
        std::hint::black_box(sim_trainer::run(&cfg, &engine).unwrap());
    });

    // Fig 4 / Tables 2-4 family: one accuracy cell (1 epoch, N=16)
    b.bench("fig4_accuracy_cell_1epoch", || {
        let cfg = TrainConfig::preset(Workload::C10, AlgorithmKind::DanaSlim, 16, 1.0);
        std::hint::black_box(sim_trainer::run(&cfg, &engine).unwrap());
    });

    // Fig 7 / Table 5 family: one ImageNet-proxy cell (0.5 epoch, N=32)
    b.bench("fig7_imagenet_cell_halfepoch", || {
        let cfg = TrainConfig::preset(Workload::ImageNet, AlgorithmKind::DanaSlim, 32, 0.5);
        std::hint::black_box(sim_trainer::run(&cfg, &engine).unwrap());
    });

    // Fig 9 / Table 1 family: one SSGD round set (0.5 epoch, total batch 1024)
    b.bench("table1_ssgd_halfepoch", || {
        let cfg = TrainConfig::preset(Workload::C10, AlgorithmKind::DanaSlim, 8, 0.5)
            .with_batch(128);
        std::hint::black_box(ssgd::run(&cfg, &engine).unwrap());
    });

    // Fig 12 family: pure timing sweep
    b.bench("fig12_speedup_sweep", || {
        std::hint::black_box(speedup::speedup_sweep(
            Environment::Heterogeneous,
            &[8, 32],
            128,
            30,
            2,
        ));
    });

    b.finish();
}

//! Master-side update-rule micro-benchmarks — the L3 request-path hot loop.
//!
//! The master apply is memory-bandwidth bound (every algorithm streams 2–4
//! k-length f32 vectors once); the report prints effective GB/s so the
//! §Perf pass can compare against the machine's triad roofline.
//!
//! Run: cargo bench --bench optimizer [-- <filter>]

use dana::math;
use dana::optim::{make_algorithm, Algorithm, AlgorithmKind, Step};
use dana::util::bench::BenchSuite;
use dana::util::rng::Rng;

const K: usize = 101_386; // mlp_c10 parameter count
const N_WORKERS: usize = 8;

fn main() {
    let mut rng = Rng::new(1);
    let theta0: Vec<f32> = (0..K).map(|_| rng.normal() as f32).collect();
    let grad: Vec<f32> = (0..K).map(|_| 0.01 * rng.normal() as f32).collect();
    let s = Step { eta: 0.05, gamma: 0.9, lambda: 1.0 };

    let mut b = BenchSuite::new("optimizer");

    // raw fused loops (the primitives every rule composes)
    let bytes_triad = (3 * K * 4) as u64;
    {
        let mut theta = theta0.clone();
        b.bench_with_bytes("math/apply_update(asgd core)", Some((2 * K * 4) as u64), || {
            math::apply_update(&mut theta, &grad, 0.05);
        });
    }
    {
        let mut theta = theta0.clone();
        let mut v = vec![0.0f32; K];
        b.bench_with_bytes("math/momentum_step", Some(bytes_triad), || {
            math::momentum_step(&mut theta, &mut v, &grad, 0.9, 0.05);
        });
    }
    {
        let mut theta = theta0.clone();
        let mut v = vec![0.0f32; K];
        let mut vsum = vec![0.0f32; K];
        b.bench_with_bytes("math/dana_fused_update", Some((4 * K * 4) as u64), || {
            math::dana_fused_update(&mut theta, &mut v, &mut vsum, &grad, 0.9, 0.05);
        });
    }
    {
        let mut hat = vec![0.0f32; K];
        let vsum = theta0.clone();
        b.bench_with_bytes("math/lookahead(send path)", Some(bytes_triad), || {
            math::lookahead(&mut hat, &theta0, &vsum, 0.9, 0.05);
        });
    }
    {
        let mut g = grad.clone();
        b.bench_with_bytes("math/dc_adjust", Some(bytes_triad), || {
            math::dc_adjust(&mut g, &theta0, &theta0, 1.0);
        });
    }
    {
        b.bench_with_bytes("math/sub_norm(gap metric)", Some((2 * K * 4) as u64), || {
            std::hint::black_box(math::sub_norm(&theta0, &grad));
        });
    }

    // full master_apply per algorithm (one push through the trait object)
    for kind in AlgorithmKind::ALL {
        let mut alg = make_algorithm(kind, &theta0, N_WORKERS);
        let sent = theta0.clone();
        let mut w = 0usize;
        b.bench(&format!("master_apply/{}", kind.name()), || {
            alg.master_apply(w, &grad, &sent, s);
            w = (w + 1) % N_WORKERS;
        });
    }

    // the O(k) incremental v0 (paper Appendix A.2) vs the naive O(kN) sum
    {
        use dana::optim::dana_zero::DanaZero;
        let mut d = DanaZero::new(&theta0, N_WORKERS);
        for w in 0..N_WORKERS {
            d.master_apply(w, &grad, &theta0, s);
        }
        b.bench("dana_vsum/incremental(O(k))", || {
            d.master_apply(0, &grad, &theta0, s);
        });
        b.bench("dana_vsum/full_recompute(O(kN))", || {
            std::hint::black_box(d.recompute_vsum());
        });
    }

    b.finish();
}

//! PJRT runtime latency: AOT train/eval step execution per variant, plus
//! the DANA-master-update-as-XLA-kernel ablation (native fused loop vs the
//! L1 Pallas kernel executed through PJRT).
//!
//! Run: cargo bench --bench runtime [-- <filter>]   (needs `make artifacts`)

use dana::config::default_artifacts_dir;
use dana::runtime::{Engine, Input};
use dana::util::bench::BenchSuite;
use dana::util::rng::Rng;

fn main() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("runtime bench skipped: run `make artifacts` first");
        return;
    }
    let engine = Engine::cpu(&dir).unwrap();
    let mut b = BenchSuite::new("runtime");

    for name in ["mlp_c10_ref", "mlp_c10", "mlp_inet_ref", "lm_small_ref"] {
        let model = engine.load_model(name).unwrap();
        let v = engine.manifest().variant(name).unwrap().clone();
        let params = engine.init_params(name).unwrap();
        let gy = dana::runtime::manifest::read_i32_file(&v.golden_y).unwrap();
        if v.x_dtype == "f32" {
            let gx = dana::runtime::manifest::read_f32_file(&v.golden_x).unwrap();
            b.bench(&format!("train_step/{name}"), || {
                std::hint::black_box(
                    model.train_step(&params, Input::F32(&gx), &gy).unwrap(),
                );
            });
            b.bench(&format!("eval_step/{name}"), || {
                std::hint::black_box(model.eval_step(&params, Input::F32(&gx), &gy).unwrap());
            });
        } else {
            let gx = dana::runtime::manifest::read_i32_file(&v.golden_x).unwrap();
            b.bench(&format!("train_step/{name}"), || {
                std::hint::black_box(
                    model.train_step(&params, Input::I32(&gx), &gy).unwrap(),
                );
            });
        }
    }

    // Ablation: the fused DANA master update, native loop vs PJRT kernel.
    let uk = engine.load_update_kernel().unwrap();
    let k = uk.k();
    let mut rng = Rng::new(3);
    let mk = |rng: &mut Rng| -> Vec<f32> { (0..k).map(|_| rng.normal() as f32).collect() };
    let (mut theta, mut v, mut vsum, g) = (mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng));
    b.bench_with_bytes(
        "master_update_native/131072",
        Some((4 * k * 4) as u64),
        || {
            dana::math::dana_fused_update(&mut theta, &mut v, &mut vsum, &g, 0.9, 0.05);
        },
    );
    b.bench_with_bytes("master_update_xla/131072", Some((4 * k * 4) as u64), || {
        std::hint::black_box(uk.apply(0.9, 0.05, &theta, &v, &vsum, &g).unwrap());
    });

    b.finish();
}

"""AOT compile path: lower every model variant to HLO text + manifest.

Run once via ``make artifacts`` (no-op when inputs are unchanged); the rust
runtime consumes only the ``artifacts/`` directory.  Interchange is HLO
*text*, not serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit
instruction ids that xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Per variant this emits:
  <name>.train.hlo.txt   train_step(params, x, y) -> (loss, grads)
  <name>.eval.hlo.txt    eval_step(params, x, y)  -> (loss, correct)
  <name>.init.f32        raw little-endian f32 initial parameters
  <name>.golden.x.{f32,i32} / .y.i32   fixed input batch
plus golden loss/grad values in manifest.json so the rust integration tests
can verify the runtime end-to-end against python numerics.

Usage: python -m compile.aot [--out-dir ../artifacts] [--only name1,name2]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as mlp_model
from . import transformer as lm_model
from .kernels.update import momentum_lookahead_update

FORMAT_VERSION = 1


def to_hlo_text(lowered) -> str:
    """jax lowering -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


@dataclasses.dataclass(frozen=True)
class Variant:
    """One AOT artifact bundle: a model architecture at a fixed batch shape."""

    name: str
    kind: str  # "mlp" | "lm"
    cfg: object
    batch: int

    def data_shapes(self):
        if self.kind == "mlp":
            x = (self.batch, self.cfg.in_dim)
            y = (self.batch,)
            x_dtype = "f32"
        else:
            x = (self.batch, self.cfg.seq)
            y = (self.batch, self.cfg.seq)
            x_dtype = "i32"
        return x, y, x_dtype


def variants() -> list[Variant]:
    mlp = mlp_model.MLPConfig
    lm = lm_model.LMConfig
    return [
        # CIFAR-10 proxy (paper: ResNet-20/CIFAR-10). Pallas hot path.
        Variant("mlp_c10", "mlp", mlp(128, (256, 256), 10, "relu", True), 128),
        # Same architecture lowered through the pure-jnp reference path:
        # independent build of the same math, used for cross-checking and as
        # the fast CPU variant for full experiment grids.
        Variant("mlp_c10_ref", "mlp", mlp(128, (256, 256), 10, "relu", False), 128),
        # WRN-16-4/CIFAR-10 proxy: same dataset as mlp_c10, wider student.
        Variant("mlp_wrn10_ref", "mlp", mlp(128, (384, 384), 10, "relu", False), 128),
        # CIFAR-100 proxy (paper: WRN-16-4/CIFAR-100).
        Variant("mlp_c100_ref", "mlp", mlp(128, (256, 256), 100, "relu", False), 128),
        # Alternate-batch builds of the C10 proxy for the total-batch-size
        # scaling study (paper Fig 9 / Table 1: 8 workers x {32..256}/GPU).
        Variant("mlp_c10_b32_ref", "mlp", mlp(128, (256, 256), 10, "relu", False), 32),
        Variant("mlp_c10_b64_ref", "mlp", mlp(128, (256, 256), 10, "relu", False), 64),
        Variant("mlp_c10_b256_ref", "mlp", mlp(128, (256, 256), 10, "relu", False), 256),
        # ImageNet proxy (paper: ResNet-50/ImageNet); smaller batch keeps the
        # 64-worker sweeps tractable on CPU (DESIGN.md §3).
        Variant("mlp_inet_ref", "mlp", mlp(128, (256, 384), 100, "relu", False), 64),
        # End-to-end char-LM workload (examples/train_async.rs).
        Variant("lm_small_ref", "lm", lm(64, 64, 128, 4, 2, 512, False), 16),
        # Pallas-kernel build of the same LM (validation + kernel demo).
        Variant("lm_small", "lm", lm(64, 64, 128, 4, 2, 512, True), 16),
    ]


def _golden_inputs(v: Variant, seed: int = 1234):
    """Deterministic input batch for the golden cross-check."""
    rng = np.random.default_rng(seed)
    x_shape, y_shape, x_dtype = v.data_shapes()
    if v.kind == "mlp":
        x = rng.standard_normal(x_shape, dtype=np.float32)
        y = rng.integers(0, v.cfg.classes, size=y_shape).astype(np.int32)
    else:
        x = rng.integers(0, v.cfg.vocab, size=x_shape).astype(np.int32)
        y = rng.integers(0, v.cfg.vocab, size=y_shape).astype(np.int32)
    return x, y


def build_variant(v: Variant, out_dir: str) -> dict:
    t0 = time.time()
    if v.kind == "mlp":
        train_step, eval_step, flat0 = mlp_model.make_steps(v.cfg)
    else:
        train_step, eval_step, flat0 = lm_model.make_steps(v.cfg)
    p = int(flat0.shape[0])
    x_shape, y_shape, x_dtype = v.data_shapes()
    x_spec = jax.ShapeDtypeStruct(x_shape, jnp.float32 if x_dtype == "f32" else jnp.int32)
    y_spec = jax.ShapeDtypeStruct(y_shape, jnp.int32)
    p_spec = jax.ShapeDtypeStruct((p,), jnp.float32)

    train_hlo = to_hlo_text(jax.jit(train_step).lower(p_spec, x_spec, y_spec))
    eval_hlo = to_hlo_text(jax.jit(eval_step).lower(p_spec, x_spec, y_spec))

    files = {
        "train": f"{v.name}.train.hlo.txt",
        "eval": f"{v.name}.eval.hlo.txt",
        "init": f"{v.name}.init.f32",
        "golden_x": f"{v.name}.golden.x.{x_dtype}",
        "golden_y": f"{v.name}.golden.y.i32",
    }
    with open(os.path.join(out_dir, files["train"]), "w") as f:
        f.write(train_hlo)
    with open(os.path.join(out_dir, files["eval"]), "w") as f:
        f.write(eval_hlo)
    np.asarray(flat0).astype("<f4").tofile(os.path.join(out_dir, files["init"]))

    # Golden cross-check: run the *python* step on a fixed batch and record
    # the numbers the rust runtime must reproduce from the HLO artifact.
    gx, gy = _golden_inputs(v)
    gx.astype("<f4" if x_dtype == "f32" else "<i4").tofile(
        os.path.join(out_dir, files["golden_x"])
    )
    gy.astype("<i4").tofile(os.path.join(out_dir, files["golden_y"]))
    loss, grads = jax.jit(train_step)(flat0, gx, gy)
    eloss, ecorrect = jax.jit(eval_step)(flat0, gx, gy)
    grads = np.asarray(grads)

    entry = {
        "name": v.name,
        "kind": v.kind,
        "param_count": p,
        "batch": v.batch,
        "x_shape": list(x_shape),
        "y_shape": list(y_shape),
        "x_dtype": x_dtype,
        "arch": dataclasses.asdict(v.cfg),
        "files": files,
        "golden": {
            "loss": float(loss),
            "grad_l2": float(np.linalg.norm(grads)),
            "grad_prefix": [float(g) for g in grads[:8]],
            "eval_loss": float(eloss),
            "eval_correct": float(ecorrect),
        },
    }
    print(f"  {v.name}: P={p} train_hlo={len(train_hlo)//1024}KiB "
          f"loss={float(loss):.4f} ({time.time()-t0:.1f}s)")
    return entry


def build_update_kernel(out_dir: str, k: int = 1 << 17) -> dict:
    """Lower the fused DANA master-update kernel (ablation artifact)."""
    s = jax.ShapeDtypeStruct((1,), jnp.float32)
    vec = jax.ShapeDtypeStruct((k,), jnp.float32)
    fn = lambda gamma, eta, th, v, vs, g: momentum_lookahead_update(
        gamma, eta, th, v, vs, g
    )
    hlo = to_hlo_text(jax.jit(fn).lower(s, s, vec, vec, vec, vec))
    fname = "update.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(hlo)
    # golden
    rng = np.random.default_rng(7)
    th, v, vs, g = (rng.standard_normal(k).astype(np.float32) for _ in range(4))
    outs = momentum_lookahead_update(
        jnp.array([0.9]), jnp.array([0.05]),
        jnp.asarray(th), jnp.asarray(v), jnp.asarray(vs), jnp.asarray(g),
    )
    golden = {
        "seed": 7,
        "gamma": 0.9,
        "eta": 0.05,
        "out_l2": [float(np.linalg.norm(np.asarray(o))) for o in outs],
    }
    print(f"  update kernel: k={k} hlo={len(hlo)//1024}KiB")
    return {"k": k, "file": fname, "golden": golden}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated variant names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    entries = []
    for v in variants():
        if only and v.name not in only:
            continue
        entries.append(build_variant(v, args.out_dir))
    manifest = {
        "format_version": FORMAT_VERSION,
        "jax_version": jax.__version__,
        "variants": entries,
        "update_kernel": build_update_kernel(args.out_dir),
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out_dir}/manifest.json ({len(entries)} variants)")


if __name__ == "__main__":
    main()

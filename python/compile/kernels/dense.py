"""Fused dense layer (matmul + bias + activation) as a Pallas kernel.

The forward pass fuses ``act(x @ w + b)`` into a single kernel so the bias
add and activation happen while the output tile is still VMEM-resident
(the TPU analogue of a CUDA epilogue fusion).  The backward pass is wired
through :func:`jax.custom_vjp` — Pallas kernels are not auto-differentiable —
and routes both gradient matmuls (``dy @ w.T`` and ``x.T @ dy``) through the
same tiled Pallas matmul, so the L1 kernel carries the full fwd+bwd hot path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import matmul, pick_block

ACTIVATIONS = ("linear", "relu", "gelu", "tanh")


def _act(z, name: str):
    if name == "linear":
        return z
    if name == "relu":
        return jnp.maximum(z, 0.0)
    if name == "gelu":
        return jax.nn.gelu(z)
    if name == "tanh":
        return jnp.tanh(z)
    raise ValueError(f"unknown activation {name!r}")


def _act_grad(z, name: str):
    """d act(z) / dz, evaluated from the pre-activation z."""
    if name == "linear":
        return jnp.ones_like(z)
    if name == "relu":
        return (z > 0).astype(z.dtype)
    if name == "gelu":
        return jax.vmap(jax.vmap(jax.grad(jax.nn.gelu)))(z)
    if name == "tanh":
        t = jnp.tanh(z)
        return 1.0 - t * t
    raise ValueError(f"unknown activation {name!r}")


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, z_ref, *, act: str):
    # Full-K blocks: each grid step owns one (bm, bn) output tile outright,
    # so bias + activation fuse into the same VMEM residency window.
    z = (
        jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...]
    )
    z_ref[...] = z.astype(z_ref.dtype)
    o_ref[...] = _act(z, act).astype(o_ref.dtype)


def dense_fwd_only(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    act: str = "relu",
    interpret: bool = True,
):
    """Fused forward dense layer. Returns ``(out, pre_activation)``."""
    if act not in ACTIVATIONS:
        raise ValueError(f"unknown activation {act!r}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2 or b.shape != (n,):
        raise ValueError(f"dense shape mismatch: {x.shape} {w.shape} {b.shape}")
    bm, bn = pick_block(m), pick_block(n)
    out, z = pl.pallas_call(
        functools.partial(_dense_kernel, act=act),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), x.dtype),
            jax.ShapeDtypeStruct((m, n), x.dtype),
        ],
        interpret=interpret,
    )(x, w, b)
    return out, z


def make_dense(act: str = "relu", *, use_pallas: bool = True, interpret: bool = True):
    """Build a differentiable fused dense layer ``f(x, w, b) -> act(x@w+b)``.

    With ``use_pallas=False`` the layer is the plain-jnp reference path (used
    for the oracle artifacts and for fast CPU experiment variants); with
    ``use_pallas=True`` forward and both backward matmuls run through the L1
    Pallas kernels.
    """
    if act not in ACTIVATIONS:
        raise ValueError(f"unknown activation {act!r}")

    if not use_pallas:

        def dense_ref(x, w, b):
            return _act(x @ w + b, act)

        return dense_ref

    @jax.custom_vjp
    def dense(x, w, b):
        out, _ = dense_fwd_only(x, w, b, act=act, interpret=interpret)
        return out

    def dense_fwd(x, w, b):
        out, z = dense_fwd_only(x, w, b, act=act, interpret=interpret)
        return out, (x, w, z)

    def dense_bwd(res, dy):
        x, w, z = res
        dz = (dy * _act_grad(z, act)).astype(x.dtype)
        dx = matmul(dz, w.T, interpret=interpret)
        dw = matmul(x.T, dz, interpret=interpret)
        db = jnp.sum(dz, axis=0)
        return dx, dw, db

    dense.defvjp(dense_fwd, dense_bwd)
    return dense

"""Layer-1 Pallas kernels (build-time only; lowered into the model HLO).

All kernels are authored for TPU-style tiling (BlockSpec grids sized for
VMEM/MXU) but lowered with ``interpret=True`` so the resulting HLO runs on
the CPU PJRT client that the rust runtime uses.  Correctness is pinned to
the pure-jnp oracles in :mod:`compile.kernels.ref` by the pytest/hypothesis
suite.
"""

from .matmul import matmul  # noqa: F401
from .dense import make_dense, dense_fwd_only  # noqa: F401
from .update import momentum_lookahead_update  # noqa: F401

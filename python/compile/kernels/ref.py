"""Pure-jnp oracles for every L1 kernel — the correctness ground truth.

The pytest/hypothesis suite sweeps shapes and dtypes and asserts
``assert_allclose(kernel(...), ref(...))``.  These functions are also what
the ``*_ref`` (non-pallas) AOT artifact variants lower, giving the rust
integration tests a second, independently-built executable to cross-check.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """Oracle for kernels.matmul: plain f32-accumulated matmul."""
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(
        jnp.promote_types(x.dtype, y.dtype)
    )


def _act_ref(z, name: str):
    return {
        "linear": lambda t: t,
        "relu": lambda t: jnp.maximum(t, 0.0),
        "gelu": jax.nn.gelu,
        "tanh": jnp.tanh,
    }[name](z)


def dense_ref(x: jax.Array, w: jax.Array, b: jax.Array, act: str = "relu"):
    """Oracle for kernels.dense: act(x @ w + b)."""
    return _act_ref(x @ w + b, act)


def dense_pre_ref(x, w, b):
    """Pre-activation oracle (dense kernel's second output)."""
    return x @ w + b


def momentum_lookahead_update_ref(gamma, eta, theta, v, vsum, g):
    """Oracle for kernels.update — DANA-Zero master step, Eq 10/11 + A.2."""
    gamma = jnp.asarray(gamma).reshape(())
    eta = jnp.asarray(eta).reshape(())
    v_new = gamma * v + g
    theta_new = theta - eta * v_new
    vsum_new = vsum - v + v_new
    hat = theta_new - eta * gamma * vsum_new
    return theta_new, v_new, vsum_new, hat

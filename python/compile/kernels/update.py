"""Fused DANA master update as a Pallas kernel (paper Appendix A.2).

One master step of DANA-Zero touches four k-length vectors:

    v'    = gamma * v + g                  (per-worker momentum, Eq 10)
    theta'= theta - eta * v'               (master weights)
    vsum' = vsum - v + v'                  (O(k) incremental v^0)
    hat   = theta' - eta * gamma * vsum'   (look-ahead sent to the worker,
                                            Eq 11)

This kernel fuses all four into a single pass so every element of the five
input streams is read exactly once — the memory-bandwidth-bound hot loop the
rust master executes on the request path (``math::dana_fused_update``).  The
Pallas version exists (a) to demonstrate the L1 expression of the paper's
O(k) trick and (b) as an ablation artifact the rust runtime can execute via
PJRT instead of the native loop (bench `master_update_xla`).

Scalars arrive as ``f32[1]`` tensors (eta decays over training, so they
cannot be baked into the HLO).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _largest_divisor_leq

# 1-D tile: 8 f32 VREG lanes x 128 sublanes.
_PREF_VEC_BLOCK = 8 * 128


def _update_kernel(gamma_ref, eta_ref, theta_ref, v_ref, vsum_ref, g_ref,
                   theta_o, v_o, vsum_o, hat_o):
    gamma = gamma_ref[0]
    eta = eta_ref[0]
    v_new = gamma * v_ref[...] + g_ref[...]
    theta_new = theta_ref[...] - eta * v_new
    vsum_new = vsum_ref[...] - v_ref[...] + v_new
    v_o[...] = v_new
    theta_o[...] = theta_new
    vsum_o[...] = vsum_new
    hat_o[...] = theta_new - eta * gamma * vsum_new


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def momentum_lookahead_update(
    gamma: jax.Array,
    eta: jax.Array,
    theta: jax.Array,
    v: jax.Array,
    vsum: jax.Array,
    g: jax.Array,
    *,
    block: int | None = None,
    interpret: bool = True,
):
    """Fused DANA-Zero master step over flat ``f32[k]`` state.

    Args:
      gamma, eta: ``f32[1]`` momentum coefficient and learning rate.
      theta, v, vsum, g: ``f32[k]`` master weights, this worker's momentum,
        the momentum sum ``v^0``, and the incoming gradient.

    Returns:
      ``(theta', v', vsum', theta_hat)`` — all ``f32[k]``.
    """
    (k,) = theta.shape
    if v.shape != (k,) or vsum.shape != (k,) or g.shape != (k,):
        raise ValueError("all state vectors must share shape")
    blk = block or _largest_divisor_leq(k, _PREF_VEC_BLOCK)
    if k % blk:
        raise ValueError(f"block {blk} must divide k={k}")
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))
    vec_spec = pl.BlockSpec((blk,), lambda i: (i,))
    out_shape = jax.ShapeDtypeStruct((k,), theta.dtype)
    return pl.pallas_call(
        _update_kernel,
        grid=(k // blk,),
        in_specs=[scalar_spec, scalar_spec] + [vec_spec] * 4,
        out_specs=[vec_spec] * 4,
        out_shape=[out_shape] * 4,
        interpret=interpret,
    )(gamma, eta, theta, v, vsum, g)

"""Tiled matmul Pallas kernel — the MXU-shaped building block.

TPU mapping of the paper's GPU hot loop (see DESIGN.md §Hardware-Adaptation):
the grid is ``(M/bm, N/bn, K/bk)`` with the K axis innermost so each output
block stays resident while partial products accumulate — the BlockSpec
expression of the HBM↔VMEM schedule a CUDA kernel would express with
threadblocks + shared memory.  Block sizes default to MXU-friendly 128 and
are shrunk to the largest divisor of the dimension so the grid tiles exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Preferred (MXU-aligned) tile edge.  8x128 is the fp32 VREG tile on TPU;
# 128x128 feeds the MXU systolic array at full width.
_PREF_BLOCK = 128


def _largest_divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= cap (always >= 1)."""
    d = min(n, cap)
    while n % d != 0:
        d -= 1
    return d


def pick_block(n: int, pref: int = _PREF_BLOCK) -> int:
    """Choose a tile edge for a dimension of size ``n``.

    Exact tiling keeps the kernel free of masking logic; for the model sizes
    this library lowers (powers of two and multiples of 8) this always finds
    a block within 2x of the preference.
    """
    return _largest_divisor_leq(n, pref)


def _mm_kernel(x_ref, y_ref, o_ref):
    # K-axis is grid dim 2: zero the output block on the first visit, then
    # accumulate partial products on every revisit.  f32 accumulation.
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """``x @ y`` via a tiled Pallas kernel.

    Args:
      x: ``f32[M, K]``.
      y: ``f32[K, N]``.
      block_m/block_n/block_k: tile edges; default = largest divisor of the
        dimension that is <= 128.
      interpret: keep ``True`` for CPU-PJRT lowering (Mosaic custom-calls are
        TPU-only); the BlockSpec structure is identical either way.

    Returns:
      ``[M, N]`` in the promoted dtype of the inputs.
    """
    if x.ndim != 2 or y.ndim != 2 or x.shape[1] != y.shape[0]:
        raise ValueError(f"matmul shape mismatch: {x.shape} @ {y.shape}")
    m, k = x.shape
    _, n = y.shape
    bm = block_m or pick_block(m)
    bn = block_n or pick_block(n)
    bk = block_k or pick_block(k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"blocks ({bm},{bn},{bk}) must divide ({m},{n},{k})")
    out_dtype = jnp.promote_types(x.dtype, y.dtype)
    return pl.pallas_call(
        _mm_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(x, y)


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """Resident VMEM footprint of one grid step (x, y and o blocks).

    Used by DESIGN.md §Perf to check the tiling against the ~16 MiB VMEM
    budget of a TPU core without running on TPU hardware.
    """
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)


def mxu_utilization_estimate(bm: int, bn: int, bk: int) -> float:
    """Fraction of 128x128x128 MXU issue slots a (bm, bn, bk) tile fills."""
    fill = lambda b: min(b, 128) / 128.0
    return fill(bm) * fill(bn) * fill(bk)

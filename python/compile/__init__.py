"""Build-time compile path: JAX/Pallas models lowered AOT to HLO text.

Nothing in this package is imported at runtime; the rust coordinator only
consumes the ``artifacts/`` directory that :mod:`compile.aot` produces.
"""

"""Layer-2 model: decoder-only char-level transformer LM.

This is the end-to-end driver workload (``examples/train_async.rs``): the
rust coordinator trains it asynchronously with DANA-Slim on a synthetic
Markov char corpus and logs the loss curve (EXPERIMENTS.md §E2E).  Sizes are
configurable; the default ``lm_small`` fits a few-hundred-step CPU run, and
``lm_medium`` exists for longer runs.  (The paper's ResNet-50/ImageNet
workload is a scale substitution — see DESIGN.md §3.)

Interface (mirrors model.py, flat f32 params):

    train_step(params f32[P], x i32[B, T], y i32[B, T]) -> (loss f32[], grads f32[P])
    eval_step  -> (loss f32[], correct f32[])   # correct = token-level hits

QKV/output/MLP projections route through the L1 fused dense / matmul Pallas
kernels when ``use_pallas`` is set; attention softmax and layernorm stay in
jnp (they lower to fused XLA ops already).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .kernels.dense import make_dense


@dataclasses.dataclass(frozen=True)
class LMConfig:
    vocab: int = 64
    seq: int = 64
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 512
    use_pallas: bool = False
    seed: int = 0

    def __post_init__(self):
        assert self.d_model % self.n_heads == 0


def init_params(cfg: LMConfig):
    key = jax.random.PRNGKey(cfg.seed)

    def nrm(key, shape, scale):
        return scale * jax.random.normal(key, shape, jnp.float32)

    keys = iter(jax.random.split(key, 4 + 8 * cfg.n_layers))
    d = cfg.d_model
    params = {
        "tok_emb": nrm(next(keys), (cfg.vocab, d), 0.02),
        "pos_emb": nrm(next(keys), (cfg.seq, d), 0.02),
        "head_w": nrm(next(keys), (d, cfg.vocab), d ** -0.5),
        "head_b": jnp.zeros((cfg.vocab,), jnp.float32),
        "blocks": [],
    }
    for _ in range(cfg.n_layers):
        blk = {
            "ln1_g": jnp.ones((d,), jnp.float32),
            "ln1_b": jnp.zeros((d,), jnp.float32),
            "qkv_w": nrm(next(keys), (d, 3 * d), d ** -0.5),
            "qkv_b": jnp.zeros((3 * d,), jnp.float32),
            "out_w": nrm(next(keys), (d, d), d ** -0.5),
            "out_b": jnp.zeros((d,), jnp.float32),
            "ln2_g": jnp.ones((d,), jnp.float32),
            "ln2_b": jnp.zeros((d,), jnp.float32),
            "ff1_w": nrm(next(keys), (d, cfg.d_ff), d ** -0.5),
            "ff1_b": jnp.zeros((cfg.d_ff,), jnp.float32),
            "ff2_w": nrm(next(keys), (cfg.d_ff, d), cfg.d_ff ** -0.5),
            "ff2_b": jnp.zeros((d,), jnp.float32),
        }
        params["blocks"].append(blk)
    return params


def param_count(cfg: LMConfig) -> int:
    flat, _ = ravel_pytree(init_params(cfg))
    return int(flat.shape[0])


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attention(cfg: LMConfig, blk, h, dense_lin):
    b, t, d = h.shape
    nh, hd = cfg.n_heads, d // cfg.n_heads
    x = _layernorm(h, blk["ln1_g"], blk["ln1_b"])
    qkv = dense_lin(x.reshape(b * t, d), blk["qkv_w"], blk["qkv_b"]).reshape(
        b, t, 3, nh, hd
    )
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
    att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, t, d)
    o = dense_lin(o.reshape(b * t, d), blk["out_w"], blk["out_b"]).reshape(b, t, d)
    return h + o


def _mlp(cfg: LMConfig, blk, h, dense_lin, dense_gelu):
    b, t, d = h.shape
    x = _layernorm(h, blk["ln2_g"], blk["ln2_b"]).reshape(b * t, d)
    x = dense_gelu(x, blk["ff1_w"], blk["ff1_b"])
    x = dense_lin(x, blk["ff2_w"], blk["ff2_b"])
    return h + x.reshape(b, t, d)


def _forward(cfg: LMConfig, params, tokens):
    dense_lin = make_dense("linear", use_pallas=cfg.use_pallas)
    dense_gelu = make_dense("gelu", use_pallas=cfg.use_pallas)
    b, t = tokens.shape
    h = params["tok_emb"][tokens] + params["pos_emb"][None, :t]
    for blk in params["blocks"]:
        h = _attention(cfg, blk, h, dense_lin)
        h = _mlp(cfg, blk, h, dense_lin, dense_gelu)
    logits = h.reshape(b * t, cfg.d_model) @ params["head_w"] + params["head_b"]
    return logits.reshape(b, t, cfg.vocab)


def _ce_loss(logits, y):
    logp = jax.nn.log_softmax(logits)
    picked = jnp.take_along_axis(logp, y[..., None], axis=-1)
    return -jnp.mean(picked)


def make_steps(cfg: LMConfig) -> tuple[Callable, Callable, jax.Array]:
    """Build (train_step, eval_step, flat_init) for one LM variant."""
    params0 = init_params(cfg)
    flat0, unravel = ravel_pytree(params0)

    def loss_fn(flat, x, y):
        return _ce_loss(_forward(cfg, unravel(flat), x), y)

    def train_step(flat, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(flat, x, y)
        return loss, grads

    def eval_step(flat, x, y):
        logits = _forward(cfg, unravel(flat), x)
        loss = _ce_loss(logits, y)
        correct = jnp.sum(jnp.argmax(logits, axis=-1) == y).astype(jnp.float32)
        return loss, correct

    return train_step, eval_step, flat0

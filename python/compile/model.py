"""Layer-2 model: MLP classifier over flat parameters (CIFAR-proxy student).

The paper's CIFAR/ImageNet conv nets are substituted by an MLP student on a
synthetic teacher task (DESIGN.md §3): gradient-staleness dynamics depend on
the optimizer state geometry (eta, gamma, N, lag distribution), not on
convolutions, and an MLP keeps the CPU-PJRT step cost low enough to sweep
the paper's full algorithm x worker-count grids.

Interface consumed by the rust runtime (all shapes static at AOT time):

    train_step(params f32[P], x f32[B, D], y i32[B]) -> (loss f32[], grads f32[P])
    eval_step(params f32[P], x f32[B, D], y i32[B])  -> (loss f32[], correct f32[])

Parameters are a single flat vector (ravel_pytree ordering) so the rust
optimizer layer works on contiguous ``&[f32]`` with zero reshaping.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .kernels.dense import make_dense


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    """Architecture + lowering options for one MLP variant."""

    in_dim: int = 128
    hidden: tuple[int, ...] = (256, 256)
    classes: int = 10
    act: str = "relu"
    use_pallas: bool = True
    seed: int = 0

    @property
    def dims(self) -> tuple[int, ...]:
        return (self.in_dim, *self.hidden, self.classes)


def init_params(cfg: MLPConfig):
    """He-initialised parameter pytree: [(W0, b0), (W1, b1), ...]."""
    key = jax.random.PRNGKey(cfg.seed)
    layers = []
    dims = cfg.dims
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
        key, sub = jax.random.split(key)
        scale = jnp.sqrt(2.0 / din)
        w = scale * jax.random.normal(sub, (din, dout), jnp.float32)
        b = jnp.zeros((dout,), jnp.float32)
        layers.append((w, b))
    return layers


def param_count(cfg: MLPConfig) -> int:
    dims = cfg.dims
    return sum(din * dout + dout for din, dout in zip(dims[:-1], dims[1:]))


def _forward(cfg: MLPConfig, params, x):
    """Logits. Hidden layers use the fused L1 dense kernel; the final
    (classes-wide, often non-128-divisible) projection stays jnp."""
    dense = make_dense(cfg.act, use_pallas=cfg.use_pallas)
    h = x
    for w, b in params[:-1]:
        h = dense(h, w, b)
    w, b = params[-1]
    return h @ w + b


def _ce_loss(logits, y):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def make_steps(cfg: MLPConfig) -> tuple[Callable, Callable, jax.Array]:
    """Build (train_step, eval_step, flat_init) for one variant."""
    params0 = init_params(cfg)
    flat0, unravel = ravel_pytree(params0)

    def loss_fn(flat, x, y):
        return _ce_loss(_forward(cfg, unravel(flat), x), y)

    def train_step(flat, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(flat, x, y)
        return loss, grads

    def eval_step(flat, x, y):
        logits = _forward(cfg, unravel(flat), x)
        loss = _ce_loss(logits, y)
        correct = jnp.sum((jnp.argmax(logits, axis=1) == y)).astype(jnp.float32)
        return loss, correct

    return train_step, eval_step, flat0

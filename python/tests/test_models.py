"""L2 model tests: shapes, gradient correctness, pallas/ref path equality."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as mlp


def tiny_cfg(use_pallas=False, classes=5):
    return mlp.MLPConfig(
        in_dim=16, hidden=(24, 24), classes=classes, use_pallas=use_pallas
    )


def batch(cfg, b=32, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((b, cfg.in_dim)), jnp.float32)
    y = jnp.asarray(rng.integers(0, cfg.classes, b), jnp.int32)
    return x, y


class TestMLP:
    def test_param_count_matches_flat(self):
        cfg = tiny_cfg()
        _, _, flat0 = mlp.make_steps(cfg)
        assert flat0.shape == (mlp.param_count(cfg),)

    def test_train_step_shapes(self):
        cfg = tiny_cfg()
        train, _, flat0 = mlp.make_steps(cfg)
        x, y = batch(cfg)
        loss, grads = jax.jit(train)(flat0, x, y)
        assert loss.shape == () and grads.shape == flat0.shape
        assert np.isfinite(float(loss)) and np.all(np.isfinite(np.asarray(grads)))

    def test_initial_loss_near_log_classes(self):
        cfg = tiny_cfg(classes=10)
        train, _, flat0 = mlp.make_steps(cfg)
        x, y = batch(cfg)
        loss, _ = train(flat0, x, y)
        # He-init logits on a tiny net: loss should sit in the vicinity of
        # the uniform-prediction value log(C), not at a trained optimum.
        assert abs(float(loss) - np.log(10)) < 1.5

    def test_gradient_is_descent_direction(self):
        cfg = tiny_cfg()
        train, _, flat0 = mlp.make_steps(cfg)
        x, y = batch(cfg)
        loss0, g = train(flat0, x, y)
        loss1, _ = train(flat0 - 0.05 * g, x, y)
        assert float(loss1) < float(loss0)

    def test_sgd_training_reduces_loss(self):
        cfg = tiny_cfg()
        train, _, flat = mlp.make_steps(cfg)
        x, y = batch(cfg, b=64)
        step = jax.jit(train)
        losses = []
        for _ in range(60):
            loss, g = step(flat, x, y)
            flat = flat - 0.2 * g
            losses.append(float(loss))
        assert losses[-1] < 0.5 * losses[0]

    def test_grad_matches_finite_difference(self):
        cfg = tiny_cfg()
        train, _, flat0 = mlp.make_steps(cfg)
        x, y = batch(cfg, b=8)
        _, g = train(flat0, x, y)
        g = np.asarray(g)
        rng = np.random.default_rng(0)
        eps = 1e-3
        for idx in rng.integers(0, flat0.shape[0], 5):
            e = np.zeros(flat0.shape[0], np.float32)
            e[idx] = eps
            lp, _ = train(flat0 + e, x, y)
            lm, _ = train(flat0 - e, x, y)
            fd = (float(lp) - float(lm)) / (2 * eps)
            np.testing.assert_allclose(g[idx], fd, rtol=5e-2, atol=5e-4)

    def test_eval_step_counts_correct(self):
        cfg = tiny_cfg()
        _, ev, flat0 = mlp.make_steps(cfg)
        x, y = batch(cfg, b=40)
        loss, correct = ev(flat0, x, y)
        assert 0.0 <= float(correct) <= 40.0
        assert np.isfinite(float(loss))

    @pytest.mark.slow
    def test_pallas_and_ref_paths_agree(self):
        # Both lowering paths of the *same* architecture must produce the
        # same loss and gradients — the pallas kernels change nothing but
        # the schedule. Uses block-divisible dims so the kernel tiles big.
        cfg_p = mlp.MLPConfig(64, (128,), 8, "relu", use_pallas=True, seed=3)
        cfg_r = mlp.MLPConfig(64, (128,), 8, "relu", use_pallas=False, seed=3)
        train_p, _, flat_p = mlp.make_steps(cfg_p)
        train_r, _, flat_r = mlp.make_steps(cfg_r)
        np.testing.assert_array_equal(np.asarray(flat_p), np.asarray(flat_r))
        x, y = batch(cfg_p, b=64)
        lp, gp = train_p(flat_p, x, y)
        lr, gr = train_r(flat_r, x, y)
        np.testing.assert_allclose(float(lp), float(lr), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(gp), np.asarray(gr), rtol=1e-4, atol=1e-5)

    def test_deterministic_init(self):
        cfg = tiny_cfg()
        _, _, a = mlp.make_steps(cfg)
        _, _, b = mlp.make_steps(cfg)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

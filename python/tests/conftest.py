import os
import sys

# Tests are run from the python/ directory (``make test-py``); make the
# compile package importable regardless of invocation cwd.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

"""AOT artifact pipeline tests: manifest schema + golden reproducibility."""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile import model as mlp
from compile import transformer as lm

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first"
)


def load_manifest():
    with open(MANIFEST) as f:
        return json.load(f)


class TestVariants:
    def test_variant_names_unique(self):
        names = [v.name for v in aot.variants()]
        assert len(names) == len(set(names))

    def test_pallas_ref_pairs_share_arch(self):
        byname = {v.name: v for v in aot.variants()}
        for base in ("mlp_c10", "lm_small"):
            a, b = byname[base].cfg, byname[base + "_ref"].cfg
            # identical architectures, differing only in the lowering path
            assert b == type(a)(**{**a.__dict__, "use_pallas": b.use_pallas})

    def test_data_shapes(self):
        for v in aot.variants():
            x, y, xd = v.data_shapes()
            assert x[0] == v.batch
            if v.kind == "mlp":
                assert xd == "f32" and y == (v.batch,)
            else:
                assert xd == "i32" and y == x


@needs_artifacts
class TestManifest:
    def test_schema(self):
        m = load_manifest()
        assert m["format_version"] == aot.FORMAT_VERSION
        assert len(m["variants"]) >= 4
        for v in m["variants"]:
            for key in ("name", "kind", "param_count", "batch", "files", "golden"):
                assert key in v, f"{v['name']} missing {key}"
            for f in v["files"].values():
                assert os.path.exists(os.path.join(ART, f)), f

    def test_hlo_text_is_parseable_header(self):
        m = load_manifest()
        for v in m["variants"]:
            with open(os.path.join(ART, v["files"]["train"])) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), v["name"]

    def test_init_params_sized_correctly(self):
        m = load_manifest()
        for v in m["variants"]:
            init = os.path.join(ART, v["files"]["init"])
            assert os.path.getsize(init) == 4 * v["param_count"]

    def test_pallas_and_ref_goldens_match(self):
        # Two independently lowered builds of the same architecture must
        # agree on the golden batch — kernel path changes nothing numeric.
        m = load_manifest()
        byname = {v["name"]: v for v in m["variants"]}
        for base in ("mlp_c10", "lm_small"):
            if base in byname and base + "_ref" in byname:
                a, b = byname[base]["golden"], byname[base + "_ref"]["golden"]
                np.testing.assert_allclose(a["loss"], b["loss"], rtol=1e-5)
                np.testing.assert_allclose(a["grad_l2"], b["grad_l2"], rtol=1e-4)

    @pytest.mark.slow
    def test_golden_reproduces(self):
        # Rebuild the python step and check it still produces the manifest's
        # golden numbers (guards against drift between aot.py and model.py).
        m = load_manifest()
        v = next(x for x in m["variants"] if x["name"] == "mlp_c10_ref")
        cfg = mlp.MLPConfig(**v["arch"])
        cfg = mlp.MLPConfig(**{**v["arch"], "hidden": tuple(v["arch"]["hidden"])})
        train, _, flat0 = mlp.make_steps(cfg)
        x = np.fromfile(
            os.path.join(ART, v["files"]["golden_x"]), dtype="<f4"
        ).reshape(v["x_shape"])
        y = np.fromfile(os.path.join(ART, v["files"]["golden_y"]), dtype="<i4")
        loss, grads = train(flat0, x, y)
        np.testing.assert_allclose(float(loss), v["golden"]["loss"], rtol=1e-5)
        np.testing.assert_allclose(
            float(np.linalg.norm(np.asarray(grads))), v["golden"]["grad_l2"], rtol=1e-4
        )
        np.testing.assert_allclose(
            np.asarray(grads)[:8], v["golden"]["grad_prefix"], rtol=1e-4, atol=1e-7
        )

"""L2 transformer tests: shapes, causality, training signal, path equality."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import transformer as lm


def tiny_cfg(use_pallas=False):
    return lm.LMConfig(
        vocab=16, seq=12, d_model=32, n_heads=2, n_layers=2, d_ff=64,
        use_pallas=use_pallas,
    )


def batch(cfg, b=4, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, cfg.vocab, (b, cfg.seq)), jnp.int32)
    y = jnp.asarray(rng.integers(0, cfg.vocab, (b, cfg.seq)), jnp.int32)
    return x, y


class TestTransformer:
    def test_shapes_and_finiteness(self):
        cfg = tiny_cfg()
        train, ev, flat0 = lm.make_steps(cfg)
        x, y = batch(cfg)
        loss, grads = jax.jit(train)(flat0, x, y)
        assert grads.shape == flat0.shape
        assert np.isfinite(float(loss))
        eloss, ecorr = ev(flat0, x, y)
        assert 0 <= float(ecorr) <= x.size

    def test_initial_loss_near_log_vocab(self):
        cfg = tiny_cfg()
        train, _, flat0 = lm.make_steps(cfg)
        x, y = batch(cfg)
        loss, _ = train(flat0, x, y)
        assert abs(float(loss) - np.log(cfg.vocab)) < 1.5

    def test_causality(self):
        # Changing a future token must not change earlier-position logits.
        cfg = tiny_cfg()
        params = lm.init_params(cfg)
        x, _ = batch(cfg, b=1)
        logits_a = lm._forward(cfg, params, x)
        x2 = x.at[0, -1].set((x[0, -1] + 1) % cfg.vocab)
        logits_b = lm._forward(cfg, params, x2)
        np.testing.assert_allclose(
            logits_a[0, :-1], logits_b[0, :-1], rtol=1e-5, atol=1e-6
        )
        assert not np.allclose(logits_a[0, -1], logits_b[0, -1])

    def test_learns_copy_task(self):
        # Predict-next on a constant sequence is learnable in a few steps.
        cfg = tiny_cfg()
        train, _, flat = lm.make_steps(cfg)
        x = jnp.tile(jnp.arange(cfg.seq, dtype=jnp.int32) % cfg.vocab, (8, 1))
        y = (x + 1) % cfg.vocab
        step = jax.jit(train)
        for _ in range(100):
            loss, g = step(flat, x, y)
            flat = flat - 0.1 * g
        assert float(loss) < 0.1

    def test_param_count(self):
        cfg = tiny_cfg()
        _, _, flat0 = lm.make_steps(cfg)
        assert lm.param_count(cfg) == flat0.shape[0]

    @pytest.mark.slow
    def test_pallas_and_ref_paths_agree(self):
        cfg_p = lm.LMConfig(16, 16, 32, 2, 1, 64, use_pallas=True, seed=5)
        cfg_r = lm.LMConfig(16, 16, 32, 2, 1, 64, use_pallas=False, seed=5)
        train_p, _, flat_p = lm.make_steps(cfg_p)
        train_r, _, flat_r = lm.make_steps(cfg_r)
        np.testing.assert_array_equal(np.asarray(flat_p), np.asarray(flat_r))
        x, y = batch(cfg_p, b=2)
        lp, gp = train_p(flat_p, x, y)
        lr, gr = train_r(flat_r, x, y)
        np.testing.assert_allclose(float(lp), float(lr), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(gp), np.asarray(gr), rtol=2e-4, atol=1e-5)

    def test_heads_must_divide_dmodel(self):
        with pytest.raises(AssertionError):
            lm.LMConfig(vocab=8, seq=8, d_model=30, n_heads=4)

"""L1 kernel correctness: Pallas vs pure-jnp oracle, hypothesis shape sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, make_dense, momentum_lookahead_update
from compile.kernels.dense import dense_fwd_only, ACTIVATIONS
from compile.kernels.matmul import pick_block, vmem_bytes, mxu_utilization_estimate
from compile.kernels import ref

# Hypothesis x jit is slow-ish; keep example counts tight but meaningful.
KERNEL_SETTINGS = dict(max_examples=15, deadline=None)

dims = st.sampled_from([1, 2, 3, 4, 8, 16, 24, 32, 64, 96, 128, 160, 256])


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


class TestMatmul:
    @settings(**KERNEL_SETTINGS)
    @given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**16))
    def test_matches_ref_f32(self, m, k, n, seed):
        x = _rand(seed, (m, k), jnp.float32)
        y = _rand(seed + 1, (k, n), jnp.float32)
        # K-split tiles accumulate in a different order than the oracle's
        # single dot — bitwise equality is not expected, closeness is.
        np.testing.assert_allclose(
            matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4
        )

    @settings(max_examples=6, deadline=None)
    @given(m=st.sampled_from([8, 32, 64]), seed=st.integers(0, 100))
    def test_matches_ref_bf16_inputs(self, m, seed):
        # bf16 storage with f32 accumulation — MXU-native dtype contract.
        x = _rand(seed, (m, 64), jnp.bfloat16)
        y = _rand(seed + 1, (64, m), jnp.bfloat16)
        got = matmul(x, y)
        want = ref.matmul_ref(x, y)
        assert got.dtype == want.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            got.astype(np.float32), want.astype(np.float32), rtol=2e-2, atol=2e-2
        )

    def test_explicit_blocks(self):
        x = _rand(0, (64, 96), jnp.float32)
        y = _rand(1, (96, 40), jnp.float32)
        out = matmul(x, y, block_m=16, block_n=8, block_k=24)
        np.testing.assert_allclose(out, ref.matmul_ref(x, y), rtol=1e-5, atol=1e-5)

    def test_rejects_bad_shapes(self):
        x = jnp.zeros((4, 5))
        y = jnp.zeros((6, 4))
        with pytest.raises(ValueError):
            matmul(x, y)

    def test_rejects_non_dividing_blocks(self):
        x = jnp.zeros((4, 4))
        with pytest.raises(ValueError):
            matmul(x, x, block_m=3)

    def test_pick_block_divides(self):
        for n in range(1, 400):
            b = pick_block(n)
            assert n % b == 0 and 1 <= b <= 128

    def test_vmem_budget_default_tiles(self):
        # 128^3 f32 tiling must fit well under a 16 MiB VMEM core budget.
        assert vmem_bytes(128, 128, 128) < 16 * 2**20 // 8
        assert mxu_utilization_estimate(128, 128, 128) == 1.0
        assert mxu_utilization_estimate(64, 128, 128) == 0.5


class TestDense:
    @settings(**KERNEL_SETTINGS)
    @given(
        m=st.sampled_from([8, 16, 64, 128]),
        k=st.sampled_from([16, 32, 96]),
        n=st.sampled_from([8, 32, 128]),
        act=st.sampled_from(ACTIVATIONS),
        seed=st.integers(0, 2**16),
    )
    def test_forward_matches_ref(self, m, k, n, act, seed):
        x = _rand(seed, (m, k), jnp.float32)
        w = _rand(seed + 1, (k, n), jnp.float32) * 0.2
        b = _rand(seed + 2, (n,), jnp.float32)
        out, z = dense_fwd_only(x, w, b, act=act)
        np.testing.assert_allclose(
            out, ref.dense_ref(x, w, b, act), rtol=1e-5, atol=1e-5
        )
        np.testing.assert_allclose(z, ref.dense_pre_ref(x, w, b), rtol=1e-5, atol=1e-5)

    @settings(max_examples=8, deadline=None)
    @given(act=st.sampled_from(ACTIVATIONS), seed=st.integers(0, 2**16))
    def test_vjp_matches_autodiff(self, act, seed):
        x = _rand(seed, (32, 48), jnp.float32)
        w = _rand(seed + 1, (48, 16), jnp.float32) * 0.2
        b = _rand(seed + 2, (16,), jnp.float32)
        dense = make_dense(act, use_pallas=True)
        f = lambda x_, w_, b_: jnp.sum(jnp.sin(dense(x_, w_, b_)))
        f_ref = lambda x_, w_, b_: jnp.sum(jnp.sin(ref.dense_ref(x_, w_, b_, act)))
        got = jax.grad(f, argnums=(0, 1, 2))(x, w, b)
        want = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
        for g, r in zip(got, want):
            np.testing.assert_allclose(g, r, rtol=1e-4, atol=1e-4)

    def test_ref_path_factory(self):
        dense = make_dense("relu", use_pallas=False)
        x = _rand(0, (8, 8), jnp.float32)
        w = jnp.eye(8)
        b = jnp.zeros((8,))
        np.testing.assert_allclose(dense(x, w, b), jnp.maximum(x, 0.0))

    def test_unknown_activation_rejected(self):
        with pytest.raises(ValueError):
            make_dense("swish-ish")


class TestUpdateKernel:
    @settings(**KERNEL_SETTINGS)
    @given(
        k=st.sampled_from([8, 128, 1024, 4096, 5120]),
        gamma=st.floats(0.0, 0.99),
        eta=st.floats(1e-4, 0.5),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, k, gamma, eta, seed):
        mk = lambda i: _rand(seed + i, (k,), jnp.float32)
        theta, v, vsum, g = mk(0), mk(1), mk(2), mk(3)
        got = momentum_lookahead_update(
            jnp.array([gamma], jnp.float32), jnp.array([eta], jnp.float32),
            theta, v, vsum, g,
        )
        want = ref.momentum_lookahead_update_ref(gamma, eta, theta, v, vsum, g)
        for o, r in zip(got, want):
            np.testing.assert_allclose(o, r, rtol=1e-5, atol=1e-5)

    def test_zero_gamma_reduces_to_sgd(self):
        k = 256
        theta = _rand(0, (k,), jnp.float32)
        g = _rand(1, (k,), jnp.float32)
        zeros = jnp.zeros((k,))
        th2, v2, vs2, hat = momentum_lookahead_update(
            jnp.array([0.0]), jnp.array([0.1]), theta, zeros, zeros, g
        )
        np.testing.assert_allclose(th2, theta - 0.1 * g, rtol=1e-6)
        np.testing.assert_allclose(hat, th2, rtol=1e-6)  # no look-ahead at gamma=0

    def test_vsum_invariant(self):
        # vsum' - vsum == v' - v (the O(k) incremental identity, Appendix A.2)
        k = 512
        mk = lambda i: _rand(i, (k,), jnp.float32)
        theta, v, vsum, g = mk(0), mk(1), mk(2), mk(3)
        th2, v2, vs2, _ = momentum_lookahead_update(
            jnp.array([0.9]), jnp.array([0.05]), theta, v, vsum, g
        )
        np.testing.assert_allclose(vs2 - vsum, v2 - v, rtol=1e-5, atol=1e-6)

    def test_shape_mismatch_rejected(self):
        with pytest.raises(ValueError):
            momentum_lookahead_update(
                jnp.array([0.9]), jnp.array([0.1]),
                jnp.zeros((8,)), jnp.zeros((8,)), jnp.zeros((8,)), jnp.zeros((4,)),
            )

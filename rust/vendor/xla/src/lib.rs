//! Offline stub of the `xla` PJRT wrapper crate.
//!
//! The real dependency wraps the PJRT C API (CPU client, HLO parsing,
//! executable compilation and literal transfer). That native library is not
//! available in the offline build environment, so this stub presents the
//! same API surface and makes every entry point that would touch PJRT
//! return [`Error::Unavailable`] at *call* time. The crate, its tests and
//! benches all compile and run: the artifact-gated integration tests check
//! for `artifacts/manifest.json` before constructing a client and skip
//! cleanly, and everything that does not execute a compiled model (the
//! parameter server, optimizers, simulator, synthetic trainers) is fully
//! functional.
//!
//! To run the compiled-model paths, repoint the `xla` path dependency in
//! the root `Cargo.toml` at a real PJRT wrapper with this interface.

use std::fmt;
use std::path::Path;

/// Error type matching the wrapper's debug-formatted error reporting.
#[derive(Debug, Clone)]
pub enum Error {
    /// The operation requires the native PJRT plugin, which this stub
    /// build does not link.
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => {
                write!(f, "{what}: PJRT unavailable (offline xla stub build)")
            }
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Element types the literal wrappers accept.
pub trait NativeType: Copy + Default + 'static {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Host-side tensor literal. The stub keeps no data: literals are only
/// ever read back after an `execute`, which always fails first.
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation ready to compile.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err}").contains("PJRT unavailable"));
    }

    #[test]
    fn literal_construction_is_cheap_and_reads_fail() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        let r = l.reshape(&[2, 1]).unwrap();
        assert!(r.to_vec::<f32>().is_err());
        assert!(r.to_tuple().is_err());
    }
}

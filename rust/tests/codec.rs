//! Wire-v4 codec acceptance (ISSUE 7):
//!
//! 1. `encoding=none` pooled frames are **byte-identical** to the legacy
//!    `Msg::encode` path, and exact-f32 round trips preserve every bit
//!    pattern (NaN payloads, denormals, signed zero).
//! 2. f16/bf16 payloads round-trip within their format's rounding error,
//!    and the decoded values are bit-for-bit what the in-process
//!    [`Compressor`] simulation produces — the wire and the simulation
//!    agree on the quantization noise.
//! 3. Top-k error feedback conserves the gradient: over a window of
//!    pushes, the sparsified updates plus the banked residual sum to
//!    exactly the dense gradients (integer-valued, so equality is exact).
//! 4. The payload decoder fails closed on malformed compression.
//! 5. Negotiation over a real loopback socket: an unadvertised request
//!    falls back to `none` (never an error), a granted f16 shrinks both
//!    directions of the wire by >= 40%, and a granted top-k run is
//!    bit-for-bit the in-process compression simulation.

use dana::config::{TrainConfig, Workload};
use dana::net::codec::{self, Compressor};
use dana::net::wire::{read_frame, Msg, MAGIC, VERSION};
use dana::net::{Encoding, EncodingSet, NetServer, RemoteMaster, ServeOptions};
use dana::optim::{AlgorithmKind, LrSchedule};
use dana::server::{make_master, Master};
use dana::train::{real_async, sim_trainer};
use dana::util::rng::Rng;
use std::io::Cursor;

fn cfg(kind: AlgorithmKind, workers: usize, epochs: f64) -> TrainConfig {
    let mut c = TrainConfig::preset(Workload::C10, kind, workers, epochs);
    c.seed = 61;
    // gap/lag metrics live server-side on a remote run; keep them off so
    // both sides of each comparison record nothing
    c.metrics_every = 0;
    c
}

/// The master a `dana serve` for this config would host: zero slots
/// (connect == join), same schedule, synthetic θ₀.
fn serve_master(c: &TrainConfig, k: usize) -> Box<dyn Master> {
    make_master(
        c.algorithm,
        &real_async::synthetic_theta0(k),
        LrSchedule::new(c.schedule.clone()),
        0,
        c.shards,
        1,
    )
}

// ------------------------------------------------------------- round trips

#[test]
fn none_pooled_frames_match_legacy_encode_bit_for_bit() {
    let vals = vec![
        f32::NAN,
        f32::from_bits(0x7FC0_1234), // payload-carrying NaN
        -0.0,
        f32::from_bits(0x0000_0001), // smallest denormal
        f32::MAX,
        -3.25,
    ];
    let legacy = Msg::Push { gen: 42, msg: vals.clone() }.encode();
    let mut pooled = Vec::new();
    let n = codec::write_push(&mut pooled, 42, Encoding::None, &vals).unwrap();
    assert_eq!(n, pooled.len(), "write_push must report the on-wire size");
    assert_eq!(pooled, legacy, "encoding=none must be byte-identical to the legacy frame");
    match read_frame(&mut Cursor::new(pooled)).unwrap() {
        Msg::Push { gen, msg } => {
            assert_eq!(gen, 42);
            assert_eq!(msg.len(), vals.len());
            for (a, b) in msg.iter().zip(&vals) {
                assert_eq!(a.to_bits(), b.to_bits(), "exact-f32 must preserve every bit");
            }
        }
        other => panic!("wrong message back: {other:?}"),
    }
}

#[test]
fn quantized_round_trip_error_is_bounded_and_matches_the_simulation() {
    let mut rng = Rng::new(17);
    let vals: Vec<f32> = (0..4096).map(|_| (rng.normal() * 8.0) as f32).collect();
    // (encoding, relative error bound, absolute floor for subnormals)
    let cases = [
        (Encoding::F16, 2.0f32.powi(-11), 2.0f32.powi(-24)),
        (Encoding::Bf16, 2.0f32.powi(-8), 2.0f32.powi(-133)),
    ];
    for (enc, rel, abs) in cases {
        let mut buf = Vec::new();
        codec::write_push(&mut buf, 0, enc, &vals).unwrap();
        let exact = Msg::Push { gen: 0, msg: vals.clone() }.encode();
        assert!(
            buf.len() < exact.len() * 6 / 10,
            "{enc}: a half-width payload must shrink the frame ({} vs {})",
            buf.len(),
            exact.len()
        );
        let back = match read_frame(&mut Cursor::new(buf)).unwrap() {
            Msg::Push { msg, .. } => msg,
            other => panic!("wrong message back: {other:?}"),
        };
        // bounded error vs the original...
        for (q, x) in back.iter().zip(&vals) {
            assert!(
                (q - x).abs() <= rel * x.abs() + abs,
                "{enc}: {x} decoded as {q}, outside the format's rounding error"
            );
        }
        // ...and bit-for-bit agreement with the in-process simulation
        let mut sim = vals.clone();
        Compressor::new(enc).transform(0, &mut sim);
        for (q, s) in back.iter().zip(&sim) {
            assert_eq!(q.to_bits(), s.to_bits(), "{enc}: wire and Compressor disagree");
        }
    }
}

#[test]
fn topk_error_feedback_conserves_the_gradient_sum() {
    let n = 64usize;
    let k = 8u32;
    let mut c = Compressor::new(Encoding::TopK { k });
    let mut rng = Rng::new(5);
    let mut dense_sum = vec![0.0f32; n];
    let mut sent_sum = vec![0.0f32; n];
    for _ in 0..10 {
        // integer-valued gradients in [-32, 32]: every partial sum stays
        // far inside f32's exact-integer range, so conservation is exact
        let g: Vec<f32> = (0..n).map(|_| rng.below(65) as f32 - 32.0).collect();
        for (d, x) in dense_sum.iter_mut().zip(&g) {
            *d += x;
        }
        let mut t = g.clone();
        c.transform(0, &mut t);
        let nnz = t.iter().filter(|x| **x != 0.0).count();
        assert!(nnz <= k as usize, "top-k sent {nnz} > k={k} coordinates");
        for (s, x) in sent_sum.iter_mut().zip(&t) {
            *s += x;
        }
    }
    // flush the residual: zero-gradient pushes drain at least k banked
    // coordinates each, so ceil(n/k) rounds empty it completely
    for _ in 0..n.div_ceil(k as usize) {
        let mut z = vec![0.0f32; n];
        c.transform(0, &mut z);
        for (s, x) in sent_sum.iter_mut().zip(&z) {
            *s += x;
        }
    }
    assert_eq!(sent_sum, dense_sum, "sparsified + residual must equal the dense gradient");
    // the residual is now empty, and a reset keeps it that way
    c.reset_slot(0);
    let mut z = vec![0.0f32; n];
    c.transform(0, &mut z);
    assert!(z.iter().all(|x| *x == 0.0), "a drained+reset slot has nothing banked");
}

// ------------------------------------------------------------- fail closed

/// A syntactically valid v4 `Push` frame (gen 7) around an arbitrary
/// payload blob — the decoder must judge the payload on its own merits.
fn push_frame(payload: &[u8]) -> Vec<u8> {
    let body_len = 4 + 1 + 1 + 4 + payload.len();
    let mut f = Vec::with_capacity(4 + body_len);
    f.extend_from_slice(&(body_len as u32).to_le_bytes());
    f.extend_from_slice(&MAGIC);
    f.push(VERSION);
    f.push(3); // Push
    f.extend_from_slice(&7u32.to_le_bytes()); // gen
    f.extend_from_slice(payload);
    f
}

#[test]
fn payload_decoder_fails_closed_on_malformed_compression() {
    let reject = |payload: &[u8], needle: &str| {
        let err = read_frame(&mut Cursor::new(push_frame(payload))).unwrap_err();
        assert!(err.to_string().contains(needle), "want {needle:?} in: {err}");
    };
    // unknown payload tag
    reject(&[9], "unknown payload encoding");
    // f16 declares 3 halves but carries only 2
    let mut short = vec![1u8];
    short.extend_from_slice(&3u64.to_le_bytes());
    short.extend_from_slice(&[0u8; 4]);
    assert!(read_frame(&mut Cursor::new(push_frame(&short))).is_err());
    // a NaN half is rejected (quantized gradients never carry NaN)
    let mut nan = vec![1u8];
    nan.extend_from_slice(&1u64.to_le_bytes());
    nan.extend_from_slice(&0x7E00u16.to_le_bytes());
    reject(&nan, "NaN");
    // top-k: an index past full_len
    let mut oob = vec![3u8];
    oob.extend_from_slice(&4u64.to_le_bytes()); // full
    oob.extend_from_slice(&1u64.to_le_bytes()); // nnz
    oob.extend_from_slice(&4u32.to_le_bytes()); // index 4 >= full 4
    oob.extend_from_slice(&1.0f32.to_le_bytes());
    reject(&oob, "out of range");
    // top-k: nnz exceeding full_len
    let mut fat = vec![3u8];
    fat.extend_from_slice(&2u64.to_le_bytes());
    fat.extend_from_slice(&3u64.to_le_bytes());
    reject(&fat, "nnz");
}

// ------------------------------------------------------------- negotiation

#[test]
fn unadvertised_request_falls_back_to_none_and_still_serves() {
    let k = 32;
    let c = cfg(AlgorithmKind::Asgd, 1, 0.2);
    let opts = ServeOptions { encodings: EncodingSet::NONE_ONLY, ..Default::default() };
    let mut srv = NetServer::start(serve_master(&c, k), "127.0.0.1:0", opts).unwrap();
    let mut rm = RemoteMaster::connect_with(&srv.url(), 1, None, Encoding::F16).unwrap();
    assert_eq!(
        rm.granted_encoding(),
        Encoding::None,
        "a strict server grants none, never an error"
    );
    let mut buf = vec![0.0f32; k];
    rm.pull_into(0, &mut buf);
    rm.push_update(0, &vec![0.5; k]).unwrap();
    assert_eq!(rm.steps_done(), 1, "the uncompressed fallback must serve normally");
    drop(rm);
    srv.stop();
}

#[test]
fn granted_f16_shrinks_both_wire_directions_by_40_percent() {
    let k = 4096;
    let c = cfg(AlgorithmKind::Asgd, 1, 0.2);
    let mut measured = Vec::new();
    for enc in [Encoding::None, Encoding::F16] {
        let opts = ServeOptions::default();
        let mut srv = NetServer::start(serve_master(&c, k), "127.0.0.1:0", opts).unwrap();
        let mut rm = RemoteMaster::connect_with(&srv.url(), 1, None, enc).unwrap();
        assert_eq!(rm.granted_encoding(), enc, "the default advertisement grants {enc}");
        let mut buf = vec![0.0f32; k];
        let g = vec![0.125f32; k];
        let (t0, r0) = rm.wire_bytes();
        for _ in 0..8 {
            rm.pull_into(0, &mut buf);
            rm.push_update(0, &g).unwrap();
        }
        let (t1, r1) = rm.wire_bytes();
        measured.push((t1 - t0, r1 - r0));
        drop(rm);
        srv.stop();
    }
    let (none_tx, none_rx) = measured[0];
    let (f16_tx, f16_rx) = measured[1];
    assert!(
        f16_tx * 10 <= none_tx * 6,
        "f16 pushes must cut tx bytes/step by >= 40% ({f16_tx} vs {none_tx})"
    );
    assert!(
        f16_rx * 10 <= none_rx * 6,
        "f16 params replies must cut rx bytes/step by >= 40% ({f16_rx} vs {none_rx})"
    );
}

#[test]
fn topk_loopback_matches_the_in_process_simulation_bit_for_bit() {
    // Top-k replies stay exact (reply_encoding), the sparse payload is
    // bit-exact for its nonzeros, and both paths run the identical
    // error-feedback transform keyed by worker index — so a compressed
    // run over real sockets must reproduce the in-process simulation's
    // trajectory exactly.
    let k = 48;
    for kind in [AlgorithmKind::Asgd, AlgorithmKind::DanaZero] {
        let mut c = cfg(kind, 2, 0.4);
        c.encoding = Encoding::TopK { k: 6 };
        let base = sim_trainer::run_synthetic(&c, k).unwrap();
        let opts = ServeOptions::default();
        let mut srv = NetServer::start(serve_master(&c, k), "127.0.0.1:0", opts).unwrap();
        let mut rc = c.clone();
        rc.master_addr = Some(srv.url());
        let remote = sim_trainer::run_synthetic(&rc, k).unwrap();
        assert_eq!(
            remote.final_test_loss, base.final_test_loss,
            "{kind}: top-k final loss diverged across the wire"
        );
        assert_eq!(remote.loss_curve, base.loss_curve, "{kind}: top-k loss curve");
        assert_eq!(remote.steps, base.steps, "{kind}");
        srv.stop();
    }
}

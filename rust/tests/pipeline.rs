//! Pipelined worker runtime obligations (ISSUE 5 acceptance):
//!
//! 1. DANA's look-ahead extrapolated `D` extra steps equals `D` literal
//!    momentum-only applications followed by the plain look-ahead —
//!    exact f32, for DANA-Zero / DANA-DC / NAG (the satellite property).
//! 2. `--pipeline-depth D ≥ 1` runs are deterministic per seed, and
//!    their staleness histogram is the `D = 0` histogram shifted by
//!    exactly the pipeline window: every recorded lag matches the
//!    closed-form prediction reconstructed from the `D = 0` run's own
//!    push schedule (the schedules are identical — at `rtt = 0` the
//!    completion stream is depth-independent).
//! 3. A single pipelined worker's lag ramps 0,1,…,D and then sits at
//!    exactly D — the "+D known, deterministic staleness" claim, pinned.
//! 4. The thread backend pipelines (channel-window) and still descends;
//!    dropped-push accounting stays zero without churn.
//! 5. Loopback smoke (run in CI on every push): D ∈ {0, 1} over TCP with
//!    deferred-ack pushes reproduces the in-process trajectories
//!    bit-for-bit, and D = 1 actually defers (the client reports acks in
//!    flight between push and pull).

use dana::config::{TrainConfig, Workload};
use dana::net::{NetServer, ServeOptions};
use dana::optim::dana_dc::DanaDc;
use dana::optim::dana_zero::DanaZero;
use dana::optim::sgd::Nag;
use dana::optim::{make_algorithm, Algorithm, AlgorithmKind, LrSchedule, Step};
use dana::server::{make_master, Master};
use dana::train::{real_async, sim_trainer};
use dana::util::rng::Rng;

fn cfg(kind: AlgorithmKind, workers: usize, epochs: f64, depth: usize) -> TrainConfig {
    let mut c = TrainConfig::preset(Workload::C10, kind, workers, epochs);
    c.seed = 53;
    c.metrics_every = 0;
    c.pipeline_depth = depth;
    c
}

fn rand_vec(rng: &mut Rng, k: usize, scale: f32) -> Vec<f32> {
    (0..k).map(|_| scale * rng.normal() as f32).collect()
}

// ---------------------------------------------------------------- (1)

/// Reference: `depth` literal momentum-only steps (`v ← γv; θ ← θ − ηv`)
/// applied to owned copies of (θ, v).
fn literal_extrapolate(theta: &[f32], v: &[f32], eta: f32, gamma: f32, depth: usize) -> (Vec<f32>, Vec<f32>) {
    let (mut t, mut vv) = (theta.to_vec(), v.to_vec());
    for _ in 0..depth {
        for (ti, vi) in t.iter_mut().zip(vv.iter_mut()) {
            *vi = gamma * *vi;
            *ti -= eta * *vi;
        }
    }
    (t, vv)
}

#[test]
fn nag_extrapolated_lookahead_equals_literal_momentum_applications() {
    let k = 37;
    let (eta, gamma) = (0.05f32, 0.9f32);
    let mut rng = Rng::new(7);
    for depth in [0usize, 1, 2, 5] {
        let mut nag = Nag::new(&rand_vec(&mut rng, k, 1.0));
        // build nonzero momentum with a few real applies
        for _ in 0..4 {
            let g = rand_vec(&mut rng, k, 1.0);
            nag.apply(&g, eta, gamma);
        }
        // literal: D zero-gradient applies on a copy, then the plain
        // look-ahead (Nag::apply with g = 0 IS the momentum-only step)
        let mut literal = nag.clone();
        let zeros = vec![0.0f32; k];
        for _ in 0..depth {
            literal.apply(&zeros, eta, gamma);
        }
        let mut want = vec![0.0f32; k];
        literal.lookahead_params(&mut want, eta, gamma);
        let mut got = vec![0.0f32; k];
        nag.lookahead_extrapolated(&mut got, eta, gamma, depth);
        assert_eq!(got, want, "depth {depth}: extrapolation != literal (exact f32)");
    }
}

#[test]
fn dana_extrapolated_send_equals_literal_momentum_applications() {
    let k = 29;
    let s = Step { eta: 0.05, gamma: 0.9, lambda: 1.0 };
    let mut rng = Rng::new(11);
    for depth in [0usize, 1, 3] {
        // DANA-Zero
        let mut dz = DanaZero::new(&rand_vec(&mut rng, k, 1.0), 2);
        for i in 0..6 {
            let g = rand_vec(&mut rng, k, 1.0);
            let sent = dz.theta().to_vec();
            dz.master_apply(i % 2, &g, &sent, s);
        }
        let (t, v) = literal_extrapolate(dz.theta(), dz.velocity_sum(), s.eta, s.gamma, depth);
        let mut want = vec![0.0f32; k];
        dana::math::lookahead(&mut want, &t, &v, s.gamma, s.eta);
        dz.set_staleness_hint(depth);
        let mut got = vec![0.0f32; k];
        dz.master_send(0, &mut got, s);
        assert_eq!(got, want, "dana-zero depth {depth}");

        // DANA-DC shares the same send
        let mut dc = DanaDc::new(&rand_vec(&mut rng, k, 1.0), 2);
        for i in 0..6 {
            let g = rand_vec(&mut rng, k, 1.0);
            let sent = dc.theta().to_vec();
            dc.master_apply(i % 2, &g, &sent, s);
        }
        let (t, v) = literal_extrapolate(dc.theta(), dc.velocity_sum(), s.eta, s.gamma, depth);
        let mut want = vec![0.0f32; k];
        dana::math::lookahead(&mut want, &t, &v, s.gamma, s.eta);
        dc.set_staleness_hint(depth);
        let mut got = vec![0.0f32; k];
        dc.master_send(1, &mut got, s);
        assert_eq!(got, want, "dana-dc depth {depth}");
    }
}

#[test]
fn nag_asgd_hint_sends_the_extrapolated_position() {
    let k = 17;
    let s = Step { eta: 0.1, gamma: 0.9, lambda: 0.0 };
    let mut rng = Rng::new(13);
    let mut a = make_algorithm(AlgorithmKind::NagAsgd, &rand_vec(&mut rng, k, 1.0), 2);
    for i in 0..5 {
        let g = rand_vec(&mut rng, k, 1.0);
        let sent = a.theta().to_vec();
        a.master_apply(i % 2, &g, &sent, s);
    }
    // hint 0: plain θ (Algorithm 8 exactly)
    let mut send0 = vec![0.0f32; k];
    a.master_send(0, &mut send0, s);
    assert_eq!(send0, a.theta().to_vec());
    // hint 2: the momentum-only 2-step future position
    a.set_staleness_hint(2);
    let mut send2 = vec![0.0f32; k];
    a.master_send(0, &mut send2, s);
    assert_ne!(send2, send0, "hinted send must move");
    // reference via the concrete momentum vector is internal; check the
    // defining property instead: hint 0 restored == plain θ again
    a.set_staleness_hint(0);
    let mut back = vec![0.0f32; k];
    a.master_send(0, &mut back, s);
    assert_eq!(back, send0, "hint 0 must be an exact no-op");
}

// ---------------------------------------------------------------- (2)

#[test]
fn pipelined_runs_are_deterministic_per_seed() {
    let k = 96;
    for kind in [AlgorithmKind::DanaZero, AlgorithmKind::DanaSlim, AlgorithmKind::Asgd] {
        let mut c = cfg(kind, 4, 0.6, 2);
        c.metrics_every = 3;
        let a = sim_trainer::run_synthetic(&c, k).unwrap();
        let b = sim_trainer::run_synthetic(&c, k).unwrap();
        assert_eq!(a.final_test_loss, b.final_test_loss, "{kind}");
        assert_eq!(a.loss_curve, b.loss_curve, "{kind}");
        assert_eq!(a.lag_curve, b.lag_curve, "{kind}");
        // and the pipeline actually changes the trajectory vs D=0
        let d0 = sim_trainer::run_synthetic(&cfg(kind, 4, 0.6, 0), k).unwrap();
        assert_ne!(
            a.final_test_loss, d0.final_test_loss,
            "{kind}: depth 2 must train on staler parameters than depth 0"
        );
    }
}

#[test]
fn lag_histogram_shifts_by_exactly_the_pipeline_depth() {
    // The completion schedule is depth-independent (rtt = 0), so the
    // depth-D run visits the same (step, worker) sequence as depth 0 and
    // its lags follow in closed form: batch i of worker w (0-based) was
    // pulled at step 0 while i <= D (the primed window) and right after
    // w's push i-D-1 otherwise.
    let k = 48;
    let n = 4;
    let depth = 2;
    let mut c0 = cfg(AlgorithmKind::DanaZero, n, 1.0, 0);
    c0.metrics_every = 1;
    let mut cd = c0.clone();
    cd.pipeline_depth = depth;
    let base = sim_trainer::run_synthetic(&c0, k).unwrap();
    let piped = sim_trainer::run_synthetic(&cd, k).unwrap();
    assert_eq!(base.lag_curve.len(), piped.lag_curve.len());
    // the push schedule itself is identical
    let sched0: Vec<(u64, usize)> = base.lag_curve.iter().map(|&(s, w, _)| (s, w)).collect();
    let schedd: Vec<(u64, usize)> = piped.lag_curve.iter().map(|&(s, w, _)| (s, w)).collect();
    assert_eq!(sched0, schedd, "completion schedule must be depth-independent");
    // reconstruct per-worker push-step sequences from the D=0 run
    let mut pushes: Vec<Vec<u64>> = vec![Vec::new(); n];
    for &(step, w, _) in &base.lag_curve {
        pushes[w].push(step);
    }
    let mut idx = vec![0usize; n];
    for (row, &(step, w, lag)) in piped.lag_curve.iter().enumerate() {
        let i = idx[w];
        idx[w] += 1;
        let pulled_at = if i <= depth { 0 } else { pushes[w][i - depth - 1] + 1 };
        assert_eq!(
            lag,
            step - pulled_at,
            "row {row}: worker {w} batch {i} at step {step}"
        );
    }
    // and the sanity check on the base run itself (D = 0 formula)
    let mut idx = vec![0usize; n];
    for &(step, w, lag) in &base.lag_curve {
        let i = idx[w];
        idx[w] += 1;
        let pulled_at = if i == 0 { 0 } else { pushes[w][i - 1] + 1 };
        assert_eq!(lag, step - pulled_at, "depth-0 self-consistency");
    }
    // net effect: mean lag strictly grows with the depth
    assert!(
        piped.mean_lag > base.mean_lag,
        "depth {depth} must raise the mean lag: {} vs {}",
        piped.mean_lag,
        base.mean_lag
    );
}

// ---------------------------------------------------------------- (3)

#[test]
fn single_worker_lag_ramps_to_exactly_the_depth() {
    let k = 16;
    for depth in [0usize, 1, 3] {
        let mut c = cfg(AlgorithmKind::Asgd, 1, 1.0, depth);
        c.metrics_every = 1;
        let rep = sim_trainer::run_synthetic(&c, k).unwrap();
        for (i, &(_, w, lag)) in rep.lag_curve.iter().enumerate() {
            assert_eq!(w, 0);
            assert_eq!(
                lag,
                (i as u64).min(depth as u64),
                "depth {depth}: lag at push {i}"
            );
        }
    }
}

// ---------------------------------------------------------------- (4)

#[test]
fn thread_backend_pipelines_and_descends() {
    let k = 512;
    let j0 = real_async::synthetic_loss(
        &real_async::synthetic_theta0(k),
        &real_async::synthetic_curvature(k),
    );
    for depth in [1usize, 2] {
        let mut c = cfg(AlgorithmKind::DanaZero, 4, 2.0, depth);
        c.metrics_every = 7;
        let rep = real_async::run_synthetic(&c, k).unwrap();
        assert_eq!(rep.steps, c.total_master_steps());
        assert!(!rep.diverged);
        assert_eq!(rep.pushes_dropped, 0, "no churn, nothing to drop");
        for w in rep.loss_curve.windows(2) {
            assert!(w[0].0 < w[1].0, "master step went backwards: {w:?}");
        }
        assert!(
            rep.final_test_loss < 0.1 * j0,
            "depth {depth}: loss {} vs initial {j0}",
            rep.final_test_loss
        );
    }
}

// ---------------------------------------------------------------- (5)

/// The `dana serve` master for a config (zero slots: connect == join).
fn serve_master(c: &TrainConfig, k: usize) -> Box<dyn Master> {
    make_master(
        c.algorithm,
        &real_async::synthetic_theta0(k),
        LrSchedule::new(c.schedule.clone()),
        0,
        c.shards,
        1,
    )
}

#[test]
fn loopback_smoke_depth_0_and_1_match_in_process_bit_for_bit() {
    let k = 48;
    for depth in [0usize, 1] {
        for kind in [AlgorithmKind::DanaZero, AlgorithmKind::DanaSlim] {
            let c = cfg(kind, 3, 0.6, depth);
            let base = sim_trainer::run_synthetic(&c, k).unwrap();
            let opts = ServeOptions { pipeline_depth: depth, ..Default::default() };
            let mut srv = NetServer::start(serve_master(&c, k), "127.0.0.1:0", opts).unwrap();
            let mut rc = c.clone();
            rc.master_addr = Some(srv.url());
            let remote = sim_trainer::run_synthetic(&rc, k).unwrap();
            assert_eq!(
                remote.final_test_loss, base.final_test_loss,
                "{kind} D={depth}: final loss diverged across the wire"
            );
            assert_eq!(remote.loss_curve, base.loss_curve, "{kind} D={depth}: loss curve");
            assert_eq!(remote.steps, base.steps, "{kind} D={depth}");
            srv.stop();
        }
    }
}

#[test]
fn deferred_ack_push_actually_defers() {
    // Between a pipelined push and the next request, the client holds an
    // un-harvested ack; a blocking (D=0) push never does.
    let k = 8;
    let c = cfg(AlgorithmKind::Asgd, 1, 1.0, 1);
    let opts = ServeOptions { pipeline_depth: 1, ..Default::default() };
    let mut srv = NetServer::start(serve_master(&c, k), "127.0.0.1:0", opts).unwrap();
    let mut rm = dana::net::RemoteMaster::connect(&srv.url(), 1).unwrap();
    rm.set_pipeline_depth(1);
    let mut buf = vec![0.0f32; k];
    rm.pull_into(0, &mut buf);
    rm.pull_into(0, &mut buf);
    assert_eq!(rm.inflight_pushes(0), 0);
    rm.push_update(0, &vec![0.1; k]).unwrap();
    assert_eq!(rm.inflight_pushes(0), 1, "the push must not block on its ack");
    // the next pull harvests it transparently
    rm.pull_into(0, &mut buf);
    assert_eq!(rm.inflight_pushes(0), 0, "the pull must harvest the owed ack");
    assert_eq!(rm.steps_done(), 1, "the harvested header reflects the applied push");
    // drain on an idle connection is a no-op
    rm.drain_inflight().unwrap();
    drop(rm);
    srv.stop();
}

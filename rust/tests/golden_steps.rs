//! Golden single-step unit tests: for each of the ten `AlgorithmKind`s,
//! a hand-computed `master_apply` / `master_send` step on a tiny θ (k = 4)
//! asserting the exact expected vectors, so optimizer regressions are
//! caught at the unit level before any trajectory-level test fires.
//!
//! All constants are small powers of two (η = γ = λ = 1/2, inputs in
//! {0, ±1/2, ±1, ±2}) so every product and sum below is exactly
//! representable in f32 — the asserts are **exact**, not tolerance-based
//! (except YellowFin, whose self-tuned learning rate is checked
//! structurally against the tuner's own output).

use dana::optim::easgd::Easgd;
use dana::optim::{make_algorithm, Algorithm, AlgorithmKind, Step};

const K: usize = 4;

fn s() -> Step {
    Step { eta: 0.5, gamma: 0.5, lambda: 0.5 }
}

fn theta0() -> Vec<f32> {
    vec![1.0, 2.0, -1.0, 0.5]
}

fn grad() -> Vec<f32> {
    vec![1.0, -1.0, 2.0, 0.0]
}

/// `sent` differing from θ by [0.5, 0, 1, 0] — exercises the DC term.
fn sent() -> Vec<f32> {
    vec![0.5, 2.0, -2.0, 0.5]
}

#[test]
fn golden_asgd() {
    // θ' = θ − η·g = [1−0.5, 2+0.5, −1−1, 0.5]
    let mut a = make_algorithm(AlgorithmKind::Asgd, &theta0(), 2);
    a.master_apply(0, &grad(), &sent(), s());
    assert_eq!(a.theta(), &[0.5, 2.5, -2.0, 0.5]);
}

#[test]
fn golden_dana_slim_master_is_asgd() {
    // The master half is byte-identical to ASGD (Algorithm 6).
    let mut a = make_algorithm(AlgorithmKind::DanaSlim, &theta0(), 2);
    a.master_apply(0, &grad(), &sent(), s());
    assert_eq!(a.theta(), &[0.5, 2.5, -2.0, 0.5]);
}

#[test]
fn golden_dana_slim_worker_message() {
    // v' = γ·0 + g = g ;  msg = γ·v' + g = 1.5·g   (Alg 6 send)
    let a = make_algorithm(AlgorithmKind::DanaSlim, &theta0(), 2);
    let mut ws = a.make_worker_state();
    let mut msg = grad();
    a.worker_message(&mut ws, &mut msg, s());
    assert_eq!(ws.v, grad());
    assert_eq!(msg, vec![1.5, -1.5, 3.0, 0.0]);
}

#[test]
fn golden_nag_asgd_two_steps() {
    // Shared v (Algorithm 8).  Step 1: v = g, θ = θ0 − 0.5·g.
    // Step 2 (same g, other worker): v = 0.5·g + g = 1.5·g,
    //   θ = [0.5, 2.5, −2, 0.5] − 0.5·1.5·g = [−0.25, 3.25, −3.5, 0.5].
    let mut a = make_algorithm(AlgorithmKind::NagAsgd, &theta0(), 2);
    a.master_apply(0, &grad(), &sent(), s());
    assert_eq!(a.theta(), &[0.5, 2.5, -2.0, 0.5]);
    a.master_apply(1, &grad(), &sent(), s());
    assert_eq!(a.theta(), &[-0.25, 3.25, -3.5, 0.5]);
}

#[test]
fn golden_multi_asgd_two_steps() {
    // Per-worker v (Algorithm 9): worker 1's v starts at 0, so the second
    // apply is NOT momentum-inflated: θ = [0.5, 2.5, −2, 0.5] − 0.5·g.
    let mut a = make_algorithm(AlgorithmKind::MultiAsgd, &theta0(), 2);
    a.master_apply(0, &grad(), &sent(), s());
    assert_eq!(a.theta(), &[0.5, 2.5, -2.0, 0.5]);
    a.master_apply(1, &grad(), &sent(), s());
    assert_eq!(a.theta(), &[0.0, 3.0, -3.0, 0.5]);
}

#[test]
fn golden_dc_asgd() {
    // ĝ = g + λ·g⊙g⊙(θ−sent)  with θ−sent = [0.5, 0, 1, 0]:
    //   ĝ = [1 + 0.5·1·0.5, −1 + 0, 2 + 0.5·4·1, 0] = [1.25, −1, 4, 0]
    // v = ĝ ; θ' = θ − 0.5·ĝ = [0.375, 2.5, −3, 0.5].
    let mut a = make_algorithm(AlgorithmKind::DcAsgd, &theta0(), 1);
    a.master_apply(0, &grad(), &sent(), s());
    assert_eq!(a.theta(), &[0.375, 2.5, -3.0, 0.5]);
}

#[test]
fn golden_lwp() {
    // Apply: shared v = g, θ = θ0 − 0.5·g (Algorithm 3).
    // Send with τ = N = 4: θ̂ = θ − τ·η·v = θ − 2·g = [−1.5, 4.5, −6, 0.5].
    let mut a = make_algorithm(AlgorithmKind::Lwp, &theta0(), 4);
    a.master_apply(0, &grad(), &sent(), s());
    assert_eq!(a.theta(), &[0.5, 2.5, -2.0, 0.5]);
    let mut hat = vec![0.0f32; K];
    a.master_send(0, &mut hat, s());
    assert_eq!(hat, vec![-1.5, 4.5, -6.0, 0.5]);
}

#[test]
fn golden_dana_zero() {
    // Apply (Eq 10 + A.2): v⁰ = g, θ = θ0 − 0.5·g, v_sum = g.
    // Send (Eq 11): θ̂ = θ − η·γ·v_sum = θ − 0.25·g = [0.25, 2.75, −2.5, 0.5].
    let mut a = make_algorithm(AlgorithmKind::DanaZero, &theta0(), 2);
    a.master_apply(0, &grad(), &sent(), s());
    assert_eq!(a.theta(), &[0.5, 2.5, -2.0, 0.5]);
    let mut hat = vec![0.0f32; K];
    a.master_send(0, &mut hat, s());
    assert_eq!(hat, vec![0.25, 2.75, -2.5, 0.5]);
}

#[test]
fn golden_dana_dc() {
    // ĝ = [1.25, −1, 4, 0] (as DC-ASGD), then the DANA bookkeeping:
    //   v⁰ = ĝ ; θ' = θ − 0.5·ĝ = [0.375, 2.5, −3, 0.5] ; v_sum = ĝ.
    // Send: θ̂ = θ' − 0.25·v_sum = [0.0625, 2.75, −4, 0.5].
    let mut a = make_algorithm(AlgorithmKind::DanaDc, &theta0(), 2);
    a.master_apply(0, &grad(), &sent(), s());
    assert_eq!(a.theta(), &[0.375, 2.5, -3.0, 0.5]);
    let mut hat = vec![0.0f32; K];
    a.master_send(0, &mut hat, s());
    assert_eq!(hat, vec![0.0625, 2.75, -4.0, 0.5]);
}

#[test]
fn golden_easgd() {
    // α = 1/4 (exact).  Worker replica: v = g, x = θ0 − 0.5·g = [0.5, 2.5, −2, 0.5].
    // Elastic exchange against the center c = θ0:
    //   d = α(x − c) = 0.25·[−0.5, 0.5, −1, 0] = [−0.125, 0.125, −0.25, 0]
    //   x' = x − d = [0.625, 2.375, −1.75, 0.5]
    //   c' = c + d = [0.875, 2.125, −1.25, 0.5]
    let mut a = Easgd::new(&theta0(), 2).with_alpha(0.25);
    a.master_apply(0, &grad(), &sent(), s());
    assert_eq!(a.theta(), &[0.875, 2.125, -1.25, 0.5]);
    assert_eq!(a.replica(0), &[0.625, 2.375, -1.75, 0.5]);
    // Worker 1's replica is untouched and is what worker 1 receives.
    let mut out = vec![0.0f32; K];
    a.master_send(1, &mut out, s());
    assert_eq!(out, theta0());
}

#[test]
fn golden_yellowfin_first_step() {
    // YellowFin ignores the schedule and self-tunes, so the golden check
    // is structural: with zero initial momentum the first applied update
    // is exactly θ' = θ0 − lr·g where lr is the tuner's post-step output,
    // and the paper-§5 initialization bounds it near 1e-4.
    let mut a = make_algorithm(AlgorithmKind::YellowFin, &theta0(), 1);
    a.master_apply(0, &grad(), &sent(), s());
    assert_eq!(a.kind(), AlgorithmKind::YellowFin);
    // recover lr from the only zero-gradient coordinate staying fixed and
    // a moved coordinate; then check all coordinates against θ0 − lr·g.
    let th = a.theta();
    let t0 = theta0();
    let g = grad();
    assert_eq!(th[3], t0[3], "zero-gradient coordinate must not move");
    let lr = (t0[0] - th[0]) / g[0];
    assert!(
        lr > 9.0e-5 && lr < 5.0e-3,
        "first-step lr {lr} outside the tuner's plausible band"
    );
    for i in 0..K {
        let want = t0[i] - lr * g[i];
        assert!(
            (th[i] - want).abs() <= 1e-6 * (1.0 + want.abs()),
            "coordinate {i}: {} vs {want}",
            th[i]
        );
    }
}

/// The factory and the golden steps above cover every kind; this guard
/// fails if a new AlgorithmKind is added without a golden test.
#[test]
fn golden_suite_covers_all_kinds() {
    assert_eq!(AlgorithmKind::ALL.len(), 10, "add a golden test for the new algorithm");
}

//! Operable-daemon acceptance (ISSUE 6): the HTTP status endpoint, the
//! checkpoint retention policy, crash-loop-aware supervision, and the
//! pipelined reconnect accounting fix — all end-to-end against real
//! sockets and a real `NetServer`.
//!
//! 1. `--status-addr` serves Prometheus text on `/metrics` and the JSON
//!    slot table on `/status` against a live cluster, and fails closed on
//!    malformed traffic without perturbing training.
//! 2. `--keep-last` retention archives every durable checkpoint and
//!    garbage-collects the tail, never the newest snapshot.
//! 3. `--max-restarts` restarts a crashed worker thread in place (exact
//!    `worker_restarts` counter) and retires it for good once the budget
//!    is exhausted (exact `workers_lost` counter); the default budget of
//!    0 preserves the classic die-once semantics.
//! 4. A pipelined client (D ≥ 1) that reconnects with acks owed abandons
//!    them into `Master::pushes_lost` and resyncs its step accounting to
//!    the resumed server — client and server agree exactly afterwards.

use dana::config::{TrainConfig, Workload};
use dana::net::retention::{self, RetentionPolicy};
use dana::net::{checkpoint, NetServer, RemoteMaster, ServeOptions};
use dana::optim::{AlgorithmKind, LeavePolicy, LrSchedule};
use dana::server::{make_master, Master};
use dana::train::real_async::{self, StepFn};
use dana::util::json::Json;
use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn cfg(kind: AlgorithmKind, workers: usize, epochs: f64) -> TrainConfig {
    let mut c = TrainConfig::preset(Workload::C10, kind, workers, epochs);
    c.seed = 23;
    c.metrics_every = 0;
    c
}

/// The master a `dana serve` for this config would host (zero slots:
/// connect == join) — same idiom as `rust/tests/net.rs`.
fn serve_master(c: &TrainConfig, k: usize) -> Box<dyn Master> {
    make_master(
        c.algorithm,
        &real_async::synthetic_theta0(k),
        LrSchedule::new(c.schedule.clone()),
        0,
        c.shards,
        2,
    )
}

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("dana-daemon-{name}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// One raw HTTP exchange against the status listener: write the request
/// bytes, read the whole reply (the server closes the connection).
fn http_get(addr: SocketAddr, request: &str) -> String {
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.write_all(request.as_bytes()).unwrap();
    let mut reply = String::new();
    conn.read_to_string(&mut reply).unwrap();
    reply
}

/// The body of a 200 reply (everything after the blank line).
fn body(reply: &str) -> &str {
    reply.split_once("\r\n\r\n").expect("complete HTTP reply").1
}

// ---------------------------------------------------------------- (1)

#[test]
fn status_endpoint_serves_live_metrics_and_slot_table() {
    let k = 16;
    let c = cfg(AlgorithmKind::DanaZero, 2, 1.0);
    let opts = ServeOptions {
        status_addr: Some("127.0.0.1:0".to_string()),
        ..Default::default()
    };
    let mut srv = NetServer::start(serve_master(&c, k), "127.0.0.1:0", opts).unwrap();
    let status = srv.status_addr().expect("--status-addr must expose the bound address");

    // a fresh daemon scrapes clean
    let text = http_get(status, "GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n");
    assert!(text.starts_with("HTTP/1.1 200 OK"), "{text}");
    assert!(body(&text).contains("dana_pushes_total 0"), "{text}");
    assert!(body(&text).contains("dana_workers_live 0"), "{text}");

    // train a little: 2 workers, 3 pushes
    let mut rm = RemoteMaster::connect(&srv.url(), 2).unwrap();
    for (round, w) in [(0, 0), (0, 1), (1, 0)] {
        let p = rm.pull_params(w);
        let g: Vec<f32> = p.iter().map(|&x| 0.1 * x + round as f32 * 0.01).collect();
        rm.push_update(w, &g).unwrap();
    }

    // /metrics reflects the live cluster, atomics only
    let text = http_get(status, "GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n");
    let m = body(&text);
    for line in [
        "dana_master_step 3",
        "dana_pushes_total 3",
        "dana_pushes_dropped_total 0",
        "dana_workers_live 2",
        "dana_workers_total 2",
        "dana_lag_count 3",
        "# TYPE dana_lag histogram",
        "# TYPE dana_gap histogram",
        "dana_pushes_per_second",
        "dana_uptime_seconds",
    ] {
        assert!(m.contains(line), "missing {line:?} in:\n{m}");
    }

    // /status adds the per-slot table as JSON
    let text = http_get(status, "GET /status HTTP/1.1\r\nHost: t\r\n\r\n");
    assert!(text.contains("application/json"), "{text}");
    let v = Json::parse(body(&text)).unwrap();
    assert_eq!(v.at(&["master_step"]).unwrap().as_usize().unwrap(), 3);
    assert_eq!(v.at(&["workers_live"]).unwrap().as_usize().unwrap(), 2);
    assert_eq!(v.at(&["pushes_total"]).unwrap().as_usize().unwrap(), 3);
    let slots = v.at(&["slots"]).unwrap().as_arr().unwrap();
    assert_eq!(slots.len(), 2);
    for (i, s) in slots.iter().enumerate() {
        assert_eq!(s.get("slot").unwrap().as_usize().unwrap(), i);
        assert!(s.get("live").unwrap().as_bool().unwrap(), "slot {i} live");
        assert_eq!(s.get("generation").unwrap().as_usize().unwrap(), 1, "attached once");
        assert!(s.get("last_push").unwrap().as_usize().unwrap() > 0, "slot {i} pushed");
    }

    // fail-closed over the real socket: answered, never 200, server fine
    for (req, code) in [
        ("BLAH\r\n\r\n", "400"),
        ("GET /secrets HTTP/1.1\r\n\r\n", "404"),
        ("POST /metrics HTTP/1.1\r\n\r\n", "405"),
    ] {
        let reply = http_get(status, req);
        assert!(reply.starts_with(&format!("HTTP/1.1 {code}")), "{req:?} -> {reply}");
    }
    // ...and training continues undisturbed after the abuse
    let p = rm.pull_params(0);
    let g: Vec<f32> = p.iter().map(|&x| 0.1 * x).collect();
    rm.push_update(0, &g).unwrap();
    let text = http_get(status, "GET /metrics HTTP/1.1\r\n\r\n");
    assert!(body(&text).contains("dana_pushes_total 4"), "{text}");

    srv.stop();
    // the status listener dies with the server
    assert!(TcpStream::connect(status).is_err() || {
        let mut conn = TcpStream::connect(status).unwrap();
        conn.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
        let _ = conn.write_all(b"GET /metrics HTTP/1.1\r\n\r\n");
        let mut buf = [0u8; 1];
        !matches!(conn.read(&mut buf), Ok(n) if n > 0)
    });
}

#[test]
fn bad_status_addr_fails_start_cleanly() {
    let k = 8;
    let c = cfg(AlgorithmKind::Asgd, 1, 1.0);
    let opts = ServeOptions {
        status_addr: Some("256.0.0.1:notaport".to_string()),
        ..Default::default()
    };
    let err = NetServer::start(serve_master(&c, k), "127.0.0.1:0", opts).unwrap_err();
    assert!(err.to_string().contains("status listener bind"), "{err:#}");
}

// ---------------------------------------------------------------- (2)

#[test]
fn retention_archives_and_gc_keep_newest_checkpoints() {
    let k = 12;
    let c = cfg(AlgorithmKind::DanaZero, 1, 1.0);
    let dir = tmpdir("retention");
    let ckpt = dir.join("server.ckpt");
    let opts = ServeOptions {
        leave_policy: LeavePolicy::Retire,
        checkpoint_path: Some(ckpt.clone()),
        checkpoint_every: 0,
        retention: RetentionPolicy { keep_last: 2, keep_hourly: 0 },
        ..Default::default()
    };
    let mut srv = NetServer::start(serve_master(&c, k), "127.0.0.1:0", opts).unwrap();
    let mut rm = RemoteMaster::connect(&srv.url(), 1).unwrap();

    // five checkpointed steps; every write runs an archive + GC pass
    for step in 1..=5u64 {
        let p = rm.pull_params(0);
        let g: Vec<f32> = p.iter().map(|&x| 0.1 * x).collect();
        rm.push_update(0, &g).unwrap();
        rm.force_checkpoint().unwrap();
        assert_eq!(checkpoint::read_snapshot(&ckpt).unwrap().master_step, step);
        assert!(
            retention::archive_path(&ckpt, step).exists(),
            "step {step}: archive must exist right after its checkpoint"
        );
    }

    // GC kept exactly the newest keep_last archives, steps ascending
    let archives = retention::list_archives(&ckpt).unwrap();
    let steps: Vec<u64> = archives.iter().map(|a| a.step).collect();
    assert_eq!(steps, vec![4, 5], "keep_last=2 keeps the two newest");
    // the newest archive is byte-identical to the plain durable file
    assert_eq!(
        checkpoint::read_snapshot(&retention::archive_path(&ckpt, 5)).unwrap(),
        checkpoint::read_snapshot(&ckpt).unwrap()
    );
    // a resume from the newest archive works like one from the base file
    let snap = checkpoint::read_snapshot(&retention::archive_path(&ckpt, 5)).unwrap();
    let mut resumed = serve_master(&c, k);
    resumed.restore(&snap).unwrap();
    assert_eq!(resumed.steps_done(), 5);
    srv.stop();
}

// ---------------------------------------------------------------- (3)

fn quad_eval(k: usize) -> impl FnMut(&[f32]) -> anyhow::Result<(f64, f64)> {
    let curv = real_async::synthetic_curvature(k);
    move |theta: &[f32]| Ok(real_async::synthetic_eval(theta, &curv))
}

/// A synthetic step factory where worker `bad` panics: once (its first
/// incarnation's first step) when `always` is false, or on every step
/// when true.
fn panicky_quadratic(
    k: usize,
    seed: u64,
    bad: usize,
    always: bool,
) -> impl Fn(usize) -> anyhow::Result<StepFn> + Sync {
    let curv = real_async::synthetic_curvature(k);
    let tripped = Arc::new(AtomicBool::new(false));
    move |w: usize| -> anyhow::Result<StepFn> {
        let curv = curv.clone();
        let tripped = Arc::clone(&tripped);
        let mut rng = real_async::synthetic_worker_rng(seed, w);
        Ok(Box::new(move |params: &[f32]| {
            if w == bad && (always || !tripped.swap(true, Ordering::SeqCst)) {
                panic!("injected crash in worker {w}");
            }
            let mut g = vec![0.0f32; params.len()];
            real_async::synthetic_grad(params, &curv, &mut rng, &mut g);
            Ok((real_async::synthetic_loss(params, &curv) as f32, g))
        }) as StepFn)
    }
}

#[test]
fn crashed_worker_restarts_in_place_and_run_completes() {
    // Worker 1 panics exactly once; with a restart budget the supervisor
    // respawns it (slot stays live, momentum kept) and the run finishes
    // with nobody lost.
    let k = 256;
    let mut c = cfg(AlgorithmKind::DanaZero, 2, 1.0); // 100 master steps
    c.max_restarts = 3;
    c.restart_backoff_ms = 1;
    let make_step = panicky_quadratic(k, c.seed, 1, false);
    let rep =
        real_async::run_core(&c, &real_async::synthetic_theta0(k), &make_step, quad_eval(k))
            .unwrap();
    assert_eq!(rep.steps, c.total_master_steps());
    assert_eq!(rep.worker_restarts, 1, "exactly one restart");
    assert_eq!(rep.workers_lost, 0, "a restarted worker is not lost");
    assert!(!rep.diverged);
    assert!(rep.summary().contains("restarts=1"), "{}", rep.summary());
}

#[test]
fn crash_loop_exhausts_restart_budget_then_retires() {
    // Worker 1 panics on every step: the supervisor restarts it
    // `max_restarts` times, then retires the slot for good — the exact
    // counters pin the budget arithmetic.
    let k = 128;
    let mut c = cfg(AlgorithmKind::DanaZero, 2, 1.0); // 100 master steps
    c.max_restarts = 2;
    c.restart_backoff_ms = 1;
    let make_step = panicky_quadratic(k, c.seed, 1, true);
    let rep =
        real_async::run_core(&c, &real_async::synthetic_theta0(k), &make_step, quad_eval(k))
            .unwrap();
    assert_eq!(rep.steps, c.total_master_steps(), "the survivor finishes the budget");
    assert_eq!(rep.worker_restarts, 2, "budget spent exactly");
    assert_eq!(rep.workers_lost, 1, "then the slot is retired once");
}

#[test]
fn default_restart_budget_is_zero_die_once() {
    // Without --max-restarts a crash is the classic implicit leave, no
    // respawn — bit-for-bit with every pre-supervision run.
    let k = 64;
    let c = cfg(AlgorithmKind::Asgd, 2, 0.5); // 50 master steps
    assert_eq!(c.max_restarts, 0, "supervision must be opt-in");
    let make_step = panicky_quadratic(k, c.seed, 1, false);
    let rep =
        real_async::run_core(&c, &real_async::synthetic_theta0(k), &make_step, quad_eval(k))
            .unwrap();
    assert_eq!(rep.steps, c.total_master_steps());
    assert_eq!(rep.worker_restarts, 0);
    assert_eq!(rep.workers_lost, 1);
    assert!(!rep.summary().contains("restarts="), "{}", rep.summary());
}

// ---------------------------------------------------------------- (4)

#[test]
fn pipelined_reconnect_abandons_owed_acks_and_resyncs_steps() {
    let k = 32;
    let c = cfg(AlgorithmKind::DanaZero, 1, 1.0);
    let dir = tmpdir("abandon");
    let ckpt = dir.join("server.ckpt");
    let opts = ServeOptions {
        leave_policy: LeavePolicy::Retire,
        checkpoint_path: Some(ckpt.clone()),
        checkpoint_every: 0,
        pipeline_depth: 1,
        ..Default::default()
    };
    let mut srv = NetServer::start(serve_master(&c, k), "127.0.0.1:0", opts.clone()).unwrap();
    let mut rm = RemoteMaster::connect(&srv.url(), 1).unwrap();
    rm.set_pipeline_depth(1);

    // one pipelined cycle: the push is a send, its ack stays owed
    let p = rm.pull_params(0);
    let g: Vec<f32> = p.iter().map(|&x| 0.1 * x).collect();
    rm.push_update(0, &g).unwrap();
    assert_eq!(rm.inflight_pushes(0), 1, "D=1 push must defer its ack");

    // wait until the server has applied the un-acked push, then make it
    // durable (control traffic must not harvest the worker's owed ack)
    let deadline = Instant::now() + Duration::from_secs(10);
    while rm.refresh_status().unwrap().master_step < 1 {
        assert!(Instant::now() < deadline, "server never applied the deferred push");
        std::thread::sleep(Duration::from_millis(5));
    }
    rm.force_checkpoint().unwrap();
    assert_eq!(checkpoint::read_snapshot(&ckpt).unwrap().master_step, 1);
    assert_eq!(rm.inflight_pushes(0), 1, "control requests must not touch worker acks");

    // hard kill with the ack still owed, resume on a fresh port
    srv.stop();
    drop(srv);
    let snap = checkpoint::read_snapshot(&ckpt).unwrap();
    let mut resumed = serve_master(&c, k);
    resumed.restore(&snap).unwrap();
    let mut srv2 = NetServer::start(resumed, "127.0.0.1:0", opts).unwrap();

    // reconnect: the owed ack is abandoned AND accounted, the step cache
    // resyncs to the resumed server, the worker gets its slot back
    rm.reconnect_to(&srv2.url()).unwrap();
    assert_eq!(rm.abandoned_pushes(), 1, "the owed ack must be abandoned exactly once");
    assert_eq!(rm.pushes_lost(), 1, "...and surfaced through Master::pushes_lost");
    assert_eq!(rm.inflight_pushes(0), 0);
    assert_eq!(rm.server_slot(0), Some(0));
    assert_eq!(rm.steps_done(), 1, "client step cache resynced to the resumed server");
    assert_eq!(srv2.steps_done(), 1);

    // the pipeline keeps working after the reconnect; drain settles it
    let p = rm.pull_params(0);
    let g: Vec<f32> = p.iter().map(|&x| 0.1 * x).collect();
    rm.push_update(0, &g).unwrap();
    rm.drain_inflight().unwrap();
    assert_eq!(rm.inflight_pushes(0), 0);
    assert_eq!(
        (rm.steps_done(), srv2.steps_done()),
        (2, 2),
        "client and server step accounting must agree after the cycle"
    );
    assert_eq!(rm.pushes_lost(), 1, "no further acks were abandoned");
    srv2.stop();
}

//! Elastic-membership equivalence obligations (ISSUE 2 acceptance):
//!
//! 1. An *empty* churn schedule reproduces the fixed-membership
//!    trajectories bit-for-bit, for all 10 algorithm kinds × both server
//!    layouts — the refactor must be invisible when nothing churns.
//! 2. The DANA invariant v⁰ = Σ live vᶦ holds across randomized
//!    join/leave sequences, under both leave policies.
//! 3. Sharded ≡ monolithic (≤1e-5 rel) survives membership changes — the
//!    change fans across all shards atomically.
//! 4. The simulated-clock driver trains through mid-run join/leave/
//!    straggler events: no deadlock, monotone steps, loss still descends.

use dana::config::{TrainConfig, Workload};
use dana::optim::dana_dc::DanaDc;
use dana::optim::dana_zero::DanaZero;
use dana::optim::{
    make_algorithm, Algorithm, AlgorithmKind, LeavePolicy, LrSchedule, ScheduleConfig, Step,
};
use dana::server::{ParameterServer, ShardedParameterServer};
use dana::sim::{AsyncSchedule, ChurnSchedule, ClusterEvent, Environment, ExecTimeModel};
use dana::train::{real_async, sim_trainer};
use dana::util::rng::Rng;

fn cfg(alg: AlgorithmKind, workers: usize, epochs: f64, shards: usize) -> TrainConfig {
    let mut cfg = TrainConfig::preset(Workload::C10, alg, workers, epochs);
    cfg.seed = 23;
    cfg.metrics_every = 7;
    cfg.shards = shards;
    cfg
}

/// Replicates the pre-elastic sim driver loop over the synthetic
/// quadratic: plain `next_completion` stream, no membership events, with
/// the same RNG forks `sim_trainer::run_synthetic` uses.  Equality against
/// it pins that the event-stream refactor changed nothing when nothing
/// churns.
fn legacy_synthetic(cfg: &TrainConfig, k: usize) -> (f64, Vec<(u64, f64)>, f64, f64) {
    let theta0 = real_async::synthetic_theta0(k);
    let curv = real_async::synthetic_curvature(k);
    let n = cfg.n_workers;
    let mut server = dana::server::make_master(
        cfg.algorithm,
        &theta0,
        LrSchedule::new(cfg.schedule.clone()),
        n,
        cfg.shards,
        dana::util::parallel::default_threads(),
    );
    server.metrics_mut().set_every(cfg.metrics_every);
    let total = cfg.total_master_steps();
    let mut cluster_rng = Rng::new(cfg.seed);
    let exec_model = ExecTimeModel::new(cfg.env, n, cfg.batch(), &mut cluster_rng);
    let mut schedule = AsyncSchedule::new(exec_model, cluster_rng.fork(1));
    let mut grad_rng = Rng::new(cfg.seed ^ sim_trainer::SYNTH_GRAD_STREAM);

    let mut local: Vec<Vec<f32>> = (0..n).map(|w| server.pull_params(w)).collect();
    let mut wstate: Vec<_> = (0..n).map(|_| server.make_worker_state()).collect();
    let loss_sample = (total / 200).max(1);
    let mut loss_curve = Vec::new();
    let mut msg = vec![0.0f32; k];
    for step in 0..total {
        let c = schedule.next_completion();
        let w = c.worker;
        for ((g, &p), &cv) in msg.iter_mut().zip(&local[w]).zip(&curv) {
            *g = cv * p + 0.01 * grad_rng.normal() as f32;
        }
        if step % loss_sample == 0 {
            loss_curve.push((step, real_async::synthetic_loss(&local[w], &curv)));
        }
        let s = server.step_now();
        server.worker_transform(&mut wstate[w], &mut msg, s);
        server.push_update(w, &msg).unwrap();
        server.pull_into(w, &mut local[w]);
    }
    let final_loss = real_async::synthetic_loss(&server.theta_vec(), &curv);
    (
        final_loss,
        loss_curve,
        server.metrics().mean_gap(),
        server.metrics().mean_lag(),
    )
}

/// (1) churn-free equivalence: all 10 kinds × {monolithic, sharded}.
#[test]
fn empty_churn_reproduces_legacy_trajectories_bit_for_bit() {
    let k = 96;
    for kind in AlgorithmKind::ALL {
        for shards in [1usize, 4] {
            let c = cfg(kind, 4, 1.0, shards);
            assert!(c.churn.is_empty());
            let rep = sim_trainer::run_synthetic(&c, k).unwrap();
            let (final_loss, loss_curve, gap, lag) = legacy_synthetic(&c, k);
            assert_eq!(
                rep.final_test_loss, final_loss,
                "{kind} S={shards}: final loss diverged from pre-elastic driver"
            );
            assert_eq!(rep.loss_curve, loss_curve, "{kind} S={shards}: loss curve");
            assert_eq!(rep.mean_gap, gap, "{kind} S={shards}: mean gap");
            assert_eq!(rep.mean_lag, lag, "{kind} S={shards}: mean lag");
            assert_eq!(rep.workers_joined + rep.workers_left + rep.workers_lost, 0);
        }
    }
}

/// Mini property driver (same shape as rust/tests/properties.rs).
fn for_random_cases(cases: u64, f: impl Fn(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xC4A1 ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed for case seed={seed}");
            std::panic::resume_unwind(e);
        }
    }
}

fn rand_vec(rng: &mut Rng, k: usize, scale: f32) -> Vec<f32> {
    (0..k).map(|_| scale * rng.normal() as f32).collect()
}

/// Drive a randomized apply/join/leave sequence (alternating leave
/// policies) against `alg`, calling `check` after every membership change
/// and at the end — the checker has the concrete type, so it can reach
/// the DANA accessors.
fn drive_membership_sequence<T: Algorithm>(
    rng: &mut Rng,
    alg: &mut T,
    k: usize,
    mut check: impl FnMut(&T),
) {
    let mut live: Vec<usize> = (0..3).collect();
    let mut next_policy = LeavePolicy::Retire;
    for _ in 0..120 {
        let roll = rng.uniform();
        if roll < 0.1 && live.len() > 1 {
            // a random live worker leaves
            let i = rng.below(live.len() as u64) as usize;
            let w = live.swap_remove(i);
            alg.remove_worker(w, next_policy);
            next_policy = match next_policy {
                LeavePolicy::Retire => LeavePolicy::Fold,
                LeavePolicy::Fold => LeavePolicy::Retire,
            };
            check(alg);
        } else if roll < 0.2 {
            let w = alg.add_worker();
            assert!(!live.contains(&w), "slot {w} double-allocated");
            live.push(w);
            check(alg);
        } else {
            let w = live[rng.below(live.len() as u64) as usize];
            let s = Step {
                eta: rng.uniform_range(0.001, 0.2) as f32,
                gamma: rng.uniform_range(0.0, 0.99) as f32,
                lambda: 1.0,
            };
            let g = rand_vec(rng, k, 1.0);
            let sent = alg.theta().to_vec();
            alg.master_apply(w, &g, &sent, s);
        }
    }
    check(alg);
}

fn assert_vsum_invariant(vsum: &[f32], full: &[f32]) {
    for (a, b) in vsum.iter().zip(full) {
        assert!(
            (a - b).abs() < 2e-3 * (1.0 + b.abs()),
            "vsum invariant broken: {a} vs {b}"
        );
    }
}

/// (2) v⁰ = Σ live vᶦ across randomized join/leave — DANA-Zero.  Checked
/// after *every* membership change, not just at the end.
#[test]
fn prop_dana_zero_vsum_invariant_under_churn() {
    for_random_cases(20, |rng| {
        let k = 1 + rng.below(48) as usize;
        let mut d = DanaZero::new(&rand_vec(rng, k, 1.0), 3);
        drive_membership_sequence(rng, &mut d, k, |d: &DanaZero| {
            assert_vsum_invariant(d.velocity_sum(), &d.recompute_vsum());
        });
    });
}

/// (2) v⁰ = Σ live vᶦ across randomized join/leave — DANA-DC.
#[test]
fn prop_dana_dc_vsum_invariant_under_churn() {
    for_random_cases(20, |rng| {
        let k = 1 + rng.below(48) as usize;
        let mut d = DanaDc::new(&rand_vec(rng, k, 1.0), 3);
        drive_membership_sequence(rng, &mut d, k, |d: &DanaDc| {
            assert_vsum_invariant(d.velocity_sum(), &d.recompute_vsum());
        });
    });
}

fn flat_schedule(n: usize) -> LrSchedule {
    LrSchedule::new(ScheduleConfig {
        base_eta: 0.05,
        gamma: 0.9,
        lambda: 1.0,
        warmup_epochs: 0.0,
        decay_epochs: vec![2.0],
        decay_factor: 0.5,
        steps_per_epoch: 20,
        n_workers: n,
        ..ScheduleConfig::default()
    })
}

/// |a − b| ≤ abs + rel·|b| — the sharded-equivalence tolerance.
fn assert_close(a: f32, b: f32, ctx: &str) {
    let tol = 1e-6 + 1e-5 * b.abs() as f64;
    assert!(
        (a as f64 - b as f64).abs() <= tol,
        "{ctx}: sharded {a} vs monolithic {b}"
    );
}

/// (3) sharded ≡ monolithic through identical randomized pull/push/
/// join/leave sequences, for every per-worker-state kind × S ∈ {2, 7}.
#[test]
fn prop_sharded_equals_monolithic_under_membership_churn() {
    let kinds = [
        AlgorithmKind::MultiAsgd,
        AlgorithmKind::DcAsgd,
        AlgorithmKind::DanaZero,
        AlgorithmKind::DanaDc,
        AlgorithmKind::Easgd,
        AlgorithmKind::YellowFin, // shared state + two-phase apply
    ];
    for kind in kinds {
        for &shards in &[2usize, 7] {
            for_random_cases(2, |rng| {
                let k = 5 + rng.below(40) as usize;
                let n = 2 + rng.below(3) as usize;
                let theta0 = rand_vec(rng, k, 1.0);
                let mut mono =
                    ParameterServer::new(make_algorithm(kind, &theta0, n), flat_schedule(n), n);
                let mut shrd =
                    ShardedParameterServer::new(kind, &theta0, flat_schedule(n), n, shards)
                        .with_threads(1 + rng.below(3) as usize);
                let mut live: Vec<usize> = (0..n).collect();
                let mut pulled: Vec<bool> = vec![false; n];
                for step in 0..120 {
                    let roll = rng.uniform();
                    if roll < 0.06 && live.len() > 1 {
                        let i = rng.below(live.len() as u64) as usize;
                        let w = live.swap_remove(i);
                        let policy = if rng.uniform() < 0.5 {
                            LeavePolicy::Retire
                        } else {
                            LeavePolicy::Fold
                        };
                        mono.remove_worker(w, policy).unwrap();
                        shrd.remove_worker(w, policy).unwrap();
                        // both must now reject the straggler's push
                        assert!(mono.push(w, &vec![0.1; k]).is_err());
                        assert!(shrd.push(w, &vec![0.1; k]).is_err());
                    } else if roll < 0.12 {
                        let a = mono.add_worker();
                        let b = shrd.add_worker();
                        assert_eq!(a, b, "{kind} S={shards}: slot drift at step {step}");
                        if a == pulled.len() {
                            pulled.push(false);
                        } else {
                            pulled[a] = false;
                        }
                        live.push(a);
                    } else {
                        let w = live[rng.below(live.len() as u64) as usize];
                        if !pulled[w] || rng.uniform() < 0.4 {
                            let a = shrd.pull(w);
                            let b = mono.pull(w).to_vec();
                            for i in 0..k {
                                assert_close(
                                    a[i],
                                    b[i],
                                    &format!("{kind} S={shards} step {step} send[{i}]"),
                                );
                            }
                            pulled[w] = true;
                        } else {
                            let g = rand_vec(rng, k, 0.5);
                            shrd.push(w, &g).unwrap();
                            mono.push(w, &g).unwrap();
                        }
                    }
                }
                let (a, b) = (shrd.theta_vec(), mono.theta().to_vec());
                for i in 0..k {
                    assert_close(a[i], b[i], &format!("{kind} S={shards} theta[{i}]"));
                }
            });
        }
    }
}

/// (3b) end-to-end: the simulated driver's trajectory under churn matches
/// between layouts (schedule events are layout-independent).
#[test]
fn sim_driver_sharded_matches_monolithic_under_churn() {
    let k = 64;
    for kind in [AlgorithmKind::DanaZero, AlgorithmKind::DcAsgd] {
        let mut mono_cfg = cfg(kind, 4, 1.0, 1);
        mono_cfg.churn = ChurnSchedule::parse("leave@0.3:2,join@0.5,slow@0.7:0=3x").unwrap();
        let mut shrd_cfg = mono_cfg.clone();
        shrd_cfg.shards = 4;
        let a = sim_trainer::run_synthetic(&mono_cfg, k).unwrap();
        let b = sim_trainer::run_synthetic(&shrd_cfg, k).unwrap();
        assert_eq!(a.workers_joined, 1);
        assert_eq!(a.workers_left, 1);
        assert_eq!((a.workers_joined, a.workers_left), (b.workers_joined, b.workers_left));
        let tol = 1e-5 * (1.0 + a.final_test_loss.abs());
        assert!(
            (a.final_test_loss - b.final_test_loss).abs() <= tol,
            "{kind}: mono {} vs sharded {}",
            a.final_test_loss,
            b.final_test_loss
        );
    }
}

/// (4) the simulated driver survives churn and still optimizes, for both
/// leave policies.
#[test]
fn sim_driver_trains_through_join_leave_straggler() {
    let k = 256;
    let j0 = real_async::synthetic_loss(
        &real_async::synthetic_theta0(k),
        &real_async::synthetic_curvature(k),
    );
    for policy in [LeavePolicy::Retire, LeavePolicy::Fold] {
        let mut c = cfg(AlgorithmKind::DanaZero, 6, 2.0, 1);
        c.churn =
            ChurnSchedule::parse("leave@0.2:1,join@0.35,slow@0.5:0=4x,leave@0.65,join@0.8")
                .unwrap();
        c.leave_policy = policy;
        let rep = sim_trainer::run_synthetic(&c, k).unwrap();
        assert_eq!(rep.steps, c.total_master_steps());
        assert!(!rep.diverged);
        assert_eq!(rep.workers_joined, 2);
        assert_eq!(rep.workers_left, 2);
        for w in rep.loss_curve.windows(2) {
            assert!(w[0].0 < w[1].0, "loss curve steps not monotone: {w:?}");
        }
        assert!(
            rep.final_test_loss < 0.1 * j0,
            "{policy}: loss {} vs initial {j0}",
            rep.final_test_loss
        );
    }
}

/// The event stream and the servers allocate join slots by the same rule
/// even when leaves created multiple holes.
#[test]
fn schedule_and_server_slot_assignment_stay_in_lockstep() {
    let k = 32;
    let mut c = cfg(AlgorithmKind::MultiAsgd, 5, 1.0, 1);
    // two holes (1 then 3), then three joins: reuse 1, reuse 3, append 5
    c.churn =
        ChurnSchedule::parse("leave@0.1:1,leave@0.2:3,join@0.4,join@0.5,join@0.6").unwrap();
    let rep = sim_trainer::run_synthetic(&c, k).unwrap();
    assert_eq!(rep.workers_joined, 3);
    assert_eq!(rep.workers_left, 2);
    assert_eq!(rep.steps, c.total_master_steps());
}

/// A churn schedule that would empty the cluster is rejected up front by
/// both drivers.
#[test]
fn emptying_schedules_error_cleanly() {
    let mut c = cfg(AlgorithmKind::Asgd, 2, 0.5, 1);
    c.churn = ChurnSchedule::parse("leave@0.2,leave@0.4").unwrap();
    assert!(sim_trainer::run_synthetic(&c, 16).is_err());
    assert!(real_async::run_synthetic(&c, 16).is_err());
}

/// Churn events interleave with completions in declared order even when
/// several fire at the same master step.
#[test]
fn same_step_events_fire_in_declaration_order() {
    let mut rng = Rng::new(3);
    let model = ExecTimeModel::new(Environment::Homogeneous, 2, 32, &mut rng);
    let churn = ChurnSchedule::parse("join@0.5,leave@0.5:0").unwrap();
    let mut s = AsyncSchedule::new(model, rng.fork(1)).with_churn(&churn, 10).unwrap();
    let mut events = Vec::new();
    let mut steps = 0;
    while steps < 10 {
        match s.next_event() {
            ClusterEvent::Completion(_) => steps += 1,
            ClusterEvent::Join { worker, .. } => events.push(format!("join:{worker}")),
            ClusterEvent::Leave { worker, .. } => events.push(format!("leave:{worker}")),
            ClusterEvent::SpeedChange { .. } => events.push("slow".into()),
        }
    }
    assert_eq!(events, vec!["join:2", "leave:0"]);
}

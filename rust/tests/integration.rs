//! Whole-stack integration tests: AOT artifacts → PJRT runtime → trainers.
//! These need `make artifacts` to have run; they skip (with a note) when
//! the artifacts directory is absent so `cargo test` stays meaningful in a
//! fresh checkout.

use dana::config::{default_artifacts_dir, TrainConfig, Workload};
use dana::optim::AlgorithmKind;
use dana::runtime::{Engine, Input};
use dana::train::{baseline, real_async, sim_trainer, ssgd};
use std::path::PathBuf;

fn artifacts() -> Option<PathBuf> {
    let dir = default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping integration test: run `make artifacts` first");
        None
    }
}

/// The pallas-kernel build and the pure-jnp build of the same architecture
/// must agree through the rust runtime end-to-end (independent lowerings of
/// the same math, executed by the same PJRT client).
#[test]
fn pallas_and_ref_artifacts_agree_through_pjrt() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::cpu(&dir).unwrap();
    let a = engine.load_model("mlp_c10").unwrap();
    let b = engine.load_model("mlp_c10_ref").unwrap();
    let params = engine.init_params("mlp_c10").unwrap();
    let v = engine.manifest().variant("mlp_c10").unwrap();
    let gx = dana::runtime::manifest::read_f32_file(&v.golden_x).unwrap();
    let gy = dana::runtime::manifest::read_i32_file(&v.golden_y).unwrap();
    let (la, ga) = a.train_step(&params, Input::F32(&gx), &gy).unwrap();
    let (lb, gb) = b.train_step(&params, Input::F32(&gx), &gy).unwrap();
    assert!((la - lb).abs() < 1e-5, "{la} vs {lb}");
    for (x, y) in ga.iter().zip(&gb) {
        assert!((x - y).abs() < 1e-4 + 1e-3 * y.abs());
    }
}

/// Same seed → identical simulated run (full determinism of the stack).
#[test]
fn sim_training_is_deterministic() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::cpu(&dir).unwrap();
    let mk = || {
        let mut cfg = TrainConfig::preset(Workload::C10, AlgorithmKind::DanaSlim, 4, 1.0);
        cfg.seed = 7;
        cfg.artifacts_dir = dir.clone();
        cfg
    };
    let a = sim_trainer::run(&mk(), &engine).unwrap();
    let b = sim_trainer::run(&mk(), &engine).unwrap();
    assert_eq!(a.final_test_error, b.final_test_error);
    assert_eq!(a.loss_curve, b.loss_curve);
    assert_eq!(a.sim_time, b.sim_time);
}

/// Different seeds → different batch order → different trajectory.
#[test]
fn seeds_change_the_run() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::cpu(&dir).unwrap();
    let mut a_cfg = TrainConfig::preset(Workload::C10, AlgorithmKind::DanaSlim, 4, 1.0);
    a_cfg.artifacts_dir = dir.clone();
    let mut b_cfg = a_cfg.clone();
    b_cfg.seed = a_cfg.seed + 1;
    let a = sim_trainer::run(&a_cfg, &engine).unwrap();
    let b = sim_trainer::run(&b_cfg, &engine).unwrap();
    assert_ne!(a.loss_curve, b.loss_curve);
}

/// All four training modes produce a learning signal on the C10 proxy.
#[test]
fn all_modes_learn() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::cpu(&dir).unwrap();
    let mut cfg = TrainConfig::preset(Workload::C10, AlgorithmKind::DanaSlim, 4, 3.0);
    cfg.artifacts_dir = dir.clone();
    let sim = sim_trainer::run(&cfg, &engine).unwrap();
    assert!(sim.final_test_error < 30.0, "sim: {}", sim.final_test_error);
    let base = baseline::run(&cfg, &engine).unwrap();
    assert!(base.final_test_error < 30.0, "baseline: {}", base.final_test_error);
    let sync = ssgd::run(&cfg, &engine).unwrap();
    assert!(sync.final_test_error < 30.0, "ssgd: {}", sync.final_test_error);
    let mut rcfg = cfg.clone();
    rcfg.epochs = 1.0; // real threads are slower; keep it short
    let real = real_async::run(&rcfg, &engine).unwrap();
    assert!(!real.diverged && real.final_test_error < 60.0, "real: {}", real.final_test_error);
}

/// The paper's headline qualitative claim, end to end: at 16 workers with
/// momentum, NAG-ASGD falls apart while DANA-Slim stays near the baseline.
#[test]
fn dana_beats_nag_asgd_at_scale() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::cpu(&dir).unwrap();
    let mk = |alg| {
        let mut cfg = TrainConfig::preset(Workload::C10, alg, 16, 8.0);
        cfg.artifacts_dir = dir.clone();
        cfg
    };
    let dana = sim_trainer::run(&mk(AlgorithmKind::DanaSlim), &engine).unwrap();
    let nag = sim_trainer::run(&mk(AlgorithmKind::NagAsgd), &engine).unwrap();
    assert!(
        dana.final_test_error + 10.0 < nag.final_test_error,
        "dana {:.2}% vs nag {:.2}%",
        dana.final_test_error,
        nag.final_test_error
    );
    assert!(dana.final_test_error < 15.0, "dana degraded: {}", dana.final_test_error);
}

/// LM workload end-to-end through the simulated trainer (the e2e driver's
/// assertion, in test form, at reduced length).
#[test]
fn lm_workload_descends() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::cpu(&dir).unwrap();
    let mut cfg = TrainConfig::preset(Workload::LmSmall, AlgorithmKind::DanaSlim, 2, 0.3);
    cfg.artifacts_dir = dir.clone();
    let rep = sim_trainer::run(&cfg, &engine).unwrap();
    assert!(!rep.diverged);
    assert!(
        rep.final_test_loss < 4.159,
        "LM did not descend below ln(64): {}",
        rep.final_test_loss
    );
}

/// The eval path agrees with the golden record for every variant.
#[test]
fn eval_goldens_all_variants() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::cpu(&dir).unwrap();
    for v in engine.manifest().variants.clone() {
        let m = engine.load_model(&v.name).unwrap();
        let params = engine.init_params(&v.name).unwrap();
        let gy = dana::runtime::manifest::read_i32_file(&v.golden_y).unwrap();
        let (loss, correct) = if v.x_dtype == "f32" {
            let gx = dana::runtime::manifest::read_f32_file(&v.golden_x).unwrap();
            m.eval_step(&params, Input::F32(&gx), &gy).unwrap()
        } else {
            let gx = dana::runtime::manifest::read_i32_file(&v.golden_x).unwrap();
            m.eval_step(&params, Input::I32(&gx), &gy).unwrap()
        };
        assert!(
            (loss as f64 - v.golden.eval_loss).abs() < 1e-4,
            "{}: {loss} vs {}",
            v.name,
            v.golden.eval_loss
        );
        assert_eq!(correct as f64, v.golden.eval_correct, "{}", v.name);
    }
}

/// Shape errors are rejected with a useful message, not a crash.
#[test]
fn runtime_rejects_bad_shapes() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::cpu(&dir).unwrap();
    let m = engine.load_model("mlp_c10_ref").unwrap();
    let params = engine.init_params("mlp_c10_ref").unwrap();
    let y = vec![0i32; 128];
    // wrong x length
    assert!(m.train_step(&params, Input::F32(&[0.0; 7]), &y).is_err());
    // wrong dtype
    assert!(m.train_step(&params, Input::I32(&[0; 128 * 128]), &y).is_err());
    // wrong param count
    assert!(m
        .train_step(&params[..10], Input::F32(&[0.0; 128 * 128]), &y)
        .is_err());
}

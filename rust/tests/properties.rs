//! Property-based tests over randomized inputs (seeded; proptest is not in
//! the offline registry, so `for_random_cases` drives a seeded generator
//! and reports the failing seed for reproduction).

use dana::optim::dana_zero::DanaZero;
use dana::optim::{make_algorithm, Algorithm, AlgorithmKind, LrSchedule, ScheduleConfig, Step};
use dana::server::{shard_bounds, ParameterServer, ShardedParameterServer};
use dana::sim::gamma::{Environment, ExecTimeModel};
use dana::sim::AsyncSchedule;
use dana::util::rng::Rng;

/// Mini property-test driver: runs `cases` seeded scenarios; panics with
/// the seed on failure so the case can be replayed.
fn for_random_cases(cases: u64, f: impl Fn(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xBEEF ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed for case seed={seed}");
            std::panic::resume_unwind(e);
        }
    }
}

fn rand_vec(rng: &mut Rng, k: usize, scale: f32) -> Vec<f32> {
    (0..k).map(|_| scale * rng.normal() as f32).collect()
}

/// Appendix A.2 invariant: the incrementally maintained v⁰ equals Σᵢ vᶦ
/// after any sequence of worker updates with any (η, γ) schedule.
#[test]
fn prop_incremental_vsum_equals_full_sum() {
    for_random_cases(25, |rng| {
        let k = 1 + rng.below(64) as usize;
        let n = 1 + rng.below(8) as usize;
        let mut d = DanaZero::new(&rand_vec(rng, k, 1.0), n);
        let updates = 20 + rng.below(100);
        for _ in 0..updates {
            let w = rng.below(n as u64) as usize;
            let s = Step {
                eta: rng.uniform_range(0.001, 0.2) as f32,
                gamma: rng.uniform_range(0.0, 0.99) as f32,
                lambda: 0.0,
            };
            let g = rand_vec(rng, k, 1.0);
            let sent = d.theta().to_vec();
            d.master_apply(w, &g, &sent, s);
            // occasional momentum correction, as the schedule would do
            if rng.uniform() < 0.1 {
                d.rescale_momentum(rng.uniform_range(0.1, 1.0) as f32);
            }
        }
        let full = d.recompute_vsum();
        for (a, b) in d.velocity_sum().iter().zip(&full) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
        }
    });
}

/// Server lag accounting: for any random interleaving, the recorded lag of
/// a push equals the number of master updates since that worker's pull.
#[test]
fn prop_lag_matches_interleaving() {
    for_random_cases(25, |rng| {
        let n = 2 + rng.below(6) as usize;
        let k = 8;
        let sched = LrSchedule::new(ScheduleConfig {
            warmup_epochs: 0.0,
            decay_epochs: vec![],
            steps_per_epoch: 100,
            n_workers: n,
            ..ScheduleConfig::default()
        });
        let mut ps = ParameterServer::new(
            make_algorithm(AlgorithmKind::Asgd, &vec![0.0; k], n),
            sched,
            n,
        );
        ps.metrics.set_every(1);
        let mut pulled_at = vec![0u64; n];
        let mut has = vec![false; n];
        let mut expected = Vec::new();
        for _ in 0..300 {
            let w = rng.below(n as u64) as usize;
            if !has[w] || rng.uniform() < 0.5 {
                ps.pull(w);
                pulled_at[w] = ps.master_step();
                has[w] = true;
            } else {
                expected.push(ps.master_step() - pulled_at[w]);
                ps.push(w, &vec![0.01; k]).unwrap();
                // worker must re-pull before next push; model that here
                ps.pull(w);
                pulled_at[w] = ps.master_step();
            }
        }
        let got: Vec<u64> = ps.metrics.rows().iter().map(|r| r.lag).collect();
        assert_eq!(got, expected);
    });
}

/// Gap is invariant to which algorithm *name* produced the same vectors:
/// it is exactly ‖θ_now − θ_sent‖/√k (metric definition check) and is
/// always non-negative and zero when nothing intervened.
#[test]
fn prop_gap_definition() {
    for_random_cases(20, |rng| {
        let n = 2;
        let k = 1 + rng.below(32) as usize;
        let sched = LrSchedule::new(ScheduleConfig {
            warmup_epochs: 0.0,
            decay_epochs: vec![],
            steps_per_epoch: 10,
            n_workers: n,
            ..ScheduleConfig::default()
        });
        let mut ps = ParameterServer::new(
            make_algorithm(AlgorithmKind::Asgd, &rand_vec(rng, k, 1.0), n),
            sched,
            n,
        );
        ps.metrics.set_every(1);
        let sent0 = ps.pull(0).to_vec();
        ps.pull(1);
        let g1 = rand_vec(rng, k, 1.0);
        ps.push(1, &g1).unwrap();
        let eta = ps.current_step().eta; // constant schedule
        ps.push(0, &rand_vec(rng, k, 1.0)).unwrap();
        let rows = ps.metrics.rows();
        // worker 0's gap = ||theta_after_w1_update - sent0|| / sqrt(k)
        let expected = eta as f64 * dana::util::stats::rmse(&g1);
        assert!((rows[1].gap - expected).abs() < 1e-5 * (1.0 + expected));
        assert_eq!(rows[0].gap, 0.0);
        let _ = sent0;
    });
}

/// The async event engine never starves a worker, keeps time monotone, and
/// (homogeneous) spreads work roughly evenly for any seed.
#[test]
fn prop_schedule_fairness_and_monotonicity() {
    for_random_cases(15, |rng| {
        let n = 2 + rng.below(8) as usize;
        let seed = rng.next_u64();
        let mut crng = Rng::new(seed);
        let model = ExecTimeModel::new(Environment::Homogeneous, n, 64, &mut crng);
        let mut s = AsyncSchedule::new(model, crng.fork(1));
        let events = s.take_n(200 * n);
        let mut counts = vec![0usize; n];
        let mut last = 0.0;
        for e in &events {
            assert!(e.time >= last);
            last = e.time;
            counts[e.worker] += 1;
        }
        for (w, &c) in counts.iter().enumerate() {
            let share = c as f64 / events.len() as f64;
            assert!(
                (share - 1.0 / n as f64).abs() < 0.5 / n as f64,
                "worker {w} share {share} (n={n}, seed={seed})"
            );
        }
    });
}

/// Gamma sampler: for any (alpha, beta) in the CVB-relevant range the
/// sample moments match theory.
#[test]
fn prop_gamma_moments() {
    for_random_cases(10, |rng| {
        let alpha = rng.uniform_range(0.5, 120.0);
        let beta = rng.uniform_range(0.05, 30.0);
        let m = 40_000;
        let mut sum = 0.0;
        let mut sum2 = 0.0;
        for _ in 0..m {
            let x = rng.gamma(alpha, beta);
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / m as f64;
        let var = sum2 / m as f64 - mean * mean;
        assert!((mean / (alpha * beta) - 1.0).abs() < 0.05, "alpha={alpha} beta={beta}");
        assert!((var / (alpha * beta * beta) - 1.0).abs() < 0.25, "alpha={alpha} beta={beta}");
    });
}

/// |a − b| ≤ abs + rel·|b| — the sharded-equivalence tolerance.  The only
/// permitted divergence is f64 reassociation across shard boundaries
/// (YellowFin's reduced tuner statistics), so the bound is tight.
fn assert_close(a: f32, b: f32, ctx: &str) {
    let tol = 1e-6 + 1e-5 * b.abs() as f64;
    assert!(
        (a as f64 - b as f64).abs() <= tol,
        "{ctx}: sharded {a} vs monolithic {b}"
    );
}

/// THE sharding contract (tentpole): for every algorithm and S ∈
/// {1, 2, 7, 16}, a sharded server driven by the same pull/push sequence
/// as a monolithic server sends the same parameters, applies the same
/// updates, and reduces the same gap/lag metrics — over gamma-model worker
/// schedules and randomized gradients, with k both above and below S (the
/// clamp path).
#[test]
fn prop_sharded_server_equals_monolithic() {
    let flat = |n: usize, steps_per_epoch: usize| {
        LrSchedule::new(ScheduleConfig {
            base_eta: 0.05,
            gamma: 0.9,
            lambda: 1.0,
            warmup_epochs: 0.0,
            // decay mid-run so momentum correction fires on both servers
            decay_epochs: vec![2.0],
            decay_factor: 0.5,
            steps_per_epoch,
            n_workers: n,
            ..ScheduleConfig::default()
        })
    };
    for kind in AlgorithmKind::ALL {
        for &shards in &[1usize, 2, 7, 16] {
            for_random_cases(2, |rng| {
                let k = 3 + rng.below(45) as usize; // spans k < S and k >= S
                let n = 1 + rng.below(4) as usize;
                let theta0 = rand_vec(rng, k, 1.0);
                let mut mono =
                    ParameterServer::new(make_algorithm(kind, &theta0, n), flat(n, 20), n);
                let mut shrd =
                    ShardedParameterServer::new(kind, &theta0, flat(n, 20), n, shards)
                        .with_threads(1 + rng.below(4) as usize);
                mono.metrics.set_every(3);
                shrd.metrics.set_every(3);

                // Drive both servers with one gamma-model worker ordering.
                let model =
                    ExecTimeModel::new(Environment::Homogeneous, n, 32, &mut Rng::new(7));
                let mut sched = AsyncSchedule::new(model, rng.fork(2));
                let mut has_pulled = vec![false; n];
                let order: Vec<usize> =
                    Iterator::take(&mut sched, 80).map(|c| c.worker).collect();
                for (step, &w) in order.iter().enumerate() {
                    if !has_pulled[w] || rng.uniform() < 0.3 {
                        let a = shrd.pull(w);
                        let b = mono.pull(w).to_vec();
                        for i in 0..k {
                            assert_close(
                                a[i],
                                b[i],
                                &format!("{kind} S={shards} step {step} send[{i}]"),
                            );
                        }
                        has_pulled[w] = true;
                    } else {
                        let g = rand_vec(rng, k, 0.5);
                        shrd.push(w, &g).unwrap();
                        mono.push(w, &g).unwrap();
                        assert_eq!(shrd.master_step(), mono.master_step());
                    }
                }
                let (a, b) = (shrd.theta_vec(), mono.theta().to_vec());
                for i in 0..k {
                    assert_close(a[i], b[i], &format!("{kind} S={shards} theta[{i}]"));
                }
                // Metric reduction: same rows, same lag, same gap (within
                // reassociation tolerance).
                let (ra, rb) = (shrd.metrics.rows(), mono.metrics.rows());
                assert_eq!(ra.len(), rb.len(), "{kind} S={shards}: metric row count");
                for (x, y) in ra.iter().zip(rb) {
                    assert_eq!(x.step, y.step);
                    assert_eq!(x.worker, y.worker);
                    assert_eq!(x.lag, y.lag);
                    assert!(
                        (x.gap - y.gap).abs() <= 1e-9 + 1e-5 * y.gap.abs(),
                        "{kind} S={shards} step {}: gap {} vs {}",
                        x.step,
                        x.gap,
                        y.gap
                    );
                    assert!(
                        (x.msg_norm - y.msg_norm).abs() <= 1e-9 + 1e-5 * y.msg_norm.abs()
                    );
                }
            });
        }
    }
}

/// shard_bounds is a partition: contiguous, complete, near-equal, and
/// stable under any (k, S) including degenerate ones.
#[test]
fn prop_shard_bounds_partition() {
    for_random_cases(40, |rng| {
        let k = rng.below(2000) as usize;
        let s = 1 + rng.below(64) as usize;
        let b = shard_bounds(k, s);
        assert_eq!(b[0].start, 0);
        assert_eq!(b.last().unwrap().end, k);
        let mut total = 0;
        for w in b.windows(2) {
            assert_eq!(w[0].end, w[1].start);
        }
        for r in &b {
            total += r.len();
            if k > 0 {
                assert!(!r.is_empty(), "k={k} s={s}: empty shard");
            }
        }
        assert_eq!(total, k);
        let lens: Vec<usize> = b.iter().map(|r| r.len()).collect();
        assert!(lens.iter().max().unwrap() - lens.iter().min().unwrap() <= 1);
    });
}

/// Every algorithm keeps finite state under bounded random gradients with
/// a sane schedule (no NaN poisoning from any code path).
#[test]
fn prop_all_algorithms_stay_finite_on_bounded_streams() {
    for_random_cases(10, |rng| {
        let k = 16;
        let n = 1 + rng.below(6) as usize;
        for kind in AlgorithmKind::ALL {
            let sched = LrSchedule::new(ScheduleConfig {
                base_eta: 0.01,
                gamma: 0.9,
                warmup_epochs: 0.0,
                decay_epochs: vec![1.0],
                steps_per_epoch: 50,
                n_workers: n,
                ..ScheduleConfig::default()
            });
            let mut ps = ParameterServer::new(
                make_algorithm(kind, &rand_vec(rng, k, 0.5), n),
                sched,
                n,
            );
            let mut ws: Vec<_> = (0..n).map(|_| ps.algorithm().make_worker_state()).collect();
            for w in 0..n {
                ps.pull(w);
            }
            for _ in 0..150 {
                let w = rng.below(n as u64) as usize;
                let mut msg = rand_vec(rng, k, 0.3);
                let s = ps.current_step();
                ps.algorithm().worker_message(&mut ws[w], &mut msg, s);
                ps.push(w, &msg).unwrap();
                ps.pull(w);
            }
            assert!(
                ps.theta().iter().all(|x| x.is_finite()),
                "{} produced non-finite state",
                kind.name()
            );
        }
    });
}

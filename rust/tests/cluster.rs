//! Shard-group placement obligations (ISSUE 8 acceptance):
//!
//! 1. Placement resolution fails closed: a gap or an overlap in the
//!    advertised shard ranges refuses to produce a `ClusterMaster`;
//!    duplicate claims of one range resolve to the higher epoch.
//! 2. Checkpoint slicing is layout-independent: a 1-server snapshot cut
//!    into per-range snapshots and stitched back is the original
//!    **bit-for-bit**, and each slice restores into a range-sized
//!    backend that re-snapshots to the same bits — for all 10 rules.
//! 3. A 2-server split behind real sockets reproduces the single-server
//!    trajectory bit-for-bit for all 10 rules (`--encoding none`),
//!    including YellowFin's whole-vector reductions (two-phase
//!    stage/commit) and an asymmetric multi-shard split.
//! 4. Hot-standby takeover: killing a primary mid-run under pipelined
//!    D=1 push load promotes the standby (one epoch up), training
//!    completes, no acked push is lost or double-applied, and the
//!    v⁰ = Σ live vᶦ invariant holds on every surviving range.
//! 5. Pre-takeover the standby serves read-only θ from the newest
//!    restored archive, stamped `standby = 1`, while still refusing
//!    worker joins (read-only never means joinable).

use dana::cluster::{coord_range, slice_snapshot, stitch_snapshots, ClusterMaster};
use dana::cluster::{StandbyConfig, StandbyServer};
use dana::config::{TrainConfig, Workload};
use dana::net::{checkpoint, retention};
use dana::net::{Encoding, NetServer, Placement, RemoteMaster, RetentionPolicy, ServeOptions};
use dana::optim::{AlgorithmKind, LrSchedule, StateVec};
use dana::server::{make_master, Master, MasterSnapshot};
use dana::train::{real_async, sim_trainer};
use dana::util::rng::Rng;
use std::path::PathBuf;
use std::time::Duration;

fn cfg(kind: AlgorithmKind, workers: usize, epochs: f64) -> TrainConfig {
    let mut c = TrainConfig::preset(Workload::C10, kind, workers, epochs);
    c.seed = 31;
    c.metrics_every = 0;
    c
}

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("dana-cluster-{name}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// A `dana serve --shard-range a..b` master for this config: the
/// identically-seeded full θ₀ sliced to the hosted coordinates, one
/// local backend shard per hosted global shard.
fn range_master(c: &TrainConfig, k: usize, total: u32, a: u32, b: u32) -> Box<dyn Master> {
    let theta0 = real_async::synthetic_theta0(k);
    let coords = coord_range(k, total, &(a..b)).unwrap();
    make_master(
        c.algorithm,
        &theta0[coords],
        LrSchedule::new(c.schedule.clone()),
        0,
        (b - a) as usize,
        2,
    )
}

fn start_range_server(
    c: &TrainConfig,
    k: usize,
    total: u32,
    a: u32,
    b: u32,
    mut opts: ServeOptions,
) -> NetServer {
    opts.placement = Placement {
        shard_start: a,
        total_shards: total,
        epoch: opts.placement.epoch,
        takeovers: 0,
    };
    NetServer::start(range_master(c, k, total, a, b), "127.0.0.1:0", opts).unwrap()
}

// ---------------------------------------------------------------- (1)

/// A hole in the tiling (shard 1 unhosted) refuses to resolve, with a
/// diagnosis naming the gap; an overlap likewise.
#[test]
fn placement_with_gap_or_overlap_fails_closed() {
    let k = 24;
    let c = cfg(AlgorithmKind::DanaZero, 2, 0.5);
    // gap: 0..1 and 2..4 of a 4-shard placement
    let mut s1 = start_range_server(&c, k, 4, 0, 1, ServeOptions::default());
    let mut s2 = start_range_server(&c, k, 4, 2, 4, ServeOptions::default());
    let urls = vec![s1.url(), s2.url()];
    let err = ClusterMaster::connect(&urls, 2, None, Encoding::None, false)
        .err()
        .expect("a placement with a hole must not resolve");
    assert!(format!("{err:#}").contains("gap"), "undiagnosed: {err:#}");
    s1.stop();
    s2.stop();

    // overlap: 0..3 and 2..4
    let mut s1 = start_range_server(&c, k, 4, 0, 3, ServeOptions::default());
    let mut s2 = start_range_server(&c, k, 4, 2, 4, ServeOptions::default());
    let urls = vec![s1.url(), s2.url()];
    let err = ClusterMaster::connect(&urls, 2, None, Encoding::None, false)
        .err()
        .expect("overlapping ranges must not resolve");
    assert!(format!("{err:#}").contains("overlap"), "undiagnosed: {err:#}");
    s1.stop();
    s2.stop();
}

/// Two servers claiming the same range resolve to the higher placement
/// epoch — the client sides with the newest incarnation, never both.
#[test]
fn duplicate_range_resolves_to_highest_epoch() {
    let k = 16;
    let c = cfg(AlgorithmKind::Asgd, 1, 0.5);
    let old = ServeOptions {
        placement: Placement { epoch: 0, ..Default::default() },
        ..Default::default()
    };
    let new = ServeOptions {
        placement: Placement { epoch: 3, ..Default::default() },
        ..Default::default()
    };
    let mut stale = start_range_server(&c, k, 2, 0, 2, old);
    let mut fresh = start_range_server(&c, k, 2, 0, 2, new);
    let urls = vec![stale.url(), fresh.url()];
    let cm = ClusterMaster::connect(&urls, 1, None, Encoding::None, false).unwrap();
    assert_eq!(cm.group_count(), 1, "duplicate claims must dedup to one group");
    // the chosen group is the epoch-3 server: pushing advances it, not the stale one
    let mut cm = cm;
    cm.pull_params(0);
    cm.push_update(0, &vec![0.1; k]).unwrap();
    assert_eq!(cm.steps_done(), 1);
    let rows = cm.placement_groups();
    assert_eq!(rows.len(), 1);
    assert!(
        rows[0].0.contains(&fresh.addr().port().to_string()),
        "resolved to {} but the epoch-3 server is {}",
        rows[0].0,
        fresh.addr()
    );
    stale.stop();
    fresh.stop();
}

// ---------------------------------------------------------------- (2)

/// pull → noisy grad → push, round-robin over 2 workers.
fn drive(m: &mut dyn Master, curv: &[f32], rng: &mut Rng, steps: usize) {
    let k = curv.len();
    let mut buf = vec![0.0f32; k];
    let mut g = vec![0.0f32; k];
    for step in 0..steps {
        let w = step % 2;
        m.pull_into(w, &mut buf);
        real_async::synthetic_grad(&buf, curv, rng, &mut g);
        m.push_update(w, &g).unwrap();
    }
}

/// slice → stitch is the identity, and slice → restore → snapshot is
/// the identity per range, for every update rule.
#[test]
fn snapshot_slice_stitch_roundtrip_all_kinds_bit_for_bit() {
    let k = 48;
    let curv = real_async::synthetic_curvature(k);
    for kind in AlgorithmKind::ALL {
        let c = cfg(kind, 2, 0.5);
        let mut full = make_master(
            kind,
            &real_async::synthetic_theta0(k),
            LrSchedule::new(c.schedule.clone()),
            0,
            1,
            2,
        );
        assert_eq!(full.add_worker(), 0);
        assert_eq!(full.add_worker(), 1);
        let mut rng = Rng::new(7);
        drive(&mut *full, &curv, &mut rng, 30);
        let snap = full.snapshot().unwrap();

        // 1-server → 3-server split (uneven: 48 coords over 3 shards)
        let total = 3u32;
        let mut parts = Vec::new();
        for a in 0..total {
            let coords = coord_range(k, total, &(a..a + 1)).unwrap();
            let part = slice_snapshot(&snap, &coords).unwrap();
            // each slice restores into a range-sized backend and
            // re-snapshots to the same bits
            let mut rm = make_master(
                kind,
                &real_async::synthetic_theta0(k)[coords],
                LrSchedule::new(c.schedule.clone()),
                0,
                1,
                2,
            );
            rm.restore(&part).unwrap();
            assert_eq!(rm.steps_done(), 30, "{kind}: restored step count");
            assert_eq!(
                rm.snapshot().unwrap(),
                part,
                "{kind}: range {a} snapshot drifted through restore"
            );
            parts.push(part);
        }
        // …and back: the stitch is the original, bit-for-bit
        let stitched = stitch_snapshots(&parts).unwrap();
        assert_eq!(stitched, snap, "{kind}: slice→stitch is not the identity");
    }
}

/// Stitching refuses ranges that did not apply the same push sequence.
#[test]
fn stitch_rejects_skewed_ranges() {
    let k = 16;
    let c = cfg(AlgorithmKind::DanaZero, 2, 0.5);
    let curv = real_async::synthetic_curvature(k);
    let mut m = make_master(
        AlgorithmKind::DanaZero,
        &real_async::synthetic_theta0(k),
        LrSchedule::new(c.schedule.clone()),
        0,
        1,
        2,
    );
    m.add_worker();
    m.add_worker();
    let mut rng = Rng::new(9);
    drive(&mut *m, &curv, &mut rng, 10);
    let snap = m.snapshot().unwrap();
    let a = slice_snapshot(&snap, &coord_range(k, 2, &(0..1)).unwrap()).unwrap();
    let mut b = slice_snapshot(&snap, &coord_range(k, 2, &(1..2)).unwrap()).unwrap();
    b.master_step += 1;
    let err = stitch_snapshots(&[a, b]).err().expect("skewed stitch must fail");
    assert!(format!("{err:#}").contains("master step"), "undiagnosed: {err:#}");
}

// ---------------------------------------------------------------- (3)

/// A 2-server split (`--encoding none`) behind real sockets ≡ the
/// single-server trajectory, bit-for-bit, all 10 rules.  YellowFin
/// exercises the two-phase stage/commit push.
#[test]
fn two_server_split_matches_single_server_bit_for_bit_all_kinds() {
    let k = 48;
    for kind in AlgorithmKind::ALL {
        let c = cfg(kind, 3, 0.6);
        let base = sim_trainer::run_synthetic(&c, k).unwrap();
        let mut s1 = start_range_server(&c, k, 2, 0, 1, ServeOptions::default());
        let mut s2 = start_range_server(&c, k, 2, 1, 2, ServeOptions::default());
        let mut rc = c.clone();
        rc.master_addr = Some(format!("{},{}", s1.url(), s2.url()));
        let split = sim_trainer::run_synthetic(&rc, k).unwrap();
        assert_eq!(
            split.final_test_loss, base.final_test_loss,
            "{kind}: final loss diverged across the 2-server split"
        );
        assert_eq!(split.loss_curve, base.loss_curve, "{kind}: loss curve");
        assert_eq!(split.steps, base.steps, "{kind}");
        s1.stop();
        s2.stop();
    }
}

/// An asymmetric split (1 + 3 shards of a 4-shard placement) is still
/// exact — placement boundaries are invisible to the math.
#[test]
fn asymmetric_split_matches_single_server() {
    let k = 48;
    let c = cfg(AlgorithmKind::DanaDc, 3, 0.5);
    let base = sim_trainer::run_synthetic(&c, k).unwrap();
    let mut s1 = start_range_server(&c, k, 4, 0, 1, ServeOptions::default());
    let mut s2 = start_range_server(&c, k, 4, 1, 4, ServeOptions::default());
    let mut rc = c.clone();
    rc.master_addr = Some(format!("{},{}", s1.url(), s2.url()));
    let split = sim_trainer::run_synthetic(&rc, k).unwrap();
    assert_eq!(split.final_test_loss, base.final_test_loss);
    assert_eq!(split.loss_curve, base.loss_curve);
    s1.stop();
    s2.stop();
}

/// A wire shard id outside the hosted range is rejected recoverably
/// (the connection survives), not by indexing out of bounds.
#[test]
fn out_of_range_shard_is_rejected_recoverably() {
    use dana::net::wire::{read_frame, write_frame, Msg, Role};
    use std::io::{BufReader, BufWriter};
    use std::net::TcpStream;

    let k = 24;
    let c = cfg(AlgorithmKind::Asgd, 1, 0.5);
    // hosts global shards 1..2 of 2 — global shard 0 is someone else's
    let mut srv = start_range_server(&c, k, 2, 1, 2, ServeOptions::default());
    let s = TcpStream::connect(srv.addr()).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let mut w = BufWriter::new(s);
    let mut req = |m: &Msg| -> Msg {
        write_frame(&mut w, m).unwrap();
        read_frame(&mut r).unwrap()
    };
    let hello =
        req(&Msg::Hello { role: Role::Worker, reattach: false, encoding: Encoding::None });
    let (shards, header) = match hello {
        Msg::HelloAck { shards, header, .. } => (shards, header),
        other => panic!("handshake failed: {other:?}"),
    };
    // the handshake advertises the hosted range, not the whole space
    assert_eq!((header.shard_start, header.shard_hosted, header.total_shards), (1, 1, 2));
    assert_eq!(shards, 1);
    match req(&Msg::PullShard { shard: 0 }) {
        Msg::Error { recoverable, detail } => {
            assert!(recoverable, "foreign shard must be refused recoverably: {detail}");
            assert!(detail.contains("hosted range"), "undiagnosed: {detail}");
        }
        other => panic!("foreign shard was served: {other:?}"),
    }
    // the connection survived: the hosted shard still serves, echoing
    // its global id
    match req(&Msg::PullShard { shard: 1 }) {
        Msg::ShardParams { shard, params, .. } => {
            assert_eq!(shard, 1);
            assert_eq!(params.len(), coord_range(k, 2, &(1..2)).unwrap().len());
        }
        other => panic!("hosted shard refused: {other:?}"),
    }
    srv.stop();
}

// ---------------------------------------------------------------- (4)

fn dana_invariant(snap: &MasterSnapshot) {
    let v = match &snap.state.iter().find(|(n, _)| n == "v").expect("v entry").1 {
        StateVec::PerWorker(vs) => vs,
        other => panic!("v has wrong shape: {other:?}"),
    };
    let vsum = match &snap.state.iter().find(|(n, _)| n == "vsum").expect("vsum entry").1 {
        StateVec::Coord(s) => s,
        other => panic!("vsum has wrong shape: {other:?}"),
    };
    for j in 0..vsum.len() {
        let full: f32 = v.iter().map(|vi| vi[j]).sum();
        assert!(
            (vsum[j] - full).abs() < 2e-3 * (1.0 + full.abs()),
            "v0 invariant broken at coord {j}: {} vs {full}",
            vsum[j]
        );
    }
}

fn newest_archive(base: &std::path::Path) -> MasterSnapshot {
    let archives = retention::list_archives(base).unwrap();
    let newest = archives.iter().max_by_key(|a| a.step).expect("no archives written");
    checkpoint::read_snapshot(&newest.path).unwrap()
}

/// Kill a primary under pipelined D=1 push load: the hot standby takes
/// its exact range over one epoch up, the run completes, every acked
/// push is applied exactly once (archive-before-ack at cadence 1), and
/// v⁰ = Σ live vᶦ holds on both surviving ranges.
#[test]
fn standby_takeover_preserves_every_acked_push() {
    let k = 32;
    let c = cfg(AlgorithmKind::DanaZero, 2, 1.0);
    let d1 = tmpdir("takeover-r0");
    let d2 = tmpdir("takeover-r1");
    let archived = |dir: &PathBuf| ServeOptions {
        checkpoint_path: Some(dir.join("server.ckpt")),
        checkpoint_every: 1,
        retention: RetentionPolicy { keep_last: 64, keep_hourly: 0 },
        pipeline_depth: 1,
        ..Default::default()
    };
    let mut s1 = start_range_server(&c, k, 2, 0, 1, archived(&d1));
    let mut s2 = start_range_server(&c, k, 2, 1, 2, archived(&d2));

    // hot standby for s1, sharing its archive directory
    let mut sb = StandbyServer::start(StandbyConfig {
        listen: "127.0.0.1:0".into(),
        primary: s1.url(),
        archive_base: d1.join("server.ckpt"),
        schedule: LrSchedule::new(c.schedule.clone()),
        threads: 2,
        striped: false,
        opts: archived(&d1),
        poll: Duration::from_millis(50),
        miss_budget: 3,
    })
    .unwrap();

    // the endpoint list includes the standby — resolution skips it,
    // fail-over probes it
    let urls = vec![s1.url(), s2.url(), sb.url()];
    let mut cm =
        ClusterMaster::connect(&urls, 2, Some((c.algorithm, k)), Encoding::None, false).unwrap();
    cm.failover_attempts = 100;
    cm.failover_delay = Duration::from_millis(100);
    cm.set_pipeline_depth(1);

    let curv = real_async::synthetic_curvature(k);
    let mut rng = Rng::new(77);
    drive(&mut cm, &curv, &mut rng, 20);

    // hard-kill the range-0 primary mid-load and wait for the takeover
    s1.stop();
    let t0 = std::time::Instant::now();
    while sb.takeovers() == 0 {
        assert!(t0.elapsed() < Duration::from_secs(20), "standby never took over");
        std::thread::sleep(Duration::from_millis(25));
    }
    assert_eq!(sb.takeovers(), 1);

    // training continues through the fail-over (pulls re-resolve; the
    // in-flight push is counted lost, never retried)
    drive(&mut cm, &curv, &mut rng, 20);
    cm.drain_inflight().unwrap();
    let lost = cm.pushes_lost();
    let rows = cm.placement_groups();
    assert_eq!(rows.len(), 2);

    // exactly-once accounting, per range: every push the client saw
    // acked is applied (archive-before-ack ⇒ the newest archive has
    // it), nothing is applied twice (lost pushes are never retried) —
    // so each range's step count is the 40 attempts minus at most the
    // pushes lost cluster-wide.
    let final0 = newest_archive(&d1.join("server.ckpt"));
    let final1 = newest_archive(&d2.join("server.ckpt"));
    for (name, snap) in [("range 0 (taken over)", &final0), ("range 1", &final1)] {
        assert!(
            snap.master_step <= 40,
            "{name}: {} steps from 40 pushes — a push was double-applied",
            snap.master_step
        );
        assert!(
            snap.master_step + lost >= 40,
            "{name}: {} steps + {lost} lost < 40 pushes — an acked push vanished",
            snap.master_step,
        );
        dana_invariant(snap);
    }
    // the promoted range serves one epoch up, and a fresh resolve of
    // the same endpoint list lands on it without seeing s1 at all
    let cm2 = ClusterMaster::connect(&urls, 0, Some((c.algorithm, k)), Encoding::None, false)
        .unwrap();
    assert_eq!(cm2.group_count(), 2);
    drop(cm2);
    drop(cm);
    s2.stop();
    sb.stop();
}

/// The standby answers placement probes while waiting (standby flag
/// set, no worker traffic) — clients must not mistake it for a primary.
#[test]
fn standby_refuses_worker_traffic_before_takeover() {
    let k = 16;
    let c = cfg(AlgorithmKind::Asgd, 1, 0.5);
    let dir = tmpdir("standby-idle");
    let opts = ServeOptions {
        checkpoint_path: Some(dir.join("server.ckpt")),
        checkpoint_every: 1,
        retention: RetentionPolicy { keep_last: 8, keep_hourly: 0 },
        ..Default::default()
    };
    let mut s1 = start_range_server(&c, k, 1, 0, 1, opts.clone());
    let mut sb = StandbyServer::start(StandbyConfig {
        listen: "127.0.0.1:0".into(),
        primary: s1.url(),
        archive_base: dir.join("server.ckpt"),
        schedule: LrSchedule::new(c.schedule.clone()),
        threads: 2,
        striped: false,
        opts,
        poll: Duration::from_millis(50),
        miss_budget: 1000, // never promote during this test
    })
    .unwrap();
    // give the standby one probe so it has a view to advertise
    std::thread::sleep(Duration::from_millis(300));
    assert!(
        RemoteMaster::connect(&sb.url(), 1).is_err(),
        "a standby must not accept worker joins before takeover"
    );
    // a placement resolve over {primary, standby} sees exactly one group
    let urls = vec![s1.url(), sb.url()];
    let cm = ClusterMaster::connect(&urls, 0, None, Encoding::None, false).unwrap();
    assert_eq!(cm.group_count(), 1);
    drop(cm);
    sb.stop();
    s1.stop();
}

/// Pre-takeover, the standby answers read-only `PullParams`/`GetTheta`
/// from the newest restored archive, stamped `standby = 1` — a
/// dashboard or a prefetching worker can read θ off the warm spare
/// without the standby ever accepting a push.
#[test]
fn standby_serves_read_only_theta_before_takeover() {
    use dana::net::wire::{read_frame, write_frame, Msg, Role};
    use std::io::{BufReader, BufWriter};
    use std::net::TcpStream;

    let k = 16;
    let c = cfg(AlgorithmKind::Asgd, 2, 0.5);
    let dir = tmpdir("standby-read");
    let opts = ServeOptions {
        checkpoint_path: Some(dir.join("server.ckpt")),
        checkpoint_every: 1,
        retention: RetentionPolicy { keep_last: 8, keep_hourly: 0 },
        ..Default::default()
    };
    let mut s1 = start_range_server(&c, k, 1, 0, 1, opts.clone());
    let mut sb = StandbyServer::start(StandbyConfig {
        listen: "127.0.0.1:0".into(),
        primary: s1.url(),
        archive_base: dir.join("server.ckpt"),
        schedule: LrSchedule::new(c.schedule.clone()),
        threads: 2,
        striped: false,
        opts,
        poll: Duration::from_millis(25),
        miss_budget: 1000, // never promote during this test
    })
    .unwrap();

    // advance the primary so there are archives to tail
    let curv = real_async::synthetic_curvature(k);
    let mut rng = Rng::new(5);
    let mut rm = RemoteMaster::connect(&s1.url(), 2).unwrap();
    drive(&mut rm, &curv, &mut rng, 6);
    let want = newest_archive(&dir.join("server.ckpt"));
    assert_eq!(want.master_step, 6);

    // raw-wire client against the standby: no handshake needed for the
    // read-only path, and the reply must carry the newest archive's θ
    let s = TcpStream::connect(sb.addr()).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let mut w = BufWriter::new(s);
    let mut req = |m: &Msg| -> Msg {
        write_frame(&mut w, m).unwrap();
        read_frame(&mut r).unwrap()
    };
    let t0 = std::time::Instant::now();
    let header = loop {
        assert!(
            t0.elapsed() < Duration::from_secs(20),
            "standby never restored the newest archive"
        );
        match req(&Msg::PullParams) {
            Msg::Params { header, params } if params == want.theta => break header,
            // an older archive or none yet: the tail catches up
            Msg::Params { .. } => {}
            Msg::Error { recoverable, detail } => {
                assert!(recoverable, "must stay recoverable while waiting: {detail}");
            }
            other => panic!("unexpected reply: {other:?}"),
        }
        std::thread::sleep(Duration::from_millis(25));
    };
    assert_eq!(header.standby, 1, "read-only θ must be stamped standby = 1");
    assert_eq!(header.master_step, 6, "the header carries the archive's step");
    // GetTheta serves the same bits with the same stamp
    match req(&Msg::GetTheta) {
        Msg::Theta { header, theta } => {
            assert_eq!(header.standby, 1);
            assert_eq!(theta, want.theta);
        }
        other => panic!("GetTheta refused: {other:?}"),
    }
    // ...and worker traffic is still refused: read-only never means joinable
    match req(&Msg::Hello { role: Role::Worker, reattach: false, encoding: Encoding::None }) {
        Msg::Error { recoverable, detail } => {
            assert!(recoverable && detail.contains("no takeover"), "got: {detail}");
        }
        other => panic!("a standby accepted a worker: {other:?}"),
    }
    drop(rm);
    sb.stop();
    s1.stop();
}

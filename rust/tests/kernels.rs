//! Kernel-dispatch equivalence obligations (PR 10 acceptance):
//!
//! 1. Every SIMD backend reproduces the scalar reference **bit-for-bit**
//!    for every elementwise kernel, on adversarial inputs — NaN payloads
//!    (quiet and signaling), signed zeros, infinities, subnormals, and
//!    values straddling the f16 overflow/underflow ladders — at every
//!    remainder length `0..=3 * lane_width`.
//! 2. The f16/bf16 wire codecs are bit-identical across backends, with
//!    the decode direction checked exhaustively over all 2^16 half bits.
//! 3. The reductions (`dot`, `norm2_sq`, `sub_norm_sq`) return identical
//!    f64 bits under every backend and from any execution context (the
//!    fixed 8-lane strided shape of DESIGN.md §15), so `DANA_THREADS`
//!    and `--kernels` never change a gap/lag measurement.
//! 4. The persistent [`WorkerPool`] fans out over exactly the chunk
//!    boundaries of the scoped `par_chunks_mut` reference, so pooled
//!    applies are bit-identical to the spawn-per-call baseline.
//! 5. Full stack: a loopback train run under `--kernels scalar` equals
//!    the auto-dispatched run bit-for-bit (DANA-Zero and YellowFin — the
//!    latter exercises the reduction paths end-to-end).

use dana::config::{TrainConfig, Workload};
use dana::math::{self, scalar, KernelBackend};
use dana::net::{NetServer, ServeOptions};
use dana::optim::{AlgorithmKind, LrSchedule};
use dana::server::{make_master, Master};
use dana::train::{real_async, sim_trainer};
use dana::util::parallel::{self, WorkerPool};
use dana::util::rng::Rng;

/// Widest f32 lane count of any backend (AVX2); remainder sweeps cover
/// `0..=3 * MAX_LANES` so every `main`/tail split shape is exercised.
const MAX_LANES: usize = 8;

/// Adversarial f32 bit patterns: zeros of both signs, infinities, NaNs
/// with distinct payloads (one signaling), the subnormal extremes, the
/// f32 extremes, and values that sit exactly on the f16 conversion
/// ladder's branch points.
const WEIRD: &[u32] = &[
    0x0000_0000, // +0
    0x8000_0000, // -0
    0x7f80_0000, // +inf
    0xff80_0000, // -inf
    0x7fc0_0000, // canonical quiet NaN
    0x7fc0_0001, // quiet NaN, payload 1
    0xffc1_2345, // negative quiet NaN, fat payload
    0x7f80_0001, // signaling NaN
    0x0000_0001, // smallest subnormal
    0x007f_ffff, // largest subnormal
    0x0080_0000, // smallest normal
    0x7f7f_ffff, // f32::MAX
    0xff7f_ffff, // f32::MIN
    0x3f80_0000, // 1.0
    0xbf80_0000, // -1.0
    0x477f_e000, // 65504.0 = f16::MAX
    0x477f_f000, // rounds to +inf in f16
    0x3880_0000, // 2^-14 = smallest f16 normal
    0x387f_c000, // inside the f16 subnormal ladder
    0x3300_0000, // 2^-25: the f16 round-to-zero boundary
    0x3eaa_aaab, // 1/3 (inexact everywhere)
    0xc2c8_0000, // -100.0
];

/// Every third element a weird pattern, the rest small pseudo-random
/// normals — outputs mix exceptional and ordinary lanes in one vector.
fn fill(n: usize, salt: u64) -> Vec<f32> {
    let mut rng = Rng::new(0x5eed ^ salt);
    (0..n)
        .map(|i| {
            if i % 3 == 0 {
                f32::from_bits(WEIRD[(i / 3 + salt as usize) % WEIRD.len()])
            } else {
                rng.uniform_range(-2.0, 2.0) as f32
            }
        })
        .collect()
}

/// Like [`fill`] but finite-only (for reference trajectories that must
/// not collapse to all-NaN before the comparison happens).
fn fill_finite(n: usize, salt: u64) -> Vec<f32> {
    fill(n, salt)
        .into_iter()
        .map(|x| if x.is_finite() { x } else { 0.25 })
        .collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The non-scalar backends this host can run (empty on exotic arches —
/// the suite then still pins scalar self-consistency and the pool).
fn simd_backends() -> Vec<KernelBackend> {
    math::available_backends()
        .into_iter()
        .filter(|&b| b != KernelBackend::Scalar)
        .collect()
}

fn lengths() -> Vec<usize> {
    (0..=3 * MAX_LANES).chain([57, 251, 1003]).collect()
}

// ---------------------------------------------------------------- (1)

#[test]
fn elementwise_kernels_match_scalar_bit_for_bit_on_weird_inputs() {
    let (gamma, eta, lambda) = (0.9f32, 0.05f32, 1.5f32);
    for b in simd_backends() {
        for n in lengths() {
            let salt = n as u64;
            let g = fill(n, salt);
            let sent = fill(n, salt + 7);

            // axpy (also covers apply_update = axpy(theta, -eta, u))
            let mut want = fill(n, salt + 1);
            let mut got = want.clone();
            scalar::axpy(&mut want, -eta, &g);
            math::with_backend(b, || math::axpy(&mut got, -eta, &g));
            assert_eq!(bits(&want), bits(&got), "{b}: axpy n={n}");

            // momentum_step
            let (mut t_w, mut v_w) = (fill(n, salt + 2), fill(n, salt + 3));
            let (mut t_g, mut v_g) = (t_w.clone(), v_w.clone());
            scalar::momentum_step(&mut t_w, &mut v_w, &g, gamma, eta);
            math::with_backend(b, || math::momentum_step(&mut t_g, &mut v_g, &g, gamma, eta));
            assert_eq!(bits(&t_w), bits(&t_g), "{b}: momentum_step theta n={n}");
            assert_eq!(bits(&v_w), bits(&v_g), "{b}: momentum_step v n={n}");

            // dana_fused_update
            let (mut t_w, mut v_w, mut s_w) =
                (fill(n, salt + 2), fill(n, salt + 3), fill(n, salt + 4));
            let (mut t_g, mut v_g, mut s_g) = (t_w.clone(), v_w.clone(), s_w.clone());
            scalar::dana_fused_update(&mut t_w, &mut v_w, &mut s_w, &g, gamma, eta);
            math::with_backend(b, || {
                math::dana_fused_update(&mut t_g, &mut v_g, &mut s_g, &g, gamma, eta)
            });
            assert_eq!(bits(&t_w), bits(&t_g), "{b}: dana_fused theta n={n}");
            assert_eq!(bits(&v_w), bits(&v_g), "{b}: dana_fused v n={n}");
            assert_eq!(bits(&s_w), bits(&s_g), "{b}: dana_fused vsum n={n}");

            // dc_dana_fused_update
            let (mut t_w, mut v_w, mut s_w) =
                (fill(n, salt + 2), fill(n, salt + 3), fill(n, salt + 4));
            let (mut t_g, mut v_g, mut s_g) = (t_w.clone(), v_w.clone(), s_w.clone());
            scalar::dc_dana_fused_update(
                &mut t_w, &mut v_w, &mut s_w, &g, &sent, gamma, eta, lambda,
            );
            math::with_backend(b, || {
                math::dc_dana_fused_update(
                    &mut t_g, &mut v_g, &mut s_g, &g, &sent, gamma, eta, lambda,
                )
            });
            assert_eq!(bits(&t_w), bits(&t_g), "{b}: dc_dana theta n={n}");
            assert_eq!(bits(&v_w), bits(&v_g), "{b}: dc_dana v n={n}");
            assert_eq!(bits(&s_w), bits(&s_g), "{b}: dc_dana vsum n={n}");

            // lookahead + the extrapolated variant at several depths
            let theta = fill(n, salt + 5);
            let vsum = fill(n, salt + 6);
            let mut want = vec![0.0f32; n];
            let mut got = vec![0.0f32; n];
            scalar::lookahead(&mut want, &theta, &vsum, gamma, eta);
            math::with_backend(b, || math::lookahead(&mut got, &theta, &vsum, gamma, eta));
            assert_eq!(bits(&want), bits(&got), "{b}: lookahead n={n}");
            for depth in [0usize, 1, 3] {
                scalar::lookahead_extrapolated(&mut want, &theta, &vsum, gamma, eta, depth);
                math::with_backend(b, || {
                    math::lookahead_extrapolated(&mut got, &theta, &vsum, gamma, eta, depth)
                });
                assert_eq!(bits(&want), bits(&got), "{b}: extrapolated d={depth} n={n}");
            }

            // dc_adjust
            let mut g_w = g.clone();
            let mut g_g = g.clone();
            scalar::dc_adjust(&mut g_w, &theta, &sent, lambda);
            math::with_backend(b, || math::dc_adjust(&mut g_g, &theta, &sent, lambda));
            assert_eq!(bits(&g_w), bits(&g_g), "{b}: dc_adjust n={n}");

            // slim_worker_update_inplace
            let (mut v_w, mut g_w) = (fill(n, salt + 3), g.clone());
            let (mut v_g, mut g_g) = (v_w.clone(), g_w.clone());
            scalar::slim_worker_update_inplace(&mut v_w, &mut g_w, gamma);
            math::with_backend(b, || {
                math::slim_worker_update_inplace(&mut v_g, &mut g_g, gamma)
            });
            assert_eq!(bits(&v_w), bits(&v_g), "{b}: slim v n={n}");
            assert_eq!(bits(&g_w), bits(&g_g), "{b}: slim send n={n}");
        }
    }
}

// ---------------------------------------------------------------- (2)

#[test]
fn f16_bf16_codecs_match_scalar_bit_for_bit() {
    for b in simd_backends() {
        for n in lengths() {
            let vals = fill(n, n as u64 + 11);

            let mut want = vec![0xAAu8; 3]; // nonempty: append semantics
            let mut got = want.clone();
            scalar::f16_encode_into(&mut want, &vals);
            math::with_backend(b, || math::f16_encode_into(&mut got, &vals));
            assert_eq!(want, got, "{b}: f16 encode n={n}");

            let mut want = vec![0xAAu8; 3];
            let mut got = want.clone();
            scalar::bf16_encode_into(&mut want, &vals);
            math::with_backend(b, || math::bf16_encode_into(&mut got, &vals));
            assert_eq!(want, got, "{b}: bf16 encode n={n}");

            let mut want = vals.clone();
            let mut got = vals.clone();
            scalar::f16_round_trip(&mut want);
            math::with_backend(b, || math::f16_round_trip(&mut got));
            assert_eq!(bits(&want), bits(&got), "{b}: f16 round trip n={n}");

            let mut want = vals.clone();
            let mut got = vals;
            scalar::bf16_round_trip(&mut want);
            math::with_backend(b, || math::bf16_round_trip(&mut got));
            assert_eq!(bits(&want), bits(&got), "{b}: bf16 round trip n={n}");
        }

        // Decode: exhaustive over every possible half value in one shot,
        // plus one extra half so the length is not a lane-count multiple
        // and the remainder loop runs too.
        let mut all: Vec<u8> = (0..=u16::MAX).flat_map(|h: u16| h.to_le_bytes()).collect();
        all.extend_from_slice(&0x1234u16.to_le_bytes());
        for decode in [true, false] {
            let mut want: Vec<f32> = vec![9.0]; // nonempty: append semantics
            let mut got = want.clone();
            if decode {
                scalar::f16_decode_into(&mut want, &all);
                math::with_backend(b, || math::f16_decode_into(&mut got, &all));
            } else {
                scalar::bf16_decode_into(&mut want, &all);
                math::with_backend(b, || math::bf16_decode_into(&mut got, &all));
            }
            assert_eq!(
                bits(&want),
                bits(&got),
                "{b}: exhaustive {} decode",
                if decode { "f16" } else { "bf16" }
            );
        }
    }
}

// ---------------------------------------------------------------- (3)

#[test]
fn reductions_are_bit_identical_across_backends_and_thread_context() {
    for n in lengths() {
        let a = fill(n, n as u64 + 21);
        let c = fill(n, n as u64 + 22);
        let want = (
            scalar::dot(&a, &c).to_bits(),
            scalar::norm2_sq(&a).to_bits(),
            scalar::sub_norm_sq(&a, &c).to_bits(),
        );
        for b in simd_backends() {
            let got = math::with_backend(b, || {
                (
                    math::dot(&a, &c).to_bits(),
                    math::norm2_sq(&a).to_bits(),
                    math::sub_norm_sq(&a, &c).to_bits(),
                )
            });
            assert_eq!(want, got, "{b}: reductions n={n}");
        }
        // The executing thread is irrelevant: the same reduction run from
        // inside pool workers of any size returns the same bits.
        for threads in [1usize, 2, 4] {
            let pool = WorkerPool::new(threads);
            let mut out = vec![(0u64, 0u64, 0u64); 1];
            pool.par_chunks_mut(&mut out, |_, slot| {
                slot[0] = (
                    scalar::dot(&a, &c).to_bits(),
                    scalar::norm2_sq(&a).to_bits(),
                    scalar::sub_norm_sq(&a, &c).to_bits(),
                );
            });
            assert_eq!(want, out[0], "pool threads={threads} n={n}");
        }
    }
}

// ---------------------------------------------------------------- (4)

/// One chunk's worth of work for the pooled-vs-scoped duel: a momentum
/// step per element (a plain `fn` item so both vehicles get the exact
/// same callee).
fn duel_step(_i: usize, chunk: &mut [(f32, f32, f32)]) {
    for (t, v, g) in chunk.iter_mut() {
        let mut ts = [*t];
        let mut vs = [*v];
        scalar::momentum_step(&mut ts, &mut vs, &[*g], 0.9, 0.05);
        (*t, *v) = (ts[0], vs[0]);
    }
}

#[test]
fn pooled_kernel_fanout_equals_scoped_reference() {
    for threads in [1usize, 2, 3, 7] {
        let pool = WorkerPool::new(threads);
        for n in [1usize, 16, 257, 1003] {
            let g = fill(n, n as u64 + 31);
            let theta0 = fill(n, n as u64 + 32);
            let v0 = fill(n, n as u64 + 33);
            // Scoped reference: chunked momentum steps over paired state.
            let mut scoped: Vec<(f32, f32, f32)> = theta0
                .iter()
                .zip(&v0)
                .zip(&g)
                .map(|((&t, &v), &g)| (t, v, g))
                .collect();
            let mut pooled = scoped.clone();
            parallel::par_chunks_mut(&mut scoped, threads, duel_step);
            pool.par_chunks_mut(&mut pooled, duel_step);
            let key = |v: &[(f32, f32, f32)]| -> Vec<(u32, u32)> {
                v.iter().map(|(t, v, _)| (t.to_bits(), v.to_bits())).collect()
            };
            assert_eq!(key(&scoped), key(&pooled), "threads={threads} n={n}");
        }
    }
}

// ---------------------------------------------------------------- (5)

fn smoke_cfg(kind: AlgorithmKind, workers: usize, epochs: f64) -> TrainConfig {
    let mut c = TrainConfig::preset(Workload::C10, kind, workers, epochs);
    c.seed = 47;
    c.metrics_every = 0;
    c
}

fn start_server(c: &TrainConfig, k: usize) -> NetServer {
    let master: Box<dyn Master> = make_master(
        c.algorithm,
        &real_async::synthetic_theta0(k),
        LrSchedule::new(c.schedule.clone()),
        0,
        c.shards,
        2,
    );
    NetServer::start(master, "127.0.0.1:0", ServeOptions::default()).unwrap()
}

/// `--kernels scalar` vs auto-dispatch, end to end over loopback: the
/// trajectories must be bit-for-bit identical.  DANA-Zero covers the
/// fused elementwise path; YellowFin additionally drives the reductions
/// (curvature/variance statistics) through the dispatch layer.
#[test]
fn loopback_scalar_vs_auto_dispatch_is_bit_for_bit() {
    let k = 48;
    let widest = *math::available_backends().last().unwrap();
    for kind in [AlgorithmKind::DanaZero, AlgorithmKind::YellowFin] {
        let c = smoke_cfg(kind, 3, 0.6);
        let run = |b: KernelBackend| {
            math::with_backend(b, || {
                let mut srv = start_server(&c, k);
                let mut rc = c.clone();
                rc.master_addr = Some(srv.url());
                let report = sim_trainer::run_synthetic(&rc, k).unwrap();
                srv.stop();
                report
            })
        };
        let scalar_run = run(KernelBackend::Scalar);
        let auto_run = run(widest);
        assert_eq!(
            scalar_run.final_test_loss, auto_run.final_test_loss,
            "{kind}: final loss diverged between scalar and {widest}"
        );
        assert_eq!(scalar_run.loss_curve, auto_run.loss_curve, "{kind}: loss curve");
        assert_eq!(scalar_run.steps, auto_run.steps, "{kind}: steps");
    }
}

/// The in-process (no wire) driver agrees across backends too — a faster
/// bisection signal than the loopback pair when a backend regresses.
#[test]
fn in_process_trainer_is_backend_invariant() {
    let k = 32;
    let widest = *math::available_backends().last().unwrap();
    let c = smoke_cfg(AlgorithmKind::DanaDc, 3, 0.5);
    let a = math::with_backend(KernelBackend::Scalar, || {
        sim_trainer::run_synthetic(&c, k).unwrap()
    });
    let b = math::with_backend(widest, || sim_trainer::run_synthetic(&c, k).unwrap());
    assert_eq!(a.final_test_loss, b.final_test_loss);
    assert_eq!(a.loss_curve, b.loss_curve);
}

/// Sanity on the harness itself: finite fills really are finite and the
/// weird pool really contains NaNs/infs/subnormals (guards against a
/// refactor silently defanging the adversarial inputs).
#[test]
fn weird_pool_is_actually_weird() {
    let v = fill(3 * WEIRD.len(), 0);
    assert!(v.iter().any(|x| x.is_nan()));
    assert!(v.iter().any(|x| x.is_infinite()));
    assert!(v.iter().any(|x| x.is_subnormal()));
    assert!(v.iter().any(|&x| x == 0.0 && x.is_sign_negative()));
    assert!(fill_finite(64, 1).iter().all(|x| x.is_finite()));
}

//! Algorithmic equivalence obligations from the paper (DESIGN.md §6):
//! exact identities between update rules, tested end-to-end through the
//! parameter-server machinery on synthetic objectives (no PJRT needed).

use dana::optim::sgd::{BengioNag, Nag};
use dana::optim::{make_algorithm, AlgorithmKind, LrSchedule, ScheduleConfig, Step};
use dana::server::ParameterServer;
use dana::util::rng::Rng;

const K: usize = 37;

fn flat_schedule(n: usize) -> LrSchedule {
    LrSchedule::new(ScheduleConfig {
        base_eta: 0.05,
        gamma: 0.9,
        lambda: 1.0,
        warmup_epochs: 0.0,
        decay_epochs: vec![],
        decay_factor: 1.0,
        steps_per_epoch: 100,
        n_workers: n,
        ..ScheduleConfig::default()
    })
}

/// Quadratic objective J(x) = 0.5 Σ k_i x_i² with per-coordinate curvature.
fn quad_grad(theta: &[f32], ks: &[f32], out: &mut Vec<f32>) {
    out.clear();
    out.extend(theta.iter().zip(ks).map(|(&t, &k)| k * t));
}

fn curvatures() -> Vec<f32> {
    (0..K).map(|i| 0.3 + 0.05 * (i as f32)).collect()
}

fn theta0() -> Vec<f32> {
    (0..K).map(|i| ((i * 7 + 3) as f32 * 0.37).sin()).collect()
}

/// Paper Eq 11 vs Eq 15: DANA-Zero's look-ahead send θ̂ and DANA-Slim's
/// master parameters Θ are THE SAME VECTOR, so both algorithms send
/// identical parameters and follow identical trajectories when driven by
/// the same gradient schedule.
#[test]
fn dana_slim_trajectory_equals_dana_zero() {
    let n = 4;
    let ks = curvatures();
    let mut zero = ParameterServer::new(
        make_algorithm(AlgorithmKind::DanaZero, &theta0(), n),
        flat_schedule(n),
        n,
    );
    let mut slim = ParameterServer::new(
        make_algorithm(AlgorithmKind::DanaSlim, &theta0(), n),
        flat_schedule(n),
        n,
    );
    let mut slim_ws: Vec<_> = (0..n).map(|_| slim.algorithm().make_worker_state()).collect();
    let mut zero_local = vec![vec![0.0f32; K]; n];
    let mut slim_local = vec![vec![0.0f32; K]; n];
    for w in 0..n {
        zero_local[w].copy_from_slice(zero.pull(w));
        slim_local[w].copy_from_slice(slim.pull(w));
        assert_eq!(zero_local[w], slim_local[w], "initial sends differ");
    }
    let mut rng = Rng::new(42);
    let mut g = Vec::new();
    for step in 0..400 {
        let w = rng.below(n as u64) as usize;
        // DANA-Zero worker: compute grad at received θ̂, send raw gradient.
        quad_grad(&zero_local[w], &ks, &mut g);
        zero.push(w, &g).unwrap();
        zero_local[w].copy_from_slice(zero.pull(w));
        // DANA-Slim worker: compute grad at received Θ, send γv+g.
        quad_grad(&slim_local[w], &ks, &mut g);
        let s = slim.current_step();
        let mut msg = g.clone();
        slim.algorithm().worker_message(&mut slim_ws[w], &mut msg, s);
        slim.push(w, &msg).unwrap();
        slim_local[w].copy_from_slice(slim.pull(w));

        for i in 0..K {
            let a = zero_local[w][i];
            let b = slim_local[w][i];
            assert!(
                (a - b).abs() < 1e-4,
                "step {step}: sends diverged at [{i}]: {a} vs {b}"
            );
        }
    }
    // Eq 15 cross-check: Θ_slim = θ_zero − ηγ·v⁰ at rest.
    let s = Step { eta: 0.05, gamma: 0.9, lambda: 1.0 };
    let mut hat = vec![0.0f32; K];
    zero.algorithm_mut().master_send(0, &mut hat, s);
    for i in 0..K {
        assert!((hat[i] - slim.theta()[i]).abs() < 1e-4);
    }
}

/// Paper Algorithm 5: with one worker the DANA pull→grad→push cycle IS
/// sequential NAG; and Bengio-NAG matches NAG under Θ = θ − ηγv (Eq 13).
#[test]
fn single_worker_dana_is_nag_is_bengio() {
    let ks = curvatures();
    let mut server = ParameterServer::new(
        make_algorithm(AlgorithmKind::DanaZero, &theta0(), 1),
        flat_schedule(1),
        1,
    );
    let mut nag = Nag::new(&theta0());
    let mut ben = BengioNag::new(&theta0());
    let (eta, gamma) = (0.05, 0.9);
    let mut hat = vec![0.0f32; K];
    let mut g = Vec::new();
    for _ in 0..200 {
        // DANA through the server
        let sent = server.pull(0).to_vec();
        quad_grad(&sent, &ks, &mut g);
        server.push(0, &g).unwrap();
        // sequential NAG
        nag.lookahead_params(&mut hat, eta, gamma);
        quad_grad(&hat, &ks, &mut g);
        nag.apply(&g, eta, gamma);
        // Bengio-NAG
        quad_grad(&ben.theta.clone(), &ks, &mut g);
        ben.apply(&g, eta, gamma);
        for i in 0..K {
            assert!((server.theta()[i] - nag.theta[i]).abs() < 1e-4);
            let theta_big = nag.theta[i] - eta * gamma * nag.v[i];
            assert!((theta_big - ben.theta[i]).abs() < 1e-4);
        }
    }
    // and it converges on the quadratic
    assert!(dana::math::norm2_sq(server.theta()) < 1e-3);
}

/// Paper Eq 12: with equal deterministic gradients, DANA's displacement
/// `E[Δ_{t+τ}] = θ_{t+τ} − θ̂_t` equals ASGD's `−η Σᵢ g_prev(i)`.  The
/// paper's sums run over all N workers' latest updates (prev(i, t+τ)
/// *includes the pushing worker's own*), so the displacement is measured
/// post-apply; in steady round-robin both sides are exactly N·η·g.
#[test]
fn eq12_dana_gap_equals_asgd_gap_in_expectation() {
    let n = 6;
    let eta = 0.05f64;
    let g0 = 0.02f64;
    let constant_grad = vec![g0 as f32; K];
    let mut gaps = Vec::new();
    for kind in [AlgorithmKind::Asgd, AlgorithmKind::DanaZero] {
        let mut ps = ParameterServer::new(
            make_algorithm(kind, &theta0(), n),
            flat_schedule(n),
            n,
        );
        let mut sent = vec![vec![0.0f32; K]; n];
        for w in 0..n {
            sent[w].copy_from_slice(ps.pull(w));
        }
        let mut tail = Vec::new();
        for step in 0..600usize {
            let w = step % n;
            ps.push(w, &constant_grad).unwrap();
            // post-apply displacement vs what the worker computed on
            if step >= 300 {
                tail.push(dana::util::stats::rmse(
                    &ps.theta()
                        .iter()
                        .zip(&sent[w])
                        .map(|(a, b)| a - b)
                        .collect::<Vec<f32>>(),
                ));
            }
            sent[w].copy_from_slice(ps.pull(w));
        }
        gaps.push(tail.iter().sum::<f64>() / tail.len() as f64);
    }
    let (asgd, dana) = (gaps[0], gaps[1]);
    let expected = n as f64 * eta * g0; // N·η·g per coordinate
    assert!(
        (dana / asgd - 1.0).abs() < 0.05,
        "Eq 12 violated: ASGD gap {asgd:.3e} vs DANA gap {dana:.3e}"
    );
    assert!(
        (asgd / expected - 1.0).abs() < 0.05,
        "steady-state magnitude off: {asgd:.3e} vs {expected:.3e}"
    );
}

/// NAG-ASGD's gap under the same constant-gradient schedule is ~1/(1-γ)
/// larger — the momentum inflation DANA removes (Section 3, footnote 2).
#[test]
fn nag_asgd_gap_is_momentum_inflated() {
    let n = 6;
    let constant_grad = vec![0.02f32; K];
    let mut gaps = Vec::new();
    for kind in [AlgorithmKind::Asgd, AlgorithmKind::NagAsgd] {
        let mut ps = ParameterServer::new(
            make_algorithm(kind, &theta0(), n),
            flat_schedule(n),
            n,
        );
        ps.metrics.set_every(1);
        for w in 0..n {
            ps.pull(w);
        }
        for step in 0..600 {
            let w = step % n;
            ps.push(w, &constant_grad).unwrap();
            ps.pull(w);
        }
        let rows = ps.metrics.rows();
        let tail = &rows[rows.len() / 2..];
        gaps.push(tail.iter().map(|r| r.gap).sum::<f64>() / tail.len() as f64);
    }
    let ratio = gaps[1] / gaps[0];
    // gamma = 0.9 -> momentum multiplies steady-state velocity by 10
    assert!(
        ratio > 5.0,
        "NAG-ASGD gap should be ~1/(1-gamma) larger, got {ratio:.2}x"
    );
}

/// DANA-DC with λ=0 equals DANA-Zero through the full server stack.
#[test]
fn dana_dc_lambda0_is_dana_zero() {
    let n = 3;
    let ks = curvatures();
    let mut sched = flat_schedule(n).config().clone();
    sched.lambda = 0.0;
    let mk = |kind| {
        ParameterServer::new(
            make_algorithm(kind, &theta0(), n),
            LrSchedule::new(sched.clone()),
            n,
        )
    };
    let mut dc = mk(AlgorithmKind::DanaDc);
    let mut zero = mk(AlgorithmKind::DanaZero);
    let mut rng = Rng::new(3);
    let mut g = Vec::new();
    for w in 0..n {
        dc.pull(w);
        zero.pull(w);
    }
    for _ in 0..200 {
        let w = rng.below(n as u64) as usize;
        let sent = dc.pull(w).to_vec();
        quad_grad(&sent, &ks, &mut g);
        dc.push(w, &g).unwrap();
        let sent_z = zero.pull(w).to_vec();
        assert_eq!(sent, sent_z);
        quad_grad(&sent_z, &ks, &mut g);
        zero.push(w, &g).unwrap();
    }
    for i in 0..K {
        assert!((dc.theta()[i] - zero.theta()[i]).abs() < 1e-5);
    }
}

/// Momentum correction (Goyal): after an LR decay, a NAG trajectory with
/// correction matches a fresh NAG started from the same state with the
/// momentum rescaled — i.e. no velocity overshoot glitch.
#[test]
fn momentum_correction_prevents_decay_glitch() {
    let ks = curvatures();
    let sched = ScheduleConfig {
        base_eta: 0.05,
        gamma: 0.9,
        lambda: 1.0,
        warmup_epochs: 0.0,
        decay_epochs: vec![1.0],
        decay_factor: 0.1,
        steps_per_epoch: 50,
        n_workers: 1,
        ..ScheduleConfig::default()
    };
    let mut with = ParameterServer::new(
        make_algorithm(AlgorithmKind::NagAsgd, &theta0(), 1),
        LrSchedule::new(sched.clone()),
        1,
    );
    let mut without = ParameterServer::new(
        make_algorithm(AlgorithmKind::NagAsgd, &theta0(), 1),
        LrSchedule::new(sched),
        1,
    )
    .with_momentum_correction(false);
    let mut g = Vec::new();
    for ps in [&mut with, &mut without] {
        for _ in 0..120 {
            let sent = ps.pull(0).to_vec();
            quad_grad(&sent, &ks, &mut g);
            ps.push(0, &g).unwrap();
        }
    }
    // both converge on a quadratic, but the corrected run must not be worse
    let jw = dana::math::norm2_sq(with.theta());
    let jo = dana::math::norm2_sq(without.theta());
    assert!(jw.is_finite() && jo.is_finite());
    assert!(jw <= jo * 1.5, "correction made things worse: {jw} vs {jo}");
}

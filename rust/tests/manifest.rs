//! Cluster-manifest tests (DESIGN.md §14): golden parses of the
//! committed example manifests into exact expected structs, pinned
//! rejection text for every structural failure mode, and `==`
//! equivalence between the manifest spelling and the flag spelling of
//! the same process config (the `from_manifest` constructors).

use dana::cluster::manifest::{
    parse_shard_range, ArtifactRef, CheckpointSpec, ClusterManifest, FleetSpec, ModelSpec,
    RestartPolicy, ServerSpec, StandbySpec,
};
use dana::cluster::StandbyConfig;
use dana::config::{ServeSpec, StandbyOf, TrainConfig, Workload};
use dana::net::{Encoding, EncodingSet, Placement, RetentionPolicy, ServeOptions};
use dana::optim::{AlgorithmKind, LeavePolicy};
use dana::sim::ChurnSchedule;
use dana::util::json::Json;
use dana::util::sha256::sha256_hex;
use std::path::{Path, PathBuf};
use std::time::Duration;

fn repo(p: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join(p)
}

fn load_fixture(name: &str) -> anyhow::Result<ClusterManifest> {
    ClusterManifest::load(&repo("rust/tests/fixtures/manifest").join(name))
}

/// Load must fail, and the error must carry the pinned substring (the
/// fail-closed contract: every rejection names what is wrong).
fn rejects(name: &str, substring: &str) {
    let err = match load_fixture(name) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("{name} parsed — it must reject"),
    };
    assert!(err.contains(substring), "{name}: error {err:?} lacks {substring:?}");
}

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("dana-manifest-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

// ------------------------------------------------------------- golden

/// The committed two-server example parses to exactly this struct —
/// field for field, defaults included.  Any schema drift (a renamed
/// field, a changed default) breaks this test by construction.
#[test]
fn two_server_example_parses_to_expected_struct() {
    let path = repo("examples/cluster/two_server.json");
    let m = ClusterManifest::load(&path).unwrap();
    let ck = |base: &str| {
        Some(CheckpointSpec {
            path: PathBuf::from(base),
            every: 1,
            keep_last: 8,
            keep_hourly: 0,
        })
    };
    let expected = ClusterManifest {
        name: "two-server-takeover".into(),
        algorithm: AlgorithmKind::DanaZero,
        shards: 4,
        model: ModelSpec::Synthetic { k: 4096 },
        epochs: 10.0,
        seed: 1,
        eta: None,
        gamma: None,
        pipeline_depth: 1,
        leave_policy: LeavePolicy::Retire,
        encodings: EncodingSet::ALL,
        kernels: Default::default(),
        metrics_every: 0,
        servers: vec![
            ServerSpec {
                name: "r0".into(),
                listen: "127.0.0.1:7795".into(),
                status_addr: Some("127.0.0.1:9636".into()),
                shard_range: 0..2,
                placement_epoch: 0,
                serve_threads: 1,
                checkpoint: ck("r0.bin"),
                restart: RestartPolicy::default(),
            },
            ServerSpec {
                name: "r1".into(),
                listen: "127.0.0.1:7796".into(),
                status_addr: Some("127.0.0.1:9638".into()),
                shard_range: 2..4,
                placement_epoch: 0,
                serve_threads: 1,
                checkpoint: ck("r1.bin"),
                restart: RestartPolicy::default(),
            },
        ],
        standbys: vec![StandbySpec {
            name: "sb0".into(),
            of: "r0".into(),
            listen: "127.0.0.1:7797".into(),
            status_addr: Some("127.0.0.1:9637".into()),
            poll_ms: 100,
            miss_budget: 3,
            restart: RestartPolicy::default(),
        }],
        fleet: Some(FleetSpec {
            workers: 2,
            epochs: 0.3,
            mode: "real".into(),
            encoding: Encoding::None,
            churn: ChurnSchedule::default(),
            leave_policy: LeavePolicy::Retire,
            max_restarts: 0,
            restart_backoff_ms: 50,
            metrics_every: 0,
            seed: 1,
            restart: RestartPolicy::default(),
        }),
        artifacts: vec![],
        base_dir: repo("examples/cluster"),
    };
    assert_eq!(m, expected);
    assert_eq!(
        m.master_list(),
        "tcp://127.0.0.1:7795,tcp://127.0.0.1:7796,tcp://127.0.0.1:7797"
    );
    assert_eq!(m.synthetic_k(), Some(4096));
}

#[test]
fn churny_fleet_example_parses() {
    let m = ClusterManifest::load(&repo("examples/cluster/churny_fleet.json")).unwrap();
    assert_eq!(m.algorithm, AlgorithmKind::Dana);
    assert_eq!(m.leave_policy, LeavePolicy::Fold);
    assert_eq!(m.servers[0].serve_threads, 2);
    assert_eq!(m.servers[0].restart, RestartPolicy { max: 2, backoff_ms: 200 });
    let f = m.fleet.as_ref().unwrap();
    assert_eq!(f.workers, 4);
    assert_eq!(f.churn.events.len(), 2);
    assert_eq!(f.encoding, Encoding::F16);
    // the fleet inherits the manifest-wide leave policy
    assert_eq!(f.leave_policy, LeavePolicy::Fold);
}

// ------------------------------------------ from_manifest equivalence

/// `ServeOptions::from_manifest` for a `servers[]` entry equals the
/// hand-built options the equivalent `dana serve` flags produce.
#[test]
fn serve_options_from_manifest_match_flag_spelling() {
    let m = ClusterManifest::load(&repo("examples/cluster/two_server.json")).unwrap();
    let run = Path::new("/run/dana");
    let got = ServeOptions::from_manifest(&m, m.server("r0").unwrap(), run);
    let want = ServeOptions {
        leave_policy: LeavePolicy::Retire,
        checkpoint_path: Some(PathBuf::from("/run/dana/r0.bin")),
        checkpoint_every: 1,
        pipeline_depth: 1,
        status_addr: Some("127.0.0.1:9636".into()),
        retention: RetentionPolicy { keep_last: 8, keep_hourly: 0 },
        encodings: EncodingSet::ALL,
        placement: Placement { shard_start: 0, total_shards: 4, epoch: 0, takeovers: 0 },
    };
    assert_eq!(got, want);
    // the second range starts where the first ends
    let r1 = ServeOptions::from_manifest(&m, m.server("r1").unwrap(), run);
    assert_eq!(r1.placement.shard_start, 2);
    assert_eq!(r1.checkpoint_path, Some(PathBuf::from("/run/dana/r1.bin")));
}

#[test]
fn serve_spec_from_manifest_matches_flag_spelling() {
    let m = ClusterManifest::load(&repo("examples/cluster/two_server.json")).unwrap();
    let run = Path::new("/run/dana");
    let got = ServeSpec::from_manifest(&m, "r0", run).unwrap();
    let want = ServeSpec {
        listen: "127.0.0.1:7795".into(),
        algorithm: AlgorithmKind::DanaZero,
        workload: Workload::C10, // schedule donor for synthetic models
        synthetic_k: Some(4096),
        workers: 2,
        epochs: 10.0,
        seed: 1,
        eta: None,
        gamma: None,
        shards: 4,
        shard_range: Some(0..2),
        placement_epoch: 0,
        serve_threads: 1,
        pipeline_depth: 1,
        leave_policy: LeavePolicy::Retire,
        checkpoint_path: Some(PathBuf::from("/run/dana/r0.bin")),
        checkpoint_every: 1,
        resume: None,
        status_addr: Some("127.0.0.1:9636".into()),
        retention: RetentionPolicy { keep_last: 8, keep_hourly: 0 },
        encodings: EncodingSet::ALL,
        kernels: Default::default(),
        metrics_every: 0,
        artifacts_dir: got.artifacts_dir.clone(),
        standby: None,
    };
    assert_eq!(got, want);
    // a standby name yields the standby spelling: the primary's archive
    // base and retention, the standby's own listener, and `standby` set
    let sb = ServeSpec::from_manifest(&m, "sb0", run).unwrap();
    assert_eq!(sb.listen, "127.0.0.1:7797");
    assert_eq!(sb.checkpoint_path, Some(PathBuf::from("/run/dana/r0.bin")));
    assert_eq!(sb.retention, RetentionPolicy { keep_last: 8, keep_hourly: 0 });
    assert_eq!(
        sb.standby,
        Some(StandbyOf { primary: "tcp://127.0.0.1:7795".into(), poll_ms: 100, miss_budget: 3 })
    );
    // unknown names list what exists
    let err = format!("{:#}", ServeSpec::from_manifest(&m, "nope", run).unwrap_err());
    assert!(err.contains("no server or standby named \"nope\""), "got: {err}");
    assert!(err.contains("r0") && err.contains("sb0"), "got: {err}");
}

#[test]
fn train_config_from_manifest_matches_flag_spelling() {
    let m = ClusterManifest::load(&repo("examples/cluster/two_server.json")).unwrap();
    let cfg = TrainConfig::from_manifest(&m).unwrap();
    // the flag spelling the CI smoke used: --algorithm dana-zero
    // --workers 2 --epochs 0.3 --pipeline-depth 1 --master <list>
    let mut want = TrainConfig::preset(Workload::C10, AlgorithmKind::DanaZero, 2, 10.0);
    want.epochs = 0.3; // fleet run length; epochs=10 stays the schedule
    want.pipeline_depth = 1;
    want.master_addr = Some(m.master_list());
    assert_eq!(cfg, want);
    assert_eq!(cfg.total_master_steps(), 30);
}

#[test]
fn standby_config_from_manifest_pairs_with_primary() {
    let m = ClusterManifest::load(&repo("examples/cluster/two_server.json")).unwrap();
    let run = Path::new("/run/dana");
    let sb = StandbyConfig::from_manifest(&m, "sb0", run).unwrap();
    assert_eq!(sb.listen, "127.0.0.1:7797");
    assert_eq!(sb.primary, "tcp://127.0.0.1:7795");
    assert_eq!(sb.archive_base, PathBuf::from("/run/dana/r0.bin"));
    assert_eq!(sb.poll, Duration::from_millis(100));
    assert_eq!(sb.miss_budget, 3);
    // the status endpoint is the standby's own, not the primary's
    assert_eq!(sb.opts.status_addr, Some("127.0.0.1:9637".into()));
    // the placement is learned from the primary at takeover, never
    // configured up front
    assert_eq!(sb.opts.placement, Placement::default());
    let err =
        format!("{:#}", StandbyConfig::from_manifest(&m, "r0", run).unwrap_err());
    assert!(err.contains("no standby named \"r0\""), "got: {err}");
}

// --------------------------------------------------------- rejections

#[test]
fn overlapping_ranges_reject() {
    rejects("overlap.json", "overlap");
    rejects("overlap.json", "cluster manifest");
}

#[test]
fn gappy_ranges_reject() {
    rejects("gap.json", "leave a gap");
}

#[test]
fn unknown_top_level_field_rejects_by_name() {
    rejects("unknown_field.json", "unknown field \"pipline_depth\" in top level");
}

#[test]
fn malformed_sha256_rejects() {
    rejects("bad_sha256.json", "sha256 must be 64 hex characters");
}

#[test]
fn duplicate_listen_address_rejects() {
    rejects("duplicate_addr.json", "duplicate listen address \"127.0.0.1:7901\"");
}

#[test]
fn standby_naming_unknown_primary_rejects() {
    rejects("standby_of_unknown.json", "standby \"sb\" names unknown server \"ghost\"");
}

#[test]
fn standby_of_unarchived_primary_rejects() {
    rejects("standby_unarchived.json", "keeps no retention archives to tail");
}

/// The remaining structural failure modes, built from the valid example
/// by mutation so the fixtures stay minimal.
#[test]
fn mutated_manifests_reject_with_pinned_text() {
    let base = std::fs::read_to_string(repo("examples/cluster/two_server.json")).unwrap();
    let dir = tmpdir("mutations");
    let check = |tag: &str, from: &str, to: &str, substring: &str| {
        let mutated = base.replacen(from, to, 1);
        assert_ne!(mutated, base, "{tag}: mutation {from:?} did not apply");
        let p = dir.join(format!("{tag}.json"));
        std::fs::write(&p, mutated).unwrap();
        let err = match ClusterManifest::load(&p) {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("{tag} parsed — it must reject"),
        };
        assert!(err.contains(substring), "{tag}: error {err:?} lacks {substring:?}");
        // load() prefixes the failing file's path
        assert!(err.contains(&format!("{tag}.json")), "{tag}: error {err:?} lacks the path");
    };
    // coverage must reach the global shard count
    check("short", "\"shards\": 4", "\"shards\": 5", "covers shards only up to 4 of 5");
    // an empty range is named before tiling is even considered
    check("empty", "\"0..2\"", "\"2..2\"", "is empty (need A < B)");
    // unknown fields reject in nested sections too, naming the section
    check(
        "nested",
        "\"poll_ms\": 100",
        "\"pollms\": 100",
        "unknown field \"pollms\" in standbys[0]",
    );
    // unknown enum values surface the inner FromStr error with context
    check("algo", "\"dana-zero\"", "\"dana-9000\"", "algorithm");
    // duplicate process names reject even with distinct addresses
    check("dupname", "\"name\": \"r1\"", "\"name\": \"r0\"", "duplicate process name \"r0\"");
    // pipeline depth must fit the pull-window budget
    check(
        "window",
        "\"pipeline_depth\": 1",
        "\"pipeline_depth\": 33",
        "pipeline_depth 33 exceeds the supported window (32)",
    );
    // fleet mode is a closed enum
    check("mode", "\"mode\": \"real\"", "\"mode\": \"fast\"", "fleet.mode must be \"real\" or \"sim\"");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn shard_range_grammar_is_shared() {
    assert_eq!(parse_shard_range("0..2").unwrap(), 0..2);
    assert_eq!(parse_shard_range(" 3 .. 7 ").unwrap(), 3..7);
    let err = parse_shard_range("3").unwrap_err().to_string();
    assert!(err.contains("wants A..B"), "got: {err}");
    let err = parse_shard_range("5..5").unwrap_err().to_string();
    assert!(err.contains("is empty (need A < B)"), "got: {err}");
}

// ---------------------------------------------------------- artifacts

/// Checksum verification fails closed — absent file, mismatched digest
/// — and passes byte-identical content; `--verify-only` is exactly this
/// plus the structural parse.
#[test]
fn artifact_checksums_verify_fail_closed() {
    let dir = tmpdir("artifacts");
    let body = b"not actually weights";
    std::fs::write(dir.join("weights.bin"), body).unwrap();
    let manifest = |digest: &str, file: &str| {
        format!(
            r#"{{
              "algorithm": "dana-zero",
              "shards": 1,
              "model": {{"synthetic": true, "k": 64}},
              "servers": [{{"name": "a", "listen": "127.0.0.1:7901", "shard_range": "0..1"}}],
              "artifacts": [{{"path": "{file}", "sha256": "{digest}"}}]
            }}"#
        )
    };
    // pinned digest matches the file: verification counts it
    let good = manifest(&sha256_hex(body), "weights.bin");
    std::fs::write(dir.join("good.json"), good).unwrap();
    let m = ClusterManifest::load(&dir.join("good.json")).unwrap();
    assert_eq!(m.verify_artifacts().unwrap(), 1);
    // artifact paths resolve against the manifest's own directory
    assert_eq!(m.artifacts[0], ArtifactRef {
        path: PathBuf::from("weights.bin"),
        sha256: sha256_hex(body),
    });

    // wrong digest: rejected, naming both digests
    let bad = manifest(&"0".repeat(64), "weights.bin");
    std::fs::write(dir.join("bad.json"), bad).unwrap();
    let m = ClusterManifest::load(&dir.join("bad.json")).unwrap();
    let err = format!("{:#}", m.verify_artifacts().unwrap_err());
    assert!(err.contains("sha256 mismatch for \"weights.bin\""), "got: {err}");
    assert!(err.contains(&sha256_hex(body)), "got: {err}");

    // absent file: rejected, naming the artifact
    let gone = manifest(&"0".repeat(64), "missing.bin");
    std::fs::write(dir.join("gone.json"), gone).unwrap();
    let m = ClusterManifest::load(&dir.join("gone.json")).unwrap();
    let err = format!("{:#}", m.verify_artifacts().unwrap_err());
    assert!(err.contains("missing.bin"), "got: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

// --------------------------------------------------- strict overrides

/// Satellite: `TrainConfig::apply_json` is fail-closed from a manifest
/// context too — the `--config` path and the manifest share the
/// rejection discipline.
#[test]
fn config_overrides_share_the_fail_closed_discipline() {
    let mut cfg = TrainConfig::preset(Workload::C10, AlgorithmKind::DanaSlim, 8, 10.0);
    let doc = Json::parse(r#"{"pipline_depth": 2}"#).unwrap();
    let err = cfg.apply_json(&doc).unwrap_err().to_string();
    assert!(err.contains("unknown key \"pipline_depth\""), "got: {err}");
    assert_eq!(cfg.pipeline_depth, 0, "a rejected document must not half-apply");
}

//! Network-transport equivalence obligations (ISSUE 3 acceptance):
//!
//! 1. Loopback end-to-end: `NetServer` on `127.0.0.1:0` + `RemoteMaster`
//!    workers reproduce the in-process driver's trajectory **bit-for-bit**
//!    for all 10 algorithms (the wire moves exact f32 bits and the master
//!    runs the identical op sequence).
//! 2. A mid-run client disconnect (EOF, no Leave frame) triggers the same
//!    `LeavePolicy` state transition `rust/tests/churn.rs` asserts
//!    in-process — verified by snapshot equality against an in-process
//!    replica driven through the identical op sequence.
//! 3. checkpoint → kill → `--resume` → reconnect continues from the
//!    snapshot step, bit-for-bit against an uninterrupted reference, with
//!    the v⁰ = Σ live vᶦ invariant intact at the end.
//! 4. Stragglers from a previous incarnation of a slot (stale generation)
//!    are rejected recoverably; protocol abuse is rejected fatally.

use dana::config::{TrainConfig, Workload};
use dana::net::checkpoint;
use dana::net::wire::{read_frame, write_frame, Msg, Role};
use dana::net::{Encoding, NetServer, RemoteMaster, ServeOptions};
use dana::optim::{AlgorithmKind, LeavePolicy, LrSchedule, StateVec};
use dana::server::{make_master, Master, MasterSnapshot};
use dana::sim::ChurnSchedule;
use dana::train::{real_async, sim_trainer};
use dana::util::rng::Rng;
use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;

fn cfg(kind: AlgorithmKind, workers: usize, epochs: f64, shards: usize) -> TrainConfig {
    let mut c = TrainConfig::preset(Workload::C10, kind, workers, epochs);
    c.seed = 31;
    // gap/lag metrics live server-side on a remote run; keep them off so
    // both sides of each comparison record nothing
    c.metrics_every = 0;
    c.shards = shards;
    c
}

/// The master a `dana serve` for this config would host: zero slots
/// (connect == join), same schedule, synthetic θ₀.
fn serve_master(c: &TrainConfig, k: usize) -> Box<dyn Master> {
    make_master(
        c.algorithm,
        &real_async::synthetic_theta0(k),
        LrSchedule::new(c.schedule.clone()),
        0,
        c.shards,
        2,
    )
}

fn start_server(c: &TrainConfig, k: usize, opts: ServeOptions) -> NetServer {
    NetServer::start(serve_master(c, k), "127.0.0.1:0", opts).unwrap()
}

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("dana-net-{name}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

// ---------------------------------------------------------------- (1)

/// Loopback `RemoteMaster` ≡ in-process master, all 10 kinds.
#[test]
fn loopback_sim_driver_matches_in_process_bit_for_bit_all_kinds() {
    let k = 48;
    for kind in AlgorithmKind::ALL {
        let c = cfg(kind, 3, 0.6, 1);
        let base = sim_trainer::run_synthetic(&c, k).unwrap();
        let mut srv = start_server(&c, k, ServeOptions::default());
        let mut rc = c.clone();
        rc.master_addr = Some(srv.url());
        let remote = sim_trainer::run_synthetic(&rc, k).unwrap();
        assert_eq!(
            remote.final_test_loss, base.final_test_loss,
            "{kind}: final loss diverged across the wire"
        );
        assert_eq!(remote.loss_curve, base.loss_curve, "{kind}: loss curve");
        assert_eq!(remote.steps, base.steps, "{kind}");
        srv.stop();
    }
}

/// `--shards` composes with the transport: a sharded master behind the
/// wire equals the monolithic one (elementwise rule ⇒ exact).
#[test]
fn sharded_master_behind_the_wire_matches_monolithic() {
    let k = 48;
    let mono = cfg(AlgorithmKind::DanaDc, 3, 0.5, 1);
    let shrd = cfg(AlgorithmKind::DanaDc, 3, 0.5, 4);
    let mut reports = Vec::new();
    for c in [&mono, &shrd] {
        let mut srv = start_server(c, k, ServeOptions::default());
        let mut rc = c.clone();
        rc.master_addr = Some(srv.url());
        reports.push(sim_trainer::run_synthetic(&rc, k).unwrap());
        srv.stop();
    }
    assert_eq!(reports[0].final_test_loss, reports[1].final_test_loss);
    assert_eq!(reports[0].loss_curve, reports[1].loss_curve);
}

/// The pipelined driver (depth ≥ 1, deferred-ack pushes) over loopback ≡
/// in-process, bit-for-bit, for look-ahead and baseline rules alike —
/// the server op sequence is identical, only the ack timing moves.
#[test]
fn loopback_pipelined_driver_matches_in_process_bit_for_bit() {
    let k = 48;
    for depth in [1usize, 2] {
        for kind in [
            AlgorithmKind::DanaZero,
            AlgorithmKind::DanaDc,
            AlgorithmKind::DcAsgd,
            AlgorithmKind::Lwp,
        ] {
            let mut c = cfg(kind, 3, 0.6, 1);
            c.pipeline_depth = depth;
            let base = sim_trainer::run_synthetic(&c, k).unwrap();
            let opts = ServeOptions { pipeline_depth: depth, ..Default::default() };
            let mut srv = NetServer::start(serve_master(&c, k), "127.0.0.1:0", opts).unwrap();
            let mut rc = c.clone();
            rc.master_addr = Some(srv.url());
            let remote = sim_trainer::run_synthetic(&rc, k).unwrap();
            assert_eq!(
                remote.final_test_loss, base.final_test_loss,
                "{kind} D={depth}: pipelined trajectory diverged across the wire"
            );
            assert_eq!(remote.loss_curve, base.loss_curve, "{kind} D={depth}");
            srv.stop();
        }
    }
}

/// Churn events flow through real sockets: joins open connections,
/// leaves close them, and the trajectory still matches in-process.
#[test]
fn loopback_churn_matches_in_process() {
    let k = 64;
    for kind in [AlgorithmKind::DanaZero, AlgorithmKind::DanaSlim] {
        let mut c = cfg(kind, 4, 1.0, 1);
        c.churn = ChurnSchedule::parse("leave@0.3:2,join@0.5,leave@0.6,join@0.8").unwrap();
        let base = sim_trainer::run_synthetic(&c, k).unwrap();
        let mut srv = start_server(&c, k, ServeOptions::default());
        let mut rc = c.clone();
        rc.master_addr = Some(srv.url());
        let remote = sim_trainer::run_synthetic(&rc, k).unwrap();
        assert_eq!(remote.final_test_loss, base.final_test_loss, "{kind}: churn trajectory");
        assert_eq!(remote.loss_curve, base.loss_curve, "{kind}");
        assert_eq!(
            (remote.workers_joined, remote.workers_left),
            (base.workers_joined, base.workers_left),
            "{kind}"
        );
        srv.stop();
    }
}

/// The real-thread driver (OS threads + mpsc + churn) runs against a
/// socket master end-to-end.  Thread timing is nondeterministic, so this
/// asserts completion and descent rather than bit equality.
#[test]
fn real_thread_driver_runs_against_a_socket_master() {
    let k = 96;
    let mut c = cfg(AlgorithmKind::DanaSlim, 3, 1.0, 1);
    c.churn = ChurnSchedule::parse("leave@0.3,join@0.6").unwrap();
    let mut srv = start_server(&c, k, ServeOptions::default());
    let mut rc = c.clone();
    rc.master_addr = Some(srv.url());
    let rep = real_async::run_synthetic(&rc, k).unwrap();
    assert_eq!(rep.steps, rc.total_master_steps());
    assert!(!rep.diverged);
    assert_eq!((rep.workers_joined, rep.workers_left), (1, 1));
    let j0 = real_async::synthetic_loss(
        &real_async::synthetic_theta0(k),
        &real_async::synthetic_curvature(k),
    );
    assert!(rep.final_test_loss < j0, "loss {} vs initial {j0}", rep.final_test_loss);
    srv.stop();
}

// ------------------------------------------------- raw wire test rig

struct RawConn {
    r: BufReader<TcpStream>,
    w: BufWriter<TcpStream>,
    slot: u64,
    gen: u32,
}

impl RawConn {
    fn open(addr: &SocketAddr, role: Role) -> RawConn {
        Self::open_with(addr, role, false)
    }

    fn open_with(addr: &SocketAddr, role: Role, reattach: bool) -> RawConn {
        let s = TcpStream::connect(addr).unwrap();
        let mut conn = RawConn {
            r: BufReader::new(s.try_clone().unwrap()),
            w: BufWriter::new(s),
            slot: u64::MAX,
            gen: 0,
        };
        match conn.req(&Msg::Hello { role, reattach, encoding: Encoding::None }) {
            Msg::HelloAck { slot, gen, .. } => {
                conn.slot = slot;
                conn.gen = gen;
            }
            other => panic!("handshake failed: {other:?}"),
        }
        conn
    }

    fn req(&mut self, m: &Msg) -> Msg {
        write_frame(&mut self.w, m).unwrap();
        read_frame(&mut self.r).unwrap()
    }

    fn pull(&mut self) -> Vec<f32> {
        match self.req(&Msg::PullParams) {
            Msg::Params { params, .. } => params,
            other => panic!("pull failed: {other:?}"),
        }
    }

    fn push_ok(&mut self, g: &[f32]) {
        let gen = self.gen;
        match self.req(&Msg::Push { gen, msg: g.to_vec() }) {
            Msg::PushAck { .. } => {}
            other => panic!("push failed: {other:?}"),
        }
    }
}

fn wait_for_live(ctl: &mut RawConn, want: u64) {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    loop {
        if let Msg::Ack { header } = ctl.req(&Msg::Status) {
            if header.live_workers == want {
                return;
            }
        }
        assert!(
            std::time::Instant::now() < deadline,
            "server never reached {want} live workers"
        );
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
}

// ---------------------------------------------------------------- (2)

/// Abrupt disconnect (EOF, no Leave frame) = `remove_worker` under the
/// server's configured policy — the server state afterwards equals an
/// in-process replica driven through the identical op sequence, exactly.
#[test]
fn eof_disconnect_applies_the_configured_leave_policy() {
    let k = 8;
    let dir = tmpdir("eof");
    for policy in [LeavePolicy::Retire, LeavePolicy::Fold] {
        let c = cfg(AlgorithmKind::DanaZero, 3, 1.0, 1);
        let ckpt = dir.join(format!("{}.ckpt", policy.name()));
        let opts = ServeOptions {
            leave_policy: policy,
            checkpoint_path: Some(ckpt.clone()),
            checkpoint_every: 0,
            ..Default::default()
        };
        let mut srv = start_server(&c, k, opts);
        let addr = srv.addr();

        // in-process replica of the exact op sequence the server will see
        let mut replica = serve_master(&c, k);

        let mut workers: Vec<RawConn> =
            (0..3).map(|_| RawConn::open(&addr, Role::Worker)).collect();
        for (i, w) in workers.iter().enumerate() {
            assert_eq!(w.slot, i as u64, "slots assigned in connect order");
            assert_eq!(replica.add_worker(), i);
        }
        for _round in 0..2 {
            for i in 0..3 {
                let p = workers[i].pull();
                let q = replica.pull_params(i);
                assert_eq!(p, q, "{policy}: pull diverged");
                let g: Vec<f32> = p.iter().map(|&x| 0.1 * x + (i as f32 + 1.0) * 0.01).collect();
                workers[i].push_ok(&g);
                replica.push_update(i, &g).unwrap();
            }
        }
        // worker 1 vanishes without a Leave frame
        drop(workers.remove(1));
        let mut ctl = RawConn::open(&addr, Role::Control);
        wait_for_live(&mut ctl, 2);
        replica.remove_worker(1, policy).unwrap();

        assert!(matches!(ctl.req(&Msg::Checkpoint), Msg::Ack { .. }));
        let snap = checkpoint::read_snapshot(&ckpt).unwrap();
        assert_eq!(snap.live, vec![true, false, true], "{policy}");
        assert_eq!(
            snap,
            replica.snapshot().unwrap(),
            "{policy}: socket-side leave state != in-process remove_worker state"
        );
        dana_invariant(&snap);
        srv.stop();
    }
}

// ---------------------------------------------------------------- (4)

/// Straggler rejection: a retired slot's old connection keeps its stale
/// generation and every push from it bounces recoverably — while the
/// joiner that reused the slot trains on unharmed.
#[test]
fn stale_generation_pushes_are_rejected_recoverably() {
    let k = 4;
    let c = cfg(AlgorithmKind::DanaZero, 2, 1.0, 1);
    let mut srv = start_server(&c, k, ServeOptions::default());
    let addr = srv.addr();

    let mut a = RawConn::open(&addr, Role::Worker);
    assert_eq!(a.slot, 0);
    a.pull();
    a.push_ok(&[0.1; 4]);
    // deliberate leave with a per-departure policy override
    assert!(matches!(a.req(&Msg::Leave { policy: LeavePolicy::Fold }), Msg::Ack { .. }));

    // push after own leave: recoverable, not fatal, nothing applied
    let gen = a.gen;
    let mut ctl = RawConn::open(&addr, Role::Control);
    let (steps_before, drops_before) = match ctl.req(&Msg::Status) {
        Msg::Ack { header } => (header.master_step, header.pushes_dropped),
        other => panic!("{other:?}"),
    };
    assert_eq!(drops_before, 0, "no push dropped yet");
    match a.req(&Msg::Push { gen, msg: vec![0.5; 4] }) {
        Msg::Error { recoverable: true, .. } => {}
        other => panic!("expected recoverable rejection, got {other:?}"),
    }

    // a joiner reuses slot 0 with a bumped generation
    let mut b = RawConn::open(&addr, Role::Worker);
    assert_eq!(b.slot, 0, "lowest retired slot reused");
    assert!(b.gen > a.gen, "generation must advance on reuse");
    // the old incarnation still bounces
    match a.req(&Msg::Push { gen, msg: vec![0.5; 4] }) {
        Msg::Error { recoverable: true, .. } => {}
        other => panic!("expected recoverable rejection, got {other:?}"),
    }
    // push-before-pull is the same recoverable server error as in-process
    let bgen = b.gen;
    match b.req(&Msg::Push { gen: bgen, msg: vec![0.5; 4] }) {
        Msg::Error { recoverable: true, detail } => {
            assert!(detail.contains("before ever pulling"), "{detail}");
        }
        other => panic!("{other:?}"),
    }
    b.pull();
    b.push_ok(&[0.2; 4]);
    match ctl.req(&Msg::Status) {
        Msg::Ack { header } => {
            assert_eq!(header.master_step, steps_before + 1, "only the valid push applied");
            // ISSUE 5 satellite: dropped work is counted, not silent —
            // the two straggler pushes and the push-before-pull all
            // surface in the Status header
            assert_eq!(header.pushes_dropped, 3, "dropped pushes must be counted");
        }
        other => panic!("{other:?}"),
    }
    srv.stop();
}

/// Protocol misuse is rejected fatally (and never panics the server).
#[test]
fn server_rejects_protocol_abuse() {
    let k = 4;
    let c = cfg(AlgorithmKind::Asgd, 1, 1.0, 1);
    let mut srv = start_server(&c, k, ServeOptions::default());
    let addr = srv.addr();

    // first frame must be Hello
    {
        let s = TcpStream::connect(addr).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut w = BufWriter::new(s);
        write_frame(&mut w, &Msg::Status).unwrap();
        match read_frame(&mut r).unwrap() {
            Msg::Error { recoverable: false, detail } => {
                assert!(detail.contains("Hello"), "{detail}")
            }
            other => panic!("{other:?}"),
        }
    }
    // control-only requests on a worker connection
    let mut w = RawConn::open(&addr, Role::Worker);
    assert!(matches!(
        w.req(&Msg::Checkpoint),
        Msg::Error { recoverable: false, .. }
    ));
    // wrong-length push is a protocol error, not a recoverable drop
    let gen = w.gen;
    assert!(matches!(
        w.req(&Msg::Push { gen, msg: vec![0.0; k + 1] }),
        Msg::Error { recoverable: false, .. }
    ));
    // worker requests on a control connection
    let mut ctl = RawConn::open(&addr, Role::Control);
    assert!(matches!(
        ctl.req(&Msg::PullParams),
        Msg::Error { recoverable: false, .. }
    ));
    // the server survived all of it
    w.pull();
    w.push_ok(&[0.1; 4]);
    srv.stop();
}

// ---------------------------------------------------------------- (3)

/// pull → noisy grad → push, round-robin over 2 workers (the resume test
/// drives the reference and the remote master through this identically).
fn drive(m: &mut dyn Master, curv: &[f32], rng: &mut Rng, steps: usize) {
    let k = curv.len();
    let mut buf = vec![0.0f32; k];
    let mut g = vec![0.0f32; k];
    for step in 0..steps {
        let w = step % 2;
        m.pull_into(w, &mut buf);
        real_async::synthetic_grad(&buf, curv, rng, &mut g);
        m.push_update(w, &g).unwrap();
    }
}

fn dana_invariant(snap: &MasterSnapshot) {
    let v = match &snap.state.iter().find(|(n, _)| n == "v").expect("v entry").1 {
        StateVec::PerWorker(vs) => vs,
        other => panic!("v has wrong shape: {other:?}"),
    };
    let vsum = match &snap.state.iter().find(|(n, _)| n == "vsum").expect("vsum entry").1 {
        StateVec::Coord(s) => s,
        other => panic!("vsum has wrong shape: {other:?}"),
    };
    for j in 0..vsum.len() {
        let full: f32 = v.iter().map(|vi| vi[j]).sum();
        assert!(
            (vsum[j] - full).abs() < 2e-3 * (1.0 + full.abs()),
            "v0 invariant broken at coord {j}: {} vs {full}",
            vsum[j]
        );
    }
}

/// checkpoint → kill → resume → reconnect-as-join: the interrupted remote
/// run continues bit-for-bit against an uninterrupted in-process
/// reference, and the final full state (θ, vᶦ, v⁰, bookkeeping) is equal.
#[test]
fn checkpoint_kill_resume_reconnect_continues_bit_for_bit() {
    let k = 32;
    let c = cfg(AlgorithmKind::DanaZero, 2, 1.0, 1);
    let dir = tmpdir("resume");
    let ckpt = dir.join("server.ckpt");
    let opts = ServeOptions {
        leave_policy: LeavePolicy::Retire,
        checkpoint_path: Some(ckpt.clone()),
        checkpoint_every: 0,
        ..Default::default()
    };

    let mut srv = start_server(&c, k, opts.clone());
    let mut rm = RemoteMaster::connect(&srv.url(), 2).unwrap();

    // uninterrupted in-process reference over the same op sequence
    let mut reference = serve_master(&c, k);
    assert_eq!(reference.add_worker(), 0);
    assert_eq!(reference.add_worker(), 1);

    let curv = real_async::synthetic_curvature(k);
    let mut rng_ref = Rng::new(77);
    let mut rng_net = Rng::new(77);

    drive(&mut *reference, &curv, &mut rng_ref, 40);
    drive(&mut rm, &curv, &mut rng_net, 40);
    rm.force_checkpoint().unwrap();
    assert_eq!(checkpoint::read_snapshot(&ckpt).unwrap().master_step, 40);

    // hard kill: no final checkpoint, client connections go dead
    srv.stop();
    drop(srv);

    // resume into a fresh server on a fresh port
    let snap = checkpoint::read_snapshot(&ckpt).unwrap();
    let mut resumed = serve_master(&c, k);
    resumed.restore(&snap).unwrap();
    assert_eq!(resumed.steps_done(), 40);
    let mut srv2 = NetServer::start(resumed, "127.0.0.1:0", opts).unwrap();

    // reconnect-as-join: both workers re-attach to their old slots
    rm.reconnect_to(&srv2.url()).unwrap();
    assert_eq!(rm.server_slot(0), Some(0));
    assert_eq!(rm.server_slot(1), Some(1));

    drive(&mut *reference, &curv, &mut rng_ref, 40);
    drive(&mut rm, &curv, &mut rng_net, 40);

    assert_eq!(rm.steps_done(), 80);
    assert_eq!(
        rm.theta_vec(),
        reference.theta_vec(),
        "trajectory diverged across the kill/resume cycle"
    );
    // final full state equality + the DANA invariant
    rm.force_checkpoint().unwrap();
    let fin = checkpoint::read_snapshot(&ckpt).unwrap();
    assert_eq!(fin, reference.snapshot().unwrap());
    dana_invariant(&fin);
    srv2.stop();
}

/// After a resume, only *reattaching* workers may claim the checkpointed
/// live slots — a genuinely fresh join (churn) never inherits a departed
/// worker's momentum, even while resumed slots sit unclaimed.
#[test]
fn fresh_joins_never_inherit_resumed_slots() {
    let k = 8;
    let c = cfg(AlgorithmKind::DanaZero, 3, 1.0, 1);
    // build a snapshot with 3 live slots carrying momentum
    let mut src = serve_master(&c, k);
    for w in 0..3 {
        assert_eq!(src.add_worker(), w);
        src.pull_params(w);
        src.push_update(w, &vec![0.5; k]).unwrap();
    }
    let snap = src.snapshot().unwrap();
    let mut resumed = serve_master(&c, k);
    resumed.restore(&snap).unwrap();
    let mut srv = NetServer::start(resumed, "127.0.0.1:0", ServeOptions::default()).unwrap();
    let addr = srv.addr();

    // two workers reattach: lowest resumed slots, in order
    let a = RawConn::open_with(&addr, Role::Worker, true);
    let b = RawConn::open_with(&addr, Role::Worker, true);
    assert_eq!((a.slot, b.slot), (0, 1));
    // a fresh join must NOT be handed live slot 2 (and its momentum):
    // it appends a brand-new slot instead
    let c2 = RawConn::open_with(&addr, Role::Worker, false);
    assert_eq!(c2.slot, 3, "fresh join inherited a resumed slot");
    // a late reattacher still finds its slot
    let d = RawConn::open_with(&addr, Role::Worker, true);
    assert_eq!(d.slot, 2);
    drop((a, b, c2, d));
    srv.stop();
}

/// A graceful in-band Shutdown writes a final checkpoint, unblocks
/// `wait()`, and refuses new connections.
#[test]
fn graceful_shutdown_checkpoints_and_stops_accepting() {
    let k = 4;
    let c = cfg(AlgorithmKind::NagAsgd, 1, 1.0, 1);
    let dir = tmpdir("shutdown");
    let ckpt = dir.join("final.ckpt");
    let opts = ServeOptions {
        leave_policy: LeavePolicy::Retire,
        checkpoint_path: Some(ckpt.clone()),
        checkpoint_every: 0,
        ..Default::default()
    };
    let mut srv = start_server(&c, k, opts);
    let addr = srv.addr();
    let url = srv.url();

    let mut w = RawConn::open(&addr, Role::Worker);
    w.pull();
    w.push_ok(&[0.3; 4]);
    let mut ctl = RawConn::open(&addr, Role::Control);
    assert!(matches!(ctl.req(&Msg::Shutdown), Msg::Ack { .. }));
    srv.wait();

    let snap = checkpoint::read_snapshot(&ckpt).unwrap();
    assert_eq!(snap.master_step, 1, "shutdown checkpointed the final state");
    assert!(
        RemoteMaster::connect(&url, 1).is_err(),
        "a stopped server must refuse new clusters"
    );
}

/// Periodic checkpoints fire on the configured cadence.
#[test]
fn periodic_checkpoints_fire_every_n_steps() {
    let k = 4;
    let c = cfg(AlgorithmKind::Asgd, 1, 1.0, 1);
    let dir = tmpdir("periodic");
    let ckpt = dir.join("periodic.ckpt");
    let opts = ServeOptions {
        leave_policy: LeavePolicy::Retire,
        checkpoint_path: Some(ckpt.clone()),
        checkpoint_every: 5,
        ..Default::default()
    };
    let mut srv = start_server(&c, k, opts);
    let mut w = RawConn::open(&srv.addr(), Role::Worker);
    for _ in 0..12 {
        let p = w.pull();
        w.push_ok(&vec![0.1; p.len()]);
    }
    // 12 pushes → checkpoints at steps 5 and 10; the file holds step 10
    let snap = checkpoint::read_snapshot(&ckpt).unwrap();
    assert_eq!(snap.master_step, 10);
    srv.stop();
}

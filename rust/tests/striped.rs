//! Striped-serving equivalence suite (ISSUE 4 acceptance):
//!
//! 1. The lock-striped serving path (per-shard locks, ticket-ordered
//!    applies, no global mutex) is **bit-for-bit** identical to the PR 3
//!    global-lock serving path for all 10 algorithms × {mono, sharded
//!    S ∈ {2, 7, 16}} × {in-process, loopback TCP} — including under
//!    churn and through a checkpoint → kill → resume → reconnect cycle.
//! 2. Shard-sliced parameter traffic (`PullShard`/`PushShard` frames)
//!    assembles to exactly the monolithic-frame trajectories, on both
//!    serving backends.
//! 3. A many-thread hammer: concurrent clients pushing disjoint shards
//!    through real sockets leave the striped server in exactly the
//!    serial-FIFO state of its ticket order.
//! 4. Sliced-push protocol discipline: duplicate slices and interleaved
//!    requests fail closed; a dead connection's half-sent group is
//!    dropped, never half-applied.

use dana::config::{TrainConfig, Workload};
use dana::net::checkpoint;
use dana::net::wire::{read_frame, write_frame, Msg, Role};
use dana::net::{Encoding, NetServer, RemoteMaster, ServeOptions};
use dana::optim::{AlgorithmKind, LeavePolicy, LrSchedule, ScheduleConfig};
use dana::server::{make_master, make_serving_master, Master, ServingMaster, ShardedParameterServer};
use dana::sim::ChurnSchedule;
use dana::train::{real_async, sim_trainer};
use dana::util::rng::Rng;
use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;

fn cfg(kind: AlgorithmKind, workers: usize, epochs: f64, shards: usize) -> TrainConfig {
    let mut c = TrainConfig::preset(Workload::C10, kind, workers, epochs);
    c.seed = 47;
    // gap/lag metrics live server-side on a remote run; keep them off so
    // both sides of each comparison record nothing
    c.metrics_every = 0;
    c.shards = shards;
    c
}

fn schedule_of(c: &TrainConfig) -> LrSchedule {
    LrSchedule::new(c.schedule.clone())
}

/// A `dana serve` master for this config (zero slots: connect == join),
/// on the chosen serving backend.
fn start_backend(c: &TrainConfig, k: usize, striped: bool, opts: ServeOptions) -> NetServer {
    let master = make_serving_master(
        c.algorithm,
        &real_async::synthetic_theta0(k),
        schedule_of(c),
        0,
        c.shards,
        1,
        striped,
    );
    NetServer::start_serving(master, "127.0.0.1:0", opts).unwrap()
}

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("dana-striped-{name}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

// ---------------------------------------------------------------- (1)

/// Striped serving ≡ global-lock serving ≡ in-process, bit-for-bit, all
/// 10 algorithms × mono + sharded layouts.  Both serving backends host
/// the identical shard math, so even YellowFin's f64 tuner reductions
/// agree exactly.
#[test]
fn striped_serving_matches_global_lock_serving_all_kinds() {
    let k = 48;
    for kind in AlgorithmKind::ALL {
        for shards in [1usize, 2, 7, 16] {
            let c = cfg(kind, 3, 0.4, shards);
            let in_process = sim_trainer::run_synthetic(&c, k).unwrap();
            let mut reports = Vec::new();
            for striped in [false, true] {
                let mut srv = start_backend(&c, k, striped, ServeOptions::default());
                let mut rc = c.clone();
                rc.master_addr = Some(srv.url());
                reports.push(sim_trainer::run_synthetic(&rc, k).unwrap());
                srv.stop();
            }
            let (locked, striped) = (&reports[0], &reports[1]);
            assert_eq!(
                striped.final_test_loss, locked.final_test_loss,
                "{kind} S={shards}: striped vs global-lock final loss"
            );
            assert_eq!(
                striped.loss_curve, locked.loss_curve,
                "{kind} S={shards}: striped vs global-lock curve"
            );
            assert_eq!(striped.steps, locked.steps, "{kind} S={shards}");
            assert_eq!(
                striped.final_test_loss, in_process.final_test_loss,
                "{kind} S={shards}: wire vs in-process"
            );
            assert_eq!(striped.loss_curve, in_process.loss_curve, "{kind} S={shards}");
        }
    }
}

/// The config-plumbed sliced path (`--shard-frames` / JSON
/// `"shard_frames"`): a full trainer run over PullShard/PushShard frames
/// equals the monolithic-frame run bit-for-bit.
#[test]
fn config_enabled_shard_frames_match_monolithic_run() {
    let k = 48;
    let c = cfg(AlgorithmKind::DanaZero, 3, 0.4, 7);
    let base = sim_trainer::run_synthetic(&c, k).unwrap();
    let mut srv = start_backend(&c, k, true, ServeOptions::default());
    let mut rc = c.clone();
    rc.master_addr = Some(srv.url());
    rc.shard_frames = true;
    let remote = sim_trainer::run_synthetic(&rc, k).unwrap();
    assert_eq!(remote.final_test_loss, base.final_test_loss);
    assert_eq!(remote.loss_curve, base.loss_curve);
    srv.stop();
}

/// The pipelined driver against BOTH serving backends — including
/// shard-sliced frames on the striped path — reproduces the in-process
/// depth-D trajectory bit-for-bit (`--pipeline-depth` composes with
/// `--shards` and `--shard-frames`).
#[test]
fn pipelined_driver_matches_on_both_backends_with_sliced_frames() {
    let k = 45; // not divisible by 7: uneven shard lengths on the wire
    let depth = 1;
    for kind in [AlgorithmKind::DanaZero, AlgorithmKind::DcAsgd] {
        let mut c = cfg(kind, 3, 0.5, 7);
        c.pipeline_depth = depth;
        let in_process = sim_trainer::run_synthetic(&c, k).unwrap();
        for striped in [false, true] {
            for sliced in [false, true] {
                let opts = ServeOptions { pipeline_depth: depth, ..Default::default() };
                let mut srv = start_backend(&c, k, striped, opts);
                let mut rc = c.clone();
                rc.master_addr = Some(srv.url());
                rc.shard_frames = sliced;
                let remote = sim_trainer::run_synthetic(&rc, k).unwrap();
                assert_eq!(
                    remote.final_test_loss, in_process.final_test_loss,
                    "{kind} striped={striped} sliced={sliced}: pipelined trajectory"
                );
                assert_eq!(
                    remote.loss_curve, in_process.loss_curve,
                    "{kind} striped={striped} sliced={sliced}"
                );
                srv.stop();
            }
        }
    }
}

/// Same equivalence with cluster churn flowing through real sockets:
/// joins/leaves fan across all shards atomically under the epoch lock.
#[test]
fn striped_serving_matches_under_churn() {
    let k = 64;
    for kind in [AlgorithmKind::DanaZero, AlgorithmKind::Easgd] {
        let mut c = cfg(kind, 4, 1.0, 7);
        c.churn = ChurnSchedule::parse("leave@0.3:2,join@0.5,leave@0.6,join@0.8").unwrap();
        let base = sim_trainer::run_synthetic(&c, k).unwrap();
        for striped in [false, true] {
            let mut srv = start_backend(&c, k, striped, ServeOptions::default());
            let mut rc = c.clone();
            rc.master_addr = Some(srv.url());
            let remote = sim_trainer::run_synthetic(&rc, k).unwrap();
            assert_eq!(
                remote.final_test_loss, base.final_test_loss,
                "{kind} striped={striped}: churn trajectory"
            );
            assert_eq!(remote.loss_curve, base.loss_curve, "{kind} striped={striped}");
            assert_eq!(
                (remote.workers_joined, remote.workers_left),
                (base.workers_joined, base.workers_left),
                "{kind} striped={striped}"
            );
            srv.stop();
        }
    }
}

/// In-process: the concurrent `&self` API and the `&mut self` [`Master`]
/// trait are the same machine — serial driving is bit-for-bit.
#[test]
fn concurrent_api_matches_master_trait_serially() {
    let k = 31;
    let theta0: Vec<f32> = (0..k).map(|i| (i as f32 * 0.23).sin()).collect();
    let sched = || {
        LrSchedule::new(ScheduleConfig {
            steps_per_epoch: 10,
            n_workers: 2,
            ..ScheduleConfig::default()
        })
    };
    for kind in AlgorithmKind::ALL {
        let shared = ShardedParameterServer::new(kind, &theta0, sched(), 2, 5);
        let mut owned = ShardedParameterServer::new(kind, &theta0, sched(), 2, 5);
        let mut rng = Rng::new(13);
        for step in 0..40 {
            let w = step % 2;
            let a = shared.pull_concurrent(w).unwrap();
            let b = owned.pull(w);
            assert_eq!(a, b, "{kind} step {step}: pulls diverged");
            let g: Vec<f32> = (0..k).map(|_| rng.normal() as f32 * 0.1).collect();
            let sa = shared.push_concurrent(w, &g).unwrap();
            let sb = owned.push(w, &g).unwrap();
            assert_eq!(sa, sb, "{kind} step {step}: applied steps diverged");
        }
        assert_eq!(shared.theta_vec(), owned.theta_vec(), "{kind}");
        assert_eq!(
            shared.snapshot_concurrent().unwrap(),
            owned.snapshot_concurrent().unwrap(),
            "{kind}: full state diverged"
        );
    }
}

// ---------------------------------------------------------------- (2)

/// pull → noisy grad → push over 2 workers (shared with the resume test).
fn drive(m: &mut dyn Master, curv: &[f32], rng: &mut Rng, steps: usize) {
    let k = curv.len();
    let mut buf = vec![0.0f32; k];
    let mut g = vec![0.0f32; k];
    for step in 0..steps {
        let w = step % 2;
        m.pull_into(w, &mut buf);
        real_async::synthetic_grad(&buf, curv, rng, &mut g);
        m.push_update(w, &g).unwrap();
    }
}

/// Shard-sliced frames ≡ monolithic frames, against both backends.
#[test]
fn sliced_frames_match_monolithic_frames_bit_for_bit() {
    let k = 45; // not divisible by 7: uneven shard lengths on the wire
    for striped in [false, true] {
        let c = cfg(AlgorithmKind::DanaDc, 2, 1.0, 7);
        let mut srv_a = start_backend(&c, k, striped, ServeOptions::default());
        let mut srv_b = start_backend(&c, k, striped, ServeOptions::default());
        let mut full = RemoteMaster::connect(&srv_a.url(), 2).unwrap();
        let mut sliced = RemoteMaster::connect(&srv_b.url(), 2).unwrap();
        assert_eq!(sliced.server_shards(), 7);
        sliced.set_shard_frames(true);
        let curv = real_async::synthetic_curvature(k);
        let (mut rng_a, mut rng_b) = (Rng::new(5), Rng::new(5));
        let mut buf_a = vec![0.0f32; k];
        let mut buf_b = vec![0.0f32; k];
        for step in 0..60 {
            let w = step % 2;
            full.pull_into(w, &mut buf_a);
            sliced.pull_into(w, &mut buf_b);
            assert_eq!(buf_a, buf_b, "striped={striped} step {step}: pulls diverged");
            let mut ga = vec![0.0f32; k];
            let mut gb = vec![0.0f32; k];
            real_async::synthetic_grad(&buf_a, &curv, &mut rng_a, &mut ga);
            real_async::synthetic_grad(&buf_b, &curv, &mut rng_b, &mut gb);
            let sa = full.push_update(w, &ga).unwrap();
            let sb = sliced.push_update(w, &gb).unwrap();
            assert_eq!(sa, sb, "striped={striped} step {step}: applied steps diverged");
        }
        assert_eq!(
            full.theta_vec(),
            sliced.theta_vec(),
            "striped={striped}: final parameters diverged"
        );
        assert_eq!(full.steps_done(), sliced.steps_done());
        srv_a.stop();
        srv_b.stop();
    }
}

// ---------------------------------------------------------------- (1c)

/// checkpoint → kill → resume → reconnect on the striped backend, with a
/// shard-sliced client, continues bit-for-bit against an uninterrupted
/// in-process reference of the same shard layout.
#[test]
fn checkpoint_kill_resume_reconnect_on_striped_backend() {
    let k = 32;
    let c = cfg(AlgorithmKind::DanaZero, 2, 1.0, 7);
    let dir = tmpdir("resume");
    let ckpt = dir.join("striped.ckpt");
    let opts = ServeOptions {
        leave_policy: LeavePolicy::Retire,
        checkpoint_path: Some(ckpt.clone()),
        checkpoint_every: 0,
        ..Default::default()
    };

    let mut srv = start_backend(&c, k, true, opts.clone());
    let mut rm = RemoteMaster::connect(&srv.url(), 2).unwrap();
    rm.set_shard_frames(true);

    // uninterrupted in-process reference over the same op sequence
    let mut reference = make_master(
        c.algorithm,
        &real_async::synthetic_theta0(k),
        schedule_of(&c),
        0,
        c.shards,
        1,
    );
    assert_eq!(reference.add_worker(), 0);
    assert_eq!(reference.add_worker(), 1);

    let curv = real_async::synthetic_curvature(k);
    let mut rng_ref = Rng::new(91);
    let mut rng_net = Rng::new(91);

    drive(&mut *reference, &curv, &mut rng_ref, 40);
    drive(&mut rm, &curv, &mut rng_net, 40);
    rm.force_checkpoint().unwrap();
    assert_eq!(checkpoint::read_snapshot(&ckpt).unwrap().master_step, 40);

    // hard kill: no final checkpoint, client connections go dead
    srv.stop();
    drop(srv);

    // resume into a fresh striped server on a fresh port
    let snap = checkpoint::read_snapshot(&ckpt).unwrap();
    let mut resumed = make_serving_master(
        c.algorithm,
        &real_async::synthetic_theta0(k),
        schedule_of(&c),
        0,
        c.shards,
        1,
        true,
    );
    resumed.restore(&snap).unwrap();
    let mut srv2 = NetServer::start_serving(resumed, "127.0.0.1:0", opts).unwrap();

    // reconnect-as-join: both workers re-attach to their old slots
    rm.reconnect_to(&srv2.url()).unwrap();
    assert_eq!(rm.server_slot(0), Some(0));
    assert_eq!(rm.server_slot(1), Some(1));

    drive(&mut *reference, &curv, &mut rng_ref, 40);
    drive(&mut rm, &curv, &mut rng_net, 40);

    assert_eq!(rm.steps_done(), 80);
    assert_eq!(
        rm.theta_vec(),
        reference.theta_vec(),
        "trajectory diverged across the kill/resume cycle"
    );
    // final full state equality (θ, vᶦ, v⁰, bookkeeping)
    rm.force_checkpoint().unwrap();
    let fin = checkpoint::read_snapshot(&ckpt).unwrap();
    assert_eq!(fin, reference.snapshot().unwrap());
    srv2.stop();
}

// ---------------------------------------------------------------- (3)

/// Many-thread hammer through real sockets: clients (half sliced, half
/// monolithic) concurrently push IDENTICAL messages; the ticket gates
/// make any interleaving equal to the serial trajectory bit-for-bit
/// (identical messages ⇒ the per-step float ops don't depend on which
/// client lands which ticket).
#[test]
fn hammer_concurrent_clients_equal_serial_fifo() {
    let k = 53;
    let c = cfg(AlgorithmKind::Asgd, 6, 1.0, 8);
    let srv = start_backend(&c, k, true, ServeOptions::default());
    let url = srv.url();
    let clients = 6usize;
    let per = 30usize;
    let g = vec![0.004f32; k];
    std::thread::scope(|s| {
        for t in 0..clients {
            let url = url.clone();
            let g = &g;
            s.spawn(move || {
                let mut rm = RemoteMaster::connect(&url, 1).unwrap();
                rm.set_shard_frames(t % 2 == 0);
                let mut buf = vec![0.0f32; k];
                rm.pull_into(0, &mut buf);
                for _ in 0..per {
                    rm.push_update(0, g).unwrap();
                }
                // leave deliberately so the scope can't hang on EOF races
                rm.remove_worker(0, LeavePolicy::Retire).unwrap();
            });
        }
    });
    assert_eq!(srv.steps_done(), (clients * per) as u64);

    // serial replica: same push count, same message, same schedule
    let mut serial = ShardedParameterServer::new(
        c.algorithm,
        &real_async::synthetic_theta0(k),
        schedule_of(&c),
        1,
        8,
    );
    serial.pull(0);
    for _ in 0..clients * per {
        serial.push(0, &g).unwrap();
    }
    // read the final parameters over the wire, then stop
    let mut ctl = RemoteMaster::connect(&url, 0).unwrap();
    assert_eq!(ctl.theta_vec(), serial.theta_vec(), "hammer diverged from serial FIFO");
    drop(ctl);
    drop(srv);
}

// ---------------------------------------------------------------- (4)

struct RawConn {
    r: BufReader<TcpStream>,
    w: BufWriter<TcpStream>,
    gen: u32,
}

impl RawConn {
    fn open(addr: &SocketAddr, role: Role) -> RawConn {
        let s = TcpStream::connect(addr).unwrap();
        let mut conn = RawConn {
            r: BufReader::new(s.try_clone().unwrap()),
            w: BufWriter::new(s),
            gen: 0,
        };
        match conn.req(&Msg::Hello { role, reattach: false, encoding: Encoding::None }) {
            Msg::HelloAck { gen, .. } => conn.gen = gen,
            other => panic!("handshake failed: {other:?}"),
        }
        conn
    }

    fn req(&mut self, m: &Msg) -> Msg {
        write_frame(&mut self.w, m).unwrap();
        read_frame(&mut self.r).unwrap()
    }
}

/// Sliced-push discipline: duplicate slices and interleaved worker
/// requests fail closed (dropping the half-built group), and a half-sent
/// group dies with its connection — never half-applied.
#[test]
fn sliced_push_protocol_fails_closed() {
    let k = 12;
    let c = cfg(AlgorithmKind::Asgd, 2, 1.0, 3);
    let srv = start_backend(&c, k, true, ServeOptions::default());
    let addr = srv.addr();
    let slice0 = vec![0.1f32; 4]; // shard_bounds(12, 3) = three slices of 4

    let mut w = RawConn::open(&addr, Role::Worker);
    // must pull before pushing, like any worker
    for shard in 0..3u32 {
        assert!(matches!(w.req(&Msg::PullShard { shard }), Msg::ShardParams { .. }));
    }
    // wrong-length slice: fatal
    let gen = w.gen;
    assert!(matches!(
        w.req(&Msg::PushShard { gen, shard: 0, msg: vec![0.1; 5] }),
        Msg::Error { recoverable: false, .. }
    ));
    let slice_req = |w: &mut RawConn, shard: u32| {
        w.req(&Msg::PushShard { gen, shard, msg: slice0.clone() })
    };
    // duplicate slice in one group: fatal, group dropped
    assert!(matches!(slice_req(&mut w, 0), Msg::Ack { .. }));
    assert!(matches!(slice_req(&mut w, 0), Msg::Error { recoverable: false, .. }));
    // interleaving a full Push into an open group: fatal, group dropped
    assert!(matches!(slice_req(&mut w, 1), Msg::Ack { .. }));
    assert!(matches!(
        w.req(&Msg::Push { gen, msg: vec![0.1; k] }),
        Msg::Error { recoverable: false, .. }
    ));
    assert_eq!(srv.steps_done(), 0, "no partial group may apply");
    // a clean complete group still applies afterwards
    assert!(matches!(slice_req(&mut w, 0), Msg::Ack { .. }));
    assert!(matches!(slice_req(&mut w, 2), Msg::Ack { .. }));
    assert!(matches!(slice_req(&mut w, 1), Msg::PushAck { .. }));
    assert_eq!(srv.steps_done(), 1);

    // a second worker abandons a group mid-flight: dropped with the conn
    let mut dying = RawConn::open(&addr, Role::Worker);
    for shard in 0..3u32 {
        assert!(matches!(dying.req(&Msg::PullShard { shard }), Msg::ShardParams { .. }));
    }
    let dgen = dying.gen;
    assert!(matches!(
        dying.req(&Msg::PushShard { gen: dgen, shard: 0, msg: slice0.clone() }),
        Msg::Ack { .. }
    ));
    drop(dying); // EOF with one slice buffered
    // give the server a moment to process the disconnect
    let mut ctl = RawConn::open(&addr, Role::Control);
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    loop {
        if let Msg::Ack { header } = ctl.req(&Msg::Status) {
            if header.live_workers == 1 {
                break;
            }
        }
        assert!(std::time::Instant::now() < deadline, "leave never processed");
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert_eq!(srv.steps_done(), 1, "abandoned group must not apply");
    drop(srv);
}

//! Wire-protocol property suite (ISSUE 3 satellite, extended for the v2
//! shard-sliced frames in ISSUE 4 and the v4 encoded payloads in ISSUE
//! 7): encode/decode round-trips for every message type — including
//! empty and huge payloads — and *rejection* (never a panic) of
//! truncated frames, bad magic, bad versions, oversized length prefixes,
//! unknown tags, and trailing bytes; plus the encode-side symmetry:
//! `write_frame` refuses an over-cap body before serializing, instead of
//! letting the `u32` length prefix truncate.  The v4 additions pin the
//! encoded-payload frames byte-for-byte (none/f16/bf16/top-k) and the
//! payload decoder's fail-closed posture against malformed compression.

use dana::net::codec::{self, Encoding};
use dana::net::wire::{read_frame, write_frame, Header, Msg, Role, MAGIC, MAX_FRAME, VERSION};
use dana::optim::{AlgorithmKind, ApplyStats, LeavePolicy};
use std::io::Cursor;

fn sample_header() -> Header {
    Header {
        master_step: 123_456_789_012,
        eta: 0.0125,
        gamma: 0.9,
        lambda: 1.0,
        live_workers: 7,
        worker_slots: 9,
        pushes_dropped: 3,
        epoch: 5,
        shard_start: 4,
        shard_hosted: 12,
        total_shards: 16,
        standby: 0,
    }
}

/// One instance of every message variant, with assorted payload sizes.
fn all_messages() -> Vec<Msg> {
    let h = sample_header();
    let mut msgs = vec![
        Msg::Hello { role: Role::Worker, reattach: false, encoding: Encoding::None },
        Msg::Hello { role: Role::Worker, reattach: true, encoding: Encoding::F16 },
        Msg::Hello { role: Role::Worker, reattach: false, encoding: Encoding::Bf16 },
        Msg::Hello { role: Role::Worker, reattach: true, encoding: Encoding::TopK { k: 777 } },
        Msg::Hello { role: Role::Control, reattach: false, encoding: Encoding::None },
        Msg::PullParams,
        Msg::Push { gen: 0, msg: vec![] },
        Msg::Push { gen: u32::MAX, msg: vec![f32::MIN, -0.0, 0.0, f32::MAX, 1.5e-42] },
        Msg::Leave { policy: LeavePolicy::Retire },
        Msg::Leave { policy: LeavePolicy::Fold },
        Msg::Checkpoint,
        Msg::Status,
        Msg::GetTheta,
        Msg::Shutdown,
        Msg::PullShard { shard: 0 },
        Msg::PullShard { shard: u32::MAX },
        Msg::PushShard { gen: 0, shard: 0, msg: vec![] },
        Msg::PushShard { gen: 9, shard: 6, msg: vec![-1.5, 0.25, f32::MAX] },
        // v5 two-phase cluster apply: stage (read-only partials) + commit
        Msg::PushStage { gen: 0, msg: vec![] },
        Msg::PushStage { gen: 4, msg: vec![0.25, -1.0, f32::MIN] },
        Msg::PushCommit { gen: 0, stats: ApplyStats::default(), msg: vec![] },
        Msg::PushCommit {
            gen: 11,
            stats: ApplyStats {
                msg_norm2: 1.5e300,
                g_avg_norm2: -0.0,
                prev_dot: f64::MIN_POSITIVE,
                prev_norm2: 42.0,
            },
            msg: vec![1.0, 2.0, 3.0],
        },
        Msg::StageStats {
            header: h,
            stats: ApplyStats {
                msg_norm2: 0.5,
                g_avg_norm2: 0.25,
                prev_dot: -3.0,
                prev_norm2: 9.0,
            },
        },
        Msg::HelloAck {
            slot: u64::MAX,
            gen: 7,
            kind: AlgorithmKind::DanaSlim,
            k: 101_386,
            shards: 16,
            pipeline: 2,
            encodings: 0b1111,
            header: h,
        },
        Msg::Params { header: h, params: vec![] },
        Msg::Params { header: h, params: (0..257).map(|i| (i as f32 * 0.7).sin()).collect() },
        Msg::ShardParams { header: h, shard: 3, params: vec![0.5; 11] },
        Msg::ShardParams { header: h, shard: 0, params: vec![] },
        Msg::PushAck { header: h, step: 123_456_789_011, eta: 0.05, gamma: 0.9, lambda: 2.0 },
        Msg::Ack { header: h },
        // a standby's probe answer: flag set, extreme epoch
        Msg::Ack { header: Header { standby: 1, epoch: u64::MAX, ..h } },
        Msg::Theta { header: h, theta: vec![1.0; 3] },
        Msg::Error { recoverable: true, detail: String::new() },
        Msg::Error { recoverable: false, detail: "straggler push for slot 3 (gen 2 != 5)".into() },
    ];
    for kind in AlgorithmKind::ALL {
        msgs.push(Msg::HelloAck {
            slot: 0,
            gen: 1,
            kind,
            k: 16,
            shards: 1,
            pipeline: 0,
            encodings: 0b0001,
            header: h,
        });
    }
    // huge payload: ~1.2 MB of parameters round-trips bit-exactly
    let huge: Vec<f32> = (0..300_000).map(|i| (i as f32).to_bits() as f32 * 1e-30).collect();
    msgs.push(Msg::Push { gen: 3, msg: huge.clone() });
    msgs.push(Msg::Theta { header: h, theta: huge });
    msgs
}

#[test]
fn every_message_round_trips_through_a_stream() {
    // all messages written back-to-back on one stream, read back in order
    let msgs = all_messages();
    let mut buf = Vec::new();
    for m in &msgs {
        write_frame(&mut buf, m).unwrap();
    }
    let mut cur = Cursor::new(buf);
    for want in &msgs {
        let got = read_frame(&mut cur).unwrap();
        assert_eq!(&got, want);
    }
    // clean EOF afterwards is an error (there is no frame to read)
    assert!(read_frame(&mut cur).is_err());
}

#[test]
fn f32_payloads_are_bit_exact() {
    // NaNs and denormals survive the trip with their exact bit patterns
    let weird = vec![
        f32::NAN,
        f32::from_bits(0x7FC0_1234), // payload-carrying NaN
        f32::from_bits(0x0000_0001), // smallest denormal
        f32::NEG_INFINITY,
    ];
    let mut buf = Vec::new();
    write_frame(&mut buf, &Msg::Push { gen: 0, msg: weird.clone() }).unwrap();
    match read_frame(&mut Cursor::new(buf)).unwrap() {
        Msg::Push { msg, .. } => {
            assert_eq!(msg.len(), weird.len());
            for (a, b) in msg.iter().zip(&weird) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        other => panic!("wrong message back: {other:?}"),
    }
}

#[test]
fn truncation_at_every_length_is_rejected() {
    for m in all_messages() {
        let frame = m.encode();
        if frame.len() > 4096 {
            continue; // truncating the huge payloads at every byte is slow
        }
        for cut in 0..frame.len() {
            let mut cur = Cursor::new(&frame[..cut]);
            assert!(
                read_frame(&mut cur).is_err(),
                "truncated frame (cut={cut}/{}) must be rejected: {m:?}",
                frame.len()
            );
        }
    }
}

#[test]
fn bad_magic_and_version_are_rejected() {
    let frame = Msg::PullParams.encode();
    // body starts after the 4-byte length prefix
    for i in 0..MAGIC.len() {
        let mut bad = frame.clone();
        bad[4 + i] ^= 0xFF;
        assert!(read_frame(&mut Cursor::new(bad)).is_err(), "magic byte {i}");
    }
    let mut bad_version = frame.clone();
    bad_version[4 + MAGIC.len()] = VERSION + 1;
    let err = read_frame(&mut Cursor::new(bad_version)).unwrap_err();
    assert!(err.to_string().contains("version"), "{err}");
}

#[test]
fn oversized_length_prefix_is_rejected_before_allocation() {
    // a length prefix over the cap must error out without trying to read
    // (or allocate) the body
    let mut frame = Vec::new();
    frame.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
    let err = read_frame(&mut Cursor::new(frame)).unwrap_err();
    assert!(err.to_string().contains("exceeds cap"), "{err}");
    // undersized: shorter than the fixed header
    let mut tiny = Vec::new();
    tiny.extend_from_slice(&3u32.to_le_bytes());
    tiny.extend_from_slice(&[0, 0, 0]);
    assert!(read_frame(&mut Cursor::new(tiny)).is_err());
}

#[test]
fn inner_count_beyond_frame_is_rejected() {
    // a Push whose f32 count claims more elements than the frame holds
    let mut body = Vec::new();
    body.extend_from_slice(&MAGIC);
    body.push(VERSION);
    body.push(3); // Push tag
    body.extend_from_slice(&0u32.to_le_bytes()); // gen
    body.push(0); // payload encoding: none
    body.extend_from_slice(&(u64::MAX).to_le_bytes()); // absurd count
    let err = Msg::decode(&body).unwrap_err();
    assert!(
        err.to_string().contains("overflow") || err.to_string().contains("truncated"),
        "{err}"
    );
}

#[test]
fn unknown_tag_role_and_names_are_rejected() {
    let make = |tag: u8, payload: &[u8]| {
        let mut body = Vec::new();
        body.extend_from_slice(&MAGIC);
        body.push(VERSION);
        body.push(tag);
        body.extend_from_slice(payload);
        body
    };
    assert!(Msg::decode(&make(99, &[])).is_err(), "unknown tag");
    assert!(Msg::decode(&make(1, &[7, 0])).is_err(), "unknown role");
    assert!(Msg::decode(&make(1, &[0])).is_err(), "hello without the reattach byte");
    assert!(Msg::decode(&make(1, &[0, 0])).is_err(), "hello without the encoding");
    assert!(
        Msg::decode(&make(1, &[0, 0, 9, 0, 0, 0, 0])).is_err(),
        "hello with an unknown encoding tag"
    );
    assert!(
        Msg::decode(&make(1, &[0, 0, 3, 0, 0, 0, 0])).is_err(),
        "hello requesting top-k with k = 0"
    );
    // Leave with an unknown policy name
    let mut p = Vec::new();
    p.extend_from_slice(&4u32.to_le_bytes());
    p.extend_from_slice(b"meld");
    assert!(Msg::decode(&make(4, &p)).is_err(), "unknown policy");
    // HelloAck with an unknown algorithm name fails closed
    let mut h = Vec::new();
    h.extend_from_slice(&0u64.to_le_bytes()); // slot
    h.extend_from_slice(&0u32.to_le_bytes()); // gen
    h.extend_from_slice(&9u32.to_le_bytes());
    h.extend_from_slice(b"quantum-9");
    assert!(Msg::decode(&make(16, &h)).is_err(), "unknown algorithm");
}

#[test]
fn trailing_bytes_are_rejected() {
    for m in [Msg::PullParams, Msg::Status, Msg::Push { gen: 1, msg: vec![1.0, 2.0] }] {
        let mut frame = m.encode();
        // graft one extra byte into the body and fix up the length prefix
        frame.push(0xAB);
        let new_len = (frame.len() - 4) as u32;
        frame[..4].copy_from_slice(&new_len.to_le_bytes());
        let err = read_frame(&mut Cursor::new(frame)).unwrap_err();
        assert!(err.to_string().contains("trailing"), "{m:?}: {err}");
    }
}

#[test]
fn body_len_matches_the_encoder_for_every_message() {
    // write_frame's oversize rejection is only sound if the arithmetic
    // body_len agrees with what encode actually produces
    for m in all_messages() {
        assert_eq!(m.encode().len(), 4 + m.body_len(), "{m:?}");
    }
}

#[test]
fn oversize_encode_is_rejected_before_serialization() {
    // A payload whose frame body would exceed MAX_FRAME: the u32 length
    // prefix would silently truncate it without the encode-side guard.
    // (The vec is zero-initialized — the allocator maps it lazily and
    // write_frame must refuse before ever touching the data.)
    let n = MAX_FRAME as usize / 4;
    type Make = fn(Vec<f32>) -> Msg;
    let cases: [Make; 3] = [
        |v| Msg::Push { gen: 1, msg: v },
        |v| Msg::PushShard { gen: 1, shard: 0, msg: v },
        |v| Msg::Theta { header: sample_header(), theta: v },
    ];
    for make in cases {
        // one lazily-mapped buffer at a time; never cloned, never read
        let msg = make(vec![0.0f32; n]);
        assert!(msg.body_len() > MAX_FRAME as usize, "test premise");
        let mut sink = Vec::new();
        let err = write_frame(&mut sink, &msg).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err}");
        assert!(sink.is_empty(), "nothing may reach the wire");
    }
    // symmetric with the decoder: an under-cap frame still flows
    let ok = Msg::Push { gen: 1, msg: vec![0.0; 64] };
    let mut sink = Vec::new();
    write_frame(&mut sink, &ok).unwrap();
    assert_eq!(read_frame(&mut Cursor::new(sink)).unwrap(), ok);
}

/// Pin the v4 encoded `Push` frames byte-for-byte: the hand-built
/// expected bytes, the `Msg` encoder (encoding `none` only), and the
/// borrowed-slice `codec::write_push` writer must all agree — and the
/// decoder must densify each back to the same `Vec<f32>`.
#[test]
fn v4_encoded_push_frames_are_pinned_byte_for_byte() {
    let push_frame = |payload: &[u8]| {
        let mut body = Vec::new();
        body.extend_from_slice(&MAGIC);
        body.push(VERSION);
        body.push(3); // Push tag
        body.extend_from_slice(&7u32.to_le_bytes()); // gen
        body.extend_from_slice(payload);
        let mut frame = (body.len() as u32).to_le_bytes().to_vec();
        frame.extend_from_slice(&body);
        frame
    };

    // none: payload tag 0 + u64 count + f32 LE words
    let vals = [1.0f32, -2.5];
    let mut p = vec![0u8];
    p.extend_from_slice(&2u64.to_le_bytes());
    p.extend_from_slice(&1.0f32.to_le_bytes());
    p.extend_from_slice(&(-2.5f32).to_le_bytes());
    let frame = push_frame(&p);
    assert_eq!(Msg::Push { gen: 7, msg: vals.to_vec() }.encode(), frame);
    let mut sink = Vec::new();
    codec::write_push(&mut sink, 7, Encoding::None, &vals).unwrap();
    assert_eq!(sink, frame, "borrowed-slice writer must match the Msg encoder");

    // f16: payload tag 1 + u64 count + 2-byte halves
    // (1.0 = 0x3C00, -2.5 = 0xC100 — both exactly representable)
    let mut p = vec![1u8];
    p.extend_from_slice(&2u64.to_le_bytes());
    p.extend_from_slice(&0x3C00u16.to_le_bytes());
    p.extend_from_slice(&0xC100u16.to_le_bytes());
    let frame = push_frame(&p);
    let mut sink = Vec::new();
    codec::write_push(&mut sink, 7, Encoding::F16, &vals).unwrap();
    assert_eq!(sink, frame);
    match read_frame(&mut Cursor::new(frame)).unwrap() {
        Msg::Push { gen, msg } => {
            assert_eq!(gen, 7);
            assert_eq!(msg, vals.to_vec());
        }
        other => panic!("wrong message back: {other:?}"),
    }

    // bf16: payload tag 2 + u64 count + truncated-rounded high halves
    // (1.0 = 0x3F80, -2.5 = 0xC020)
    let mut p = vec![2u8];
    p.extend_from_slice(&2u64.to_le_bytes());
    p.extend_from_slice(&0x3F80u16.to_le_bytes());
    p.extend_from_slice(&0xC020u16.to_le_bytes());
    let frame = push_frame(&p);
    let mut sink = Vec::new();
    codec::write_push(&mut sink, 7, Encoding::Bf16, &vals).unwrap();
    assert_eq!(sink, frame);

    // top-k: payload tag 3 + u64 full + u64 nnz + ascending u32 indices
    // + f32 values (zeros never serialized)
    let sparse = [0.0f32, 3.0, 0.0, -4.0];
    let mut p = vec![3u8];
    p.extend_from_slice(&4u64.to_le_bytes());
    p.extend_from_slice(&2u64.to_le_bytes());
    p.extend_from_slice(&1u32.to_le_bytes());
    p.extend_from_slice(&3u32.to_le_bytes());
    p.extend_from_slice(&3.0f32.to_le_bytes());
    p.extend_from_slice(&(-4.0f32).to_le_bytes());
    let frame = push_frame(&p);
    let mut sink = Vec::new();
    codec::write_push(&mut sink, 7, Encoding::TopK { k: 2 }, &sparse).unwrap();
    assert_eq!(sink, frame);
    match read_frame(&mut Cursor::new(frame)).unwrap() {
        Msg::Push { msg, .. } => assert_eq!(msg, sparse.to_vec(), "densified exactly once"),
        other => panic!("wrong message back: {other:?}"),
    }
}

/// The payload decoder's fail-closed posture: every malformed encoded
/// payload is rejected with an error (never a panic, never a partial
/// vector) — unknown tag, length mismatch, NaN-bearing halves, and the
/// top-k index abuses.
#[test]
fn v4_payload_decoder_fails_closed() {
    let push_body = |payload: &[u8]| {
        let mut body = Vec::new();
        body.extend_from_slice(&MAGIC);
        body.push(VERSION);
        body.push(3); // Push tag
        body.extend_from_slice(&0u32.to_le_bytes()); // gen
        body.extend_from_slice(payload);
        body
    };
    // unknown payload encoding tag
    let mut p = vec![9u8];
    p.extend_from_slice(&0u64.to_le_bytes());
    let err = Msg::decode(&push_body(&p)).unwrap_err();
    assert!(err.to_string().contains("unknown payload encoding"), "{err}");
    // f16 length mismatch: count says 3 halves, only 2 present
    let mut p = vec![1u8];
    p.extend_from_slice(&3u64.to_le_bytes());
    p.extend_from_slice(&[0u8; 4]);
    assert!(Msg::decode(&push_body(&p)).is_err(), "truncated f16 payload");
    // a NaN-bearing f16 half (0x7E00) fails closed — quantized momentum
    // must never smuggle a NaN past the server's finite checks
    let mut p = vec![1u8];
    p.extend_from_slice(&1u64.to_le_bytes());
    p.extend_from_slice(&0x7E00u16.to_le_bytes());
    let err = Msg::decode(&push_body(&p)).unwrap_err();
    assert!(err.to_string().contains("NaN"), "{err}");
    // same for bf16 (0x7FC0)
    let mut p = vec![2u8];
    p.extend_from_slice(&1u64.to_le_bytes());
    p.extend_from_slice(&0x7FC0u16.to_le_bytes());
    assert!(Msg::decode(&push_body(&p)).is_err(), "bf16 NaN rejected");

    let topk = |full: u64, nnz: u64, idx: &[u32], vals: &[f32]| {
        let mut p = vec![3u8];
        p.extend_from_slice(&full.to_le_bytes());
        p.extend_from_slice(&nnz.to_le_bytes());
        for i in idx {
            p.extend_from_slice(&i.to_le_bytes());
        }
        for v in vals {
            p.extend_from_slice(&v.to_le_bytes());
        }
        push_body(&p)
    };
    // out-of-range index
    let err = Msg::decode(&topk(4, 1, &[4], &[1.0])).unwrap_err();
    assert!(err.to_string().contains("out of range"), "{err}");
    // non-increasing (duplicate) indices
    let err = Msg::decode(&topk(4, 2, &[2, 2], &[1.0, 1.0])).unwrap_err();
    assert!(err.to_string().contains("strictly increasing"), "{err}");
    // nnz exceeding the full length
    let err = Msg::decode(&topk(2, 3, &[0, 1, 2], &[1.0; 3])).unwrap_err();
    assert!(err.to_string().contains("nnz"), "{err}");
    // an absurd full length is rejected before the dense allocation
    let err = Msg::decode(&topk(u64::MAX / 8, 0, &[], &[])).unwrap_err();
    assert!(err.to_string().contains("frame cap"), "{err}");
    // a well-formed sparse payload still flows
    assert!(Msg::decode(&topk(4, 2, &[0, 3], &[1.0, 2.0])).is_ok());
}

#[test]
fn non_utf8_strings_are_rejected() {
    let mut body = Vec::new();
    body.extend_from_slice(&MAGIC);
    body.push(VERSION);
    body.push(21); // Error tag
    body.push(1); // recoverable
    body.extend_from_slice(&2u32.to_le_bytes());
    body.extend_from_slice(&[0xFF, 0xFE]);
    assert!(Msg::decode(&body).is_err());
}

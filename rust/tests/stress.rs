//! Deterministic concurrency stress tests for the real-thread trainer
//! (`train/real_async.rs`): seeded synthetic workloads, real OS threads,
//! no PJRT.  The assertions are the §5.4 driver's liveness and progress
//! contract — termination (no deadlock on the channel FIFO), a monotone
//! master step, and actual optimization progress on the quadratic — now
//! also under elastic membership (mid-run join/leave via `cfg.churn`) and
//! worker failures (per-worker exits surface in `workers_lost`; the driver
//! fails fast instead of deadlocking when nobody is left).

use dana::config::{TrainConfig, Workload};
use dana::optim::{AlgorithmKind, LeavePolicy};
use dana::sim::ChurnSchedule;
use dana::train::real_async::{self, StepFn};

fn stress_cfg(alg: AlgorithmKind, workers: usize, epochs: f64) -> TrainConfig {
    let mut cfg = TrainConfig::preset(Workload::C10, alg, workers, epochs);
    cfg.seed = 11;
    cfg.metrics_every = 7;
    cfg
}

/// A synthetic quadratic step factory where the workers in `bad` fail —
/// at init (`fail_init`) or on their `fail_at`-th step.  Built on the
/// shared synthetic objective helpers so the fault-injection harness
/// tests the same workload the drivers run.
fn flaky_quadratic(
    k: usize,
    seed: u64,
    bad: Vec<usize>,
    fail_init: bool,
    fail_at: usize,
) -> impl Fn(usize) -> anyhow::Result<StepFn> + Sync {
    let curv = real_async::synthetic_curvature(k);
    move |w: usize| -> anyhow::Result<StepFn> {
        if bad.contains(&w) && fail_init {
            anyhow::bail!("injected init failure for worker {w}");
        }
        let curv = curv.clone();
        let is_bad = bad.contains(&w);
        let mut rng = real_async::synthetic_worker_rng(seed, w);
        let mut steps = 0usize;
        Ok(Box::new(move |params: &[f32]| {
            steps += 1;
            if is_bad && steps >= fail_at {
                anyhow::bail!("injected step failure for worker {w}");
            }
            let mut g = vec![0.0f32; params.len()];
            real_async::synthetic_grad(params, &curv, &mut rng, &mut g);
            Ok((real_async::synthetic_loss(params, &curv) as f32, g))
        }) as StepFn)
    }
}

fn quad_eval(k: usize) -> impl FnMut(&[f32]) -> anyhow::Result<(f64, f64)> {
    let curv = real_async::synthetic_curvature(k);
    move |theta: &[f32]| Ok(real_async::synthetic_eval(theta, &curv))
}

#[test]
fn real_async_8_workers_terminates_and_descends() {
    let k = 4096;
    let cfg = stress_cfg(AlgorithmKind::DanaZero, 8, 2.0); // 200 master steps
    let j0 = real_async::synthetic_loss(
        &real_async::synthetic_theta0(k),
        &real_async::synthetic_curvature(k),
    );
    let rep = real_async::run_synthetic(&cfg, k).unwrap();
    // Termination with the full step budget (deadlock would hang the test).
    assert_eq!(rep.steps, cfg.total_master_steps());
    assert!(!rep.diverged, "synthetic quadratic must not diverge");
    // Monotone master step: the loss curve is sampled at strictly
    // increasing master steps.
    assert!(!rep.loss_curve.is_empty());
    for w in rep.loss_curve.windows(2) {
        assert!(w[0].0 < w[1].0, "master step went backwards: {:?}", w);
    }
    // Progress: at least 10x below the initial loss (the schedule leaves
    // plenty of margin — typical runs land near the noise floor).
    assert!(
        rep.final_test_loss < 0.1 * j0,
        "final loss {} vs initial {j0}",
        rep.final_test_loss
    );
    // With 8 workers in flight the sampled lag must show real asynchrony:
    // the first 8 pushes alone (all pulled at step 0) have lags 0..7, and
    // metrics_every=7 samples inside that window.
    assert!(rep.mean_lag > 0.0, "no asynchrony observed: mean lag 0");
    assert!(rep.wall_secs > 0.0);
}

#[test]
fn real_async_sharded_master_matches_contract_under_threads() {
    // Same run shape, sharded master: 8 worker threads against a 4-shard
    // lock-striped server — exercises scoped-thread fan-out nested inside
    // the channel FIFO.
    let k = 2048;
    let mut cfg = stress_cfg(AlgorithmKind::DanaDc, 8, 2.0);
    cfg.shards = 4;
    let j0 = real_async::synthetic_loss(
        &real_async::synthetic_theta0(k),
        &real_async::synthetic_curvature(k),
    );
    let rep = real_async::run_synthetic(&cfg, k).unwrap();
    assert_eq!(rep.steps, cfg.total_master_steps());
    assert!(!rep.diverged);
    assert!(
        rep.final_test_loss < 0.1 * j0,
        "final loss {} vs initial {j0}",
        rep.final_test_loss
    );
}

#[test]
fn real_async_slim_worker_rule_runs_worker_side() {
    // DANA-Slim keeps momentum in the worker threads; the master is plain
    // ASGD.  The stress contract must hold with the worker-side transform
    // active (state lives and dies inside each thread).
    let k = 1024;
    let cfg = stress_cfg(AlgorithmKind::DanaSlim, 4, 1.0); // 100 steps
    let j0 = real_async::synthetic_loss(
        &real_async::synthetic_theta0(k),
        &real_async::synthetic_curvature(k),
    );
    let rep = real_async::run_synthetic(&cfg, k).unwrap();
    assert_eq!(rep.steps, cfg.total_master_steps());
    assert!(!rep.diverged);
    assert!(
        rep.final_test_loss < 0.5 * j0,
        "final loss {} vs initial {j0}",
        rep.final_test_loss
    );
}

#[test]
fn run_synthetic_rejects_empty_parameter_vector() {
    let cfg = stress_cfg(AlgorithmKind::Asgd, 2, 0.1);
    assert!(real_async::run_synthetic(&cfg, 0).is_err());
}

#[test]
fn real_async_survives_mid_run_join_and_leave() {
    // Satellite (c): real OS threads spawned/stopped mid-run.  The leave
    // retires a slot whose in-flight push must be dropped (not applied,
    // not deadlocked on), the join spawns a brand-new thread, and the run
    // still completes its full step budget and descends.
    let k = 1024;
    for policy in [LeavePolicy::Retire, LeavePolicy::Fold] {
        let mut cfg = stress_cfg(AlgorithmKind::DanaZero, 6, 2.0); // 200 steps
        cfg.churn = ChurnSchedule::parse("leave@0.2:1,join@0.4,leave@0.6,join@0.8").unwrap();
        cfg.leave_policy = policy;
        let j0 = real_async::synthetic_loss(
            &real_async::synthetic_theta0(k),
            &real_async::synthetic_curvature(k),
        );
        let rep = real_async::run_synthetic(&cfg, k).unwrap();
        assert_eq!(rep.steps, cfg.total_master_steps());
        assert!(!rep.diverged);
        assert_eq!(rep.workers_joined, 2);
        assert_eq!(rep.workers_left, 2);
        assert_eq!(rep.workers_lost, 0);
        for w in rep.loss_curve.windows(2) {
            assert!(w[0].0 < w[1].0, "master step went backwards: {w:?}");
        }
        assert!(
            rep.final_test_loss < 0.1 * j0,
            "{policy}: final loss {} vs initial {j0}",
            rep.final_test_loss
        );
    }
}

#[test]
fn real_async_sharded_survives_churn() {
    let k = 512;
    let mut cfg = stress_cfg(AlgorithmKind::DanaDc, 6, 2.0);
    cfg.shards = 4;
    cfg.churn = ChurnSchedule::parse("leave@0.3:2,join@0.5").unwrap();
    let rep = real_async::run_synthetic(&cfg, k).unwrap();
    assert_eq!(rep.steps, cfg.total_master_steps());
    assert!(!rep.diverged);
    assert_eq!((rep.workers_joined, rep.workers_left), (1, 1));
}

#[test]
fn lost_workers_surface_in_report_and_run_completes() {
    // One worker's gradient source dies at init, another mid-run: the
    // survivors finish the budget and the report counts both losses.
    let k = 256;
    let cfg = stress_cfg(AlgorithmKind::DanaZero, 5, 1.0); // 100 steps
    let make_step = flaky_quadratic(k, cfg.seed, vec![0, 3], false, 4);
    let rep = real_async::run_core(
        &cfg,
        &real_async::synthetic_theta0(k),
        &make_step,
        quad_eval(k),
    )
    .unwrap();
    assert_eq!(rep.steps, cfg.total_master_steps());
    assert_eq!(rep.workers_lost, 2, "both step-failures must be counted");
    assert!(!rep.diverged);
}

#[test]
fn init_failures_surface_in_report() {
    let k = 128;
    let cfg = stress_cfg(AlgorithmKind::Asgd, 4, 0.5); // 50 steps
    let make_step = flaky_quadratic(k, cfg.seed, vec![1], true, 0);
    let rep = real_async::run_core(
        &cfg,
        &real_async::synthetic_theta0(k),
        &make_step,
        quad_eval(k),
    )
    .unwrap();
    assert_eq!(rep.steps, cfg.total_master_steps());
    assert_eq!(rep.workers_lost, 1);
}

#[test]
fn panicking_worker_surfaces_as_lost_instead_of_hanging() {
    // A panic (not an Err) in the gradient source must be caught inside
    // the worker thread and reported as an exit: before this was handled,
    // the master — which keeps a sender alive for mid-run joins — would
    // block on recv forever once the last panicked worker went silent.
    let k = 64;
    let make_step = {
        let curv = real_async::synthetic_curvature(k);
        move |w: usize| -> anyhow::Result<StepFn> {
            let curv = curv.clone();
            let mut rng = real_async::synthetic_worker_rng(17, w);
            let mut steps = 0usize;
            Ok(Box::new(move |params: &[f32]| {
                steps += 1;
                if w == 0 && steps >= 3 {
                    panic!("injected panic in worker {w}");
                }
                let mut g = vec![0.0f32; params.len()];
                real_async::synthetic_grad(params, &curv, &mut rng, &mut g);
                Ok((real_async::synthetic_loss(params, &curv) as f32, g))
            }) as StepFn)
        }
    };
    let cfg = stress_cfg(AlgorithmKind::Asgd, 3, 1.0); // 100 steps
    let rep = real_async::run_core(
        &cfg,
        &real_async::synthetic_theta0(k),
        &make_step,
        quad_eval(k),
    )
    .unwrap();
    assert_eq!(rep.steps, cfg.total_master_steps());
    assert_eq!(rep.workers_lost, 1, "the panicked worker must be counted");

    // ...and when EVERY worker panics, the run errors out promptly.
    let all_panic = |_w: usize| -> anyhow::Result<StepFn> {
        Ok(Box::new(move |_params: &[f32]| panic!("boom")) as StepFn)
    };
    let err = real_async::run_core(
        &cfg,
        &real_async::synthetic_theta0(k),
        &all_panic,
        quad_eval(k),
    )
    .unwrap_err();
    assert!(err.to_string().contains("no live workers"), "{err}");
}

#[test]
fn scheduled_leave_of_crashed_worker_is_skipped_not_fatal() {
    // Worker 1 dies at init (implicit leave); the schedule later names it
    // in an explicit leave.  The leave must be a no-op — the run finishes
    // on the survivors with the crash counted once, in workers_lost.
    let k = 128;
    let mut cfg = stress_cfg(AlgorithmKind::DanaZero, 4, 1.0); // 100 steps
    cfg.churn = ChurnSchedule::parse("leave@0.5:1").unwrap();
    let make_step = flaky_quadratic(k, cfg.seed, vec![1], true, 0);
    let rep = real_async::run_core(
        &cfg,
        &real_async::synthetic_theta0(k),
        &make_step,
        quad_eval(k),
    )
    .unwrap();
    assert_eq!(rep.steps, cfg.total_master_steps());
    assert_eq!(rep.workers_lost, 1);
    assert_eq!(rep.workers_left, 0, "the skipped leave must not be counted");
}

#[test]
fn all_workers_dead_fails_fast_instead_of_hanging() {
    // Every worker fails at init: the master must error out promptly with
    // a clear message, not hang waiting on the FIFO (a deadlock here would
    // hit the test harness timeout).
    let k = 64;
    let cfg = stress_cfg(AlgorithmKind::Asgd, 3, 1.0);
    let make_step = flaky_quadratic(k, cfg.seed, vec![0, 1, 2], true, 0);
    let err = real_async::run_core(
        &cfg,
        &real_async::synthetic_theta0(k),
        &make_step,
        quad_eval(k),
    )
    .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("no live workers"), "unexpected error: {msg}");
    // mid-run collective death fails fast too
    let make_step = flaky_quadratic(k, cfg.seed, vec![0, 1, 2], false, 5);
    let err = real_async::run_core(
        &cfg,
        &real_async::synthetic_theta0(k),
        &make_step,
        quad_eval(k),
    )
    .unwrap_err();
    assert!(err.to_string().contains("no live workers"), "{err}");
}

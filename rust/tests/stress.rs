//! Deterministic concurrency stress tests for the real-thread trainer
//! (`train/real_async.rs`): seeded synthetic workloads, real OS threads,
//! no PJRT.  The assertions are the §5.4 driver's liveness and progress
//! contract — termination (no deadlock on the channel FIFO), a monotone
//! master step, and actual optimization progress on the quadratic.

use dana::config::{TrainConfig, Workload};
use dana::optim::AlgorithmKind;
use dana::train::real_async;

fn stress_cfg(alg: AlgorithmKind, workers: usize, epochs: f64) -> TrainConfig {
    let mut cfg = TrainConfig::preset(Workload::C10, alg, workers, epochs);
    cfg.seed = 11;
    cfg.metrics_every = 7;
    cfg
}

#[test]
fn real_async_8_workers_terminates_and_descends() {
    let k = 4096;
    let cfg = stress_cfg(AlgorithmKind::DanaZero, 8, 2.0); // 200 master steps
    let j0 = real_async::synthetic_loss(
        &real_async::synthetic_theta0(k),
        &real_async::synthetic_curvature(k),
    );
    let rep = real_async::run_synthetic(&cfg, k).unwrap();
    // Termination with the full step budget (deadlock would hang the test).
    assert_eq!(rep.steps, cfg.total_master_steps());
    assert!(!rep.diverged, "synthetic quadratic must not diverge");
    // Monotone master step: the loss curve is sampled at strictly
    // increasing master steps.
    assert!(!rep.loss_curve.is_empty());
    for w in rep.loss_curve.windows(2) {
        assert!(w[0].0 < w[1].0, "master step went backwards: {:?}", w);
    }
    // Progress: at least 10x below the initial loss (the schedule leaves
    // plenty of margin — typical runs land near the noise floor).
    assert!(
        rep.final_test_loss < 0.1 * j0,
        "final loss {} vs initial {j0}",
        rep.final_test_loss
    );
    // With 8 workers in flight the sampled lag must show real asynchrony:
    // the first 8 pushes alone (all pulled at step 0) have lags 0..7, and
    // metrics_every=7 samples inside that window.
    assert!(rep.mean_lag > 0.0, "no asynchrony observed: mean lag 0");
    assert!(rep.wall_secs > 0.0);
}

#[test]
fn real_async_sharded_master_matches_contract_under_threads() {
    // Same run shape, sharded master: 8 worker threads against a 4-shard
    // lock-striped server — exercises scoped-thread fan-out nested inside
    // the channel FIFO.
    let k = 2048;
    let mut cfg = stress_cfg(AlgorithmKind::DanaDc, 8, 2.0);
    cfg.shards = 4;
    let j0 = real_async::synthetic_loss(
        &real_async::synthetic_theta0(k),
        &real_async::synthetic_curvature(k),
    );
    let rep = real_async::run_synthetic(&cfg, k).unwrap();
    assert_eq!(rep.steps, cfg.total_master_steps());
    assert!(!rep.diverged);
    assert!(
        rep.final_test_loss < 0.1 * j0,
        "final loss {} vs initial {j0}",
        rep.final_test_loss
    );
}

#[test]
fn real_async_slim_worker_rule_runs_worker_side() {
    // DANA-Slim keeps momentum in the worker threads; the master is plain
    // ASGD.  The stress contract must hold with the worker-side transform
    // active (state lives and dies inside each thread).
    let k = 1024;
    let cfg = stress_cfg(AlgorithmKind::DanaSlim, 4, 1.0); // 100 steps
    let j0 = real_async::synthetic_loss(
        &real_async::synthetic_theta0(k),
        &real_async::synthetic_curvature(k),
    );
    let rep = real_async::run_synthetic(&cfg, k).unwrap();
    assert_eq!(rep.steps, cfg.total_master_steps());
    assert!(!rep.diverged);
    assert!(
        rep.final_test_loss < 0.5 * j0,
        "final loss {} vs initial {j0}",
        rep.final_test_loss
    );
}

#[test]
fn run_synthetic_rejects_empty_parameter_vector() {
    let cfg = stress_cfg(AlgorithmKind::Asgd, 2, 0.1);
    assert!(real_async::run_synthetic(&cfg, 0).is_err());
}

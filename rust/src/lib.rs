//! # DANA — Taming Momentum in a Distributed Asynchronous Environment
//!
//! Full reproduction of Hakimi, Barkai, Gabel & Schuster (2019) as a
//! three-layer rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the asynchronous parameter-server
//!   coordinator: every update rule evaluated in the paper
//!   ([`optim`]), the parameter server with gap/lag instrumentation —
//!   monolithic and sharded/lock-striped layouts behind one [`server::Master`]
//!   interface ([`server`]), the TCP transport + checkpoint/restore
//!   subsystem that makes the cluster multi-process ([`net`]), the
//!   shard-group placement layer — multi-server fan-out client and
//!   hot-standby fail-over ([`cluster`]), the gamma
//!   execution-time cluster simulator ([`sim`]), training drivers
//!   ([`train`]) and the experiment harness that regenerates each paper
//!   table/figure ([`experiments`]).
//! * **Layer 2/1 (python, build-time)** — JAX models whose dense hot paths
//!   are Pallas kernels, AOT-lowered to HLO text in `artifacts/`.
//! * **Runtime bridge** — [`runtime`] loads the artifacts through the PJRT
//!   CPU client (`xla` crate) so Python is never on the request path.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for measured reproductions.

// Every `unsafe` block must say why it is sound — the SIMD kernel
// dispatch ([`math::simd`]) and the worker-pool fan-out
// ([`util::parallel`]) are the only users, and both live or die by
// their stated invariants.  CI runs clippy with `-D warnings`, so this
// warn is a deny in practice.
#![warn(clippy::undocumented_unsafe_blocks)]

pub mod cluster;
pub mod config;
pub mod data;
pub mod experiments;
pub mod math;
pub mod net;
pub mod optim;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod train;
pub mod util;

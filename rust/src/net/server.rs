//! `NetServer` — any [`Master`] implementation behind a `TcpListener`.
//!
//! Connection lifecycle maps one-to-one onto the elastic-membership
//! machinery PR 2 built:
//!
//! * **connect** (a [`wire::Role::Worker`] Hello) = [`Master::add_worker`]
//!   — or, after a `--resume`, re-attachment to the lowest live slot left
//!   unattached by the checkpoint, so a returning worker finds its
//!   momentum vᶦ exactly where it left it (*reconnect-as-join*);
//! * **disconnect / EOF** = [`Master::remove_worker`] under the server's
//!   configured default [`LeavePolicy`] (an explicit [`wire::Msg::Leave`]
//!   frame may override the policy per departure);
//! * every attach bumps the slot's **generation**; a `Push` whose echoed
//!   generation no longer matches is a straggler from a previous
//!   incarnation of the slot and is rejected recoverably, exactly like
//!   the in-process drivers drop late pushes after a leave.
//!
//! Threading: one OS thread per connection, all serialized through one
//! mutex around the master — the FIFO discipline of the paper's Appendix
//! A.1 falls out of lock acquisition order.  The master's own sharded
//! parallelism (S shards fanned out per apply) still runs *inside* the
//! lock, so `--shards` composes with the transport unchanged.
//!
//! Fault tolerance: with a checkpoint path configured the server writes a
//! [`crate::net::checkpoint`] snapshot every `checkpoint_every` master
//! steps (atomic rename; see that module for the torn-write guarantees),
//! on demand (`Checkpoint` control frame), and on graceful `Shutdown`.  A
//! hard [`NetServer::stop`] intentionally skips the final write — tests
//! use it to simulate a crash, and a crashed process by definition keeps
//! only its last periodic snapshot.

use super::checkpoint;
use super::wire::{self, Msg, Role};
use crate::optim::LeavePolicy;
use crate::server::{Master, MasterSnapshot};
use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// Server-side policy knobs (everything else lives in the [`Master`]).
#[derive(Debug, Clone, Default)]
pub struct ServeOptions {
    /// Policy for a worker that disconnects without an explicit Leave.
    pub leave_policy: LeavePolicy,
    /// Checkpoint file path (None = checkpointing disabled).
    pub checkpoint_path: Option<PathBuf>,
    /// Write a checkpoint every N master steps (0 = only on demand /
    /// graceful shutdown).
    pub checkpoint_every: u64,
}

struct Shared {
    inner: Mutex<Inner>,
    /// Serializes checkpoint file writes that happen *outside* the master
    /// lock (periodic snapshots) and records the highest master step ever
    /// written, so a slow write can never clobber a newer snapshot.
    ckpt_gate: Mutex<u64>,
}

struct Inner {
    master: Box<dyn Master>,
    /// Whether a connection currently owns each slot.
    attached: Vec<bool>,
    /// Per-slot generation, bumped at every attach.
    slot_gen: Vec<u32>,
    opts: ServeOptions,
    /// The bound address — the in-band Shutdown path dials it once to
    /// wake the accept loop out of `accept(2)`.
    addr: SocketAddr,
    /// Once set (under the lock), no further request is served: handler
    /// threads close their connections and the accept loop exits.
    shutdown: bool,
}

impl Inner {
    fn header(&self) -> wire::Header {
        let s = self.master.step_now();
        wire::Header {
            master_step: self.master.steps_done(),
            eta: s.eta,
            gamma: s.gamma,
            lambda: s.lambda,
            live_workers: self.master.live_workers() as u64,
            worker_slots: self.master.workers() as u64,
        }
    }

    /// Claim a slot for a worker connection.  A *reattaching* worker is
    /// handed the lowest live-but-unattached slot (restored from a
    /// checkpoint) first — deterministic, so a client reconnecting its
    /// workers in order gets its old slots (and their momentum) back.  A
    /// fresh join never inherits such a slot: it always goes through
    /// `Master::add_worker` (zero momentum, EASGD at the center, auto
    /// α/τ retune), preserving PR 2's joiner semantics.
    fn attach_worker(&mut self, reattach: bool) -> usize {
        let resumable = if reattach {
            (0..self.master.workers()).find(|&w| {
                self.master.is_live(w) && !self.attached.get(w).copied().unwrap_or(false)
            })
        } else {
            None
        };
        let slot = resumable.unwrap_or_else(|| self.master.add_worker());
        if slot >= self.attached.len() {
            self.attached.resize(slot + 1, false);
            self.slot_gen.resize(slot + 1, 0);
        }
        self.attached[slot] = true;
        self.slot_gen[slot] = self.slot_gen[slot].wrapping_add(1);
        slot
    }

    /// Synchronous checkpoint (explicit `Checkpoint` frame / graceful
    /// shutdown): snapshot + write under the master lock, so the reply
    /// acknowledges a durable file.  Takes the write gate so it composes
    /// with in-flight periodic writes (lock order inner → gate; the
    /// periodic path takes only the gate).
    fn write_checkpoint(&self, shared: &Shared) -> anyhow::Result<()> {
        let path = self
            .opts
            .checkpoint_path
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("no checkpoint path configured"))?;
        let snap = self.master.snapshot()?;
        let mut last = shared.ckpt_gate.lock().expect("ckpt gate poisoned");
        checkpoint::write_atomic(path, &snap)?;
        *last = (*last).max(snap.master_step);
        Ok(())
    }

    /// Periodic-checkpoint trigger after a push: clone a consistent
    /// snapshot under the master lock and hand it back — the expensive
    /// encode + write + fsync runs *outside* the lock so worker traffic
    /// is not stalled behind the disk.  Failures are logged, not fatal.
    fn pending_checkpoint(&self) -> Option<(std::path::PathBuf, MasterSnapshot)> {
        if self.opts.checkpoint_every == 0 {
            return None;
        }
        let path = self.opts.checkpoint_path.as_ref()?;
        if self.master.steps_done() % self.opts.checkpoint_every != 0 {
            return None;
        }
        match self.master.snapshot() {
            Ok(snap) => Some((path.clone(), snap)),
            Err(e) => {
                eprintln!("checkpoint failed at step {}: {e:#}", self.master.steps_done());
                None
            }
        }
    }
}

/// Write a periodic snapshot outside the master lock.  The gate both
/// serializes concurrent writers and drops a snapshot that raced behind a
/// newer one.
fn write_pending_checkpoint(shared: &Shared, path: &std::path::Path, snap: &MasterSnapshot) {
    let mut last = shared.ckpt_gate.lock().expect("ckpt gate poisoned");
    if snap.master_step <= *last {
        return; // a newer snapshot is already on disk
    }
    match checkpoint::write_atomic(path, snap) {
        Ok(()) => *last = snap.master_step,
        Err(e) => eprintln!("checkpoint failed at step {}: {e:#}", snap.master_step),
    }
}

/// A running transport server.  Dropping it stops the accept loop (hard,
/// without a final checkpoint — see the module docs).
pub struct NetServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl NetServer {
    /// Bind `listen` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start serving `master`.  Slots already live in the master (a
    /// `--resume` restore) start *unattached* and are claimed by
    /// reconnecting workers; a fresh master should be built with 0
    /// workers so that connect == join.
    pub fn start(
        master: Box<dyn Master>,
        listen: &str,
        opts: ServeOptions,
    ) -> anyhow::Result<NetServer> {
        let listener = TcpListener::bind(listen)
            .map_err(|e| anyhow::anyhow!("bind {listen}: {e}"))?;
        let addr = listener.local_addr()?;
        let slots = master.workers();
        let shared = Arc::new(Shared {
            inner: Mutex::new(Inner {
                master,
                attached: vec![false; slots],
                slot_gen: vec![0; slots],
                opts,
                addr,
                shutdown: false,
            }),
            ckpt_gate: Mutex::new(0),
        });
        let accept_shared = Arc::clone(&shared);
        let accept = std::thread::spawn(move || accept_loop(listener, accept_shared));
        Ok(NetServer { addr, shared, accept: Some(accept) })
    }

    /// The bound address (resolves `:0` to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// `tcp://host:port` form, ready for `--master`.
    pub fn url(&self) -> String {
        format!("tcp://{}", self.addr)
    }

    /// Hard stop ("kill"): refuse all further requests and close the
    /// listener.  No final checkpoint is written; in-flight client
    /// requests observe EOF.  Blocks until the accept loop exits.
    pub fn stop(&mut self) {
        {
            let mut g = self.shared.inner.lock().expect("net server poisoned");
            if g.shutdown {
                return;
            }
            g.shutdown = true;
        }
        // wake the accept loop so it observes the flag
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Block until the server shuts down (a `Shutdown` control frame).
    pub fn wait(&mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Master steps applied so far (test/operator introspection).
    pub fn steps_done(&self) -> u64 {
        self.shared.inner.lock().expect("net server poisoned").master.steps_done()
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    for stream in listener.incoming() {
        if shared.inner.lock().expect("net server poisoned").shutdown {
            break;
        }
        match stream {
            Ok(s) => {
                let conn_shared = Arc::clone(&shared);
                std::thread::spawn(move || {
                    if let Err(e) = handle_conn(s, conn_shared) {
                        eprintln!("net: connection error: {e:#}");
                    }
                });
            }
            Err(_) => continue, // transient accept failure
        }
    }
}

/// One connection, handshake to EOF.  Returns Err only for reply-write
/// failures worth logging; a client disconnect is a normal return.
fn handle_conn(stream: TcpStream, shared: Arc<Shared>) -> anyhow::Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);

    // Handshake: the first frame must be Hello.
    let (slot, gen) = match wire::read_frame(&mut reader) {
        Ok(Msg::Hello { role, reattach }) => {
            let ack = {
                let mut g = shared.inner.lock().expect("net server poisoned");
                if g.shutdown {
                    return Ok(());
                }
                match role {
                    Role::Worker => {
                        let slot = g.attach_worker(reattach);
                        let gen = g.slot_gen[slot];
                        (
                            Some((slot, gen)),
                            Msg::HelloAck {
                                slot: slot as u64,
                                gen,
                                kind: g.master.algo_kind(),
                                k: g.master.param_len() as u64,
                                header: g.header(),
                            },
                        )
                    }
                    Role::Control => (
                        None,
                        Msg::HelloAck {
                            slot: u64::MAX,
                            gen: 0,
                            kind: g.master.algo_kind(),
                            k: g.master.param_len() as u64,
                            header: g.header(),
                        },
                    ),
                }
            };
            wire::write_frame(&mut writer, &ack.1)?;
            match ack.0 {
                Some((s, g)) => (Some(s), g),
                None => (None, 0),
            }
        }
        Ok(_) => {
            let _ = wire::write_frame(
                &mut writer,
                &Msg::Error { recoverable: false, detail: "expected Hello".into() },
            );
            return Ok(());
        }
        Err(_) => return Ok(()), // dropped before the handshake
    };

    let served = serve_requests(&mut reader, &mut writer, &shared, slot, gen);

    // Disconnect = leave.  Only the *current* incarnation of the slot may
    // retire it, and a shutdown freezes membership (so the state a crash
    // leaves behind matches the last checkpoint's worldview).
    if let Some(w) = slot {
        let mut g = shared.inner.lock().expect("net server poisoned");
        if g.slot_gen[w] == gen && g.attached[w] {
            g.attached[w] = false;
            if !g.shutdown && g.master.is_live(w) {
                let policy = g.opts.leave_policy;
                if let Err(e) = g.master.remove_worker(w, policy) {
                    eprintln!("net: retire of disconnected worker {w} failed: {e:#}");
                }
            }
        }
    }
    served
}

fn serve_requests(
    reader: &mut BufReader<TcpStream>,
    writer: &mut BufWriter<TcpStream>,
    shared: &Arc<Shared>,
    slot: Option<usize>,
    gen: u32,
) -> anyhow::Result<()> {
    loop {
        // EOF or a malformed (fail-closed) frame both end the connection.
        let msg = match wire::read_frame(reader) {
            Ok(m) => m,
            Err(_) => return Ok(()),
        };
        let (reply, shutdown_after, pending) = {
            let mut g = shared.inner.lock().expect("net server poisoned");
            if g.shutdown {
                return Ok(()); // close without a reply: the client sees EOF
            }
            dispatch(&mut g, shared, slot, gen, msg)
        };
        // periodic snapshot: the disk I/O happens with the master unlocked
        if let Some((path, snap)) = pending {
            write_pending_checkpoint(shared, &path, &snap);
        }
        wire::write_frame(writer, &reply)?;
        if shutdown_after {
            return Ok(());
        }
    }
}

/// Handle one request under the master lock.  Returns the reply, whether
/// the connection should close after sending it (Shutdown), and a
/// periodic snapshot the caller must write after releasing the lock.
fn dispatch(
    g: &mut Inner,
    shared: &Shared,
    slot: Option<usize>,
    gen: u32,
    msg: Msg,
) -> (Msg, bool, Option<(std::path::PathBuf, MasterSnapshot)>) {
    let recoverable = |detail: String| Msg::Error { recoverable: true, detail };
    let fatal = |detail: &str| Msg::Error { recoverable: false, detail: detail.to_string() };
    let mut pending = None;
    let reply = match (msg, slot) {
        (Msg::PullParams, Some(w)) => {
            if g.slot_gen[w] != gen || !g.master.is_live(w) {
                recoverable(format!("pull for retired worker slot {w}"))
            } else {
                let params = g.master.pull_params(w);
                Msg::Params { header: g.header(), params }
            }
        }
        (Msg::Push { gen: push_gen, msg }, Some(w)) => {
            if push_gen != g.slot_gen[w] || g.slot_gen[w] != gen || !g.master.is_live(w) {
                // a straggler from a previous incarnation of the slot
                recoverable(format!("stale push for worker slot {w}"))
            } else if msg.len() != g.master.param_len() {
                fatal(&format!(
                    "push length {} != parameter count {}",
                    msg.len(),
                    g.master.param_len()
                ))
            } else {
                match g.master.push_update(w, &msg) {
                    Ok(s) => {
                        pending = g.pending_checkpoint();
                        Msg::PushAck {
                            header: g.header(),
                            eta: s.eta,
                            gamma: s.gamma,
                            lambda: s.lambda,
                        }
                    }
                    Err(e) => recoverable(format!("{e:#}")),
                }
            }
        }
        (Msg::Leave { policy }, Some(w)) => {
            if g.slot_gen[w] != gen || !g.attached[w] || !g.master.is_live(w) {
                recoverable(format!("leave for already-retired slot {w}"))
            } else {
                g.attached[w] = false;
                match g.master.remove_worker(w, policy) {
                    Ok(()) => Msg::Ack { header: g.header() },
                    Err(e) => recoverable(format!("{e:#}")),
                }
            }
        }
        (Msg::Status, _) => Msg::Ack { header: g.header() },
        (Msg::GetTheta, _) => Msg::Theta { header: g.header(), theta: g.master.theta_vec() },
        (Msg::Checkpoint, None) => match g.write_checkpoint(shared) {
            Ok(()) => Msg::Ack { header: g.header() },
            Err(e) => fatal(&format!("{e:#}")),
        },
        (Msg::Shutdown, None) => {
            // graceful: snapshot first (best effort), then stop the world
            if g.opts.checkpoint_path.is_some() {
                if let Err(e) = g.write_checkpoint(shared) {
                    eprintln!("net: shutdown checkpoint failed: {e:#}");
                }
            }
            g.shutdown = true;
            wake(g.addr);
            return (Msg::Ack { header: g.header() }, true, None);
        }
        (Msg::Checkpoint | Msg::Shutdown, Some(_)) => {
            fatal("control-only request on a worker connection")
        }
        (Msg::PullParams | Msg::Push { .. } | Msg::Leave { .. }, None) => {
            fatal("worker request on a control connection")
        }
        (Msg::Hello { .. }, _) => fatal("duplicate Hello"),
        // server->client messages arriving at the server are protocol abuse
        (
            Msg::HelloAck { .. }
            | Msg::Params { .. }
            | Msg::PushAck { .. }
            | Msg::Ack { .. }
            | Msg::Theta { .. }
            | Msg::Error { .. },
            _,
        ) => fatal("unexpected reply-type message"),
    };
    (reply, false, pending)
}

/// Wake any listener blocked in accept after an in-band Shutdown: the
/// control client's connection closing is not enough, the loop needs one
/// more incoming event.  Called by the shutdown path on a best-effort
/// clone of the address.
pub(crate) fn wake(addr: SocketAddr) {
    let _ = TcpStream::connect(addr);
}

//! `NetServer` — a [`ServingMaster`] behind a `TcpListener`.
//!
//! Connection lifecycle maps one-to-one onto the elastic-membership
//! machinery PR 2 built:
//!
//! * **connect** (a [`wire::Role::Worker`] Hello) = [`ServingMaster::join`]
//!   — or, after a `--resume`, re-attachment to the lowest live slot left
//!   unattached by the checkpoint, so a returning worker finds its
//!   momentum vᶦ exactly where it left it (*reconnect-as-join*);
//! * **disconnect / EOF** = [`ServingMaster::leave`] under the server's
//!   configured default [`LeavePolicy`] (an explicit [`wire::Msg::Leave`]
//!   frame may override the policy per departure);
//! * every attach bumps the slot's **generation**; a `Push` whose echoed
//!   generation no longer matches is a straggler from a previous
//!   incarnation of the slot and is rejected recoverably, exactly like
//!   the in-process drivers drop late pushes after a leave.
//!
//! Threading: one OS thread per connection, but — unlike the PR 3 version
//! of this file — **no global lock in front of the master**.  Connection
//! bookkeeping (attachment, generations, the shutdown flag) lives under
//! one small mutex held for O(1) work; pulls and pushes then run against
//! the [`ServingMaster`] concurrently.  With the lock-striped backend
//! ([`crate::server::ShardedParameterServer`]) two workers' applies
//! pipeline across shards and pulls run under per-shard read locks, so
//! the sharded layout finally buys throughput *through the wire*; the
//! global-lock backend ([`crate::server::LockedMaster`]) is preserved as
//! the reference path and serializes exactly like PR 3.  This is safe
//! without widening the gen-check critical section because a slot is only
//! retired by the connection that owns its current generation — the very
//! thread executing the request — so a gen check at dispatch time cannot
//! be invalidated mid-request by another thread.
//!
//! Shard-sliced frames: a client may fetch parameters shard-by-shard
//! ([`Msg::PullShard`]) and deliver updates the same way
//! ([`Msg::PushShard`]).  Push slices are buffered *per connection* and
//! applied as one master step when the last slice lands
//! (gather-then-apply): a worker dying mid-group leaves no partial
//! update, and the slices of different workers interleave freely on the
//! striped backend.
//!
//! Failure containment: every lock is taken through the poison-recovering
//! helpers in [`crate::util::sync`], and a panicking request handler is
//! caught ([`std::panic::catch_unwind`]), logged, and turned into the
//! normal disconnect path — the offending slot is retired and the rest of
//! the cluster keeps training.  (The PR 3 version `.expect()`ed on every
//! lock, so one panicking connection thread poisoned the master mutex and
//! permanently killed the whole cluster.)
//!
//! Fault tolerance: with a checkpoint path configured the server writes a
//! [`crate::net::checkpoint`] snapshot every `checkpoint_every` master
//! steps (atomic rename + parent-directory fsync; see that module), on
//! demand (`Checkpoint` control frame), and on graceful `Shutdown`.  A
//! hard [`NetServer::stop`] intentionally skips the final write — tests
//! use it to simulate a crash, and a crashed process by definition keeps
//! only its last periodic snapshot.

use super::checkpoint;
use super::codec::{self, Encoding, EncodingSet};
use super::http::{self, CheckpointInfo, SlotRow, StatusSnapshot};
use super::retention::{self, RetentionPolicy};
use super::wire::{self, Msg, Role};
use crate::optim::LeavePolicy;
use crate::server::{LockedMaster, Master, MasterSnapshot, ServingMaster};
use crate::util::sync;
use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Server-side policy knobs (everything else lives in the master).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServeOptions {
    /// Policy for a worker that disconnects without an explicit Leave.
    pub leave_policy: LeavePolicy,
    /// Checkpoint file path (None = checkpointing disabled).
    pub checkpoint_path: Option<PathBuf>,
    /// Write a checkpoint every N master steps (0 = only on demand /
    /// graceful shutdown).
    pub checkpoint_every: u64,
    /// Pipeline depth the clients run at (`dana serve --pipeline-depth`):
    /// sizes the master's per-slot pull windows, forwards the staleness
    /// hint to the algorithm, and is reported in `HelloAck` so a
    /// mismatched client can warn.  0 = classic synchronous serving.
    pub pipeline_depth: usize,
    /// HTTP status listener address (`dana serve --status-addr`, e.g.
    /// `"127.0.0.1:9633"`); None = no status endpoint.  See [`http`].
    pub status_addr: Option<String>,
    /// Checkpoint archive retention (`--keep-last`/`--keep-hourly`);
    /// disabled by default.  See [`retention`].
    pub retention: RetentionPolicy,
    /// Payload encodings this server advertises (`--encodings`, wire v4).
    /// A worker's `Hello` request outside this set is granted `none`
    /// instead ([`codec::grant`]).  Defaults to everything this build
    /// speaks; `none` is always included.
    pub encodings: EncodingSet,
    /// Where this server sits in a multi-server placement (wire v5).
    /// The default (`0..0 @ epoch 0`) is normalized at start into "all
    /// shards, epoch 0" — a standalone server advertises itself as the
    /// whole cluster and every existing single-endpoint flow is
    /// unchanged.
    pub placement: Placement,
}

/// This server's slice of a cluster-wide shard placement, advertised in
/// every reply header (wire v5) so clients can resolve and re-resolve
/// the cluster layout from any endpoint.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Placement {
    /// First global shard hosted here (`dana serve --shard-range A..B`
    /// sets A; the hosted count B−A is the master's own shard count).
    pub shard_start: u32,
    /// Global shard count across the whole placement (0 = standalone:
    /// normalized to the master's shard count at start).
    pub total_shards: u32,
    /// Placement epoch this server serves under.  Strictly increases at
    /// every takeover, so a client comparing epochs can fence a stale
    /// primary: whichever server of a range advertises the highest epoch
    /// is the authority, and replies carrying an older epoch than the
    /// client has already seen for the range must be treated as stale.
    pub epoch: u64,
    /// Takeovers this process has performed (0 for a server that started
    /// as a primary; a standby promotes with 1).  Surfaced as the
    /// `dana_takeovers_total` counter.
    pub takeovers: u64,
}

impl ServeOptions {
    /// The serving options a manifest's `servers[]` entry normalizes to
    /// — the same struct the `dana serve` flags build, so golden tests
    /// can compare the two spellings with `==`.  Checkpoint paths
    /// resolve against `run_dir` (the committed manifest stays
    /// portable).
    pub fn from_manifest(
        m: &crate::cluster::manifest::ClusterManifest,
        server: &crate::cluster::manifest::ServerSpec,
        run_dir: &std::path::Path,
    ) -> ServeOptions {
        use crate::cluster::manifest::ClusterManifest;
        let (checkpoint_path, checkpoint_every, retention) = match &server.checkpoint {
            Some(ck) => (
                Some(ClusterManifest::resolve_run_path(run_dir, &ck.path)),
                ck.every,
                RetentionPolicy { keep_last: ck.keep_last, keep_hourly: ck.keep_hourly },
            ),
            None => (None, 0, RetentionPolicy::default()),
        };
        ServeOptions {
            leave_policy: m.leave_policy,
            checkpoint_path,
            checkpoint_every,
            pipeline_depth: m.pipeline_depth,
            status_addr: server.status_addr.clone(),
            retention,
            encodings: m.encodings,
            placement: Placement {
                shard_start: server.shard_range.start,
                total_shards: m.shards,
                epoch: server.placement_epoch,
                takeovers: 0,
            },
        }
    }
}

/// Connection bookkeeping, under one short mutex (never held across a
/// master data operation).
struct Conns {
    /// Whether a connection currently owns each slot.
    attached: Vec<bool>,
    /// Per-slot generation, bumped at every attach.
    slot_gen: Vec<u32>,
    /// Once set, no further request is served: handler threads close
    /// their connections and the accept loop exits.
    shutdown: bool,
}

struct Shared {
    master: Box<dyn ServingMaster>,
    conns: Mutex<Conns>,
    opts: ServeOptions,
    /// The bound address — the in-band Shutdown path dials it once to
    /// wake the accept loop out of `accept(2)`.
    addr: SocketAddr,
    /// Serializes checkpoint file writes and records the highest master
    /// step ever written, so a slow write can never clobber a newer
    /// snapshot.
    ckpt_gate: Mutex<u64>,
    /// Pushes dropped (recoverably rejected) over this server's lifetime:
    /// stale-generation stragglers and retired-slot races.  Surfaced in
    /// every reply header, so `Status` makes silently discarded work
    /// visible instead of vanishing into `eprintln`-less rejections.
    drops: AtomicU64,
    /// When this server started serving (uptime / checkpoint-age base).
    started: Instant,
    /// Master step count at startup.  `/metrics` derives the current step
    /// as `base_steps + hub.pushes_total()` — every applied push advances
    /// the step by exactly one — so the scrape never touches
    /// [`ServingMaster::status`] (whose seq lock the push path holds).
    base_steps: u64,
    /// Last checkpoint written: master step, file bytes, and write time as
    /// millis since `started` (`u64::MAX` = never).  Plain atomics so the
    /// scrape path shares no lock with checkpoint writers either.
    ckpt_step: AtomicU64,
    ckpt_bytes: AtomicU64,
    ckpt_at_ms: AtomicU64,
}

impl Shared {
    fn header(&self) -> wire::Header {
        let (master_step, s, live, slots) = self.master.status();
        wire::Header {
            master_step,
            eta: s.eta,
            gamma: s.gamma,
            lambda: s.lambda,
            live_workers: live as u64,
            worker_slots: slots as u64,
            pushes_dropped: self.drops.load(Ordering::Relaxed),
            epoch: self.opts.placement.epoch,
            shard_start: self.opts.placement.shard_start,
            shard_hosted: self.master.shard_count() as u32,
            total_shards: self.opts.placement.total_shards,
            standby: 0,
        }
    }

    /// Map a wire (global) shard id onto this server's local shard
    /// table.  Out-of-range slices are a *recoverable* protocol error —
    /// a client acting on a stale placement must get an error reply it
    /// can re-resolve from, never a fatal close or an out-of-bounds
    /// index into the local table.
    fn local_shard(&self, shard: u32, n_local: usize) -> Result<usize, String> {
        let start = self.opts.placement.shard_start;
        let local = shard.wrapping_sub(start) as usize;
        if shard < start || local >= n_local {
            return Err(format!(
                "shard {shard} is outside this server's hosted range {start}..{}",
                start as usize + n_local
            ));
        }
        Ok(local)
    }

    /// Count one dropped push and build the recoverable error reply.
    fn drop_push(&self, detail: String) -> Msg {
        self.drops.fetch_add(1, Ordering::Relaxed);
        Msg::Error { recoverable: true, detail }
    }

    /// Claim a slot for a worker connection.  A *reattaching* worker is
    /// handed the lowest live-but-unattached slot (restored from a
    /// checkpoint) first — deterministic, so a client reconnecting its
    /// workers in order gets its old slots (and their momentum) back.  A
    /// fresh join never inherits such a slot: it always goes through
    /// [`ServingMaster::join`] (zero momentum, EASGD at the center, auto
    /// α/τ retune), preserving PR 2's joiner semantics.
    /// Returns None when the server is already shutting down (the check
    /// happens under the conns lock, so no join can slip in after a
    /// graceful shutdown froze membership and wrote its final snapshot).
    fn attach_worker(&self, reattach: bool) -> Option<(usize, u32)> {
        let mut c = sync::lock(&self.conns);
        if c.shutdown {
            return None;
        }
        let (_, _, _, slots) = self.master.status();
        let resumable = if reattach {
            (0..slots).find(|&w| {
                self.master.is_live(w) && !c.attached.get(w).copied().unwrap_or(false)
            })
        } else {
            None
        };
        let slot = resumable.unwrap_or_else(|| self.master.join());
        if slot >= c.attached.len() {
            c.attached.resize(slot + 1, false);
            c.slot_gen.resize(slot + 1, 0);
        }
        c.attached[slot] = true;
        c.slot_gen[slot] = c.slot_gen[slot].wrapping_add(1);
        Some((slot, c.slot_gen[slot]))
    }

    /// Synchronous checkpoint (explicit `Checkpoint` frame / graceful
    /// shutdown): the reply acknowledges a durable file.  Returns the
    /// snapshotted master step.
    fn write_checkpoint(&self) -> anyhow::Result<u64> {
        let path = self
            .opts
            .checkpoint_path
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("no checkpoint path configured"))?;
        let snap = self.master.snapshot()?;
        let mut last = sync::lock(&self.ckpt_gate);
        checkpoint::write_atomic(path, &snap)?;
        *last = (*last).max(snap.master_step);
        self.after_checkpoint_write(path, &snap);
        Ok(snap.master_step)
    }

    /// Post-write bookkeeping shared by every checkpoint path (gate
    /// held): stamp the scrape mirrors, then — with retention enabled —
    /// write the step-stamped archive copy and run one GC pass.  Archive
    /// and GC failures are logged, never propagated: the plain
    /// `checkpoint_path` file is already durable by the time this runs,
    /// so recovery is unaffected.
    fn after_checkpoint_write(&self, path: &Path, snap: &MasterSnapshot) {
        let bytes = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
        self.ckpt_step.store(snap.master_step, Ordering::Relaxed);
        self.ckpt_bytes.store(bytes, Ordering::Relaxed);
        self.ckpt_at_ms.store(self.started.elapsed().as_millis() as u64, Ordering::Relaxed);
        if !self.opts.retention.enabled() {
            return;
        }
        let archive = retention::archive_path(path, snap.master_step);
        if let Err(e) = checkpoint::write_atomic(&archive, snap) {
            eprintln!("net: checkpoint archive {}: {e:#}", archive.display());
            return;
        }
        if let Err(e) = retention::collect_garbage(path, self.opts.retention) {
            eprintln!("net: checkpoint retention gc: {e:#}");
        }
    }

    /// Final checkpoint for a graceful shutdown.  The shutdown flag is
    /// already set, so no *new* request is admitted — but a push that
    /// passed the gate before the flag may still be in flight and will
    /// still be PushAck'd; re-snapshot until the step count is stable so
    /// every acknowledged update is in the final file.  Terminates: the
    /// in-flight set only shrinks once the flag is up.
    fn write_final_checkpoint(&self) {
        loop {
            match self.write_checkpoint() {
                Ok(step) => {
                    if self.master.steps_done() == step {
                        return;
                    }
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => {
                    eprintln!("net: shutdown checkpoint failed: {e:#}");
                    return;
                }
            }
        }
    }

    /// Periodic-checkpoint trigger after a push.  Fires when the step
    /// count has advanced `checkpoint_every` past the last *written*
    /// snapshot (the gate value) — a monotone condition, so concurrent
    /// pushes racing the counter past a multiple cannot skip a cadence
    /// point the way a `% every == 0` check could.  The snapshot quiesces
    /// the master briefly; the expensive encode + write + fsync runs with
    /// no master state locked, behind the step-ordered write gate (which
    /// both serializes concurrent writers and drops a snapshot that raced
    /// behind a newer one).  Failures are logged, not fatal.
    fn maybe_periodic_checkpoint(&self) {
        if self.opts.checkpoint_every == 0 {
            return;
        }
        let Some(path) = self.opts.checkpoint_path.as_ref() else { return };
        {
            // Check-and-claim under the gate: while one thread snapshots
            // and writes, every other push crossing the threshold sees
            // the claimed step and skips — no redundant whole-server
            // quiesce + snapshot per racing push.
            let mut last = sync::lock(&self.ckpt_gate);
            let steps = self.master.steps_done();
            if steps < *last + self.opts.checkpoint_every {
                return;
            }
            *last = steps;
        }
        let snap = match self.master.snapshot() {
            Ok(snap) => snap,
            Err(e) => {
                eprintln!("checkpoint failed at step {}: {e:#}", self.master.steps_done());
                return;
            }
        };
        // Write under the gate (serializes with synchronous checkpoints);
        // the claim above may undershoot the snapshot's real step, so
        // record the max.
        let mut last = sync::lock(&self.ckpt_gate);
        match checkpoint::write_atomic(path, &snap) {
            Ok(()) => {
                *last = (*last).max(snap.master_step);
                self.after_checkpoint_write(path, &snap);
            }
            Err(e) => eprintln!("checkpoint failed at step {}: {e:#}", snap.master_step),
        }
    }
}

/// The status listener's view of the server.  `metrics_snapshot` is the
/// `/metrics` scrape path and reads *only* atomics (the metrics hub, the
/// striped backend's gate/membership mirrors, the drop counter, the
/// checkpoint stamps) — it shares no lock with
/// [`crate::server::ShardedParameterServer::push_concurrent`].
/// `slot_rows` backs `/status` only and may take the short conns mutex
/// and per-slot locks, never a shard or seq lock.
impl http::StatusSource for Shared {
    fn metrics_snapshot(&self) -> StatusSnapshot {
        let hub = self.master.metrics_hub();
        let (live, slots) = self.master.worker_counts();
        let pushes = hub.pushes_total();
        StatusSnapshot {
            uptime_secs: self.started.elapsed().as_secs_f64(),
            master_step: self.base_steps + pushes,
            live_workers: live,
            total_slots: slots,
            pushes_total: pushes,
            pushes_dropped: self.drops.load(Ordering::Relaxed),
            pushes_per_sec: 0.0, // filled in by the listener from deltas
            bytes_tx: hub.bytes_tx_total(),
            bytes_rx: hub.bytes_rx_total(),
            bytes_per_second: 0.0, // listener-filled, like pushes/s
            kernels: crate::math::active_kernels().name(),
            gap: hub.gap_histogram(),
            lag: hub.lag_histogram(),
            shard_gates: self.master.shard_gates(),
            checkpoint: self.checkpoint_info(),
            cluster: http::ClusterStatus {
                standby: false,
                epoch: self.opts.placement.epoch,
                takeovers: self.opts.placement.takeovers,
                shard_start: self.opts.placement.shard_start,
                shard_hosted: self.master.shard_count() as u32,
                total_shards: self.opts.placement.total_shards,
                standby_lag: None,
            },
            slots: Vec::new(),
        }
    }

    fn slot_rows(&self) -> Vec<SlotRow> {
        let table = self.master.slot_table();
        let gens: Vec<u32> = sync::lock(&self.conns).slot_gen.clone();
        table
            .iter()
            .enumerate()
            .map(|(slot, s)| SlotRow {
                slot,
                generation: gens.get(slot).copied().unwrap_or(0),
                live: s.live,
                window: s.window,
                last_push: s.last_push,
            })
            .collect()
    }
}

impl Shared {
    fn checkpoint_info(&self) -> Option<CheckpointInfo> {
        let at_ms = self.ckpt_at_ms.load(Ordering::Relaxed);
        if at_ms == u64::MAX {
            return None;
        }
        let now_ms = self.started.elapsed().as_millis() as u64;
        Some(CheckpointInfo {
            step: self.ckpt_step.load(Ordering::Relaxed),
            bytes: self.ckpt_bytes.load(Ordering::Relaxed),
            age_secs: now_ms.saturating_sub(at_ms) as f64 / 1000.0,
        })
    }
}

/// A running transport server.  Dropping it stops the accept loop (hard,
/// without a final checkpoint — see the module docs).
pub struct NetServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<std::thread::JoinHandle<()>>,
    status: Option<http::StatusServer>,
}

impl NetServer {
    /// Bind `listen` and serve `master` behind one global lock — the
    /// PR 3 reference path, kept for any [`Master`] implementation.  Use
    /// [`NetServer::start_serving`] with a
    /// [`crate::server::make_serving_master`] product for lock-striped
    /// concurrent serving.
    pub fn start(
        master: Box<dyn Master>,
        listen: &str,
        opts: ServeOptions,
    ) -> anyhow::Result<NetServer> {
        Self::start_serving(Box::new(LockedMaster::new(master)), listen, opts)
    }

    /// Bind `listen` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start serving `master`.  Slots already live in the master (a
    /// `--resume` restore) start *unattached* and are claimed by
    /// reconnecting workers; a fresh master should be built with 0
    /// workers so that connect == join.
    pub fn start_serving(
        master: Box<dyn ServingMaster>,
        listen: &str,
        opts: ServeOptions,
    ) -> anyhow::Result<NetServer> {
        let listener = TcpListener::bind(listen)
            .map_err(|e| anyhow::anyhow!("bind {listen}: {e}"))?;
        Self::start_serving_on(listener, master, opts)
    }

    /// [`Self::start_serving`] on an already-bound listener.  A standby
    /// that takes over morphs into a real server on the very listener it
    /// has been answering placement probes on — no rebind, no window
    /// where the advertised address refuses connections.
    pub fn start_serving_on(
        listener: TcpListener,
        mut master: Box<dyn ServingMaster>,
        mut opts: ServeOptions,
    ) -> anyhow::Result<NetServer> {
        let addr = listener.local_addr()?;
        // a standalone server IS the whole placement: all shards, as-is
        if opts.placement.total_shards == 0 {
            opts.placement.shard_start = 0;
            opts.placement.total_shards = master.shard_count() as u32;
        }
        // size the pull windows before the master is shared with
        // connection threads (0 = classic serving, bit-for-bit)
        master.set_pipeline_hint(opts.pipeline_depth);
        let (base_steps, _, _, slots) = master.status();
        // restored masters may carry steps the hub never saw; anchor the
        // scrape-derived step count so base + pushes_total == steps_done
        let base_steps = base_steps.saturating_sub(master.metrics_hub().pushes_total());
        let shared = Arc::new(Shared {
            master,
            conns: Mutex::new(Conns {
                attached: vec![false; slots],
                slot_gen: vec![0; slots],
                shutdown: false,
            }),
            opts,
            addr,
            ckpt_gate: Mutex::new(0),
            drops: AtomicU64::new(0),
            started: Instant::now(),
            base_steps,
            ckpt_step: AtomicU64::new(0),
            ckpt_bytes: AtomicU64::new(0),
            ckpt_at_ms: AtomicU64::new(u64::MAX),
        });
        // the status listener binds before the accept thread spawns, so a
        // bad --status-addr fails the whole start instead of leaking a
        // half-started server
        let status = match shared.opts.status_addr.clone() {
            Some(saddr) => {
                let source: Arc<dyn http::StatusSource> = Arc::clone(&shared);
                Some(http::StatusServer::start(&saddr, source)?)
            }
            None => None,
        };
        let accept_shared = Arc::clone(&shared);
        let accept = std::thread::spawn(move || accept_loop(listener, accept_shared));
        Ok(NetServer { addr, shared, accept: Some(accept), status })
    }

    /// The bound address (resolves `:0` to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// `tcp://host:port` form, ready for `--master`.
    pub fn url(&self) -> String {
        format!("tcp://{}", self.addr)
    }

    /// The bound status-listener address, when `--status-addr` was given.
    pub fn status_addr(&self) -> Option<SocketAddr> {
        self.status.as_ref().map(|s| s.addr())
    }

    /// Hard stop ("kill"): refuse all further requests and close the
    /// listener.  No final checkpoint is written; in-flight client
    /// requests observe EOF.  Blocks until the accept loop exits.
    pub fn stop(&mut self) {
        {
            let mut c = sync::lock(&self.shared.conns);
            if c.shutdown {
                if let Some(mut s) = self.status.take() {
                    drop(c);
                    s.stop();
                }
                return;
            }
            c.shutdown = true;
        }
        // wake the accept loop so it observes the flag
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(mut s) = self.status.take() {
            s.stop();
        }
    }

    /// Block until the server shuts down (a `Shutdown` control frame).
    pub fn wait(&mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(mut s) = self.status.take() {
            s.stop();
        }
    }

    /// Master steps applied so far (test/operator introspection).
    pub fn steps_done(&self) -> u64 {
        self.shared.master.steps_done()
    }

    /// The `/metrics`–`/status` source backing this server.  A standby's
    /// persistent status listener re-points here after its takeover, so
    /// the scrape endpoint survives the role change.
    pub(crate) fn status_source(&self) -> Arc<dyn http::StatusSource> {
        Arc::clone(&self.shared) as Arc<dyn http::StatusSource>
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    for stream in listener.incoming() {
        if sync::lock(&shared.conns).shutdown {
            break;
        }
        match stream {
            Ok(s) => {
                let conn_shared = Arc::clone(&shared);
                std::thread::spawn(move || {
                    if let Err(e) = handle_conn(s, conn_shared) {
                        eprintln!("net: connection error: {e:#}");
                    }
                });
            }
            Err(_) => continue, // transient accept failure
        }
    }
}

/// Conn-local reassembly buffer for a shard-sliced push (gather-then-
/// apply: nothing reaches the master until every slice has landed, so a
/// disconnect mid-group drops the group with no partial update).
struct PushGroup {
    buf: Vec<f32>,
    got: Vec<bool>,
    n_got: usize,
}

impl PushGroup {
    fn new(k: usize, shards: usize) -> PushGroup {
        PushGroup { buf: vec![0.0; k], got: vec![false; shards], n_got: 0 }
    }

    fn reset(&mut self) {
        self.got.fill(false);
        self.n_got = 0;
    }

    fn open(&self) -> bool {
        self.n_got > 0
    }

    /// Record one slice; `Ok(true)` when the group is complete.
    fn add(&mut self, shard: usize, range: Range<usize>, msg: &[f32]) -> anyhow::Result<bool> {
        anyhow::ensure!(!self.got[shard], "duplicate slice for shard {shard} in one push");
        anyhow::ensure!(
            msg.len() == range.len(),
            "shard {shard} slice length {} != shard length {}",
            msg.len(),
            range.len()
        );
        self.buf[range].copy_from_slice(msg);
        self.got[shard] = true;
        self.n_got += 1;
        Ok(self.n_got == self.got.len())
    }
}

/// One connection, handshake to EOF.  Returns Err only for reply-write
/// failures worth logging; a client disconnect is a normal return.
fn handle_conn(stream: TcpStream, shared: Arc<Shared>) -> anyhow::Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);

    // Handshake: the first frame must be Hello.
    let hub = shared.master.metrics_hub();
    let (slot, gen, reply_enc) = match wire::read_frame_sized(&mut reader) {
        Ok((Msg::Hello { role, reattach, encoding }, nread)) => {
            hub.note_rx(nread);
            let (slot, gen) = match role {
                Role::Worker => match shared.attach_worker(reattach) {
                    Some((s, g)) => (Some(s), g),
                    None => return Ok(()), // shutting down: refuse the join
                },
                Role::Control => {
                    if sync::lock(&shared.conns).shutdown {
                        return Ok(());
                    }
                    (None, 0)
                }
            };
            // Control connections stay exact (θ reads, status); a worker
            // gets the codec::grant of its request against our advertised
            // set — the client computes the same from the HelloAck mask.
            let granted = match slot {
                Some(_) => codec::grant(shared.opts.encodings, encoding),
                None => Encoding::None,
            };
            let ack = Msg::HelloAck {
                slot: slot.map(|s| s as u64).unwrap_or(u64::MAX),
                gen,
                kind: shared.master.algo_kind(),
                k: shared.master.param_len() as u64,
                shards: shared.master.shard_count() as u32,
                pipeline: shared.opts.pipeline_depth as u32,
                encodings: shared.opts.encodings.0,
                header: shared.header(),
            };
            hub.note_tx(wire::write_frame(&mut writer, &ack)?);
            (slot, gen, codec::reply_encoding(granted))
        }
        Ok(_) => {
            let _ = wire::write_frame(
                &mut writer,
                &Msg::Error { recoverable: false, detail: "expected Hello".into() },
            );
            return Ok(());
        }
        Err(_) => return Ok(()), // dropped before the handshake
    };

    // A panic while serving must not leak the slot (or poison anything for
    // good): catch it, log it, and fall through to the disconnect path so
    // the offending slot is retired like any other dead connection.
    let served = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        serve_requests(&mut reader, &mut writer, &shared, slot, gen, reply_enc)
    }));
    let served = match served {
        Ok(result) => result,
        Err(panic) => {
            let what = panic
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| panic.downcast_ref::<&str>().copied())
                .unwrap_or("non-string panic payload");
            eprintln!(
                "net: request handler panicked ({what}); retiring slot {slot:?} and \
                 keeping the server up"
            );
            Ok(())
        }
    };

    // Disconnect = leave.  Only the *current* incarnation of the slot may
    // retire it, and a shutdown freezes membership (so the state a crash
    // leaves behind matches the last checkpoint's worldview).
    if let Some(w) = slot {
        let mut c = sync::lock(&shared.conns);
        if c.slot_gen[w] == gen && c.attached[w] {
            c.attached[w] = false;
            if !c.shutdown && shared.master.is_live(w) {
                let policy = shared.opts.leave_policy;
                if let Err(e) = shared.master.leave(w, policy) {
                    eprintln!("net: retire of disconnected worker {w} failed: {e:#}");
                }
            }
        }
    }
    served
}

fn serve_requests(
    reader: &mut BufReader<TcpStream>,
    writer: &mut BufWriter<TcpStream>,
    shared: &Arc<Shared>,
    slot: Option<usize>,
    gen: u32,
    reply_enc: Encoding,
) -> anyhow::Result<()> {
    let hub = shared.master.metrics_hub();
    let ranges = shared.master.shard_ranges();
    let mut group = PushGroup::new(shared.master.param_len(), ranges.len());
    // Per-connection pull scratch: parameter replies borrow this one
    // buffer instead of allocating a fresh Vec<f32> per pull (the reply's
    // byte side already reuses the pooled `FrameBuf`).  It travels through
    // the reply `Msg` by value and is reclaimed after the write.
    let mut pull_scratch: Vec<f32> = Vec::new();
    loop {
        // EOF or a malformed (fail-closed) frame both end the connection.
        let msg = match wire::read_frame_sized(reader) {
            Ok((m, nread)) => {
                hub.note_rx(nread);
                m
            }
            Err(_) => return Ok(()),
        };
        if sync::lock(&shared.conns).shutdown {
            return Ok(()); // close without a reply: the client sees EOF
        }
        let (reply, shutdown_after) =
            dispatch(shared, slot, gen, msg, &ranges, &mut group, &mut pull_scratch);
        // Parameter replies to a quantization-granted worker go through
        // the codec writers (straight from the reply's buffer); everything
        // else — and every `none` reply — is the byte-exact `Msg` path.
        let nwrote = match &reply {
            Msg::Params { header, params } if reply_enc != Encoding::None => {
                codec::write_params(writer, header, reply_enc, params)?
            }
            Msg::ShardParams { header, shard, params } if reply_enc != Encoding::None => {
                codec::write_shard_params(writer, header, *shard, reply_enc, params)?
            }
            other => wire::write_frame(writer, other)?,
        };
        hub.note_tx(nwrote);
        // Reclaim the scratch a parameter reply carried out (keeps its
        // capacity for the next pull on this connection).
        match reply {
            Msg::Params { params, .. } | Msg::ShardParams { params, .. } => {
                pull_scratch = params;
            }
            _ => {}
        }
        if shutdown_after {
            return Ok(());
        }
    }
}

/// Validate a worker-slot request against the slot's current generation
/// and liveness.  O(1) under the conns mutex; the master operation that
/// follows runs without it.  This is race-free because only the
/// connection owning the current generation — the caller itself — can
/// retire or reuse the slot.
fn slot_ok(shared: &Shared, w: usize, gen: u32, push_gen: Option<u32>) -> bool {
    let c = sync::lock(&shared.conns);
    c.slot_gen[w] == gen
        && push_gen.map(|g| g == c.slot_gen[w]).unwrap_or(true)
        && shared.master.is_live(w)
}

/// Handle one request.  Returns the reply and whether the connection
/// should close after sending it (Shutdown).
fn dispatch(
    shared: &Shared,
    slot: Option<usize>,
    gen: u32,
    msg: Msg,
    ranges: &[Range<usize>],
    group: &mut PushGroup,
    pull_scratch: &mut Vec<f32>,
) -> (Msg, bool) {
    let recoverable = |detail: String| Msg::Error { recoverable: true, detail };
    let fatal = |detail: &str| Msg::Error { recoverable: false, detail: detail.to_string() };
    // ANY non-slice frame interleaved into an open sliced push is a
    // client bug; fail it closed and drop the half-built group (a
    // misbehaving client must not be able to complete it afterwards).
    if group.open() && slot.is_some() && !matches!(msg, Msg::PushShard { .. }) {
        group.reset();
        return (fatal("request interleaved into an incomplete sharded push"), false);
    }
    let reply = match (msg, slot) {
        (Msg::PullParams, Some(w)) => {
            if !slot_ok(shared, w, gen, None) {
                recoverable(format!("pull for retired worker slot {w}"))
            } else {
                match shared.master.pull_into(w, pull_scratch) {
                    Ok(()) => Msg::Params {
                        header: shared.header(),
                        params: std::mem::take(pull_scratch),
                    },
                    Err(e) => recoverable(format!("{e:#}")),
                }
            }
        }
        (Msg::PullShard { shard }, Some(w)) => {
            // wire shard ids are GLOBAL under the placement; map onto the
            // local table (identity for a standalone server) and refuse
            // out-of-range slices recoverably
            match shared.local_shard(shard, ranges.len()) {
                Err(detail) => recoverable(detail),
                Ok(local) => {
                    if !slot_ok(shared, w, gen, None) {
                        recoverable(format!("pull for retired worker slot {w}"))
                    } else {
                        match shared.master.pull_shard_into(w, local, pull_scratch) {
                            Ok(()) => {
                                // echo the global id: the client indexes
                                // its own placement-wide ranges by it
                                Msg::ShardParams {
                                    header: shared.header(),
                                    shard,
                                    params: std::mem::take(pull_scratch),
                                }
                            }
                            Err(e) => recoverable(format!("{e:#}")),
                        }
                    }
                }
            }
        }
        (Msg::Push { gen: push_gen, msg }, Some(w)) => {
            if !slot_ok(shared, w, gen, Some(push_gen)) {
                // a straggler from a previous incarnation of the slot
                shared.drop_push(format!("stale push for worker slot {w}"))
            } else if msg.len() != shared.master.param_len() {
                fatal(&format!(
                    "push length {} != parameter count {}",
                    msg.len(),
                    shared.master.param_len()
                ))
            } else {
                match shared.master.push(w, &msg) {
                    Ok((s, settled)) => {
                        shared.maybe_periodic_checkpoint();
                        Msg::PushAck {
                            header: shared.header(),
                            step: settled,
                            eta: s.eta,
                            gamma: s.gamma,
                            lambda: s.lambda,
                        }
                    }
                    Err(e) => shared.drop_push(format!("{e:#}")),
                }
            }
        }
        (Msg::PushStage { gen: push_gen, msg }, Some(w)) => {
            // phase 1 of a cluster two-phase apply: compute this range's
            // additive statistics partials against the worker's pending
            // pull — read-only, nothing applied, nothing staged
            if !slot_ok(shared, w, gen, Some(push_gen)) {
                recoverable(format!("staged push for retired worker slot {w}"))
            } else if msg.len() != shared.master.param_len() {
                fatal(&format!(
                    "staged push length {} != parameter count {}",
                    msg.len(),
                    shared.master.param_len()
                ))
            } else {
                match shared.master.push_stats(w, &msg) {
                    Ok(stats) => Msg::StageStats { header: shared.header(), stats },
                    Err(e) => recoverable(format!("{e:#}")),
                }
            }
        }
        (Msg::PushCommit { gen: push_gen, stats, msg }, Some(w)) => {
            // phase 2: apply the (re-sent) update under the globally
            // merged statistics — acknowledged exactly like a plain Push
            if !slot_ok(shared, w, gen, Some(push_gen)) {
                shared.drop_push(format!("stale push commit for worker slot {w}"))
            } else if msg.len() != shared.master.param_len() {
                fatal(&format!(
                    "push commit length {} != parameter count {}",
                    msg.len(),
                    shared.master.param_len()
                ))
            } else {
                match shared.master.push_with_stats(w, &msg, &stats) {
                    Ok((s, settled)) => {
                        shared.maybe_periodic_checkpoint();
                        Msg::PushAck {
                            header: shared.header(),
                            step: settled,
                            eta: s.eta,
                            gamma: s.gamma,
                            lambda: s.lambda,
                        }
                    }
                    Err(e) => shared.drop_push(format!("{e:#}")),
                }
            }
        }
        (Msg::PushShard { gen: push_gen, shard, msg }, Some(w)) => {
            let local = match shared.local_shard(shard, ranges.len()) {
                Ok(local) => local,
                Err(detail) => {
                    group.reset();
                    return (shared.drop_push(detail), false);
                }
            };
            if !slot_ok(shared, w, gen, Some(push_gen)) {
                group.reset();
                shared.drop_push(format!("stale push for worker slot {w}"))
            } else {
                match group.add(local, ranges[local].clone(), &msg) {
                    Err(e) => {
                        group.reset();
                        fatal(&format!("{e:#}"))
                    }
                    Ok(false) => Msg::Ack { header: shared.header() },
                    Ok(true) => {
                        // reset clears only the slice bookkeeping; the
                        // assembled buffer is applied below
                        group.reset();
                        match shared.master.push(w, &group.buf) {
                            Ok((s, settled)) => {
                                shared.maybe_periodic_checkpoint();
                                Msg::PushAck {
                                    header: shared.header(),
                                    step: settled,
                                    eta: s.eta,
                                    gamma: s.gamma,
                                    lambda: s.lambda,
                                }
                            }
                            Err(e) => shared.drop_push(format!("{e:#}")),
                        }
                    }
                }
            }
        }
        (Msg::Leave { policy }, Some(w)) => {
            let mut c = sync::lock(&shared.conns);
            if c.slot_gen[w] != gen || !c.attached[w] || !shared.master.is_live(w) {
                recoverable(format!("leave for already-retired slot {w}"))
            } else {
                c.attached[w] = false;
                match shared.master.leave(w, policy) {
                    Ok(()) => Msg::Ack { header: shared.header() },
                    Err(e) => recoverable(format!("{e:#}")),
                }
            }
        }
        (Msg::Status, _) => Msg::Ack { header: shared.header() },
        (Msg::GetTheta, _) => {
            Msg::Theta { header: shared.header(), theta: shared.master.theta() }
        }
        (Msg::Checkpoint, None) => match shared.write_checkpoint() {
            Ok(_) => Msg::Ack { header: shared.header() },
            Err(e) => fatal(&format!("{e:#}")),
        },
        (Msg::Shutdown, None) => {
            // freeze membership/state first, then snapshot the final
            // world (best effort, draining in-flight acknowledged
            // pushes), then wake the accept loop
            sync::lock(&shared.conns).shutdown = true;
            if shared.opts.checkpoint_path.is_some() {
                shared.write_final_checkpoint();
            }
            wake(shared.addr);
            return (Msg::Ack { header: shared.header() }, true);
        }
        (Msg::Checkpoint | Msg::Shutdown, Some(_)) => {
            fatal("control-only request on a worker connection")
        }
        (
            Msg::PullParams | Msg::Push { .. } | Msg::PullShard { .. } | Msg::PushShard { .. }
            | Msg::PushStage { .. } | Msg::PushCommit { .. } | Msg::Leave { .. },
            None,
        ) => fatal("worker request on a control connection"),
        (Msg::Hello { .. }, _) => fatal("duplicate Hello"),
        // server->client messages arriving at the server are protocol abuse
        (
            Msg::HelloAck { .. }
            | Msg::Params { .. }
            | Msg::ShardParams { .. }
            | Msg::PushAck { .. }
            | Msg::Ack { .. }
            | Msg::Theta { .. }
            | Msg::StageStats { .. }
            | Msg::Error { .. },
            _,
        ) => fatal("unexpected reply-type message"),
    };
    (reply, false)
}

/// Wake any listener blocked in accept after an in-band Shutdown: the
/// control client's connection closing is not enough, the loop needs one
/// more incoming event.  Called by the shutdown path on a best-effort
/// clone of the address.
pub(crate) fn wake(addr: SocketAddr) {
    let _ = TcpStream::connect(addr);
}

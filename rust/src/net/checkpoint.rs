//! Binary checkpoint format for [`MasterSnapshot`] — the fault-tolerance
//! half of the `net/` subsystem.
//!
//! Layout (little-endian, building on the wire codec's primitives):
//!
//! ```text
//! [b"DANACKPT"][u32 version]
//! [str kind][u64 master_step][f32 last_eta]
//! [u64 k][k × f32 theta]
//! [u64 n_slots][n × u8 live]
//! [n × (u64 window; window × ([u64 pulled_at][u64 len + f32s params]))]
//! [u32 n_state_entries] then per entry:
//!     [str name][u8 shape_tag]
//!     tag 0 (Coord):     [u64 len + f32s]
//!     tag 1 (PerWorker): [u64 count][count × (u64 len + f32s)]
//!     tag 2 (Scalars):   [u64 len + f64s]
//! [u64 fnv1a-64 of every byte above]
//! ```
//!
//! Decoding is fail-closed exactly like the wire protocol: bad magic,
//! unknown version, truncation, counts that exceed the remaining bytes,
//! trailing bytes, or a checksum mismatch are all errors — a torn or
//! corrupted file can never restore into a half-valid master.
//!
//! **Atomicity & durability.**  [`write_atomic`] writes to `<path>.tmp`
//! in the same directory, fsyncs the file, `rename(2)`s over the target,
//! and then fsyncs the **parent directory**.  The file fsync + rename
//! makes the swap atomic (a crash mid-write leaves either the previous
//! complete checkpoint or a stray `.tmp`, never a torn file); the
//! directory fsync makes it *durable* — without it, a power loss after
//! the rename can roll the directory entry back and lose the checkpoint
//! entirely, even though the write was acknowledged.  (The checksum is
//! the second line of defense, for torn *copies* of the file.)

use crate::net::wire::{put_f32, put_str, put_u32, put_u64, put_vec_f32, put_vec_f64, Dec};
use crate::optim::{StateDict, StateVec};
use crate::server::MasterSnapshot;
use std::io::Write;
use std::path::Path;

/// Checkpoint file magic.
pub const CKPT_MAGIC: [u8; 8] = *b"DANACKPT";
/// Checkpoint format version (2: per-slot pull *windows* — the pipelined
/// driver keeps up to `--pipeline-depth + 1` outstanding pulls per worker
/// — replacing v1's single sent/pulled_at/has_pulled triple).  v1 files
/// are still READ: the old triple maps losslessly onto a one-entry
/// window, so a pre-pipeline cluster's checkpoint resumes into this
/// build; writes are always v2.
pub const CKPT_VERSION: u32 = 2;

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Serialize a snapshot (checksum appended).
pub fn encode_snapshot(s: &MasterSnapshot) -> Vec<u8> {
    let mut out = Vec::with_capacity(64 + s.theta.len() * 4 * (2 + s.slots()));
    out.extend_from_slice(&CKPT_MAGIC);
    put_u32(&mut out, CKPT_VERSION);
    put_str(&mut out, s.kind.name());
    put_u64(&mut out, s.master_step);
    put_f32(&mut out, s.last_eta);
    put_vec_f32(&mut out, &s.theta);
    put_u64(&mut out, s.slots() as u64);
    for &l in &s.live {
        out.push(u8::from(l));
    }
    for window in &s.pulls {
        put_u64(&mut out, window.len() as u64);
        for (at, params) in window {
            put_u64(&mut out, *at);
            put_vec_f32(&mut out, params);
        }
    }
    put_u32(&mut out, s.state.len() as u32);
    for (name, val) in &s.state {
        put_str(&mut out, name);
        match val {
            StateVec::Coord(v) => {
                out.push(0);
                put_vec_f32(&mut out, v);
            }
            StateVec::PerWorker(vs) => {
                out.push(1);
                put_u64(&mut out, vs.len() as u64);
                for v in vs {
                    put_vec_f32(&mut out, v);
                }
            }
            StateVec::Scalars(v) => {
                out.push(2);
                put_vec_f64(&mut out, v);
            }
        }
    }
    let sum = fnv1a(&out);
    put_u64(&mut out, sum);
    out
}

/// Decode a snapshot, verifying structure and checksum.  Fail-closed.
pub fn decode_snapshot(bytes: &[u8]) -> anyhow::Result<MasterSnapshot> {
    anyhow::ensure!(bytes.len() >= 8 + 4 + 8, "checkpoint truncated ({} bytes)", bytes.len());
    let (body, sum_bytes) = bytes.split_at(bytes.len() - 8);
    let stored = u64::from_le_bytes(sum_bytes.try_into().expect("8 bytes"));
    anyhow::ensure!(
        fnv1a(body) == stored,
        "checkpoint checksum mismatch (torn or corrupted file)"
    );
    let mut d = Dec { b: body, i: 0 };
    let magic = d.take(8)?;
    anyhow::ensure!(magic == CKPT_MAGIC, "not a DANA checkpoint (magic {magic:02x?})");
    let version = d.u32()?;
    anyhow::ensure!(
        version == 1 || version == CKPT_VERSION,
        "checkpoint version {version} (this build reads 1..={CKPT_VERSION})"
    );
    let kind = d.str()?.parse()?;
    let master_step = d.u64()?;
    let last_eta = d.f32()?;
    let theta = d.vec_f32()?;
    let n = d.u64()? as usize;
    // n is bounded by the remaining bytes (1 byte per live flag minimum)
    anyhow::ensure!(n <= body.len(), "slot count {n} exceeds file size");
    let mut live = Vec::with_capacity(n);
    for _ in 0..n {
        live.push(d.u8()? != 0);
    }
    let pulls = if version == 1 {
        // v1 migration: the single sent/pulled_at/has_pulled triple is a
        // one-entry pull window (empty when the slot never pulled)
        let mut pulled_at = Vec::with_capacity(n);
        for _ in 0..n {
            pulled_at.push(d.u64()?);
        }
        let mut has_pulled = Vec::with_capacity(n);
        for _ in 0..n {
            has_pulled.push(d.u8()? != 0);
        }
        let mut pulls = Vec::with_capacity(n);
        for w in 0..n {
            let sent = d.vec_f32()?;
            pulls.push(if has_pulled[w] { vec![(pulled_at[w], sent)] } else { vec![] });
        }
        pulls
    } else {
        let mut pulls = Vec::with_capacity(n);
        for _ in 0..n {
            let window = d.u64()? as usize;
            anyhow::ensure!(window <= body.len(), "pull window {window} exceeds file size");
            let mut q = Vec::with_capacity(window.min(64));
            for _ in 0..window {
                let at = d.u64()?;
                q.push((at, d.vec_f32()?));
            }
            pulls.push(q);
        }
        pulls
    };
    let n_state = d.u32()? as usize;
    let mut state: StateDict = Vec::with_capacity(n_state.min(64));
    for _ in 0..n_state {
        let name = d.str()?.to_string();
        let val = match d.u8()? {
            0 => StateVec::Coord(d.vec_f32()?),
            1 => {
                let count = d.u64()? as usize;
                anyhow::ensure!(count <= body.len(), "per-worker count {count} exceeds file");
                let mut vs = Vec::with_capacity(count);
                for _ in 0..count {
                    vs.push(d.vec_f32()?);
                }
                StateVec::PerWorker(vs)
            }
            2 => StateVec::Scalars(d.vec_f64()?),
            other => anyhow::bail!("unknown state shape tag {other}"),
        };
        state.push((name, val));
    }
    d.done()?;
    let snap = MasterSnapshot {
        kind,
        master_step,
        last_eta,
        theta,
        live,
        pulls,
        state,
    };
    snap.validate(kind, snap.theta.len())?;
    Ok(snap)
}

/// fsync the directory containing `path`, making a just-renamed entry
/// durable.  On non-Unix platforms directory handles cannot be fsynced;
/// there the rename itself is the best available barrier and this is a
/// no-op.  `pub(crate)`: retention GC (`net/retention.rs`) uses the same
/// barrier after unlinking expired archives.
pub(crate) fn sync_parent_dir(path: &Path) -> std::io::Result<()> {
    #[cfg(unix)]
    {
        let dir = match path.parent() {
            Some(p) if !p.as_os_str().is_empty() => p,
            _ => Path::new("."),
        };
        std::fs::File::open(dir)?.sync_all()
    }
    #[cfg(not(unix))]
    {
        let _ = path;
        Ok(())
    }
}

/// Write a snapshot to `path` atomically and durably:
/// `<path>.tmp` + file fsync + rename + parent-directory fsync.
/// The `.tmp` suffix is *appended* (not substituted for the extension),
/// so `run.ckpt` and `run.bin` in one directory never share a tmp file.
pub fn write_atomic(path: &Path, snap: &MasterSnapshot) -> anyhow::Result<()> {
    let bytes = encode_snapshot(snap);
    let mut tmp_name = path
        .file_name()
        .ok_or_else(|| anyhow::anyhow!("checkpoint path {} has no file name", path.display()))?
        .to_os_string();
    tmp_name.push(".tmp");
    let tmp = path.with_file_name(tmp_name);
    {
        let mut f = std::fs::File::create(&tmp)
            .map_err(|e| anyhow::anyhow!("create {}: {e}", tmp.display()))?;
        f.write_all(&bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)
        .map_err(|e| anyhow::anyhow!("rename {} -> {}: {e}", tmp.display(), path.display()))?;
    // The rename is atomic but not durable until the directory entry
    // itself is on disk; failing here must fail the checkpoint LOUDLY —
    // callers treat Ok as "safe to delete the previous generation".
    sync_parent_dir(path)
        .map_err(|e| anyhow::anyhow!("fsync parent dir of {}: {e}", path.display()))?;
    Ok(())
}

/// Read and decode a checkpoint file.
pub fn read_snapshot(path: &Path) -> anyhow::Result<MasterSnapshot> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("read checkpoint {}: {e}", path.display()))?;
    decode_snapshot(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::AlgorithmKind;

    fn sample() -> MasterSnapshot {
        MasterSnapshot {
            kind: AlgorithmKind::DanaZero,
            master_step: 41,
            last_eta: 0.0125,
            theta: vec![1.5, -2.25, 0.0],
            live: vec![true, false, true],
            // slot 0 carries a depth-2 pipeline window, slot 1 is retired
            // (empty window), slot 2 the classic single entry
            pulls: vec![
                vec![(39, vec![0.5; 3]), (40, vec![0.25; 3])],
                vec![],
                vec![(39, vec![-1.0; 3])],
            ],
            state: vec![
                (
                    "v".to_string(),
                    StateVec::PerWorker(vec![vec![0.1; 3], vec![0.0; 3], vec![-0.2; 3]]),
                ),
                ("vsum".to_string(), StateVec::Coord(vec![-0.1; 3])),
            ],
        }
    }

    #[test]
    fn snapshot_codec_round_trips() {
        let s = sample();
        let bytes = encode_snapshot(&s);
        let back = decode_snapshot(&bytes).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn v1_checkpoints_migrate_to_one_entry_windows() {
        // Hand-encode the v1 layout (single sent/pulled_at/has_pulled
        // triple per slot): a pre-pipeline cluster's checkpoint must
        // resume into this build, each slot migrating to a one-entry
        // window (empty when it never pulled).
        let mut out = Vec::new();
        out.extend_from_slice(&CKPT_MAGIC);
        put_u32(&mut out, 1);
        put_str(&mut out, "dana-zero");
        put_u64(&mut out, 41); // master_step
        put_f32(&mut out, 0.0125); // last_eta
        put_vec_f32(&mut out, &[1.5, -2.25, 0.0]);
        put_u64(&mut out, 3); // slots
        for l in [1u8, 0, 1] {
            out.push(l);
        }
        for p in [40u64, 0, 39] {
            put_u64(&mut out, p);
        }
        for h in [1u8, 0, 1] {
            out.push(h);
        }
        for sent in [[0.5f32; 3], [0.0; 3], [-1.0; 3]] {
            put_vec_f32(&mut out, &sent);
        }
        put_u32(&mut out, 2); // state entries
        put_str(&mut out, "v");
        out.push(1);
        put_u64(&mut out, 3);
        for v in [[0.1f32; 3], [0.0; 3], [-0.2f32; 3]] {
            put_vec_f32(&mut out, &v);
        }
        put_str(&mut out, "vsum");
        out.push(0);
        put_vec_f32(&mut out, &[-0.1f32; 3]);
        let sum = fnv1a(&out);
        put_u64(&mut out, sum);

        let snap = decode_snapshot(&out).unwrap();
        assert_eq!(snap.master_step, 41);
        assert_eq!(snap.live, vec![true, false, true]);
        assert_eq!(snap.pulls[0], vec![(40, vec![0.5; 3])]);
        assert!(snap.pulls[1].is_empty(), "never-pulled slot → empty window");
        assert_eq!(snap.pulls[2], vec![(39, vec![-1.0; 3])]);
        // and the v2 re-encode of the migrated snapshot round-trips
        assert_eq!(decode_snapshot(&encode_snapshot(&snap)).unwrap(), snap);
    }

    #[test]
    fn corruption_is_rejected_not_panicked() {
        let bytes = encode_snapshot(&sample());
        // truncation at every prefix length
        for cut in 0..bytes.len() {
            assert!(decode_snapshot(&bytes[..cut]).is_err(), "cut={cut}");
        }
        // single-byte corruption anywhere trips the checksum (or a
        // structural check)
        for i in (0..bytes.len()).step_by(7) {
            let mut bad = bytes.clone();
            bad[i] ^= 0x40;
            assert!(decode_snapshot(&bad).is_err(), "flip at {i}");
        }
        // trailing garbage
        let mut long = bytes.clone();
        long.push(0);
        assert!(decode_snapshot(&long).is_err());
    }

    /// The durability sequence: tmp write + fsync, rename, parent-dir
    /// fsync — and a parent fsync failure surfaces as a checkpoint error
    /// instead of an acknowledged-but-volatile write.
    #[test]
    #[cfg(unix)]
    fn rename_is_followed_by_a_parent_dir_fsync() {
        let dir = std::env::temp_dir().join(format!("dana-ckpt-sync-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.bin");
        write_atomic(&path, &sample()).unwrap();
        // the tmp is gone (renamed, not left behind) and the entry reads
        assert!(!dir.join("ckpt.bin.tmp").exists());
        assert_eq!(read_snapshot(&path).unwrap(), sample());
        // sync_parent_dir on the live file succeeds...
        sync_parent_dir(&path).unwrap();
        // ...and fails loudly when the parent directory cannot be opened,
        // which write_atomic propagates (no silent volatile success)
        let orphan = dir.join("no-such-subdir").join("ckpt.bin");
        assert!(sync_parent_dir(&orphan).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn atomic_write_round_trips_and_replaces() {
        let dir = std::env::temp_dir().join(format!("dana-ckpt-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.bin");
        let mut s = sample();
        write_atomic(&path, &s).unwrap();
        assert_eq!(read_snapshot(&path).unwrap(), s);
        s.master_step = 99;
        write_atomic(&path, &s).unwrap();
        assert_eq!(read_snapshot(&path).unwrap().master_step, 99);
        assert!(!dir.join("ckpt.bin.tmp").exists(), "tmp cleaned up");
        // distinct targets sharing a stem must not share a tmp file
        let sibling = dir.join("ckpt.other");
        write_atomic(&sibling, &s).unwrap();
        assert!(read_snapshot(&sibling).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }
}

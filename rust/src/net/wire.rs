//! The DANA wire protocol — a versioned, length-prefixed binary framing
//! over any `Read`/`Write` byte stream (TCP in practice).
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//! [u32 body_len][b"DANA"][u8 version][u8 tag][payload...]
//! ```
//!
//! Parameter payloads are *tagged* (see [`crate::net::codec`]): a
//! one-byte encoding tag followed by the vector in that encoding.  The
//! default encoding (`none`, tag 0) is raw little-endian f32s, so a
//! loopback round trip is bit-exact — the loopback equivalence suite
//! (`rust/tests/net.rs`) pins `RemoteMaster` trajectories bit-for-bit
//! against the in-process drivers for every algorithm.
//!
//! Decoding is **fail-closed**: a truncated frame, wrong magic, unknown
//! version, unknown tag, unknown payload encoding, oversized length
//! prefix, an inner count that exceeds the remaining bytes, or trailing
//! bytes after the payload all produce an error (never a panic, never a
//! partial message).  The peer that sent the bad frame is disconnected
//! by the caller.  Encoding is fail-closed *symmetrically*:
//! [`write_frame`] computes the exact body length up front
//! ([`Msg::body_len`]) and refuses a frame over [`MAX_FRAME`] before
//! serializing a byte — the length prefix can never silently truncate
//! into something the decoder then misparses.
//!
//! Version 2 adds shard-sliced transfers for the lock-striped server:
//! [`Msg::HelloAck`] carries the server's shard count, [`Msg::PullShard`]
//! fetches one shard's parameter slice ([`Msg::ShardParams`] reply), and
//! [`Msg::PushShard`] delivers one shard's slice of an update — the
//! server assembles a worker's slices and applies them as a single master
//! step when the last one lands (gather-then-apply, so a worker dying
//! mid-group leaves no partial update).
//!
//! Version 3 is the pipelined-worker addition: [`Msg::PushAck`] carries
//! the master step the push *settled as* (its ticket), so a pipelined
//! client harvesting deferred acknowledgements knows exactly which
//! in-flight push each ack settles; [`Header`] carries the server's
//! cumulative dropped-push count (stale-generation / retired-slot
//! rejections) so `Status` surfaces silently discarded work; and
//! [`Msg::HelloAck`] carries the server's configured pipeline depth so a
//! client can warn when its `--pipeline-depth` disagrees with the
//! server's window accounting.
//!
//! Version 4 adds negotiated payload compression and the pooled
//! zero-copy frame path: [`Msg::Hello`] carries the worker's requested
//! [`Encoding`] and [`Msg::HelloAck`] the server's advertised
//! [`crate::net::codec::EncodingSet`] (both sides compute the same
//! [`crate::net::codec::grant`], so no extra round trip); the four
//! vector-bearing frames (`Push`/`PushShard`/`Params`/`ShardParams`)
//! carry the per-payload encoding tag described above; and frame
//! building/reading goes through a thread-local buffer pool
//! ([`with_frame_buf`]) plus [`Msg::encode_into`] /
//! [`crate::net::codec::write_push`]-style borrowed-slice writers, so
//! the steady-state worker cycle allocates nothing on the push path.
//!
//! Version 5 is the placement layer: [`Header`] — piggybacked on every
//! reply — advertises the server's hosted shard *range* within the
//! global placement (`shard_start`/`shard_hosted`/`total_shards`), its
//! monotonically-increasing placement `epoch` (a standby that takes a
//! dead primary's range over restarts it at `epoch + 1`, fencing the
//! stale primary: a client that recorded a newer epoch for the range
//! refuses to follow an older claimant), and a `standby` flag (a hot
//! standby answers control probes but serves no workers until
//! takeover).  Two new frames carry YellowFin's two-phase cluster
//! apply: [`Msg::PushStage`] asks a server for the additive
//! [`ApplyStats`] partials of an update *without applying it* (reply
//! [`Msg::StageStats`]), and [`Msg::PushCommit`] applies the update
//! under the globally-summed statistics — which is how a fan-out client
//! keeps YellowFin's whole-vector tuner reductions exact when the
//! coordinate range is split across servers.  Stage/commit payloads are
//! always exact (never quantized): they exist for bit-equivalence.
//!
//! Algorithm kinds and leave policies travel as their canonical names (the
//! same strings the CLI parses), so the protocol does not depend on enum
//! discriminant order; an unknown name is a decode error.

use crate::net::codec::{self, Encoding};
use crate::optim::{AlgorithmKind, ApplyStats, LeavePolicy, Step};
use std::cell::RefCell;
use std::io::{Read, Write};

/// Frame magic — rejects non-DANA peers and stream desync immediately.
pub const MAGIC: [u8; 4] = *b"DANA";
/// Protocol version; bumped on any incompatible change (2: shard-sliced
/// PullShard/PushShard/ShardParams frames + shard count in HelloAck;
/// 3: settled step in PushAck, dropped-push count in Header, pipeline
/// depth in HelloAck; 4: negotiated payload encodings — requested
/// encoding in Hello, advertised set in HelloAck, a payload-encoding
/// tag on every parameter vector; 5: placement advertisement in Header
/// — hosted shard range, placement epoch, standby flag — plus the
/// PushStage/StageStats/PushCommit frames for the fan-out client's
/// two-phase YellowFin apply).
pub const VERSION: u8 = 5;
/// Upper bound on one frame body (1 GiB ≈ 256M f32 parameters).
pub const MAX_FRAME: u32 = 1 << 30;

/// What a connection is for, declared in its first frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// The connection IS a worker: accepting it joins the cluster
    /// (`add_worker`), EOF leaves it (`remove_worker`).
    Worker,
    /// Observer/operator connection: status, θ reads, checkpoint and
    /// shutdown requests.  Never owns a worker slot.
    Control,
}

/// Server state piggybacked on every reply, so clients track the master
/// step and current schedule point without extra round trips (the sim
/// driver's `step_now()` is a cache read, not a network call).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Header {
    /// Master steps applied so far.
    pub master_step: u64,
    /// Schedule hyperparameters at `master_step` (the *next* apply).
    pub eta: f32,
    pub gamma: f32,
    pub lambda: f32,
    /// Live workers / slot high-water mark, cluster-wide.
    pub live_workers: u64,
    pub worker_slots: u64,
    /// Pushes the server has dropped (recoverably rejected) so far:
    /// stale-generation stragglers and retired-slot races.  Cumulative
    /// over the server's lifetime, so deltas across `Status` reads count
    /// drops in a window.
    pub pushes_dropped: u64,
    /// Placement epoch of this server's claim on its shard range.
    /// Monotonically increasing per range: a standby taking over
    /// advertises the dead primary's last-seen epoch + 1.  Clients fence
    /// on it — once a newer epoch has been observed for a range, replies
    /// and claims carrying an older one are refused (a resurrected stale
    /// primary cannot win its range back without a fresh, higher epoch).
    pub epoch: u64,
    /// First global placement shard hosted by this server.
    pub shard_start: u32,
    /// Number of contiguous global shards hosted here ([`shard_start`,
    /// `shard_start + shard_hosted`)).
    pub shard_hosted: u32,
    /// Global placement shard count.  `shard_hosted == total_shards`
    /// means the server hosts the whole model (standalone).
    pub total_shards: u32,
    /// 1 while the peer is a hot standby: it answers control probes
    /// (this header included) but serves no worker traffic until it
    /// takes its primary's range over.
    pub standby: u8,
}

impl Header {
    /// The schedule point as a [`Step`].
    pub fn step(&self) -> Step {
        Step { eta: self.eta, gamma: self.gamma, lambda: self.lambda }
    }
}

/// Every message of the protocol.  Client→server requests first, then
/// server→client replies.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// First frame on every connection.  `reattach` distinguishes a
    /// returning worker (may claim a live slot a checkpoint restore left
    /// unattached, inheriting its momentum) from a genuinely fresh join
    /// (always `Master::add_worker`: zero momentum, EASGD at the center).
    /// `encoding` is the payload encoding this worker *requests* for its
    /// pushes (granted iff the server advertises it; see
    /// [`crate::net::codec::grant`]).  Control connections ignore both.
    Hello { role: Role, reattach: bool, encoding: Encoding },
    /// Worker: pull parameters (the algorithm's send — θ or look-ahead).
    PullParams,
    /// Worker: deliver an update vector.  `gen` echoes the generation
    /// assigned at [`Msg::HelloAck`]; a push whose generation no longer
    /// matches the slot's (the slot was retired and reused while this
    /// message was in flight) is rejected recoverably.
    Push { gen: u32, msg: Vec<f32> },
    /// Worker: leave the cluster deliberately, with an explicit policy
    /// (EOF without Leave uses the server's configured default).
    Leave { policy: LeavePolicy },
    /// Worker: pull one shard's parameter slice (shard indices are
    /// `0..HelloAck::shards`; ranges follow
    /// [`crate::server::shard_bounds`]).  A worker's sliced pulls count
    /// as one full pull once every shard has been fetched.
    PullShard { shard: u32 },
    /// Worker: one shard's slice of an update.  Slices of one logical
    /// push may arrive in any order, each shard at most once; the server
    /// buffers them per connection and applies the assembled update as a
    /// single master step when the last slice lands (that slice is
    /// answered with [`Msg::PushAck`], earlier ones with [`Msg::Ack`]).
    /// `gen` echoes the slot generation exactly like [`Msg::Push`].
    PushShard { gen: u32, shard: u32, msg: Vec<f32> },
    /// Worker: phase 1 of a two-phase (fan-out) push — compute the
    /// additive [`ApplyStats`] partials this update would produce over
    /// this server's coordinate range, *without applying anything*.
    /// Reply: [`Msg::StageStats`].  The payload is always exact (raw
    /// f32s, never the negotiated encoding): staging exists to keep
    /// YellowFin's whole-vector reductions bit-equal across a split.
    PushStage { gen: u32, msg: Vec<f32> },
    /// Worker: phase 2 — apply the update as one master step using the
    /// provided globally-summed statistics instead of locally computed
    /// ones.  Reply: [`Msg::PushAck`], exactly like [`Msg::Push`].
    PushCommit { gen: u32, stats: ApplyStats, msg: Vec<f32> },
    /// Control: force a checkpoint write now.
    Checkpoint,
    /// Control: refresh the header.
    Status,
    /// Control: fetch the master parameters (final eval).
    GetTheta,
    /// Control: stop accepting connections and wind the server down.
    Shutdown,

    /// Reply to [`Msg::Hello`].  For workers, `slot`/`gen` identify the
    /// claimed worker slot; control connections get `slot == u64::MAX`.
    /// `shards` is the server's slice granularity for
    /// [`Msg::PullShard`]/[`Msg::PushShard`] (1 = unsliced serving);
    /// `pipeline` is the server's configured pull-window depth
    /// (`dana serve --pipeline-depth`); `encodings` is the server's
    /// advertised [`crate::net::codec::EncodingSet`] bitmask.
    HelloAck {
        slot: u64,
        gen: u32,
        kind: AlgorithmKind,
        k: u64,
        shards: u32,
        pipeline: u32,
        encodings: u32,
        header: Header,
    },
    /// Reply to [`Msg::PullParams`].
    Params { header: Header, params: Vec<f32> },
    /// Reply to [`Msg::PullShard`].
    ShardParams { header: Header, shard: u32, params: Vec<f32> },
    /// Reply to [`Msg::Push`]: the [`Step`] that was applied and `step`,
    /// the master step the push settled as (its ticket) — what a
    /// pipelined client's deferred-ack harvest accounts against.
    PushAck { header: Header, step: u64, eta: f32, gamma: f32, lambda: f32 },
    /// Generic success reply (Leave/Checkpoint/Shutdown/Status).
    Ack { header: Header },
    /// Reply to [`Msg::GetTheta`].
    Theta { header: Header, theta: Vec<f32> },
    /// Reply to [`Msg::PushStage`]: this server's additive statistics
    /// partials for the staged update (nothing was applied).
    StageStats { header: Header, stats: ApplyStats },
    /// Error reply.  `recoverable` distinguishes a droppable condition (a
    /// straggler push after leave) from a fatal one (protocol misuse).
    Error { recoverable: bool, detail: String },
}

// ---------------------------------------------------------------- encode

pub(crate) fn put_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

pub(crate) fn put_u64(out: &mut Vec<u8>, x: u64) {
    out.extend_from_slice(&x.to_le_bytes());
}

pub(crate) fn put_f32(out: &mut Vec<u8>, x: f32) {
    out.extend_from_slice(&x.to_le_bytes());
}

pub(crate) fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

pub(crate) fn put_vec_f32(out: &mut Vec<u8>, v: &[f32]) {
    put_u64(out, v.len() as u64);
    out.reserve(v.len() * 4);
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

pub(crate) fn put_f64(out: &mut Vec<u8>, x: f64) {
    out.extend_from_slice(&x.to_le_bytes());
}

pub(crate) fn put_header(out: &mut Vec<u8>, h: &Header) {
    put_u64(out, h.master_step);
    put_f32(out, h.eta);
    put_f32(out, h.gamma);
    put_f32(out, h.lambda);
    put_u64(out, h.live_workers);
    put_u64(out, h.worker_slots);
    put_u64(out, h.pushes_dropped);
    put_u64(out, h.epoch);
    put_u32(out, h.shard_start);
    put_u32(out, h.shard_hosted);
    put_u32(out, h.total_shards);
    out.push(h.standby);
}

/// [`ApplyStats`] on the wire: four little-endian f64s.
pub(crate) fn put_stats(out: &mut Vec<u8>, s: &ApplyStats) {
    put_f64(out, s.msg_norm2);
    put_f64(out, s.g_avg_norm2);
    put_f64(out, s.prev_dot);
    put_f64(out, s.prev_norm2);
}

/// Encoded size of [`put_stats`].
pub(crate) const STATS_LEN: usize = 4 * 8;

impl Msg {
    fn tag(&self) -> u8 {
        match self {
            Msg::Hello { .. } => 1,
            Msg::PullParams => 2,
            Msg::Push { .. } => 3,
            Msg::Leave { .. } => 4,
            Msg::Checkpoint => 5,
            Msg::Status => 6,
            Msg::GetTheta => 7,
            Msg::Shutdown => 8,
            Msg::PullShard { .. } => 9,
            Msg::PushShard { .. } => 10,
            Msg::PushStage { .. } => 11,
            Msg::PushCommit { .. } => 12,
            Msg::HelloAck { .. } => 16,
            Msg::Params { .. } => 17,
            Msg::PushAck { .. } => 18,
            Msg::Ack { .. } => 19,
            Msg::Theta { .. } => 20,
            Msg::Error { .. } => 21,
            Msg::ShardParams { .. } => 22,
            Msg::StageStats { .. } => 23,
        }
    }

    /// Exact encoded body length (magic + version + tag + payload, without
    /// the length prefix), computed arithmetically — [`write_frame`] uses
    /// it to reject an oversized frame *before* serializing anything, and
    /// [`Msg::encode_into`] to reserve the whole frame in one shot.
    /// Parameter vectors count 1 extra byte for the payload-encoding tag
    /// (the `Msg` path always writes them as `none`; compressed frames go
    /// through the [`crate::net::codec`] writers, which size themselves
    /// with [`crate::net::codec::payload_wire_len`]).
    pub fn body_len(&self) -> usize {
        const HDR: usize = 8 + 4 + 4 + 4 + 8 + 8 + 8 + 8 + 4 + 4 + 4 + 1; // Header
        let payload = match self {
            Msg::Hello { .. } => 2 + 1 + 4,
            Msg::PullParams | Msg::Checkpoint | Msg::Status | Msg::GetTheta | Msg::Shutdown => 0,
            Msg::Push { msg, .. } => 4 + 1 + 8 + 4 * msg.len(),
            Msg::Leave { policy } => 4 + policy.name().len(),
            Msg::PullShard { .. } => 4,
            Msg::PushShard { msg, .. } => 4 + 4 + 1 + 8 + 4 * msg.len(),
            Msg::PushStage { msg, .. } => 4 + 8 + 4 * msg.len(),
            Msg::PushCommit { msg, .. } => 4 + STATS_LEN + 8 + 4 * msg.len(),
            Msg::StageStats { .. } => HDR + STATS_LEN,
            Msg::HelloAck { kind, .. } => 8 + 4 + (4 + kind.name().len()) + 8 + 4 + 4 + 4 + HDR,
            Msg::Params { params, .. } => HDR + 1 + 8 + 4 * params.len(),
            Msg::ShardParams { params, .. } => HDR + 4 + 1 + 8 + 4 * params.len(),
            Msg::PushAck { .. } => HDR + 8 + 12,
            Msg::Ack { .. } => HDR,
            Msg::Theta { theta, .. } => HDR + 8 + 4 * theta.len(),
            Msg::Error { detail, .. } => 1 + 4 + detail.len(),
        };
        4 + 1 + 1 + payload // magic + version + tag
    }

    /// Serialize one frame (length prefix included) into `frame`,
    /// clearing it first.  The buffer is pre-reserved to the exact frame
    /// size via [`Self::body_len`], so a pooled buffer reaches its
    /// steady-state capacity once and never reallocates again.  Callers
    /// that reach a wire go through [`write_frame`], which enforces
    /// [`MAX_FRAME`]; this method itself asserts only internal
    /// consistency with [`Self::body_len`].
    pub fn encode_into(&self, frame: &mut Vec<u8>) {
        frame.clear();
        let body_len = self.body_len();
        frame.reserve(4 + body_len);
        put_u32(frame, body_len as u32);
        frame.extend_from_slice(&MAGIC);
        frame.push(VERSION);
        frame.push(self.tag());
        match self {
            Msg::Hello { role, reattach, encoding } => {
                frame.push(match role {
                    Role::Worker => 0,
                    Role::Control => 1,
                });
                frame.push(u8::from(*reattach));
                frame.push(encoding.tag());
                put_u32(frame, encoding.param());
            }
            Msg::PullParams | Msg::Checkpoint | Msg::Status | Msg::GetTheta | Msg::Shutdown => {}
            Msg::Push { gen, msg } => {
                put_u32(frame, *gen);
                codec::put_payload(frame, Encoding::None, msg);
            }
            Msg::Leave { policy } => put_str(frame, policy.name()),
            Msg::PullShard { shard } => put_u32(frame, *shard),
            Msg::PushShard { gen, shard, msg } => {
                put_u32(frame, *gen);
                put_u32(frame, *shard);
                codec::put_payload(frame, Encoding::None, msg);
            }
            Msg::PushStage { gen, msg } => {
                put_u32(frame, *gen);
                put_vec_f32(frame, msg);
            }
            Msg::PushCommit { gen, stats, msg } => {
                put_u32(frame, *gen);
                put_stats(frame, stats);
                put_vec_f32(frame, msg);
            }
            Msg::StageStats { header, stats } => {
                put_header(frame, header);
                put_stats(frame, stats);
            }
            Msg::HelloAck { slot, gen, kind, k, shards, pipeline, encodings, header } => {
                put_u64(frame, *slot);
                put_u32(frame, *gen);
                put_str(frame, kind.name());
                put_u64(frame, *k);
                put_u32(frame, *shards);
                put_u32(frame, *pipeline);
                put_u32(frame, *encodings);
                put_header(frame, header);
            }
            Msg::Params { header, params } => {
                put_header(frame, header);
                codec::put_payload(frame, Encoding::None, params);
            }
            Msg::ShardParams { header, shard, params } => {
                put_header(frame, header);
                put_u32(frame, *shard);
                codec::put_payload(frame, Encoding::None, params);
            }
            Msg::PushAck { header, step, eta, gamma, lambda } => {
                put_header(frame, header);
                put_u64(frame, *step);
                put_f32(frame, *eta);
                put_f32(frame, *gamma);
                put_f32(frame, *lambda);
            }
            Msg::Ack { header } => put_header(frame, header),
            Msg::Theta { header, theta } => {
                put_header(frame, header);
                put_vec_f32(frame, theta);
            }
            Msg::Error { recoverable, detail } => {
                frame.push(u8::from(*recoverable));
                put_str(frame, detail);
            }
        }
        debug_assert_eq!(frame.len(), 4 + body_len, "body_len out of sync with encode");
    }

    /// Serialize into one freshly allocated frame (length prefix
    /// included) — the non-pooled convenience over [`Self::encode_into`].
    pub fn encode(&self) -> Vec<u8> {
        let mut frame = Vec::new();
        self.encode_into(&mut frame);
        frame
    }

    /// Decode one frame *body* (magic/version/tag/payload, without the
    /// length prefix).  Fail-closed; see the module docs.  Parameter
    /// payloads are densified to `Vec<f32>` here — exactly once per
    /// frame, whatever their wire encoding — so everything above this
    /// layer (masters, ticket gates, tests) sees dense vectors.
    pub fn decode(body: &[u8]) -> anyhow::Result<Msg> {
        let mut d = Dec { b: body, i: 0 };
        let magic = d.take(4)?;
        anyhow::ensure!(magic == MAGIC, "bad magic {magic:02x?}");
        let version = d.u8()?;
        anyhow::ensure!(
            version == VERSION,
            "protocol version {version} (this build speaks {VERSION})"
        );
        let tag = d.u8()?;
        let msg = match tag {
            1 => Msg::Hello {
                role: match d.u8()? {
                    0 => Role::Worker,
                    1 => Role::Control,
                    other => anyhow::bail!("unknown role {other}"),
                },
                reattach: d.u8()? != 0,
                encoding: {
                    let tag = d.u8()?;
                    let param = d.u32()?;
                    Encoding::from_wire(tag, param)?
                },
            },
            2 => Msg::PullParams,
            3 => Msg::Push { gen: d.u32()?, msg: codec::get_payload(&mut d)? },
            4 => Msg::Leave { policy: d.str()?.parse()? },
            5 => Msg::Checkpoint,
            6 => Msg::Status,
            7 => Msg::GetTheta,
            8 => Msg::Shutdown,
            9 => Msg::PullShard { shard: d.u32()? },
            10 => Msg::PushShard {
                gen: d.u32()?,
                shard: d.u32()?,
                msg: codec::get_payload(&mut d)?,
            },
            11 => Msg::PushStage { gen: d.u32()?, msg: d.vec_f32()? },
            12 => Msg::PushCommit { gen: d.u32()?, stats: d.stats()?, msg: d.vec_f32()? },
            23 => Msg::StageStats { header: d.header()?, stats: d.stats()? },
            16 => Msg::HelloAck {
                slot: d.u64()?,
                gen: d.u32()?,
                kind: d.str()?.parse()?,
                k: d.u64()?,
                shards: d.u32()?,
                pipeline: d.u32()?,
                encodings: d.u32()?,
                header: d.header()?,
            },
            17 => Msg::Params { header: d.header()?, params: codec::get_payload(&mut d)? },
            22 => Msg::ShardParams {
                header: d.header()?,
                shard: d.u32()?,
                params: codec::get_payload(&mut d)?,
            },
            18 => Msg::PushAck {
                header: d.header()?,
                step: d.u64()?,
                eta: d.f32()?,
                gamma: d.f32()?,
                lambda: d.f32()?,
            },
            19 => Msg::Ack { header: d.header()? },
            20 => Msg::Theta { header: d.header()?, theta: d.vec_f32()? },
            21 => Msg::Error { recoverable: d.u8()? != 0, detail: d.str()?.to_string() },
            other => anyhow::bail!("unknown message tag {other}"),
        };
        d.done()?;
        Ok(msg)
    }
}

// ------------------------------------------------------------ frame pool

thread_local! {
    /// Per-thread frame-buffer pool.  Every connection-handling loop and
    /// every hot-path writer borrows scratch from here, so the second
    /// and every later frame on a thread reuses the same steady-state
    /// allocation instead of growing a fresh `Vec` per frame.
    static FRAME_BUFS: RefCell<Vec<Vec<u8>>> = RefCell::new(Vec::new());
}

/// Keep at most this many buffers per thread…
const POOL_BUFS: usize = 8;
/// …and never pool a buffer that grew past this capacity (one giant
/// `Theta` transfer must not pin gigabytes on a serving thread).
const POOL_CAP: usize = 16 << 20;

/// Run `f` with a pooled scratch buffer (contents undefined — clear it).
/// Reentrancy-safe: the pool is a stack and the borrow is released
/// before `f` runs, so nested calls simply take distinct buffers.
pub(crate) fn with_frame_buf<T>(f: impl FnOnce(&mut Vec<u8>) -> T) -> T {
    let mut buf = FRAME_BUFS.with(|p| p.borrow_mut().pop()).unwrap_or_default();
    let out = f(&mut buf);
    if buf.capacity() <= POOL_CAP {
        FRAME_BUFS.with(|p| {
            let mut p = p.borrow_mut();
            if p.len() < POOL_BUFS {
                buf.clear();
                p.push(buf);
            }
        });
    }
    out
}

/// Write one message as a frame and flush, returning the frame's size on
/// the wire (length prefix included) for byte accounting.  Fail-closed
/// symmetrically with [`read_frame`]: a body over [`MAX_FRAME`] is
/// refused *before* serialization — without this, the `u32` length
/// prefix would silently truncate and the peer's fail-closed decoder
/// would tear the stream.  The frame is built in a pooled buffer
/// ([`with_frame_buf`]), so steady-state writes allocate nothing.
pub fn write_frame<W: Write>(w: &mut W, msg: &Msg) -> std::io::Result<usize> {
    let n = msg.body_len();
    if n > MAX_FRAME as usize {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("refusing to encode a {n}-byte frame body (cap {MAX_FRAME})"),
        ));
    }
    with_frame_buf(|buf| {
        msg.encode_into(buf);
        w.write_all(buf)?;
        w.flush()?;
        Ok(4 + n)
    })
}

/// Read one frame and decode it.  Any transport error (including EOF,
/// which the servers treat as a worker leave) surfaces as `Err`.
pub fn read_frame<R: Read>(r: &mut R) -> anyhow::Result<Msg> {
    Ok(read_frame_sized(r)?.0)
}

/// [`read_frame`] plus the frame's size on the wire (length prefix
/// included), for byte accounting.  The body is staged in a pooled
/// buffer, so steady-state reads allocate only the decoded message.
pub fn read_frame_sized<R: Read>(r: &mut R) -> anyhow::Result<(Msg, usize)> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len);
    anyhow::ensure!(len <= MAX_FRAME, "frame length {len} exceeds cap {MAX_FRAME}");
    anyhow::ensure!(len >= 6, "frame length {len} shorter than the header");
    with_frame_buf(|body| {
        body.clear();
        body.resize(len as usize, 0);
        r.read_exact(body)?;
        Ok((Msg::decode(body)?, 4 + len as usize))
    })
}

// ---------------------------------------------------------------- decode

/// Bounds-checked little-endian cursor (fail-closed on truncation).
pub(crate) struct Dec<'a> {
    pub(crate) b: &'a [u8],
    pub(crate) i: usize,
}

impl<'a> Dec<'a> {
    pub(crate) fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            n <= self.b.len() - self.i,
            "truncated: wanted {n} bytes, {} left",
            self.b.len() - self.i
        );
        let out = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(out)
    }

    pub(crate) fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    pub(crate) fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    pub(crate) fn f32(&mut self) -> anyhow::Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    pub(crate) fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    pub(crate) fn str(&mut self) -> anyhow::Result<&'a str> {
        let n = self.u32()? as usize;
        Ok(std::str::from_utf8(self.take(n)?)?)
    }

    /// f32 vector with its count validated against the remaining bytes
    /// *before* any allocation — an adversarial count cannot OOM us.
    pub(crate) fn vec_f32(&mut self) -> anyhow::Result<Vec<f32>> {
        let n = self.u64()? as usize;
        let bytes = self.take(
            n.checked_mul(4)
                .ok_or_else(|| anyhow::anyhow!("f32 count {n} overflows"))?,
        )?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().expect("4 bytes")))
            .collect())
    }

    /// f64 vector (checkpoint scalar sections).
    pub(crate) fn vec_f64(&mut self) -> anyhow::Result<Vec<f64>> {
        let n = self.u64()? as usize;
        let bytes = self.take(
            n.checked_mul(8)
                .ok_or_else(|| anyhow::anyhow!("f64 count {n} overflows"))?,
        )?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().expect("8 bytes")))
            .collect())
    }

    pub(crate) fn header(&mut self) -> anyhow::Result<Header> {
        let h = Header {
            master_step: self.u64()?,
            eta: self.f32()?,
            gamma: self.f32()?,
            lambda: self.f32()?,
            live_workers: self.u64()?,
            worker_slots: self.u64()?,
            pushes_dropped: self.u64()?,
            epoch: self.u64()?,
            shard_start: self.u32()?,
            shard_hosted: self.u32()?,
            total_shards: self.u32()?,
            standby: self.u8()?,
        };
        anyhow::ensure!(h.standby <= 1, "standby flag {} is not a bool", h.standby);
        Ok(h)
    }

    pub(crate) fn stats(&mut self) -> anyhow::Result<ApplyStats> {
        Ok(ApplyStats {
            msg_norm2: self.f64()?,
            g_avg_norm2: self.f64()?,
            prev_dot: self.f64()?,
            prev_norm2: self.f64()?,
        })
    }

    /// Reject trailing garbage after a complete payload.
    pub(crate) fn done(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.i == self.b.len(),
            "{} trailing bytes after payload",
            self.b.len() - self.i
        );
        Ok(())
    }
}

pub(crate) fn put_vec_f64(out: &mut Vec<u8>, v: &[f64]) {
    put_u64(out, v.len() as u64);
    out.reserve(v.len() * 8);
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

//! `RemoteMaster` — the full [`Master`] trait spoken over the wire
//! protocol, so both training drivers run **unchanged** against
//! `--master tcp://host:port`.
//!
//! Topology: one TCP connection *per worker slot* (connect = join,
//! disconnect = leave — the server maps the socket lifecycle onto
//! membership directly) plus one control connection for cluster-wide
//! reads (θ for eval, status) and operator requests (checkpoint,
//! shutdown).  Local worker indices mirror the server's `claim_slot` rule
//! (lowest free index, else append), so a single-client cluster keeps
//! local index == server slot and the sim driver's membership-lockstep
//! assertion holds across the network unchanged.
//!
//! Every reply piggybacks a [`wire::Header`] (master step, current
//! schedule point, membership counts), which this client caches —
//! [`Master::step_now`]/[`Master::steps_done`] are cache reads, not round
//! trips.  The cache is exact for a single-client cluster (nothing
//! advances the master between this client's own calls), which is what
//! the bit-for-bit loopback equivalence relies on; with multiple clients
//! it is eventually consistent, like any snapshot of a racing master.
//!
//! **Failure semantics.**  The [`Master`] trait keeps in-process
//! signatures (`pull_params` returns a bare `Vec<f32>`), so transport
//! loss surfaces in two ways: fallible methods (`push_update`,
//! `remove_worker`) return errors after reconnection attempts are
//! exhausted, and infallible ones panic with a clear message — the same
//! contract as the in-process master, where a pull for a retired slot is
//! a caller bug that panics.  Before giving up, every request transparently
//! retries once after [`RemoteMaster::reconnect`] (bounded attempts with
//! backoff), which re-runs the join handshake for all live workers —
//! against a server restarted from `--resume` this re-attaches each
//! worker to its checkpointed slot (lowest-first on both sides), i.e.
//! *reconnect-as-join* fault recovery.  Worker-local optimizer state
//! (DANA-Slim momentum) lives in the driver and survives reconnects
//! untouched.
//!
//! **Pipelined pushes (deferred acks).**  With
//! [`Master::set_pipeline_depth`] `> 0` (and monolithic frames) a push is
//! a *send*: the frame is written and flushed, the ack left unread, and
//! the round trip overlaps the worker's next computation.  Replies are
//! FIFO per connection, so the harvest is free of ambiguity: each
//! connection tracks how many reply frames it is owed, and any later
//! request writes its own frame first, THEN drains the owed acks, then
//! reads its reply — the driver's push-then-pull cycle thus pays ONE
//! combined round trip instead of two (the pull frame chases the push
//! frame onto the wire).
//! [`Master::drain_inflight`] settles everything explicitly (the drivers
//! call it before θ reads, which go over a separate control connection
//! and would otherwise race the unharvested pushes).  A connection lost
//! with acks owed abandons them (logged; the server may or may not have
//! applied those pushes — its `Status` drop counter tells).
//!
//! **Compressed pushes (wire v4).**  [`RemoteMaster::connect_with`]
//! requests a payload [`Encoding`]; the grant is computed from the
//! server's advertised set in the handshake ([`codec::grant`] — an
//! unadvertised request falls back to `none` with a warning, never an
//! error).  f16/bf16 quantization happens inside the frame writers;
//! top-k sparsification runs client-side first ([`Compressor`]), with
//! one error-feedback residual per local worker slot.  Residuals are
//! connection-soft state: a reconnect abandons them together with the
//! owed acks (the banked noise belonged to pushes whose fate is already
//! unknown), and a slot leave/join resets that slot's residual.
//!
//! Gap/lag metrics are recorded server-side (where θ lives); the local
//! [`MetricsRecorder`] stays empty and reports zeros.  Wire byte totals
//! are tracked client-side ([`RemoteMaster::wire_bytes`]) for the
//! benches and the compression smokes.

use super::codec::{self, Compressor, Encoding, EncodingSet, WireStats};
use super::wire::{self, Header, Msg, Role};
use crate::optim::{make_algorithm, Algorithm, AlgorithmKind, ApplyStats, LeavePolicy, Step, WorkerState};
use crate::server::metrics::MetricsRecorder;
use crate::server::{Master, MasterSnapshot};
use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::sync::Arc;

/// Strip the optional `tcp://` scheme from a master address.
pub fn strip_scheme(addr: &str) -> &str {
    addr.strip_prefix("tcp://").unwrap_or(addr)
}

/// A deferred (pipelined) push was REJECTED by the master — a protocol
/// outcome, not a transport failure.  The driver already counted that
/// push as a completed step, so this must propagate and end the run (the
/// in-process drivers abort on a push error too); the reconnect-and-retry
/// wrapper checks for this marker and refuses to retry it away.
#[derive(Debug)]
pub(crate) struct DeferredPushRejected(String);

impl std::fmt::Display for DeferredPushRejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "deferred push rejected by the master: {}", self.0)
    }
}

impl std::error::Error for DeferredPushRejected {}

/// True when `e` is a [`DeferredPushRejected`] — i.e. retrying/reconnecting
/// cannot help and the error must surface to the driver.
pub(crate) fn is_rejection(e: &anyhow::Error) -> bool {
    e.downcast_ref::<DeferredPushRejected>().is_some()
}

struct Conn {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    /// Server-side slot id (worker connections; `u64::MAX` for control).
    slot: u64,
    /// Generation the server assigned at attach; echoed in every Push.
    gen: u32,
    /// Reply frames still owed on this connection: deferred (pipelined)
    /// pushes whose `PushAck` has not been read yet.  Replies arrive in
    /// request order, so the next `owed` frames are push acks and only
    /// the frame after them answers a new request.
    owed: usize,
    /// Shared tx/rx byte counters (one [`WireStats`] per client, all its
    /// connections feed it).
    stats: Arc<WireStats>,
}

/// What the server told us at handshake time.  `pub(crate)` because the
/// cluster layer's placement probe ([`probe`]) is exactly a handshake:
/// the piggybacked header carries the hosted shard range, placement
/// epoch, and standby flag (wire v5).
pub(crate) struct HelloInfo {
    pub(crate) kind: AlgorithmKind,
    pub(crate) k: usize,
    /// Server-side slice granularity for PullShard/PushShard frames.
    pub(crate) shards: usize,
    /// Server-side pipeline window depth (`dana serve --pipeline-depth`).
    pub(crate) pipeline: usize,
    /// Server-advertised payload-encoding set (bitmask; wire v4).
    pub(crate) encodings: u32,
    pub(crate) header: Header,
}

/// One-shot placement probe: connect to `addr` as a control client, run
/// the hello handshake, and return what the server advertised — hosted
/// shard range, placement epoch, standby flag (all in
/// [`HelloInfo::header`]), algorithm kind, and local parameter count.
/// The connection is dropped immediately (a control hello never touches
/// membership).  The cluster layer uses this to resolve a placement
/// spec against live endpoints and to find the takeover claimant of a
/// failed group's shard range.
pub(crate) fn probe(addr: &str) -> anyhow::Result<HelloInfo> {
    let stats = Arc::new(WireStats::default());
    let (_conn, info) =
        Conn::open(strip_scheme(addr), Role::Control, false, Encoding::None, stats)?;
    Ok(info)
}

/// One-shot θ read: a throwaway control connection that pulls the full
/// parameter vector from `addr` and returns it with the reply header.
/// The cluster layer uses this when a group's own server died mid-eval —
/// the claimant's θ can be read without disturbing any worker
/// connection (the next fallible op performs the real fail-over).
pub(crate) fn fetch_theta_once(addr: &str) -> anyhow::Result<(Header, Vec<f32>)> {
    let stats = Arc::new(WireStats::default());
    let (mut conn, _info) =
        Conn::open(strip_scheme(addr), Role::Control, false, Encoding::None, stats)?;
    match conn.roundtrip(&Msg::GetTheta)? {
        Msg::Theta { header, theta } => Ok((header, theta)),
        Msg::Error { detail, .. } => anyhow::bail!("theta read refused: {detail}"),
        other => anyhow::bail!("unexpected theta reply: {other:?}"),
    }
}

/// One-shot graceful shutdown: a throwaway control connection that
/// sends the in-band `Shutdown` frame and waits for the ack.  The
/// server checkpoints first when configured, so this is how the cluster
/// supervisor winds a placement down without losing acked pushes.
pub(crate) fn shutdown_once(addr: &str) -> anyhow::Result<()> {
    let stats = Arc::new(WireStats::default());
    let (mut conn, _info) =
        Conn::open(strip_scheme(addr), Role::Control, false, Encoding::None, stats)?;
    match conn.roundtrip(&Msg::Shutdown)? {
        Msg::Ack { .. } => Ok(()),
        Msg::Error { detail, .. } => anyhow::bail!("shutdown refused: {detail}"),
        other => anyhow::bail!("unexpected shutdown reply: {other:?}"),
    }
}

impl Conn {
    fn open(
        addr: &str,
        role: Role,
        reattach: bool,
        encoding: Encoding,
        stats: Arc<WireStats>,
    ) -> anyhow::Result<(Conn, HelloInfo)> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| anyhow::anyhow!("connect to master {addr}: {e}"))?;
        stream.set_nodelay(true).ok();
        let mut conn = Conn {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
            slot: u64::MAX,
            gen: 0,
            owed: 0,
            stats,
        };
        match conn.roundtrip(&Msg::Hello { role, reattach, encoding })? {
            Msg::HelloAck { slot, gen, kind, k, shards, pipeline, encodings, header } => {
                conn.slot = slot;
                conn.gen = gen;
                Ok((
                    conn,
                    HelloInfo {
                        kind,
                        k: k as usize,
                        shards: shards as usize,
                        pipeline: pipeline as usize,
                        encodings,
                        header,
                    },
                ))
            }
            Msg::Error { detail, .. } => anyhow::bail!("master refused hello: {detail}"),
            other => anyhow::bail!("unexpected hello reply: {other:?}"),
        }
    }

    /// Write one `Msg` frame, counting its bytes.
    fn send(&mut self, msg: &Msg) -> std::io::Result<()> {
        let n = wire::write_frame(&mut self.writer, msg)?;
        self.stats.add_tx(n);
        Ok(())
    }

    /// Read one frame, counting its bytes.
    fn recv(&mut self) -> anyhow::Result<Msg> {
        let (msg, n) = wire::read_frame_sized(&mut self.reader)?;
        self.stats.add_rx(n);
        Ok(msg)
    }

    /// Write a `Push` frame straight from a borrowed (already
    /// transformed) slice under this connection's current generation —
    /// the zero-copy hot path.
    fn send_push(&mut self, enc: Encoding, msg: &[f32]) -> std::io::Result<()> {
        let n = codec::write_push(&mut self.writer, self.gen, enc, msg)?;
        self.stats.add_tx(n);
        Ok(())
    }

    /// Write one `PushShard` slice from a borrowed subslice
    /// (scatter-gather: all slices view ONE gradient buffer).
    fn send_push_shard(&mut self, shard: u32, enc: Encoding, msg: &[f32]) -> std::io::Result<()> {
        let n = codec::write_push_shard(&mut self.writer, self.gen, shard, enc, msg)?;
        self.stats.add_tx(n);
        Ok(())
    }

    fn roundtrip(&mut self, msg: &Msg) -> anyhow::Result<Msg> {
        self.send(msg)?;
        self.recv()
    }
}

/// See the module docs.  Construct with [`RemoteMaster::connect`].
pub struct RemoteMaster {
    addr: String,
    kind: AlgorithmKind,
    k: usize,
    /// Server-side shard count (slice granularity for shard frames).
    server_shards: usize,
    /// Server-side pipeline window depth (for the mismatch warning).
    server_pipeline: usize,
    /// Move parameters as per-shard PullShard/PushShard frames (pipelined,
    /// one round trip) instead of one monolithic frame.  Off by default;
    /// a no-op when the server serves unsliced (`server_shards <= 1`).
    shard_frames: bool,
    /// Pipeline depth ([`Master::set_pipeline_depth`]): with `pipeline >
    /// 0` (and monolithic frames) `push_update` writes the Push frame and
    /// returns WITHOUT reading the ack — the send path.  Owed acks are
    /// harvested by the next request on the same connection (replies are
    /// FIFO), by [`Master::drain_inflight`], or when the un-acked window
    /// would exceed the depth — the deferred-ack harvest.  0 = classic
    /// blocking round trip, bit-for-bit.
    pipeline: usize,
    /// Payload encoding this client *requested* (`--encoding`).
    encoding: Encoding,
    /// What the handshake granted ([`codec::grant`] of the request
    /// against the server's advertised set); what pushes actually use.
    granted: Encoding,
    /// Client-side gradient transform for `granted` — top-k selection +
    /// error-feedback residuals, keyed by local worker index.
    compressor: Compressor,
    /// Reused staging buffer for the top-k pre-transform (the quantizing
    /// encodings write straight from the caller's slice instead).
    push_scratch: Vec<f32>,
    /// Byte counters shared with every connection.
    stats: Arc<WireStats>,
    control: Conn,
    /// Local worker index → connection (None = left/retired locally).
    workers: Vec<Option<Conn>>,
    /// Latest server header seen on any reply.
    header: Header,
    /// Local instance for the worker-side algorithm half (DANA-Slim's
    /// momentum transform) — stateless master-side, never networked.
    local_alg: Box<dyn Algorithm>,
    metrics: MetricsRecorder,
    /// Deferred (pipelined) pushes whose acks were abandoned by
    /// reconnects over this client's lifetime: each one may or may not
    /// have been applied server-side.  Surfaced through
    /// [`Master::pushes_lost`] so the drivers fold the uncertainty into
    /// [`crate::train::TrainReport::pushes_dropped`] instead of leaving
    /// it buried in a log line.
    abandoned_pushes: u64,
    /// Reconnect budget per failed request.
    pub reconnect_attempts: u32,
    /// Pause between reconnect attempts.
    pub reconnect_delay: std::time::Duration,
}

impl RemoteMaster {
    /// Connect to `addr` (`host:port` or `tcp://host:port`) and join
    /// `n_workers` worker slots.  The initial joins are *reattaching*:
    /// against a `--resume`d server they claim the checkpointed slots
    /// (lowest first); against a fresh server they are plain joins.
    pub fn connect(addr: &str, n_workers: usize) -> anyhow::Result<RemoteMaster> {
        Self::connect_with(addr, n_workers, None, Encoding::None)
    }

    /// Like [`Self::connect`], but validates the server's algorithm kind
    /// and parameter count from the control handshake **before** any
    /// worker slot is joined — a misconfigured client is rejected without
    /// ever perturbing a live cluster's membership.
    pub fn connect_expect(
        addr: &str,
        n_workers: usize,
        kind: AlgorithmKind,
        k: usize,
    ) -> anyhow::Result<RemoteMaster> {
        Self::connect_with(addr, n_workers, Some((kind, k)), Encoding::None)
    }

    /// The full constructor: optional shape validation plus a requested
    /// payload [`Encoding`] for this client's pushes (wire v4).  The
    /// request is granted iff the server advertises it; otherwise the
    /// client warns and falls back to `none` — negotiation never fails a
    /// connection.
    pub fn connect_with(
        addr: &str,
        n_workers: usize,
        expect: Option<(AlgorithmKind, usize)>,
        encoding: Encoding,
    ) -> anyhow::Result<RemoteMaster> {
        let addr = strip_scheme(addr).to_string();
        let stats = Arc::new(WireStats::default());
        let (control, info) =
            Conn::open(&addr, Role::Control, false, Encoding::None, stats.clone())?;
        let (kind, k, header) = (info.kind, info.k, info.header);
        anyhow::ensure!(k > 0, "master reports k=0 parameters");
        if let Some((want_kind, want_k)) = expect {
            anyhow::ensure!(
                kind == want_kind,
                "master at {addr} runs {}, this run is configured for {}",
                kind.name(),
                want_kind.name()
            );
            anyhow::ensure!(
                k == want_k,
                "master at {addr} has k={k}, this run's model has k={want_k}"
            );
        }
        let granted = codec::grant(EncodingSet(info.encodings), encoding);
        if granted != encoding {
            eprintln!(
                "net: master at {addr} does not advertise encoding {encoding} (advertises \
                 {}) — falling back to none",
                EncodingSet(info.encodings)
            );
        }
        let local_alg = make_algorithm(kind, &vec![0.0f32; k], 0);
        let mut rm = RemoteMaster {
            addr,
            kind,
            k,
            server_shards: info.shards.max(1),
            server_pipeline: info.pipeline,
            shard_frames: false,
            pipeline: 0,
            encoding,
            granted,
            compressor: Compressor::new(granted),
            push_scratch: Vec::new(),
            stats,
            control,
            workers: Vec::with_capacity(n_workers),
            header,
            local_alg,
            metrics: MetricsRecorder::default(),
            abandoned_pushes: 0,
            reconnect_attempts: 20,
            reconnect_delay: std::time::Duration::from_millis(250),
        };
        for _ in 0..n_workers {
            let conn = rm.open_worker(true)?;
            rm.workers.push(Some(conn));
        }
        Ok(rm)
    }

    fn open_worker(&mut self, reattach: bool) -> anyhow::Result<Conn> {
        let (conn, info) =
            Conn::open(&self.addr, Role::Worker, reattach, self.encoding, self.stats.clone())?;
        anyhow::ensure!(
            info.kind == self.kind && info.k == self.k,
            "master changed shape mid-run: {}/k={} (expected {}/k={})",
            info.kind.name(),
            info.k,
            self.kind.name(),
            self.k
        );
        self.server_shards = info.shards.max(1);
        self.server_pipeline = info.pipeline;
        self.header = info.header;
        Ok(conn)
    }

    /// Switch parameter traffic to per-shard frames (pipelined: all `S`
    /// slices of a pull or push are written before the first reply is
    /// read, so the round-trip count is unchanged while the striped
    /// server overlaps slice service with other workers' traffic).  The
    /// assembled trajectories are bit-for-bit the monolithic-frame ones —
    /// pinned in `rust/tests/striped.rs`.
    pub fn set_shard_frames(&mut self, on: bool) {
        self.shard_frames = on;
    }

    /// Server-side shard count (1 = the server serves unsliced).
    pub fn server_shards(&self) -> usize {
        self.server_shards
    }

    fn sliced(&self) -> bool {
        self.shard_frames && self.server_shards > 1
    }

    /// Point this client at a (possibly restarted) server and re-run the
    /// join handshake for the control connection and every live worker,
    /// in slot order.  Against a `--resume`d server the lowest-first
    /// re-attachment hands each worker its checkpointed slot back.
    pub fn reconnect_to(&mut self, addr: &str) -> anyhow::Result<()> {
        self.addr = strip_scheme(addr).to_string();
        self.reconnect()
    }

    /// Re-run the join handshake against the current address, with
    /// bounded retries (the server may still be restarting).
    ///
    /// Semantics by scenario: against a **restarted** (`--resume`) server
    /// this re-attaches every live worker to its checkpointed slot,
    /// momentum intact.  Against a **still-live** server (a transient
    /// socket failure) the stale connections are dropped *first*, so the
    /// server processes our leaves before the rejoin — the same slots are
    /// reclaimed under the claim-slot rule and the cluster never grows;
    /// the bounce costs the workers their server-side momentum under the
    /// configured leave policy, exactly like any other leave+rejoin.
    pub fn reconnect(&mut self) -> anyhow::Result<()> {
        let pattern: Vec<bool> = self.workers.iter().map(Option::is_some).collect();
        let ours = pattern.iter().filter(|&&p| p).count() as u64;
        let expected_live = self.header.live_workers.saturating_sub(ours);
        // Deferred acks die with their connections: the server may or may
        // not have applied those pushes (reconnect-as-join re-attaches the
        // slot either way; the uncertainty is the price of a mid-pipeline
        // transport loss, and the server's Status drop counter tells).
        let lost: usize = self.workers.iter().flatten().map(|c| c.owed).sum();
        if lost > 0 {
            self.abandoned_pushes += lost as u64;
            eprintln!(
                "net: reconnect abandons {lost} un-acked pipelined push(es) to {}",
                self.addr
            );
        }
        // Drop stale connections up front (a no-op against a dead server:
        // the sockets are already gone).
        for w in self.workers.iter_mut() {
            *w = None;
        }
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..self.reconnect_attempts.max(1) {
            if attempt > 0 {
                std::thread::sleep(self.reconnect_delay);
            }
            match self.try_reconnect(&pattern, expected_live) {
                Ok(()) => return Ok(()),
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| anyhow::anyhow!("reconnect failed")))
    }

    fn try_reconnect(&mut self, pattern: &[bool], expected_live: u64) -> anyhow::Result<()> {
        let (mut control, info) =
            Conn::open(&self.addr, Role::Control, false, Encoding::None, self.stats.clone())?;
        let mut header = info.header;
        anyhow::ensure!(
            info.kind == self.kind && info.k == self.k,
            "reconnected master runs {}/k={}, this run needs {}/k={}",
            info.kind.name(),
            info.k,
            self.kind.name(),
            self.k
        );
        self.server_shards = info.shards.max(1);
        self.server_pipeline = info.pipeline;
        // Give a still-live server a moment to process our dropped
        // connections' EOF-leaves, so the rejoin below reclaims the same
        // retired slots instead of growing the cluster.  Against a
        // restarted server the condition never holds and this times out
        // quickly into the re-attachment path.
        for _ in 0..20 {
            if header.live_workers <= expected_live {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(25));
            header = match control.roundtrip(&Msg::Status)? {
                Msg::Ack { header } => header,
                Msg::Error { detail, .. } => anyhow::bail!("status refused: {detail}"),
                other => anyhow::bail!("unexpected status reply: {other:?}"),
            };
        }
        let mut fresh: Vec<Option<Conn>> = Vec::with_capacity(pattern.len());
        for &had_worker in pattern {
            fresh.push(if had_worker {
                let (conn, ..) =
                    Conn::open(&self.addr, Role::Worker, true, self.encoding, self.stats.clone())?;
                Some(conn)
            } else {
                None
            });
        }
        // Re-grant against the (possibly restarted-with-different-flags)
        // server's advertised set, and drop every error-feedback residual:
        // the banked noise belonged to pushes whose acks died with the old
        // connections (DESIGN.md §12).
        self.granted = codec::grant(EncodingSet(info.encodings), self.encoding);
        self.compressor = Compressor::new(self.granted);
        self.control = control;
        self.workers = fresh;
        self.header = header;
        Ok(())
    }

    fn note(&mut self, header: &Header) {
        self.header = *header;
    }

    /// Read and account every reply frame still owed on worker `w`'s
    /// connection (deferred push acknowledgements) — replies arrive in
    /// request order, so after this the next frame read answers the next
    /// request.  An `Error` reply means a deferred push was rejected
    /// server-side; the driver already counted that push as a step, so it
    /// surfaces as a hard, NON-retryable error ([`DeferredPushRejected`] —
    /// the retry wrappers propagate it instead of reconnecting it away).
    fn harvest_acks(&mut self, w: usize) -> anyhow::Result<()> {
        let conn = self.workers[w]
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("harvest for retired local worker {w}"))?;
        let mut latest: Option<Header> = None;
        while conn.owed > 0 {
            let reply = conn.recv()?;
            conn.owed -= 1;
            match reply {
                Msg::PushAck { header, .. } => latest = Some(header),
                Msg::Error { detail, .. } => {
                    return Err(anyhow::Error::new(DeferredPushRejected(format!(
                        "worker {w}: {detail}"
                    ))));
                }
                other => anyhow::bail!("unexpected deferred-push reply: {other:?}"),
            }
        }
        if let Some(h) = latest {
            self.note(&h);
        }
        Ok(())
    }

    /// Write one request on worker `w`'s connection, drain any owed
    /// deferred-push acks (their replies precede ours — FIFO), then read
    /// the request's own reply.  Writing BEFORE draining is what lets a
    /// pipelined cycle's push and pull share one round trip: the pull
    /// frame chases the push frame onto the wire, and the client then
    /// reads the push ack and the pull reply back to back.  With nothing
    /// owed this is exactly a classic blocking round trip.
    fn send_harvest_read(&mut self, w: usize, msg: &Msg) -> anyhow::Result<Msg> {
        {
            let conn = self.workers[w].as_mut().expect("validated by caller");
            conn.send(msg)?;
        }
        self.harvest_acks(w)?;
        let conn = self.workers[w].as_mut().expect("validated by caller");
        conn.recv()
    }

    /// One request on worker `w`'s connection, transparently reconnecting
    /// once on transport failure.  `Err` after that means the master is
    /// unreachable; a `Msg::Error` reply passes through as `Ok`.
    fn worker_request(&mut self, w: usize, msg: &Msg) -> anyhow::Result<Msg> {
        anyhow::ensure!(
            w < self.workers.len() && self.workers[w].is_some(),
            "request for retired local worker {w}"
        );
        let first = self.send_harvest_read(w, msg);
        let reply = match first {
            Ok(r) => r,
            // a rejected deferred push is a protocol outcome, not a
            // transport failure: reconnecting cannot help
            Err(e) if is_rejection(&e) => return Err(e),
            Err(_) => {
                self.reconnect()?;
                // pushes carry their own generation handling (the codec
                // writers tag from the fresh conn); everything routed here
                // (pulls, leaves) is generation-free and resends verbatim
                self.workers[w].as_mut().expect("reconnected").roundtrip(msg)?
            }
        };
        if let Msg::Params { header, .. }
        | Msg::PushAck { header, .. }
        | Msg::Ack { header }
        | Msg::Theta { header, .. } = &reply
        {
            let header = *header;
            self.note(&header);
        }
        Ok(reply)
    }

    /// A pipelined batch of requests on worker `w`'s connection, with the
    /// same transparent reconnect-once contract as [`Self::worker_request`].
    /// `make` builds the frames from the slot's *current* generation and
    /// the server's *current* shard count, so a retry after
    /// reconnect-as-join re-tags AND re-slices them — a server resumed
    /// with a different `--shards` (layout-independent checkpoints allow
    /// it) gets correctly shaped slices, not the old layout's.  A batch
    /// interrupted mid-flight is safe to resend wholesale: the server
    /// buffers push slices per connection and drops an incomplete group
    /// with the dead socket (gather-then-apply).
    fn worker_request_batch(
        &mut self,
        w: usize,
        make: impl Fn(u32, usize) -> Vec<Msg>,
    ) -> anyhow::Result<Vec<Msg>> {
        anyhow::ensure!(
            w < self.workers.len() && self.workers[w].is_some(),
            "request for retired local worker {w}"
        );
        let first = self.send_batch_harvest_read(w, &make);
        let replies = match first {
            Ok(r) => r,
            Err(e) if is_rejection(&e) => return Err(e),
            Err(_) => {
                self.reconnect()?;
                self.send_batch_harvest_read(w, &make)?
            }
        };
        for reply in &replies {
            if let Msg::Params { header, .. }
            | Msg::ShardParams { header, .. }
            | Msg::PushAck { header, .. }
            | Msg::Ack { header }
            | Msg::Theta { header, .. } = reply
            {
                let header = *header;
                self.note(&header);
            }
        }
        Ok(replies)
    }

    /// Batch variant of [`Self::send_harvest_read`]: write every frame of
    /// the batch before reading any reply (one round trip for a whole
    /// shard-sliced group), drain owed deferred-push acks, then read the
    /// batch's replies in order.
    fn send_batch_harvest_read(
        &mut self,
        w: usize,
        make: &impl Fn(u32, usize) -> Vec<Msg>,
    ) -> anyhow::Result<Vec<Msg>> {
        let n = {
            let shards = self.server_shards;
            let conn = self.workers[w].as_mut().expect("validated by caller");
            let msgs = make(conn.gen, shards);
            for m in &msgs {
                conn.send(m)?;
            }
            msgs.len()
        };
        self.harvest_acks(w)?;
        let conn = self.workers[w].as_mut().expect("validated by caller");
        (0..n).map(|_| conn.recv()).collect()
    }

    /// Shard-sliced pull: one pipelined `PullShard` round per shard,
    /// assembled into the full parameter vector.
    fn pull_sliced(&mut self, worker: usize) -> anyhow::Result<Vec<f32>> {
        let replies = self.worker_request_batch(worker, |_, shards| {
            (0..shards as u32).map(|shard| Msg::PullShard { shard }).collect()
        })?;
        // recompute AFTER the batch: a mid-batch reconnect may have
        // landed on a server with a different shard count
        let ranges = crate::server::shard_bounds(self.k, self.server_shards);
        let mut out = vec![0.0f32; self.k];
        for reply in replies {
            match reply {
                Msg::ShardParams { shard, params, .. } => {
                    let r = ranges
                        .get(shard as usize)
                        .ok_or_else(|| anyhow::anyhow!("server sent unknown shard {shard}"))?
                        .clone();
                    anyhow::ensure!(
                        params.len() == r.len(),
                        "shard {shard} slice length {} != {}",
                        params.len(),
                        r.len()
                    );
                    out[r].copy_from_slice(&params);
                }
                Msg::Error { detail, .. } => anyhow::bail!("sliced pull refused: {detail}"),
                other => anyhow::bail!("unexpected sliced-pull reply: {other:?}"),
            }
        }
        Ok(out)
    }

    /// Write every `PushShard` slice of one logical push (scatter-gather:
    /// each frame borrows its subslice of the ONE gradient buffer — no
    /// per-shard copies), drain owed acks, then read the group's replies.
    /// Shard count and generation are read at call time, so a retry after
    /// reconnect-as-join re-tags AND re-slices correctly even against a
    /// server resumed with a different `--shards`.
    fn send_sliced_push(&mut self, w: usize, data: &[f32]) -> anyhow::Result<Vec<Msg>> {
        let enc = self.granted;
        let n = {
            let ranges = crate::server::shard_bounds(self.k, self.server_shards);
            let conn = self.workers[w].as_mut().expect("validated by caller");
            for (shard, r) in ranges.iter().enumerate() {
                conn.send_push_shard(shard as u32, enc, &data[r.clone()])?;
            }
            ranges.len()
        };
        self.harvest_acks(w)?;
        let conn = self.workers[w].as_mut().expect("validated by caller");
        (0..n).map(|_| conn.recv()).collect()
    }

    /// Shard-sliced push: the update travels as one pipelined `PushShard`
    /// frame per shard; the server applies the assembled update as a
    /// single master step when the last slice lands.  A batch interrupted
    /// mid-flight is safe to resend wholesale: the server buffers push
    /// slices per connection and drops an incomplete group with the dead
    /// socket (gather-then-apply).
    fn push_sliced(&mut self, worker: usize, data: &[f32]) -> anyhow::Result<Step> {
        anyhow::ensure!(
            worker < self.workers.len() && self.workers[worker].is_some(),
            "push from retired local worker {worker}"
        );
        let first = self.send_sliced_push(worker, data);
        let replies = match first {
            Ok(r) => r,
            Err(e) if is_rejection(&e) => return Err(e),
            Err(_) => {
                self.reconnect()?;
                self.send_sliced_push(worker, data)?
            }
        };
        let mut step = None;
        for reply in replies {
            match reply {
                Msg::Ack { header } => self.note(&header),
                Msg::PushAck { header, eta, gamma, lambda, .. } => {
                    self.note(&header);
                    step = Some(Step { eta, gamma, lambda })
                }
                Msg::Error { detail, .. } => anyhow::bail!("push rejected: {detail}"),
                other => anyhow::bail!("unexpected sliced-push reply: {other:?}"),
            }
        }
        step.ok_or_else(|| anyhow::anyhow!("sliced push never completed (no PushAck)"))
    }

    /// One request on the control connection, same retry contract.
    fn control_request(&mut self, msg: &Msg) -> anyhow::Result<Msg> {
        let reply = match self.control.roundtrip(msg) {
            Ok(r) => r,
            Err(_) => {
                self.reconnect()?;
                self.control.roundtrip(msg)?
            }
        };
        if let Msg::Params { header, .. }
        | Msg::PushAck { header, .. }
        | Msg::Ack { header }
        | Msg::Theta { header, .. } = &reply
        {
            let header = *header;
            self.note(&header);
        }
        Ok(reply)
    }

    /// Ask the server to write a checkpoint now (requires the serve side
    /// to have a `--checkpoint` path).
    pub fn force_checkpoint(&mut self) -> anyhow::Result<()> {
        match self.control_request(&Msg::Checkpoint)? {
            Msg::Ack { .. } => Ok(()),
            Msg::Error { detail, .. } => anyhow::bail!("checkpoint refused: {detail}"),
            other => anyhow::bail!("unexpected checkpoint reply: {other:?}"),
        }
    }

    /// Gracefully shut the server down (it checkpoints first when
    /// configured).
    pub fn shutdown_server(&mut self) -> anyhow::Result<()> {
        match self.control_request(&Msg::Shutdown)? {
            Msg::Ack { .. } => Ok(()),
            Msg::Error { detail, .. } => anyhow::bail!("shutdown refused: {detail}"),
            other => anyhow::bail!("unexpected shutdown reply: {other:?}"),
        }
    }

    /// Refresh and return the latest server header (cluster-wide counts).
    pub fn refresh_status(&mut self) -> anyhow::Result<Header> {
        match self.control_request(&Msg::Status)? {
            Msg::Ack { header } => Ok(header),
            Msg::Error { detail, .. } => anyhow::bail!("status refused: {detail}"),
            other => anyhow::bail!("unexpected status reply: {other:?}"),
        }
    }

    /// Server slot backing local worker `w` (tests/diagnostics).
    pub fn server_slot(&self, w: usize) -> Option<u64> {
        self.workers.get(w).and_then(|c| c.as_ref().map(|c| c.slot))
    }

    /// Deferred-push acks abandoned by reconnects so far (also exposed as
    /// [`Master::pushes_lost`]).
    pub fn abandoned_pushes(&self) -> u64 {
        self.abandoned_pushes
    }

    /// The payload encoding the handshake granted this client (what its
    /// pushes actually use; `none` when the request wasn't advertised).
    pub fn granted_encoding(&self) -> Encoding {
        self.granted
    }

    /// (bytes sent, bytes received) over every connection this client has
    /// opened — the counters the benches and the CI compression smoke
    /// assert shrink under f16.
    pub fn wire_bytes(&self) -> (u64, u64) {
        self.stats.totals()
    }

    /// Un-acked deferred pushes currently in flight on worker `w`'s
    /// connection (tests/diagnostics).
    pub fn inflight_pushes(&self, w: usize) -> usize {
        self.workers
            .get(w)
            .and_then(|c| c.as_ref().map(|c| c.owed))
            .unwrap_or(0)
    }

    /// The deferred (pipelined) push: write the frame, flush, return
    /// without reading the ack — the round trip overlaps the worker's
    /// next gradient computation.  The ack is harvested by the next
    /// request on this connection (the driver's following pull, which
    /// thereby costs ONE combined round trip per cycle instead of two),
    /// by [`Master::drain_inflight`], or here when the un-acked window
    /// would exceed the pipeline depth.
    ///
    /// The returned [`Step`] is the latest *known* schedule point (both
    /// drivers read the schedule via `step_now()` before the push and
    /// ignore this value); the exact applied step arrives with the ack.
    fn push_deferred(&mut self, worker: usize, data: &[f32]) -> anyhow::Result<Step> {
        if self.inflight_pushes(worker) >= self.pipeline {
            if let Err(e) = self.harvest_acks(worker) {
                if is_rejection(&e) {
                    return Err(e);
                }
                self.reconnect()?;
            }
        }
        let step = self.header.step();
        let enc = self.granted;
        let sent = {
            let conn = self.workers[worker]
                .as_mut()
                .ok_or_else(|| anyhow::anyhow!("push from retired local worker {worker}"))?;
            match conn.send_push(enc, data) {
                Ok(()) => {
                    conn.owed += 1;
                    true
                }
                Err(_) => false,
            }
        };
        if !sent {
            // the write died mid-pipeline: reconnect and retry once as a
            // plain blocking push under the fresh generation (conn.gen)
            self.reconnect()?;
            let enc = self.granted;
            let conn = self.workers[worker].as_mut().expect("reconnected");
            conn.send_push(enc, data)?;
            let reply = conn.recv()?;
            return match reply {
                Msg::PushAck { header, eta, gamma, lambda, .. } => {
                    self.note(&header);
                    Ok(Step { eta, gamma, lambda })
                }
                Msg::Error { detail, .. } => anyhow::bail!("push rejected: {detail}"),
                other => anyhow::bail!("unexpected push reply: {other:?}"),
            };
        }
        Ok(step)
    }

    /// Route one already-transformed update to the right wire shape.
    fn push_transformed(&mut self, worker: usize, data: &[f32]) -> anyhow::Result<Step> {
        if self.sliced() {
            // sliced pushes stay blocking: a deferred multi-frame group
            // would have to be resent wholesale on any mid-group failure
            return self.push_sliced(worker, data);
        }
        if self.pipeline > 0 {
            return self.push_deferred(worker, data);
        }
        self.push_blocking(worker, data)
    }

    /// The classic blocking push, written straight from the borrowed
    /// slice ([`Conn::send_push`]) with the same reconnect-once contract
    /// as [`Self::worker_request`] — the retry picks up the fresh
    /// generation from the reconnected connection automatically.
    fn push_blocking(&mut self, w: usize, data: &[f32]) -> anyhow::Result<Step> {
        let first = self.send_push_harvest_read(w, data);
        let reply = match first {
            Ok(r) => r,
            Err(e) if is_rejection(&e) => return Err(e),
            Err(_) => {
                self.reconnect()?;
                let enc = self.granted;
                let conn = self.workers[w].as_mut().expect("reconnected");
                conn.send_push(enc, data)?;
                conn.recv()?
            }
        };
        match reply {
            Msg::PushAck { header, eta, gamma, lambda, .. } => {
                self.note(&header);
                Ok(Step { eta, gamma, lambda })
            }
            Msg::Error { detail, .. } => anyhow::bail!("push rejected: {detail}"),
            other => anyhow::bail!("unexpected push reply: {other:?}"),
        }
    }

    /// Push half of [`Self::send_harvest_read`]: write the frame from the
    /// borrowed slice, drain owed deferred acks, read our reply.
    fn send_push_harvest_read(&mut self, w: usize, data: &[f32]) -> anyhow::Result<Msg> {
        let enc = self.granted;
        {
            let conn = self.workers[w].as_mut().expect("validated by caller");
            conn.send_push(enc, data)?;
        }
        self.harvest_acks(w)?;
        let conn = self.workers[w].as_mut().expect("validated by caller");
        conn.recv()
    }

    // ------------------------------------------------------------------
    // Split-phase worker ops (cluster fan-out).
    //
    // `begin_*` writes and flushes the request frame on worker `w`'s
    // connection WITHOUT reading the reply; the matching `finish_*`
    // drains any owed deferred-push acks (FIFO — their replies precede
    // ours) and reads it.  A `ClusterMaster` begins one op on EVERY
    // placement group before finishing any, so a worker's cross-server
    // pull or push costs one overlapped round trip instead of one per
    // server.  Unlike `worker_request` these never reconnect
    // internally: a transport error bubbles to the cluster layer, which
    // owns endpoint re-resolution (the replacement server is usually a
    // DIFFERENT address — the standby's).

    fn worker_conn(&mut self, w: usize) -> anyhow::Result<&mut Conn> {
        self.workers
            .get_mut(w)
            .and_then(Option::as_mut)
            .ok_or_else(|| anyhow::anyhow!("request for retired local worker {w}"))
    }

    /// Send a `PullParams` frame on worker `w`'s connection; reply read
    /// by [`Self::finish_pull_into`].
    pub(crate) fn begin_pull(&mut self, w: usize) -> anyhow::Result<()> {
        self.worker_conn(w)?.send(&Msg::PullParams)?;
        Ok(())
    }

    /// Read the reply to [`Self::begin_pull`] into `out` (length `k`).
    pub(crate) fn finish_pull_into(&mut self, w: usize, out: &mut [f32]) -> anyhow::Result<()> {
        self.harvest_acks(w)?;
        match self.worker_conn(w)?.recv()? {
            Msg::Params { header, params } => {
                anyhow::ensure!(
                    params.len() == self.k && out.len() == self.k,
                    "pull slice length {} (buffer {}) != k={}",
                    params.len(),
                    out.len(),
                    self.k
                );
                out.copy_from_slice(&params);
                self.note(&header);
                Ok(())
            }
            Msg::Error { detail, .. } => anyhow::bail!("pull refused: {detail}"),
            other => anyhow::bail!("unexpected pull reply: {other:?}"),
        }
    }

    /// Send a blocking `Push` frame (this client's granted encoding) on
    /// worker `w`'s connection; ack read by [`Self::finish_push`].
    pub(crate) fn begin_push(&mut self, w: usize, data: &[f32]) -> anyhow::Result<()> {
        let enc = self.granted;
        self.worker_conn(w)?.send_push(enc, data)?;
        Ok(())
    }

    /// Read the `PushAck` for [`Self::begin_push`] (or
    /// [`Self::begin_push_commit`] — a commit acks like a push).
    pub(crate) fn finish_push(&mut self, w: usize) -> anyhow::Result<Step> {
        self.harvest_acks(w)?;
        match self.worker_conn(w)?.recv()? {
            Msg::PushAck { header, eta, gamma, lambda, .. } => {
                self.note(&header);
                Ok(Step { eta, gamma, lambda })
            }
            Msg::Error { detail, .. } => anyhow::bail!("push rejected: {detail}"),
            other => anyhow::bail!("unexpected push reply: {other:?}"),
        }
    }

    /// Phase 1 of the cluster's two-phase apply: send a `PushStage`
    /// frame carrying this group's slice of the update (always raw f32 —
    /// statistics are computed from exact coordinates).
    pub(crate) fn begin_push_stage(&mut self, w: usize, data: &[f32]) -> anyhow::Result<()> {
        let conn = self.worker_conn(w)?;
        let gen = conn.gen;
        conn.send(&Msg::PushStage { gen, msg: data.to_vec() })?;
        Ok(())
    }

    /// Read the `StageStats` reply to [`Self::begin_push_stage`]: this
    /// group's additive statistics partials, ready to merge.
    pub(crate) fn finish_push_stage(&mut self, w: usize) -> anyhow::Result<ApplyStats> {
        self.harvest_acks(w)?;
        match self.worker_conn(w)?.recv()? {
            Msg::StageStats { header, stats } => {
                self.note(&header);
                Ok(stats)
            }
            Msg::Error { detail, .. } => anyhow::bail!("push stage refused: {detail}"),
            other => anyhow::bail!("unexpected stage reply: {other:?}"),
        }
    }

    /// Phase 2 of the two-phase apply: send a `PushCommit` frame with
    /// the globally merged statistics and the same slice again (the
    /// server holds no staging state).  Ack via [`Self::finish_push`].
    pub(crate) fn begin_push_commit(
        &mut self,
        w: usize,
        stats: &ApplyStats,
        data: &[f32],
    ) -> anyhow::Result<()> {
        let conn = self.worker_conn(w)?;
        let gen = conn.gen;
        conn.send(&Msg::PushCommit { gen, stats: *stats, msg: data.to_vec() })?;
        Ok(())
    }

    /// The deferred (pipelined) push, for the cluster layer: same
    /// contract as the trait path at depth > 0, including the internal
    /// window-full harvest and reconnect-once.  The cluster layer keeps
    /// this group's in-flight count via [`Self::inflight_pushes`].
    pub(crate) fn push_deferred_raw(&mut self, w: usize, data: &[f32]) -> anyhow::Result<Step> {
        self.push_deferred(w, data)
    }

    /// Latest server header seen on any reply — hosted shard range,
    /// placement epoch, standby flag (wire v5), schedule point.
    pub(crate) fn last_header(&self) -> Header {
        self.header
    }

    /// The address this client is currently connected to.
    pub(crate) fn addr(&self) -> &str {
        &self.addr
    }

    /// Fallible θ read over a one-shot control connection (bounded
    /// retries against the current address).  [`Master::theta_vec`]
    /// panics on error; the cluster layer instead fails over and reads
    /// the claimant.
    pub(crate) fn try_theta(&self) -> anyhow::Result<Vec<f32>> {
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..self.reconnect_attempts.max(1) {
            if attempt > 0 {
                std::thread::sleep(self.reconnect_delay);
            }
            let mut conn = match Conn::open(
                &self.addr,
                Role::Control,
                false,
                Encoding::None,
                self.stats.clone(),
            ) {
                Ok((conn, ..)) => conn,
                Err(e) => {
                    last = Some(e);
                    continue;
                }
            };
            match conn.roundtrip(&Msg::GetTheta) {
                Ok(Msg::Theta { theta, .. }) => return Ok(theta),
                Ok(Msg::Error { detail, .. }) => {
                    anyhow::bail!("master refused theta read: {detail}")
                }
                Ok(other) => anyhow::bail!("unexpected theta reply: {other:?}"),
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| anyhow::anyhow!("theta read failed")))
    }
}

impl Master for RemoteMaster {
    fn algo_kind(&self) -> AlgorithmKind {
        self.kind
    }

    fn workers(&self) -> usize {
        self.workers.len()
    }

    fn live_workers(&self) -> usize {
        self.workers.iter().filter(|c| c.is_some()).count()
    }

    fn is_live(&self, worker: usize) -> bool {
        self.workers.get(worker).map(Option::is_some).unwrap_or(false)
    }

    fn add_worker(&mut self) -> usize {
        // mirror claim_slot: lowest retired local index, else append
        let local = self
            .workers
            .iter()
            .position(Option::is_none)
            .unwrap_or(self.workers.len());
        // a churn join is a genuinely fresh worker — never reattach it to
        // a checkpointed slot's momentum
        let conn = self
            .open_worker(false)
            .unwrap_or_else(|e| panic!("join against master {} failed: {e:#}", self.addr));
        if local == self.workers.len() {
            self.workers.push(Some(conn));
        } else {
            self.workers[local] = Some(conn);
        }
        // a fresh worker starts with no banked compression error
        self.compressor.reset_slot(local);
        local
    }

    fn remove_worker(&mut self, worker: usize, policy: LeavePolicy) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.is_live(worker),
            "remove_worker: local worker {worker} is not live"
        );
        let reply = self.worker_request(worker, &Msg::Leave { policy });
        // the connection closes either way: dropping it is the leave —
        // and the slot's error-feedback residual goes with it
        self.workers[worker] = None;
        self.compressor.reset_slot(worker);
        match reply? {
            Msg::Ack { .. } => Ok(()),
            Msg::Error { detail, .. } => anyhow::bail!("leave refused: {detail}"),
            other => anyhow::bail!("unexpected leave reply: {other:?}"),
        }
    }

    fn steps_done(&self) -> u64 {
        self.header.master_step
    }

    fn param_len(&self) -> usize {
        self.k
    }

    fn step_now(&self) -> Step {
        self.header.step()
    }

    fn theta_vec(&self) -> Vec<f32> {
        // &self signature forces an interior-mutability-free workaround:
        // a one-shot control connection per read, with the same bounded
        // retry budget as every other request (an eval landing in a
        // server-restart window must survive it, not abort the run).
        self.try_theta().unwrap_or_else(|e| {
            panic!("theta read from master {} failed after retries: {e:#}", self.addr)
        })
    }

    fn pull_params(&mut self, worker: usize) -> Vec<f32> {
        if self.sliced() {
            return self
                .pull_sliced(worker)
                .unwrap_or_else(|e| panic!("sliced pull for worker {worker} failed: {e:#}"));
        }
        match self.worker_request(worker, &Msg::PullParams) {
            Ok(Msg::Params { params, .. }) => {
                assert_eq!(params.len(), self.k, "master sent {} of k={}", params.len(), self.k);
                params
            }
            Ok(Msg::Error { detail, .. }) => {
                // in-process pull for a retired slot is a caller-bug panic;
                // keep the same contract over the wire
                panic!("pull for worker {worker} refused: {detail}")
            }
            Ok(other) => panic!("unexpected pull reply: {other:?}"),
            // transport loss after retries, or a rejected deferred push
            // surfacing through the harvest — either ends the run
            Err(e) => panic!("pull for worker {worker} against master {} failed: {e:#}", self.addr),
        }
    }

    fn pull_into(&mut self, worker: usize, out: &mut [f32]) {
        let params = self.pull_params(worker);
        out.copy_from_slice(&params);
    }

    fn push_update(&mut self, worker: usize, msg: &[f32]) -> anyhow::Result<Step> {
        anyhow::ensure!(
            worker < self.workers.len() && self.workers[worker].is_some(),
            "push from retired local worker {worker}"
        );
        // Top-k runs its error-feedback selection client-side first (the
        // residual fold must see the dense gradient); the quantizing
        // encodings are applied inside the frame writers, straight from
        // the caller's slice.
        if matches!(self.granted, Encoding::TopK { .. }) {
            let mut scratch = std::mem::take(&mut self.push_scratch);
            scratch.clear();
            scratch.extend_from_slice(msg);
            self.compressor.transform(worker, &mut scratch);
            let out = self.push_transformed(worker, &scratch);
            self.push_scratch = scratch;
            return out;
        }
        self.push_transformed(worker, msg)
    }

    fn set_pipeline_depth(&mut self, depth: usize) {
        self.pipeline = depth;
        if depth != self.server_pipeline {
            eprintln!(
                "net: this run pipelines at depth {depth} but the master at {} is configured \
                 for depth {} — its pull-window (lag/gap/DC-ASGD) accounting and DANA's \
                 look-ahead extrapolation follow the server setting; start the server with \
                 `--pipeline-depth {depth}` to align",
                self.addr, self.server_pipeline
            );
        }
    }

    fn drain_inflight(&mut self) -> anyhow::Result<()> {
        for w in 0..self.workers.len() {
            if self.workers[w].as_ref().map(|c| c.owed > 0).unwrap_or(false) {
                self.harvest_acks(w)?;
            }
        }
        Ok(())
    }

    fn pushes_lost(&self) -> u64 {
        self.abandoned_pushes
    }

    fn make_worker_state(&self) -> WorkerState {
        self.local_alg.make_worker_state()
    }

    fn worker_transform(&self, ws: &mut WorkerState, grad: &mut [f32], s: Step) {
        self.local_alg.worker_message(ws, grad, s);
    }

    fn metrics(&self) -> &MetricsRecorder {
        &self.metrics
    }

    fn metrics_mut(&mut self) -> &mut MetricsRecorder {
        &mut self.metrics
    }

    fn snapshot(&self) -> anyhow::Result<MasterSnapshot> {
        anyhow::bail!(
            "a remote master checkpoints server-side — send the Checkpoint control \
             frame (RemoteMaster::force_checkpoint) instead"
        )
    }

    fn restore(&mut self, _snap: &MasterSnapshot) -> anyhow::Result<()> {
        anyhow::bail!("a remote master restores server-side (`dana serve --resume PATH`)")
    }
}

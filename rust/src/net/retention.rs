//! Checkpoint retention: bounded disk for a long-running daemon.
//!
//! With retention enabled (`--keep-last N` / `--keep-hourly H`) the wire
//! server writes every snapshot TWICE: the plain base path (what
//! `--resume` reads — always the newest state) and a step-stamped
//! archive `<base>.<step:012>`, both via [`checkpoint::write_atomic`]'s
//! tmp + fsync + rename + parent-fsync discipline.  A GC pass then
//! deletes expired archives and fsyncs the parent directory once.
//!
//! Safety invariants, pinned by the tests below:
//!
//! * the plain base path is **never** a GC candidate (its name has no
//!   numeric suffix, so [`list_archives`] cannot even see it);
//! * the newest-by-step archive always survives, whatever the policy —
//!   [`plan_gc`] inserts it into the keep set unconditionally;
//! * GC is idempotent and crash-safe: every delete is independent, a
//!   file already gone is not an error, and a crash mid-pass just
//!   leaves extra archives for the next pass (nothing is ever renamed
//!   or rewritten during GC).
//!
//! `--keep-last N` keeps the N newest archives by step; `--keep-hourly
//! H` additionally keeps the newest archive inside each of the H newest
//! distinct wall-clock hours (mtime-bucketed), so an operator retains
//! both fine recent history and coarse long-range restore points.

use crate::net::checkpoint::sync_parent_dir;
use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::time::{SystemTime, UNIX_EPOCH};

/// What to keep.  `Default` (all zeros) disables retention entirely —
/// no archives are written and nothing is ever deleted.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RetentionPolicy {
    /// Keep this many newest archives (by step).
    pub keep_last: usize,
    /// Additionally keep the newest archive of each of this many newest
    /// distinct hours (by file mtime).
    pub keep_hourly: usize,
}

impl RetentionPolicy {
    pub fn enabled(&self) -> bool {
        self.keep_last > 0 || self.keep_hourly > 0
    }
}

/// One step-stamped checkpoint archive on disk.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Archive {
    pub path: PathBuf,
    pub step: u64,
    pub modified: SystemTime,
}

/// The archive path for a snapshot settled at `step`: the numeric
/// suffix is appended to the full file name (`run.ckpt` →
/// `run.ckpt.000000000032`), zero-padded so lexical and numeric order
/// agree.
pub fn archive_path(base: &Path, step: u64) -> PathBuf {
    let mut name = base
        .file_name()
        .expect("checkpoint path has a file name")
        .to_os_string();
    name.push(format!(".{step:012}"));
    base.with_file_name(name)
}

/// Enumerate `base`'s archives: siblings named `<base>.<digits>`.  The
/// plain base, `.tmp` leftovers and unrelated files are skipped.
/// Sorted by step ascending.
pub fn list_archives(base: &Path) -> anyhow::Result<Vec<Archive>> {
    let dir = match base.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
        _ => PathBuf::from("."),
    };
    let stem = base
        .file_name()
        .ok_or_else(|| anyhow::anyhow!("checkpoint path {} has no file name", base.display()))?
        .to_string_lossy()
        .into_owned();
    let prefix = format!("{stem}.");
    let mut out = Vec::new();
    for entry in std::fs::read_dir(&dir)
        .map_err(|e| anyhow::anyhow!("list archives in {}: {e}", dir.display()))?
    {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().into_owned();
        let Some(suffix) = name.strip_prefix(&prefix) else { continue };
        if suffix.is_empty() || !suffix.bytes().all(|b| b.is_ascii_digit()) {
            continue; // the plain base, `.tmp`, or an unrelated sibling
        }
        let Ok(step) = suffix.parse::<u64>() else { continue };
        let modified = entry.metadata()?.modified()?;
        out.push(Archive { path: entry.path(), step, modified });
    }
    out.sort_by_key(|a| (a.step, a.path.clone()));
    Ok(out)
}

/// Decide what to delete.  Pure over the listing, so the policy logic
/// is testable without a filesystem; returns doomed paths in step
/// order.  The newest-by-step archive is kept unconditionally.
pub fn plan_gc(archives: &[Archive], policy: RetentionPolicy) -> Vec<PathBuf> {
    if !policy.enabled() || archives.is_empty() {
        return Vec::new();
    }
    let mut by_step: Vec<&Archive> = archives.iter().collect();
    by_step.sort_by_key(|a| a.step);
    let mut keep: BTreeSet<&Path> = BTreeSet::new();
    keep.insert(by_step.last().expect("non-empty").path.as_path());
    for a in by_step.iter().rev().take(policy.keep_last) {
        keep.insert(a.path.as_path());
    }
    if policy.keep_hourly > 0 {
        // ascending-step iteration ⇒ the last insert per hour bucket is
        // that hour's newest archive
        let mut best_of_hour: BTreeMap<u64, &Archive> = BTreeMap::new();
        for a in &by_step {
            let hour = a
                .modified
                .duration_since(UNIX_EPOCH)
                .unwrap_or_default()
                .as_secs()
                / 3600;
            best_of_hour.insert(hour, a);
        }
        for a in best_of_hour.values().rev().take(policy.keep_hourly) {
            keep.insert(a.path.as_path());
        }
    }
    by_step
        .iter()
        .filter(|a| !keep.contains(a.path.as_path()))
        .map(|a| a.path.clone())
        .collect()
}

/// One GC pass: delete everything [`plan_gc`] condemns, then fsync the
/// parent directory once so the unlinks are durable.  Idempotent — a
/// file already gone (crash midway through a previous pass) is skipped,
/// not an error.  Returns the number of archives removed.
pub fn collect_garbage(base: &Path, policy: RetentionPolicy) -> anyhow::Result<usize> {
    let doomed = plan_gc(&list_archives(base)?, policy);
    let mut removed = 0usize;
    for path in &doomed {
        match std::fs::remove_file(path) {
            Ok(()) => removed += 1,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => anyhow::bail!("retention gc: remove {}: {e}", path.display()),
        }
    }
    if removed > 0 {
        sync_parent_dir(base)
            .map_err(|e| anyhow::anyhow!("retention gc: fsync {}: {e}", base.display()))?;
    }
    Ok(removed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn scratch(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dana-retention-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// Write a fake archive and stamp its mtime `hours_ago` back.
    fn fake_archive(base: &Path, step: u64, hours_ago: u64) -> PathBuf {
        let path = archive_path(base, step);
        std::fs::write(&path, step.to_le_bytes()).unwrap();
        let when = SystemTime::now() - Duration::from_secs(hours_ago * 3600 + (step % 60));
        let f = std::fs::File::options().write(true).open(&path).unwrap();
        f.set_modified(when).unwrap();
        path
    }

    #[test]
    fn listing_sees_only_numeric_archives() {
        let dir = scratch("list");
        let base = dir.join("run.ckpt");
        std::fs::write(&base, b"plain").unwrap();
        std::fs::write(dir.join("run.ckpt.tmp"), b"torn").unwrap();
        std::fs::write(dir.join("other.ckpt.000000000001"), b"x").unwrap();
        fake_archive(&base, 20, 0);
        fake_archive(&base, 3, 1);
        let got = list_archives(&base).unwrap();
        assert_eq!(got.iter().map(|a| a.step).collect::<Vec<_>>(), vec![3, 20]);
        assert_eq!(got[1].path, archive_path(&base, 20));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn keep_last_retains_the_newest_n() {
        let dir = scratch("keeplast");
        let base = dir.join("run.ckpt");
        for step in [1u64, 5, 9, 13, 17] {
            fake_archive(&base, step, 0);
        }
        let archives = list_archives(&base).unwrap();
        let doomed = plan_gc(&archives, RetentionPolicy { keep_last: 2, keep_hourly: 0 });
        assert_eq!(
            doomed,
            vec![
                archive_path(&base, 1),
                archive_path(&base, 5),
                archive_path(&base, 9)
            ]
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn keep_hourly_retains_the_newest_per_hour() {
        let dir = scratch("hourly");
        let base = dir.join("run.ckpt");
        // two archives in each of three hour buckets
        fake_archive(&base, 10, 2);
        fake_archive(&base, 20, 2);
        fake_archive(&base, 30, 1);
        fake_archive(&base, 40, 1);
        fake_archive(&base, 50, 0);
        fake_archive(&base, 60, 0);
        let archives = list_archives(&base).unwrap();
        let doomed = plan_gc(&archives, RetentionPolicy { keep_last: 0, keep_hourly: 2 });
        // the two newest hours keep their newest archive (40, 60); the
        // newest-by-step guard also covers 60
        assert_eq!(
            doomed,
            vec![
                archive_path(&base, 10),
                archive_path(&base, 20),
                archive_path(&base, 30),
                archive_path(&base, 50)
            ]
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn disabled_policy_deletes_nothing() {
        let dir = scratch("disabled");
        let base = dir.join("run.ckpt");
        for step in 0..5u64 {
            fake_archive(&base, step, 0);
        }
        assert!(plan_gc(&list_archives(&base).unwrap(), RetentionPolicy::default()).is_empty());
        assert_eq!(collect_garbage(&base, RetentionPolicy::default()).unwrap(), 0);
        assert_eq!(list_archives(&base).unwrap().len(), 5);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Property: over randomized step/mtime layouts and policies, GC
    /// never deletes the newest-by-step archive, never touches the
    /// plain base, and keeps at least `min(keep_last, n)` archives.
    #[test]
    fn gc_never_deletes_the_newest_durable_snapshot() {
        let mut rng = crate::util::rng::Rng::new(613);
        for case in 0..25 {
            let dir = scratch(&format!("prop{case}"));
            let base = dir.join("run.ckpt");
            std::fs::write(&base, b"plain").unwrap();
            let n = 1 + rng.below(8) as usize;
            let mut steps = BTreeSet::new();
            while steps.len() < n {
                steps.insert(rng.below(500));
            }
            for &step in &steps {
                fake_archive(&base, step, rng.below(4));
            }
            let policy = RetentionPolicy {
                keep_last: rng.below(4) as usize,
                keep_hourly: rng.below(3) as usize,
            };
            let newest = *steps.iter().max().unwrap();
            collect_garbage(&base, policy).unwrap();
            let left = list_archives(&base).unwrap();
            assert!(
                left.iter().any(|a| a.step == newest),
                "case {case}: newest archive {newest} was deleted (policy {policy:?})"
            );
            if policy.enabled() {
                assert!(
                    left.len() >= policy.keep_last.min(n).max(1),
                    "case {case}: kept {} < keep_last {} (n={n})",
                    left.len(),
                    policy.keep_last
                );
            } else {
                assert_eq!(left.len(), n, "case {case}: disabled policy must not GC");
            }
            assert!(base.exists(), "case {case}: plain base must never be touched");
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    /// A crash midway through a GC pass (some doomed files already
    /// unlinked) leaves a state the next pass finishes cleanly.
    #[test]
    fn gc_survives_a_crash_mid_pass() {
        let dir = scratch("crash");
        let base = dir.join("run.ckpt");
        for step in [1u64, 2, 3, 4, 5, 6] {
            fake_archive(&base, step, 0);
        }
        let policy = RetentionPolicy { keep_last: 2, keep_hourly: 0 };
        let doomed = plan_gc(&list_archives(&base).unwrap(), policy);
        assert_eq!(doomed.len(), 4);
        // "crash" after deleting half the doomed set
        for path in &doomed[..2] {
            std::fs::remove_file(path).unwrap();
        }
        // the next pass deletes the rest and is then a no-op
        assert_eq!(collect_garbage(&base, policy).unwrap(), 2);
        let left: Vec<u64> = list_archives(&base).unwrap().iter().map(|a| a.step).collect();
        assert_eq!(left, vec![5, 6]);
        assert_eq!(collect_garbage(&base, policy).unwrap(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}

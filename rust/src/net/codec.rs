//! Wire v4 payload codecs: negotiated gradient compression plus the
//! pooled scatter-gather frame writers behind the zero-allocation hot
//! path.
//!
//! Every parameter-bearing frame (`Push`/`PushShard`/`Params`/
//! `ShardParams`) carries a one-byte payload-encoding tag ahead of its
//! vector:
//!
//! ```text
//! tag 0  none   [u64 count][count x f32 LE]            (bit-exact)
//! tag 1  f16    [u64 count][count x u16 LE]            (IEEE binary16)
//! tag 2  bf16   [u64 count][count x u16 LE]            (bfloat16)
//! tag 3  top-k  [u64 full_len][u64 nnz]
//!               [nnz x u32 index LE, strictly increasing]
//!               [nnz x f32 value LE]
//! ```
//!
//! Frames are self-describing: the decoder densifies whatever tag it
//! finds into a full-length `Vec<f32>` exactly once, so everything above
//! the wire layer (the masters, the ticket gates, the tests) keeps
//! seeing dense vectors.  What each side *sends* is negotiated in the
//! handshake: the server advertises an [`EncodingSet`] in `HelloAck`,
//! the client requests an [`Encoding`] in `Hello`, and both compute the
//! same [`grant`] — an unadvertised request falls back to `none`, never
//! to an error, so a v4 client always interoperates with a stricter
//! server.  `encoding=none` is the default and is byte-identical to the
//! uncompressed frames every equivalence suite pins.
//!
//! Decoding is fail-closed like the rest of the wire: an unknown payload
//! tag, a truncated half/value array, a NaN-bearing f16/bf16 (a
//! quantized gradient has no business carrying NaN; ±inf from overflow
//! is legal), a top-k `full_len` past the frame cap (the densify would
//! OOM), `nnz > full_len`, an out-of-range index, or a non-increasing
//! index sequence all reject the frame.
//!
//! Top-k sparsification uses **error feedback**: the [`Compressor`]
//! keeps one residual vector per worker slot, folds it into the next
//! gradient before selection, and banks whatever didn't make the cut.
//! Residuals are worker-local soft state — a reconnect abandons them
//! together with the owed acks (DESIGN.md §12).

use crate::net::wire::{self, Dec, Header, MAGIC, MAX_FRAME, VERSION};
use std::io::Write;
use std::sync::atomic::{AtomicU64, Ordering};

// ---------------------------------------------------------- negotiation

/// A per-frame payload encoding (the v4 negotiation unit).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Encoding {
    /// Raw little-endian f32s — bit-exact, the v3-equivalent default.
    #[default]
    None,
    /// IEEE binary16 quantization (round-to-nearest-even): half the
    /// bytes, ~3 decimal digits, gradients under ~65504 in magnitude.
    F16,
    /// bfloat16 quantization (round-to-nearest-even): half the bytes,
    /// full f32 exponent range, ~2 decimal digits.
    Bf16,
    /// Top-k magnitude sparsification with worker-side error-feedback
    /// residuals; `k` is the number of coordinates kept per push.
    TopK { k: u32 },
}

impl Encoding {
    /// The one-byte wire tag ahead of each encoded payload.
    pub fn tag(self) -> u8 {
        match self {
            Encoding::None => 0,
            Encoding::F16 => 1,
            Encoding::Bf16 => 2,
            Encoding::TopK { .. } => 3,
        }
    }

    /// The u32 parameter carried next to the tag in `Hello` (`k` for
    /// top-k, 0 otherwise).
    pub fn param(self) -> u32 {
        match self {
            Encoding::TopK { k } => k,
            _ => 0,
        }
    }

    /// This encoding's bit in an advertised [`EncodingSet`].
    pub fn bit(self) -> u32 {
        1 << self.tag()
    }

    /// Rebuild from the (tag, param) pair a `Hello` carries.
    pub fn from_wire(tag: u8, param: u32) -> anyhow::Result<Encoding> {
        match tag {
            0 => Ok(Encoding::None),
            1 => Ok(Encoding::F16),
            2 => Ok(Encoding::Bf16),
            3 => {
                anyhow::ensure!(param >= 1, "top-k encoding needs k >= 1");
                Ok(Encoding::TopK { k: param })
            }
            other => anyhow::bail!("unknown encoding tag {other}"),
        }
    }
}

impl std::fmt::Display for Encoding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Encoding::None => write!(f, "none"),
            Encoding::F16 => write!(f, "f16"),
            Encoding::Bf16 => write!(f, "bf16"),
            Encoding::TopK { k } => write!(f, "topk:{k}"),
        }
    }
}

impl std::str::FromStr for Encoding {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let s = s.to_ascii_lowercase();
        match s.as_str() {
            "none" => Ok(Encoding::None),
            "f16" => Ok(Encoding::F16),
            "bf16" => Ok(Encoding::Bf16),
            other => match other.strip_prefix("topk:") {
                Some(ks) => {
                    let k: u32 = ks
                        .parse()
                        .map_err(|e| anyhow::anyhow!("bad top-k count {ks:?}: {e}"))?;
                    anyhow::ensure!(k >= 1, "top-k needs k >= 1");
                    Ok(Encoding::TopK { k })
                }
                None => anyhow::bail!("unknown encoding {other:?} (none|f16|bf16|topk:K)"),
            },
        }
    }
}

/// The set of encodings a server is willing to receive/serve, advertised
/// as a bitmask in `HelloAck` (`dana serve --encodings none,f16,...`).
/// `none` is always a member — the protocol must stay speakable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EncodingSet(pub u32);

impl EncodingSet {
    /// Everything this build speaks.
    pub const ALL: EncodingSet = EncodingSet(0b1111);
    /// Uncompressed frames only.
    pub const NONE_ONLY: EncodingSet = EncodingSet(0b0001);

    pub fn contains(self, e: Encoding) -> bool {
        self.0 & e.bit() != 0
    }
}

impl Default for EncodingSet {
    fn default() -> Self {
        EncodingSet::ALL
    }
}

impl std::str::FromStr for EncodingSet {
    type Err = anyhow::Error;

    /// Comma list of encoding classes (`none,f16,bf16,topk` or `all`);
    /// `none` is implied even when omitted.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let mut bits = EncodingSet::NONE_ONLY.0;
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            bits |= match part.to_ascii_lowercase().as_str() {
                "all" => EncodingSet::ALL.0,
                "none" => Encoding::None.bit(),
                "f16" => Encoding::F16.bit(),
                "bf16" => Encoding::Bf16.bit(),
                "topk" => Encoding::TopK { k: 1 }.bit(),
                other => anyhow::bail!("unknown encoding class {other:?} (none|f16|bf16|topk|all)"),
            };
        }
        Ok(EncodingSet(bits))
    }
}

impl std::fmt::Display for EncodingSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut first = true;
        for (bit, name) in [(0b0001, "none"), (0b0010, "f16"), (0b0100, "bf16"), (0b1000, "topk")] {
            if self.0 & bit != 0 {
                if !first {
                    write!(f, ",")?;
                }
                write!(f, "{name}")?;
                first = false;
            }
        }
        if first {
            write!(f, "none")?;
        }
        Ok(())
    }
}

/// What a request resolves to against an advertised set: the request if
/// advertised, else `none`.  Both sides compute this identically from
/// the handshake, so no extra round trip carries the decision.
pub fn grant(advertised: EncodingSet, req: Encoding) -> Encoding {
    if advertised.contains(req) {
        req
    } else {
        Encoding::None
    }
}

/// The encoding the server uses for its parameter replies to a worker
/// granted `enc`.  Quantizations compress both directions; top-k is
/// push-only — sparsifying θ would discard parameters, not noise.
pub fn reply_encoding(enc: Encoding) -> Encoding {
    match enc {
        Encoding::F16 | Encoding::Bf16 => enc,
        _ => Encoding::None,
    }
}

// ---------------------------------------------------------- f16 / bf16
//
// The per-element converters moved to `math::scalar` so the kernel
// dispatch layer can share one reference definition between the scalar
// and SIMD batch codecs; re-exported here (their historical home) with
// identical signatures and bit behaviour, pinned by the tests below.

pub use crate::math::scalar::{bf16_to_f32, f16_to_f32, f32_to_bf16, f32_to_f16};

// ---------------------------------------------------------- payload codec

/// Exact on-wire length of `vals` under `enc`, including the tag byte —
/// the frame writers size and cap-check bodies with this before
/// serializing anything.
pub fn payload_wire_len(enc: Encoding, vals: &[f32]) -> usize {
    1 + match enc {
        Encoding::None => 8 + 4 * vals.len(),
        Encoding::F16 | Encoding::Bf16 => 8 + 2 * vals.len(),
        Encoding::TopK { .. } => {
            let nnz = vals.iter().filter(|x| **x != 0.0).count();
            8 + 8 + 6 * nnz
        }
    }
}

/// Append the tagged payload for `vals` under `enc`.  For top-k the
/// caller has already run the [`Compressor`] — `vals` is dense with
/// zeros outside the selection, and only the nonzeros travel.
pub(crate) fn put_payload(out: &mut Vec<u8>, enc: Encoding, vals: &[f32]) {
    out.push(enc.tag());
    match enc {
        Encoding::None => wire::put_vec_f32(out, vals),
        Encoding::F16 => {
            wire::put_u64(out, vals.len() as u64);
            crate::math::f16_encode_into(out, vals);
        }
        Encoding::Bf16 => {
            wire::put_u64(out, vals.len() as u64);
            crate::math::bf16_encode_into(out, vals);
        }
        Encoding::TopK { .. } => {
            let nnz = vals.iter().filter(|x| **x != 0.0).count();
            wire::put_u64(out, vals.len() as u64);
            wire::put_u64(out, nnz as u64);
            out.reserve(6 * nnz);
            for (i, &x) in vals.iter().enumerate() {
                if x != 0.0 {
                    wire::put_u32(out, i as u32);
                }
            }
            for &x in vals {
                if x != 0.0 {
                    wire::put_f32(out, x);
                }
            }
        }
    }
}

/// Decode one tagged payload into a dense `Vec<f32>` — the single
/// densify of a frame's lifetime.  Fail-closed; see the module docs.
pub(crate) fn get_payload(d: &mut Dec<'_>) -> anyhow::Result<Vec<f32>> {
    let tag = d.u8()?;
    match tag {
        0 => d.vec_f32(),
        1 | 2 => {
            let n = d.u64()? as usize;
            let bytes = d.take(
                n.checked_mul(2)
                    .ok_or_else(|| anyhow::anyhow!("f16 count {n} overflows"))?,
            )?;
            let mut out = Vec::with_capacity(n);
            if tag == 1 {
                crate::math::f16_decode_into(&mut out, bytes);
            } else {
                crate::math::bf16_decode_into(&mut out, bytes);
            }
            // Fail-closed NaN scan after the batch decode (same rejection
            // as the old per-element loop; the frame is dropped whole
            // either way, so checking after densify is equivalent).
            anyhow::ensure!(
                !out.iter().any(|x| x.is_nan()),
                "NaN in a {}-encoded payload",
                if tag == 1 { "f16" } else { "bf16" }
            );
            Ok(out)
        }
        3 => {
            let full = d.u64()? as usize;
            anyhow::ensure!(
                full <= (MAX_FRAME / 4) as usize,
                "top-k full length {full} exceeds the frame cap"
            );
            let nnz = d.u64()? as usize;
            anyhow::ensure!(nnz <= full, "top-k nnz {nnz} exceeds full length {full}");
            let idx = d.take(
                nnz.checked_mul(4)
                    .ok_or_else(|| anyhow::anyhow!("top-k nnz {nnz} overflows"))?,
            )?;
            let vals = d.take(4 * nnz)?;
            let mut out = vec![0.0f32; full];
            let mut prev: i64 = -1;
            for (ic, vc) in idx.chunks_exact(4).zip(vals.chunks_exact(4)) {
                let i = u32::from_le_bytes(ic.try_into().expect("4 bytes")) as i64;
                anyhow::ensure!(
                    (i as usize) < full,
                    "top-k index {i} out of range (full length {full})"
                );
                anyhow::ensure!(i > prev, "top-k indices must be strictly increasing");
                prev = i;
                out[i as usize] = f32::from_le_bytes(vc.try_into().expect("4 bytes"));
            }
            Ok(out)
        }
        other => anyhow::bail!("unknown payload encoding tag {other}"),
    }
}

// ---------------------------------------------------------- frame writers

/// Write a `Push` frame straight from a borrowed gradient slice — the
/// hot-loop equivalent of `write_frame(&Msg::Push {..})`, minus the
/// `Vec<f32>` clone and the fresh frame allocation.  Byte-identical to
/// the `Msg` path when `enc` is `none`.  Returns the frame's size on
/// the wire (length prefix included).
pub fn write_push<W: Write>(w: &mut W, gen: u32, enc: Encoding, msg: &[f32]) -> std::io::Result<usize> {
    write_encoded(w, 3, 4, |b| wire::put_u32(b, gen), enc, msg)
}

/// Write one shard slice of a push (`PushShard`) from a borrowed slice —
/// the scatter-gather half: `push_sliced` hands each shard's subslice of
/// ONE gradient buffer to this writer, so slicing never copies.
pub fn write_push_shard<W: Write>(
    w: &mut W,
    gen: u32,
    shard: u32,
    enc: Encoding,
    msg: &[f32],
) -> std::io::Result<usize> {
    write_encoded(
        w,
        10,
        8,
        |b| {
            wire::put_u32(b, gen);
            wire::put_u32(b, shard);
        },
        enc,
        msg,
    )
}

/// Write a `Params` reply from the server's borrowed parameter buffer.
pub fn write_params<W: Write>(
    w: &mut W,
    header: &Header,
    enc: Encoding,
    params: &[f32],
) -> std::io::Result<usize> {
    write_encoded(w, 17, HDR_LEN, |b| wire::put_header(b, header), enc, params)
}

/// Write a `ShardParams` reply from a borrowed slice.
pub fn write_shard_params<W: Write>(
    w: &mut W,
    header: &Header,
    shard: u32,
    enc: Encoding,
    params: &[f32],
) -> std::io::Result<usize> {
    write_encoded(
        w,
        22,
        HDR_LEN + 4,
        |b| {
            wire::put_header(b, header);
            wire::put_u32(b, shard);
        },
        enc,
        params,
    )
}

/// Encoded [`Header`] size (kept in sync with `Msg::body_len`'s HDR).
const HDR_LEN: usize = 8 + 4 + 4 + 4 + 8 + 8 + 8 + 8 + 4 + 4 + 4 + 1;

/// Shared frame writer: compute the exact body length, refuse an
/// oversized frame before serializing (symmetric with the decoder),
/// then build the whole frame in a pooled thread-local buffer and write
/// it with one `write_all` + flush.
fn write_encoded<W: Write>(
    w: &mut W,
    tag: u8,
    prefix_len: usize,
    prefix: impl FnOnce(&mut Vec<u8>),
    enc: Encoding,
    vals: &[f32],
) -> std::io::Result<usize> {
    let body_len = 4 + 1 + 1 + prefix_len + payload_wire_len(enc, vals);
    if body_len > MAX_FRAME as usize {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("refusing to encode a {body_len}-byte frame body (cap {MAX_FRAME})"),
        ));
    }
    wire::with_frame_buf(|buf| {
        buf.clear();
        buf.reserve(4 + body_len);
        wire::put_u32(buf, body_len as u32);
        buf.extend_from_slice(&MAGIC);
        buf.push(VERSION);
        buf.push(tag);
        prefix(buf);
        put_payload(buf, enc, vals);
        debug_assert_eq!(buf.len(), 4 + body_len, "payload_wire_len out of sync with put_payload");
        w.write_all(buf)?;
        w.flush()?;
        Ok(4 + body_len)
    })
}

// ---------------------------------------------------------- compressor

/// Worker-side gradient transform for a negotiated encoding.
///
/// * f16/bf16: quantize–dequantize in place, so the caller trains
///   against exactly the values the wire will carry (used by the
///   in-process drivers to simulate compression noise; the remote path
///   lets the frame writer quantize, which produces identical bits).
/// * top-k: fold in the slot's error-feedback residual, keep the `k`
///   largest-magnitude coordinates, bank the rest.  The transformed
///   gradient is dense-with-zeros — ready for [`write_push`]'s sparse
///   encoding or a direct in-process apply.
///
/// Residuals are per-slot soft state: [`Compressor::reset_slot`] drops
/// one on churn/reconnect (the update they were banked against is
/// unaccounted), [`Compressor::reset_all`] on a full reconnect.
pub struct Compressor {
    enc: Encoding,
    residuals: Vec<Option<Vec<f32>>>,
    idx: Vec<u32>,
}

impl Compressor {
    pub fn new(enc: Encoding) -> Self {
        Compressor { enc, residuals: Vec::new(), idx: Vec::new() }
    }

    pub fn encoding(&self) -> Encoding {
        self.enc
    }

    /// True when [`Compressor::transform`] changes anything.
    pub fn is_active(&self) -> bool {
        self.enc != Encoding::None
    }

    /// Transform `g` in place into what the master will actually apply.
    pub fn transform(&mut self, slot: usize, g: &mut [f32]) {
        match self.enc {
            Encoding::None => {}
            Encoding::F16 => crate::math::f16_round_trip(g),
            Encoding::Bf16 => crate::math::bf16_round_trip(g),
            Encoding::TopK { k } => {
                let n = g.len();
                if slot >= self.residuals.len() {
                    self.residuals.resize_with(slot + 1, || None);
                }
                let r = self.residuals[slot].get_or_insert_with(|| vec![0.0; n]);
                if r.len() != n {
                    *r = vec![0.0; n];
                }
                for (x, ri) in g.iter_mut().zip(r.iter_mut()) {
                    *x += *ri;
                    *ri = 0.0;
                }
                let kk = (k as usize).min(n);
                if kk == 0 || kk >= n {
                    return;
                }
                self.idx.clear();
                self.idx.extend(0..n as u32);
                // partition: the kk largest |g| land in idx[..kk]
                self.idx.select_nth_unstable_by(kk - 1, |&a, &b| {
                    g[b as usize].abs().total_cmp(&g[a as usize].abs())
                });
                for &i in &self.idx[kk..] {
                    let i = i as usize;
                    r[i] = g[i];
                    g[i] = 0.0;
                }
            }
        }
    }

    /// Drop one slot's residual (the slot left, died, or was retagged).
    pub fn reset_slot(&mut self, slot: usize) {
        if let Some(r) = self.residuals.get_mut(slot) {
            *r = None;
        }
    }

    /// Drop every residual (full reconnect: all owed acks abandoned).
    pub fn reset_all(&mut self) {
        for r in &mut self.residuals {
            *r = None;
        }
    }
}

// ---------------------------------------------------------- byte counters

/// Lock-free tx/rx byte counters a connection owner shares with its
/// conns — the client-side mirror of the server's `MetricsHub` bytes.
#[derive(Debug, Default)]
pub struct WireStats {
    tx: AtomicU64,
    rx: AtomicU64,
}

impl WireStats {
    pub fn add_tx(&self, n: usize) {
        self.tx.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn add_rx(&self, n: usize) {
        self.rx.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// (bytes sent, bytes received) so far.
    pub fn totals(&self) -> (u64, u64) {
        (self.tx.load(Ordering::Relaxed), self.rx.load(Ordering::Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_round_trips_representable_values() {
        for x in [0.0f32, -0.0, 1.0, -1.0, 0.5, 65504.0, -65504.0, 6.103_515_6e-5, 1.5, 0.099_975_586] {
            let h = f32_to_f16(x);
            assert_eq!(f16_to_f32(h), x, "{x} must survive (it is a half)");
        }
        // signs of zero survive the trip
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        assert_eq!(f32_to_f16(0.0), 0x0000);
    }

    #[test]
    fn f16_rounds_to_nearest_even_and_overflows_to_inf() {
        // 1 + 2^-11 sits exactly between 1.0 and the next half (1+2^-10):
        // ties-to-even keeps 1.0
        assert_eq!(f16_to_f32(f32_to_f16(1.0 + 2.0f32.powi(-11))), 1.0);
        // a hair above the tie rounds up
        assert_eq!(
            f16_to_f32(f32_to_f16(1.0 + 2.0f32.powi(-11) + 2.0f32.powi(-20))),
            1.0 + 2.0f32.powi(-10)
        );
        assert_eq!(f32_to_f16(70000.0), 0x7c00, "overflow is +inf");
        assert_eq!(f32_to_f16(-70000.0), 0xfc00);
        assert_eq!(f32_to_f16(1e-10), 0, "underflow is +0");
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        assert_eq!(f16_to_f32(f32_to_f16(f32::INFINITY)), f32::INFINITY);
    }

    #[test]
    fn f16_subnormals_round_trip() {
        // smallest positive half (2^-24) and a mid-range subnormal
        let tiny = 2.0f32.powi(-24);
        assert_eq!(f16_to_f32(f32_to_f16(tiny)), tiny);
        let sub = 2.0f32.powi(-17);
        assert_eq!(f16_to_f32(f32_to_f16(sub)), sub);
    }

    #[test]
    fn bf16_truncates_with_rounding_and_keeps_nan() {
        assert_eq!(bf16_to_f32(f32_to_bf16(1.0)), 1.0);
        assert_eq!(bf16_to_f32(f32_to_bf16(-2.5)), -2.5);
        // bf16 keeps the f32 exponent range
        assert_eq!(bf16_to_f32(f32_to_bf16(1e30)), 1.0009766e30);
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        let x = 1.0 + 2.0f32.powi(-8); // tie between 1.0 and 1+2^-7
        assert_eq!(bf16_to_f32(f32_to_bf16(x)), 1.0, "ties to even");
    }

    #[test]
    fn quantization_is_idempotent() {
        // re-quantizing an already-quantized value is exact: the client
        // pre-transform and the wire encoder agree bit-for-bit
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..1000 {
            let x = rng.normal() as f32;
            let q = f16_to_f32(f32_to_f16(x));
            assert_eq!(f16_to_f32(f32_to_f16(q)).to_bits(), q.to_bits());
            let qb = bf16_to_f32(f32_to_bf16(x));
            assert_eq!(bf16_to_f32(f32_to_bf16(qb)).to_bits(), qb.to_bits());
        }
    }

    #[test]
    fn payload_round_trips_and_len_matches() {
        let vals = [0.5f32, -1.25, 0.0, 3.0, -0.0078125, 2.0f32.powi(-14)];
        for enc in [
            Encoding::None,
            Encoding::F16,
            Encoding::Bf16,
            Encoding::TopK { k: 3 },
        ] {
            let mut out = Vec::new();
            put_payload(&mut out, enc, &vals);
            assert_eq!(out.len(), payload_wire_len(enc, &vals), "{enc}");
            let mut d = Dec { b: &out, i: 0 };
            let back = get_payload(&mut d).unwrap();
            d.done().unwrap();
            assert_eq!(back.len(), vals.len(), "{enc}");
            match enc {
                Encoding::None | Encoding::TopK { .. } => {
                    // the dense path is bit-exact; top-k here encodes the
                    // already-sparse buffer, so nonzeros are bit-exact too
                    assert_eq!(back, vals.to_vec(), "{enc}");
                }
                Encoding::F16 => {
                    for (a, b) in back.iter().zip(vals.iter()) {
                        assert_eq!(*a, f16_to_f32(f32_to_f16(*b)), "{enc}");
                    }
                }
                Encoding::Bf16 => {
                    for (a, b) in back.iter().zip(vals.iter()) {
                        assert_eq!(*a, bf16_to_f32(f32_to_bf16(*b)), "{enc}");
                    }
                }
            }
        }
    }

    #[test]
    fn compressor_topk_keeps_largest_and_banks_the_rest() {
        let mut c = Compressor::new(Encoding::TopK { k: 2 });
        let mut g = vec![1.0f32, -4.0, 0.25, 3.0];
        c.transform(0, &mut g);
        assert_eq!(g, vec![0.0, -4.0, 0.0, 3.0]);
        // the residual carries what was dropped, and folds into the next push
        let mut g2 = vec![0.5f32, 0.0, 0.5, 0.0];
        c.transform(0, &mut g2);
        // g2 + residual = [1.5, 0, 0.75, 0]: top-2 keeps both nonzeros
        assert_eq!(g2, vec![1.5, 0.0, 0.75, 0.0]);
        // reset drops the (now empty) residual without touching others
        c.reset_slot(0);
        let mut g3 = vec![1.0f32, 2.0, 3.0, 4.0];
        c.transform(0, &mut g3);
        assert_eq!(g3, vec![0.0, 0.0, 3.0, 4.0]);
    }

    #[test]
    fn encoding_parse_round_trips() {
        for e in [Encoding::None, Encoding::F16, Encoding::Bf16, Encoding::TopK { k: 64 }] {
            assert_eq!(e.to_string().parse::<Encoding>().unwrap(), e);
        }
        assert!("topk:0".parse::<Encoding>().is_err());
        assert!("fp8".parse::<Encoding>().is_err());
        let set: EncodingSet = "f16,topk".parse().unwrap();
        assert!(set.contains(Encoding::None), "none is always speakable");
        assert!(set.contains(Encoding::F16));
        assert!(set.contains(Encoding::TopK { k: 9 }));
        assert!(!set.contains(Encoding::Bf16));
        assert_eq!("all".parse::<EncodingSet>().unwrap(), EncodingSet::ALL);
        assert_eq!(EncodingSet::default(), EncodingSet::ALL);
        assert!("f16,quantum".parse::<EncodingSet>().is_err());
    }

    #[test]
    fn grants_fall_back_to_none() {
        assert_eq!(grant(EncodingSet::ALL, Encoding::F16), Encoding::F16);
        assert_eq!(grant(EncodingSet::NONE_ONLY, Encoding::F16), Encoding::None);
        let k = Encoding::TopK { k: 32 };
        assert_eq!(grant(EncodingSet::ALL, k), k);
        assert_eq!(reply_encoding(k), Encoding::None, "top-k never quantizes pulls");
        assert_eq!(reply_encoding(Encoding::Bf16), Encoding::Bf16);
    }
}

//! Std-only HTTP/1.1 status endpoint for `dana serve` (`--status-addr`).
//!
//! A monitoring scrape must never be able to hurt training, so the
//! listener is isolated from the serving threads on every axis:
//!
//! * **its own thread + socket** — the wire protocol's accept loop and
//!   serving threads are untouched; a wedged scraper wedges only itself
//!   (2 s read/write timeouts, one connection served at a time,
//!   `Connection: close`);
//! * **lock-free data sources** — `GET /metrics` renders exclusively
//!   from [`crate::server::metrics::MetricsHub`] atomics and the atomic
//!   gate/membership mirrors ([`StatusSource::metrics_snapshot`]), so a
//!   scrape takes no lock `push_concurrent` wants.  `GET /status`
//!   additionally reads the per-slot tables under their own (effectively
//!   uncontended) mutexes;
//! * **fail-closed parsing** — same posture as the wire decoder
//!   (`net/wire.rs`): bounded request line, bounded header block, `GET`
//!   only, exact path match.  A malformed request is answered and the
//!   connection dropped *without ever touching the master* (the snapshot
//!   is taken only after the request fully validates).
//!
//! Hand-rolled HTTP/1.1 because the offline registry has no HTTP crate;
//! the surface is deliberately tiny (two read-only GET endpoints).

use crate::server::metrics::HistogramSnapshot;
use crate::util::json::Json;
use std::io::{self, BufRead, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Longest accepted request line (`GET /metrics HTTP/1.1` is 24 bytes;
/// anything near the cap is an attack or a bug).
pub const MAX_REQUEST_LINE: usize = 1024;
/// Total header block budget.
pub const MAX_HEADER_BYTES: usize = 8 * 1024;
/// Maximum number of header lines.
pub const MAX_HEADER_LINES: usize = 64;

/// A fully validated request — the only two things this server serves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HttpRequest {
    Metrics,
    Status,
}

/// Why a request was refused.  Fail-closed: every variant is answered
/// with a final status and the connection is closed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HttpError {
    BadRequest(&'static str),
    NotFound,
    MethodNotAllowed,
}

impl HttpError {
    pub fn status_line(&self) -> &'static str {
        match self {
            HttpError::BadRequest(_) => "400 Bad Request",
            HttpError::NotFound => "404 Not Found",
            HttpError::MethodNotAllowed => "405 Method Not Allowed",
        }
    }

    pub fn message(&self) -> &'static str {
        match self {
            HttpError::BadRequest(m) => m,
            HttpError::NotFound => "not found (try /metrics or /status)",
            HttpError::MethodNotAllowed => "method not allowed (GET only)",
        }
    }
}

/// One row of the per-worker slot table (`GET /status`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotRow {
    pub slot: usize,
    /// Connection generation (bumped on every (re)attach; 0 = never
    /// attached over the wire).
    pub generation: u32,
    pub live: bool,
    /// Outstanding pulls in the slot's pipeline window.
    pub window: usize,
    /// Master step count right after the slot's last applied push
    /// (0 = never pushed).
    pub last_push: u64,
}

/// Last durable checkpoint, as the daemon remembers writing it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CheckpointInfo {
    pub step: u64,
    pub bytes: u64,
    pub age_secs: f64,
}

/// Where this process sits in a multi-server placement (PR 8): its
/// role, placement epoch, hosted shard range, and — for a standby —
/// how far it trails its primary.  The default is a standalone primary
/// at epoch 0 that has never taken over.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClusterStatus {
    /// True while this process is a hot standby tailing a primary.
    pub standby: bool,
    /// Placement epoch served/observed (monotone across takeovers).
    pub epoch: u64,
    /// Takeovers performed by this process (`dana_takeovers_total`).
    pub takeovers: u64,
    /// First global shard hosted (primaries) or watched (standbys).
    pub shard_start: u32,
    /// Number of shards hosted/watched.
    pub shard_hosted: u32,
    /// Global shard count across the placement.
    pub total_shards: u32,
    /// Steps the newest tailed archive trails the primary's live step
    /// count by; `None` for primaries (and for a standby that has not
    /// seen its primary yet).
    pub standby_lag: Option<u64>,
}

/// Everything the renderers need, gathered in one place so both
/// endpoints and their tests work from plain data.
#[derive(Debug, Clone)]
pub struct StatusSnapshot {
    pub uptime_secs: f64,
    pub master_step: u64,
    pub live_workers: usize,
    pub total_slots: usize,
    pub pushes_total: u64,
    pub pushes_dropped: u64,
    /// Filled by the listener from the delta between scrapes (0.0 on the
    /// first scrape).
    pub pushes_per_sec: f64,
    /// Wire bytes written / read by the serving threads (whole frames,
    /// handshake included), from the `MetricsHub` byte counters.
    pub bytes_tx: u64,
    pub bytes_rx: u64,
    /// tx-byte rate from the scrape-to-scrape delta, filled by the
    /// listener like `pushes_per_sec` (0.0 on the first scrape).
    pub bytes_per_second: f64,
    /// Active math kernel backend name (`scalar`/`sse2`/`avx2`/`neon`),
    /// from [`crate::math::active_kernels`] — a scrape can tell at a
    /// glance whether a deployment is running the SIMD path it expects.
    pub kernels: &'static str,
    pub gap: HistogramSnapshot,
    pub lag: HistogramSnapshot,
    /// Per-shard (gate position, ticket backlog); empty on the
    /// global-lock backend.
    pub shard_gates: Vec<(u64, u64)>,
    pub checkpoint: Option<CheckpointInfo>,
    /// Cluster placement: role, epoch, hosted range, takeovers.
    pub cluster: ClusterStatus,
    /// Per-slot rows; left empty for `/metrics` (which must not take
    /// slot locks) and filled via [`StatusSource::slot_rows`] for
    /// `/status`.
    pub slots: Vec<SlotRow>,
}

/// What the daemon exposes to the listener.  Implemented by the wire
/// server's shared state; mocked in tests.
pub trait StatusSource: Send + Sync {
    /// Everything `GET /metrics` needs, from lock-free sources only.
    /// `slots` must be left empty and `pushes_per_sec` /
    /// `bytes_per_second` zero (the listener fills them from
    /// scrape-to-scrape deltas).
    fn metrics_snapshot(&self) -> StatusSnapshot;

    /// Per-slot rows for `GET /status`.  May take short per-slot /
    /// connection-table locks — never the sequencer or a shard lock.
    fn slot_rows(&self) -> Vec<SlotRow>;
}

// ------------------------------------------------------------ parsing

/// Read one `\n`-terminated line of at most `max` bytes (CR/LF
/// stripped).  Longer lines, EOF mid-line, and non-UTF-8 all fail.
fn read_line_bounded<R: BufRead>(r: &mut R, max: usize) -> Result<String, HttpError> {
    let mut buf = Vec::new();
    let n = (&mut *r)
        .take(max as u64 + 1)
        .read_until(b'\n', &mut buf)
        .map_err(|_| HttpError::BadRequest("read error"))?;
    if n == 0 {
        return Err(HttpError::BadRequest("unexpected end of stream"));
    }
    if buf.last() != Some(&b'\n') || buf.len() > max {
        return Err(HttpError::BadRequest("line too long"));
    }
    while matches!(buf.last(), Some(b'\n' | b'\r')) {
        buf.pop();
    }
    String::from_utf8(buf).map_err(|_| HttpError::BadRequest("non-utf8 bytes"))
}

/// Parse one request, fail-closed.  The caller takes a master snapshot
/// only on `Ok`, so malformed traffic never touches training state.
pub fn read_request<R: BufRead>(r: &mut R) -> Result<HttpRequest, HttpError> {
    let line = read_line_bounded(r, MAX_REQUEST_LINE)?;
    let mut parts = line.split_whitespace();
    let (method, target, version) =
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(t), Some(v), None) => (m, t, v),
            _ => return Err(HttpError::BadRequest("malformed request line")),
        };
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::BadRequest("unsupported protocol"));
    }
    // Drain the header block under hard bounds before judging the
    // method/path (one response per connection either way).
    let mut lines = 0usize;
    let mut total = 0usize;
    loop {
        let h = read_line_bounded(r, MAX_HEADER_BYTES)?;
        if h.is_empty() {
            break;
        }
        lines += 1;
        total += h.len();
        if lines > MAX_HEADER_LINES || total > MAX_HEADER_BYTES {
            return Err(HttpError::BadRequest("header block too large"));
        }
        if !h.contains(':') {
            return Err(HttpError::BadRequest("malformed header"));
        }
    }
    if method != "GET" {
        return Err(HttpError::MethodNotAllowed);
    }
    match target {
        "/metrics" => Ok(HttpRequest::Metrics),
        "/status" => Ok(HttpRequest::Status),
        _ => Err(HttpError::NotFound),
    }
}

/// Write one complete HTTP/1.1 response and flush.
pub fn write_response(
    w: &mut dyn Write,
    status: &str,
    content_type: &str,
    body: &str,
) -> io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    )?;
    w.flush()
}

// ---------------------------------------------------------- rendering

fn render_histogram(o: &mut String, name: &str, h: &HistogramSnapshot) {
    use std::fmt::Write as _;
    let _ = writeln!(o, "# TYPE {name} histogram");
    let mut cum = 0u64;
    for (i, &n) in h.buckets.iter().enumerate() {
        cum += n;
        if i < h.bounds.len() {
            let _ = writeln!(o, "{name}_bucket{{le=\"{}\"}} {cum}", h.bounds[i]);
        } else {
            let _ = writeln!(o, "{name}_bucket{{le=\"+Inf\"}} {cum}");
        }
    }
    let _ = writeln!(o, "{name}_sum {}", h.sum);
    let _ = writeln!(o, "{name}_count {}", h.count);
    for q in [0.5, 0.9, 0.99] {
        let _ = writeln!(o, "{name}_quantile{{q=\"{q}\"}} {}", h.quantile(q));
    }
}

/// Prometheus text exposition (format version 0.0.4).
pub fn render_prometheus(s: &StatusSnapshot) -> String {
    use std::fmt::Write as _;
    let mut o = String::with_capacity(4096);
    let _ = writeln!(o, "# TYPE dana_uptime_seconds gauge");
    let _ = writeln!(o, "dana_uptime_seconds {}", s.uptime_secs);
    let _ = writeln!(o, "# TYPE dana_master_step gauge");
    let _ = writeln!(o, "dana_master_step {}", s.master_step);
    let _ = writeln!(o, "# TYPE dana_pushes_total counter");
    let _ = writeln!(o, "dana_pushes_total {}", s.pushes_total);
    let _ = writeln!(o, "# TYPE dana_pushes_per_second gauge");
    let _ = writeln!(o, "dana_pushes_per_second {}", s.pushes_per_sec);
    let _ = writeln!(o, "# TYPE dana_pushes_dropped_total counter");
    let _ = writeln!(o, "dana_pushes_dropped_total {}", s.pushes_dropped);
    let _ = writeln!(o, "# TYPE dana_bytes_tx_total counter");
    let _ = writeln!(o, "dana_bytes_tx_total {}", s.bytes_tx);
    let _ = writeln!(o, "# TYPE dana_bytes_rx_total counter");
    let _ = writeln!(o, "dana_bytes_rx_total {}", s.bytes_rx);
    let _ = writeln!(o, "# TYPE dana_bytes_per_second gauge");
    let _ = writeln!(o, "dana_bytes_per_second {}", s.bytes_per_second);
    let _ = writeln!(o, "# TYPE dana_kernel_backend gauge");
    let _ = writeln!(o, "dana_kernel_backend{{backend=\"{}\"}} 1", s.kernels);
    let _ = writeln!(o, "# TYPE dana_workers_live gauge");
    let _ = writeln!(o, "dana_workers_live {}", s.live_workers);
    let _ = writeln!(o, "# TYPE dana_workers_total gauge");
    let _ = writeln!(o, "dana_workers_total {}", s.total_slots);
    let _ = writeln!(o, "# TYPE dana_workers_retired gauge");
    let _ = writeln!(
        o,
        "dana_workers_retired {}",
        s.total_slots.saturating_sub(s.live_workers)
    );
    if !s.shard_gates.is_empty() {
        let _ = writeln!(o, "# TYPE dana_shard_gate_position gauge");
        for (i, &(pos, _)) in s.shard_gates.iter().enumerate() {
            let _ = writeln!(o, "dana_shard_gate_position{{shard=\"{i}\"}} {pos}");
        }
        let _ = writeln!(o, "# TYPE dana_shard_ticket_backlog gauge");
        for (i, &(_, backlog)) in s.shard_gates.iter().enumerate() {
            let _ = writeln!(o, "dana_shard_ticket_backlog{{shard=\"{i}\"}} {backlog}");
        }
    }
    render_histogram(&mut o, "dana_gap", &s.gap);
    render_histogram(&mut o, "dana_lag", &s.lag);
    if let Some(c) = &s.checkpoint {
        let _ = writeln!(o, "# TYPE dana_checkpoint_step gauge");
        let _ = writeln!(o, "dana_checkpoint_step {}", c.step);
        let _ = writeln!(o, "# TYPE dana_checkpoint_bytes gauge");
        let _ = writeln!(o, "dana_checkpoint_bytes {}", c.bytes);
        let _ = writeln!(o, "# TYPE dana_checkpoint_age_seconds gauge");
        let _ = writeln!(o, "dana_checkpoint_age_seconds {}", c.age_secs);
    }
    let c = &s.cluster;
    let _ = writeln!(o, "# TYPE dana_cluster_role gauge");
    let _ = writeln!(o, "dana_cluster_role{{role=\"primary\"}} {}", u64::from(!c.standby));
    let _ = writeln!(o, "dana_cluster_role{{role=\"standby\"}} {}", u64::from(c.standby));
    let _ = writeln!(o, "# TYPE dana_placement_epoch gauge");
    let _ = writeln!(o, "dana_placement_epoch {}", c.epoch);
    let _ = writeln!(o, "# TYPE dana_takeovers_total counter");
    let _ = writeln!(o, "dana_takeovers_total {}", c.takeovers);
    let _ = writeln!(o, "# TYPE dana_shard_start gauge");
    let _ = writeln!(o, "dana_shard_start {}", c.shard_start);
    let _ = writeln!(o, "# TYPE dana_shards_hosted gauge");
    let _ = writeln!(o, "dana_shards_hosted {}", c.shard_hosted);
    let _ = writeln!(o, "# TYPE dana_shards_total gauge");
    let _ = writeln!(o, "dana_shards_total {}", c.total_shards);
    if let Some(lag) = c.standby_lag {
        let _ = writeln!(o, "# TYPE dana_standby_lag_steps gauge");
        let _ = writeln!(o, "dana_standby_lag_steps {lag}");
    }
    o
}

fn histogram_json(h: &HistogramSnapshot) -> Json {
    Json::obj(vec![
        ("count", Json::num(h.count as f64)),
        ("sum", Json::num(h.sum)),
        ("p50", Json::num(h.quantile(0.5))),
        ("p90", Json::num(h.quantile(0.9))),
        ("p99", Json::num(h.quantile(0.99))),
    ])
}

/// `GET /status` body: the same data as `/metrics` plus the per-worker
/// slot table, as one JSON object.
pub fn render_status_json(s: &StatusSnapshot) -> String {
    let slots: Vec<Json> = s
        .slots
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("slot", Json::num(r.slot as f64)),
                ("generation", Json::num(r.generation as f64)),
                ("live", Json::Bool(r.live)),
                ("window", Json::num(r.window as f64)),
                ("last_push", Json::num(r.last_push as f64)),
            ])
        })
        .collect();
    let shards: Vec<Json> = s
        .shard_gates
        .iter()
        .enumerate()
        .map(|(i, &(pos, backlog))| {
            Json::obj(vec![
                ("shard", Json::num(i as f64)),
                ("gate_position", Json::num(pos as f64)),
                ("ticket_backlog", Json::num(backlog as f64)),
            ])
        })
        .collect();
    let checkpoint = match &s.checkpoint {
        Some(c) => Json::obj(vec![
            ("step", Json::num(c.step as f64)),
            ("bytes", Json::num(c.bytes as f64)),
            ("age_secs", Json::num(c.age_secs)),
        ]),
        None => Json::Null,
    };
    let cl = &s.cluster;
    let cluster = Json::obj(vec![
        ("role", Json::Str(if cl.standby { "standby" } else { "primary" }.into())),
        ("placement_epoch", Json::num(cl.epoch as f64)),
        ("takeovers_total", Json::num(cl.takeovers as f64)),
        ("shard_start", Json::num(cl.shard_start as f64)),
        ("shards_hosted", Json::num(cl.shard_hosted as f64)),
        ("shards_total", Json::num(cl.total_shards as f64)),
        (
            "standby_lag_steps",
            match cl.standby_lag {
                Some(lag) => Json::num(lag as f64),
                None => Json::Null,
            },
        ),
    ]);
    Json::obj(vec![
        ("uptime_secs", Json::num(s.uptime_secs)),
        ("master_step", Json::num(s.master_step as f64)),
        ("workers_live", Json::num(s.live_workers as f64)),
        ("workers_total", Json::num(s.total_slots as f64)),
        ("pushes_total", Json::num(s.pushes_total as f64)),
        ("pushes_dropped", Json::num(s.pushes_dropped as f64)),
        ("pushes_per_sec", Json::num(s.pushes_per_sec)),
        ("bytes_tx", Json::num(s.bytes_tx as f64)),
        ("bytes_rx", Json::num(s.bytes_rx as f64)),
        ("bytes_per_sec", Json::num(s.bytes_per_second)),
        ("kernels", Json::Str(s.kernels.into())),
        ("gap", histogram_json(&s.gap)),
        ("lag", histogram_json(&s.lag)),
        ("shards", Json::Arr(shards)),
        ("checkpoint", checkpoint),
        ("cluster", cluster),
        ("slots", Json::Arr(slots)),
    ])
    .to_string()
}

// ----------------------------------------------------------- listener

/// The status listener: one thread, one connection at a time, owned
/// socket.  Stop by flag + self-connect wake, same idiom as the wire
/// server's accept loop.
pub struct StatusServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl StatusServer {
    pub fn start(addr: &str, source: Arc<dyn StatusSource>) -> anyhow::Result<StatusServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("status listener bind {addr}: {e}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let handle = std::thread::Builder::new()
            .name("dana-status".into())
            .spawn(move || serve_loop(&listener, source.as_ref(), &flag))?;
        Ok(StatusServer { addr: local, stop, handle: Some(handle) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn url(&self) -> String {
        format!("http://{}", self.addr)
    }

    /// Idempotent shutdown: raise the flag, wake the accept loop with a
    /// throwaway connection, join the thread.
    pub fn stop(&mut self) {
        if let Some(h) = self.handle.take() {
            self.stop.store(true, Ordering::SeqCst);
            let _ = TcpStream::connect(self.addr);
            let _ = h.join();
        }
    }
}

impl Drop for StatusServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_loop(listener: &TcpListener, source: &dyn StatusSource, stop: &AtomicBool) {
    // pushes/s and bytes/s need scrape-to-scrape memory; it lives here
    // so the source stays stateless.
    let mut last_scrape: Option<(Instant, u64, u64)> = None;
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        // A rude client (timeout, reset, garbage) hurts only its own
        // connection; nothing to do but move on.
        if let Ok(stream) = conn {
            let _ = handle_conn(stream, source, &mut last_scrape);
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    source: &dyn StatusSource,
    last_scrape: &mut Option<(Instant, u64, u64)>,
) -> io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    let mut reader = io::BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    match read_request(&mut reader) {
        Err(e) => write_response(
            &mut writer,
            e.status_line(),
            "text/plain",
            &format!("{}\n", e.message()),
        ),
        Ok(req) => {
            // Only a fully validated request reaches the master's
            // (lock-free) scrape surface.
            let mut snap = source.metrics_snapshot();
            let now = Instant::now();
            if let Some((t0, n0, b0)) = *last_scrape {
                let dt = now.duration_since(t0).as_secs_f64();
                if dt > 0.0 && snap.pushes_total >= n0 {
                    snap.pushes_per_sec = (snap.pushes_total - n0) as f64 / dt;
                }
                if dt > 0.0 && snap.bytes_tx >= b0 {
                    snap.bytes_per_second = (snap.bytes_tx - b0) as f64 / dt;
                }
            }
            *last_scrape = Some((now, snap.pushes_total, snap.bytes_tx));
            match req {
                HttpRequest::Metrics => write_response(
                    &mut writer,
                    "200 OK",
                    "text/plain; version=0.0.4",
                    &render_prometheus(&snap),
                ),
                HttpRequest::Status => {
                    snap.slots = source.slot_rows();
                    write_response(
                        &mut writer,
                        "200 OK",
                        "application/json",
                        &render_status_json(&snap),
                    )
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::metrics::{AtomicHistogram, GAP_BOUNDS, LAG_BOUNDS};
    use std::io::{Cursor, Read as _};
    use std::sync::atomic::AtomicUsize;

    fn parse(req: &str) -> Result<HttpRequest, HttpError> {
        read_request(&mut Cursor::new(req.as_bytes().to_vec()))
    }

    #[test]
    fn valid_requests_parse() {
        assert_eq!(
            parse("GET /metrics HTTP/1.1\r\nHost: x\r\nAccept: */*\r\n\r\n").unwrap(),
            HttpRequest::Metrics
        );
        assert_eq!(parse("GET /status HTTP/1.0\r\n\r\n").unwrap(), HttpRequest::Status);
        // bare-LF line endings are tolerated (curl never sends them, but
        // the parser strips CR and LF alike)
        assert_eq!(parse("GET /metrics HTTP/1.1\n\n").unwrap(), HttpRequest::Metrics);
    }

    #[test]
    fn malformed_requests_fail_closed() {
        for (req, want) in [
            ("BLAH\r\n\r\n", HttpError::BadRequest("malformed request line")),
            ("\r\n\r\n", HttpError::BadRequest("malformed request line")),
            (
                "GET /metrics HTTP/1.1 extra\r\n\r\n",
                HttpError::BadRequest("malformed request line"),
            ),
            ("GET /metrics SPDY/3\r\n\r\n", HttpError::BadRequest("unsupported protocol")),
            (
                "GET /metrics HTTP/1.1\r\nno-colon\r\n\r\n",
                HttpError::BadRequest("malformed header"),
            ),
            ("POST /metrics HTTP/1.1\r\n\r\n", HttpError::MethodNotAllowed),
            ("GET /secrets HTTP/1.1\r\n\r\n", HttpError::NotFound),
            ("GET / HTTP/1.1\r\n\r\n", HttpError::NotFound),
        ] {
            assert_eq!(parse(req), Err(want), "{req:?}");
        }
        // truncated stream (no blank line) fails rather than hanging
        assert_eq!(
            parse("GET /metrics HTTP/1.1\r\n"),
            Err(HttpError::BadRequest("unexpected end of stream"))
        );
    }

    #[test]
    fn oversized_requests_fail_closed() {
        let long_line = format!("GET /{} HTTP/1.1\r\n\r\n", "x".repeat(MAX_REQUEST_LINE));
        assert_eq!(parse(&long_line), Err(HttpError::BadRequest("line too long")));
        let many_headers = format!(
            "GET /metrics HTTP/1.1\r\n{}\r\n",
            "a: b\r\n".repeat(MAX_HEADER_LINES + 1)
        );
        assert_eq!(parse(&many_headers), Err(HttpError::BadRequest("header block too large")));
        let fat_headers = format!(
            "GET /metrics HTTP/1.1\r\n{}\r\n",
            format!("a: {}\r\n", "y".repeat(4096)).repeat(3)
        );
        assert_eq!(parse(&fat_headers), Err(HttpError::BadRequest("header block too large")));
    }

    fn synthetic_snapshot() -> StatusSnapshot {
        let gap = AtomicHistogram::new(GAP_BOUNDS);
        for v in [1e-6, 1e-6, 0.05] {
            gap.observe(v);
        }
        let lag = AtomicHistogram::new(LAG_BOUNDS);
        for v in [0.0, 1.0, 1.0, 3.0] {
            lag.observe(v);
        }
        StatusSnapshot {
            uptime_secs: 12.5,
            master_step: 40,
            live_workers: 3,
            total_slots: 4,
            pushes_total: 40,
            pushes_dropped: 2,
            pushes_per_sec: 8.0,
            bytes_tx: 4096,
            bytes_rx: 2048,
            bytes_per_second: 512.0,
            kernels: "scalar",
            gap: gap.snapshot(),
            lag: lag.snapshot(),
            shard_gates: vec![(40, 0), (39, 1)],
            checkpoint: Some(CheckpointInfo { step: 32, bytes: 1024, age_secs: 3.0 }),
            cluster: ClusterStatus {
                standby: false,
                epoch: 2,
                takeovers: 1,
                shard_start: 0,
                shard_hosted: 2,
                total_shards: 4,
                standby_lag: None,
            },
            slots: vec![
                SlotRow { slot: 0, generation: 1, live: true, window: 2, last_push: 40 },
                SlotRow { slot: 1, generation: 3, live: false, window: 0, last_push: 17 },
            ],
        }
    }

    #[test]
    fn prometheus_rendering_is_pinned() {
        let text = render_prometheus(&synthetic_snapshot());
        for line in [
            "dana_uptime_seconds 12.5",
            "dana_master_step 40",
            "dana_pushes_total 40",
            "dana_pushes_per_second 8",
            "dana_pushes_dropped_total 2",
            "dana_bytes_tx_total 4096",
            "dana_bytes_rx_total 2048",
            "dana_bytes_per_second 512",
            "dana_workers_live 3",
            "dana_workers_total 4",
            "dana_workers_retired 1",
            "dana_kernel_backend{backend=\"scalar\"} 1",
            "dana_shard_gate_position{shard=\"0\"} 40",
            "dana_shard_ticket_backlog{shard=\"1\"} 1",
            // cumulative le-buckets: two 1e-6 gaps, one 0.05
            "dana_gap_bucket{le=\"0.000001\"} 2",
            "dana_gap_bucket{le=\"0.1\"} 3",
            "dana_gap_bucket{le=\"+Inf\"} 3",
            "dana_gap_count 3",
            // lag: one 0, two 1s, one 3 ⇒ cum 1, 3, 3, 4
            "dana_lag_bucket{le=\"0\"} 1",
            "dana_lag_bucket{le=\"1\"} 3",
            "dana_lag_bucket{le=\"4\"} 4",
            "dana_lag_count 4",
            "dana_lag_sum 5",
            "dana_checkpoint_step 32",
            "dana_checkpoint_bytes 1024",
            "dana_checkpoint_age_seconds 3",
            "dana_cluster_role{role=\"primary\"} 1",
            "dana_cluster_role{role=\"standby\"} 0",
            "dana_placement_epoch 2",
            "dana_takeovers_total 1",
            "dana_shard_start 0",
            "dana_shards_hosted 2",
            "dana_shards_total 4",
        ] {
            assert!(text.contains(line), "missing {line:?} in:\n{text}");
        }
        // primaries expose no standby-lag series
        assert!(!text.contains("dana_standby_lag_steps"));
        // a standby flips the role series and exposes its lag
        let mut standby = synthetic_snapshot();
        standby.cluster.standby = true;
        standby.cluster.standby_lag = Some(7);
        let text = render_prometheus(&standby);
        assert!(text.contains("dana_cluster_role{role=\"primary\"} 0"), "{text}");
        assert!(text.contains("dana_cluster_role{role=\"standby\"} 1"), "{text}");
        assert!(text.contains("dana_standby_lag_steps 7"), "{text}");
    }

    #[test]
    fn status_json_round_trips() {
        let s = synthetic_snapshot();
        let v = Json::parse(&render_status_json(&s)).unwrap();
        assert_eq!(v.at(&["master_step"]).unwrap().as_usize().unwrap(), 40);
        assert_eq!(v.at(&["workers_live"]).unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.at(&["pushes_dropped"]).unwrap().as_usize().unwrap(), 2);
        assert_eq!(v.at(&["bytes_tx"]).unwrap().as_usize().unwrap(), 4096);
        assert_eq!(v.at(&["bytes_rx"]).unwrap().as_usize().unwrap(), 2048);
        assert_eq!(v.at(&["checkpoint", "step"]).unwrap().as_usize().unwrap(), 32);
        let slots = v.at(&["slots"]).unwrap().as_arr().unwrap();
        assert_eq!(slots.len(), 2);
        assert_eq!(slots[1].get("generation").unwrap().as_usize().unwrap(), 3);
        assert!(!slots[1].get("live").unwrap().as_bool().unwrap());
        assert_eq!(slots[1].get("last_push").unwrap().as_usize().unwrap(), 17);
        let shards = v.at(&["shards"]).unwrap().as_arr().unwrap();
        assert_eq!(shards[1].get("ticket_backlog").unwrap().as_usize().unwrap(), 1);
        assert_eq!(
            v.at(&["cluster", "role"]).unwrap(),
            &Json::str("primary"),
            "role renders as a string"
        );
        assert_eq!(v.at(&["cluster", "placement_epoch"]).unwrap().as_usize().unwrap(), 2);
        assert_eq!(v.at(&["cluster", "takeovers_total"]).unwrap().as_usize().unwrap(), 1);
        assert_eq!(v.at(&["cluster", "shards_total"]).unwrap().as_usize().unwrap(), 4);
        assert_eq!(v.at(&["cluster", "standby_lag_steps"]).unwrap(), &Json::Null);
        assert_eq!(v.at(&["kernels"]).unwrap(), &Json::str("scalar"));
        // lag histogram quantiles survive the trip
        assert!(v.at(&["lag", "p50"]).unwrap().as_f64().unwrap() <= 1.0);
    }

    #[test]
    fn empty_snapshot_renders_without_shard_or_checkpoint_series() {
        let s = StatusSnapshot {
            uptime_secs: 0.0,
            master_step: 0,
            live_workers: 0,
            total_slots: 0,
            pushes_total: 0,
            pushes_dropped: 0,
            pushes_per_sec: 0.0,
            bytes_tx: 0,
            bytes_rx: 0,
            bytes_per_second: 0.0,
            kernels: "scalar",
            gap: AtomicHistogram::new(GAP_BOUNDS).snapshot(),
            lag: AtomicHistogram::new(LAG_BOUNDS).snapshot(),
            shard_gates: Vec::new(),
            checkpoint: None,
            cluster: ClusterStatus::default(),
            slots: Vec::new(),
        };
        let text = render_prometheus(&s);
        assert!(!text.contains("dana_shard_gate_position"));
        assert!(!text.contains("dana_checkpoint_step"));
        assert!(!text.contains("dana_standby_lag_steps"));
        assert!(text.contains("dana_pushes_total 0"));
        assert!(text.contains("dana_cluster_role{role=\"primary\"} 1"));
        let v = Json::parse(&render_status_json(&s)).unwrap();
        assert_eq!(v.at(&["checkpoint"]).unwrap(), &Json::Null);
    }

    /// Counts how often the master surface was touched — the fail-closed
    /// tests pin that malformed requests never reach it.
    struct MockSource {
        scrapes: AtomicUsize,
        slot_reads: AtomicUsize,
    }

    impl StatusSource for MockSource {
        fn metrics_snapshot(&self) -> StatusSnapshot {
            self.scrapes.fetch_add(1, Ordering::SeqCst);
            let mut s = synthetic_snapshot();
            s.slots = Vec::new();
            s.pushes_per_sec = 0.0;
            s.bytes_per_second = 0.0;
            s
        }

        fn slot_rows(&self) -> Vec<SlotRow> {
            self.slot_reads.fetch_add(1, Ordering::SeqCst);
            synthetic_snapshot().slots
        }
    }

    fn roundtrip(addr: SocketAddr, request: &str) -> String {
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(request.as_bytes()).unwrap();
        let mut reply = String::new();
        conn.read_to_string(&mut reply).unwrap();
        reply
    }

    #[test]
    fn server_serves_both_endpoints_and_fails_closed() {
        let source = Arc::new(MockSource {
            scrapes: AtomicUsize::new(0),
            slot_reads: AtomicUsize::new(0),
        });
        let mut srv = StatusServer::start("127.0.0.1:0", source.clone()).unwrap();
        let addr = srv.addr();

        let metrics = roundtrip(addr, "GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n");
        assert!(metrics.starts_with("HTTP/1.1 200 OK"), "{metrics}");
        assert!(metrics.contains("dana_pushes_total 40"));
        assert_eq!(source.scrapes.load(Ordering::SeqCst), 1);
        assert_eq!(source.slot_reads.load(Ordering::SeqCst), 0, "/metrics skips slot locks");

        let status = roundtrip(addr, "GET /status HTTP/1.1\r\nHost: t\r\n\r\n");
        assert!(status.starts_with("HTTP/1.1 200 OK"), "{status}");
        assert!(status.contains("application/json"));
        assert!(status.contains("\"generation\""));
        assert_eq!(source.slot_reads.load(Ordering::SeqCst), 1);

        // malformed / unknown / wrong-method requests are answered and
        // never touch the source
        let before = source.scrapes.load(Ordering::SeqCst);
        assert!(roundtrip(addr, "BLAH\r\n\r\n").starts_with("HTTP/1.1 400"));
        assert!(roundtrip(addr, "GET /x HTTP/1.1\r\n\r\n").starts_with("HTTP/1.1 404"));
        assert!(roundtrip(addr, "POST /metrics HTTP/1.1\r\n\r\n").starts_with("HTTP/1.1 405"));
        let long = format!("GET /{} HTTP/1.1\r\n\r\n", "z".repeat(MAX_REQUEST_LINE));
        assert!(roundtrip(addr, &long).starts_with("HTTP/1.1 400"));
        assert_eq!(source.scrapes.load(Ordering::SeqCst), before, "fail-closed scrapes");

        // second scrape fills pushes/s from the delta (same totals ⇒ 0)
        let again = roundtrip(addr, "GET /metrics HTTP/1.1\r\n\r\n");
        assert!(again.contains("dana_pushes_per_second 0"), "{again}");

        srv.stop();
        srv.stop(); // idempotent
        assert!(TcpStream::connect(addr).is_err() || {
            // the OS may briefly accept on a dead listener's backlog;
            // a full request must at least go unanswered
            let mut c = TcpStream::connect(addr).unwrap();
            c.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
            let _ = c.write_all(b"GET /metrics HTTP/1.1\r\n\r\n");
            let mut buf = [0u8; 1];
            !matches!(c.read(&mut buf), Ok(n) if n > 0)
        });
    }
}

//! Network transport: multi-process DANA over TCP.
//!
//! The rest of the system emulates asynchrony inside one process (sim
//! events or real threads); this subsystem puts the [`Master`] interface
//! behind a wire so the asynchrony is *transported*, not emulated — gap
//! and staleness then reflect real delivery delay, the quantity the
//! paper's gap analysis (and SSP/Gap-Aware in the related work) is
//! actually about.  std-only: no new dependencies.
//!
//! * [`wire`] — versioned, length-prefixed, fail-closed binary protocol;
//! * [`codec`] — wire-v4 payload encodings ([`Encoding`]): f16/bf16
//!   quantization and top-k sparsification with worker-side
//!   error-feedback residuals, negotiated per connection in the
//!   handshake, plus the pooled borrowed-slice frame writers behind the
//!   zero-allocation push path;
//! * [`server`] — `dana serve`: a [`crate::server::ServingMaster`]
//!   behind a `TcpListener`, thread-per-connection, connect = join /
//!   EOF = leave, generation tags against straggler pushes.  With the
//!   lock-striped backend, shards are the unit of concurrency from the
//!   socket to the optimizer apply (see DESIGN.md §9);
//! * [`client`] — [`RemoteMaster`], the full [`Master`] trait over a
//!   connection, so both trainers run unchanged against
//!   `--master tcp://host:port`;
//! * [`checkpoint`] — atomic binary snapshots of the full master state
//!   (θ, per-worker vᶦ, v⁰, liveness, step count) for
//!   `dana serve --resume` + client reconnect-as-join fault recovery;
//! * [`http`] — std-only HTTP/1.1 status listener (`--status-addr`):
//!   `GET /metrics` (Prometheus text) and `GET /status` (JSON) off
//!   lock-free scrape mirrors, fail-closed like the wire decoder;
//! * [`retention`] — `--keep-last`/`--keep-hourly` checkpoint archive
//!   GC, same atomicity discipline as [`checkpoint`].
//!
//! See `DESIGN.md` §8 for the format and lifecycle reference, §11 for
//! the daemon (status endpoint, retention, supervision).

pub mod checkpoint;
pub mod client;
pub mod codec;
pub mod http;
pub mod retention;
pub mod server;
pub mod wire;

pub use client::{strip_scheme, RemoteMaster};
pub use codec::{Encoding, EncodingSet};
pub use http::StatusServer;
pub use retention::RetentionPolicy;
pub use server::{NetServer, Placement, ServeOptions};

use crate::config::TrainConfig;
use crate::optim::LrSchedule;
use crate::server::{make_master, Master};

/// Build the master a training driver runs against: in-process
/// (monolithic or sharded per `cfg.shards`) by default, a
/// [`RemoteMaster`] when [`TrainConfig::master_addr`] names ONE `dana
/// serve` endpoint, or a [`crate::cluster::ClusterMaster`] when it
/// names a comma-separated list of them (a multi-server placement).
/// The single-endpoint path is untouched by the cluster layer — same
/// construction, same wire traffic, bit-for-bit.  Both remote paths
/// validate that the server's algorithm and parameter count match this
/// run's — a mismatched pairing fails fast instead of training garbage.
pub fn master_for(cfg: &TrainConfig, theta0: &[f32]) -> anyhow::Result<Box<dyn Master>> {
    match &cfg.master_addr {
        Some(addr) if addr.contains(',') => {
            let endpoints: Vec<String> = addr
                .split(',')
                .map(|e| e.trim().to_string())
                .filter(|e| !e.is_empty())
                .collect();
            let cm = crate::cluster::ClusterMaster::connect(
                &endpoints,
                cfg.n_workers,
                Some((cfg.algorithm, theta0.len())),
                cfg.encoding,
                cfg.shard_frames,
            )?;
            Ok(Box::new(cm))
        }
        Some(addr) => {
            // kind/k are validated from the control handshake BEFORE any
            // worker slot is joined: a misconfigured client never
            // perturbs a live cluster's membership (or its auto-tuned
            // α/τ) on its way to being rejected.
            let mut rm = RemoteMaster::connect_with(
                addr,
                cfg.n_workers,
                Some((cfg.algorithm, theta0.len())),
                cfg.encoding,
            )?;
            // per-shard parameter frames (no-op unless the server is
            // sharded); trajectories are bit-for-bit either way
            rm.set_shard_frames(cfg.shard_frames);
            Ok(Box::new(rm))
        }
        None => Ok(make_master(
            cfg.algorithm,
            theta0,
            LrSchedule::new(cfg.schedule.clone()),
            cfg.n_workers,
            cfg.shards,
            crate::util::parallel::default_threads(),
        )),
    }
}

//! `dana` — CLI entrypoint for the DANA reproduction.
//!
//! Subcommands:
//!   train       run one training experiment (async / ssgd / baseline)
//!   serve       host a parameter server over TCP (see `--master`)
//!   cluster     launch + supervise a whole topology from cluster.json
//!   experiment  regenerate a paper table/figure (or `all`)
//!   simulate    pure timing simulation (no model execution)
//!   info        artifact manifest + platform report
//!
//! Each subcommand's flags live in a declarative [`FlagTable`]
//! (`util::cli`): one table generates the usage block and rejects
//! unknown options with a uniform error style, so the subcommands
//! cannot drift apart in how they parse or fail.
//!
//! Examples:
//!   dana train --algorithm dana-slim --workers 8 --epochs 10
//!   dana serve --listen 127.0.0.1:7700 --algorithm dana-zero --synthetic --k 256
//!   dana train --synthetic --master tcp://127.0.0.1:7700 --algorithm dana-zero
//!   dana cluster --manifest examples/cluster/two_server.json --run-dir /tmp/run
//!   dana serve --manifest cluster.json --server web0 --run-dir /tmp/run
//!   dana experiment fig4 --full --seeds 3

use dana::cluster::manifest::parse_shard_range;
use dana::cluster::{ClusterManifest, LaunchOptions, StandbyConfig, StandbyServer};
use dana::config::{ServeSpec, StandbyOf, TrainConfig, Workload};
use dana::experiments::{self, ExpOptions};
use dana::net::{self, NetServer, ServeOptions};
use dana::optim::{AlgorithmKind, LrSchedule};
use dana::runtime::Engine;
use dana::server::{make_serving_master, ServingMaster};
use dana::sim::Environment;
use dana::train::{baseline, real_async, sim_trainer, ssgd};
use dana::util::cli::{Args, FlagDef, FlagTable};
use std::path::{Path, PathBuf};
use std::time::Duration;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const USAGE: &str = "usage: dana <train|serve|cluster|experiment|simulate|info> [options]
  train       run one training experiment (flags or --manifest)
  serve       host a parameter server / hot standby over TCP
  cluster     launch + supervise a whole topology from cluster.json
  experiment  regenerate a paper table/figure (or `all`)
  simulate    pure timing simulation (no model execution)
  info        artifact manifest + platform report
run `dana <subcommand> --oops` (any unknown flag) to see that
subcommand's full flag table.";

/// `m!` builds one [`FlagDef`] row; tables stay readable.
macro_rules! flag {
    ($name:literal, $value:literal, $help:literal) => {
        FlagDef { name: $name, value: Some($value), help: $help }
    };
    ($name:literal, $help:literal) => {
        FlagDef { name: $name, value: None, help: $help }
    };
}

const TRAIN_TABLE: FlagTable = FlagTable {
    cmd: "train",
    summary: "run one training experiment",
    flags: &[
        flag!("manifest", "FILE", "run the fleet of a cluster manifest (sole config source)"),
        flag!("workload", "W", "c10|wrn_c10|c100|imagenet|lm (default c10)"),
        flag!("algorithm", "A", "dana-slim|dana|dana-zero|asgd|... (default dana-slim)"),
        flag!("workers", "N", "cluster size (default 8)"),
        flag!("epochs", "E", "run length in proxy epochs (default 10)"),
        flag!("env", "ENV", "homo|hetero execution-time model (default homo)"),
        flag!("mode", "M", "sim|real|ssgd|baseline (default sim)"),
        flag!("seed", "S", "run seed (default 1)"),
        flag!("eta", "X", "override base learning rate"),
        flag!("gamma", "X", "override momentum"),
        flag!("warmup", "E", "override warmup epochs"),
        flag!("lambda", "X", "override DC strength"),
        flag!("metrics-every", "K", "record gap/lag every K master steps"),
        flag!("shards", "S", "parameter-server shards (in-process master)"),
        flag!("churn", "SPEC", "membership events, e.g. leave@0.3:2,join@0.5"),
        flag!("leave-policy", "P", "retire|fold a leaver's momentum"),
        flag!("config", "FILE", "JSON overrides (fail-closed on unknown keys)"),
        flag!("use-pallas", "use the Pallas-kernel artifact variant"),
        flag!("eval-every", "E", "evaluate every E epochs"),
        flag!("synthetic", "train the synthetic quadratic (artifact-free)"),
        flag!("k", "K", "synthetic model dimension (default 256)"),
        flag!("master", "URL[,URL..]", "remote parameter server(s); comma list = placement"),
        flag!("shard-frames", "move remote traffic as per-shard frames"),
        flag!("pipeline-depth", "D", "keep D+1 batches in flight per worker"),
        flag!("rtt", "T", "simulated round-trip time (sim modes)"),
        flag!("max-restarts", "R", "crash-loop budget per worker thread"),
        flag!("restart-backoff-ms", "MS", "base worker restart backoff"),
        flag!("encoding", "E", "none|f16|bf16|topk:K gradient payload encoding"),
        flag!("kernels", "B", "auto|scalar|sse2|avx2|neon math kernel backend"),
        flag!("artifacts", "DIR", "AOT artifact directory"),
    ],
};

const SERVE_TABLE: FlagTable = FlagTable {
    cmd: "serve",
    summary: "host a parameter server (or hot standby) over TCP",
    flags: &[
        flag!("manifest", "FILE", "take this process's config from a cluster manifest"),
        flag!("server", "NAME", "which servers[]/standbys[] entry this process is"),
        flag!("run-dir", "DIR", "base for checkpoint paths in manifest mode (default .)"),
        flag!("listen", "HOST:PORT", "serving address (default 127.0.0.1:7700)"),
        flag!("algorithm", "A", "algorithm this server applies (default dana-slim)"),
        flag!("workload", "W", "schedule/model donor workload (default c10)"),
        flag!("synthetic", "serve the synthetic quadratic (artifact-free)"),
        flag!("k", "K", "synthetic model dimension (default 256)"),
        flag!("workers", "N", "schedule worker count (default 8)"),
        flag!("epochs", "E", "schedule length (default 10)"),
        flag!("eta", "X", "override base learning rate"),
        flag!("gamma", "X", "override momentum"),
        flag!("seed", "S", "θ-init seed (default 1)"),
        flag!("shards", "S", "shard count (global count with --shard-range)"),
        flag!("shard-range", "A..B", "host only global shards [A,B) of the placement"),
        flag!("placement-epoch", "E", "epoch this server claims its range at"),
        flag!("standby-of", "URL", "run a hot standby watching this primary"),
        flag!("standby-poll-ms", "MS", "primary poll cadence (default 250)"),
        flag!("standby-miss-budget", "N", "missed probes before takeover (default 4)"),
        flag!("serve-threads", "T", "per-request shard fan-out cap (0 = global lock)"),
        flag!("pipeline-depth", "D", "client pipeline depth to size pull windows for"),
        flag!("leave-policy", "P", "retire|fold a leaver's momentum"),
        flag!("checkpoint", "PATH", "checkpoint base path"),
        flag!("checkpoint-every", "STEPS", "checkpoint cadence in master steps"),
        flag!("resume", "PATH", "restore master state from a checkpoint"),
        flag!("keep-last", "N", "retention: keep N newest archives"),
        flag!("keep-hourly", "H", "retention: plus newest of H distinct hours"),
        flag!("status-addr", "HOST:PORT", "HTTP /metrics + /status listener"),
        flag!("encodings", "LIST", "advertised payload encodings (default all)"),
        flag!("kernels", "B", "auto|scalar|sse2|avx2|neon math kernel backend"),
        flag!("metrics-every", "K", "record gap/lag every K master steps"),
        flag!("artifacts", "DIR", "AOT artifact directory"),
    ],
};

const CLUSTER_TABLE: FlagTable = FlagTable {
    cmd: "cluster",
    summary: "launch and supervise a whole topology from one manifest",
    flags: &[
        flag!("manifest", "FILE", "the cluster.json to launch (required)"),
        flag!("run-dir", "DIR", "base for checkpoints/logs/pids.json (default .)"),
        flag!("verify-only", "validate structure + artifact checksums, then exit"),
        flag!("no-fleet", "supervise servers only; run the fleet yourself"),
        flag!("health-timeout-ms", "MS", "launch health-gate budget (default 30000)"),
    ],
};

const EXPERIMENT_TABLE: FlagTable = FlagTable {
    cmd: "experiment",
    summary: "regenerate a paper table/figure (fig2a..fig13, table1..table6, churn, all)",
    flags: &[
        flag!("full", "full-size run (default is the quick preset)"),
        flag!("seeds", "K", "seeds per configuration (default 2)"),
        flag!("out", "DIR", "results directory (default results)"),
        flag!("encoding", "E", "none|f16|bf16|topk:K gradient payload encoding"),
        flag!("artifacts", "DIR", "AOT artifact directory"),
    ],
};

const SIMULATE_TABLE: FlagTable = FlagTable {
    cmd: "simulate",
    summary: "pure timing simulation (no model execution)",
    flags: &[
        flag!("workers", "N", "cluster size (default 8)"),
        flag!("env", "ENV", "homo|hetero execution-time model"),
        flag!("batches-per-worker", "K", "work per worker (default 100)"),
        flag!("batch", "B", "batch size (default 128)"),
        flag!("seeds", "K", "seeds to average (default 5)"),
    ],
};

const INFO_TABLE: FlagTable = FlagTable {
    cmd: "info",
    summary: "artifact manifest + platform report",
    flags: &[flag!("artifacts", "DIR", "AOT artifact directory")],
};

fn run() -> anyhow::Result<()> {
    let mut args = Args::parse_env(true)?;
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&mut args),
        Some("serve") => cmd_serve(&mut args),
        Some("cluster") => cmd_cluster(&mut args),
        Some("experiment") => cmd_experiment(&mut args),
        Some("simulate") => cmd_simulate(&mut args),
        Some("info") => cmd_info(&mut args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => anyhow::bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

fn artifacts_dir(args: &mut Args) -> PathBuf {
    args.opt_str("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(dana::config::default_artifacts_dir)
}

/// In manifest mode only `allowed` flags may accompany `--manifest` —
/// any other flag would silently lose to the manifest, so it rejects
/// instead (the manifest is the single source of process config).
fn manifest_excludes(args: &Args, allowed: &[&str]) -> anyhow::Result<()> {
    for k in args.provided() {
        anyhow::ensure!(
            allowed.contains(&k),
            "--{k} cannot be combined with --manifest (the manifest is the single source \
             of config; allowed here: {})",
            allowed.iter().map(|a| format!("--{a}")).collect::<Vec<_>>().join(", ")
        );
    }
    Ok(())
}

fn cmd_train(args: &mut Args) -> anyhow::Result<()> {
    TRAIN_TABLE.check(args)?;
    // manifest mode: the fleet of a cluster manifest, exactly as `dana
    // cluster` would launch it
    if let Some(mp) = args.opt_str("manifest") {
        manifest_excludes(args, &["manifest", "artifacts"])?;
        let m = ClusterManifest::load(Path::new(&mp))?;
        m.verify_artifacts()?;
        let mut cfg = TrainConfig::from_manifest(&m)?;
        cfg.artifacts_dir = artifacts_dir(args);
        let mode = m.fleet.as_ref().map(|f| f.mode.clone()).unwrap_or_else(|| "real".into());
        return run_train(cfg, m.synthetic_k(), &mode);
    }
    let workload: Workload = args.str_or("workload", "c10").parse()?;
    let algorithm: AlgorithmKind = args.str_or("algorithm", "dana-slim").parse()?;
    let workers = args.parse_or::<usize>("workers", 8)?;
    let epochs = args.parse_or::<f64>("epochs", 10.0)?;
    let mut cfg = TrainConfig::preset(workload, algorithm, workers, epochs);
    if let Some(path) = args.opt_str("config") {
        let j = dana::util::json::Json::parse_file(Path::new(&path))?;
        cfg.apply_json(&j)?;
    }
    cfg.env = args.str_or("env", "homo").parse()?;
    cfg.seed = args.parse_or::<u64>("seed", 1)?;
    if let Some(eta) = args.opt_parse::<f32>("eta")? {
        cfg.schedule.base_eta = eta;
    }
    if let Some(g) = args.opt_parse::<f32>("gamma")? {
        cfg.schedule.gamma = g;
    }
    if let Some(w) = args.opt_parse::<f64>("warmup")? {
        cfg.schedule.warmup_epochs = w;
    }
    if let Some(l) = args.opt_parse::<f32>("lambda")? {
        cfg.schedule.lambda = l;
    }
    cfg.metrics_every = args.parse_or::<u64>("metrics-every", 0)?;
    // only override the config-file value when the flag is present
    if let Some(shards) = args.opt_parse::<usize>("shards")? {
        cfg.shards = shards.max(1);
    }
    if let Some(churn) = args.opt_parse::<dana::sim::ChurnSchedule>("churn")? {
        cfg.churn = churn;
    }
    if let Some(policy) = args.opt_parse::<dana::optim::LeavePolicy>("leave-policy")? {
        cfg.leave_policy = policy;
    }
    cfg.use_pallas = args.flag("use-pallas");
    cfg.eval_every_epochs = args.parse_or::<f64>("eval-every", 0.0)?;
    cfg.artifacts_dir = artifacts_dir(args);
    if let Some(addr) = args.opt_str("master") {
        cfg.master_addr = Some(addr);
    }
    if args.flag("shard-frames") {
        cfg.shard_frames = true;
    }
    if let Some(d) = args.opt_parse::<usize>("pipeline-depth")? {
        anyhow::ensure!(
            d < dana::server::MAX_PULL_WINDOW,
            "--pipeline-depth {d} exceeds the supported window ({})",
            dana::server::MAX_PULL_WINDOW - 1
        );
        cfg.pipeline_depth = d;
    }
    if let Some(rtt) = args.opt_parse::<f64>("rtt")? {
        anyhow::ensure!(rtt.is_finite() && rtt >= 0.0, "--rtt must be finite and >= 0");
        cfg.rtt = rtt;
    }
    // crash-loop supervision (real-thread mode; the sim clock has no
    // threads to lose)
    if let Some(r) = args.opt_parse::<u32>("max-restarts")? {
        cfg.max_restarts = r;
    }
    if let Some(ms) = args.opt_parse::<u64>("restart-backoff-ms")? {
        cfg.restart_backoff_ms = ms;
    }
    if let Some(e) = args.opt_parse::<net::Encoding>("encoding")? {
        cfg.encoding = e;
    }
    if let Some(kb) = args.opt_parse::<dana::math::KernelChoice>("kernels")? {
        cfg.kernels = kb;
    }
    let synth_k = args.flag("synthetic").then(|| args.parse_or::<usize>("k", 256)).transpose()?;
    let mode = args.str_or("mode", "sim");
    run_train(cfg, synth_k, &mode)
}

/// Run one training experiment from a fully-built config (flags,
/// `--config` JSON, or a cluster manifest — all normalized upstream).
fn run_train(cfg: TrainConfig, synth_k: Option<usize>, mode: &str) -> anyhow::Result<()> {
    // Pin the math kernel backend first — every driver below dispatches
    // through it, and a pinned-but-unavailable backend must fail the run
    // before any state exists.
    let backend = dana::math::set_kernels(cfg.kernels)?;
    println!("math kernels: {backend} (requested {})", cfg.kernels);
    if cfg.pipeline_depth > 0 && matches!(mode, "ssgd" | "baseline") {
        anyhow::bail!("--pipeline-depth applies only to --mode sim|real (got --mode {mode})");
    }
    if cfg.shards > 1 && matches!(mode, "ssgd" | "baseline") {
        anyhow::bail!("--shards applies only to --mode sim|real (got --mode {mode})");
    }
    if !cfg.churn.is_empty() {
        if matches!(mode, "ssgd" | "baseline") {
            anyhow::bail!("--churn applies only to --mode sim|real (got --mode {mode})");
        }
        cfg.churn.validate(cfg.n_workers)?;
    }
    if (synth_k.is_some() || cfg.master_addr.is_some()) && matches!(mode, "ssgd" | "baseline") {
        anyhow::bail!("--synthetic/--master apply only to --mode sim|real (got --mode {mode})");
    }

    let workload = match synth_k {
        Some(k) => format!("synthetic quadratic (k={k})"),
        None => cfg.variant_name(),
    };
    println!(
        "training {} / {} on {} worker(s), {} epochs ({} master steps), mode={mode}{}",
        workload,
        cfg.algorithm.name(),
        cfg.n_workers,
        cfg.epochs,
        cfg.total_master_steps(),
        cfg.master_addr
            .as_deref()
            .map(|a| format!(", master={a}"))
            .unwrap_or_default()
    );
    // The synthetic drivers are artifact-free: skip PJRT engine
    // construction entirely so `dana train --synthetic` works without
    // compiled artifacts (and against the vendored xla stub).
    let report = if let Some(k) = synth_k {
        match mode {
            "sim" => sim_trainer::run_synthetic(&cfg, k)?,
            "real" => real_async::run_synthetic(&cfg, k)?,
            other => anyhow::bail!("unknown mode {other:?} (sim|real)"),
        }
    } else {
        let engine = Engine::cpu(&cfg.artifacts_dir)?;
        match mode {
            "sim" => sim_trainer::run(&cfg, &engine)?,
            "real" => real_async::run(&cfg, &engine)?,
            "ssgd" => ssgd::run(&cfg, &engine)?,
            "baseline" => baseline::run(&cfg, &engine)?,
            other => anyhow::bail!("unknown mode {other:?} (sim|real|ssgd|baseline)"),
        }
    };
    println!("{}", report.summary());
    for p in &report.curve {
        println!(
            "  epoch {:6.2}  err {:6.2}%  loss {:.4}",
            p.epoch, p.test_error, p.test_loss
        );
    }
    Ok(())
}

/// Host a parameter server over TCP.  Workers join by connecting
/// (`dana train --master tcp://HOST:PORT`); the cluster starts empty
/// unless `--resume` restores checkpointed membership, in which case
/// reconnecting workers re-attach to their old slots (lowest first).
///
/// With `--manifest FILE --server NAME` the whole spec comes from the
/// named `servers[]`/`standbys[]` entry of a cluster manifest instead
/// of flags — the two spellings normalize into the same [`ServeSpec`],
/// so `dana cluster` children and hand-flagged servers are one code
/// path.
fn cmd_serve(args: &mut Args) -> anyhow::Result<()> {
    SERVE_TABLE.check(args)?;
    if let Some(mp) = args.opt_str("manifest") {
        let name = args.opt_str("server").ok_or_else(|| {
            anyhow::anyhow!(
                "--manifest needs --server NAME: which servers[]/standbys[] entry this \
                 process serves as"
            )
        })?;
        let run_dir = PathBuf::from(args.str_or("run-dir", "."));
        manifest_excludes(args, &["manifest", "server", "run-dir", "artifacts"])?;
        let m = ClusterManifest::load(Path::new(&mp))?;
        m.verify_artifacts()?;
        // a standby entry normalizes straight to a StandbyConfig (its
        // placement is learned from the primary, never configured)
        if m.standby(&name).is_some() {
            return run_standby(StandbyConfig::from_manifest(&m, &name, &run_dir)?);
        }
        let mut spec = ServeSpec::from_manifest(&m, &name, &run_dir)?;
        spec.artifacts_dir = artifacts_dir(args);
        return run_serve(spec);
    }
    let shard_range = match args.opt_str("shard-range") {
        Some(spec) => Some(
            parse_shard_range(&spec)
                .map_err(|e| anyhow::anyhow!("--shard-range: {e:#}"))?,
        ),
        None => None,
    };
    let standby_poll_ms = args.parse_or::<u64>("standby-poll-ms", 250)?;
    let standby_miss = args.parse_or::<u32>("standby-miss-budget", 4)?;
    let standby = args.opt_str("standby-of").map(|primary| StandbyOf {
        primary,
        poll_ms: standby_poll_ms,
        miss_budget: standby_miss,
    });
    let spec = ServeSpec {
        listen: args.str_or("listen", "127.0.0.1:7700"),
        algorithm: args.str_or("algorithm", "dana-slim").parse()?,
        workload: args.str_or("workload", "c10").parse()?,
        synthetic_k: args
            .flag("synthetic")
            .then(|| args.parse_or::<usize>("k", 256))
            .transpose()?,
        workers: args.parse_or::<usize>("workers", 8)?,
        epochs: args.parse_or::<f64>("epochs", 10.0)?,
        seed: args.parse_or::<u64>("seed", 1)?,
        eta: args.opt_parse::<f32>("eta")?,
        gamma: args.opt_parse::<f32>("gamma")?,
        shards: args.parse_or::<usize>("shards", 1)?.max(1),
        shard_range,
        placement_epoch: args.parse_or::<u64>("placement-epoch", 0)?,
        serve_threads: args.parse_or::<usize>("serve-threads", 1)?,
        pipeline_depth: args.parse_or::<usize>("pipeline-depth", 0)?,
        leave_policy: args
            .parse_or::<dana::optim::LeavePolicy>("leave-policy", Default::default())?,
        checkpoint_path: args.opt_str("checkpoint").map(PathBuf::from),
        checkpoint_every: args.parse_or::<u64>("checkpoint-every", 0)?,
        resume: args.opt_str("resume").map(PathBuf::from),
        status_addr: args.opt_str("status-addr"),
        retention: dana::net::RetentionPolicy {
            keep_last: args.parse_or::<usize>("keep-last", 0)?,
            keep_hourly: args.parse_or::<usize>("keep-hourly", 0)?,
        },
        encodings: args.parse_or::<net::EncodingSet>("encodings", net::EncodingSet::ALL)?,
        kernels: args.parse_or::<dana::math::KernelChoice>("kernels", Default::default())?,
        metrics_every: args.parse_or::<u64>("metrics-every", 0)?,
        artifacts_dir: artifacts_dir(args),
        standby,
    };
    run_serve(spec)
}

/// Start a hot standby and block through watch/takeover/serving.
fn run_standby(sbcfg: StandbyConfig) -> anyhow::Result<()> {
    let primary = sbcfg.primary.clone();
    let mut sb = StandbyServer::start(sbcfg)?;
    println!(
        "dana standby: holding {} for primary {primary} — takeover restores the \
         newest archive at epoch last-seen+1",
        sb.addr()
    );
    if let Some(sa) = sb.status_addr() {
        println!("dana standby: status endpoint on http://{sa} (/metrics, /status)");
    }
    sb.wait();
    println!("dana serve: standby shut down");
    Ok(())
}

/// Serve one parameter-server process from a fully-built [`ServeSpec`].
fn run_serve(spec: ServeSpec) -> anyhow::Result<()> {
    // Kernel backend first: a pinned-but-unavailable backend must refuse
    // to serve before any listener or state exists (fail-closed launch).
    let kernel_backend = dana::math::set_kernels(spec.kernels)?;
    println!("math kernels: {kernel_backend} (requested {})", spec.kernels);
    anyhow::ensure!(
        spec.pipeline_depth < dana::server::MAX_PULL_WINDOW,
        "--pipeline-depth {} exceeds the supported window ({})",
        spec.pipeline_depth,
        dana::server::MAX_PULL_WINDOW - 1
    );
    anyhow::ensure!(
        spec.checkpoint_every == 0 || spec.checkpoint_path.is_some(),
        "--checkpoint-every needs --checkpoint PATH"
    );
    anyhow::ensure!(
        !spec.retention.enabled() || spec.checkpoint_path.is_some(),
        "--keep-last/--keep-hourly need --checkpoint PATH"
    );

    let mut cfg =
        TrainConfig::preset(spec.workload, spec.algorithm, spec.workers, spec.epochs);
    cfg.seed = spec.seed;
    if let Some(e) = spec.eta {
        cfg.schedule.base_eta = e;
    }
    if let Some(g) = spec.gamma {
        cfg.schedule.gamma = g;
    }
    let schedule = LrSchedule::new(cfg.schedule.clone());
    // --serve-threads 0 = legacy global-lock serving, which keeps PR 3's
    // intra-push shard fan-out (default_threads, inside the lock);
    // otherwise shards serve lock-striped with the per-request fan-out
    // capped at T (connection threads already provide the parallelism).
    let threads = if spec.serve_threads == 0 {
        dana::util::parallel::default_threads()
    } else {
        spec.serve_threads
    };

    // Hot standby: no model init, no master — everything the takeover
    // needs comes from the primary's handshake headers and archives.
    if let Some(sb) = &spec.standby {
        anyhow::ensure!(
            spec.resume.is_none() && spec.shard_range.is_none(),
            "--standby-of is exclusive with --resume/--shard-range (the standby learns \
             its range from the primary)"
        );
        let archive_base = spec.checkpoint_path.clone().ok_or_else(|| {
            anyhow::anyhow!(
                "--standby-of needs --checkpoint PATH: the primary's archive base \
                 (run the primary with --checkpoint PATH --checkpoint-every N --keep-last K \
                 on a filesystem both processes see)"
            )
        })?;
        let opts = ServeOptions {
            leave_policy: spec.leave_policy,
            checkpoint_path: spec.checkpoint_path.clone(),
            checkpoint_every: spec.checkpoint_every,
            pipeline_depth: spec.pipeline_depth,
            status_addr: spec.status_addr.clone(),
            retention: spec.retention,
            encodings: spec.encodings,
            placement: Default::default(),
        };
        return run_standby(StandbyConfig {
            listen: spec.listen.clone(),
            primary: sb.primary.clone(),
            archive_base,
            schedule,
            threads,
            striped: spec.serve_threads > 0,
            opts,
            poll: Duration::from_millis(sb.poll_ms.max(10)),
            miss_budget: sb.miss_budget.max(1),
        });
    }

    let mut theta0 = match spec.synthetic_k {
        Some(k) => real_async::synthetic_theta0(k),
        None => Engine::cpu(&spec.artifacts_dir)?.init_params(&cfg.variant_name())?,
    };
    // --shard-range A..B: host only that slice of the (identically
    // seeded) full model; the local backend gets one shard per hosted
    // global shard, so local and global shard boundaries coincide.
    let full_k = theta0.len();
    let mut placement = net::Placement::default();
    let mut local_shards = spec.shards;
    let mut hosted = None;
    if let Some(r) = &spec.shard_range {
        let total = spec.shards as u32;
        anyhow::ensure!(
            r.end <= total,
            "--shard-range {}..{} exceeds --shards {} (with --shard-range, --shards is \
             the GLOBAL shard count of the placement)",
            r.start,
            r.end,
            spec.shards
        );
        let coords = dana::cluster::coord_range(full_k, total, r)?;
        placement = net::Placement {
            shard_start: r.start,
            total_shards: total,
            epoch: spec.placement_epoch,
            takeovers: 0,
        };
        local_shards = (r.end - r.start) as usize;
        theta0 = theta0[coords.clone()].to_vec();
        hosted = Some(coords);
    }
    let striped = spec.serve_threads > 0 && local_shards > 1;
    let mut master = match &spec.resume {
        Some(path) => {
            let mut snap = net::checkpoint::read_snapshot(path)?;
            if let Some(coords) = &hosted {
                // A full-model archive (e.g. from a 1-server run, or a
                // stitch) restores into this split transparently.
                if snap.theta.len() == full_k && full_k != theta0.len() {
                    snap = dana::cluster::slice_snapshot(&snap, coords)?;
                    println!(
                        "dana serve: sliced full-model snapshot to hosted coordinates \
                         {}..{}",
                        coords.start, coords.end
                    );
                }
            }
            // restore() re-validates; checking here gives a better message
            snap.validate(spec.algorithm, theta0.len())?;
            let mut m = make_serving_master(
                spec.algorithm,
                &snap.theta,
                schedule,
                0,
                local_shards,
                threads,
                striped,
            );
            m.restore(&snap)?;
            let (step, _, live, slots) = m.status();
            println!(
                "resumed {} from {} at master step {step} ({live} live of {slots} slots \
                 awaiting reconnect)",
                spec.algorithm.name(),
                path.display(),
            );
            m
        }
        // fresh cluster: zero slots, every connect is a join
        None => make_serving_master(
            spec.algorithm,
            &theta0,
            schedule,
            0,
            local_shards,
            threads,
            striped,
        ),
    };
    master.set_metrics_every(spec.metrics_every);
    let k = master.param_len();
    let opts = ServeOptions {
        leave_policy: spec.leave_policy,
        checkpoint_path: spec.checkpoint_path.clone(),
        checkpoint_every: spec.checkpoint_every,
        pipeline_depth: spec.pipeline_depth,
        status_addr: spec.status_addr.clone(),
        retention: spec.retention,
        encodings: spec.encodings,
        placement,
    };
    let mut srv = NetServer::start_serving(master, &spec.listen, opts)?;
    println!(
        "dana serve: {} k={k} shards={local_shards} ({}) pipeline-depth={} on {} — \
         join with `dana train --master {}`",
        spec.algorithm.name(),
        if striped { "lock-striped" } else { "global-lock" },
        spec.pipeline_depth,
        srv.addr(),
        srv.url()
    );
    if placement.total_shards > 0 {
        println!(
            "dana serve: hosting global shards {}..{} of {} at placement epoch {}",
            placement.shard_start,
            placement.shard_start + local_shards as u32,
            placement.total_shards,
            placement.epoch
        );
    }
    if let Some(sa) = srv.status_addr() {
        println!("dana serve: status endpoint on http://{sa} (/metrics, /status)");
    }
    srv.wait();
    println!("dana serve: shut down");
    Ok(())
}

/// `dana cluster --manifest cluster.json` — see [`dana::cluster::launch`].
fn cmd_cluster(args: &mut Args) -> anyhow::Result<()> {
    CLUSTER_TABLE.check(args)?;
    let manifest_path = args.opt_str("manifest").ok_or_else(|| {
        anyhow::anyhow!("--manifest cluster.json is required\n{}", CLUSTER_TABLE.usage())
    })?;
    let opts = LaunchOptions {
        manifest_path: PathBuf::from(manifest_path),
        run_dir: PathBuf::from(args.str_or("run-dir", ".")),
        verify_only: args.flag("verify-only"),
        no_fleet: args.flag("no-fleet"),
        health_timeout: Duration::from_millis(
            args.parse_or::<u64>("health-timeout-ms", 30_000)?,
        ),
    };
    dana::cluster::launch::run(&opts)
}

fn cmd_experiment(args: &mut Args) -> anyhow::Result<()> {
    EXPERIMENT_TABLE.check(args)?;
    let id = args
        .positional
        .first()
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("experiment id required\n{}", EXPERIMENT_TABLE.usage()))?;
    let opts = ExpOptions {
        quick: !args.flag("full"),
        seeds: args.parse_or::<u64>("seeds", 2)?,
        out_dir: PathBuf::from(args.str_or("out", "results")),
        artifacts_dir: artifacts_dir(args),
        encoding: args.parse_or::<net::Encoding>("encoding", net::Encoding::None)?,
    };
    let t0 = std::time::Instant::now();
    experiments::run(&id, &opts)?;
    println!(
        "experiment {id} done in {:.1}s (results in {})",
        t0.elapsed().as_secs_f64(),
        opts.out_dir.display()
    );
    Ok(())
}

fn cmd_simulate(args: &mut Args) -> anyhow::Result<()> {
    SIMULATE_TABLE.check(args)?;
    let workers = args.parse_or::<usize>("workers", 8)?;
    let env: Environment = args.str_or("env", "homo").parse()?;
    let bpw = args.parse_or::<usize>("batches-per-worker", 100)?;
    let batch = args.parse_or::<usize>("batch", 128)?;
    let seeds = args.parse_or::<u64>("seeds", 5)?;
    let pts = dana::sim::speedup::speedup_sweep(env, &[workers], batch, bpw, seeds);
    for p in pts {
        println!(
            "{env:?} N={}: async {:.2}x, sync {:.2}x (async/sync {:.2})",
            p.n_workers,
            p.async_speedup,
            p.sync_speedup,
            p.async_speedup / p.sync_speedup
        );
    }
    Ok(())
}

fn cmd_info(args: &mut Args) -> anyhow::Result<()> {
    INFO_TABLE.check(args)?;
    let dir = artifacts_dir(args);
    let engine = Engine::cpu(&dir)?;
    println!("platform: {}", engine.platform());
    println!("artifacts: {}", dir.display());
    for v in &engine.manifest().variants {
        println!(
            "  {:<18} kind={:<4} P={:<8} batch={:<4} x{:?} golden_loss={:.4}",
            v.name, v.kind, v.param_count, v.batch, v.x_shape, v.golden.loss
        );
    }
    if let Some(uk) = &engine.manifest().update_kernel {
        println!("  update kernel: k={}", uk.k);
    }
    Ok(())
}

//! `dana` — CLI entrypoint for the DANA reproduction.
//!
//! Subcommands:
//!   train       run one training experiment (async / ssgd / baseline)
//!   serve       host a parameter server over TCP (see `--master`)
//!   experiment  regenerate a paper table/figure (or `all`)
//!   simulate    pure timing simulation (no model execution)
//!   info        artifact manifest + platform report
//!
//! Examples:
//!   dana train --algorithm dana-slim --workers 8 --epochs 10
//!   dana train --mode real --algorithm dana-slim --workers 4 --workload lm
//!   dana serve --listen 127.0.0.1:7700 --algorithm dana-zero --synthetic --k 256
//!   dana train --synthetic --master tcp://127.0.0.1:7700 --algorithm dana-zero
//!   dana experiment fig4 --full --seeds 3
//!   dana simulate --env hetero --workers 32

use dana::config::{TrainConfig, Workload};
use dana::experiments::{self, ExpOptions};
use dana::net::{self, NetServer, ServeOptions};
use dana::optim::{AlgorithmKind, LrSchedule};
use dana::runtime::Engine;
use dana::server::{make_serving_master, ServingMaster};
use dana::sim::Environment;
use dana::train::{baseline, real_async, sim_trainer, ssgd};
use dana::util::cli::Args;
use std::path::PathBuf;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const USAGE: &str = "usage: dana <train|serve|experiment|simulate|info> [options]
  train      --algorithm A --workers N [--workload c10|wrn_c10|c100|imagenet|lm]
             [--epochs E] [--env homo|hetero] [--mode sim|real|ssgd|baseline]
             [--seed S] [--eta X] [--gamma X] [--metrics-every K]
             [--shards S] [--churn \"leave@0.3:2,join@0.5,slow@0.6:0=4x\"]
             [--leave-policy retire|fold] [--config file.json] [--use-pallas]
             [--synthetic] [--k K] [--master tcp://H:P[,tcp://H:P..]]
             [--shard-frames]
             [--pipeline-depth D] [--rtt T] [--max-restarts R]
             [--restart-backoff-ms MS] [--encoding none|f16|bf16|topk:K]
             [--artifacts DIR]
  serve      --listen HOST:PORT --algorithm A [--workload W | --synthetic --k K]
             [--workers N] [--epochs E] [--shards S] [--serve-threads T]
             [--pipeline-depth D] [--leave-policy retire|fold]
             [--checkpoint PATH] [--checkpoint-every STEPS] [--resume PATH]
             [--keep-last N] [--keep-hourly H] [--status-addr HOST:PORT]
             [--encodings none|f16|bf16|topk|all[,..]]
             [--shard-range A..B] [--placement-epoch E]
             [--standby-of tcp://HOST:PORT] [--standby-poll-ms MS]
             [--standby-miss-budget N]
             [--metrics-every K] [--seed S] [--artifacts DIR]
  experiment <fig2a|fig2b|fig3|fig4|fig5|fig6|fig7|fig9|fig10|fig11|fig12|fig13|
              table1..table6|churn|all> [--full] [--seeds K] [--out DIR]
             [--encoding none|f16|bf16|topk:K] [--artifacts DIR]
  simulate   --workers N [--env homo|hetero] [--batches-per-worker K] [--batch B]
  info       [--artifacts DIR]";

fn run() -> anyhow::Result<()> {
    let mut args = Args::parse_env(true)?;
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&mut args),
        Some("serve") => cmd_serve(&mut args),
        Some("experiment") => cmd_experiment(&mut args),
        Some("simulate") => cmd_simulate(&mut args),
        Some("info") => cmd_info(&mut args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => anyhow::bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

fn artifacts_dir(args: &mut Args) -> PathBuf {
    args.opt_str("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(dana::config::default_artifacts_dir)
}

fn cmd_train(args: &mut Args) -> anyhow::Result<()> {
    let workload: Workload = args.str_or("workload", "c10").parse()?;
    let algorithm: AlgorithmKind = args.str_or("algorithm", "dana-slim").parse()?;
    let workers = args.parse_or::<usize>("workers", 8)?;
    let epochs = args.parse_or::<f64>("epochs", 10.0)?;
    let mut cfg = TrainConfig::preset(workload, algorithm, workers, epochs);
    if let Some(path) = args.opt_str("config") {
        let j = dana::util::json::Json::parse_file(std::path::Path::new(&path))?;
        cfg.apply_json(&j)?;
    }
    cfg.env = args.str_or("env", "homo").parse()?;
    cfg.seed = args.parse_or::<u64>("seed", 1)?;
    if let Some(eta) = args.opt_parse::<f32>("eta")? {
        cfg.schedule.base_eta = eta;
    }
    if let Some(g) = args.opt_parse::<f32>("gamma")? {
        cfg.schedule.gamma = g;
    }
    if let Some(w) = args.opt_parse::<f64>("warmup")? {
        cfg.schedule.warmup_epochs = w;
    }
    if let Some(l) = args.opt_parse::<f32>("lambda")? {
        cfg.schedule.lambda = l;
    }
    cfg.metrics_every = args.parse_or::<u64>("metrics-every", 0)?;
    // only override the config-file value when the flag is present
    if let Some(shards) = args.opt_parse::<usize>("shards")? {
        cfg.shards = shards.max(1);
    }
    if let Some(churn) = args.opt_parse::<dana::sim::ChurnSchedule>("churn")? {
        cfg.churn = churn;
    }
    if let Some(policy) = args.opt_parse::<dana::optim::LeavePolicy>("leave-policy")? {
        cfg.leave_policy = policy;
    }
    cfg.use_pallas = args.flag("use-pallas");
    cfg.eval_every_epochs = args.parse_or::<f64>("eval-every", 0.0)?;
    cfg.artifacts_dir = artifacts_dir(args);
    if let Some(addr) = args.opt_str("master") {
        cfg.master_addr = Some(addr);
    }
    if args.flag("shard-frames") {
        cfg.shard_frames = true;
    }
    if let Some(d) = args.opt_parse::<usize>("pipeline-depth")? {
        anyhow::ensure!(
            d < dana::server::MAX_PULL_WINDOW,
            "--pipeline-depth {d} exceeds the supported window ({})",
            dana::server::MAX_PULL_WINDOW - 1
        );
        cfg.pipeline_depth = d;
    }
    if let Some(rtt) = args.opt_parse::<f64>("rtt")? {
        anyhow::ensure!(rtt.is_finite() && rtt >= 0.0, "--rtt must be finite and >= 0");
        cfg.rtt = rtt;
    }
    // crash-loop supervision (real-thread mode; the sim clock has no
    // threads to lose)
    if let Some(r) = args.opt_parse::<u32>("max-restarts")? {
        cfg.max_restarts = r;
    }
    if let Some(ms) = args.opt_parse::<u64>("restart-backoff-ms")? {
        cfg.restart_backoff_ms = ms;
    }
    if let Some(e) = args.opt_parse::<net::Encoding>("encoding")? {
        cfg.encoding = e;
    }
    let synthetic = args.flag("synthetic");
    let synth_k = args.parse_or::<usize>("k", 256)?;
    let mode = args.str_or("mode", "sim");
    args.finish()?;
    if cfg.pipeline_depth > 0 && matches!(mode.as_str(), "ssgd" | "baseline") {
        anyhow::bail!("--pipeline-depth applies only to --mode sim|real (got --mode {mode})");
    }
    if cfg.shards > 1 && matches!(mode.as_str(), "ssgd" | "baseline") {
        anyhow::bail!("--shards applies only to --mode sim|real (got --mode {mode})");
    }
    if !cfg.churn.is_empty() {
        if matches!(mode.as_str(), "ssgd" | "baseline") {
            anyhow::bail!("--churn applies only to --mode sim|real (got --mode {mode})");
        }
        cfg.churn.validate(cfg.n_workers)?;
    }
    if (synthetic || cfg.master_addr.is_some())
        && matches!(mode.as_str(), "ssgd" | "baseline")
    {
        anyhow::bail!("--synthetic/--master apply only to --mode sim|real (got --mode {mode})");
    }

    let workload = if synthetic {
        format!("synthetic quadratic (k={synth_k})")
    } else {
        cfg.variant_name()
    };
    println!(
        "training {} / {} on {} worker(s), {} epochs ({} master steps), mode={mode}{}",
        workload,
        cfg.algorithm.name(),
        cfg.n_workers,
        cfg.epochs,
        cfg.total_master_steps(),
        cfg.master_addr
            .as_deref()
            .map(|a| format!(", master={a}"))
            .unwrap_or_default()
    );
    // The synthetic drivers are artifact-free: skip PJRT engine
    // construction entirely so `dana train --synthetic` works without
    // compiled artifacts (and against the vendored xla stub).
    let report = if synthetic {
        match mode.as_str() {
            "sim" => sim_trainer::run_synthetic(&cfg, synth_k)?,
            "real" => real_async::run_synthetic(&cfg, synth_k)?,
            other => anyhow::bail!("unknown mode {other:?} (sim|real)"),
        }
    } else {
        let engine = Engine::cpu(&cfg.artifacts_dir)?;
        match mode.as_str() {
            "sim" => sim_trainer::run(&cfg, &engine)?,
            "real" => real_async::run(&cfg, &engine)?,
            "ssgd" => ssgd::run(&cfg, &engine)?,
            "baseline" => baseline::run(&cfg, &engine)?,
            other => anyhow::bail!("unknown mode {other:?} (sim|real|ssgd|baseline)"),
        }
    };
    println!("{}", report.summary());
    for p in &report.curve {
        println!(
            "  epoch {:6.2}  err {:6.2}%  loss {:.4}",
            p.epoch, p.test_error, p.test_loss
        );
    }
    Ok(())
}

/// Host a parameter server over TCP.  Workers join by connecting
/// (`dana train --master tcp://HOST:PORT`); the cluster starts empty
/// unless `--resume` restores checkpointed membership, in which case
/// reconnecting workers re-attach to their old slots (lowest first).
///
/// With `--shards S > 1` the server serves **lock-striped**: shards are
/// the unit of concurrency from the socket down to the optimizer apply,
/// so concurrent workers' pulls and pushes proceed in parallel.
/// `--serve-threads T` caps the per-request shard fan-out (default 1 —
/// connection threads already provide the parallelism); `--serve-threads
/// 0` forces the legacy global-lock serving path.
///
/// With `--shard-range A..B` this process hosts only global shards
/// `[A, B)` of an S-shard placement (`--shards S` is then the GLOBAL
/// shard count); start one process per range so the ranges tile `0..S`,
/// and point workers at the whole group with a comma-separated
/// `--master` list.  `--standby-of ADDR` instead runs a hot standby:
/// it tails the primary's retention archives (shared `--checkpoint`
/// base) and takes the primary's exact range over on failure, one
/// placement epoch up.
fn cmd_serve(args: &mut Args) -> anyhow::Result<()> {
    let listen = args.str_or("listen", "127.0.0.1:7700");
    let algorithm: AlgorithmKind = args.str_or("algorithm", "dana-slim").parse()?;
    // schedule hyperparameters (the server owns the LR schedule; workers
    // only ever see the per-step eta/gamma/lambda in replies)
    let workers = args.parse_or::<usize>("workers", 8)?;
    let epochs = args.parse_or::<f64>("epochs", 10.0)?;
    let workload: Workload = args.str_or("workload", "c10").parse()?;
    let synthetic = args.flag("synthetic");
    let synth_k = args.parse_or::<usize>("k", 256)?;
    let shards = args.parse_or::<usize>("shards", 1)?.max(1);
    let shard_range = args.opt_str("shard-range");
    let placement_epoch = args.parse_or::<u64>("placement-epoch", 0)?;
    let standby_of = args.opt_str("standby-of");
    let standby_poll_ms = args.parse_or::<u64>("standby-poll-ms", 250)?;
    let standby_miss = args.parse_or::<u32>("standby-miss-budget", 4)?;
    let serve_threads = args.parse_or::<usize>("serve-threads", 1)?;
    let pipeline_depth = args.parse_or::<usize>("pipeline-depth", 0)?;
    anyhow::ensure!(
        pipeline_depth < dana::server::MAX_PULL_WINDOW,
        "--pipeline-depth {pipeline_depth} exceeds the supported window ({})",
        dana::server::MAX_PULL_WINDOW - 1
    );
    let leave_policy =
        args.parse_or::<dana::optim::LeavePolicy>("leave-policy", Default::default())?;
    let checkpoint_path = args.opt_str("checkpoint").map(PathBuf::from);
    let checkpoint_every = args.parse_or::<u64>("checkpoint-every", 0)?;
    let resume = args.opt_str("resume").map(PathBuf::from);
    let status_addr = args.opt_str("status-addr");
    let retention = dana::net::RetentionPolicy {
        keep_last: args.parse_or::<usize>("keep-last", 0)?,
        keep_hourly: args.parse_or::<usize>("keep-hourly", 0)?,
    };
    let encodings =
        args.parse_or::<net::EncodingSet>("encodings", net::EncodingSet::ALL)?;
    let metrics_every = args.parse_or::<u64>("metrics-every", 0)?;
    let seed = args.parse_or::<u64>("seed", 1)?;
    let eta = args.opt_parse::<f32>("eta")?;
    let gamma = args.opt_parse::<f32>("gamma")?;
    let artifacts = artifacts_dir(args);
    args.finish()?;
    anyhow::ensure!(
        checkpoint_every == 0 || checkpoint_path.is_some(),
        "--checkpoint-every needs --checkpoint PATH"
    );
    anyhow::ensure!(
        !retention.enabled() || checkpoint_path.is_some(),
        "--keep-last/--keep-hourly need --checkpoint PATH"
    );

    let mut cfg = TrainConfig::preset(workload, algorithm, workers, epochs);
    cfg.seed = seed;
    if let Some(e) = eta {
        cfg.schedule.base_eta = e;
    }
    if let Some(g) = gamma {
        cfg.schedule.gamma = g;
    }
    let schedule = LrSchedule::new(cfg.schedule.clone());
    // --serve-threads 0 = legacy global-lock serving, which keeps PR 3's
    // intra-push shard fan-out (default_threads, inside the lock);
    // otherwise shards serve lock-striped with the per-request fan-out
    // capped at T (connection threads already provide the parallelism).
    let threads = if serve_threads == 0 {
        dana::util::parallel::default_threads()
    } else {
        serve_threads
    };

    // Hot standby: no model init, no master — everything the takeover
    // needs comes from the primary's handshake headers and archives.
    if let Some(primary) = standby_of {
        anyhow::ensure!(
            resume.is_none() && shard_range.is_none(),
            "--standby-of is exclusive with --resume/--shard-range (the standby learns \
             its range from the primary)"
        );
        let archive_base = checkpoint_path.clone().ok_or_else(|| {
            anyhow::anyhow!(
                "--standby-of needs --checkpoint PATH: the primary's archive base \
                 (run the primary with --checkpoint PATH --checkpoint-every N --keep-last K \
                 on a filesystem both processes see)"
            )
        })?;
        let opts = ServeOptions {
            leave_policy,
            checkpoint_path,
            checkpoint_every,
            pipeline_depth,
            status_addr,
            retention,
            encodings,
            placement: Default::default(),
        };
        let sbcfg = dana::cluster::StandbyConfig {
            listen: listen.clone(),
            primary: primary.clone(),
            archive_base,
            schedule,
            threads,
            striped: serve_threads > 0,
            opts,
            poll: std::time::Duration::from_millis(standby_poll_ms.max(10)),
            miss_budget: standby_miss.max(1),
        };
        let mut sb = dana::cluster::StandbyServer::start(sbcfg)?;
        println!(
            "dana standby: holding {} for primary {primary} — takeover restores the \
             newest archive at epoch last-seen+1",
            sb.addr()
        );
        if let Some(sa) = sb.status_addr() {
            println!("dana standby: status endpoint on http://{sa} (/metrics, /status)");
        }
        sb.wait();
        println!("dana serve: standby shut down");
        return Ok(());
    }

    let mut theta0 = if synthetic {
        real_async::synthetic_theta0(synth_k)
    } else {
        Engine::cpu(&artifacts)?.init_params(&cfg.variant_name())?
    };
    // --shard-range A..B: host only that slice of the (identically
    // seeded) full model; the local backend gets one shard per hosted
    // global shard, so local and global shard boundaries coincide.
    let full_k = theta0.len();
    let mut placement = net::Placement::default();
    let mut local_shards = shards;
    let mut hosted = None;
    if let Some(spec) = &shard_range {
        let (a, b) = parse_shard_range(spec)?;
        let total = shards as u32;
        anyhow::ensure!(
            b <= total,
            "--shard-range {spec} exceeds --shards {shards} (with --shard-range, \
             --shards is the GLOBAL shard count of the placement)"
        );
        let coords = dana::cluster::coord_range(full_k, total, &(a..b))?;
        placement = net::Placement {
            shard_start: a,
            total_shards: total,
            epoch: placement_epoch,
            takeovers: 0,
        };
        local_shards = (b - a) as usize;
        theta0 = theta0[coords.clone()].to_vec();
        hosted = Some(coords);
    }
    let striped = serve_threads > 0 && local_shards > 1;
    let mut master = match &resume {
        Some(path) => {
            let mut snap = net::checkpoint::read_snapshot(path)?;
            if let Some(coords) = &hosted {
                // A full-model archive (e.g. from a 1-server run, or a
                // stitch) restores into this split transparently.
                if snap.theta.len() == full_k && full_k != theta0.len() {
                    snap = dana::cluster::slice_snapshot(&snap, coords)?;
                    println!(
                        "dana serve: sliced full-model snapshot to hosted coordinates \
                         {}..{}",
                        coords.start, coords.end
                    );
                }
            }
            // restore() re-validates; checking here gives a better message
            snap.validate(algorithm, theta0.len())?;
            let mut m = make_serving_master(
                algorithm,
                &snap.theta,
                schedule,
                0,
                local_shards,
                threads,
                striped,
            );
            m.restore(&snap)?;
            let (step, _, live, slots) = m.status();
            println!(
                "resumed {} from {} at master step {step} ({live} live of {slots} slots \
                 awaiting reconnect)",
                algorithm.name(),
                path.display(),
            );
            m
        }
        // fresh cluster: zero slots, every connect is a join
        None => {
            make_serving_master(algorithm, &theta0, schedule, 0, local_shards, threads, striped)
        }
    };
    master.set_metrics_every(metrics_every);
    let k = master.param_len();
    let opts = ServeOptions {
        leave_policy,
        checkpoint_path,
        checkpoint_every,
        pipeline_depth,
        status_addr,
        retention,
        encodings,
        placement,
    };
    let mut srv = NetServer::start_serving(master, &listen, opts)?;
    println!(
        "dana serve: {} k={k} shards={local_shards} ({}) pipeline-depth={pipeline_depth} on {} — \
         join with `dana train --master {}`",
        algorithm.name(),
        if striped { "lock-striped" } else { "global-lock" },
        srv.addr(),
        srv.url()
    );
    if placement.total_shards > 0 {
        println!(
            "dana serve: hosting global shards {}..{} of {} at placement epoch {}",
            placement.shard_start,
            placement.shard_start + local_shards as u32,
            placement.total_shards,
            placement.epoch
        );
    }
    if let Some(sa) = srv.status_addr() {
        println!("dana serve: status endpoint on http://{sa} (/metrics, /status)");
    }
    srv.wait();
    println!("dana serve: shut down");
    Ok(())
}

/// Parse `--shard-range A..B` (half-open, A < B).
fn parse_shard_range(spec: &str) -> anyhow::Result<(u32, u32)> {
    let (a, b) = spec
        .split_once("..")
        .ok_or_else(|| anyhow::anyhow!("--shard-range wants A..B, got {spec:?}"))?;
    let a: u32 = a
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("--shard-range start {a:?} is not a shard index"))?;
    let b: u32 = b
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("--shard-range end {b:?} is not a shard index"))?;
    anyhow::ensure!(a < b, "--shard-range {spec:?} is empty (need A < B)");
    Ok((a, b))
}

fn cmd_experiment(args: &mut Args) -> anyhow::Result<()> {
    let id = args
        .positional
        .first()
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("experiment id required\n{USAGE}"))?;
    let opts = ExpOptions {
        quick: !args.flag("full"),
        seeds: args.parse_or::<u64>("seeds", 2)?,
        out_dir: PathBuf::from(args.str_or("out", "results")),
        artifacts_dir: artifacts_dir(args),
        encoding: args.parse_or::<net::Encoding>("encoding", net::Encoding::None)?,
    };
    args.finish()?;
    let t0 = std::time::Instant::now();
    experiments::run(&id, &opts)?;
    println!(
        "experiment {id} done in {:.1}s (results in {})",
        t0.elapsed().as_secs_f64(),
        opts.out_dir.display()
    );
    Ok(())
}

fn cmd_simulate(args: &mut Args) -> anyhow::Result<()> {
    let workers = args.parse_or::<usize>("workers", 8)?;
    let env: Environment = args.str_or("env", "homo").parse()?;
    let bpw = args.parse_or::<usize>("batches-per-worker", 100)?;
    let batch = args.parse_or::<usize>("batch", 128)?;
    let seeds = args.parse_or::<u64>("seeds", 5)?;
    args.finish()?;
    let pts = dana::sim::speedup::speedup_sweep(env, &[workers], batch, bpw, seeds);
    for p in pts {
        println!(
            "{env:?} N={}: async {:.2}x, sync {:.2}x (async/sync {:.2})",
            p.n_workers,
            p.async_speedup,
            p.sync_speedup,
            p.async_speedup / p.sync_speedup
        );
    }
    Ok(())
}

fn cmd_info(args: &mut Args) -> anyhow::Result<()> {
    let dir = artifacts_dir(args);
    args.finish()?;
    let engine = Engine::cpu(&dir)?;
    println!("platform: {}", engine.platform());
    println!("artifacts: {}", dir.display());
    for v in &engine.manifest().variants {
        println!(
            "  {:<18} kind={:<4} P={:<8} batch={:<4} x{:?} golden_loss={:.4}",
            v.name, v.kind, v.param_count, v.batch, v.x_shape, v.golden.loss
        );
    }
    if let Some(uk) = &engine.manifest().update_kernel {
        println!("  update kernel: k={}", uk.k);
    }
    Ok(())
}

//! Dependency-free command-line parsing (clap is not in the offline
//! registry).  Supports `bin <subcommand> [positional...] [--flag]
//! [--key value|--key=value]` with typed accessors and an auto-generated
//! usage error on unknown keys.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    known: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I, expect_subcommand: bool) -> anyhow::Result<Args> {
        let mut a = Args::default();
        let mut it = it.into_iter().peekable();
        if expect_subcommand {
            if let Some(first) = it.peek() {
                if !first.starts_with('-') {
                    a.subcommand = it.next();
                }
            }
        }
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // "--" terminator: rest is positional
                    a.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    a.opts.insert(body.to_string(), it.next().unwrap());
                } else {
                    a.flags.push(body.to_string());
                }
            } else {
                a.positional.push(tok);
            }
        }
        Ok(a)
    }

    pub fn parse_env(expect_subcommand: bool) -> anyhow::Result<Args> {
        Self::parse_from(std::env::args().skip(1), expect_subcommand)
    }

    /// Boolean flag (`--quick`), also honours `--quick=true/false`.
    pub fn flag(&mut self, name: &str) -> bool {
        self.known.push(name.to_string());
        if self.flags.iter().any(|f| f == name) {
            return true;
        }
        matches!(self.opts.get(name).map(String::as_str), Some("true" | "1" | "yes"))
    }

    pub fn opt_str(&mut self, name: &str) -> Option<String> {
        self.known.push(name.to_string());
        self.opts.get(name).cloned()
    }

    pub fn str_or(&mut self, name: &str, default: &str) -> String {
        self.opt_str(name).unwrap_or_else(|| default.to_string())
    }

    pub fn opt_parse<T: std::str::FromStr>(&mut self, name: &str) -> anyhow::Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt_str(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{name} {s:?}: {e}")),
        }
    }

    pub fn parse_or<T: std::str::FromStr>(&mut self, name: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.opt_parse(name)?.unwrap_or(default))
    }

    /// Comma-separated list, e.g. `--workers 4,8,16`.
    pub fn list_or<T: std::str::FromStr>(&mut self, name: &str, default: &[T]) -> anyhow::Result<Vec<T>>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.opt_str(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .filter(|p| !p.is_empty())
                .map(|p| p.trim().parse::<T>().map_err(|e| anyhow::anyhow!("--{name} item {p:?}: {e}")))
                .collect(),
        }
    }

    /// Error out on any `--option` that no accessor consumed — typo guard.
    pub fn finish(&self) -> anyhow::Result<()> {
        for k in self.opts.keys().chain(self.flags.iter()) {
            if !self.known.iter().any(|n| n == k) {
                anyhow::bail!(
                    "unknown option --{k}; known: {}",
                    self.known.join(", ")
                );
            }
        }
        Ok(())
    }

    /// Every `--option` the caller provided (options with values and
    /// bare flags alike) — what a [`FlagTable`] validates against.
    pub fn provided(&self) -> impl Iterator<Item = &str> {
        self.opts.keys().map(String::as_str).chain(self.flags.iter().map(String::as_str))
    }
}

/// One flag a subcommand accepts: `--name METAVAR` (or a bare boolean
/// flag when `value` is None) plus the one-line help shown in usage.
pub struct FlagDef {
    pub name: &'static str,
    /// Metavar for the value (`"A..B"`, `"HOST:PORT"`); None = boolean.
    pub value: Option<&'static str>,
    pub help: &'static str,
}

/// Declarative flag table for one subcommand: generates the usage block
/// and rejects unknown options up front with a uniform error style (and
/// a did-you-mean suggestion), so `serve`/`train`/`cluster` cannot
/// drift apart in how they parse or how they fail.  Validation runs
/// BEFORE any accessor: a typo'd flag is named immediately instead of
/// surfacing as "unknown option" after half the command already parsed.
pub struct FlagTable {
    /// Subcommand name (`"serve"`), used in error and usage text.
    pub cmd: &'static str,
    /// One-line summary for the usage header.
    pub summary: &'static str,
    pub flags: &'static [FlagDef],
}

impl FlagTable {
    /// Reject any provided option not in the table.  Call this first,
    /// then use the typed [`Args`] accessors as usual.
    pub fn check(&self, args: &Args) -> anyhow::Result<()> {
        for k in args.provided() {
            if self.flags.iter().any(|f| f.name == k) {
                continue;
            }
            let suggest = self
                .flags
                .iter()
                .map(|f| f.name)
                .find(|n| {
                    let prefix = k.get(..k.len().min(3)).unwrap_or("");
                    (!prefix.is_empty() && n.starts_with(prefix))
                        || n.contains(k)
                        || k.contains(n)
                })
                .map(|n| format!(" (did you mean --{n}?)"))
                .unwrap_or_default();
            anyhow::bail!("dana {}: unknown option --{k}{suggest}\n{}", self.cmd, self.usage());
        }
        Ok(())
    }

    /// The generated usage block for this subcommand.
    pub fn usage(&self) -> String {
        let mut out = format!("usage: dana {} — {}\n", self.cmd, self.summary);
        for f in self.flags {
            let head = match f.value {
                Some(v) => format!("  --{} {v}", f.name),
                None => format!("  --{}", f.name),
            };
            out.push_str(&format!("{head:<34} {}\n", f.help));
        }
        out.pop();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str, sub: bool) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from), sub).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        // NB: a bare flag directly followed by a positional is ambiguous
        // (`--quick x.json` reads as --quick=x.json); positionals go first
        // or the flag uses --quick=true. This is the documented convention.
        let mut a = parse("train x.json --variant mlp_c10 --workers=8 --quick", true);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.str_or("variant", ""), "mlp_c10");
        assert_eq!(a.parse_or::<usize>("workers", 1).unwrap(), 8);
        assert!(a.flag("quick"));
        assert_eq!(a.positional, vec!["x.json"]);
        a.finish().unwrap();
    }

    #[test]
    fn flag_with_explicit_value() {
        let mut a = parse("run --quick=true --deep=false", true);
        assert!(a.flag("quick"));
        assert!(!a.flag("deep"));
        a.finish().unwrap();
    }

    #[test]
    fn lists_parse() {
        let mut a = parse("x --ns 4,8, 16", true);
        // note: "16" after the space is positional; list only splits the value
        assert_eq!(a.list_or::<usize>("ns", &[]).unwrap(), vec![4, 8]);
    }

    #[test]
    fn shards_flag_round_trips() {
        // the `dana train --shards S` spelling used by the sharded master
        let mut a = parse("train --shards 7 --workers=8", true);
        assert_eq!(a.parse_or::<usize>("shards", 1).unwrap(), 7);
        assert_eq!(a.parse_or::<usize>("workers", 1).unwrap(), 8);
        a.finish().unwrap();
        // default when absent
        let mut b = parse("train", true);
        assert_eq!(b.parse_or::<usize>("shards", 1).unwrap(), 1);
    }

    #[test]
    fn churn_flag_round_trips() {
        // the `dana train --churn SPEC --leave-policy P` spelling
        let mut a = parse("train --churn leave@0.3:2,join@0.5 --leave-policy fold", true);
        let churn = a
            .opt_parse::<crate::sim::ChurnSchedule>("churn")
            .unwrap()
            .unwrap();
        assert_eq!(churn.events.len(), 2);
        assert_eq!(
            a.opt_parse::<crate::optim::LeavePolicy>("leave-policy")
                .unwrap()
                .unwrap(),
            crate::optim::LeavePolicy::Fold
        );
        a.finish().unwrap();
        // malformed specs surface the parse error through opt_parse
        let mut b = parse("train --churn nap@0.5", true);
        assert!(b.opt_parse::<crate::sim::ChurnSchedule>("churn").is_err());
    }

    #[test]
    fn net_flags_round_trip() {
        // the `dana train --synthetic --master tcp://...` spelling
        let mut a = parse("train --synthetic --master tcp://127.0.0.1:7700 --k 64", true);
        assert!(a.flag("synthetic"));
        assert_eq!(a.opt_str("master").as_deref(), Some("tcp://127.0.0.1:7700"));
        assert_eq!(a.parse_or::<usize>("k", 256).unwrap(), 64);
        a.finish().unwrap();
        // the `dana serve` spelling
        let mut b = parse(
            "serve --listen 0.0.0.0:7700 --checkpoint ckpt.bin --checkpoint-every 500 \
             --resume ckpt.bin",
            true,
        );
        assert_eq!(b.str_or("listen", ""), "0.0.0.0:7700");
        assert_eq!(b.opt_str("checkpoint").as_deref(), Some("ckpt.bin"));
        assert_eq!(b.parse_or::<u64>("checkpoint-every", 0).unwrap(), 500);
        assert_eq!(b.opt_str("resume").as_deref(), Some("ckpt.bin"));
        b.finish().unwrap();
    }

    #[test]
    fn daemon_flags_round_trip() {
        // the `dana serve --status-addr ... --keep-last N --keep-hourly H` spelling
        let mut a = parse(
            "serve --listen 0.0.0.0:7700 --checkpoint ckpt.bin --status-addr 127.0.0.1:9100 \
             --keep-last 4 --keep-hourly 24",
            true,
        );
        assert_eq!(a.opt_str("status-addr").as_deref(), Some("127.0.0.1:9100"));
        assert_eq!(a.parse_or::<usize>("keep-last", 0).unwrap(), 4);
        assert_eq!(a.parse_or::<usize>("keep-hourly", 0).unwrap(), 24);
        let _ = a.opt_str("listen");
        let _ = a.opt_str("checkpoint");
        a.finish().unwrap();
        // defaults when absent: no endpoint, retention disabled
        let mut b = parse("serve --listen 0.0.0.0:7700", true);
        assert_eq!(b.opt_str("status-addr"), None);
        assert_eq!(b.parse_or::<usize>("keep-last", 0).unwrap(), 0);
        assert_eq!(b.parse_or::<usize>("keep-hourly", 0).unwrap(), 0);
        // the `dana train --max-restarts R --restart-backoff-ms MS` spelling
        let mut c = parse("train --max-restarts 3 --restart-backoff-ms=10", true);
        assert_eq!(c.opt_parse::<u32>("max-restarts").unwrap(), Some(3));
        assert_eq!(c.opt_parse::<u64>("restart-backoff-ms").unwrap(), Some(10));
        c.finish().unwrap();
        // malformed counts surface the parse error
        let mut d = parse("train --max-restarts many", true);
        assert!(d.opt_parse::<u32>("max-restarts").is_err());
    }

    #[test]
    fn encoding_flags_round_trip() {
        use crate::net::{Encoding, EncodingSet};
        // the `dana train --encoding E` spelling (wire v4)
        let mut a = parse("train --encoding topk:32 --workers=8", true);
        assert_eq!(
            a.opt_parse::<Encoding>("encoding").unwrap(),
            Some(Encoding::TopK { k: 32 })
        );
        let _ = a.parse_or::<usize>("workers", 1);
        a.finish().unwrap();
        // the `dana serve --encodings LIST` spelling
        let mut b = parse("serve --encodings f16,bf16", true);
        let set = b.parse_or::<EncodingSet>("encodings", EncodingSet::ALL).unwrap();
        assert!(set.contains(Encoding::F16));
        assert!(set.contains(Encoding::Bf16));
        assert!(set.contains(Encoding::None), "none is always advertised");
        assert!(!set.contains(Encoding::TopK { k: 1 }));
        b.finish().unwrap();
        // defaults and malformed values
        let mut c = parse("train", true);
        assert_eq!(c.opt_parse::<Encoding>("encoding").unwrap(), None);
        let mut d = parse("train --encoding topk:0", true);
        assert!(d.opt_parse::<Encoding>("encoding").is_err(), "topk needs k >= 1");
        let mut e = parse("serve --encodings f16,flac", true);
        assert!(e.parse_or::<EncodingSet>("encodings", EncodingSet::ALL).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        let mut a = parse("run --oops 1", true);
        let _ = a.flag("quick");
        assert!(a.finish().is_err());
    }

    #[test]
    fn flag_absent_is_false() {
        let mut a = parse("run", true);
        assert!(!a.flag("quick"));
        a.finish().unwrap();
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = parse("run -- --not-an-option", true);
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }

    #[test]
    fn flag_table_rejects_unknown_with_suggestion() {
        const T: FlagTable = FlagTable {
            cmd: "serve",
            summary: "host a parameter server",
            flags: &[
                FlagDef { name: "listen", value: Some("HOST:PORT"), help: "bind address" },
                FlagDef { name: "synthetic", value: None, help: "quadratic model" },
            ],
        };
        let a = parse("serve --listen 0.0.0.0:7700 --synthetic", true);
        T.check(&a).unwrap();
        // unknown flag: uniform error naming the subcommand + suggestion
        let b = parse("serve --listne 0.0.0.0:7700", true);
        let err = T.check(&b).unwrap_err().to_string();
        assert!(err.contains("dana serve: unknown option --listne"), "got: {err}");
        assert!(err.contains("did you mean --listen?"), "got: {err}");
        // usage block lists every flag with its metavar
        let u = T.usage();
        assert!(u.contains("--listen HOST:PORT"));
        assert!(u.contains("--synthetic"));
    }

    #[test]
    fn defaults_apply() {
        let mut a = parse("run", true);
        assert_eq!(a.parse_or::<f64>("eta", 0.1).unwrap(), 0.1);
        assert_eq!(a.str_or("mode", "homo"), "homo");
    }
}

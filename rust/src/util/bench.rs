//! Micro-benchmark harness (criterion replacement for `cargo bench`).
//!
//! Usage inside a `harness = false` bench target:
//! ```ignore
//! let mut b = BenchSuite::new("optimizer");
//! b.bench("dana_zero_apply_100k", || { ... });
//! b.finish();
//! ```
//! Each case is auto-calibrated to a target wall time, then timed over
//! multiple samples; the report prints mean ± std and throughput when the
//! case registers a byte count.

use std::hint::black_box;
use std::time::{Duration, Instant};

pub use std::hint::black_box as bb;

#[derive(Debug, Clone)]
pub struct CaseResult {
    pub name: String,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub samples: usize,
    pub iters_per_sample: u64,
    pub bytes_per_iter: Option<u64>,
}

pub struct BenchSuite {
    group: String,
    target_sample: Duration,
    samples: usize,
    results: Vec<CaseResult>,
    filter: Option<String>,
}

impl BenchSuite {
    pub fn new(group: &str) -> Self {
        // `cargo bench -- <filter>` passes the filter as an arg.
        let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
        BenchSuite {
            group: group.to_string(),
            target_sample: Duration::from_millis(
                std::env::var("BENCH_SAMPLE_MS")
                    .ok()
                    .and_then(|s| s.parse().ok())
                    .unwrap_or(60),
            ),
            samples: std::env::var("BENCH_SAMPLES")
                .ok()
                .and_then(|s| s.parse().ok())
                .unwrap_or(12),
            results: Vec::new(),
            filter,
        }
    }

    fn skip(&self, name: &str) -> bool {
        self.filter.as_ref().map(|f| !name.contains(f.as_str())).unwrap_or(false)
    }

    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) {
        self.bench_with_bytes(name, None, f)
    }

    /// Benchmark with a bytes-touched-per-iteration figure so the report can
    /// show effective memory bandwidth (the master loops are BW-bound).
    pub fn bench_with_bytes<F: FnMut()>(&mut self, name: &str, bytes: Option<u64>, mut f: F) {
        if self.skip(name) {
            return;
        }
        // Calibrate: how many iters fill one sample window?
        let mut iters = 1u64;
        loop {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            let el = t.elapsed();
            if el >= self.target_sample / 4 || iters > 1 << 30 {
                let scale = (self.target_sample.as_secs_f64() / el.as_secs_f64().max(1e-9))
                    .clamp(1.0, 1e6);
                iters = ((iters as f64) * scale).max(1.0) as u64;
                break;
            }
            iters *= 8;
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            times.push(t.elapsed().as_secs_f64() * 1e9 / iters as f64);
        }
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>()
            / (times.len() - 1).max(1) as f64;
        let res = CaseResult {
            name: name.to_string(),
            mean_ns: mean,
            std_ns: var.sqrt(),
            samples: self.samples,
            iters_per_sample: iters,
            bytes_per_iter: bytes,
        };
        println!("{}", format_result(&self.group, &res));
        self.results.push(res);
    }

    /// Print the summary; returns results for programmatic use.
    pub fn finish(self) -> Vec<CaseResult> {
        println!(
            "{}: {} case(s) done",
            self.group,
            self.results.len()
        );
        self.results
    }

    /// Like [`Self::finish`], but also write the results as JSON to
    /// `path` (e.g. `BENCH_serve.json` at the repo root) so the perf
    /// trajectory is tracked in-tree run over run.  A filtered run
    /// (`cargo bench -- <filter>`) writes only the rows it ran.
    ///
    /// A filter that matched **no** case of this suite is an error
    /// ([`NoCaseMatched`]): the tracked file is left untouched and the
    /// caller decides whether that's fatal (a typo'd filter silently
    /// "passing" in CI is how perf tracking rots) or fine (a multi-suite
    /// binary where another suite ran the filtered case).  A failed
    /// write is always an error — a bench run whose numbers vanished
    /// must not look green.
    pub fn finish_json(self, path: &str) -> anyhow::Result<Vec<CaseResult>> {
        if self.results.is_empty() {
            if let Some(filter) = self.filter.clone() {
                return Err(anyhow::Error::new(NoCaseMatched {
                    group: self.group.clone(),
                    filter,
                }));
            }
        }
        let json = results_json(&self.group, &self.results);
        std::fs::write(path, &json)
            .map_err(|e| anyhow::anyhow!("{}: could not write {path}: {e}", self.group))?;
        println!("{}: wrote {path}", self.group);
        Ok(self.finish())
    }
}

/// A `cargo bench -- <filter>` run whose filter matched none of a
/// suite's cases.  Typed so a multi-suite bench binary can distinguish
/// "this suite was filtered out" (fine when some other suite ran) from a
/// filter that matched nothing anywhere (a typo — fail the run).
#[derive(Debug, Clone)]
pub struct NoCaseMatched {
    pub group: String,
    pub filter: String,
}

impl std::fmt::Display for NoCaseMatched {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "bench suite {:?}: filter {:?} matched no case",
            self.group, self.filter
        )
    }
}

impl std::error::Error for NoCaseMatched {}

/// Serialize results as a stable, diff-friendly JSON document (no serde
/// in the offline registry — see `util/json.rs` for the reader side).
fn results_json(group: &str, results: &[CaseResult]) -> String {
    let esc = |s: &str| s.replace('\\', "\\\\").replace('"', "\\\"");
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"group\": \"{}\",\n", esc(group)));
    out.push_str("  \"unit\": \"ns_per_iter\",\n");
    out.push_str("  \"results\": [\n");
    for (i, r) in results.iter().enumerate() {
        let bytes = r
            .bytes_per_iter
            .map(|b| b.to_string())
            .unwrap_or_else(|| "null".to_string());
        let gbps = r
            .bytes_per_iter
            .map(|b| format!("{:.3}", b as f64 / r.mean_ns))
            .unwrap_or_else(|| "null".to_string());
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"mean_ns\": {:.1}, \"std_ns\": {:.1}, \
             \"samples\": {}, \"iters_per_sample\": {}, \"bytes_per_iter\": {}, \
             \"gb_per_s\": {}}}{}\n",
            esc(&r.name),
            r.mean_ns,
            r.std_ns,
            r.samples,
            r.iters_per_sample,
            bytes,
            gbps,
            if i + 1 == results.len() { "" } else { "," }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

fn format_result(group: &str, r: &CaseResult) -> String {
    let human = |ns: f64| -> String {
        if ns < 1e3 {
            format!("{ns:.1} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.2} s", ns / 1e9)
        }
    };
    let mut line = format!(
        "{group}/{:<40} {:>12} ± {:>10}  (n={} x{})",
        r.name,
        human(r.mean_ns),
        human(r.std_ns),
        r.samples,
        r.iters_per_sample
    );
    if let Some(bytes) = r.bytes_per_iter {
        let gbs = bytes as f64 / r.mean_ns; // bytes/ns == GB/s
        line.push_str(&format!("  {gbs:.2} GB/s"));
    }
    line
}

/// Keep a value alive and opaque to the optimizer.
pub fn keep<T>(x: T) -> T {
    black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        std::env::set_var("BENCH_SAMPLE_MS", "1");
        std::env::set_var("BENCH_SAMPLES", "3");
        let mut b = BenchSuite::new("selftest");
        let mut acc = 0u64;
        b.bench("add", || {
            acc = keep(acc.wrapping_add(1));
        });
        let res = b.finish();
        assert_eq!(res.len(), 1);
        assert!(res[0].mean_ns > 0.0);
    }

    #[test]
    fn json_output_is_parseable_and_complete() {
        let results = vec![
            CaseResult {
                name: "a\"quoted\"".into(),
                mean_ns: 123.4,
                std_ns: 5.6,
                samples: 3,
                iters_per_sample: 10,
                bytes_per_iter: Some(400),
            },
            CaseResult {
                name: "plain".into(),
                mean_ns: 1.0,
                std_ns: 0.0,
                samples: 1,
                iters_per_sample: 1,
                bytes_per_iter: None,
            },
        ];
        let s = results_json("serve", &results);
        let j = crate::util::json::Json::parse(&s).expect("bench JSON must parse");
        assert_eq!(j.get("group").and_then(|g| g.as_str()), Some("serve"));
        let rows = j.get("results").and_then(|r| r.as_arr()).expect("results array");
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("bytes_per_iter").and_then(|b| b.as_usize()), Some(400));
    }

    #[test]
    fn filtered_empty_finish_json_errors_and_keeps_file() {
        let dir = std::env::temp_dir().join(format!("dana-bench-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_x.json");
        std::fs::write(&path, "{\"group\":\"old\"}").unwrap();
        let b = BenchSuite {
            group: "empty".into(),
            target_sample: Duration::from_millis(1),
            samples: 1,
            results: Vec::new(),
            filter: Some("no-such-case".into()),
        };
        let err = b.finish_json(path.to_str().unwrap()).unwrap_err();
        assert!(err.downcast_ref::<NoCaseMatched>().is_some(), "typed error: {err:#}");
        assert_eq!(
            std::fs::read_to_string(&path).unwrap(),
            "{\"group\":\"old\"}",
            "tracked file must be left untouched"
        );
        // an UNfiltered empty suite legitimately writes an empty result set
        let b = BenchSuite {
            group: "empty".into(),
            target_sample: Duration::from_millis(1),
            samples: 1,
            results: Vec::new(),
            filter: None,
        };
        assert!(b.finish_json(path.to_str().unwrap()).is_ok());
        // and an unwritable path is an error, not a shrug
        let b = BenchSuite {
            group: "empty".into(),
            target_sample: Duration::from_millis(1),
            samples: 1,
            results: Vec::new(),
            filter: None,
        };
        assert!(b.finish_json("/no-such-dir-dana/out.json").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn format_includes_bandwidth() {
        let r = CaseResult {
            name: "x".into(),
            mean_ns: 100.0,
            std_ns: 1.0,
            samples: 3,
            iters_per_sample: 10,
            bytes_per_iter: Some(400),
        };
        let s = format_result("g", &r);
        assert!(s.contains("GB/s"), "{s}");
    }
}

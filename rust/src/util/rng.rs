//! Deterministic PRNG + distribution sampling (no external crates).
//!
//! The registry mirror ships only the `xla` closure, so the usual
//! `rand`/`rand_distr` stack is unavailable; this module provides the three
//! samplers the paper's evaluation needs:
//!
//! * uniform / normal draws for data generation and schedules,
//! * **gamma** draws for the CVB task-execution-time model (Ali et al. 2000,
//!   paper Appendix A.4) via Marsaglia–Tsang with the alpha < 1 boost.
//!
//! Generator: xoshiro256++ seeded through SplitMix64 — fast, well-tested
//! constants, and fully reproducible across runs/platforms.

/// xoshiro256++ PRNG with SplitMix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box–Muller pair.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64 state expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-worker / per-seed forks).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply keeps bias < 2^-64 — negligible for simulation.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller (pair-cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u in (0,1] to keep ln finite.
        let u = 1.0 - self.uniform();
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    /// Normal with given mean/stddev.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Gamma(shape alpha, scale beta) via Marsaglia–Tsang (2000).
    ///
    /// For alpha < 1 uses the boost `G(alpha) = G(alpha+1) * U^(1/alpha)`.
    pub fn gamma(&mut self, alpha: f64, beta: f64) -> f64 {
        assert!(alpha > 0.0 && beta > 0.0, "gamma params must be positive");
        if alpha < 1.0 {
            let u = self.uniform().max(f64::MIN_POSITIVE);
            return self.gamma(alpha + 1.0, beta) * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let (x, v) = loop {
                let x = self.normal();
                let v = 1.0 + c * x;
                if v > 0.0 {
                    break (x, v * v * v);
                }
            };
            let u = self.uniform();
            if u < 1.0 - 0.0331 * (x * x) * (x * x) {
                return d * v * beta;
            }
            if u > 0.0 && u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v * beta;
            }
        }
    }

    /// Fill a slice with N(0, std) f32 values.
    pub fn fill_normal_f32(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for o in out.iter_mut() {
            *o = self.normal_ms(mean as f64, std as f64) as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval_and_centered() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            m1 += z;
            m2 += z * z;
        }
        let mean = m1 / n as f64;
        let var = m2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn gamma_moments_match_theory() {
        // E[G(a, b)] = a*b, Var = a*b^2 — covers both alpha branches.
        let mut r = Rng::new(13);
        for &(a, b) in &[(0.5, 2.0), (2.0, 0.5), (100.0, 1.28), (9.0, 3.0)] {
            let n = 100_000;
            let (mut m1, mut m2) = (0.0, 0.0);
            for _ in 0..n {
                let g = r.gamma(a, b);
                assert!(g > 0.0);
                m1 += g;
                m2 += g * g;
            }
            let mean = m1 / n as f64;
            let var = m2 / n as f64 - mean * mean;
            assert!((mean / (a * b) - 1.0).abs() < 0.03, "mean a={a} b={b}: {mean}");
            assert!((var / (a * b * b) - 1.0).abs() < 0.12, "var a={a} b={b}: {var}");
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10) as usize;
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}

//! Minimal JSON parser + writer (serde is not in the offline registry).
//!
//! Covers the full JSON grammar we produce/consume: the artifact
//! `manifest.json`, experiment configs, and result files.  Strings support
//! the standard escapes incl. `\uXXXX` (surrogate pairs folded).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use BTreeMap for deterministic output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ---------- accessors ----------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` chained access; returns Null-typed error context free None.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---------- builders ----------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // ---------- parse ----------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?)
    }

    // ---------- write ----------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

impl Json {
    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if pretty {
                            out.push(' ');
                        }
                    }
                    x.write(out, indent, false); // arrays stay inline
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.b[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                hi
                            };
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.pos -= 1; // compensating the +1 below
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.peek().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
            self.pos += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_scalars() {
        for s in ["null", "true", "false", "0", "-1.5", "3e8", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            let back = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, back, "{s}");
        }
    }

    #[test]
    fn parses_nested_structure() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.at(&["c"]).unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parses_unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "tru", "{\"a\"}", "1 2", "\"\\x\""] {
            assert!(Json::parse(s).is_err(), "{s}");
        }
    }

    #[test]
    fn round_trips_floats_exactly_enough() {
        let v = Json::parse("[0.1, 1e-9, 123456789.125]").unwrap();
        let xs = v.as_arr().unwrap();
        assert_eq!(xs[0].as_f64().unwrap(), 0.1);
        assert_eq!(xs[2].as_f64().unwrap(), 123456789.125);
    }

    #[test]
    fn pretty_output_reparses() {
        let v = Json::obj(vec![
            ("name", Json::str("x")),
            ("xs", Json::arr_f64(&[1.0, 2.5])),
            ("nested", Json::obj(vec![("k", Json::Bool(true))])),
        ]);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}

//! Dependency-free substrates: PRNG/distributions, JSON, CLI, statistics,
//! CSV output, micro-benchmarking and thread parallelism.
//!
//! The offline registry only carries the `xla` crate closure, so everything
//! the usual ecosystem would provide (`rand`, `serde`, `clap`, `criterion`,
//! `rayon`) is implemented here from scratch, sized to what the paper's
//! reproduction actually needs.

pub mod bench;
pub mod cli;
pub mod csvw;
pub mod json;
pub mod parallel;
pub mod rng;
pub mod sha256;
pub mod stats;
pub mod sync;

/// Bounded exponential restart backoff (the PR 6 crash-loop discipline,
/// shared by the in-process worker supervisor and the cluster process
/// supervisor): attempt `a >= 1` waits `base << (a-1)`, capped at 5 s.
pub fn backoff_ms(base_ms: u64, attempt: u32) -> u64 {
    base_ms
        .saturating_mul(1u64 << attempt.saturating_sub(1).min(6))
        .min(5_000)
}

//! Dependency-free substrates: PRNG/distributions, JSON, CLI, statistics,
//! CSV output, micro-benchmarking and thread parallelism.
//!
//! The offline registry only carries the `xla` crate closure, so everything
//! the usual ecosystem would provide (`rand`, `serde`, `clap`, `criterion`,
//! `rayon`) is implemented here from scratch, sized to what the paper's
//! reproduction actually needs.

pub mod bench;
pub mod cli;
pub mod csvw;
pub mod json;
pub mod parallel;
pub mod rng;
pub mod stats;
pub mod sync;

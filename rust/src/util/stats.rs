//! Small statistics toolkit: summaries, online accumulation, RMSE (the
//! paper's *gap* metric is an RMSE — Section 3).

/// Streaming mean/variance via Welford's algorithm.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for n < 2.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Summary of a sample: mean, std, min, max, median.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty(), "summarize of empty sample");
    let mut w = Welford::default();
    let (mut min, mut max) = (f64::INFINITY, f64::NEG_INFINITY);
    for &x in xs {
        w.push(x);
        min = min.min(x);
        max = max.max(x);
    }
    Summary {
        n: xs.len(),
        mean: w.mean(),
        std: w.std(),
        min,
        max,
        median: quantile(xs, 0.5),
    }
}

/// Quantile with linear interpolation (sorts a copy).
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty() && (0.0..=1.0).contains(&q));
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    let pos = q * (s.len() - 1) as f64;
    let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
    }
}

/// RMSE of a vector — the paper's gap:  G(d) = ||d||_2 / sqrt(k).
pub fn rmse(d: &[f32]) -> f64 {
    if d.is_empty() {
        return 0.0;
    }
    let ss: f64 = d.iter().map(|&x| (x as f64) * (x as f64)).sum();
    (ss / d.len() as f64).sqrt()
}

/// L2 norm of an f32 slice in f64 accumulation.
pub fn l2_norm(d: &[f32]) -> f64 {
    d.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / 5.0;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / 4.0;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.var() - var).abs() < 1e-12);
    }

    #[test]
    fn quantiles_interpolate() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(quantile(&xs, 0.5), 2.5);
    }

    #[test]
    fn rmse_matches_definition() {
        let d = [3.0f32, 4.0];
        // ||d|| = 5, k = 2 -> 5/sqrt(2)
        assert!((rmse(&d) - 5.0 / 2.0f64.sqrt()).abs() < 1e-9);
        assert_eq!(rmse(&[]), 0.0);
    }

    #[test]
    fn summary_fields() {
        let s = summarize(&[2.0, 1.0, 3.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.median, 2.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }
}

//! Tiny CSV writer for experiment results (`results/<id>.csv`).

use std::io::Write;
use std::path::Path;

pub struct CsvWriter {
    file: std::fs::File,
    cols: usize,
}

impl CsvWriter {
    /// Create `path` (parents included) and write the header row.
    pub fn create(path: &Path, header: &[&str]) -> anyhow::Result<CsvWriter> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut file = std::fs::File::create(path)?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter { file, cols: header.len() })
    }

    /// Write one row of already-formatted fields.
    pub fn row(&mut self, fields: &[String]) -> anyhow::Result<()> {
        anyhow::ensure!(
            fields.len() == self.cols,
            "csv row has {} fields, header has {}",
            fields.len(),
            self.cols
        );
        let escaped: Vec<String> = fields
            .iter()
            .map(|f| {
                if f.contains(',') || f.contains('"') || f.contains('\n') {
                    format!("\"{}\"", f.replace('"', "\"\""))
                } else {
                    f.clone()
                }
            })
            .collect();
        writeln!(self.file, "{}", escaped.join(","))?;
        Ok(())
    }
}

/// Format an f64 compactly for CSV cells.
pub fn fnum(x: f64) -> String {
    if x == x.trunc() && x.abs() < 1e12 {
        format!("{}", x as i64)
    } else {
        format!("{x:.6}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_escapes() {
        let dir = std::env::temp_dir().join(format!("csvw_test_{}", std::process::id()));
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.row(&["1".into(), "x,\"y\"".into()]).unwrap();
        drop(w);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,\"x,\"\"y\"\"\"\n");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn wrong_arity_rejected() {
        let dir = std::env::temp_dir().join(format!("csvw_test2_{}", std::process::id()));
        let mut w = CsvWriter::create(&dir.join("t.csv"), &["a"]).unwrap();
        assert!(w.row(&["1".into(), "2".into()]).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn fnum_formats() {
        assert_eq!(fnum(3.0), "3");
        assert_eq!(fnum(0.125), "0.125000");
    }
}

//! Poison-recovering lock primitives.
//!
//! `std`'s locks poison when a holder panics, and every subsequent
//! `.lock().unwrap()` then panics too — so one crashed connection thread
//! would take the whole serving process down with it.  For a parameter
//! server the right failure model is the opposite: a panic while holding a
//! lock may leave *that* operation torn (the slot is retired, the incident
//! logged), but the cluster keeps serving.  Every protected structure here
//! is either repaired by its owner (the net server retires the offending
//! slot) or self-consistent per field (counters, masks, coordinate
//! vectors), so taking the guard out of a [`PoisonError`] is sound.
//!
//! These helpers are the single place the recovery decision lives; all
//! server/net code locks through them instead of `.expect("poisoned")`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Log the first recovery only — a poisoned lock is touched by every
/// subsequent operation and would otherwise flood the log.
static POISON_SEEN: AtomicBool = AtomicBool::new(false);

fn note_poison(what: &str) {
    if !POISON_SEEN.swap(true, Ordering::Relaxed) {
        eprintln!("warn: recovered a poisoned {what} (a holder panicked); continuing");
    }
}

/// Lock a mutex, recovering the guard if a previous holder panicked.
pub fn lock<T: ?Sized>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| {
        note_poison("mutex");
        e.into_inner()
    })
}

/// Read-lock, recovering from poison.
pub fn read<T: ?Sized>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|e| {
        note_poison("rwlock");
        e.into_inner()
    })
}

/// Write-lock, recovering from poison.
pub fn write<T: ?Sized>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|e| {
        note_poison("rwlock");
        e.into_inner()
    })
}

/// Condvar wait that re-acquires through poison like [`lock`].
pub fn wait<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(|e| {
        note_poison("condvar mutex");
        e.into_inner()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex, RwLock};

    #[test]
    fn mutex_recovers_after_holder_panic() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("die holding the lock");
        })
        .join();
        assert!(m.is_poisoned());
        // plain lock().unwrap() would panic here; the helper recovers
        *lock(&m) += 1;
        assert_eq!(*lock(&m), 8);
    }

    #[test]
    fn rwlock_recovers_after_holder_panic() {
        let l = Arc::new(RwLock::new(vec![1, 2, 3]));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("die holding the write lock");
        })
        .join();
        assert!(l.is_poisoned());
        write(&l).push(4);
        assert_eq!(read(&l).len(), 4);
    }
}

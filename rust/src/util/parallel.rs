//! Data parallelism for the master's O(k) fan-outs (rayon is not in the
//! offline registry).
//!
//! Two execution strategies share one chunking rule (`chunk =
//! n.div_ceil(threads)`, chunk `i` covering `[i*chunk, min((i+1)*chunk, n))`),
//! so their parallel results are interchangeable:
//!
//! * [`par_chunks_mut`] — the original scoped-thread reference: spawns OS
//!   threads per call.  Kept as the semantic baseline (the equivalence
//!   tests pit the pool against it) and for one-shot callers.
//! * [`WorkerPool`] — a persistent parked pool, spawned once per
//!   [`crate::server::ShardedParameterServer`].  Spawning OS threads inside
//!   every gated apply costs more than the memory-bound loop it fans out at
//!   the 1e5–1e6-element sizes this repo targets; the pool parks instead.
//!
//! ## Why the submitter participates (deadlock freedom)
//!
//! Push fan-out parts block in `ShardCell::wait_ticket` until every earlier
//! ticket has applied on that shard.  With a bounded shared pool, all pool
//! workers could be parked inside parts of a *later*-ticket push while the
//! earlier push's job sits queued — a deadlock the per-call `thread::scope`
//! never had (it spawned unboundedly).  The pool therefore never makes a
//! submitter depend on pool capacity: after enqueueing, the submitting
//! thread claims parts *from its own job only* until none remain.  The push
//! holding the minimum outstanding ticket never blocks in `wait_ticket`, so
//! it can always drain its own job inline, bumping shard gates and waking
//! any pool workers parked on later tickets.  Progress is guaranteed with
//! any pool size, including zero workers.
//!
//! ## Panic containment
//!
//! A panicking part must not kill a pool worker (the pool outlives the
//! request) and must not wedge the submitter (it waits for all parts to
//! finish).  Each part runs under `catch_unwind`; the job counts panicked
//! parts, the worker survives, and the submitter re-raises a panic once the
//! job completes — the same observable contract as `thread::scope`, which
//! propagates a child panic to the scope's owner.

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

use crate::util::sync;

/// Number of worker threads to use by default: the `DANA_THREADS` env
/// override when set (fail-closed on garbage — a typo'd tuning knob should
/// abort, not silently fall back), else cores capped at 16.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("DANA_THREADS") {
        match v.trim().parse::<usize>() {
            Ok(n) if n >= 1 => return n,
            _ => panic!("invalid DANA_THREADS {v:?} (want a positive integer)"),
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(16)
}

/// Apply `f(chunk_index, chunk)` to disjoint mutable chunks of `data` in
/// parallel across `threads` scoped threads (the spawn-per-call reference;
/// see [`WorkerPool::par_chunks_mut`] for the persistent-pool equivalent).
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], threads: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        f(0, data);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || f(i, c));
        }
    });
}

/// Parallel map over items, preserving order.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    let chunk = items.len().div_ceil(threads);
    std::thread::scope(|s| {
        for (ins, outs) in items.chunks(chunk).zip(out.chunks_mut(chunk)) {
            let f = &f;
            s.spawn(move || {
                for (i, o) in ins.iter().zip(outs.iter_mut()) {
                    *o = Some(f(i));
                }
            });
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

// ------------------------------------------------------------ worker pool

/// One queued fan-out.  Lives on the submitting thread's stack for the
/// whole job (the submitter blocks in [`WorkerPool::run`] until
/// `finished == parts`), so the raw pointers handed to pool workers stay
/// valid.  All `Cell` fields are only touched under the pool's state mutex.
struct JobInner {
    /// Type-erased trampoline: calls the submitter's part closure.
    call: unsafe fn(*const (), usize),
    /// Points at a `&(dyn Fn(usize) + Sync)` on the submitter's stack.
    ctx: *const (),
    parts: usize,
    /// Next part index to claim (== `parts` once fully claimed).
    next: Cell<usize>,
    /// Parts that have finished running (panicked or not).
    finished: Cell<usize>,
    /// Parts that panicked; the submitter re-raises after completion.
    panicked: Cell<usize>,
}

struct JobPtr(*const JobInner);

// SAFETY: the `JobInner` behind a `JobPtr` outlives its time in the queue —
// the submitter keeps it on its stack until `finished == parts`, and a job
// leaves the queue no later than its last part is claimed.  All mutation
// goes through `Cell`s guarded by the pool's state mutex.
unsafe impl Send for JobPtr {}

struct PoolState {
    queue: VecDeque<JobPtr>,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Signals workers: a job was queued, or shutdown.
    work: Condvar,
    /// Signals submitters: some job's last part finished.
    done: Condvar,
}

/// A persistent parked worker pool (see module docs for the design).
///
/// `WorkerPool::new(t)` spawns `t - 1` parked workers; the submitting
/// thread is the `t`-th executor, so a fan-out runs on the same number of
/// threads as the scoped reference with `threads = t`.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    threads: usize,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Create a pool that fans out across `threads` executors (the
    /// submitter plus `threads - 1` spawned workers).  `threads <= 1`
    /// spawns nothing; fan-outs then run inline on the submitter.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState { queue: VecDeque::new(), shutdown: false }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (1..threads)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("dana-pool-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, threads, handles }
    }

    /// Fan-out width this pool was built for.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Apply `f(chunk_index, chunk)` to disjoint mutable chunks of `data`,
    /// with chunk boundaries identical to [`par_chunks_mut`] at
    /// `threads = self.threads()` — parallel results are unchanged, only
    /// the execution vehicle differs (parked pool instead of spawns).
    pub fn par_chunks_mut<T, F>(&self, data: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let n = data.len();
        if n == 0 {
            return;
        }
        let threads = self.threads.min(n);
        if threads == 1 || self.handles.is_empty() {
            // Same serial order as the reference's single-thread path when
            // threads == 1; otherwise parts still run, just sequentially.
            let chunk = n.div_ceil(threads);
            for (i, c) in data.chunks_mut(chunk).enumerate() {
                f(i, c);
            }
            return;
        }
        let chunk = n.div_ceil(threads);
        let parts = n.div_ceil(chunk);
        let base = SendPtr(data.as_mut_ptr());
        let f = &f;
        let run_part = move |i: usize| {
            let start = i * chunk;
            let len = chunk.min(n - start);
            // SAFETY: part indices partition `[0, n)` into disjoint
            // `[i*chunk, i*chunk + len)` ranges of a `&mut [T]` that the
            // submitter keeps borrowed until every part has finished; each
            // index is claimed exactly once, so no two threads alias.
            let c = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), len) };
            f(i, c);
        };
        self.run(parts, &run_part);
    }

    /// Queue `parts` invocations of `part_fn` and run them to completion,
    /// claiming parts from this job (never another submitter's) on the
    /// calling thread — the deadlock-freedom rule from the module docs.
    fn run(&self, parts: usize, part_fn: &(dyn Fn(usize) + Sync)) {
        /// Trampoline re-materializing the part closure from the erased
        /// context pointer.
        ///
        /// # Safety
        /// `ctx` must point at a live `&(dyn Fn(usize) + Sync)`; the
        /// submitter keeps it on its stack until the job finishes.
        unsafe fn call(ctx: *const (), i: usize) {
            // SAFETY: upheld by the caller per the function contract above.
            let f: &&(dyn Fn(usize) + Sync) = unsafe { &*ctx.cast() };
            f(i);
        }
        let job = JobInner {
            call,
            ctx: std::ptr::addr_of!(part_fn).cast(),
            parts,
            next: Cell::new(0),
            finished: Cell::new(0),
            panicked: Cell::new(0),
        };
        {
            let mut st = sync::lock(&self.shared.state);
            st.queue.push_back(JobPtr(&job));
            drop(st);
            self.shared.work.notify_all();
        }
        // Participate: claim parts from our own job until none remain.
        loop {
            let i = {
                let st = sync::lock(&self.shared.state);
                let i = job.next.get();
                if i >= parts {
                    break;
                }
                job.next.set(i + 1);
                if i + 1 == parts {
                    // Fully claimed: out of the queue, workers move on.
                    st_remove(st, &job);
                }
                i
            };
            run_one(&self.shared, &job, i);
        }
        // Wait for parts claimed by pool workers to finish.
        let panicked = {
            let mut st = sync::lock(&self.shared.state);
            while job.finished.get() < parts {
                st = sync::wait(&self.shared.done, st);
            }
            job.panicked.get()
        };
        if panicked > 0 {
            panic!("{panicked} worker pool chunk(s) panicked");
        }
    }
}

/// Remove `job` from the queue (it may not be at the front when the
/// submitter claims its last part while older jobs still drain).
fn st_remove(mut st: std::sync::MutexGuard<'_, PoolState>, job: &JobInner) {
    let target: *const JobInner = job;
    st.queue.retain(|jp| !std::ptr::eq(jp.0, target));
}

/// Run one claimed part under `catch_unwind`, then account its completion.
fn run_one(shared: &PoolShared, job: &JobInner, i: usize) {
    let hit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        // SAFETY: `call`/`ctx` were packed together by `run`; the job (and
        // the closure `ctx` points at) is alive because our claimed part
        // has not yet been counted finished, so the submitter still waits.
        unsafe { (job.call)(job.ctx, i) }
    }))
    .is_err();
    let st = sync::lock(&shared.state);
    if hit {
        job.panicked.set(job.panicked.get() + 1);
    }
    job.finished.set(job.finished.get() + 1);
    let complete = job.finished.get() == job.parts;
    drop(st);
    if complete {
        shared.done.notify_all();
    }
}

fn worker_loop(shared: &PoolShared) {
    loop {
        let (ptr, i) = {
            let mut st = sync::lock(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(front) = st.queue.front() {
                    let ptr = front.0;
                    // SAFETY: queued jobs are alive (see `JobPtr`).
                    let job = unsafe { &*ptr };
                    let i = job.next.get();
                    job.next.set(i + 1);
                    if i + 1 == job.parts {
                        st.queue.pop_front();
                    }
                    break (ptr, i);
                }
                st = sync::wait(&shared.work, st);
            }
        };
        // SAFETY: our claimed part is not yet counted finished, so the
        // submitter still has the job (and its closure) on its stack.
        let job = unsafe { &*ptr };
        run_one(shared, job, i);
        // `job` must not be touched past `run_one`: once the last part is
        // counted, the submitter may return and pop its stack frame.
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = sync::lock(&self.shared.state);
            st.shutdown = true;
        }
        self.shared.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// A `*mut T` that may cross threads: the pool hands disjoint index ranges
/// of one live `&mut [T]` to its executors.
struct SendPtr<T>(*mut T);

// SAFETY: only disjoint, exactly-once-claimed ranges are ever formed from
// the pointer (see `par_chunks_mut`), and `T: Send` bounds the element.
unsafe impl<T: Send> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything() {
        let mut xs = vec![0u32; 1003];
        par_chunks_mut(&mut xs, 4, |_, c| {
            for x in c {
                *x += 1;
            }
        });
        assert!(xs.iter().all(|&x| x == 1));
    }

    #[test]
    fn single_thread_path() {
        let mut xs = vec![1i64; 10];
        par_chunks_mut(&mut xs, 1, |i, c| {
            assert_eq!(i, 0);
            for x in c {
                *x *= 3;
            }
        });
        assert_eq!(xs, vec![3i64; 10]);
    }

    #[test]
    fn map_preserves_order() {
        let xs: Vec<u64> = (0..100).collect();
        let ys = par_map(&xs, 8, |&x| x * x);
        assert_eq!(ys, xs.iter().map(|&x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn empty_inputs_are_fine() {
        let mut e: Vec<u8> = vec![];
        par_chunks_mut(&mut e, 4, |_, _| panic!("must not run"));
        let out = par_map::<u8, u8, _>(&[], 4, |_| 0);
        assert!(out.is_empty());
        let pool = WorkerPool::new(4);
        let mut e2: Vec<u8> = vec![];
        pool.par_chunks_mut(&mut e2, |_, _| panic!("must not run"));
    }

    #[test]
    fn pool_matches_scoped_chunking() {
        // Same chunk boundaries as the scoped reference: record which
        // chunk index touched every element under both vehicles.
        for threads in [1usize, 2, 3, 4, 7] {
            for n in [1usize, 2, 5, 16, 1003] {
                let mut scoped = vec![usize::MAX; n];
                par_chunks_mut(&mut scoped, threads, |i, c| c.fill(i));
                let pool = WorkerPool::new(threads);
                let mut pooled = vec![usize::MAX; n];
                pool.par_chunks_mut(&mut pooled, |i, c| c.fill(i));
                assert_eq!(scoped, pooled, "threads={threads} n={n}");
            }
        }
    }

    #[test]
    fn pool_is_reusable_and_concurrent() {
        let pool = WorkerPool::new(4);
        for round in 0..50u32 {
            let mut xs = vec![0u32; 257];
            pool.par_chunks_mut(&mut xs, |_, c| {
                for x in c {
                    *x += round;
                }
            });
            assert!(xs.iter().all(|&x| x == round));
        }
        // Concurrent submitters share the pool without interference.
        let pool = std::sync::Arc::new(WorkerPool::new(3));
        std::thread::scope(|s| {
            for t in 0..4u32 {
                let pool = std::sync::Arc::clone(&pool);
                s.spawn(move || {
                    for _ in 0..20 {
                        let mut xs = vec![0u32; 101];
                        pool.par_chunks_mut(&mut xs, |_, c| {
                            for x in c {
                                *x += t + 1;
                            }
                        });
                        assert!(xs.iter().all(|&x| x == t + 1));
                    }
                });
            }
        });
    }

    #[test]
    fn pool_propagates_part_panics_and_survives() {
        let pool = WorkerPool::new(4);
        let hit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut xs = vec![0u8; 64];
            pool.par_chunks_mut(&mut xs, |i, _| {
                if i == 1 {
                    panic!("boom");
                }
            });
        }));
        assert!(hit.is_err(), "part panic must reach the submitter");
        // The pool keeps working after a contained panic.
        let mut xs = vec![0u32; 64];
        pool.par_chunks_mut(&mut xs, |_, c| c.fill(9));
        assert!(xs.iter().all(|&x| x == 9));
    }

    #[test]
    fn default_threads_env_override() {
        // Serialize against other env-reading tests in this binary by
        // running the whole check in one test.
        std::env::set_var("DANA_THREADS", "3");
        assert_eq!(default_threads(), 3);
        std::env::set_var("DANA_THREADS", " 12 ");
        assert_eq!(default_threads(), 12);
        std::env::remove_var("DANA_THREADS");
        assert!(default_threads() >= 1);
    }
}

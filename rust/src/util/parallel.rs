//! Scoped-thread data parallelism (rayon is not in the offline registry).
//!
//! The master's O(k) update loops are memory-bandwidth bound; for the param
//! sizes in this repo (1e5..1e6 f32) single-thread is usually fastest, but
//! the chunked helper lets the perf pass measure the crossover and the
//! benches exercise both paths.

/// Number of worker threads to use by default (cores, capped).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(16)
}

/// Apply `f(chunk_index, chunk)` to disjoint mutable chunks of `data` in
/// parallel across `threads` scoped threads.
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], threads: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        f(0, data);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || f(i, c));
        }
    });
}

/// Parallel map over items, preserving order.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    let chunk = items.len().div_ceil(threads);
    std::thread::scope(|s| {
        for (ins, outs) in items.chunks(chunk).zip(out.chunks_mut(chunk)) {
            let f = &f;
            s.spawn(move || {
                for (i, o) in ins.iter().zip(outs.iter_mut()) {
                    *o = Some(f(i));
                }
            });
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything() {
        let mut xs = vec![0u32; 1003];
        par_chunks_mut(&mut xs, 4, |_, c| {
            for x in c {
                *x += 1;
            }
        });
        assert!(xs.iter().all(|&x| x == 1));
    }

    #[test]
    fn single_thread_path() {
        let mut xs = vec![1i64; 10];
        par_chunks_mut(&mut xs, 1, |i, c| {
            assert_eq!(i, 0);
            for x in c {
                *x *= 3;
            }
        });
        assert_eq!(xs, vec![3i64; 10]);
    }

    #[test]
    fn map_preserves_order() {
        let xs: Vec<u64> = (0..100).collect();
        let ys = par_map(&xs, 8, |&x| x * x);
        assert_eq!(ys, xs.iter().map(|&x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn empty_inputs_are_fine() {
        let mut e: Vec<u8> = vec![];
        par_chunks_mut(&mut e, 4, |_, _| panic!("must not run"));
        let out = par_map::<u8, u8, _>(&[], 4, |_| 0);
        assert!(out.is_empty());
    }
}

//! `dana cluster --manifest cluster.json` — launch and supervise a
//! whole topology from one validated [`ClusterManifest`].
//!
//! The supervisor is deliberately dumb about *what* it runs: every
//! child is this same binary re-invoked as `dana serve --manifest M
//! --server NAME --run-dir D` (or `dana train --manifest M`), and each
//! child re-parses the manifest through the same `from_manifest`
//! constructors the CLI flags normalize into — so there is exactly one
//! source of per-process configuration and no flag soup to regenerate.
//!
//! Lifecycle (DESIGN.md §14):
//!
//! 1. **validate** — [`ClusterManifest::load`] + artifact checksum
//!    verification, all before any process spawns.  `--verify-only`
//!    stops here.
//! 2. **launch** — primaries first, then standbys, each with stdout and
//!    stderr captured to `<run_dir>/logs/<name>.log`; then a health
//!    gate: every primary must answer a placement probe (and every
//!    standby must accept a connection) within the gate timeout, or the
//!    whole launch is torn down.
//! 3. **fleet** — the worker fleet (`dana train --manifest`) runs with
//!    inherited stdio, so its `placement:` accounting lines land in the
//!    supervisor's own output.
//! 4. **supervise** — a process that dies is relaunched under its
//!    manifest `restart` policy with the PR 6 bounded-exponential
//!    backoff ([`crate::util::backoff_ms`]).  The default budget is 0:
//!    a killed primary stays dead, which is what makes standby takeover
//!    drills mean something.  Live pids are kept current in
//!    `<run_dir>/logs/pids.json`.
//! 5. **shutdown** — fleet success, fleet retirement, or SIGTERM/SIGINT
//!    winds the cluster down gracefully: each server gets the in-band
//!    `Shutdown` control frame (checkpoint-then-exit), stragglers are
//!    killed after a grace period.

use crate::cluster::manifest::{ClusterManifest, RestartPolicy};
use crate::net::client::{probe, shutdown_once};
use crate::util::backoff_ms;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

/// `dana cluster` options (see `util::cli` flag table in `main.rs`).
#[derive(Debug, Clone)]
pub struct LaunchOptions {
    pub manifest_path: PathBuf,
    /// Base directory for mutable state: checkpoints and logs resolve
    /// against this, never against the committed manifest's directory.
    pub run_dir: PathBuf,
    /// Validate (structure + artifact checksums) and exit.
    pub verify_only: bool,
    /// Launch and supervise the servers but not the worker fleet (CI
    /// drives `dana train --manifest` in the foreground itself).
    pub no_fleet: bool,
    /// Health-gate timeout for the whole topology.
    pub health_timeout: Duration,
}

impl Default for LaunchOptions {
    fn default() -> Self {
        LaunchOptions {
            manifest_path: PathBuf::from("cluster.json"),
            run_dir: PathBuf::from("."),
            verify_only: false,
            no_fleet: false,
            health_timeout: Duration::from_secs(30),
        }
    }
}

/// Raised by the SIGTERM/SIGINT handler; polled by the supervise loop.
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
fn install_signal_handlers() {
    // std-only signal hookup: the handler just raises a flag, the
    // supervise loop does the actual (allocation-heavy) wind-down.
    extern "C" fn on_signal(_sig: i32) {
        SHUTDOWN.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(sig: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    // SAFETY: `signal(2)` with a valid signal number and an
    // async-signal-safe handler (a single atomic store) is sound; the
    // returned previous handler is deliberately discarded.
    unsafe {
        signal(SIGINT, on_signal as usize);
        signal(SIGTERM, on_signal as usize);
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

/// One supervised child process.
struct Proc {
    name: String,
    /// argv after the binary path, for respawns.
    args: Vec<String>,
    child: Option<Child>,
    restart: RestartPolicy,
    attempts: u32,
    /// When a pending respawn becomes due (backoff in progress).
    respawn_at: Option<Instant>,
    /// Serving address, for the graceful in-band shutdown.
    listen: Option<String>,
    /// `<run_dir>/logs/<name>.log`, or None for inherited stdio.
    log_path: Option<PathBuf>,
    fleet: bool,
    /// Permanently finished: clean exit or restart budget exhausted.
    retired: bool,
}

impl Proc {
    fn spawn(&mut self, exe: &Path) -> anyhow::Result<()> {
        let mut cmd = Command::new(exe);
        cmd.args(&self.args);
        if let Some(log) = &self.log_path {
            let out = std::fs::File::create(log)
                .map_err(|e| anyhow::anyhow!("creating {}: {e}", log.display()))?;
            let err = out.try_clone()?;
            cmd.stdout(Stdio::from(out)).stderr(Stdio::from(err));
        }
        let child = cmd
            .spawn()
            .map_err(|e| anyhow::anyhow!("spawning {}: {e}", self.name))?;
        self.child = Some(child);
        Ok(())
    }

    fn pid(&self) -> Option<u32> {
        self.child.as_ref().map(|c| c.id())
    }
}

/// Write `<run_dir>/logs/pids.json`: `{name: pid}` for every live
/// child, so an operator (or the CI takeover drill) can signal a
/// process by its manifest name.
fn write_pids(logs: &Path, procs: &[Proc]) {
    let mut map = BTreeMap::new();
    for p in procs {
        if let Some(pid) = p.pid() {
            if !p.retired {
                map.insert(p.name.clone(), crate::util::json::Json::Num(pid as f64));
            }
        }
    }
    let j = crate::util::json::Json::Obj(map);
    let _ = std::fs::write(logs.join("pids.json"), j.to_string_pretty());
}

pub fn run(opts: &LaunchOptions) -> anyhow::Result<()> {
    // ---- 1. validate: everything rejects before anything spawns ----
    let m = ClusterManifest::load(&opts.manifest_path)?;
    let verified = m.verify_artifacts()?;
    println!("cluster manifest OK: {}", m.summary());
    if verified > 0 {
        println!("cluster manifest: {verified} artifact checksum(s) verified");
    }
    if opts.verify_only {
        return Ok(());
    }

    let exe = std::env::current_exe()
        .map_err(|e| anyhow::anyhow!("resolving own executable: {e}"))?;
    let logs = opts.run_dir.join("logs");
    std::fs::create_dir_all(&logs)
        .map_err(|e| anyhow::anyhow!("creating {}: {e}", logs.display()))?;
    let manifest_arg = opts.manifest_path.display().to_string();
    let run_dir_arg = opts.run_dir.display().to_string();
    install_signal_handlers();

    // ---- 2. launch: primaries, then standbys, then the health gate ----
    let mut procs: Vec<Proc> = Vec::new();
    let serve_args = |name: &str| {
        vec![
            "serve".to_string(),
            "--manifest".to_string(),
            manifest_arg.clone(),
            "--server".to_string(),
            name.to_string(),
            "--run-dir".to_string(),
            run_dir_arg.clone(),
        ]
    };
    for s in &m.servers {
        procs.push(Proc {
            name: s.name.clone(),
            args: serve_args(&s.name),
            child: None,
            restart: s.restart,
            attempts: 0,
            respawn_at: None,
            listen: Some(s.listen.clone()),
            log_path: Some(logs.join(format!("{}.log", s.name))),
            fleet: false,
            retired: false,
        });
    }
    for s in &m.standbys {
        procs.push(Proc {
            name: s.name.clone(),
            args: serve_args(&s.name),
            child: None,
            restart: s.restart,
            attempts: 0,
            respawn_at: None,
            listen: Some(s.listen.clone()),
            log_path: Some(logs.join(format!("{}.log", s.name))),
            fleet: false,
            retired: false,
        });
    }
    for p in &mut procs {
        p.spawn(&exe)?;
        println!(
            "dana cluster: launched {} (pid {}) → {}",
            p.name,
            p.pid().unwrap_or(0),
            p.log_path.as_deref().map(|l| l.display().to_string()).unwrap_or_default()
        );
    }
    write_pids(&logs, &procs);

    // Health gate: every primary must answer a placement probe, every
    // standby must at least accept a connection (a standby cannot probe
    // OK until it has seen its primary's advertisement).
    let gate_deadline = Instant::now() + opts.health_timeout;
    let standby_names: Vec<&str> = m.standbys.iter().map(|s| s.name.as_str()).collect();
    for (name, listen) in m
        .servers
        .iter()
        .map(|s| (s.name.as_str(), s.listen.as_str()))
        .chain(m.standbys.iter().map(|s| (s.name.as_str(), s.listen.as_str())))
    {
        let is_standby = standby_names.contains(&name);
        loop {
            let healthy = if is_standby {
                std::net::TcpStream::connect(listen).is_ok()
            } else {
                probe(listen).is_ok()
            };
            if healthy {
                break;
            }
            if Instant::now() >= gate_deadline {
                teardown(&mut procs);
                anyhow::bail!(
                    "health gate: {name} ({listen}) not serving within {:?} — see {}",
                    opts.health_timeout,
                    logs.join(format!("{name}.log")).display()
                );
            }
            if SHUTDOWN.load(Ordering::SeqCst) {
                teardown(&mut procs);
                anyhow::bail!("interrupted during launch");
            }
            std::thread::sleep(Duration::from_millis(50));
        }
    }
    println!(
        "dana cluster: health gate passed ({} server(s), {} standby(s))",
        m.servers.len(),
        m.standbys.len()
    );

    // ---- 3. fleet ----
    if let (false, Some(f)) = (opts.no_fleet, &m.fleet) {
        procs.push(Proc {
            name: "fleet".to_string(),
            args: vec![
                "train".to_string(),
                "--manifest".to_string(),
                manifest_arg.clone(),
            ],
            child: None,
            restart: f.restart,
            attempts: 0,
            respawn_at: None,
            listen: None,
            // inherited stdio: the fleet's `placement:` step accounting
            // is the run's primary observable output
            log_path: None,
            fleet: true,
            retired: false,
        });
        let i = procs.len() - 1;
        if let Err(e) = procs[i].spawn(&exe) {
            teardown(&mut procs);
            return Err(e);
        }
        println!("dana cluster: launched fleet (pid {})", procs[i].pid().unwrap_or(0));
        write_pids(&logs, &procs);
    }

    // ---- 4. supervise ----
    let mut fleet_outcome: Option<bool> = None;
    loop {
        if SHUTDOWN.load(Ordering::SeqCst) {
            println!("dana cluster: signal received — winding down with checkpoints");
            break;
        }
        let mut changed = false;
        for p in &mut procs {
            if p.retired {
                continue;
            }
            // pending respawn due?
            if let Some(at) = p.respawn_at {
                if Instant::now() >= at {
                    p.respawn_at = None;
                    match p.spawn(&exe) {
                        Ok(()) => {
                            println!(
                                "dana cluster: restarted {} (attempt {}/{}, pid {})",
                                p.name,
                                p.attempts,
                                p.restart.max,
                                p.pid().unwrap_or(0)
                            );
                            changed = true;
                        }
                        Err(e) => {
                            eprintln!("dana cluster: respawn of {} failed: {e:#}", p.name);
                            p.retired = true;
                        }
                    }
                }
                continue;
            }
            let Some(child) = p.child.as_mut() else { continue };
            match child.try_wait() {
                Ok(None) => {}
                Ok(Some(status)) => {
                    changed = true;
                    let ok = status.success();
                    if p.fleet && ok {
                        println!("dana cluster: fleet completed");
                        p.retired = true;
                        fleet_outcome = Some(true);
                    } else if p.attempts < p.restart.max {
                        p.attempts += 1;
                        let wait = backoff_ms(p.restart.backoff_ms, p.attempts);
                        eprintln!(
                            "dana cluster: {} exited ({status}); restarting in {wait} ms",
                            p.name
                        );
                        p.respawn_at = Some(Instant::now() + Duration::from_millis(wait));
                    } else {
                        eprintln!(
                            "dana cluster: {} exited ({status}); restart budget exhausted \
                             ({}/{}) — retired",
                            p.name, p.attempts, p.restart.max
                        );
                        p.retired = true;
                        if p.fleet {
                            fleet_outcome = Some(ok);
                        }
                    }
                }
                Err(e) => {
                    eprintln!("dana cluster: waiting on {}: {e}", p.name);
                    p.retired = true;
                }
            }
        }
        if changed {
            write_pids(&logs, &procs);
        }
        // fleet done (either way): the run is over, wind the servers down
        if fleet_outcome.is_some() {
            break;
        }
        // nothing left alive to supervise
        if procs.iter().all(|p| p.retired) {
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }

    // ---- 5. graceful shutdown-with-checkpoint ----
    for p in &procs {
        if p.retired {
            continue;
        }
        if let Some(listen) = &p.listen {
            match shutdown_once(listen) {
                Ok(()) => println!("dana cluster: {} shut down (checkpointed)", p.name),
                Err(e) => eprintln!("dana cluster: in-band shutdown of {}: {e:#}", p.name),
            }
        }
    }
    let grace = Instant::now() + Duration::from_secs(10);
    loop {
        let mut all_done = true;
        for p in procs.iter_mut() {
            if p.retired {
                continue;
            }
            let exited = match p.child.as_mut() {
                None => true,
                Some(child) => matches!(child.try_wait(), Ok(Some(_)) | Err(_)),
            };
            if exited {
                p.child = None;
                p.retired = true;
            } else {
                all_done = false;
            }
        }
        if all_done || Instant::now() >= grace {
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    teardown(&mut procs);
    write_pids(&logs, &procs);

    match fleet_outcome {
        Some(true) | None => Ok(()),
        Some(false) => anyhow::bail!("fleet failed (restart budget exhausted)"),
    }
}

/// Kill and reap everything still running.  Idempotent.
fn teardown(procs: &mut [Proc]) {
    for p in procs.iter_mut() {
        if let Some(child) = p.child.as_mut() {
            let _ = child.kill();
            let _ = child.wait();
        }
        p.child = None;
    }
}

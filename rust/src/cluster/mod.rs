//! Shard-group placement: the multi-server cluster layer (PR 8).
//!
//! PR 4 made the shard the unit of *concurrency* (lock striping inside
//! one server); this subsystem promotes it to the unit of *placement*:
//! the global shard space is tiled by contiguous per-server ranges, one
//! `dana serve --shard-range A..B` process per range, and a training
//! driver runs against the whole placement through one fan-out
//! [`Master`](crate::server::Master) — `--master` with a
//! comma-separated endpoint list.
//!
//! * [`placement`] — [`PlacementMap`]: resolve the placement by probing
//!   endpoints for the shard range, placement epoch, and standby flag
//!   each advertises in its handshake header (wire v5); fail-closed
//!   validation (full coverage, no overlap, no empty range, shapes
//!   consistent);
//! * [`master`] — [`ClusterMaster`]: every pull/push fans coordinate
//!   slices across all groups in one overlapped round trip per server;
//!   membership fans to every group; epoch-fenced fail-over re-homes a
//!   group to whichever server claims its range (pulls retry, pushes
//!   are counted lost — never retried, the double-apply hazard);
//!   YellowFin pushes in two overlapped phases (stage partials → merge
//!   → commit under global sums) so whole-vector reductions stay exact
//!   across the split;
//! * [`snapshot`] — layout-independent checkpoint slicing: a 1-server
//!   archive restores into an S-server split (and back) bit-for-bit;
//! * [`standby`] — [`StandbyServer`] (`dana serve --standby-of ADDR`):
//!   tails the primary's retention archives, takes its exact range over
//!   on failure at epoch `last_seen + 1`, serving on the listener it
//!   held from the start; pre-takeover it also answers read-only
//!   `PullParams`/`GetTheta` from the restored archive, stamped
//!   `standby = 1`;
//! * [`manifest`] — [`ClusterManifest`]: one fail-closed `cluster.json`
//!   describing the whole topology (placement, standby pairings, fleet,
//!   checkpoints, sha256-pinned artifacts), validated with the same
//!   tiling rules live resolution applies;
//! * [`launch`] — `dana cluster --manifest`: launch, health-gate, and
//!   supervise every process the manifest names, with crash-loop
//!   restarts and graceful in-band shutdown-with-checkpoint.
//!
//! A single-endpoint `--master` never touches this layer — that path
//! stays the plain [`crate::net::RemoteMaster`], bit-for-bit.  See
//! DESIGN.md §13.

pub mod launch;
pub mod manifest;
pub mod master;
pub mod placement;
pub mod snapshot;
pub mod standby;

pub use launch::LaunchOptions;
pub use manifest::ClusterManifest;
pub use master::ClusterMaster;
pub use placement::{PlacementMap, ResolvedGroup};
pub use snapshot::{coord_range, slice_snapshot, stitch_snapshots};
pub use standby::{StandbyConfig, StandbyServer};

//! Placement resolution: which live server hosts which contiguous range
//! of global shards.
//!
//! A placement promotes the shard from unit of *concurrency* (the
//! lock-striped backend, DESIGN.md §9) to unit of *placement*: the
//! global shard index space `0..total_shards` is tiled by contiguous
//! per-server ranges, and a server hosting shards `[A, B)` holds
//! exactly the coordinates `shard_bounds(k, total_shards)[A..B]` of the
//! global model.  Nothing is configured client-side — the map is
//! *resolved* by probing every `--master` endpoint and reading the
//! hosted range, placement epoch, and standby flag each one advertises
//! in its handshake header (wire v5).
//!
//! Resolution is fail-closed: the ranges must cover the whole shard
//! space with no gap, no overlap, and no empty range, every server must
//! agree on the algorithm and the global shard count, and each server's
//! local parameter count must equal the span its range implies.  A
//! standby answers probes but never claims its range, so listing
//! standbys alongside primaries in `--master` is safe; when two servers
//! claim the *same* range (a takeover raced a stale primary's
//! resurrection) the higher placement epoch wins.

use crate::net::client::probe;
use crate::optim::AlgorithmKind;
use crate::server::shard_bounds;
use std::ops::Range;

/// One placement group: a server endpoint and the contiguous slice of
/// the model it hosts.
#[derive(Debug, Clone)]
pub struct ResolvedGroup {
    /// Endpoint as listed in `--master` (scheme optional).
    pub endpoint: String,
    /// Hosted global shard range `[start, end)`.
    pub shards: Range<u32>,
    /// Global coordinate range the shard range spans.
    pub coords: Range<usize>,
    /// Placement epoch of the server's claim (monotone across
    /// takeovers; see [`crate::net::wire::Header::epoch`]).
    pub epoch: u64,
    /// Local parameter count (== `coords.len()`).
    pub k_local: usize,
}

/// A resolved, validated placement: groups in shard order tiling
/// `0..total_shards`, with the global model shape they add up to.
#[derive(Debug, Clone)]
pub struct PlacementMap {
    pub kind: AlgorithmKind,
    /// Global parameter count (sum of the groups' local counts).
    pub k: usize,
    pub total_shards: u32,
    /// Placement order: ascending, contiguous shard ranges.
    pub groups: Vec<ResolvedGroup>,
}

/// Fail-closed tiling check, shared by live placement resolution and
/// the cluster manifest (`cluster.json` validates statically with the
/// *same* rules, so a manifest that parses is a topology that
/// resolves).  `ranges` are (label, hosted shard range) pairs in any
/// order; they must tile `0..total` exactly — no empty range, no
/// overlap, no gap, nothing past the end.  `what` names the subject in
/// error text ("placement", "cluster manifest").
pub fn validate_tiling(
    what: &str,
    ranges: &[(String, Range<u32>)],
    total: u32,
) -> anyhow::Result<()> {
    anyhow::ensure!(total > 0, "{what} has no shards (global shard count is 0)");
    anyhow::ensure!(!ranges.is_empty(), "{what} has no shard ranges to tile 0..{total}");
    let mut sorted: Vec<&(String, Range<u32>)> = ranges.iter().collect();
    sorted.sort_by_key(|(_, r)| (r.start, r.end));
    for (label, r) in &sorted {
        anyhow::ensure!(
            r.start < r.end,
            "{what} range {label} ({}..{}) is empty",
            r.start,
            r.end
        );
        anyhow::ensure!(
            r.end <= total,
            "{what} range {label} ({}..{}) exceeds the global shard count {total}",
            r.start,
            r.end
        );
    }
    anyhow::ensure!(
        sorted[0].1.start == 0,
        "{what} does not cover shards 0..{total}: lowest hosted range starts at {}",
        sorted[0].1.start
    );
    for w in sorted.windows(2) {
        let (a_label, a) = w[0];
        let (b_label, b) = w[1];
        anyhow::ensure!(
            b.start == a.end,
            "{what} ranges {a_label} ({}..{}) and {b_label} ({}..{}) {}",
            a.start,
            a.end,
            b.start,
            b.end,
            if b.start < a.end { "overlap" } else { "leave a gap" }
        );
    }
    let (last_label, last) = sorted.last().expect("validated non-empty");
    anyhow::ensure!(
        last.end == total,
        "{what} covers shards only up to {} of {total} (highest range is {last_label} at \
         {}..{})",
        last.end,
        last.start,
        last.end
    );
    Ok(())
}

impl PlacementMap {
    /// Probe every endpoint and assemble the placement they jointly
    /// advertise.  Unreachable endpoints and standbys are skipped (they
    /// are reported only if the remainder fails validation); everything
    /// else is strict.
    pub fn resolve(endpoints: &[String]) -> anyhow::Result<PlacementMap> {
        anyhow::ensure!(!endpoints.is_empty(), "placement needs at least one endpoint");
        struct Cand {
            endpoint: String,
            shards: Range<u32>,
            epoch: u64,
            kind: AlgorithmKind,
            k_local: usize,
            total: u32,
        }
        let mut cands: Vec<Cand> = Vec::new();
        let mut skipped: Vec<String> = Vec::new();
        for ep in endpoints {
            match probe(ep) {
                Ok(info) => {
                    let h = info.header;
                    if h.standby != 0 {
                        skipped.push(format!(
                            "{ep}: standby watching shards {}..{} (epoch {})",
                            h.shard_start,
                            h.shard_start + h.shard_hosted,
                            h.epoch
                        ));
                        continue;
                    }
                    anyhow::ensure!(
                        h.total_shards > 0 && h.shard_hosted > 0,
                        "placement endpoint {ep} advertises an empty shard range"
                    );
                    let end = h
                        .shard_start
                        .checked_add(h.shard_hosted)
                        .filter(|&e| e <= h.total_shards)
                        .ok_or_else(|| {
                            anyhow::anyhow!(
                                "placement endpoint {ep} advertises shards {}..{} beyond the \
                                 global count {}",
                                h.shard_start,
                                h.shard_start as u64 + h.shard_hosted as u64,
                                h.total_shards
                            )
                        })?;
                    cands.push(Cand {
                        endpoint: ep.clone(),
                        shards: h.shard_start..end,
                        epoch: h.epoch,
                        kind: info.kind,
                        k_local: info.k,
                        total: h.total_shards,
                    });
                }
                Err(e) => skipped.push(format!("{ep}: {e:#}")),
            }
        }
        let context = move |msg: String| {
            if skipped.is_empty() {
                msg
            } else {
                format!("{msg} (skipped endpoints: {})", skipped.join("; "))
            }
        };
        anyhow::ensure!(
            !cands.is_empty(),
            "{}",
            context("no placement endpoint answered as a primary".into())
        );
        let total = cands[0].total;
        let kind = cands[0].kind;
        for c in &cands {
            anyhow::ensure!(
                c.total == total,
                "placement endpoints disagree on the global shard count: {} says {}, {} \
                 says {}",
                cands[0].endpoint,
                total,
                c.endpoint,
                c.total
            );
            anyhow::ensure!(
                c.kind == kind,
                "placement endpoints disagree on the algorithm: {} runs {}, {} runs {}",
                cands[0].endpoint,
                kind.name(),
                c.endpoint,
                c.kind.name()
            );
        }
        // identical ranges: the higher epoch wins (a resurrected stale
        // primary loses to the standby that took its range over)
        cands.sort_by_key(|c| (c.shards.start, c.shards.end, std::cmp::Reverse(c.epoch)));
        cands.dedup_by_key(|c| (c.shards.start, c.shards.end));
        // strict tiling of 0..total — the same fail-closed rules the
        // cluster manifest applies statically (validate_tiling)
        let labeled: Vec<(String, Range<u32>)> = cands
            .iter()
            .map(|c| (c.endpoint.clone(), c.shards.clone()))
            .collect();
        validate_tiling("placement", &labeled, total)
            .map_err(|e| anyhow::anyhow!("{}", context(format!("{e:#}"))))?;
        // derive the global model shape and check each group spans
        // exactly the coordinates its shard range implies
        let k: usize = cands.iter().map(|c| c.k_local).sum();
        anyhow::ensure!(
            total as usize <= k,
            "placement has more shards ({total}) than parameters ({k})"
        );
        let bounds = shard_bounds(k, total as usize);
        let mut groups = Vec::with_capacity(cands.len());
        for c in cands {
            let coords = bounds[c.shards.start as usize].start
                ..bounds[c.shards.end as usize - 1].end;
            anyhow::ensure!(
                coords.len() == c.k_local,
                "placement endpoint {} hosts {} parameters but its shards {}..{} span \
                 {} of k={}",
                c.endpoint,
                c.k_local,
                c.shards.start,
                c.shards.end,
                coords.len(),
                k
            );
            groups.push(ResolvedGroup {
                endpoint: c.endpoint,
                shards: c.shards,
                coords,
                epoch: c.epoch,
                k_local: c.k_local,
            });
        }
        Ok(PlacementMap { kind, k, total_shards: total, groups })
    }
}

/// Probe `endpoints` for a live primary claiming exactly `shards` of a
/// `total`-shard placement at an epoch no older than `min_epoch` —
/// the fail-over search.  Returns the claimant with the highest epoch.
/// Probes only; touches no membership, so it is safe from `&self`
/// contexts (θ reads) as well as real fail-over.
pub(crate) fn find_claimant(
    endpoints: &[String],
    shards: &Range<u32>,
    total: u32,
    kind: AlgorithmKind,
    k_local: usize,
    min_epoch: u64,
) -> Option<(String, u64)> {
    let mut best: Option<(String, u64)> = None;
    for ep in endpoints {
        let Ok(info) = probe(ep) else { continue };
        let h = info.header;
        let claims = h.standby == 0
            && h.shard_start == shards.start
            && h.shard_start.checked_add(h.shard_hosted) == Some(shards.end)
            && h.total_shards == total
            && h.epoch >= min_epoch
            && info.kind == kind
            && info.k == k_local;
        if claims && best.as_ref().map(|(_, e)| h.epoch > *e).unwrap_or(true) {
            best = Some((ep.clone(), h.epoch));
        }
    }
    best
}

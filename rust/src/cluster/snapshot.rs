//! Layout-independent checkpoint slicing: cut a full-model
//! [`MasterSnapshot`] into per-placement-range snapshots and stitch
//! per-range snapshots back into a full-model one.
//!
//! The state a master holds is, coordinate-wise, *separable*: θ, the
//! retained pull windows, and every [`StateVec::Coord`] /
//! [`StateVec::PerWorker`] entry are per-coordinate vectors, while
//! [`StateVec::Scalars`] entries are coordinate-independent and
//! identical on every range (the sharded backend already relies on
//! this; see `server/sharded.rs`).  That makes a placement split a pure
//! re-slicing: a 1-server checkpoint restores into an S-server split —
//! and back — bit-for-bit, for every update rule.  Stitching validates
//! the cross-range invariants (same kind, step count, liveness, pull
//! schedule, and bitwise-equal scalars) and fails closed on any skew,
//! because skew means the ranges did not observe the same push
//! sequence.

use crate::optim::StateVec;
use crate::server::{shard_bounds, MasterSnapshot};
use std::ops::Range;

/// The global coordinate range spanned by global shards
/// `[shards.start, shards.end)` of a `total_shards`-shard placement
/// over `k` parameters.
pub fn coord_range(
    k: usize,
    total_shards: u32,
    shards: &Range<u32>,
) -> anyhow::Result<Range<usize>> {
    anyhow::ensure!(total_shards > 0, "coord_range: zero total shards");
    anyhow::ensure!(
        shards.start < shards.end && shards.end <= total_shards,
        "coord_range: shard range {}..{} invalid for {} total shards",
        shards.start,
        shards.end,
        total_shards
    );
    anyhow::ensure!(
        total_shards as usize <= k,
        "coord_range: more shards ({total_shards}) than parameters ({k})"
    );
    let bounds = shard_bounds(k, total_shards as usize);
    Ok(bounds[shards.start as usize].start..bounds[shards.end as usize - 1].end)
}

fn slice_coord(v: &[f32], k: usize, coords: &Range<usize>, what: &str) -> anyhow::Result<Vec<f32>> {
    anyhow::ensure!(
        v.len() == k,
        "snapshot {what} has {} coordinates, expected k={k}",
        v.len()
    );
    Ok(v[coords.clone()].to_vec())
}

/// Cut one global coordinate range out of a full-model snapshot,
/// producing the snapshot the server hosting that range would have
/// written itself.  Everything per-coordinate is sliced; scalars,
/// liveness, the step count, and the pull schedule are replicated.
pub fn slice_snapshot(
    snap: &MasterSnapshot,
    coords: &Range<usize>,
) -> anyhow::Result<MasterSnapshot> {
    let k = snap.theta.len();
    anyhow::ensure!(
        coords.start < coords.end && coords.end <= k,
        "slice {}..{} out of bounds for k={k}",
        coords.start,
        coords.end
    );
    let mut pulls = Vec::with_capacity(snap.pulls.len());
    for (w, window) in snap.pulls.iter().enumerate() {
        let mut out = Vec::with_capacity(window.len());
        for (at, params) in window {
            out.push((*at, slice_coord(params, k, coords, &format!("pull window of slot {w}"))?));
        }
        pulls.push(out);
    }
    let mut state = Vec::with_capacity(snap.state.len());
    for (name, v) in &snap.state {
        let sliced = match v {
            StateVec::Coord(c) => StateVec::Coord(slice_coord(c, k, coords, name)?),
            StateVec::PerWorker(rows) => {
                let mut out = Vec::with_capacity(rows.len());
                for row in rows {
                    out.push(slice_coord(row, k, coords, name)?);
                }
                StateVec::PerWorker(out)
            }
            StateVec::Scalars(s) => StateVec::Scalars(s.clone()),
        };
        state.push((name.clone(), sliced));
    }
    Ok(MasterSnapshot {
        kind: snap.kind,
        master_step: snap.master_step,
        last_eta: snap.last_eta,
        theta: snap.theta[coords.clone()].to_vec(),
        live: snap.live.clone(),
        pulls,
        state,
    })
}

/// Stitch per-range snapshots (in placement order) back into one
/// full-model snapshot.  Every cross-range invariant is checked: the
/// ranges must agree on kind, step count, η, slot liveness, the shape
/// and timing of every pull window, the state-dict schema, and the
/// bitwise value of every scalar entry — disagreement means the ranges
/// did not see the same push sequence and the stitch would be garbage.
pub fn stitch_snapshots(parts: &[MasterSnapshot]) -> anyhow::Result<MasterSnapshot> {
    anyhow::ensure!(!parts.is_empty(), "stitch of zero snapshots");
    let first = &parts[0];
    for (i, p) in parts.iter().enumerate().skip(1) {
        anyhow::ensure!(
            p.kind == first.kind,
            "range {i} snapshot is for {} but range 0 is for {}",
            p.kind.name(),
            first.kind.name()
        );
        anyhow::ensure!(
            p.master_step == first.master_step,
            "range {i} is at master step {} but range 0 is at {} — the ranges did not \
             apply the same pushes",
            p.master_step,
            first.master_step
        );
        anyhow::ensure!(
            p.last_eta.to_bits() == first.last_eta.to_bits(),
            "range {i} last η {} != range 0 last η {}",
            p.last_eta,
            first.last_eta
        );
        anyhow::ensure!(
            p.live == first.live,
            "range {i} slot liveness differs from range 0"
        );
        anyhow::ensure!(
            p.pulls.len() == first.pulls.len(),
            "range {i} has {} pull windows, range 0 has {}",
            p.pulls.len(),
            first.pulls.len()
        );
        for (w, (a, b)) in first.pulls.iter().zip(&p.pulls).enumerate() {
            anyhow::ensure!(
                a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.0 == y.0),
                "range {i} slot {w} pull window (depth/steps) differs from range 0"
            );
        }
        anyhow::ensure!(
            p.state.len() == first.state.len()
                && p.state.iter().zip(&first.state).all(|((a, _), (b, _))| a == b),
            "range {i} state-dict schema differs from range 0"
        );
    }
    let mut theta = Vec::new();
    for p in parts {
        theta.extend_from_slice(&p.theta);
    }
    let mut pulls = Vec::with_capacity(first.pulls.len());
    for w in 0..first.pulls.len() {
        let mut window = Vec::with_capacity(first.pulls[w].len());
        for d in 0..first.pulls[w].len() {
            let at = first.pulls[w][d].0;
            let mut params = Vec::new();
            for p in parts {
                params.extend_from_slice(&p.pulls[w][d].1);
            }
            window.push((at, params));
        }
        pulls.push(window);
    }
    let mut state = Vec::with_capacity(first.state.len());
    for (e, (name, v0)) in first.state.iter().enumerate() {
        let stitched = match v0 {
            StateVec::Coord(_) => {
                let mut out = Vec::new();
                for p in parts {
                    match &p.state[e].1 {
                        StateVec::Coord(c) => out.extend_from_slice(c),
                        _ => anyhow::bail!("state entry {name:?} changes variant across ranges"),
                    }
                }
                StateVec::Coord(out)
            }
            StateVec::PerWorker(rows0) => {
                let mut out: Vec<Vec<f32>> = vec![Vec::new(); rows0.len()];
                for p in parts {
                    match &p.state[e].1 {
                        StateVec::PerWorker(rows) => {
                            anyhow::ensure!(
                                rows.len() == rows0.len(),
                                "state entry {name:?} slot count differs across ranges"
                            );
                            for (dst, row) in out.iter_mut().zip(rows) {
                                dst.extend_from_slice(row);
                            }
                        }
                        _ => anyhow::bail!("state entry {name:?} changes variant across ranges"),
                    }
                }
                StateVec::PerWorker(out)
            }
            StateVec::Scalars(s0) => {
                for (i, p) in parts.iter().enumerate().skip(1) {
                    match &p.state[e].1 {
                        StateVec::Scalars(s) => anyhow::ensure!(
                            s.len() == s0.len()
                                && s.iter()
                                    .zip(s0)
                                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                            "scalar state entry {name:?} differs between range 0 and range \
                             {i} — the ranges did not apply the same push sequence"
                        ),
                        _ => anyhow::bail!("state entry {name:?} changes variant across ranges"),
                    }
                }
                StateVec::Scalars(s0.clone())
            }
        };
        state.push((name.clone(), stitched));
    }
    Ok(MasterSnapshot {
        kind: first.kind,
        master_step: first.master_step,
        last_eta: first.last_eta,
        theta,
        live: first.live.clone(),
        pulls,
        state,
    })
}

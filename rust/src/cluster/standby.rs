//! [`StandbyServer`] — hot-standby fail-over for one placement range:
//! `dana serve --standby-of ADDR`.
//!
//! A standby pairs with one primary.  It binds its main listener
//! immediately (so its address is stable from the start and can be
//! listed in every client's `--master`), but pre-takeover it answers
//! only control traffic: probes get the watched range's placement
//! header with `standby = 1`, worker hellos get a recoverable refusal.
//! A monitor thread polls the primary's handshake header and tails its
//! retention archives (`--keep-last` series on a shared filesystem),
//! tracking how many steps the newest archive trails the primary's
//! live count — the published `dana_standby_lag_steps`.
//!
//! When the primary misses `miss_budget` consecutive probes, the
//! standby **takes over**: it restores the newest archive into a fresh
//! backend, adopts the primary's exact shard range, and starts serving
//! real traffic *on the very listener it has held all along* — at
//! placement epoch `last_seen + 1`.  The epoch is the fence: clients
//! that saw the takeover refuse older epochs for this range, so a
//! resurrected stale primary cannot win its range back (see
//! [`crate::net::wire::Header::epoch`]).
//!
//! **Why acked pushes survive.**  The serving loop archives *before*
//! acknowledging (apply → periodic checkpoint → ack), so with
//! `--checkpoint-every 1` every acknowledged push is in the archive the
//! standby restores; only unacknowledged in-flight pushes can be lost,
//! and the cluster client counts exactly those in
//! [`crate::server::Master::pushes_lost`].  A coarser cadence widens
//! the window to at most `checkpoint_every - 1` acked steps, traded
//! deliberately for checkpoint bandwidth (DESIGN.md §13).

use crate::net::client::probe;
use crate::net::http::{ClusterStatus, SlotRow, StatusServer, StatusSnapshot, StatusSource};
use crate::net::server::wake;
use crate::net::wire::{self, Header, Msg, Role};
use crate::net::{checkpoint, codec::EncodingSet, retention, NetServer, Placement, ServeOptions};
use crate::optim::{AlgorithmKind, LrSchedule};
use crate::server::make_serving_master;
use crate::server::metrics::{AtomicHistogram, GAP_BOUNDS, LAG_BOUNDS};
use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Everything a standby needs to watch one primary and take its range
/// over.  The placement itself (shard range, epoch, algorithm, local k)
/// is never configured — it is learned from the primary's own
/// advertisement, so the pair cannot disagree.
pub struct StandbyConfig {
    /// Address to bind the (future) serving listener on.
    pub listen: String,
    /// The watched primary's serving address.
    pub primary: String,
    /// The primary's checkpoint base path (`--checkpoint` on the
    /// primary); its step-stamped retention archives are tailed from
    /// here, so primary and standby must share this filesystem.
    pub archive_base: PathBuf,
    /// LR schedule for the post-takeover server (must match the
    /// primary's — the schedule is config, not checkpointed state).
    pub schedule: LrSchedule,
    /// Backend build knobs for the post-takeover server (the shard
    /// count itself comes from the primary's advertised hosted range).
    pub threads: usize,
    /// Serve lock-striped after takeover — honored only when the taken
    /// range spans more than one shard, mirroring `dana serve`.
    pub striped: bool,
    /// Serving options for the post-takeover server.  `status_addr` is
    /// consumed by the standby itself (the endpoint is live from the
    /// start and survives the takeover); `placement` is overwritten.
    pub opts: ServeOptions,
    /// Primary poll cadence.
    pub poll: Duration,
    /// Consecutive missed probes that declare the primary dead.
    pub miss_budget: u32,
}

impl StandbyConfig {
    /// The standby config a manifest's `standbys[]` entry normalizes to
    /// (`dana serve --manifest M --server NAME` for a standby name).
    /// Everything pairing-sensitive — the primary's address, its archive
    /// base, its retention — comes from the primary's own `servers[]`
    /// entry, so the pair cannot disagree by construction.
    pub fn from_manifest(
        m: &crate::cluster::manifest::ClusterManifest,
        name: &str,
        run_dir: &std::path::Path,
    ) -> anyhow::Result<StandbyConfig> {
        use crate::cluster::manifest::ClusterManifest;
        let sb = m
            .standby(name)
            .ok_or_else(|| anyhow::anyhow!("cluster manifest has no standby named {name:?}"))?;
        let primary = m
            .server(&sb.of)
            .expect("manifest validation pairs every standby with a primary");
        let ck = primary
            .checkpoint
            .as_ref()
            .expect("manifest validation requires the watched primary to archive");
        let cfg = crate::config::TrainConfig::from_manifest(m)?;
        // the standby owns the status endpoint across the takeover; the
        // placement itself is learned from the primary, never configured
        let mut opts = ServeOptions::from_manifest(m, primary, run_dir);
        opts.status_addr = sb.status_addr.clone();
        opts.placement = Placement::default();
        Ok(StandbyConfig {
            listen: sb.listen.clone(),
            primary: format!("tcp://{}", primary.listen),
            archive_base: ClusterManifest::resolve_run_path(run_dir, &ck.path),
            schedule: LrSchedule::new(cfg.schedule.clone()),
            threads: if primary.serve_threads == 0 {
                crate::util::parallel::default_threads()
            } else {
                primary.serve_threads
            },
            striped: primary.serve_threads > 0,
            opts,
            poll: Duration::from_millis(sb.poll_ms.max(10)),
            miss_budget: sb.miss_budget.max(1),
        })
    }
}

/// What the last successful primary probe advertised.
#[derive(Debug, Clone, Copy)]
struct PrimaryView {
    kind: AlgorithmKind,
    k: usize,
    epoch: u64,
    shard_start: u32,
    shard_hosted: u32,
    total_shards: u32,
}

/// State shared between the monitor thread, the control-answer loop,
/// and the status listener.
struct Watch {
    stop: AtomicBool,
    /// Raised to make the answer loop hand its listener back (takeover
    /// or shutdown).
    handoff: AtomicBool,
    takeovers: AtomicU64,
    /// Step of the newest tailed archive (what a takeover restores to).
    archive_step: AtomicU64,
    /// The primary's live step count, from the last successful probe.
    primary_step: AtomicU64,
    seen_primary: AtomicBool,
    view: Mutex<Option<PrimaryView>>,
    /// θ restored from the newest tailed archive (at `archive_step`),
    /// for read-only pre-takeover serving: `PullParams`/`GetTheta`
    /// answered from the archive, stamped `standby = 1` so no client
    /// mistakes the reply for a live primary's (and none can push — the
    /// worker hello is still refused).
    theta: Mutex<Option<Arc<Vec<f32>>>>,
    /// Post-takeover: the serving NetServer's own status source; the
    /// standby's status listener delegates to it from then on.
    served: Mutex<Option<Arc<dyn StatusSource>>>,
}

impl Watch {
    fn view(&self) -> Option<PrimaryView> {
        *crate::util::sync::lock(&self.view)
    }

    fn theta(&self) -> Option<Arc<Vec<f32>>> {
        crate::util::sync::lock(&self.theta).clone()
    }

    fn served(&self) -> Option<Arc<dyn StatusSource>> {
        crate::util::sync::lock(&self.served).clone()
    }

    /// The header every pre-takeover control reply carries: the watched
    /// range at the last-seen epoch, `standby = 1`, and the step the
    /// newest archive would restore to.  Schedule fields are zero — a
    /// standby applies nothing.
    fn standby_header(&self, v: &PrimaryView) -> Header {
        Header {
            master_step: self.archive_step.load(Ordering::SeqCst),
            eta: 0.0,
            gamma: 0.0,
            lambda: 0.0,
            live_workers: 0,
            worker_slots: 0,
            pushes_dropped: 0,
            epoch: v.epoch,
            shard_start: v.shard_start,
            shard_hosted: v.shard_hosted,
            total_shards: v.total_shards,
            standby: 1,
        }
    }
}

/// `/metrics` + `/status` source for the standby: role/epoch/lag gauges
/// pre-takeover, a pure delegate to the serving server afterwards.
struct StandbySource {
    watch: Arc<Watch>,
    started: Instant,
}

impl StatusSource for StandbySource {
    fn metrics_snapshot(&self) -> StatusSnapshot {
        if let Some(src) = self.watch.served() {
            return src.metrics_snapshot();
        }
        let v = self.watch.view();
        let archive = self.watch.archive_step.load(Ordering::SeqCst);
        let lag = self
            .watch
            .seen_primary
            .load(Ordering::SeqCst)
            .then(|| self.watch.primary_step.load(Ordering::SeqCst).saturating_sub(archive));
        StatusSnapshot {
            uptime_secs: self.started.elapsed().as_secs_f64(),
            master_step: archive,
            live_workers: 0,
            total_slots: 0,
            pushes_total: 0,
            pushes_dropped: 0,
            pushes_per_sec: 0.0,
            bytes_tx: 0,
            bytes_rx: 0,
            bytes_per_second: 0.0,
            kernels: crate::math::active_kernels().name(),
            gap: AtomicHistogram::new(GAP_BOUNDS).snapshot(),
            lag: AtomicHistogram::new(LAG_BOUNDS).snapshot(),
            shard_gates: Vec::new(),
            checkpoint: None,
            cluster: ClusterStatus {
                standby: true,
                epoch: v.map(|v| v.epoch).unwrap_or(0),
                takeovers: self.watch.takeovers.load(Ordering::SeqCst),
                shard_start: v.map(|v| v.shard_start).unwrap_or(0),
                shard_hosted: v.map(|v| v.shard_hosted).unwrap_or(0),
                total_shards: v.map(|v| v.total_shards).unwrap_or(0),
                standby_lag: lag,
            },
            slots: Vec::new(),
        }
    }

    fn slot_rows(&self) -> Vec<SlotRow> {
        self.watch.served().map(|s| s.slot_rows()).unwrap_or_default()
    }
}

/// See the module docs.  [`StandbyServer::start`] returns immediately;
/// [`StandbyServer::wait`] blocks through watch, takeover, and serving.
pub struct StandbyServer {
    addr: SocketAddr,
    status: Option<StatusServer>,
    watch: Arc<Watch>,
    monitor: Option<JoinHandle<anyhow::Result<Option<NetServer>>>>,
    net: Option<NetServer>,
}

impl StandbyServer {
    pub fn start(mut cfg: StandbyConfig) -> anyhow::Result<StandbyServer> {
        let listener = TcpListener::bind(&cfg.listen)
            .map_err(|e| anyhow::anyhow!("bind {}: {e}", cfg.listen))?;
        let addr = listener.local_addr()?;
        let watch = Arc::new(Watch {
            stop: AtomicBool::new(false),
            handoff: AtomicBool::new(false),
            takeovers: AtomicU64::new(0),
            archive_step: AtomicU64::new(0),
            primary_step: AtomicU64::new(0),
            seen_primary: AtomicBool::new(false),
            view: Mutex::new(None),
            theta: Mutex::new(None),
            served: Mutex::new(None),
        });
        // the standby owns its status endpoint across the takeover; the
        // post-takeover server must not try to bind a second one
        let status = match cfg.opts.status_addr.take() {
            Some(sa) => Some(StatusServer::start(
                &sa,
                Arc::new(StandbySource { watch: Arc::clone(&watch), started: Instant::now() }),
            )?),
            None => None,
        };
        let answer = {
            let watch = Arc::clone(&watch);
            std::thread::Builder::new()
                .name("dana-standby-answer".into())
                .spawn(move || answer_loop(listener, &watch))?
        };
        let monitor = {
            let watch = Arc::clone(&watch);
            std::thread::Builder::new()
                .name("dana-standby".into())
                .spawn(move || monitor_loop(cfg, addr, &watch, answer))?
        };
        Ok(StandbyServer { addr, status, watch, monitor: Some(monitor), net: None })
    }

    /// The main (future serving) listener address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// `tcp://host:port`, ready for a `--master` list.
    pub fn url(&self) -> String {
        format!("tcp://{}", self.addr)
    }

    pub fn status_addr(&self) -> Option<SocketAddr> {
        self.status.as_ref().map(|s| s.addr())
    }

    /// Takeovers performed (0 while still watching).
    pub fn takeovers(&self) -> u64 {
        self.watch.takeovers.load(Ordering::SeqCst)
    }

    fn join_monitor(&mut self) {
        if let Some(h) = self.monitor.take() {
            match h.join() {
                Ok(Ok(net)) => self.net = net,
                Ok(Err(e)) => eprintln!("dana standby: {e:#}"),
                Err(_) => eprintln!("dana standby: monitor thread panicked"),
            }
        }
    }

    /// Block through the whole lifecycle: watching, takeover, and — if
    /// one happened — serving, until the served server winds down.
    pub fn wait(&mut self) {
        self.join_monitor();
        if let Some(net) = self.net.as_mut() {
            net.wait();
        }
        if let Some(mut s) = self.status.take() {
            s.stop();
        }
    }

    /// Stop watching (and, post-takeover, stop serving).  Idempotent.
    pub fn stop(&mut self) {
        self.watch.stop.store(true, Ordering::SeqCst);
        self.watch.handoff.store(true, Ordering::SeqCst);
        wake(self.addr);
        self.join_monitor();
        if let Some(net) = self.net.as_mut() {
            net.stop();
        }
        if let Some(mut s) = self.status.take() {
            s.stop();
        }
    }
}

impl Drop for StandbyServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Pre-takeover accept loop: answer control traffic with standby
/// headers, refuse workers recoverably, and hand the listener back the
/// moment `handoff` is raised (a [`wake`] connection unblocks accept).
fn answer_loop(listener: TcpListener, watch: &Arc<Watch>) -> TcpListener {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if watch.handoff.load(Ordering::SeqCst) || watch.stop.load(Ordering::SeqCst) {
                    return listener;
                }
                let watch = Arc::clone(watch);
                let _ = std::thread::Builder::new()
                    .name("dana-standby-conn".into())
                    .spawn(move || answer_conn(stream, &watch));
            }
            Err(_) => {
                if watch.handoff.load(Ordering::SeqCst) || watch.stop.load(Ordering::SeqCst) {
                    return listener;
                }
            }
        }
    }
}

fn answer_conn(stream: TcpStream, watch: &Watch) {
    stream.set_nodelay(true).ok();
    let Ok(read_half) = stream.try_clone() else { return };
    let mut r = BufReader::new(read_half);
    let mut w = BufWriter::new(stream);
    loop {
        let Ok(msg) = wire::read_frame(&mut r) else { return };
        let reply = match (msg, watch.view()) {
            (Msg::Hello { role: Role::Control, .. }, Some(v)) => Msg::HelloAck {
                slot: u64::MAX,
                gen: 0,
                kind: v.kind,
                k: v.k as u64,
                shards: v.shard_hosted,
                pipeline: 0,
                encodings: EncodingSet::ALL.0,
                header: watch.standby_header(&v),
            },
            (Msg::Hello { role: Role::Control, .. }, None) => Msg::Error {
                recoverable: true,
                detail: "standby has not observed its primary yet".into(),
            },
            (Msg::Hello { .. }, _) => Msg::Error {
                recoverable: true,
                detail: "standby: not serving worker traffic (no takeover yet)".into(),
            },
            (Msg::Status, Some(v)) => Msg::Ack { header: watch.standby_header(&v) },
            // read-only θ from the restored archive (standby = 1 in the
            // header: placement resolution still skips this endpoint,
            // and there is no slot to push through)
            (Msg::PullParams, Some(v)) => match watch.theta() {
                Some(theta) if theta.len() == v.k => {
                    Msg::Params { header: watch.standby_header(&v), params: (*theta).clone() }
                }
                _ => Msg::Error {
                    recoverable: true,
                    detail: "standby: no archive restored yet (read-only θ unavailable)"
                        .into(),
                },
            },
            (Msg::GetTheta, Some(v)) => match watch.theta() {
                Some(theta) if theta.len() == v.k => {
                    Msg::Theta { header: watch.standby_header(&v), theta: (*theta).clone() }
                }
                _ => Msg::Error {
                    recoverable: true,
                    detail: "standby: no archive restored yet (read-only θ unavailable)"
                        .into(),
                },
            },
            // in-band graceful shutdown, same control frame the serving
            // path honors — the cluster supervisor winds a watching
            // standby down without a signal race
            (Msg::Shutdown, v) => {
                watch.stop.store(true, Ordering::SeqCst);
                let header = v.map(|v| watch.standby_header(&v)).unwrap_or_default();
                let _ = wire::write_frame(&mut w, &Msg::Ack { header });
                return;
            }
            _ => Msg::Error {
                recoverable: true,
                detail: "standby: not serving (watching its primary)".into(),
            },
        };
        if wire::write_frame(&mut w, &reply).is_err() {
            return;
        }
    }
}

fn monitor_loop(
    cfg: StandbyConfig,
    addr: SocketAddr,
    watch: &Arc<Watch>,
    answer: JoinHandle<TcpListener>,
) -> anyhow::Result<Option<NetServer>> {
    let reclaim = |watch: &Arc<Watch>| -> anyhow::Result<TcpListener> {
        watch.handoff.store(true, Ordering::SeqCst);
        wake(addr);
        answer.join().map_err(|_| anyhow::anyhow!("standby answer loop panicked"))
    };
    let mut misses = 0u32;
    // step of the archive θ currently restored for read-only serving
    let mut theta_step: Option<u64> = None;
    loop {
        if watch.stop.load(Ordering::SeqCst) {
            let _ = reclaim(watch);
            return Ok(None);
        }
        match probe(&cfg.primary) {
            Ok(info) => {
                let h = info.header;
                if h.standby == 0 {
                    misses = 0;
                    let v = PrimaryView {
                        kind: info.kind,
                        k: info.k,
                        epoch: h.epoch,
                        shard_start: h.shard_start,
                        shard_hosted: h.shard_hosted,
                        total_shards: h.total_shards,
                    };
                    *crate::util::sync::lock(&watch.view) = Some(v);
                    watch.primary_step.store(h.master_step, Ordering::SeqCst);
                    watch.seen_primary.store(true, Ordering::SeqCst);
                }
            }
            Err(_) => misses += 1,
        }
        if let Ok(archives) = retention::list_archives(&cfg.archive_base) {
            if let Some(newest) = archives.iter().max_by_key(|a| a.step) {
                watch.archive_step.store(newest.step, Ordering::SeqCst);
                // restore θ for read-only pre-takeover serving whenever a
                // newer archive lands (a failed read — e.g. the archive
                // GC'd between list and open — just retries next poll)
                if theta_step != Some(newest.step) {
                    if let (Ok(snap), Some(v)) = (checkpoint::read_snapshot(&newest.path), watch.view())
                    {
                        if snap.validate(v.kind, v.k).is_ok() {
                            *crate::util::sync::lock(&watch.theta) =
                                Some(Arc::new(snap.theta));
                            theta_step = Some(newest.step);
                        }
                    }
                }
            }
        }
        if misses >= cfg.miss_budget.max(1) {
            let Some(view) = watch.view() else {
                // never observed the primary: nothing to take over
                let _ = reclaim(watch);
                anyhow::bail!(
                    "primary {} unreachable and never observed — no range to take over",
                    cfg.primary
                );
            };
            let listener = reclaim(watch)?;
            let net = take_over(&cfg, view, listener, watch)?;
            *crate::util::sync::lock(&watch.served) = Some(net.status_source());
            return Ok(Some(net));
        }
        std::thread::sleep(cfg.poll);
    }
}

/// Restore the newest archive and start serving the watched range on
/// the standby's own listener, one epoch past the dead primary's.
fn take_over(
    cfg: &StandbyConfig,
    view: PrimaryView,
    listener: TcpListener,
    watch: &Arc<Watch>,
) -> anyhow::Result<NetServer> {
    let archives = retention::list_archives(&cfg.archive_base)?;
    let newest = archives
        .iter()
        .max_by_key(|a| a.step)
        .ok_or_else(|| {
            anyhow::anyhow!(
                "takeover impossible: no archives under {} (primary must run with \
                 --checkpoint + --keep-last)",
                cfg.archive_base.display()
            )
        })?;
    let snap = checkpoint::read_snapshot(&newest.path)?;
    snap.validate(view.kind, view.k)?;
    // local backend shards == hosted placement shards: the global→local
    // shard-id mapping (and the sliced frame layout) depends on it
    let mut master = make_serving_master(
        view.kind,
        &snap.theta,
        cfg.schedule.clone(),
        0,
        view.shard_hosted as usize,
        cfg.threads,
        cfg.striped && view.shard_hosted > 1,
    );
    master.restore(&snap)?;
    let epoch = view.epoch + 1;
    let takeovers = watch.takeovers.fetch_add(1, Ordering::SeqCst) + 1;
    let mut opts = cfg.opts.clone();
    opts.placement = Placement {
        shard_start: view.shard_start,
        total_shards: view.total_shards,
        epoch,
        takeovers,
    };
    let net = NetServer::start_serving_on(listener, master, opts)?;
    eprintln!(
        "dana standby: took over shards {}..{} at epoch {epoch} (restored step {} from \
         {}; primary {} last seen at step {})",
        view.shard_start,
        view.shard_start + view.shard_hosted,
        snap.master_step,
        newest.path.display(),
        cfg.primary,
        watch.primary_step.load(Ordering::SeqCst),
    );
    Ok(net)
}

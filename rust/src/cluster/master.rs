//! [`ClusterMaster`] — the fan-out [`Master`]: one training driver
//! against a multi-server placement.
//!
//! Each placement group (one server hosting a contiguous shard range,
//! see [`super::placement`]) gets its own [`RemoteMaster`]; every
//! driver-facing operation fans its coordinate slices across all groups
//! in **one overlapped round trip per server** — the split-phase
//! begin/finish surface writes every group's request frame before the
//! first reply is read, extending PR 5's deferred-ack machinery from
//! one connection to the whole placement.  Membership and pipeline
//! configuration fan to every group (slot indices stay aligned because
//! every group sees the identical join/leave sequence).
//!
//! **Fail-over.**  Every successful reply carries the server's
//! placement epoch; the cluster records the highest epoch seen per
//! range and treats any lower one as a fenced zombie (a stale primary
//! resurrected after its standby took the range over).  When a group's
//! server fails — transport loss, or an epoch fence — the cluster
//! probes the full endpoint list for a live primary claiming exactly
//! that shard range at an epoch no older than the recorded one, and
//! re-attaches the group's workers to it.  Pulls are retried against
//! the claimant; pushes are **never** retried across a fail-over (the
//! dead primary may have applied-and-archived the push before dying, so
//! a retry could double-apply it) — they are counted in
//! [`Master::pushes_lost`] instead, exactly like the deferred acks a
//! reconnect abandons.
//!
//! **YellowFin.**  Rules whose apply needs whole-vector reductions
//! ([`crate::optim::Algorithm::needs_apply_stats`]) push in two phases:
//! stage the update on every group (read-only; returns each range's
//! additive [`ApplyStats`] partials), sum the partials — exact, because
//! every field is a plain coordinate sum — then commit everywhere under
//! the global sums.  Both phases are overlapped across groups, so the
//! split costs two round trips instead of one.  Staging always moves
//! raw f32 payloads and ignores `--pipeline-depth` (the merge is a
//! synchronization point by construction).

use super::placement::{find_claimant, PlacementMap};
use crate::net::client::{fetch_theta_once, is_rejection};
use crate::net::{Encoding, RemoteMaster};
use crate::optim::{
    make_algorithm, Algorithm, AlgorithmKind, ApplyStats, LeavePolicy, Step, WorkerState,
};
use crate::server::metrics::MetricsRecorder;
use crate::server::{Master, MasterSnapshot};
use std::ops::Range;
use std::time::Duration;

struct Group {
    rm: RemoteMaster,
    shards: Range<u32>,
    coords: Range<usize>,
    /// Highest placement epoch observed for this range — the fence.
    epoch: u64,
}

/// See the module docs.  Construct with [`ClusterMaster::connect`]
/// (which [`crate::net::master_for`] does for a comma-separated
/// `--master` list).
pub struct ClusterMaster {
    /// The endpoint list as given — primaries *and* standbys; the
    /// fail-over search probes all of them.
    endpoints: Vec<String>,
    kind: AlgorithmKind,
    k: usize,
    total_shards: u32,
    groups: Vec<Group>,
    pipeline: usize,
    /// Whole-vector-reduction rules (YellowFin) push via the two-phase
    /// stage/commit path.
    needs_stats: bool,
    /// Per-shard parameter frames requested (`--shard-frames`): parameter
    /// traffic goes through each group's own sliced path, sequentially.
    shard_frames: bool,
    local_alg: Box<dyn Algorithm>,
    metrics: MetricsRecorder,
    /// Pushes lost at the cluster layer: in flight to a group whose
    /// server failed (never retried — double-apply hazard).  The groups'
    /// own abandoned deferred acks are counted separately and summed in
    /// [`Master::pushes_lost`].
    lost: u64,
    /// Fail-over probe budget: attempts × delay bounds how long a
    /// takeover may take end to end (standby poll + restore + serve).
    pub failover_attempts: u32,
    pub failover_delay: Duration,
}

impl ClusterMaster {
    /// Resolve the placement advertised by `endpoints` (see
    /// [`PlacementMap::resolve`]), validate it against this run's
    /// expected algorithm/parameter count, and join `n_workers` worker
    /// slots on every group.
    pub fn connect(
        endpoints: &[String],
        n_workers: usize,
        expect: Option<(AlgorithmKind, usize)>,
        encoding: Encoding,
        shard_frames: bool,
    ) -> anyhow::Result<ClusterMaster> {
        let map = PlacementMap::resolve(endpoints)?;
        if let Some((want_kind, want_k)) = expect {
            anyhow::ensure!(
                map.kind == want_kind,
                "placement runs {}, this run is configured for {}",
                map.kind.name(),
                want_kind.name()
            );
            anyhow::ensure!(
                map.k == want_k,
                "placement hosts k={} in total, this run's model has k={}",
                map.k,
                want_k
            );
        }
        let mut groups = Vec::with_capacity(map.groups.len());
        for g in &map.groups {
            let mut rm = RemoteMaster::connect_with(
                &g.endpoint,
                n_workers,
                Some((map.kind, g.k_local)),
                encoding,
            )?;
            rm.set_shard_frames(shard_frames);
            // fail fast per group: the cluster layer owns endpoint
            // re-resolution, so a group's internal same-address retries
            // only need to ride out a socket blip, not a takeover
            rm.reconnect_attempts = 3;
            rm.reconnect_delay = Duration::from_millis(200);
            let epoch = g.epoch.max(rm.last_header().epoch);
            groups.push(Group { rm, shards: g.shards.clone(), coords: g.coords.clone(), epoch });
        }
        let local_alg = make_algorithm(map.kind, &vec![0.0f32; map.k], 0);
        eprintln!(
            "net: cluster placement resolved: {} group(s) over {} shard(s), k={} ({})",
            groups.len(),
            map.total_shards,
            map.k,
            groups
                .iter()
                .map(|g| format!("{}..{}@{}", g.shards.start, g.shards.end, g.rm.addr()))
                .collect::<Vec<_>>()
                .join(", ")
        );
        Ok(ClusterMaster {
            endpoints: endpoints.to_vec(),
            kind: map.kind,
            k: map.k,
            total_shards: map.total_shards,
            needs_stats: local_alg.needs_apply_stats(),
            groups,
            pipeline: 0,
            shard_frames,
            local_alg,
            metrics: MetricsRecorder::default(),
            lost: 0,
            failover_attempts: 60,
            failover_delay: Duration::from_millis(500),
        })
    }

    /// Number of placement groups (servers) this master fans out over.
    pub fn group_count(&self) -> usize {
        self.groups.len()
    }

    /// Check the epoch fence after a successful reply from group `g`:
    /// a reply carrying an *older* epoch than the recorded one comes
    /// from a fenced zombie (the group's connection quietly landed on a
    /// stale primary) and is treated as a group failure.
    fn check_epoch(&mut self, g: usize) -> anyhow::Result<()> {
        let h = self.groups[g].rm.last_header();
        anyhow::ensure!(
            h.epoch >= self.groups[g].epoch,
            "group {g} ({}) replied at epoch {} but epoch {} has been observed for \
             shards {}..{} — stale primary fenced",
            self.groups[g].rm.addr(),
            h.epoch,
            self.groups[g].epoch,
            self.groups[g].shards.start,
            self.groups[g].shards.end
        );
        self.groups[g].epoch = h.epoch;
        Ok(())
    }

    /// Fail group `g` over: probe the endpoint list (plus the group's
    /// current address) for a live primary claiming exactly this shard
    /// range at `>=` the recorded epoch, and re-attach the group's
    /// workers to it.  Deferred pushes owed on the old connections are
    /// counted into the group's abandoned tally by the reconnect.
    fn failover(&mut self, g: usize) -> anyhow::Result<()> {
        let shards = self.groups[g].shards.clone();
        let k_local = self.groups[g].coords.len();
        let min_epoch = self.groups[g].epoch;
        let mut probed: Vec<String> = self.endpoints.clone();
        let current = self.groups[g].rm.addr().to_string();
        if !probed.iter().any(|e| e == &current) {
            probed.push(current);
        }
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..self.failover_attempts.max(1) {
            if attempt > 0 {
                std::thread::sleep(self.failover_delay);
            }
            let Some((addr, epoch)) =
                find_claimant(&probed, &shards, self.total_shards, self.kind, k_local, min_epoch)
            else {
                continue;
            };
            match self.groups[g].rm.reconnect_to(&addr) {
                Ok(()) => {
                    self.groups[g].epoch = epoch.max(self.groups[g].rm.last_header().epoch);
                    eprintln!(
                        "net: cluster group {g} (shards {}..{}) failed over to {addr} at \
                         epoch {}",
                        shards.start, shards.end, self.groups[g].epoch
                    );
                    return Ok(());
                }
                Err(e) => last = Some(e),
            }
        }
        Err(match last {
            Some(e) => e.context(format!(
                "no server claims shards {}..{} at epoch >= {min_epoch}",
                shards.start, shards.end
            )),
            None => anyhow::anyhow!(
                "no server claims shards {}..{} at epoch >= {min_epoch} after {} probe \
                 rounds",
                shards.start,
                shards.end,
                self.failover_attempts.max(1)
            ),
        })
    }

    /// The sequential per-group fallback path is required whenever a
    /// group's parameter traffic is transformed below the fan-out layer
    /// (sliced shard frames, or a granted top-k compressor with its
    /// error-feedback residuals).
    fn sequential(&self) -> bool {
        self.shard_frames
            || self
                .groups
                .iter()
                .any(|g| matches!(g.rm.granted_encoding(), Encoding::TopK { .. }))
    }

    /// Overlapped fan-out pull: begin on every group, then finish each
    /// into its coordinate slice.  Failed groups fail over and re-pull
    /// once (a pull is safe to retry: re-pulling only refreshes the
    /// slot's window entry).
    fn pull_fan(&mut self, worker: usize, out: &mut [f32]) -> anyhow::Result<()> {
        anyhow::ensure!(out.len() == self.k, "pull buffer {} != k={}", out.len(), self.k);
        if self.sequential() {
            for g in 0..self.groups.len() {
                let r = self.groups[g].coords.clone();
                // the group's own sliced/compressed path; its internal
                // retry budget applies, hard failure propagates as the
                // usual pull panic
                let params = self.groups[g].rm.pull_params(worker);
                out[r].copy_from_slice(&params);
                self.check_epoch(g)?;
            }
            return Ok(());
        }
        let n = self.groups.len();
        let mut begun = vec![false; n];
        let mut failed: Vec<usize> = Vec::new();
        for g in 0..n {
            match self.groups[g].rm.begin_pull(worker) {
                Ok(()) => begun[g] = true,
                Err(_) => failed.push(g),
            }
        }
        for g in 0..n {
            if !begun[g] {
                continue;
            }
            let r = self.groups[g].coords.clone();
            let ok = self.groups[g].rm.finish_pull_into(worker, &mut out[r]).is_ok()
                && self.check_epoch(g).is_ok();
            if !ok {
                failed.push(g);
            }
        }
        for g in failed {
            self.failover(g)?;
            let r = self.groups[g].coords.clone();
            self.groups[g].rm.begin_pull(worker)?;
            self.groups[g].rm.finish_pull_into(worker, &mut out[r])?;
            self.check_epoch(g)?;
        }
        Ok(())
    }

    /// Overlapped fan-out push (elementwise rules, depth 0): begin on
    /// every group, then collect each ack.  A failed group fails over
    /// but the push is NOT retried there — it may already be applied
    /// and archived on the dead primary, so a retry could double-apply;
    /// it is counted lost instead.
    fn push_fan(&mut self, worker: usize, msg: &[f32]) -> anyhow::Result<Step> {
        let n = self.groups.len();
        let mut begun = vec![false; n];
        let mut failed: Vec<usize> = Vec::new();
        let mut step: Option<Step> = None;
        for g in 0..n {
            let r = self.groups[g].coords.clone();
            match self.groups[g].rm.begin_push(worker, &msg[r]) {
                Ok(()) => begun[g] = true,
                Err(_) => failed.push(g),
            }
        }
        for g in 0..n {
            if !begun[g] {
                continue;
            }
            match self.groups[g].rm.finish_push(worker) {
                Ok(s) => {
                    if self.check_epoch(g).is_ok() {
                        // group 0's schedule is authoritative (all groups
                        // run the same one in lock-step)
                        if step.is_none() || g == 0 {
                            step = Some(s);
                        }
                    } else {
                        failed.push(g);
                    }
                }
                Err(_) => failed.push(g),
            }
        }
        for g in failed {
            self.lost += 1;
            self.failover(g)?;
        }
        step.ok_or_else(|| anyhow::anyhow!("push acknowledged by no placement group"))
    }

    /// Two-phase fan-out push for whole-vector-reduction rules: stage
    /// everywhere (read-only — safe to retry across a fail-over), sum
    /// the additive partials, commit everywhere under the global sums.
    fn push_two_phase(&mut self, worker: usize, msg: &[f32]) -> anyhow::Result<Step> {
        let n = self.groups.len();
        // ---- phase 1: stage (overlapped; retried once after fail-over)
        let mut stats = ApplyStats::default();
        for attempt in 0..2 {
            let mut begun = vec![false; n];
            let mut failed: Vec<usize> = Vec::new();
            stats = ApplyStats::default();
            for g in 0..n {
                let r = self.groups[g].coords.clone();
                match self.groups[g].rm.begin_push_stage(worker, &msg[r]) {
                    Ok(()) => begun[g] = true,
                    Err(_) => failed.push(g),
                }
            }
            for g in 0..n {
                if !begun[g] {
                    continue;
                }
                match self.groups[g].rm.finish_push_stage(worker) {
                    Ok(part) => {
                        if self.check_epoch(g).is_ok() {
                            stats.merge(&part);
                        } else {
                            failed.push(g);
                        }
                    }
                    Err(_) => failed.push(g),
                }
            }
            if failed.is_empty() {
                break;
            }
            anyhow::ensure!(attempt == 0, "staged push failed on {} group(s) twice", failed.len());
            for g in failed {
                self.failover(g)?;
            }
        }
        // ---- phase 2: commit (overlapped; never retried — see push_fan)
        let mut begun = vec![false; n];
        let mut failed: Vec<usize> = Vec::new();
        let mut step: Option<Step> = None;
        for g in 0..n {
            let r = self.groups[g].coords.clone();
            match self.groups[g].rm.begin_push_commit(worker, &stats, &msg[r]) {
                Ok(()) => begun[g] = true,
                Err(_) => failed.push(g),
            }
        }
        for g in 0..n {
            if !begun[g] {
                continue;
            }
            match self.groups[g].rm.finish_push(worker) {
                Ok(s) => {
                    if self.check_epoch(g).is_ok() {
                        if step.is_none() || g == 0 {
                            step = Some(s);
                        }
                    } else {
                        failed.push(g);
                    }
                }
                Err(_) => failed.push(g),
            }
        }
        for g in failed {
            self.lost += 1;
            self.failover(g)?;
        }
        step.ok_or_else(|| anyhow::anyhow!("committed push acknowledged by no placement group"))
    }

    /// Deferred fan-out push (depth > 0, or the sequential fallback):
    /// each group's own [`Master::push_update`] handles deferral,
    /// negotiated encodings, and shard frames for its slice.
    fn push_per_group(&mut self, worker: usize, msg: &[f32]) -> anyhow::Result<Step> {
        let mut step: Option<Step> = None;
        for g in 0..self.groups.len() {
            let r = self.groups[g].coords.clone();
            match self.groups[g].rm.push_update(worker, &msg[r]) {
                Ok(s) => {
                    self.check_epoch(g)?;
                    if step.is_none() || g == 0 {
                        step = Some(s);
                    }
                }
                // a server-side rejection (stale generation) must surface
                // to the driver exactly like the single-server path
                Err(e) if is_rejection(&e) => return Err(e),
                Err(_) => {
                    self.lost += 1;
                    self.failover(g)?;
                    if step.is_none() {
                        step = Some(self.groups[g].rm.step_now());
                    }
                }
            }
        }
        step.ok_or_else(|| anyhow::anyhow!("push accepted by no placement group"))
    }
}

impl Master for ClusterMaster {
    fn algo_kind(&self) -> AlgorithmKind {
        self.kind
    }

    fn workers(&self) -> usize {
        self.groups[0].rm.workers()
    }

    fn live_workers(&self) -> usize {
        self.groups[0].rm.live_workers()
    }

    fn is_live(&self, worker: usize) -> bool {
        self.groups[0].rm.is_live(worker)
    }

    fn add_worker(&mut self) -> usize {
        // membership fans to every group; the claim-slot rule is
        // deterministic, so identical join/leave sequences keep local
        // indices aligned across groups
        let mut local: Option<usize> = None;
        for g in 0..self.groups.len() {
            let idx = self.groups[g].rm.add_worker();
            match local {
                None => local = Some(idx),
                Some(first) => assert_eq!(
                    idx, first,
                    "placement groups disagree on the joined worker's slot ({idx} vs \
                     {first}) — membership fan-out diverged"
                ),
            }
        }
        local.expect("placement has at least one group")
    }

    fn remove_worker(&mut self, worker: usize, policy: LeavePolicy) -> anyhow::Result<()> {
        // attempt every group even after a failure, so the membership
        // sequences (and thus slot alignment) cannot diverge
        let mut first_err: Option<anyhow::Error> = None;
        for g in 0..self.groups.len() {
            if let Err(e) = self.groups[g].rm.remove_worker(worker, policy) {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    fn steps_done(&self) -> u64 {
        self.groups[0].rm.steps_done()
    }

    fn param_len(&self) -> usize {
        self.k
    }

    fn step_now(&self) -> Step {
        self.groups[0].rm.step_now()
    }

    fn theta_vec(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.k];
        for (g, group) in self.groups.iter().enumerate() {
            let r = group.coords.clone();
            match group.rm.try_theta() {
                Ok(theta) => out[r].copy_from_slice(&theta),
                Err(e) => {
                    // &self: cannot fail the group over here.  Read the
                    // slice from whoever claims the range now; the next
                    // fallible &mut operation performs the real fail-over.
                    let (addr, _) = find_claimant(
                        &self.endpoints,
                        &group.shards,
                        self.total_shards,
                        self.kind,
                        r.len(),
                        group.epoch,
                    )
                    .unwrap_or_else(|| {
                        panic!(
                            "theta read: group {g} ({}) failed ({e:#}) and no server \
                             claims shards {}..{}",
                            group.rm.addr(),
                            group.shards.start,
                            group.shards.end
                        )
                    });
                    let (_, theta) = fetch_theta_once(&addr).unwrap_or_else(|e2| {
                        panic!("theta read from claimant {addr} failed: {e2:#}")
                    });
                    assert_eq!(theta.len(), r.len(), "claimant {addr} sent a wrong-size slice");
                    out[r].copy_from_slice(&theta);
                }
            }
        }
        out
    }

    fn pull_params(&mut self, worker: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.k];
        self.pull_fan(worker, &mut out)
            .unwrap_or_else(|e| panic!("cluster pull for worker {worker} failed: {e:#}"));
        out
    }

    fn pull_into(&mut self, worker: usize, out: &mut [f32]) {
        self.pull_fan(worker, out)
            .unwrap_or_else(|e| panic!("cluster pull for worker {worker} failed: {e:#}"));
    }

    fn push_update(&mut self, worker: usize, msg: &[f32]) -> anyhow::Result<Step> {
        anyhow::ensure!(msg.len() == self.k, "push of {} values, k={}", msg.len(), self.k);
        if self.needs_stats {
            return self.push_two_phase(worker, msg);
        }
        if self.pipeline > 0 || self.sequential() {
            return self.push_per_group(worker, msg);
        }
        self.push_fan(worker, msg)
    }

    fn set_pipeline_depth(&mut self, depth: usize) {
        self.pipeline = depth;
        for g in &mut self.groups {
            g.rm.set_pipeline_depth(depth);
        }
        if depth > 0 && self.needs_stats {
            eprintln!(
                "net: cluster: {} pushes via the two-phase stage/commit path, which is a \
                 synchronization point — --pipeline-depth {depth} does not overlap its \
                 round trips",
                self.kind.name()
            );
        }
    }

    fn drain_inflight(&mut self) -> anyhow::Result<()> {
        for g in 0..self.groups.len() {
            match self.groups[g].rm.drain_inflight() {
                Ok(()) => self.check_epoch(g)?,
                Err(e) if is_rejection(&e) => return Err(e),
                // the owed acks were already counted abandoned by the
                // group's reconnect path; just re-home the group
                Err(_) => self.failover(g)?,
            }
        }
        Ok(())
    }

    fn make_worker_state(&self) -> WorkerState {
        self.local_alg.make_worker_state()
    }

    fn worker_transform(&self, ws: &mut WorkerState, grad: &mut [f32], s: Step) {
        self.local_alg.worker_message(ws, grad, s);
    }

    fn metrics(&self) -> &MetricsRecorder {
        &self.metrics
    }

    fn metrics_mut(&mut self) -> &mut MetricsRecorder {
        &mut self.metrics
    }

    fn pushes_lost(&self) -> u64 {
        self.lost + self.groups.iter().map(|g| g.rm.abandoned_pushes()).sum::<u64>()
    }

    fn placement_groups(&mut self) -> Vec<(String, u64)> {
        let mut rows = Vec::with_capacity(self.groups.len());
        for g in &mut self.groups {
            let step = match g.rm.refresh_status() {
                Ok(h) => h.master_step,
                Err(_) => g.rm.last_header().master_step,
            };
            rows.push((g.rm.addr().to_string(), step));
        }
        rows
    }

    fn slot_stats(&self, worker: usize) -> (usize, u64) {
        self.groups[0].rm.slot_stats(worker)
    }

    fn snapshot(&self) -> anyhow::Result<MasterSnapshot> {
        anyhow::bail!(
            "a cluster master checkpoints server-side: each group archives its own \
             range (`dana serve --checkpoint`); stitch the per-range archives with \
             cluster::snapshot::stitch_snapshots"
        )
    }

    fn restore(&mut self, _snap: &MasterSnapshot) -> anyhow::Result<()> {
        anyhow::bail!(
            "a cluster master restores server-side: slice the full snapshot with \
             cluster::snapshot::slice_snapshot and `dana serve --resume` each range"
        )
    }
}

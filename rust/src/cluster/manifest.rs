//! [`ClusterManifest`] — one fail-closed `cluster.json` describing a
//! whole topology: server shard placement, hot-standby pairings, the
//! worker fleet, checkpoint/retention, and sha256-pinned artifact
//! references.
//!
//! The manifest is parsed with the same discipline as the wire decoder
//! (DESIGN.md §8): **everything rejects**.  Unknown fields name the
//! offending key, shard ranges must tile the global shard space exactly
//! (the very [`validate_tiling`](super::placement::validate_tiling)
//! rules live resolution applies — a manifest that parses is a topology
//! that resolves), standbys must name an existing primary that archives
//! checkpoints, listen/status addresses must be unique, and artifact
//! checksums must be 64 hex chars that match the file's actual SHA-256.
//! Validation happens entirely at parse time, *before any process
//! spawns* (`dana cluster --verify-only` is exactly parse + checksum
//! verification and nothing else).
//!
//! Everything a `dana serve`/`dana train` flag soup could express is a
//! field here; the `from_manifest` constructors on
//! [`crate::config::ServeSpec`], [`crate::config::TrainConfig`], and
//! [`super::StandbyConfig`] normalize both spellings into the same
//! structs, making flags the single-process special case.  See
//! DESIGN.md §14.

use crate::cluster::placement::validate_tiling;
use crate::net::{Encoding, EncodingSet};
use crate::optim::{AlgorithmKind, LeavePolicy};
use crate::sim::ChurnSchedule;
use crate::util::json::Json;
use crate::util::sha256::sha256_file;
use std::collections::BTreeMap;
use std::ops::Range;
use std::path::{Path, PathBuf};

/// Process restart policy for the cluster supervisor (`dana cluster`):
/// a supervised process that exits is relaunched up to `max` times
/// under the bounded exponential backoff of
/// [`crate::util::backoff_ms`].  The default (`max = 0`) never
/// restarts — fail-over is the standby's job, and a `kill -9`d primary
/// must stay dead for takeover drills to mean anything.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RestartPolicy {
    pub max: u32,
    pub backoff_ms: u64,
}

impl Default for RestartPolicy {
    fn default() -> Self {
        RestartPolicy { max: 0, backoff_ms: 500 }
    }
}

/// What the cluster trains: a synthetic quadratic (artifact-free) or an
/// AOT workload proxy.
#[derive(Debug, Clone, PartialEq)]
pub enum ModelSpec {
    /// `{"synthetic": true, "k": K}` — the k-dim quadratic.
    Synthetic { k: usize },
    /// `{"workload": "c10"}` — an AOT artifact workload.
    Workload(crate::config::Workload),
}

/// One primary server: a contiguous slice of the global shard space.
#[derive(Debug, Clone, PartialEq)]
pub struct ServerSpec {
    pub name: String,
    pub listen: String,
    pub status_addr: Option<String>,
    /// Hosted global shards `[start, end)` of the manifest's `shards`.
    pub shard_range: Range<u32>,
    pub placement_epoch: u64,
    pub serve_threads: usize,
    /// Checkpoint base path, relative to the launch run dir (None =
    /// checkpointing off — then no standby may pair with this server).
    pub checkpoint: Option<CheckpointSpec>,
    pub restart: RestartPolicy,
}

/// Checkpoint + retention config for one server (`--checkpoint`,
/// `--checkpoint-every`, `--keep-last`, `--keep-hourly`).
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointSpec {
    /// Base path; relative paths resolve against the run dir at launch
    /// time (mutable state never resolves against the committed
    /// manifest's own directory).
    pub path: PathBuf,
    pub every: u64,
    pub keep_last: usize,
    pub keep_hourly: usize,
}

/// One hot standby, paired to a primary by name.
#[derive(Debug, Clone, PartialEq)]
pub struct StandbySpec {
    pub name: String,
    /// Name of the [`ServerSpec`] this standby tails and takes over.
    pub of: String,
    pub listen: String,
    pub status_addr: Option<String>,
    pub poll_ms: u64,
    pub miss_budget: u32,
    pub restart: RestartPolicy,
}

/// The worker fleet: one `dana train` run against the whole placement.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    pub workers: usize,
    pub epochs: f64,
    /// `real` (thread-per-worker over TCP) or `sim` (gamma clock).
    pub mode: String,
    pub encoding: Encoding,
    pub churn: ChurnSchedule,
    pub leave_policy: LeavePolicy,
    /// Worker-thread crash-loop supervision inside the driver (PR 6).
    pub max_restarts: u32,
    pub restart_backoff_ms: u64,
    pub metrics_every: u64,
    pub seed: u64,
    /// Process-level restart policy under `dana cluster`.
    pub restart: RestartPolicy,
}

/// A content-pinned file reference: `{path, sha256}`.  Paths resolve
/// against the manifest's own directory (artifacts are committed
/// alongside it); verification fails closed on absence or mismatch.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactRef {
    pub path: PathBuf,
    pub sha256: String,
}

/// The whole topology, validated.  See the module docs for the schema.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterManifest {
    pub name: String,
    pub algorithm: AlgorithmKind,
    /// Global shard count the server ranges tile.
    pub shards: u32,
    pub model: ModelSpec,
    /// Schedule length in epochs (the LR schedule is server-owned and
    /// must agree across the placement; the fleet inherits it).
    pub epochs: f64,
    pub seed: u64,
    pub eta: Option<f32>,
    pub gamma: Option<f32>,
    /// Cluster-wide pipeline depth D: sizes every server's pull windows
    /// and the fleet's in-flight batches (they must match — DESIGN.md
    /// §10).
    pub pipeline_depth: usize,
    pub leave_policy: LeavePolicy,
    /// Payload encodings every server advertises.
    pub encodings: EncodingSet,
    /// Math kernel backend every process dispatches to (`auto` = widest
    /// SIMD the host supports; pinned backends fail closed at launch).
    pub kernels: crate::math::KernelChoice,
    pub metrics_every: u64,
    pub servers: Vec<ServerSpec>,
    pub standbys: Vec<StandbySpec>,
    pub fleet: Option<FleetSpec>,
    pub artifacts: Vec<ArtifactRef>,
    /// Directory the manifest was loaded from (artifact references
    /// resolve against it).  Not a JSON field.
    pub base_dir: PathBuf,
}

// ---------------------------------------------------------------------
// strict JSON walking
// ---------------------------------------------------------------------

/// One JSON object in the manifest, addressed by a human-readable
/// section path (`"servers[0]"`, `"fleet"`).  Construction rejects
/// non-objects and — the fail-closed heart — any key outside `known`,
/// naming the offending field.
struct Sect<'a> {
    path: String,
    map: &'a BTreeMap<String, Json>,
}

impl<'a> Sect<'a> {
    fn new(j: &'a Json, path: &str, known: &[&str]) -> anyhow::Result<Sect<'a>> {
        let map = j
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("cluster manifest: {path} must be a JSON object"))?;
        for k in map.keys() {
            anyhow::ensure!(
                known.contains(&k.as_str()),
                "cluster manifest: unknown field {k:?} in {path} (known: {})",
                known.join(", ")
            );
        }
        Ok(Sect { path: path.to_string(), map })
    }

    fn want<T>(&self, key: &str, what: &str, v: Option<T>) -> anyhow::Result<T> {
        v.ok_or_else(|| {
            anyhow::anyhow!("cluster manifest: {}.{key} must be {what}", self.path)
        })
    }

    fn str(&self, key: &str) -> anyhow::Result<String> {
        let v = self.want(key, "present", self.map.get(key))?;
        Ok(self.want(key, "a string", v.as_str())?.to_string())
    }

    fn opt_str(&self, key: &str) -> anyhow::Result<Option<String>> {
        match self.map.get(key) {
            None => Ok(None),
            Some(v) => Ok(Some(self.want(key, "a string", v.as_str())?.to_string())),
        }
    }

    fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => self.want(key, "a non-negative integer", v.as_usize()),
        }
    }

    fn u64_or(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        Ok(self.usize_or(key, default as usize)? as u64)
    }

    fn f64_or(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => self.want(key, "a number", v.as_f64()),
        }
    }

    fn opt_f32(&self, key: &str) -> anyhow::Result<Option<f32>> {
        match self.map.get(key) {
            None => Ok(None),
            Some(v) => Ok(Some(self.want(key, "a number", v.as_f64())? as f32)),
        }
    }

    fn bool_or(&self, key: &str, default: bool) -> anyhow::Result<bool> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => self.want(key, "a boolean", v.as_bool()),
        }
    }

    /// Parse a string-typed field through `FromStr` (algorithm kinds,
    /// encodings, churn specs, leave policies — the CLI grammars).
    fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt_str(key)? {
            None => Ok(default),
            Some(s) => s.parse::<T>().map_err(|e| {
                anyhow::anyhow!("cluster manifest: {}.{key} {s:?}: {e}", self.path)
            }),
        }
    }

    fn arr(&self, key: &str) -> anyhow::Result<&'a [Json]> {
        match self.map.get(key) {
            None => Ok(&[]),
            Some(v) => self.want(key, "an array", v.as_arr()),
        }
    }

    fn restart(&self) -> anyhow::Result<RestartPolicy> {
        match self.map.get("restart") {
            None => Ok(RestartPolicy::default()),
            Some(v) => {
                let s =
                    Sect::new(v, &format!("{}.restart", self.path), &["max", "backoff_ms"])?;
                Ok(RestartPolicy {
                    max: s.u64_or("max", 0)? as u32,
                    backoff_ms: s.u64_or("backoff_ms", 500)?,
                })
            }
        }
    }
}

/// Parse `"A..B"` (half-open, `A < B`) — the `--shard-range` grammar,
/// shared verbatim with the CLI.
pub fn parse_shard_range(spec: &str) -> anyhow::Result<Range<u32>> {
    let (a, b) = spec
        .split_once("..")
        .ok_or_else(|| anyhow::anyhow!("shard range wants A..B, got {spec:?}"))?;
    let a: u32 = a
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("shard range start {a:?} is not a shard index"))?;
    let b: u32 = b
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("shard range end {b:?} is not a shard index"))?;
    anyhow::ensure!(a < b, "shard range {spec:?} is empty (need A < B)");
    Ok(a..b)
}

impl ClusterManifest {
    /// Load and fully validate `path`.  Everything but artifact
    /// checksums (IO-bound; see [`ClusterManifest::verify_artifacts`])
    /// is checked here.
    pub fn load(path: &Path) -> anyhow::Result<ClusterManifest> {
        let j = Json::parse_file(path)?;
        let base = path.parent().unwrap_or(Path::new(".")).to_path_buf();
        Self::from_json(&j, base).map_err(|e| anyhow::anyhow!("{}: {e:#}", path.display()))
    }

    /// Parse + validate from an already-parsed JSON value.
    pub fn from_json(j: &Json, base_dir: PathBuf) -> anyhow::Result<ClusterManifest> {
        const TOP: &[&str] = &[
            "name",
            "algorithm",
            "shards",
            "model",
            "epochs",
            "seed",
            "eta",
            "gamma",
            "pipeline_depth",
            "leave_policy",
            "encodings",
            "kernels",
            "metrics_every",
            "servers",
            "standbys",
            "fleet",
            "artifacts",
        ];
        let top = Sect::new(j, "top level", TOP)?;
        let algorithm: AlgorithmKind = top
            .str("algorithm")?
            .parse()
            .map_err(|e| anyhow::anyhow!("cluster manifest: algorithm: {e}"))?;
        let shards = top.usize_or("shards", 0)? as u32;
        anyhow::ensure!(shards > 0, "cluster manifest: shards must be >= 1");

        let model_j = top
            .map
            .get("model")
            .ok_or_else(|| anyhow::anyhow!("cluster manifest: missing \"model\" section"))?;
        let ms = Sect::new(model_j, "model", &["synthetic", "k", "workload"])?;
        let model = if ms.bool_or("synthetic", false)? {
            let k = ms.usize_or("k", 0)?;
            anyhow::ensure!(k > 0, "cluster manifest: model.k must be >= 1 for a synthetic model");
            ModelSpec::Synthetic { k }
        } else {
            ModelSpec::Workload(ms.parse_or("workload", crate::config::Workload::C10)?)
        };

        let epochs = top.f64_or("epochs", 10.0)?;
        anyhow::ensure!(
            epochs.is_finite() && epochs > 0.0,
            "cluster manifest: epochs must be finite and > 0"
        );
        let pipeline_depth = top.usize_or("pipeline_depth", 0)?;
        anyhow::ensure!(
            pipeline_depth < crate::server::MAX_PULL_WINDOW,
            "cluster manifest: pipeline_depth {pipeline_depth} exceeds the supported window \
             ({})",
            crate::server::MAX_PULL_WINDOW - 1
        );

        const SERVER: &[&str] = &[
            "name",
            "listen",
            "status_addr",
            "shard_range",
            "placement_epoch",
            "serve_threads",
            "checkpoint",
            "restart",
        ];
        let mut servers = Vec::new();
        for (i, sj) in top.arr("servers")?.iter().enumerate() {
            let s = Sect::new(sj, &format!("servers[{i}]"), SERVER)?;
            let name = s.str("name")?;
            let range_spec = s.str("shard_range")?;
            let shard_range = parse_shard_range(&range_spec).map_err(|e| {
                anyhow::anyhow!("cluster manifest: servers[{i}].shard_range: {e}")
            })?;
            let checkpoint = match s.map.get("checkpoint") {
                None => None,
                Some(cj) => {
                    let c = Sect::new(
                        cj,
                        &format!("servers[{i}].checkpoint"),
                        &["path", "every", "keep_last", "keep_hourly"],
                    )?;
                    Some(CheckpointSpec {
                        path: PathBuf::from(c.str("path")?),
                        every: c.u64_or("every", 1)?,
                        keep_last: c.usize_or("keep_last", 0)?,
                        keep_hourly: c.usize_or("keep_hourly", 0)?,
                    })
                }
            };
            servers.push(ServerSpec {
                name,
                listen: s.str("listen")?,
                status_addr: s.opt_str("status_addr")?,
                shard_range,
                placement_epoch: s.u64_or("placement_epoch", 0)?,
                serve_threads: s.usize_or("serve_threads", 1)?,
                checkpoint,
                restart: s.restart()?,
            });
        }

        const STANDBY: &[&str] =
            &["name", "of", "listen", "status_addr", "poll_ms", "miss_budget", "restart"];
        let mut standbys = Vec::new();
        for (i, sj) in top.arr("standbys")?.iter().enumerate() {
            let s = Sect::new(sj, &format!("standbys[{i}]"), STANDBY)?;
            standbys.push(StandbySpec {
                name: s.str("name")?,
                of: s.str("of")?,
                listen: s.str("listen")?,
                status_addr: s.opt_str("status_addr")?,
                poll_ms: s.u64_or("poll_ms", 250)?.max(10),
                miss_budget: (s.u64_or("miss_budget", 4)? as u32).max(1),
                restart: s.restart()?,
            });
        }

        const FLEET: &[&str] = &[
            "workers",
            "epochs",
            "mode",
            "encoding",
            "churn",
            "leave_policy",
            "max_restarts",
            "restart_backoff_ms",
            "metrics_every",
            "seed",
            "restart",
        ];
        let leave_policy: LeavePolicy = top.parse_or("leave_policy", LeavePolicy::default())?;
        let seed = top.u64_or("seed", 1)?;
        let fleet = match top.map.get("fleet") {
            None => None,
            Some(fj) => {
                let f = Sect::new(fj, "fleet", FLEET)?;
                let workers = f.usize_or("workers", 0)?;
                anyhow::ensure!(workers >= 1, "cluster manifest: fleet.workers must be >= 1");
                let mode = f.opt_str("mode")?.unwrap_or_else(|| "real".to_string());
                anyhow::ensure!(
                    matches!(mode.as_str(), "real" | "sim"),
                    "cluster manifest: fleet.mode must be \"real\" or \"sim\" (got {mode:?})"
                );
                let churn: ChurnSchedule = f.parse_or("churn", ChurnSchedule::default())?;
                churn
                    .validate(workers)
                    .map_err(|e| anyhow::anyhow!("cluster manifest: fleet.churn: {e:#}"))?;
                Some(FleetSpec {
                    workers,
                    epochs: f.f64_or("epochs", epochs)?,
                    mode,
                    encoding: f.parse_or("encoding", Encoding::None)?,
                    churn,
                    leave_policy: f.parse_or("leave_policy", leave_policy)?,
                    max_restarts: f.u64_or("max_restarts", 0)? as u32,
                    restart_backoff_ms: f.u64_or("restart_backoff_ms", 50)?,
                    metrics_every: f.u64_or("metrics_every", 0)?,
                    seed: f.u64_or("seed", seed)?,
                    restart: f.restart()?,
                })
            }
        };

        let mut artifacts = Vec::new();
        for (i, aj) in top.arr("artifacts")?.iter().enumerate() {
            let a = Sect::new(aj, &format!("artifacts[{i}]"), &["path", "sha256"])?;
            let path = PathBuf::from(a.str("path")?);
            let sha256 = a.str("sha256")?.to_ascii_lowercase();
            anyhow::ensure!(
                sha256.len() == 64 && sha256.bytes().all(|b| b.is_ascii_hexdigit()),
                "cluster manifest: artifact {:?}: sha256 must be 64 hex characters",
                path.display().to_string()
            );
            artifacts.push(ArtifactRef { path, sha256 });
        }

        let m = ClusterManifest {
            name: top.opt_str("name")?.unwrap_or_default(),
            algorithm,
            shards,
            model,
            epochs,
            seed,
            eta: top.opt_f32("eta")?,
            gamma: top.opt_f32("gamma")?,
            pipeline_depth,
            leave_policy,
            encodings: top.parse_or("encodings", EncodingSet::ALL)?,
            kernels: top.parse_or("kernels", Default::default())?,
            metrics_every: top.u64_or("metrics_every", 0)?,
            servers,
            standbys,
            fleet,
            artifacts,
            base_dir,
        };
        m.validate()?;
        Ok(m)
    }

    /// Structural validation: tiling, pairings, address uniqueness.
    /// Called by [`ClusterManifest::from_json`]; a constructed manifest
    /// is always valid.
    fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.servers.is_empty(),
            "cluster manifest: needs at least one entry in \"servers\""
        );
        // the exact fail-closed tiling rules live placement resolution
        // applies (cluster/placement.rs) — no overlap, no gap, full
        // coverage of 0..shards
        let labeled: Vec<(String, Range<u32>)> = self
            .servers
            .iter()
            .map(|s| (format!("{:?}", s.name), s.shard_range.clone()))
            .collect();
        validate_tiling("cluster manifest", &labeled, self.shards)?;

        // unique process names, unique listen + status addresses
        let mut names: BTreeMap<&str, &str> = BTreeMap::new();
        let mut addrs: BTreeMap<&str, String> = BTreeMap::new();
        for (who, name) in self
            .servers
            .iter()
            .map(|s| ("server", s.name.as_str()))
            .chain(self.standbys.iter().map(|s| ("standby", s.name.as_str())))
        {
            anyhow::ensure!(!name.is_empty(), "cluster manifest: a {who} has an empty name");
            if let Some(prev) = names.insert(name, who) {
                anyhow::bail!(
                    "cluster manifest: duplicate process name {name:?} (a {prev} and a {who})"
                );
            }
        }
        for (addr, who) in self
            .servers
            .iter()
            .flat_map(|s| {
                std::iter::once((s.listen.as_str(), format!("server {:?}", s.name))).chain(
                    s.status_addr
                        .iter()
                        .map(move |a| (a.as_str(), format!("server {:?} status", s.name))),
                )
            })
            .chain(self.standbys.iter().flat_map(|s| {
                std::iter::once((s.listen.as_str(), format!("standby {:?}", s.name))).chain(
                    s.status_addr
                        .iter()
                        .map(move |a| (a.as_str(), format!("standby {:?} status", s.name))),
                )
            }))
        {
            anyhow::ensure!(!addr.is_empty(), "cluster manifest: {who} has an empty address");
            if let Some(prev) = addrs.insert(addr, who.clone()) {
                anyhow::bail!(
                    "cluster manifest: duplicate listen address {addr:?} ({prev} and {who})"
                );
            }
        }

        // standby pairings: the primary must exist and must archive
        for sb in &self.standbys {
            let primary = self.servers.iter().find(|s| s.name == sb.of).ok_or_else(|| {
                anyhow::anyhow!(
                    "cluster manifest: standby {:?} names unknown server {:?} (servers: {})",
                    sb.name,
                    sb.of,
                    self.servers
                        .iter()
                        .map(|s| format!("{:?}", s.name))
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })?;
            let ck = primary.checkpoint.as_ref().filter(|c| c.every >= 1 && c.keep_last >= 1);
            anyhow::ensure!(
                ck.is_some(),
                "cluster manifest: standby {:?}: its primary {:?} keeps no retention \
                 archives to tail (give it checkpoint.path with every >= 1 and keep_last \
                 >= 1)",
                sb.name,
                sb.of
            );
        }
        Ok(())
    }

    /// Look up a primary by name.
    pub fn server(&self, name: &str) -> Option<&ServerSpec> {
        self.servers.iter().find(|s| s.name == name)
    }

    /// Look up a standby by name.
    pub fn standby(&self, name: &str) -> Option<&StandbySpec> {
        self.standbys.iter().find(|s| s.name == name)
    }

    /// The full `--master` endpoint list: every primary and standby,
    /// in manifest order (standbys are skipped at resolution but probed
    /// at fail-over, so clients list them from the start).
    pub fn master_list(&self) -> String {
        self.servers
            .iter()
            .map(|s| format!("tcp://{}", s.listen))
            .chain(self.standbys.iter().map(|s| format!("tcp://{}", s.listen)))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// The synthetic model dimension, if this manifest is synthetic.
    pub fn synthetic_k(&self) -> Option<usize> {
        match self.model {
            ModelSpec::Synthetic { k } => Some(k),
            ModelSpec::Workload(_) => None,
        }
    }

    /// Resolve a checkpoint base path against the launch run dir
    /// (mutable state) — absolute paths pass through.
    pub fn resolve_run_path(run_dir: &Path, p: &Path) -> PathBuf {
        if p.is_absolute() {
            p.to_path_buf()
        } else {
            run_dir.join(p)
        }
    }

    /// Resolve an artifact reference against the manifest's directory
    /// (committed content) — absolute paths pass through.
    pub fn resolve_artifact_path(&self, p: &Path) -> PathBuf {
        if p.is_absolute() {
            p.to_path_buf()
        } else {
            self.base_dir.join(p)
        }
    }

    /// Verify every artifact reference's SHA-256 against the file on
    /// disk.  Fail-closed: a missing file or a mismatched digest is an
    /// error naming the artifact.  Returns the number verified.
    pub fn verify_artifacts(&self) -> anyhow::Result<usize> {
        for a in &self.artifacts {
            let full = self.resolve_artifact_path(&a.path);
            let actual = sha256_file(&full)
                .map_err(|e| anyhow::anyhow!("artifact {:?}: {e:#}", a.path.display().to_string()))?;
            anyhow::ensure!(
                actual == a.sha256,
                "sha256 mismatch for {:?}: manifest pins {}, file is {actual}",
                a.path.display().to_string(),
                a.sha256
            );
        }
        Ok(self.artifacts.len())
    }

    /// One-line human summary (`dana cluster --verify-only`).
    pub fn summary(&self) -> String {
        format!(
            "{}{} · {} · {} global shard(s) tiled by {} server(s), {} standby(s){}{}",
            if self.name.is_empty() { "cluster" } else { &self.name },
            match &self.model {
                ModelSpec::Synthetic { k } => format!(" (synthetic k={k})"),
                ModelSpec::Workload(w) => format!(" ({})", w.name()),
            },
            self.algorithm.name(),
            self.shards,
            self.servers.len(),
            self.standbys.len(),
            match &self.fleet {
                Some(f) => format!(
                    ", fleet of {} worker(s) ({} mode, D={})",
                    f.workers, f.mode, self.pipeline_depth
                ),
                None => ", no fleet".to_string(),
            },
            if self.artifacts.is_empty() {
                String::new()
            } else {
                format!(", {} pinned artifact(s)", self.artifacts.len())
            },
        )
    }
}

//! DANA-DC (paper Algorithm 7, §4.3): DANA-Zero + delay compensation.
//!
//! The incoming gradient is first Taylor-adjusted toward the master's
//! current position (DC-ASGD, Eq 17), then fed through the DANA-Zero fused
//! momentum/look-ahead update.  DANA's small gap is what makes the Taylor
//! term accurate — the combination converges fastest in the paper's Fig 5
//! and holds the highest accuracy at 128 workers (Table 5).

use super::{
    dict_coord, dict_per_worker, Algorithm, AlgorithmKind, LeavePolicy, StateDict, StateVec, Step,
};
use crate::math;

#[derive(Debug, Clone)]
pub struct DanaDc {
    theta: Vec<f32>,
    /// Per-worker momentum vᶦ (retired slots zeroed).
    v: Vec<Vec<f32>>,
    /// v⁰ = Σ live vᶦ, maintained incrementally through updates *and*
    /// membership changes (Appendix A.2).
    vsum: Vec<f32>,
    /// Slot liveness (elastic membership).
    live: Vec<bool>,
    /// Pipeline staleness hint: extra momentum-only steps to extrapolate
    /// the Eq 11 look-ahead by ([`Algorithm::set_staleness_hint`]).
    pipeline: usize,
}

impl DanaDc {
    pub fn new(theta0: &[f32], n_workers: usize) -> Self {
        DanaDc {
            theta: theta0.to_vec(),
            v: vec![vec![0.0; theta0.len()]; n_workers],
            vsum: vec![0.0; theta0.len()],
            live: vec![true; n_workers],
            pipeline: 0,
        }
    }

    pub fn velocity(&self, worker: usize) -> &[f32] {
        &self.v[worker]
    }

    pub fn velocity_sum(&self) -> &[f32] {
        &self.vsum
    }

    pub fn is_live(&self, worker: usize) -> bool {
        self.live.get(worker).copied().unwrap_or(false)
    }

    /// O(k·N) reference sum over all slots (retired slots are zero), for
    /// the churn invariant property test.
    pub fn recompute_vsum(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.theta.len()];
        for v in &self.v {
            math::axpy(&mut out, 1.0, v);
        }
        out
    }
}

impl Algorithm for DanaDc {
    fn kind(&self) -> AlgorithmKind {
        AlgorithmKind::DanaDc
    }

    fn theta(&self) -> &[f32] {
        &self.theta
    }

    fn master_apply(&mut self, worker: usize, msg: &[f32], sent: &[f32], s: Step) {
        // Alg 7 in one fused pass: ghat = g + λ·g⊙g⊙(θ⁰−θ_sent), then the
        // DANA momentum/look-ahead bookkeeping (§Perf).
        math::dc_dana_fused_update(
            &mut self.theta,
            &mut self.v[worker],
            &mut self.vsum,
            msg,
            sent,
            s.gamma,
            s.eta,
            s.lambda,
        );
    }

    fn master_send(&self, _worker: usize, out: &mut [f32], s: Step) {
        math::lookahead_extrapolated(out, &self.theta, &self.vsum, s.gamma, s.eta, self.pipeline);
    }

    fn set_staleness_hint(&mut self, extra_steps: usize) {
        self.pipeline = extra_steps;
    }

    fn rescale_momentum(&mut self, ratio: f32) {
        for v in &mut self.v {
            math::scale(v, ratio);
        }
        math::scale(&mut self.vsum, ratio);
    }

    fn add_worker(&mut self) -> usize {
        super::join_momentum_slot(&mut self.live, &mut self.v, self.theta.len())
    }

    fn remove_worker(&mut self, worker: usize, policy: LeavePolicy) {
        super::retire_momentum_slot(
            &mut self.live,
            &mut self.v,
            worker,
            policy,
            Some(&mut self.vsum),
        );
    }

    fn state_dict(&self) -> StateDict {
        vec![
            ("v".to_string(), StateVec::PerWorker(self.v.clone())),
            ("vsum".to_string(), StateVec::Coord(self.vsum.clone())),
        ]
    }

    fn load_state_dict(&mut self, dict: &StateDict) -> anyhow::Result<()> {
        self.v = dict_per_worker(dict, "v", self.v.len(), self.theta.len())?;
        self.vsum = dict_coord(dict, "vsum", self.theta.len())?;
        Ok(())
    }

    fn set_theta(&mut self, theta: &[f32]) {
        self.theta.copy_from_slice(theta);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_lambda_reduces_to_dana_zero() {
        let theta0: Vec<f32> = (0..17).map(|i| (i as f32 * 0.3).sin()).collect();
        let mut dc = DanaDc::new(&theta0, 3);
        let mut zero = super::super::dana_zero::DanaZero::new(&theta0, 3);
        let s = Step { eta: 0.1, gamma: 0.9, lambda: 0.0 };
        let mut rng = crate::util::rng::Rng::new(2);
        for i in 0..30 {
            let g: Vec<f32> = (0..17).map(|_| rng.normal() as f32).collect();
            let mut sent = vec![0.0; 17];
            dc.master_send(i % 3, &mut sent, s);
            dc.master_apply(i % 3, &g, &sent, s);
            zero.master_apply(i % 3, &g, &sent, s);
        }
        for (a, b) in dc.theta().iter().zip(zero.theta()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn compensation_applies_before_momentum() {
        let mut dc = DanaDc::new(&[2.0], 1);
        let s = Step { eta: 1.0, gamma: 0.0, lambda: 0.5 };
        dc.master_apply(0, &[1.0], &[1.0], s);
        // ghat = 1 + 0.5*1*(2-1) = 1.5; v=1.5; theta = 2-1.5 = 0.5
        assert_eq!(dc.theta(), &[0.5]);
        assert_eq!(dc.velocity_sum(), &[1.5]);
    }
}

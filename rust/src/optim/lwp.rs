//! Linear Weight Prediction (Kosson et al. 2020; paper Algorithm 3, §3.1).
//!
//! A *shared* momentum vector with a linear extrapolation send:
//!
//! ```text
//! send  theta_hat = theta - tau * eta * v
//! ```
//!
//! i.e. NAG's look-ahead scaled by the expected lag τ, assuming the same v
//! is replayed for all τ upcoming updates.  In large clusters v drifts over
//! the lag window, so the prediction misses — the paper shows LWP's gap
//! only slightly below NAG-ASGD (Fig 2b).  The default τ is the steady-state
//! expected lag of N equal workers (the N next updates the paper's DANA
//! analysis predicts over).

use super::{
    dict_coord, dict_scalars, Algorithm, AlgorithmKind, LeavePolicy, StateDict, StateVec, Step,
    ANY_SLOT,
};
use crate::math;

#[derive(Debug, Clone)]
pub struct Lwp {
    theta: Vec<f32>,
    v: Vec<f32>,
    /// Prediction horizon τ (defaults to the cluster size N).
    tau: f32,
    /// Live worker count; τ tracks it (the steady-state expected lag of N
    /// equal workers) unless [`Lwp::with_tau`] pinned τ explicitly.
    live: usize,
    tau_auto: bool,
    /// Pipeline staleness hint: each worker keeps `pipeline + 1` batches
    /// in flight, so the expected lag — and the auto-τ — scales by the
    /// in-flight multiplicity (the Zhang et al. staleness-aware scaling
    /// applied to the prediction horizon).  0 leaves τ = N exactly.
    pipeline: usize,
}

impl Lwp {
    pub fn new(theta0: &[f32], n_workers: usize) -> Self {
        let mut l = Self::with_tau(theta0, n_workers as f32);
        l.live = n_workers;
        l.tau_auto = true;
        l
    }

    pub fn with_tau(theta0: &[f32], tau: f32) -> Self {
        Lwp {
            theta: theta0.to_vec(),
            v: vec![0.0; theta0.len()],
            tau,
            live: tau.max(1.0) as usize,
            tau_auto: false,
            pipeline: 0,
        }
    }

    pub fn tau(&self) -> f32 {
        self.tau
    }

    /// Auto-τ: steady-state expected lag of `live` equal workers with
    /// `pipeline + 1` batches in flight each.
    fn retune_tau(&mut self) {
        if self.tau_auto {
            self.tau = (self.live.max(1) * (self.pipeline + 1)) as f32;
        }
    }
}

impl Algorithm for Lwp {
    fn kind(&self) -> AlgorithmKind {
        AlgorithmKind::Lwp
    }

    fn theta(&self) -> &[f32] {
        &self.theta
    }

    fn master_apply(&mut self, _worker: usize, msg: &[f32], _sent: &[f32], s: Step) {
        // shared v <- gamma*v + g ; theta <- theta - eta*v
        math::momentum_step(&mut self.theta, &mut self.v, msg, s.gamma, s.eta);
    }

    fn master_send(&self, _worker: usize, out: &mut [f32], s: Step) {
        // theta_hat = theta - tau*eta*v
        let c = self.tau * s.eta;
        for ((o, &t), &v) in out.iter_mut().zip(&self.theta).zip(&self.v) {
            *o = t - c * v;
        }
    }

    fn rescale_momentum(&mut self, ratio: f32) {
        math::scale(&mut self.v, ratio);
    }

    /// The momentum vector is shared, so membership only moves the
    /// prediction horizon: τ tracks the live worker count (the expected
    /// lag changes with the cluster size).
    fn add_worker(&mut self) -> usize {
        self.live += 1;
        self.retune_tau();
        ANY_SLOT
    }

    fn remove_worker(&mut self, _worker: usize, _policy: LeavePolicy) {
        self.live = self.live.saturating_sub(1);
        self.retune_tau();
    }

    fn set_staleness_hint(&mut self, extra_steps: usize) {
        self.pipeline = extra_steps;
        self.retune_tau();
    }

    fn state_dict(&self) -> StateDict {
        vec![
            ("v".to_string(), StateVec::Coord(self.v.clone())),
            (
                "tau".to_string(),
                StateVec::Scalars(vec![
                    self.tau as f64,
                    self.live as f64,
                    if self.tau_auto { 1.0 } else { 0.0 },
                ]),
            ),
        ]
    }

    fn load_state_dict(&mut self, dict: &StateDict) -> anyhow::Result<()> {
        self.v = dict_coord(dict, "v", self.theta.len())?;
        let s = dict_scalars(dict, "tau", 3)?;
        self.tau = s[0] as f32;
        self.live = s[1] as usize;
        self.tau_auto = s[2] != 0.0;
        Ok(())
    }

    fn set_theta(&mut self, theta: &[f32]) {
        self.theta.copy_from_slice(theta);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn send_extrapolates_tau_steps() {
        let mut l = Lwp::with_tau(&[0.0], 3.0);
        let s = Step { eta: 1.0, gamma: 0.0, lambda: 0.0 };
        l.master_apply(0, &[1.0], &[0.0], s); // v=1, theta=-1
        let mut out = [0.0f32];
        l.master_send(0, &mut out, s);
        assert_eq!(out, [-4.0]); // -1 - 3*1*1
    }

    #[test]
    fn tau_tracks_live_workers_unless_pinned() {
        let mut l = Lwp::new(&[0.0], 4);
        assert_eq!(l.add_worker(), ANY_SLOT);
        assert_eq!(l.tau(), 5.0);
        l.remove_worker(0, LeavePolicy::Retire);
        l.remove_worker(1, LeavePolicy::Fold);
        assert_eq!(l.tau(), 3.0);
        let mut pinned = Lwp::with_tau(&[0.0], 7.0);
        pinned.add_worker();
        assert_eq!(pinned.tau(), 7.0);
    }

    #[test]
    fn pipeline_hint_scales_auto_tau_by_inflight_multiplicity() {
        let mut l = Lwp::new(&[0.0], 4);
        l.set_staleness_hint(2); // 3 batches in flight per worker
        assert_eq!(l.tau(), 12.0);
        l.add_worker();
        assert_eq!(l.tau(), 15.0);
        l.set_staleness_hint(0);
        assert_eq!(l.tau(), 5.0, "hint 0 restores tau = N exactly");
        // pinned tau ignores the hint, like it ignores membership
        let mut pinned = Lwp::with_tau(&[0.0], 7.0);
        pinned.set_staleness_hint(3);
        assert_eq!(pinned.tau(), 7.0);
    }

    #[test]
    fn zero_momentum_state_sends_theta() {
        let mut l = Lwp::new(&[5.0, -5.0], 8);
        let mut out = [0.0f32; 2];
        l.master_send(0, &mut out, Step::default());
        assert_eq!(out, [5.0, -5.0]);
    }
}

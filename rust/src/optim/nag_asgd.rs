//! NAG-ASGD (paper Algorithm 8): one *shared* NAG optimizer at the master.
//!
//! The cautionary baseline of the paper — a single momentum vector absorbs
//! every worker's gradients, so the momentum term both grows stale and is
//! applied with multiplicity N.  Fig 2(b) shows its gap blowing up and
//! Tables 2–5 show divergence beyond ~12–16 workers; reproducing that
//! failure shape is part of the evaluation.

use super::{dict_coord, Algorithm, AlgorithmKind, StateDict, StateVec, Step};
use crate::math;

#[derive(Debug, Clone)]
pub struct NagAsgd {
    theta: Vec<f32>,
    v: Vec<f32>,
    /// Pipeline staleness hint ([`Algorithm::set_staleness_hint`]): with
    /// `pipeline > 0` the send extrapolates θ by that many momentum-only
    /// steps of the shared v (the future position the gradient will land
    /// on); 0 sends plain θ (Algorithm 8 exactly).
    pipeline: usize,
}

impl NagAsgd {
    pub fn new(theta0: &[f32]) -> Self {
        NagAsgd { theta: theta0.to_vec(), v: vec![0.0; theta0.len()], pipeline: 0 }
    }

    pub fn velocity(&self) -> &[f32] {
        &self.v
    }
}

impl Algorithm for NagAsgd {
    fn kind(&self) -> AlgorithmKind {
        AlgorithmKind::NagAsgd
    }

    fn theta(&self) -> &[f32] {
        &self.theta
    }

    fn master_apply(&mut self, _worker: usize, msg: &[f32], _sent: &[f32], s: Step) {
        // v <- gamma*v + g ; theta <- theta - eta*v   (shared v)
        math::momentum_step(&mut self.theta, &mut self.v, msg, s.gamma, s.eta);
    }

    fn master_send(&self, _worker: usize, out: &mut [f32], s: Step) {
        if self.pipeline == 0 {
            // Algorithm 8: send plain θ (the default behavior, exactly).
            out.copy_from_slice(&self.theta);
        } else {
            math::extrapolate_position(out, &self.theta, &self.v, s.gamma, s.eta, self.pipeline);
        }
    }

    fn set_staleness_hint(&mut self, extra_steps: usize) {
        self.pipeline = extra_steps;
    }

    fn rescale_momentum(&mut self, ratio: f32) {
        math::scale(&mut self.v, ratio);
    }

    fn state_dict(&self) -> StateDict {
        vec![("v".to_string(), StateVec::Coord(self.v.clone()))]
    }

    fn load_state_dict(&mut self, dict: &StateDict) -> anyhow::Result<()> {
        self.v = dict_coord(dict, "v", self.theta.len())?;
        Ok(())
    }

    fn set_theta(&mut self, theta: &[f32]) {
        self.theta.copy_from_slice(theta);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn momentum_accumulates_across_workers() {
        let mut a = NagAsgd::new(&[0.0]);
        let s = Step { eta: 1.0, gamma: 0.5, lambda: 0.0 };
        a.master_apply(0, &[1.0], &[0.0], s); // v=1, theta=-1
        a.master_apply(1, &[1.0], &[0.0], s); // v=1.5, theta=-2.5
        assert_eq!(a.velocity(), &[1.5]);
        assert_eq!(a.theta(), &[-2.5]);
    }

    #[test]
    fn momentum_correction_rescales_v() {
        let mut a = NagAsgd::new(&[0.0]);
        let s = Step { eta: 1.0, gamma: 1.0, lambda: 0.0 };
        a.master_apply(0, &[2.0], &[0.0], s);
        a.rescale_momentum(0.5);
        assert_eq!(a.velocity(), &[1.0]);
    }
}

//! DANA-Zero (paper Algorithm 4) — the paper's primary contribution.
//!
//! The master keeps one momentum vector per worker (Eq 10) plus their sum
//! `v⁰ = Σᵢ vᶦ` maintained incrementally in O(k) (Appendix A.2), and sends
//! each worker the *look-ahead* estimate of its own future position:
//!
//! ```text
//! v^i   <- gamma * v^i + g^i
//! theta <- theta - eta * v^i
//! send  theta_hat = theta - eta * gamma * v0        (Eq 11)
//! ```
//!
//! This is Nesterov's look-ahead generalized to N in-flight workers: the
//! prediction folds in the momentum every other worker will apply before
//! this worker's next gradient lands, which collapses the gap to ASGD's
//! (Eq 12) and lets momentum survive asynchrony.
//!
//! The apply path is a single fused pass ([`crate::math::dana_fused_update`],
//! mirrored 1:1 by the L1 Pallas kernel `kernels/update.py`).

use super::{
    dict_coord, dict_per_worker, Algorithm, AlgorithmKind, LeavePolicy, StateDict, StateVec, Step,
};
use crate::math;

#[derive(Debug, Clone)]
pub struct DanaZero {
    theta: Vec<f32>,
    /// Per-worker momentum vᶦ (retired slots are zeroed, so v⁰ = Σ over
    /// *all* slots equals Σ over live slots).
    v: Vec<Vec<f32>>,
    /// v⁰ = Σ live vᶦ, maintained incrementally (Appendix A.2) — including
    /// through membership changes ([`Algorithm::remove_worker`]).
    vsum: Vec<f32>,
    /// Slot liveness (elastic membership).
    live: Vec<bool>,
    /// Pipeline staleness hint: extra momentum-only steps to extrapolate
    /// the Eq 11 look-ahead by ([`Algorithm::set_staleness_hint`]).  0 =
    /// the plain look-ahead, bit-for-bit.
    pipeline: usize,
}

impl DanaZero {
    pub fn new(theta0: &[f32], n_workers: usize) -> Self {
        DanaZero {
            theta: theta0.to_vec(),
            v: vec![vec![0.0; theta0.len()]; n_workers],
            vsum: vec![0.0; theta0.len()],
            live: vec![true; n_workers],
            pipeline: 0,
        }
    }

    pub fn velocity(&self, worker: usize) -> &[f32] {
        &self.v[worker]
    }

    pub fn velocity_sum(&self) -> &[f32] {
        &self.vsum
    }

    pub fn is_live(&self, worker: usize) -> bool {
        self.live.get(worker).copied().unwrap_or(false)
    }

    /// Recompute v⁰ from scratch in O(k·N) — the naive path the paper's
    /// Appendix A.2 optimizes away; kept for the invariant property test
    /// and the ablation bench.  Retired slots are zero, so summing every
    /// slot equals summing the live ones.
    pub fn recompute_vsum(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.theta.len()];
        for v in &self.v {
            math::axpy(&mut out, 1.0, v);
        }
        out
    }
}

impl Algorithm for DanaZero {
    fn kind(&self) -> AlgorithmKind {
        AlgorithmKind::DanaZero
    }

    fn theta(&self) -> &[f32] {
        &self.theta
    }

    fn master_apply(&mut self, worker: usize, msg: &[f32], _sent: &[f32], s: Step) {
        math::dana_fused_update(
            &mut self.theta,
            &mut self.v[worker],
            &mut self.vsum,
            msg,
            s.gamma,
            s.eta,
        );
    }

    fn master_send(&self, _worker: usize, out: &mut [f32], s: Step) {
        math::lookahead_extrapolated(out, &self.theta, &self.vsum, s.gamma, s.eta, self.pipeline);
    }

    fn set_staleness_hint(&mut self, extra_steps: usize) {
        self.pipeline = extra_steps;
    }

    fn rescale_momentum(&mut self, ratio: f32) {
        for v in &mut self.v {
            math::scale(v, ratio);
        }
        math::scale(&mut self.vsum, ratio);
    }

    fn add_worker(&mut self) -> usize {
        // The joiner's vᶦ is zero, so v⁰ = Σ live vᶦ holds untouched.
        super::join_momentum_slot(&mut self.live, &mut self.v, self.theta.len())
    }

    fn remove_worker(&mut self, worker: usize, policy: LeavePolicy) {
        // Fold merges the leaver's momentum into the lowest surviving
        // slot (v⁰ unchanged); Retire — or Fold with nobody left —
        // subtracts it from v⁰.  Either way the A.2 invariant is exact.
        super::retire_momentum_slot(
            &mut self.live,
            &mut self.v,
            worker,
            policy,
            Some(&mut self.vsum),
        );
    }

    fn state_dict(&self) -> StateDict {
        vec![
            ("v".to_string(), StateVec::PerWorker(self.v.clone())),
            ("vsum".to_string(), StateVec::Coord(self.vsum.clone())),
        ]
    }

    fn load_state_dict(&mut self, dict: &StateDict) -> anyhow::Result<()> {
        self.v = dict_per_worker(dict, "v", self.v.len(), self.theta.len())?;
        self.vsum = dict_coord(dict, "vsum", self.theta.len())?;
        Ok(())
    }

    fn set_theta(&mut self, theta: &[f32]) {
        self.theta.copy_from_slice(theta);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step() -> Step {
        Step { eta: 0.1, gamma: 0.9, lambda: 0.0 }
    }

    #[test]
    fn incremental_vsum_matches_full_sum() {
        let mut d = DanaZero::new(&vec![0.0; 33], 4);
        let mut rng = crate::util::rng::Rng::new(5);
        for i in 0..100 {
            let g: Vec<f32> = (0..33).map(|_| rng.normal() as f32).collect();
            let sent = d.theta().to_vec();
            d.master_apply(i % 4, &g, &sent, step());
        }
        let full = d.recompute_vsum();
        for (a, b) in d.velocity_sum().iter().zip(&full) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn n1_equals_sequential_nag() {
        // Paper Algorithm 5: with one worker, the (master_send -> grad at
        // hat -> master_apply) cycle IS Nesterov's accelerated gradient.
        // Emulate NAG on a quadratic J(x) = 0.5*x^2 (grad = x).
        let s = step();
        let mut d = DanaZero::new(&[1.0], 1);
        // sequential NAG reference
        let (mut theta, mut v) = (1.0f32, 0.0f32);
        for _ in 0..50 {
            // DANA side: pull hat, compute grad at hat, apply
            let mut hat = [0.0f32];
            d.master_send(0, &mut hat, s);
            let g = [hat[0]]; // grad of 0.5 x^2 at hat
            let sent = hat;
            d.master_apply(0, &g, &sent, s);
            // NAG reference (Eq 3)
            let hat_ref = theta - s.eta * s.gamma * v;
            let g_ref = hat_ref;
            v = s.gamma * v + g_ref;
            theta -= s.eta * v;
            assert!((d.theta()[0] - theta).abs() < 1e-6, "{} vs {theta}", d.theta()[0]);
        }
        assert!(theta.abs() < 1.0); // converging
    }

    #[test]
    fn retire_subtracts_leaver_from_vsum() {
        let s = Step { eta: 1.0, gamma: 0.5, lambda: 0.0 };
        let mut d = DanaZero::new(&[0.0], 2);
        d.master_apply(0, &[1.0], &[0.0], s); // v0=1
        d.master_apply(1, &[2.0], &[0.0], s); // v1=2, vsum=3
        d.remove_worker(1, LeavePolicy::Retire);
        assert_eq!(d.velocity_sum(), &[1.0]);
        assert_eq!(d.velocity(1), &[0.0]);
        assert!(!d.is_live(1));
    }

    #[test]
    fn fold_moves_leaver_momentum_to_survivor() {
        let s = Step { eta: 1.0, gamma: 0.5, lambda: 0.0 };
        let mut d = DanaZero::new(&[0.0], 2);
        d.master_apply(0, &[1.0], &[0.0], s);
        d.master_apply(1, &[2.0], &[0.0], s);
        d.remove_worker(1, LeavePolicy::Fold);
        assert_eq!(d.velocity_sum(), &[3.0], "fold keeps v0 intact");
        assert_eq!(d.velocity(0), &[3.0], "survivor absorbed the momentum");
        // folding the last worker degenerates to retire
        d.remove_worker(0, LeavePolicy::Fold);
        assert_eq!(d.velocity_sum(), &[0.0]);
    }

    #[test]
    fn rejoin_reuses_lowest_retired_slot_with_zero_momentum() {
        let s = Step { eta: 1.0, gamma: 0.5, lambda: 0.0 };
        let mut d = DanaZero::new(&[0.0], 3);
        d.master_apply(1, &[1.0], &[0.0], s);
        d.remove_worker(1, LeavePolicy::Retire);
        assert_eq!(d.add_worker(), 1);
        assert_eq!(d.velocity(1), &[0.0]);
        assert_eq!(d.add_worker(), 3, "no retired slot left: append");
        assert_eq!(d.velocity(3), &[0.0]);
        let full = d.recompute_vsum();
        assert_eq!(d.velocity_sum(), &full[..]);
    }

    #[test]
    fn lookahead_send_uses_all_worker_momenta() {
        let s = Step { eta: 1.0, gamma: 0.5, lambda: 0.0 };
        let mut d = DanaZero::new(&[0.0], 2);
        d.master_apply(0, &[1.0], &[0.0], s); // v0=1, theta=-1, vsum=1
        d.master_apply(1, &[1.0], &[0.0], s); // v1=1, theta=-2, vsum=2
        let mut out = [0.0f32];
        d.master_send(0, &mut out, s);
        // hat = -2 - 1*0.5*2 = -3
        assert_eq!(out, [-3.0]);
    }
}

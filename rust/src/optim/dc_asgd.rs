//! DC-ASGD (Zheng et al. 2017; paper Algorithm 10): delay-compensated
//! asynchronous SGD.
//!
//! The incoming gradient is adjusted with a cheap diagonal-Hessian Taylor
//! term before the momentum update (Eq 17):
//!
//! ```text
//! g_hat = g + lambda * g ⊙ g ⊙ (theta_master - theta_sent)
//! ```
//!
//! The Taylor expansion is only accurate when `theta_sent` is close to the
//! master's current parameters — i.e. when the *gap* is small.  Momentum
//! inflates the gap, which is exactly why plain DC-ASGD collapses at scale
//! in the paper's tables while DANA-DC (the same compensation applied on
//! top of DANA's small gap) keeps working.

use super::{dict_per_worker, Algorithm, AlgorithmKind, LeavePolicy, StateDict, StateVec, Step};
use crate::math;

#[derive(Debug, Clone)]
pub struct DcAsgd {
    theta: Vec<f32>,
    v: Vec<Vec<f32>>,
    /// Slot liveness (elastic membership).
    live: Vec<bool>,
}

impl DcAsgd {
    pub fn new(theta0: &[f32], n_workers: usize) -> Self {
        DcAsgd {
            theta: theta0.to_vec(),
            v: vec![vec![0.0; theta0.len()]; n_workers],
            live: vec![true; n_workers],
        }
    }
}

impl Algorithm for DcAsgd {
    fn kind(&self) -> AlgorithmKind {
        AlgorithmKind::DcAsgd
    }

    fn theta(&self) -> &[f32] {
        &self.theta
    }

    fn master_apply(&mut self, worker: usize, msg: &[f32], sent: &[f32], s: Step) {
        // single fused pass: compensate + momentum + apply (§Perf)
        math::dc_momentum_step(
            &mut self.theta,
            &mut self.v[worker],
            msg,
            sent,
            s.gamma,
            s.eta,
            s.lambda,
        );
    }

    fn rescale_momentum(&mut self, ratio: f32) {
        for v in &mut self.v {
            math::scale(v, ratio);
        }
    }

    fn add_worker(&mut self) -> usize {
        super::join_momentum_slot(&mut self.live, &mut self.v, self.theta.len())
    }

    fn remove_worker(&mut self, worker: usize, policy: LeavePolicy) {
        super::retire_momentum_slot(&mut self.live, &mut self.v, worker, policy, None);
    }

    fn state_dict(&self) -> StateDict {
        vec![("v".to_string(), StateVec::PerWorker(self.v.clone()))]
    }

    fn load_state_dict(&mut self, dict: &StateDict) -> anyhow::Result<()> {
        self.v = dict_per_worker(dict, "v", self.v.len(), self.theta.len())?;
        Ok(())
    }

    fn set_theta(&mut self, theta: &[f32]) {
        self.theta.copy_from_slice(theta);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_lag_means_no_compensation() {
        // When the sent params equal the master params the compensation
        // term vanishes and DC-ASGD == Multi-ASGD.
        let theta0 = [1.0f32, -2.0];
        let mut dc = DcAsgd::new(&theta0, 1);
        let mut multi = super::super::multi_asgd::MultiAsgd::new(&theta0, 1);
        let s = Step { eta: 0.1, gamma: 0.9, lambda: 2.0 };
        let sent = dc.theta().to_vec();
        dc.master_apply(0, &[0.3, 0.4], &sent, s);
        multi.master_apply(0, &[0.3, 0.4], &sent, s);
        assert_eq!(dc.theta(), multi.theta());
    }

    #[test]
    fn compensation_direction_follows_divergence() {
        // master moved to 2.0 while worker saw 1.0; positive gradient gets
        // amplified toward the master's position (Eq 17 by hand).
        let mut dc = DcAsgd::new(&[2.0], 1);
        let s = Step { eta: 1.0, gamma: 0.0, lambda: 0.5 };
        dc.master_apply(0, &[1.0], &[1.0], s);
        // ghat = 1 + 0.5*1*1*(2-1) = 1.5 ; theta = 2 - 1.5
        assert_eq!(dc.theta(), &[0.5]);
    }
}

//! DANA-Slim (paper Algorithm 6, §4.2): DANA with zero master overhead.
//!
//! The Bengio-NAG re-parameterization `Θ_t = θ_t − ηγ Σⱼ vʲ` (Eq 15) folds
//! the look-ahead into the trained parameters themselves.  The momentum
//! vector moves to the worker; the master is *byte-identical to plain ASGD*
//! (it just applies `θ ← θ − η·msg`), and the worker sends the combined
//! update vector
//!
//! ```text
//! v^i  <- gamma * v^i + g^i
//! send gamma * v^i + g^i            (the Bengio-NAG update direction)
//! ```
//!
//! Equation (16) shows the resulting Θ-trajectory equals DANA-Zero's up to
//! the parameter switch — verified exactly by the integration test
//! `dana_slim_equals_dana_zero`.

use super::{Algorithm, AlgorithmKind, Step, WorkerState};
use crate::math;

#[derive(Debug, Clone)]
pub struct DanaSlim {
    theta: Vec<f32>,
}

impl DanaSlim {
    pub fn new(theta0: &[f32]) -> Self {
        DanaSlim { theta: theta0.to_vec() }
    }
}

impl Algorithm for DanaSlim {
    fn kind(&self) -> AlgorithmKind {
        AlgorithmKind::DanaSlim
    }

    fn theta(&self) -> &[f32] {
        &self.theta
    }

    /// Master half == ASGD (Algorithm 2). The message is the worker's
    /// Bengio-NAG update vector, not a raw gradient (footnote 3: both live
    /// in R^k, the master cannot tell and does not care).
    fn master_apply(&mut self, _worker: usize, msg: &[f32], _sent: &[f32], s: Step) {
        math::apply_update(&mut self.theta, msg, s.eta);
    }

    fn worker_message(&self, ws: &mut WorkerState, grad: &mut [f32], s: Step) {
        if ws.v.len() != grad.len() {
            ws.v = vec![0.0; grad.len()];
        }
        // v <- gamma*v + g ; msg <- gamma*v_new + g   (in place over grad)
        math::slim_worker_update_inplace(&mut ws.v, grad, s.gamma);
    }

    fn make_worker_state(&self) -> WorkerState {
        WorkerState { v: vec![0.0; self.theta.len()] }
    }

    fn set_theta(&mut self, theta: &[f32]) {
        self.theta.copy_from_slice(theta);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn master_is_plain_asgd() {
        let mut slim = DanaSlim::new(&[1.0]);
        let mut asgd = super::super::asgd::Asgd::new(&[1.0]);
        let s = Step::default();
        slim.master_apply(0, &[0.25], &[1.0], s);
        asgd.master_apply(0, &[0.25], &[1.0], s);
        assert_eq!(slim.theta(), asgd.theta());
    }

    #[test]
    fn worker_sends_bengio_nag_vector() {
        let slim = DanaSlim::new(&[0.0; 1]);
        let mut ws = slim.make_worker_state();
        let s = Step { eta: 0.1, gamma: 0.5, lambda: 0.0 };
        let mut g = vec![1.0f32];
        slim.worker_message(&mut ws, &mut g, s);
        // v = 0.5*0 + 1 = 1 ; msg = 0.5*1 + 1 = 1.5
        assert_eq!(ws.v, vec![1.0]);
        assert_eq!(g, vec![1.5]);
    }

    #[test]
    fn worker_state_is_per_worker() {
        let slim = DanaSlim::new(&[0.0; 2]);
        let mut wa = slim.make_worker_state();
        let mut wb = slim.make_worker_state();
        let s = Step { eta: 0.1, gamma: 0.9, lambda: 0.0 };
        let mut g = vec![1.0f32, 1.0];
        slim.worker_message(&mut wa, &mut g, s);
        assert_eq!(wb.v, vec![0.0, 0.0]); // untouched
        let mut g2 = vec![1.0f32, 1.0];
        slim.worker_message(&mut wb, &mut g2, s);
        assert_eq!(wa.v, wb.v);
    }
}

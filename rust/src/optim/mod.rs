//! Asynchronous update rules — every algorithm the paper evaluates.
//!
//! Each algorithm implements [`Algorithm`]: the *master half* (how an
//! incoming worker message mutates the master state and what parameters are
//! sent back) and optionally a *worker half* (DANA-Slim keeps the momentum
//! vector worker-side).  The parameter server ([`crate::server`]) owns the
//! FIFO and metric instrumentation and drives this trait; the trait itself
//! is schedule-agnostic — the learning rate and momentum for each step
//! arrive in [`Step`].
//!
//! | Kind          | Paper | Master state                | Send              |
//! |---------------|-------|-----------------------------|-------------------|
//! | `Asgd`        | Alg 2 | θ                           | θ                 |
//! | `NagAsgd`     | Alg 8 | θ, shared v                 | θ                 |
//! | `MultiAsgd`   | Alg 9 | θ, per-worker vᶦ            | θ                 |
//! | `DcAsgd`      | Alg 10| θ, per-worker vᶦ            | θ                 |
//! | `Lwp`         | Alg 3 | θ, shared v                 | θ − τηv           |
//! | `DanaZero`    | Alg 4 | θ, vᶦ, v⁰=Σvᶦ (O(k) A.2)    | θ − ηγv⁰          |
//! | `DanaSlim`    | Alg 6 | θ (= ASGD master)           | θ (worker holds v)|
//! | `DanaDc`      | Alg 7 | θ, vᶦ, v⁰                   | θ − ηγv⁰          |
//! | `YellowFin`   | §5    | θ, shared v + tuner         | θ                 |
//! | `Easgd`       | §6 (future work) | center x̃, replicas xᶦ, vᶦ | xᶦ     |

pub mod asgd;
pub mod dana_dc;
pub mod dana_slim;
pub mod dana_zero;
pub mod dc_asgd;
pub mod easgd;
pub mod lwp;
pub mod multi_asgd;
pub mod nag_asgd;
pub mod schedule;
pub mod sgd;
pub mod yellowfin;

pub use schedule::{LrSchedule, ScheduleConfig};

/// Per-step hyperparameters delivered by the schedule at apply time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Step {
    /// Learning rate η (after warmup/decay).
    pub eta: f32,
    /// Momentum coefficient γ.
    pub gamma: f32,
    /// DC-ASGD delay-compensation strength λ.
    pub lambda: f32,
}

impl Default for Step {
    fn default() -> Self {
        Step { eta: 0.1, gamma: 0.9, lambda: 2.0 }
    }
}

/// Worker-side optimizer state. Only DANA-Slim populates `v`; for every
/// other algorithm the worker is stateless (sends the raw gradient).
#[derive(Debug, Clone, Default)]
pub struct WorkerState {
    pub v: Vec<f32>,
}

/// What happens to a departing worker's momentum (elastic membership).
///
/// The DANA invariant v⁰ = Σ live vᶦ (Appendix A.2) forces a choice when a
/// worker leaves: its momentum either leaves with it or stays in the
/// cluster.  Both policies preserve the invariant exactly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LeavePolicy {
    /// The leaver's momentum is retired with it: v⁰ -= vᶦ, the slot is
    /// zeroed.  The cluster forgets the leaver's velocity immediately.
    #[default]
    Retire,
    /// The leaver's momentum is folded into the surviving cluster: vᶦ is
    /// merged into the lowest live worker's slot (v⁰ unchanged), where it
    /// keeps decaying through that worker's subsequent updates.  Falls back
    /// to [`LeavePolicy::Retire`] when no other worker is live.
    Fold,
}

impl LeavePolicy {
    pub fn name(self) -> &'static str {
        match self {
            LeavePolicy::Retire => "retire",
            LeavePolicy::Fold => "fold",
        }
    }
}

impl std::str::FromStr for LeavePolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "retire" => Ok(LeavePolicy::Retire),
            "fold" => Ok(LeavePolicy::Fold),
            other => anyhow::bail!("unknown leave policy {other:?} (retire|fold)"),
        }
    }
}

impl std::fmt::Display for LeavePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One named piece of checkpointable optimizer state (everything except θ,
/// which the servers snapshot through [`Algorithm::theta`] /
/// [`Algorithm::set_theta`]).
///
/// The three shapes matter to the sharded server: coordinate-aligned state
/// is concatenated across shards at snapshot time and sliced back by
/// [`crate::server::shard_bounds`] at restore time, while shard-replicated
/// scalars (tuner EMAs, τ, α, step counters) are taken from shard 0 and
/// broadcast back to every shard.  f32 state round-trips exactly through
/// the f64 scalar channel (`f32 as f64 as f32` is lossless).
#[derive(Debug, Clone, PartialEq)]
pub enum StateVec {
    /// One f32 per master coordinate (length k), e.g. a shared momentum
    /// vector or v⁰.
    Coord(Vec<f32>),
    /// Per-slot coordinate vectors (n_slots × k), e.g. the DANA family's
    /// vᶦ.  Retired slots are present (zeroed), so the slot indexing of a
    /// restored instance matches the snapshot's exactly.
    PerWorker(Vec<Vec<f32>>),
    /// Coordinate-independent scalars, identical on every shard.
    Scalars(Vec<f64>),
}

/// Ordered, named state entries: what [`Algorithm::state_dict`] returns
/// and [`Algorithm::load_state_dict`] consumes.  Order and names are part
/// of the checkpoint format — load fails closed on any mismatch.
pub type StateDict = Vec<(String, StateVec)>;

/// Load-side helper: look up `name` in `dict` or fail closed.
pub(crate) fn dict_get<'d>(dict: &'d StateDict, name: &str) -> anyhow::Result<&'d StateVec> {
    dict.iter()
        .find(|(n, _)| n == name)
        .map(|(_, v)| v)
        .ok_or_else(|| anyhow::anyhow!("checkpoint state missing entry {name:?}"))
}

/// Load-side helper: a [`StateVec::Coord`] entry of exactly length `k`.
pub(crate) fn dict_coord(dict: &StateDict, name: &str, k: usize) -> anyhow::Result<Vec<f32>> {
    match dict_get(dict, name)? {
        StateVec::Coord(v) => {
            anyhow::ensure!(v.len() == k, "state {name:?}: length {} != k {k}", v.len());
            Ok(v.clone())
        }
        other => anyhow::bail!("state {name:?}: expected Coord, got {other:?}"),
    }
}

/// Load-side helper: a [`StateVec::PerWorker`] entry with `n_slots` vectors
/// of exactly length `k`.
pub(crate) fn dict_per_worker(
    dict: &StateDict,
    name: &str,
    n_slots: usize,
    k: usize,
) -> anyhow::Result<Vec<Vec<f32>>> {
    match dict_get(dict, name)? {
        StateVec::PerWorker(vs) => {
            anyhow::ensure!(
                vs.len() == n_slots,
                "state {name:?}: {} slots != expected {n_slots}",
                vs.len()
            );
            for (i, v) in vs.iter().enumerate() {
                anyhow::ensure!(
                    v.len() == k,
                    "state {name:?}[{i}]: length {} != k {k}",
                    v.len()
                );
            }
            Ok(vs.clone())
        }
        other => anyhow::bail!("state {name:?}: expected PerWorker, got {other:?}"),
    }
}

/// Load-side helper: a [`StateVec::Scalars`] entry of exactly `n` values.
pub(crate) fn dict_scalars(dict: &StateDict, name: &str, n: usize) -> anyhow::Result<Vec<f64>> {
    match dict_get(dict, name)? {
        StateVec::Scalars(v) => {
            anyhow::ensure!(v.len() == n, "state {name:?}: {} scalars != expected {n}", v.len());
            Ok(v.clone())
        }
        other => anyhow::bail!("state {name:?}: expected Scalars, got {other:?}"),
    }
}

/// Sentinel returned by [`Algorithm::add_worker`] for shared-state rules:
/// the rule keeps no per-worker vectors, so any slot id the caller assigns
/// is acceptable.
pub const ANY_SLOT: usize = usize::MAX;

/// Claim the lowest retired slot in `live` (or append a new one) and mark
/// it live.  This is THE deterministic slot-assignment rule — algorithms,
/// both server layouts and the cluster simulator all use it, which is what
/// keeps their independently tracked memberships in agreement.
pub fn claim_slot(live: &mut Vec<bool>) -> usize {
    match live.iter().position(|l| !l) {
        Some(i) => {
            live[i] = true;
            i
        }
        None => {
            live.push(true);
            live.len() - 1
        }
    }
}

/// Join half of the per-worker-momentum membership rule shared by
/// Multi-ASGD, DC-ASGD and the DANA family: claim a slot and make sure its
/// momentum vector exists (retired slots were zeroed at leave time, so a
/// reused slot is already a valid zero vᶦ).
pub(crate) fn join_momentum_slot(
    live: &mut Vec<bool>,
    v: &mut Vec<Vec<f32>>,
    k: usize,
) -> usize {
    let slot = claim_slot(live);
    if slot == v.len() {
        v.push(vec![0.0; k]);
    }
    slot
}

/// Leave half of the shared rule: zero the leaver's vᶦ after applying the
/// policy — Fold merges it into the lowest surviving slot; Retire (or Fold
/// with nobody left) subtracts it from the incremental v⁰ when the rule
/// maintains one (`vsum: Some`, the DANA family) and simply drops it
/// otherwise.  Keeps v⁰ = Σ live vᶦ exact in every case.
pub(crate) fn retire_momentum_slot(
    live: &mut [bool],
    v: &mut [Vec<f32>],
    worker: usize,
    policy: LeavePolicy,
    vsum: Option<&mut [f32]>,
) {
    debug_assert!(live[worker], "remove of retired worker {worker}");
    live[worker] = false;
    let mut leaver = std::mem::take(&mut v[worker]);
    let fold_into = match policy {
        LeavePolicy::Fold => live.iter().position(|&l| l),
        LeavePolicy::Retire => None,
    };
    match (fold_into, vsum) {
        (Some(j), _) => crate::math::axpy(&mut v[j], 1.0, &leaver),
        (None, Some(vsum)) => crate::math::axpy(vsum, -1.0, &leaver),
        (None, None) => {}
    }
    leaver.fill(0.0);
    v[worker] = leaver;
}

/// Additive whole-vector statistics for the sharded two-phase apply.
///
/// Most update rules are purely elementwise, so a contiguous shard of their
/// state evolves independently and sharding is trivially exact.  YellowFin
/// is the exception: its tuner consumes global reductions (‖g‖², the
/// gradient-mean norm, and the realized-momentum projection).  The sharded
/// server therefore runs a two-phase apply: phase 1 collects these partial
/// sums per shard ([`Algorithm::apply_stats`]), the server adds them up
/// (every field is a plain sum over coordinates), and phase 2 applies the
/// elementwise update with the *global* statistics
/// ([`Algorithm::master_apply_with`]) — which keeps every shard's scalar
/// tuner state in lockstep with the monolithic server's.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ApplyStats {
    /// Σ msg² — squared norm of the incoming message.
    pub msg_norm2: f64,
    /// Σ (β·ḡ + (1−β)·msg)² — squared norm of the *post-EMA* gradient mean
    /// (computable read-only before the EMA state is written).
    pub g_avg_norm2: f64,
    /// Σ prev_update · prev_prev_update (realized-momentum numerator).
    pub prev_dot: f64,
    /// Σ prev_prev_update² (realized-momentum denominator).
    pub prev_norm2: f64,
}

impl ApplyStats {
    /// Fold another shard's partials into this one (plain sums).
    pub fn merge(&mut self, other: &ApplyStats) {
        self.msg_norm2 += other.msg_norm2;
        self.g_avg_norm2 += other.g_avg_norm2;
        self.prev_dot += other.prev_dot;
        self.prev_norm2 += other.prev_norm2;
    }
}

/// One asynchronous update rule (master + worker halves).
///
/// `Sync` is required so the sharded server can run its read-only phase-1
/// statistics pass over shards from multiple threads; every implementation
/// is plain owned data.
pub trait Algorithm: Send + Sync {
    fn kind(&self) -> AlgorithmKind;

    /// Master parameters θ⁰ (what eval reads).
    fn theta(&self) -> &[f32];

    fn param_count(&self) -> usize {
        self.theta().len()
    }

    /// Master: apply the message from `worker`. `sent` is the parameter
    /// vector this worker received at pull time (the server retains it for
    /// gap accounting; DC-ASGD's compensation term needs it too).
    fn master_apply(&mut self, worker: usize, msg: &[f32], sent: &[f32], s: Step);

    /// True when [`Self::master_apply`] depends on whole-vector reductions,
    /// i.e. a sharded apply must run the phase-1 statistics pass first.
    /// Elementwise rules (everything except YellowFin) return false and the
    /// sharded server skips the pass entirely.
    fn needs_apply_stats(&self) -> bool {
        false
    }

    /// Phase 1 of the sharded apply: additive partial statistics over this
    /// instance's coordinate range.  Must be read-only; the server sums the
    /// results across shards before phase 2.
    fn apply_stats(&self, worker: usize, msg: &[f32], sent: &[f32]) -> ApplyStats {
        let _ = (worker, msg, sent);
        ApplyStats::default()
    }

    /// Phase 2 of the sharded apply: like [`Self::master_apply`] but with
    /// globally reduced statistics.  Elementwise rules ignore `stats`.
    fn master_apply_with(
        &mut self,
        worker: usize,
        msg: &[f32],
        sent: &[f32],
        s: Step,
        stats: &ApplyStats,
    ) {
        let _ = stats;
        self.master_apply(worker, msg, sent, s);
    }

    /// Master: write the parameters to send to `worker` into `out`.
    /// Default: the current master parameters (plain ASGD behaviour).
    ///
    /// Takes `&self`: every send is a pure read of master state (θ, v⁰,
    /// replicas), which is what lets the striped server serve pulls under
    /// per-shard *read* locks, concurrently with each other and with other
    /// shards' applies.
    fn master_send(&self, worker: usize, out: &mut [f32], s: Step) {
        let _ = worker;
        let _ = s;
        out.copy_from_slice(self.theta());
    }

    /// Worker: turn a locally computed gradient into the message sent to the
    /// master, updating worker-local state. Default: send the gradient.
    fn worker_message(&self, ws: &mut WorkerState, grad: &mut [f32], s: Step) {
        let _ = ws;
        let _ = grad;
        let _ = s;
    }

    /// Fresh worker-local state for one worker.
    fn make_worker_state(&self) -> WorkerState {
        WorkerState::default()
    }

    /// Momentum correction (Goyal et al. 2017): rescale momentum state when
    /// the learning rate changes by `ratio = eta_new / eta_old`.
    fn rescale_momentum(&mut self, ratio: f32) {
        let _ = ratio;
    }

    /// Pipeline staleness hint: every pull this rule serves will be
    /// consumed `extra_steps` additional *own* steps in the future (the
    /// worker keeps `extra_steps + 1` batches in flight).  Prediction-based
    /// rules compensate — DANA/DANA-DC extrapolate their Eq 11 look-ahead
    /// `extra_steps` further momentum-only steps, NAG-ASGD sends the
    /// momentum-extrapolated future position, and LWP stretches its
    /// prediction horizon τ by the in-flight multiplicity — while
    /// gradient-difference rules (DC-ASGD's Taylor term is computed from
    /// the *actual* θ−θ_sent displacement at apply time) are already
    /// self-scaling.  Default: no-op; `extra_steps = 0` MUST leave every
    /// rule bit-for-bit at its unhinted behavior.
    fn set_staleness_hint(&mut self, extra_steps: usize) {
        let _ = extra_steps;
    }

    /// A worker joins the cluster: allocate per-worker state for it and
    /// return the slot id ([`claim_slot`] rule: lowest retired slot, else
    /// append).  Shared-state rules keep the default, which is a no-op
    /// returning [`ANY_SLOT`] — the server assigns the slot itself.
    ///
    /// A joiner always starts with zero momentum, so for the DANA family
    /// v⁰ = Σ live vᶦ holds across the join without touching v⁰.
    fn add_worker(&mut self) -> usize {
        ANY_SLOT
    }

    /// A worker leaves the cluster: retire its per-worker state.  `policy`
    /// decides the fate of its momentum (see [`LeavePolicy`]); the DANA
    /// family must keep v⁰ = Σ live vᶦ exact through the removal.  Default:
    /// no-op (shared-state rules).  Callers (the servers) validate that
    /// `worker` is live before delegating here.
    fn remove_worker(&mut self, worker: usize, policy: LeavePolicy) {
        let _ = (worker, policy);
    }

    /// Overwrite master parameters (checkpoint restore / tests).
    fn set_theta(&mut self, theta: &[f32]);

    /// Checkpointable auxiliary state — everything except θ (momenta, v⁰,
    /// replicas, tuner statistics).  Stateless rules return an empty dict.
    /// Slot liveness is NOT part of the dict: the servers replay
    /// membership before loading, so per-worker entries only need the
    /// right slot count (retired slots zeroed).
    fn state_dict(&self) -> StateDict {
        Vec::new()
    }

    /// Restore state produced by [`Self::state_dict`] onto an instance
    /// with identical shape (same k, same slot count and liveness).
    /// Fails closed on missing/extra entries or length mismatches; the
    /// instance is left unspecified on error (callers discard it).
    fn load_state_dict(&mut self, dict: &StateDict) -> anyhow::Result<()> {
        anyhow::ensure!(
            dict.is_empty(),
            "{}: unexpected checkpoint state entries: {:?}",
            self.kind().name(),
            dict.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>()
        );
        Ok(())
    }
}

/// Which update rule to instantiate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AlgorithmKind {
    Asgd,
    NagAsgd,
    MultiAsgd,
    DcAsgd,
    Lwp,
    DanaZero,
    DanaSlim,
    DanaDc,
    YellowFin,
    Easgd,
}

impl AlgorithmKind {
    pub const ALL: [AlgorithmKind; 10] = [
        AlgorithmKind::Asgd,
        AlgorithmKind::NagAsgd,
        AlgorithmKind::MultiAsgd,
        AlgorithmKind::DcAsgd,
        AlgorithmKind::Lwp,
        AlgorithmKind::DanaZero,
        AlgorithmKind::DanaSlim,
        AlgorithmKind::DanaDc,
        AlgorithmKind::YellowFin,
        AlgorithmKind::Easgd,
    ];

    /// The set compared in the paper's accuracy figures (Fig 4/5/7).
    pub const PAPER_SET: [AlgorithmKind; 6] = [
        AlgorithmKind::DanaDc,
        AlgorithmKind::DanaSlim,
        AlgorithmKind::DcAsgd,
        AlgorithmKind::MultiAsgd,
        AlgorithmKind::NagAsgd,
        AlgorithmKind::YellowFin,
    ];

    pub fn name(self) -> &'static str {
        match self {
            AlgorithmKind::Asgd => "asgd",
            AlgorithmKind::NagAsgd => "nag-asgd",
            AlgorithmKind::MultiAsgd => "multi-asgd",
            AlgorithmKind::DcAsgd => "dc-asgd",
            AlgorithmKind::Lwp => "lwp",
            AlgorithmKind::DanaZero => "dana-zero",
            AlgorithmKind::DanaSlim => "dana-slim",
            AlgorithmKind::DanaDc => "dana-dc",
            AlgorithmKind::YellowFin => "yellowfin",
            AlgorithmKind::Easgd => "easgd",
        }
    }
}

impl std::str::FromStr for AlgorithmKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let norm = s.to_ascii_lowercase().replace('_', "-");
        AlgorithmKind::ALL
            .into_iter()
            .find(|k| k.name() == norm)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown algorithm {s:?}; known: {}",
                    AlgorithmKind::ALL.map(|k| k.name()).join(", ")
                )
            })
    }
}

impl std::fmt::Display for AlgorithmKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Instantiate an algorithm over initial parameters for `n_workers`.
pub fn make_algorithm(
    kind: AlgorithmKind,
    theta0: &[f32],
    n_workers: usize,
) -> Box<dyn Algorithm> {
    match kind {
        AlgorithmKind::Asgd => Box::new(asgd::Asgd::new(theta0)),
        AlgorithmKind::NagAsgd => Box::new(nag_asgd::NagAsgd::new(theta0)),
        AlgorithmKind::MultiAsgd => Box::new(multi_asgd::MultiAsgd::new(theta0, n_workers)),
        AlgorithmKind::DcAsgd => Box::new(dc_asgd::DcAsgd::new(theta0, n_workers)),
        AlgorithmKind::Lwp => Box::new(lwp::Lwp::new(theta0, n_workers)),
        AlgorithmKind::DanaZero => Box::new(dana_zero::DanaZero::new(theta0, n_workers)),
        AlgorithmKind::DanaSlim => Box::new(dana_slim::DanaSlim::new(theta0)),
        AlgorithmKind::DanaDc => Box::new(dana_dc::DanaDc::new(theta0, n_workers)),
        AlgorithmKind::YellowFin => Box::new(yellowfin::YellowFin::new(theta0)),
        AlgorithmKind::Easgd => Box::new(easgd::Easgd::new(theta0, n_workers)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_round_trips_via_str() {
        for k in AlgorithmKind::ALL {
            assert_eq!(k.name().parse::<AlgorithmKind>().unwrap(), k);
        }
        assert!("nonsense".parse::<AlgorithmKind>().is_err());
        assert_eq!(
            "DANA_SLIM".parse::<AlgorithmKind>().unwrap(),
            AlgorithmKind::DanaSlim
        );
    }

    #[test]
    fn factory_produces_matching_kind() {
        let theta0 = vec![0.0f32; 16];
        for k in AlgorithmKind::ALL {
            let alg = make_algorithm(k, &theta0, 4);
            assert_eq!(alg.kind(), k);
            assert_eq!(alg.param_count(), 16);
            assert_eq!(alg.theta(), &theta0[..]);
        }
    }

    #[test]
    fn default_apply_with_matches_master_apply() {
        let theta0: Vec<f32> = (0..8).map(|i| i as f32 * 0.1).collect();
        let mut a = make_algorithm(AlgorithmKind::NagAsgd, &theta0, 2);
        let mut b = make_algorithm(AlgorithmKind::NagAsgd, &theta0, 2);
        let g = vec![0.5f32; 8];
        let sent = theta0.clone();
        assert!(!a.needs_apply_stats());
        let stats = a.apply_stats(0, &g, &sent);
        assert_eq!(stats, ApplyStats::default());
        a.master_apply_with(0, &g, &sent, Step::default(), &stats);
        b.master_apply(0, &g, &sent, Step::default());
        assert_eq!(a.theta(), b.theta());
    }

    #[test]
    fn apply_stats_merge_is_fieldwise_sum() {
        let mut a = ApplyStats { msg_norm2: 1.0, g_avg_norm2: 2.0, prev_dot: 3.0, prev_norm2: 4.0 };
        let b = ApplyStats { msg_norm2: 0.5, g_avg_norm2: 0.25, prev_dot: -3.0, prev_norm2: 1.0 };
        a.merge(&b);
        assert_eq!(
            a,
            ApplyStats { msg_norm2: 1.5, g_avg_norm2: 2.25, prev_dot: 0.0, prev_norm2: 5.0 }
        );
    }

    #[test]
    fn claim_slot_reuses_lowest_retired() {
        let mut live = vec![true, false, true, false];
        assert_eq!(claim_slot(&mut live), 1);
        assert_eq!(claim_slot(&mut live), 3);
        assert_eq!(claim_slot(&mut live), 4, "full house appends");
        assert_eq!(live, vec![true; 5]);
    }

    #[test]
    fn leave_policy_parses() {
        assert_eq!("retire".parse::<LeavePolicy>().unwrap(), LeavePolicy::Retire);
        assert_eq!("FOLD".parse::<LeavePolicy>().unwrap(), LeavePolicy::Fold);
        assert!("meld".parse::<LeavePolicy>().is_err());
        assert_eq!(LeavePolicy::default(), LeavePolicy::Retire);
    }

    #[test]
    fn shared_state_rules_default_membership_noops() {
        // Asgd/NagAsgd/DanaSlim/YellowFin keep no per-worker vectors: join
        // returns the ANY_SLOT sentinel and leave is a no-op.
        let theta0 = vec![1.0f32; 4];
        for kind in [
            AlgorithmKind::Asgd,
            AlgorithmKind::NagAsgd,
            AlgorithmKind::DanaSlim,
            AlgorithmKind::YellowFin,
        ] {
            let mut alg = make_algorithm(kind, &theta0, 2);
            assert_eq!(alg.add_worker(), ANY_SLOT, "{kind}");
            alg.remove_worker(0, LeavePolicy::Retire);
            assert_eq!(alg.theta(), &theta0[..], "{kind}: membership touched theta");
        }
    }

    #[test]
    fn state_dict_round_trips_for_all_kinds() {
        // Drive updates + a membership change, snapshot, rebuild an
        // identically-shaped instance, load, and require the continued
        // trajectories to agree bit-for-bit.
        let k = 13;
        let theta0: Vec<f32> = (0..k).map(|i| (i as f32 * 0.37).sin()).collect();
        let s = Step { eta: 0.05, gamma: 0.9, lambda: 1.0 };
        let mut rng = crate::util::rng::Rng::new(11);
        for kind in AlgorithmKind::ALL {
            let mut a = make_algorithm(kind, &theta0, 3);
            for i in 0..25 {
                let g: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
                let mut sent = vec![0.0f32; k];
                a.master_send(i % 3, &mut sent, s);
                a.master_apply(i % 3, &g, &sent, s);
            }
            a.remove_worker(1, LeavePolicy::Retire);
            // restore path: same construction, same membership replay,
            // then theta + dict
            let mut b = make_algorithm(kind, &theta0, 3);
            b.remove_worker(1, LeavePolicy::Retire);
            b.set_theta(a.theta());
            b.load_state_dict(&a.state_dict()).unwrap_or_else(|e| panic!("{kind}: {e}"));
            assert_eq!(a.theta(), b.theta(), "{kind}: theta");
            for i in 0..10 {
                let w = [0, 2][i % 2];
                let g: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
                let mut sa = vec![0.0f32; k];
                let mut sb = vec![0.0f32; k];
                a.master_send(w, &mut sa, s);
                b.master_send(w, &mut sb, s);
                assert_eq!(sa, sb, "{kind}: send diverged after restore");
                a.master_apply(w, &g, &sa, s);
                b.master_apply(w, &g, &sb, s);
                assert_eq!(a.theta(), b.theta(), "{kind}: theta diverged after restore");
            }
        }
    }

    #[test]
    fn load_state_dict_fails_closed() {
        let theta0 = vec![0.0f32; 4];
        // stateless rule rejects unexpected entries
        let mut asgd = make_algorithm(AlgorithmKind::Asgd, &theta0, 1);
        let junk: StateDict = vec![("v".to_string(), StateVec::Coord(vec![0.0; 4]))];
        assert!(asgd.load_state_dict(&junk).is_err());
        // stateful rule rejects missing entries and wrong lengths
        let mut nag = make_algorithm(AlgorithmKind::NagAsgd, &theta0, 1);
        assert!(nag.load_state_dict(&Vec::new()).is_err());
        let short: StateDict = vec![("v".to_string(), StateVec::Coord(vec![0.0; 3]))];
        assert!(nag.load_state_dict(&short).is_err());
        let wrong_shape: StateDict =
            vec![("v".to_string(), StateVec::PerWorker(vec![vec![0.0; 4]]))];
        assert!(nag.load_state_dict(&wrong_shape).is_err());
    }

    #[test]
    fn default_send_is_theta() {
        let theta0: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let mut alg = make_algorithm(AlgorithmKind::Asgd, &theta0, 2);
        let mut out = vec![0.0; 8];
        alg.master_send(0, &mut out, Step::default());
        assert_eq!(out, theta0);
    }
}

//! Asynchronous update rules — every algorithm the paper evaluates.
//!
//! Each algorithm implements [`Algorithm`]: the *master half* (how an
//! incoming worker message mutates the master state and what parameters are
//! sent back) and optionally a *worker half* (DANA-Slim keeps the momentum
//! vector worker-side).  The parameter server ([`crate::server`]) owns the
//! FIFO and metric instrumentation and drives this trait; the trait itself
//! is schedule-agnostic — the learning rate and momentum for each step
//! arrive in [`Step`].
//!
//! | Kind          | Paper | Master state                | Send              |
//! |---------------|-------|-----------------------------|-------------------|
//! | `Asgd`        | Alg 2 | θ                           | θ                 |
//! | `NagAsgd`     | Alg 8 | θ, shared v                 | θ                 |
//! | `MultiAsgd`   | Alg 9 | θ, per-worker vᶦ            | θ                 |
//! | `DcAsgd`      | Alg 10| θ, per-worker vᶦ            | θ                 |
//! | `Lwp`         | Alg 3 | θ, shared v                 | θ − τηv           |
//! | `DanaZero`    | Alg 4 | θ, vᶦ, v⁰=Σvᶦ (O(k) A.2)    | θ − ηγv⁰          |
//! | `DanaSlim`    | Alg 6 | θ (= ASGD master)           | θ (worker holds v)|
//! | `DanaDc`      | Alg 7 | θ, vᶦ, v⁰                   | θ − ηγv⁰          |
//! | `YellowFin`   | §5    | θ, shared v + tuner         | θ                 |
//! | `Easgd`       | §6 (future work) | center x̃, replicas xᶦ, vᶦ | xᶦ     |

pub mod asgd;
pub mod dana_dc;
pub mod dana_slim;
pub mod dana_zero;
pub mod dc_asgd;
pub mod easgd;
pub mod lwp;
pub mod multi_asgd;
pub mod nag_asgd;
pub mod schedule;
pub mod sgd;
pub mod yellowfin;

pub use schedule::{LrSchedule, ScheduleConfig};

/// Per-step hyperparameters delivered by the schedule at apply time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Step {
    /// Learning rate η (after warmup/decay).
    pub eta: f32,
    /// Momentum coefficient γ.
    pub gamma: f32,
    /// DC-ASGD delay-compensation strength λ.
    pub lambda: f32,
}

impl Default for Step {
    fn default() -> Self {
        Step { eta: 0.1, gamma: 0.9, lambda: 2.0 }
    }
}

/// Worker-side optimizer state. Only DANA-Slim populates `v`; for every
/// other algorithm the worker is stateless (sends the raw gradient).
#[derive(Debug, Clone, Default)]
pub struct WorkerState {
    pub v: Vec<f32>,
}

/// One asynchronous update rule (master + worker halves).
pub trait Algorithm: Send {
    fn kind(&self) -> AlgorithmKind;

    /// Master parameters θ⁰ (what eval reads).
    fn theta(&self) -> &[f32];

    fn param_count(&self) -> usize {
        self.theta().len()
    }

    /// Master: apply the message from `worker`. `sent` is the parameter
    /// vector this worker received at pull time (the server retains it for
    /// gap accounting; DC-ASGD's compensation term needs it too).
    fn master_apply(&mut self, worker: usize, msg: &[f32], sent: &[f32], s: Step);

    /// Master: write the parameters to send to `worker` into `out`.
    /// Default: the current master parameters (plain ASGD behaviour).
    fn master_send(&mut self, worker: usize, out: &mut [f32], s: Step) {
        let _ = worker;
        let _ = s;
        out.copy_from_slice(self.theta());
    }

    /// Worker: turn a locally computed gradient into the message sent to the
    /// master, updating worker-local state. Default: send the gradient.
    fn worker_message(&self, ws: &mut WorkerState, grad: &mut [f32], s: Step) {
        let _ = ws;
        let _ = grad;
        let _ = s;
    }

    /// Fresh worker-local state for one worker.
    fn make_worker_state(&self) -> WorkerState {
        WorkerState::default()
    }

    /// Momentum correction (Goyal et al. 2017): rescale momentum state when
    /// the learning rate changes by `ratio = eta_new / eta_old`.
    fn rescale_momentum(&mut self, ratio: f32) {
        let _ = ratio;
    }

    /// Overwrite master parameters (checkpoint restore / tests).
    fn set_theta(&mut self, theta: &[f32]);
}

/// Which update rule to instantiate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AlgorithmKind {
    Asgd,
    NagAsgd,
    MultiAsgd,
    DcAsgd,
    Lwp,
    DanaZero,
    DanaSlim,
    DanaDc,
    YellowFin,
    Easgd,
}

impl AlgorithmKind {
    pub const ALL: [AlgorithmKind; 10] = [
        AlgorithmKind::Asgd,
        AlgorithmKind::NagAsgd,
        AlgorithmKind::MultiAsgd,
        AlgorithmKind::DcAsgd,
        AlgorithmKind::Lwp,
        AlgorithmKind::DanaZero,
        AlgorithmKind::DanaSlim,
        AlgorithmKind::DanaDc,
        AlgorithmKind::YellowFin,
        AlgorithmKind::Easgd,
    ];

    /// The set compared in the paper's accuracy figures (Fig 4/5/7).
    pub const PAPER_SET: [AlgorithmKind; 6] = [
        AlgorithmKind::DanaDc,
        AlgorithmKind::DanaSlim,
        AlgorithmKind::DcAsgd,
        AlgorithmKind::MultiAsgd,
        AlgorithmKind::NagAsgd,
        AlgorithmKind::YellowFin,
    ];

    pub fn name(self) -> &'static str {
        match self {
            AlgorithmKind::Asgd => "asgd",
            AlgorithmKind::NagAsgd => "nag-asgd",
            AlgorithmKind::MultiAsgd => "multi-asgd",
            AlgorithmKind::DcAsgd => "dc-asgd",
            AlgorithmKind::Lwp => "lwp",
            AlgorithmKind::DanaZero => "dana-zero",
            AlgorithmKind::DanaSlim => "dana-slim",
            AlgorithmKind::DanaDc => "dana-dc",
            AlgorithmKind::YellowFin => "yellowfin",
            AlgorithmKind::Easgd => "easgd",
        }
    }
}

impl std::str::FromStr for AlgorithmKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let norm = s.to_ascii_lowercase().replace('_', "-");
        AlgorithmKind::ALL
            .into_iter()
            .find(|k| k.name() == norm)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown algorithm {s:?}; known: {}",
                    AlgorithmKind::ALL.map(|k| k.name()).join(", ")
                )
            })
    }
}

impl std::fmt::Display for AlgorithmKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Instantiate an algorithm over initial parameters for `n_workers`.
pub fn make_algorithm(
    kind: AlgorithmKind,
    theta0: &[f32],
    n_workers: usize,
) -> Box<dyn Algorithm> {
    match kind {
        AlgorithmKind::Asgd => Box::new(asgd::Asgd::new(theta0)),
        AlgorithmKind::NagAsgd => Box::new(nag_asgd::NagAsgd::new(theta0)),
        AlgorithmKind::MultiAsgd => Box::new(multi_asgd::MultiAsgd::new(theta0, n_workers)),
        AlgorithmKind::DcAsgd => Box::new(dc_asgd::DcAsgd::new(theta0, n_workers)),
        AlgorithmKind::Lwp => Box::new(lwp::Lwp::new(theta0, n_workers)),
        AlgorithmKind::DanaZero => Box::new(dana_zero::DanaZero::new(theta0, n_workers)),
        AlgorithmKind::DanaSlim => Box::new(dana_slim::DanaSlim::new(theta0)),
        AlgorithmKind::DanaDc => Box::new(dana_dc::DanaDc::new(theta0, n_workers)),
        AlgorithmKind::YellowFin => Box::new(yellowfin::YellowFin::new(theta0)),
        AlgorithmKind::Easgd => Box::new(easgd::Easgd::new(theta0, n_workers)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_round_trips_via_str() {
        for k in AlgorithmKind::ALL {
            assert_eq!(k.name().parse::<AlgorithmKind>().unwrap(), k);
        }
        assert!("nonsense".parse::<AlgorithmKind>().is_err());
        assert_eq!(
            "DANA_SLIM".parse::<AlgorithmKind>().unwrap(),
            AlgorithmKind::DanaSlim
        );
    }

    #[test]
    fn factory_produces_matching_kind() {
        let theta0 = vec![0.0f32; 16];
        for k in AlgorithmKind::ALL {
            let alg = make_algorithm(k, &theta0, 4);
            assert_eq!(alg.kind(), k);
            assert_eq!(alg.param_count(), 16);
            assert_eq!(alg.theta(), &theta0[..]);
        }
    }

    #[test]
    fn default_send_is_theta() {
        let theta0: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let mut alg = make_algorithm(AlgorithmKind::Asgd, &theta0, 2);
        let mut out = vec![0.0; 8];
        alg.master_send(0, &mut out, Step::default());
        assert_eq!(out, theta0);
    }
}

//! Learning-rate schedule: gradual warmup + step decay (Appendix A.5).
//!
//! All experiments share the paper's hyperparameter policy: the base η is
//! the single-worker value from the architecture's original paper; for N
//! workers it starts at `base/N` and ramps linearly to `base` over the
//! first five epochs (Goyal et al. 2017), then decays by a fixed factor at
//! scheduled epochs.  The server applies *momentum correction* — rescaling
//! momentum state by `eta_new/eta_old` — whenever the schedule moves.

#[derive(Debug, Clone, PartialEq)]
pub struct ScheduleConfig {
    /// Tuned single-worker learning rate.
    pub base_eta: f32,
    /// Momentum coefficient γ.
    pub gamma: f32,
    /// DC compensation strength λ.
    pub lambda: f32,
    /// Warmup duration in epochs (0 disables; paper uses 5).
    pub warmup_epochs: f64,
    /// Epochs at which η is multiplied by `decay_factor`.
    pub decay_epochs: Vec<f64>,
    pub decay_factor: f32,
    /// Master updates per epoch (dataset_size / batch, aggregated over the
    /// cluster — every master update consumes one batch).
    pub steps_per_epoch: usize,
    /// Cluster size N (warmup divides the initial η by N).
    pub n_workers: usize,
}

impl Default for ScheduleConfig {
    fn default() -> Self {
        // the ResNet-20/CIFAR-10 recipe scaled: decay at 1/2 and 3/4 depth
        ScheduleConfig {
            base_eta: 0.1,
            gamma: 0.9,
            lambda: 2.0,
            warmup_epochs: 5.0,
            decay_epochs: vec![80.0, 120.0],
            decay_factor: 0.1,
            steps_per_epoch: 390,
            n_workers: 1,
        }
    }
}

#[derive(Debug, Clone)]
pub struct LrSchedule {
    cfg: ScheduleConfig,
}

impl LrSchedule {
    pub fn new(cfg: ScheduleConfig) -> Self {
        assert!(cfg.steps_per_epoch > 0, "steps_per_epoch must be positive");
        assert!(cfg.n_workers > 0);
        LrSchedule { cfg }
    }

    pub fn config(&self) -> &ScheduleConfig {
        &self.cfg
    }

    pub fn epoch_of(&self, master_step: u64) -> f64 {
        master_step as f64 / self.cfg.steps_per_epoch as f64
    }

    /// η at a master step: warmup ramp then multiplicative decay.
    pub fn eta_at(&self, master_step: u64) -> f32 {
        let c = &self.cfg;
        let epoch = self.epoch_of(master_step);
        let mut eta = c.base_eta;
        if c.warmup_epochs > 0.0 && c.n_workers > 1 && epoch < c.warmup_epochs {
            let start = c.base_eta / c.n_workers as f32;
            let frac = (epoch / c.warmup_epochs) as f32;
            eta = start + (c.base_eta - start) * frac;
        }
        for &d in &c.decay_epochs {
            if epoch >= d {
                eta *= c.decay_factor;
            }
        }
        eta
    }

    pub fn gamma(&self) -> f32 {
        self.cfg.gamma
    }

    pub fn step_at(&self, master_step: u64) -> super::Step {
        super::Step {
            eta: self.eta_at(master_step),
            gamma: self.cfg.gamma,
            lambda: self.cfg.lambda,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(n: usize) -> ScheduleConfig {
        ScheduleConfig {
            base_eta: 0.1,
            warmup_epochs: 5.0,
            decay_epochs: vec![80.0, 120.0],
            decay_factor: 0.1,
            steps_per_epoch: 100,
            n_workers: n,
            ..ScheduleConfig::default()
        }
    }

    #[test]
    fn warmup_starts_at_base_over_n() {
        let s = LrSchedule::new(cfg(8));
        assert!((s.eta_at(0) - 0.1 / 8.0).abs() < 1e-7);
        // ramped past start shortly after
        assert!(s.eta_at(100) > s.eta_at(0));
        // at warmup end: full base
        assert!((s.eta_at(500) - 0.1).abs() < 1e-6);
    }

    #[test]
    fn single_worker_has_no_warmup() {
        let s = LrSchedule::new(cfg(1));
        assert_eq!(s.eta_at(0), 0.1);
    }

    #[test]
    fn decay_applies_multiplicatively() {
        let s = LrSchedule::new(cfg(1));
        assert!((s.eta_at(80 * 100) - 0.01).abs() < 1e-7);
        assert!((s.eta_at(120 * 100) - 0.001).abs() < 1e-8);
        assert!((s.eta_at(79 * 100) - 0.1).abs() < 1e-7);
    }

    #[test]
    fn monotone_through_warmup() {
        let s = LrSchedule::new(cfg(4));
        let mut prev = 0.0;
        for step in (0..500).step_by(10) {
            let e = s.eta_at(step);
            assert!(e >= prev, "warmup must be non-decreasing");
            prev = e;
        }
    }
}

//! Plain asynchronous SGD (paper Algorithm 2) — no momentum.
//!
//! The gap baseline of Section 3: its Δ is just the sum of the other
//! workers' recent gradients (Eq 7), which is what DANA's look-ahead is
//! engineered to match (Eq 12).

use super::{Algorithm, AlgorithmKind, Step};
use crate::math;

#[derive(Debug, Clone)]
pub struct Asgd {
    theta: Vec<f32>,
}

impl Asgd {
    pub fn new(theta0: &[f32]) -> Self {
        Asgd { theta: theta0.to_vec() }
    }
}

impl Algorithm for Asgd {
    fn kind(&self) -> AlgorithmKind {
        AlgorithmKind::Asgd
    }

    fn theta(&self) -> &[f32] {
        &self.theta
    }

    fn master_apply(&mut self, _worker: usize, msg: &[f32], _sent: &[f32], s: Step) {
        math::apply_update(&mut self.theta, msg, s.eta);
    }

    fn set_theta(&mut self, theta: &[f32]) {
        self.theta.copy_from_slice(theta);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn applies_plain_sgd_step() {
        let mut a = Asgd::new(&[1.0, 2.0]);
        let s = Step { eta: 0.5, ..Step::default() };
        a.master_apply(0, &[1.0, -1.0], &[1.0, 2.0], s);
        assert_eq!(a.theta(), &[0.5, 2.5]);
    }

    #[test]
    fn workers_share_one_theta() {
        let mut a = Asgd::new(&[0.0]);
        let s = Step { eta: 1.0, ..Step::default() };
        a.master_apply(0, &[1.0], &[0.0], s);
        a.master_apply(3, &[1.0], &[0.0], s);
        assert_eq!(a.theta(), &[-2.0]);
    }
}

//! Multi-ASGD (paper Algorithm 9, Appendix A.1): per-worker momentum
//! vectors at the master, *no* look-ahead.
//!
//! The paper's ablation: it fixes NAG-ASGD's multiplicity problem (each vᶦ
//! sees only worker i's gradients) but still sends the stale θ⁰, so its gap
//! remains momentum-sized.  Its mid-pack accuracy in Fig 4 demonstrates that
//! per-worker momentum alone is not sufficient — the look-ahead is what
//! closes the gap.

use super::{dict_per_worker, Algorithm, AlgorithmKind, LeavePolicy, StateDict, StateVec, Step};
use crate::math;

#[derive(Debug, Clone)]
pub struct MultiAsgd {
    theta: Vec<f32>,
    v: Vec<Vec<f32>>,
    /// Slot liveness (elastic membership).
    live: Vec<bool>,
}

impl MultiAsgd {
    pub fn new(theta0: &[f32], n_workers: usize) -> Self {
        MultiAsgd {
            theta: theta0.to_vec(),
            v: vec![vec![0.0; theta0.len()]; n_workers],
            live: vec![true; n_workers],
        }
    }

    pub fn velocity(&self, worker: usize) -> &[f32] {
        &self.v[worker]
    }
}

impl Algorithm for MultiAsgd {
    fn kind(&self) -> AlgorithmKind {
        AlgorithmKind::MultiAsgd
    }

    fn theta(&self) -> &[f32] {
        &self.theta
    }

    fn master_apply(&mut self, worker: usize, msg: &[f32], _sent: &[f32], s: Step) {
        // v^i <- gamma*v^i + g^i ; theta <- theta - eta*v^i
        math::momentum_step(&mut self.theta, &mut self.v[worker], msg, s.gamma, s.eta);
    }

    fn rescale_momentum(&mut self, ratio: f32) {
        for v in &mut self.v {
            math::scale(v, ratio);
        }
    }

    fn add_worker(&mut self) -> usize {
        super::join_momentum_slot(&mut self.live, &mut self.v, self.theta.len())
    }

    fn remove_worker(&mut self, worker: usize, policy: LeavePolicy) {
        // No v⁰ here (vsum: None): Retire simply drops the leaver's
        // momentum; Fold merges it into the lowest surviving slot.
        super::retire_momentum_slot(&mut self.live, &mut self.v, worker, policy, None);
    }

    fn state_dict(&self) -> StateDict {
        vec![("v".to_string(), StateVec::PerWorker(self.v.clone()))]
    }

    fn load_state_dict(&mut self, dict: &StateDict) -> anyhow::Result<()> {
        self.v = dict_per_worker(dict, "v", self.v.len(), self.theta.len())?;
        Ok(())
    }

    fn set_theta(&mut self, theta: &[f32]) {
        self.theta.copy_from_slice(theta);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leave_and_rejoin_resets_slot_momentum() {
        let mut a = MultiAsgd::new(&[0.0], 2);
        let s = Step { eta: 1.0, gamma: 0.5, lambda: 0.0 };
        a.master_apply(1, &[1.0], &[0.0], s);
        a.remove_worker(1, LeavePolicy::Retire);
        assert_eq!(a.add_worker(), 1);
        assert_eq!(a.velocity(1), &[0.0]);
        // fold path: survivor inherits
        a.master_apply(0, &[2.0], &[0.0], s);
        a.master_apply(1, &[4.0], &[0.0], s);
        a.remove_worker(0, LeavePolicy::Fold);
        assert_eq!(a.velocity(1), &[6.0]);
    }

    #[test]
    fn momenta_are_isolated_per_worker() {
        let mut a = MultiAsgd::new(&[0.0], 2);
        let s = Step { eta: 1.0, gamma: 0.5, lambda: 0.0 };
        a.master_apply(0, &[1.0], &[0.0], s);
        a.master_apply(1, &[1.0], &[0.0], s);
        // each v starts at 0: v0 = v1 = 1.0 (no cross-contamination)
        assert_eq!(a.velocity(0), &[1.0]);
        assert_eq!(a.velocity(1), &[1.0]);
        assert_eq!(a.theta(), &[-2.0]);
    }

    #[test]
    fn single_worker_reduces_to_heavy_ball() {
        let mut multi = MultiAsgd::new(&[0.0], 1);
        let mut nag = super::super::nag_asgd::NagAsgd::new(&[0.0]);
        let s = Step { eta: 0.1, gamma: 0.9, lambda: 0.0 };
        for i in 0..10 {
            let g = [(i as f32 * 0.7).sin()];
            multi.master_apply(0, &g, &[0.0], s);
            nag.master_apply(0, &g, &[0.0], s);
        }
        assert_eq!(multi.theta(), nag.theta());
    }
}

//! YellowFin with closed-loop momentum (Zhang & Mitliagkas 2019).
//!
//! An auto-tuning SGD baseline: learning rate and momentum are derived each
//! step from three online statistics of the gradient stream —
//!
//! * **curvature range** `h_min..h_max`: extremes of `||g||²` over a sliding
//!   window, EMA-smoothed,
//! * **gradient variance** `C = E[||g||²] − ||E[g]||²` (per-coordinate EMA),
//! * **distance to optimum** `D = E[||g||] / E[||g||²]`,
//!
//! then the *SingleStep* problem is solved in closed form (the cubic from
//! the YF paper/code, `get_cubic_root`) for the target momentum μ and
//! `lr = (1 − √μ)² / h_min`.
//!
//! The **closed-loop** extension for asynchronous training measures the
//! *realized total* momentum (asynchrony adds implicit momentum —
//! Mitliagkas et al. 2016) by projecting each master update onto the
//! previous one, then feeds back the difference so that algorithmic +
//! implicit momentum ≈ target.  Following the paper's §5 we initialize with
//! `eta = 1e-4, gamma = 0`.
//!
//! Faithfulness note: this is reimplemented from the published description
//! and the reference implementation's update equations; the sliding-window
//! length (20), EMA β (0.999) and feedback gain (0.3) follow the reference
//! defaults.  YellowFin is a *baseline* in this paper — the evaluation
//! expects it to work at small N and degrade at scale (Tables 2–5).

use super::{
    dict_coord, dict_get, dict_scalars, Algorithm, AlgorithmKind, ApplyStats, StateDict, StateVec,
    Step,
};
use crate::math;
use std::collections::VecDeque;

const WINDOW: usize = 20;
const BETA: f64 = 0.999;
const CLOSED_LOOP_GAIN: f64 = 0.3;

#[derive(Debug, Clone)]
pub struct YellowFin {
    theta: Vec<f32>,
    v: Vec<f32>,
    /// EMA of the gradient (for the variance estimate C).
    g_avg: Vec<f32>,
    /// Previous master update (for realized-momentum measurement).
    prev_update: Vec<f32>,
    prev_prev_update: Vec<f32>,
    h_window: VecDeque<f64>,
    h_min_avg: f64,
    h_max_avg: f64,
    g_norm_avg: f64,
    g_norm2_avg: f64,
    dist_avg: f64,
    /// Tuned values (EMA-smoothed outputs of SingleStep).
    lr: f64,
    mu: f64,
    /// Closed-loop algorithmic momentum actually applied.
    mu_alg: f64,
    steps: u64,
}

impl YellowFin {
    pub fn new(theta0: &[f32]) -> Self {
        YellowFin {
            theta: theta0.to_vec(),
            v: vec![0.0; theta0.len()],
            g_avg: vec![0.0; theta0.len()],
            prev_update: vec![0.0; theta0.len()],
            prev_prev_update: vec![0.0; theta0.len()],
            h_window: VecDeque::with_capacity(WINDOW),
            h_min_avg: 0.0,
            h_max_avg: 0.0,
            g_norm_avg: 0.0,
            g_norm2_avg: 0.0,
            dist_avg: 0.0,
            lr: 1e-4, // paper §5: eta = 1e-4
            mu: 0.0,  // paper §5: gamma = 0.0
            mu_alg: 0.0,
            steps: 0,
        }
    }

    pub fn tuned_lr(&self) -> f64 {
        self.lr
    }

    pub fn tuned_mu(&self) -> f64 {
        self.mu_alg
    }

    /// Root of `x³ + p·x² + p·x − p = 0`-style SingleStep cubic, in the
    /// closed form used by the reference implementation.
    fn cubic_root(p: f64) -> f64 {
        // w³ = −(√(p² + 4p³/27) + p)/2 ;  y = w − p/(3w) ;  x = y + 1
        let w3 = (-(p * p + 4.0 / 27.0 * p * p * p).sqrt() - p) / 2.0;
        let w = w3.signum() * w3.abs().powf(1.0 / 3.0);
        let y = w - p / (3.0 * w);
        y + 1.0
    }

    /// One tuner step from globally reduced statistics (see [`ApplyStats`]).
    ///
    /// The scalar EMA state evolves from `stats` only, so every shard of a
    /// sharded server — each fed the same cross-shard sums — tracks the
    /// identical (μ, lr) trajectory as a monolithic instance.  The
    /// per-coordinate EMA ḡ is still updated here over this instance's
    /// slice of the gradient.
    fn tune_with(&mut self, g: &[f32], stats: &ApplyStats) {
        self.steps += 1;
        let t = self.steps as f64;
        // zero-debiased EMA helper
        let debias = 1.0 - BETA.powf(t);
        let ema = |avg: &mut f64, x: f64| {
            *avg = BETA * *avg + (1.0 - BETA) * x;
        };

        let h = stats.msg_norm2;
        if self.h_window.len() == WINDOW {
            self.h_window.pop_front();
        }
        self.h_window.push_back(h);
        let h_min_t = self.h_window.iter().cloned().fold(f64::INFINITY, f64::min);
        let h_max_t = self.h_window.iter().cloned().fold(0.0, f64::max);
        ema(&mut self.h_min_avg, h_min_t);
        ema(&mut self.h_max_avg, h_max_t);
        ema(&mut self.g_norm_avg, h.sqrt());
        ema(&mut self.g_norm2_avg, h);
        for (a, &x) in self.g_avg.iter_mut().zip(g) {
            *a = (BETA * *a as f64 + (1.0 - BETA) * x as f64) as f32;
        }
        // D = E[||g||]/E[||g||^2]
        if self.g_norm2_avg > 0.0 {
            let d = self.g_norm_avg / self.g_norm2_avg;
            ema(&mut self.dist_avg, d);
        }

        let h_min = (self.h_min_avg / debias).max(1e-12);
        let h_max = (self.h_max_avg / debias).max(h_min);
        // C = E[||g||^2] - ||E[g]||^2 (debiased, clipped away from 0);
        // ||E[g]||^2 is the post-EMA mean norm from the phase-1 pass.
        let c = (self.g_norm2_avg / debias - stats.g_avg_norm2 / (debias * debias)).max(1e-12);
        let d = (self.dist_avg / debias).max(1e-12);

        // SingleStep: mu from the cubic + the condition-number lower bound.
        let p = d * d * h_min * h_min / (2.0 * c);
        let x = Self::cubic_root(p).clamp(0.0, 1.0 - 1e-6);
        let dr = (h_max / h_min).sqrt();
        let mu_cap = ((dr - 1.0) / (dr + 1.0)).powi(2);
        let mu_t = (x * x).max(mu_cap).clamp(0.0, 0.9999);
        let lr_t = (1.0 - mu_t.sqrt()).powi(2) / h_min;

        // smooth the tuner outputs
        self.mu = BETA * self.mu + (1.0 - BETA) * mu_t;
        self.lr = BETA * self.lr + (1.0 - BETA) * lr_t;

        // Closed loop: realized total momentum = projection of the latest
        // update onto the previous one; drive mu_alg so total -> target.
        if stats.prev_norm2 > 1e-20 {
            let realized = stats.prev_dot / stats.prev_norm2;
            let err = self.mu - realized;
            self.mu_alg = (self.mu_alg + CLOSED_LOOP_GAIN * err).clamp(0.0, 0.9999);
        } else {
            self.mu_alg = self.mu;
        }
    }
}

impl Algorithm for YellowFin {
    fn kind(&self) -> AlgorithmKind {
        AlgorithmKind::YellowFin
    }

    fn theta(&self) -> &[f32] {
        &self.theta
    }

    /// The schedule's eta/gamma are ignored — YellowFin self-tunes.
    /// Monolithic path: collect the statistics locally, then run the same
    /// reduced apply the sharded server uses — one code path, one formula.
    fn master_apply(&mut self, worker: usize, msg: &[f32], sent: &[f32], s: Step) {
        let stats = self.apply_stats(worker, msg, sent);
        self.master_apply_with(worker, msg, sent, s, &stats);
    }

    fn needs_apply_stats(&self) -> bool {
        true
    }

    fn apply_stats(&self, _worker: usize, msg: &[f32], _sent: &[f32]) -> ApplyStats {
        // Post-EMA gradient-mean norm, computed read-only: the phase-2
        // update will set ḡ' = β·ḡ + (1−β)·g, so Σ ḡ'² is known now.
        let mut g_avg_norm2 = 0.0f64;
        for (&a, &x) in self.g_avg.iter().zip(msg) {
            let next = BETA * a as f64 + (1.0 - BETA) * x as f64;
            g_avg_norm2 += next * next;
        }
        ApplyStats {
            msg_norm2: math::norm2_sq(msg),
            g_avg_norm2,
            prev_dot: math::dot(&self.prev_update, &self.prev_prev_update),
            prev_norm2: math::norm2_sq(&self.prev_prev_update),
        }
    }

    fn master_apply_with(
        &mut self,
        _worker: usize,
        msg: &[f32],
        _sent: &[f32],
        _s: Step,
        stats: &ApplyStats,
    ) {
        self.tune_with(msg, stats);
        std::mem::swap(&mut self.prev_prev_update, &mut self.prev_update);
        // v <- mu_alg*v + g ; theta <- theta - lr*v ; record update = -lr*v
        let (mu, lr) = (self.mu_alg as f32, self.lr as f32);
        for (((t, v), g), pu) in self
            .theta
            .iter_mut()
            .zip(self.v.iter_mut())
            .zip(msg)
            .zip(self.prev_update.iter_mut())
        {
            let vn = mu * *v + *g;
            *v = vn;
            let upd = -lr * vn;
            *t += upd;
            *pu = upd;
        }
    }

    fn rescale_momentum(&mut self, ratio: f32) {
        math::scale(&mut self.v, ratio);
    }

    fn state_dict(&self) -> StateDict {
        vec![
            ("v".to_string(), StateVec::Coord(self.v.clone())),
            ("g_avg".to_string(), StateVec::Coord(self.g_avg.clone())),
            ("prev_update".to_string(), StateVec::Coord(self.prev_update.clone())),
            (
                "prev_prev_update".to_string(),
                StateVec::Coord(self.prev_prev_update.clone()),
            ),
            (
                "h_window".to_string(),
                StateVec::Scalars(self.h_window.iter().copied().collect()),
            ),
            (
                "tuner".to_string(),
                StateVec::Scalars(vec![
                    self.h_min_avg,
                    self.h_max_avg,
                    self.g_norm_avg,
                    self.g_norm2_avg,
                    self.dist_avg,
                    self.lr,
                    self.mu,
                    self.mu_alg,
                    self.steps as f64,
                ]),
            ),
        ]
    }

    fn load_state_dict(&mut self, dict: &StateDict) -> anyhow::Result<()> {
        let k = self.theta.len();
        self.v = dict_coord(dict, "v", k)?;
        self.g_avg = dict_coord(dict, "g_avg", k)?;
        self.prev_update = dict_coord(dict, "prev_update", k)?;
        self.prev_prev_update = dict_coord(dict, "prev_prev_update", k)?;
        match dict_get(dict, "h_window")? {
            StateVec::Scalars(w) => {
                anyhow::ensure!(
                    w.len() <= WINDOW,
                    "h_window has {} entries (cap {WINDOW})",
                    w.len()
                );
                self.h_window = w.iter().copied().collect();
            }
            other => anyhow::bail!("state \"h_window\": expected Scalars, got {other:?}"),
        }
        let s = dict_scalars(dict, "tuner", 9)?;
        self.h_min_avg = s[0];
        self.h_max_avg = s[1];
        self.g_norm_avg = s[2];
        self.g_norm2_avg = s[3];
        self.dist_avg = s[4];
        self.lr = s[5];
        self.mu = s[6];
        self.mu_alg = s[7];
        self.steps = s[8] as u64;
        Ok(())
    }

    fn set_theta(&mut self, theta: &[f32]) {
        self.theta.copy_from_slice(theta);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cubic_root_limits() {
        // The YF cubic y³ + py + p = 0 with x = y+1 = √μ: small p (noisy /
        // far from optimum) drives μ → 1, large p drives μ → 0.
        let small = YellowFin::cubic_root(1e-9);
        let large = YellowFin::cubic_root(1e9);
        assert!(small > 0.98, "{small}");
        assert!(large < 0.02, "{large}");
        // the root actually satisfies the cubic at a moderate p
        for p in [0.1, 1.0, 10.0] {
            let x = YellowFin::cubic_root(p);
            let y = x - 1.0;
            let residual = y * y * y + p * y + p;
            assert!(residual.abs() < 1e-6 * (1.0 + p), "p={p}: residual {residual}");
        }
    }

    #[test]
    fn tunes_on_quadratic_and_descends() {
        // J(x) = 0.5*k*x^2 with mild noise: YF must reduce the loss.
        let k = 4.0f32;
        let mut yf = YellowFin::new(&[1.0, -1.0, 0.5, 2.0]);
        let mut rng = crate::util::rng::Rng::new(3);
        let loss = |th: &[f32]| th.iter().map(|&x| 0.5 * k as f64 * (x as f64).powi(2)).sum::<f64>();
        let l0 = loss(yf.theta());
        for _ in 0..800 {
            let g: Vec<f32> = yf
                .theta()
                .iter()
                .map(|&x| k * x + 0.01 * rng.normal() as f32)
                .collect();
            let sent = yf.theta().to_vec();
            yf.master_apply(0, &g, &sent, Step::default());
        }
        let l1 = loss(yf.theta());
        assert!(l1 < 0.5 * l0, "l0={l0} l1={l1}");
        assert!(yf.tuned_lr() > 0.0 && yf.tuned_lr().is_finite());
        assert!((0.0..1.0).contains(&yf.tuned_mu()));
    }

    #[test]
    fn initializes_at_paper_hyperparams() {
        let yf = YellowFin::new(&[0.0]);
        assert_eq!(yf.tuned_lr(), 1e-4);
        assert_eq!(yf.tuned_mu(), 0.0);
    }
}

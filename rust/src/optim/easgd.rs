//! EASGD — Elastic Averaging SGD (Zhang, Choromanska & LeCun 2015), with
//! momentum (the EAMSGD variant).
//!
//! The paper's §6/§7 names EASGD as the future-work composition target
//! ("we plan on adapting DANA ... in particular EASGD and YellowFin"); this
//! module implements it as a first-class algorithm so the harness can
//! compare it under the same schedules.
//!
//! Semantics: every worker trains its *own* replica `xᶦ` and exchanges an
//! elastic force with the center `x̃` each communication round:
//!
//! ```text
//! vᶦ  <- gamma*vᶦ + gᶦ ;  xᶦ <- xᶦ - eta*vᶦ        (local momentum SGD)
//! d   =  alpha * (xᶦ - x̃)
//! xᶦ <- xᶦ - d ;  x̃ <- x̃ + d                       (elastic exchange)
//! ```
//!
//! In this parameter-server framing the replicas live on the master (the
//! communication period is one push, the densest setting), the worker
//! computes plain gradients against its replica, and the center `x̃` is
//! what evaluation reads — faithful to the published update rule while
//! fitting the pull/push API.  The moving rate follows the authors'
//! recommendation `alpha = beta / N` with `beta = 0.9`.

use super::{
    claim_slot, dict_per_worker, dict_scalars, Algorithm, AlgorithmKind, LeavePolicy, StateDict,
    StateVec, Step,
};
use crate::math;

#[derive(Debug, Clone)]
pub struct Easgd {
    /// Center variable x̃ (what eval reads).
    center: Vec<f32>,
    /// Per-worker replicas xᶦ and momenta vᶦ.
    x: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// Elastic moving rate α.
    alpha: f32,
    /// Track α = β/N against the *live* worker count on membership
    /// changes; disabled once [`Easgd::with_alpha`] pins it.
    alpha_auto: bool,
    /// Slot liveness (elastic membership).
    live: Vec<bool>,
}

impl Easgd {
    pub fn new(theta0: &[f32], n_workers: usize) -> Self {
        Easgd {
            center: theta0.to_vec(),
            x: vec![theta0.to_vec(); n_workers],
            v: vec![vec![0.0; theta0.len()]; n_workers],
            alpha: 0.9 / n_workers.max(1) as f32,
            alpha_auto: true,
            live: vec![true; n_workers],
        }
    }

    pub fn with_alpha(mut self, alpha: f32) -> Self {
        self.alpha = alpha;
        self.alpha_auto = false;
        self
    }

    fn retune_alpha(&mut self) {
        if self.alpha_auto {
            let live = self.live.iter().filter(|&&l| l).count();
            self.alpha = 0.9 / live.max(1) as f32;
        }
    }

    pub fn alpha(&self) -> f32 {
        self.alpha
    }

    pub fn replica(&self, worker: usize) -> &[f32] {
        &self.x[worker]
    }
}

impl Algorithm for Easgd {
    fn kind(&self) -> AlgorithmKind {
        AlgorithmKind::Easgd
    }

    fn theta(&self) -> &[f32] {
        &self.center
    }

    fn master_apply(&mut self, worker: usize, msg: &[f32], _sent: &[f32], s: Step) {
        // local momentum SGD on the replica, then the elastic exchange —
        // one fused pass over (x, v, center, g).
        let alpha = self.alpha;
        for (((x, v), c), &g) in self.x[worker]
            .iter_mut()
            .zip(self.v[worker].iter_mut())
            .zip(self.center.iter_mut())
            .zip(msg)
        {
            let vn = s.gamma * *v + g;
            *v = vn;
            let mut xi = *x - s.eta * vn;
            let d = alpha * (xi - *c);
            xi -= d;
            *c += d;
            *x = xi;
        }
    }

    /// The worker receives its own replica (it trains xᶦ, not x̃).
    fn master_send(&self, worker: usize, out: &mut [f32], _s: Step) {
        out.copy_from_slice(&self.x[worker]);
    }

    fn rescale_momentum(&mut self, ratio: f32) {
        for v in &mut self.v {
            math::scale(v, ratio);
        }
    }

    fn add_worker(&mut self) -> usize {
        let slot = claim_slot(&mut self.live);
        if slot == self.x.len() {
            self.x.push(self.center.clone());
            self.v.push(vec![0.0; self.center.len()]);
        } else {
            // A joiner starts at the center with zero momentum.
            self.x[slot].copy_from_slice(&self.center);
            self.v[slot].fill(0.0);
        }
        self.retune_alpha();
        slot
    }

    fn remove_worker(&mut self, worker: usize, policy: LeavePolicy) {
        debug_assert!(self.live[worker], "remove of retired worker {worker}");
        self.live[worker] = false;
        if policy == LeavePolicy::Fold {
            // One final elastic exchange: the center absorbs α·(xᶦ − x̃) of
            // the leaver's progress before the replica is dropped.
            let alpha = self.alpha;
            for (c, &x) in self.center.iter_mut().zip(&self.x[worker]) {
                *c += alpha * (x - *c);
            }
        }
        self.v[worker].fill(0.0);
        self.retune_alpha();
    }

    fn state_dict(&self) -> StateDict {
        vec![
            ("x".to_string(), StateVec::PerWorker(self.x.clone())),
            ("v".to_string(), StateVec::PerWorker(self.v.clone())),
            (
                "alpha".to_string(),
                StateVec::Scalars(vec![
                    self.alpha as f64,
                    if self.alpha_auto { 1.0 } else { 0.0 },
                ]),
            ),
        ]
    }

    /// NB: callers restore θ via [`Algorithm::set_theta`] *before* loading
    /// the dict — `set_theta` resets every replica to the center, and the
    /// dict's per-worker `x` entries overwrite them afterwards.
    fn load_state_dict(&mut self, dict: &StateDict) -> anyhow::Result<()> {
        let k = self.center.len();
        self.x = dict_per_worker(dict, "x", self.x.len(), k)?;
        self.v = dict_per_worker(dict, "v", self.v.len(), k)?;
        let s = dict_scalars(dict, "alpha", 2)?;
        self.alpha = s[0] as f32;
        self.alpha_auto = s[1] != 0.0;
        Ok(())
    }

    fn set_theta(&mut self, theta: &[f32]) {
        self.center.copy_from_slice(theta);
        for x in &mut self.x {
            x.copy_from_slice(theta);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step() -> Step {
        Step { eta: 0.05, gamma: 0.9, lambda: 0.0 }
    }

    #[test]
    fn center_moves_toward_replicas() {
        let mut e = Easgd::new(&[0.0], 2).with_alpha(0.25);
        // worker 0 descends toward -inf on J = x (grad = 1)
        e.master_apply(0, &[1.0], &[0.0], step());
        assert!(e.replica(0)[0] < 0.0);
        assert!(e.theta()[0] < 0.0, "center must be pulled along");
        assert!(e.theta()[0] > e.replica(0)[0], "center lags the replica");
    }

    #[test]
    fn elastic_force_is_symmetric() {
        // What the center gains, the replica loses (total displacement
        // preserved by the exchange term).
        let mut e = Easgd::new(&[1.0], 1).with_alpha(0.25);
        let s = Step { eta: 0.1, gamma: 0.0, lambda: 0.0 };
        let c0 = e.theta()[0];
        e.master_apply(0, &[2.0], &[1.0], s);
        let x_before_exchange = 1.0 - 0.1 * 2.0;
        let d = 0.25 * (x_before_exchange - c0);
        assert!((e.theta()[0] - (c0 + d)).abs() < 1e-6);
        assert!((e.replica(0)[0] - (x_before_exchange - d)).abs() < 1e-6);
    }

    #[test]
    fn converges_on_quadratic() {
        let k = 9;
        let n = 4;
        let theta0: Vec<f32> = (0..k).map(|i| 1.0 + i as f32 * 0.1).collect();
        let mut e = Easgd::new(&theta0, n);
        let mut rng = crate::util::rng::Rng::new(1);
        for step_i in 0..2000 {
            let w = rng.below(n as u64) as usize;
            let g: Vec<f32> = e.replica(w).iter().map(|&x| x).collect(); // grad of 0.5x^2
            let sent = e.replica(w).to_vec();
            e.master_apply(w, &g, &sent, step());
            let _ = step_i;
        }
        assert!(crate::math::norm2_sq(e.theta()) < 1e-3);
    }

    #[test]
    fn membership_retunes_alpha_and_joiner_starts_at_center() {
        let mut e = Easgd::new(&[1.0], 3);
        assert!((e.alpha() - 0.3).abs() < 1e-6);
        e.remove_worker(2, LeavePolicy::Retire);
        assert!((e.alpha() - 0.45).abs() < 1e-6, "alpha follows live count");
        let slot = e.add_worker();
        assert_eq!(slot, 2);
        assert_eq!(e.replica(2), e.theta(), "joiner replica = center");
        assert!((e.alpha() - 0.3).abs() < 1e-6);
        // explicit alpha disables the auto-retune
        let mut pinned = Easgd::new(&[1.0], 3).with_alpha(0.5);
        pinned.remove_worker(0, LeavePolicy::Retire);
        assert_eq!(pinned.alpha(), 0.5);
    }

    #[test]
    fn fold_leave_runs_a_final_exchange() {
        let mut e = Easgd::new(&[0.0], 2).with_alpha(0.25);
        let s = Step { eta: 0.1, gamma: 0.0, lambda: 0.0 };
        e.master_apply(0, &[2.0], &[0.0], s);
        let (c, x) = (e.theta()[0], e.replica(0)[0]);
        e.remove_worker(0, LeavePolicy::Fold);
        let expect = c + 0.25 * (x - c);
        assert!((e.theta()[0] - expect).abs() < 1e-6);
    }

    #[test]
    fn workers_receive_their_replica() {
        let mut e = Easgd::new(&[0.0, 0.0], 2);
        e.master_apply(0, &[1.0, 1.0], &[0.0, 0.0], step());
        let mut out = [0.0f32; 2];
        e.master_send(0, &mut out, step());
        assert_eq!(out, *e.replica(0));
        e.master_send(1, &mut out, step());
        assert_eq!(out, [0.0, 0.0], "worker 1's replica untouched");
    }
}

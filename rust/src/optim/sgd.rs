//! Sequential optimizers (single worker) and the synchronous baseline.
//!
//! * [`Sgd`] — vanilla SGD (Eq 1).
//! * [`HeavyBall`] — Polyak momentum (Eq 2).
//! * [`Nag`] — Nesterov's accelerated gradient in look-ahead form (Eq 3):
//!   the caller pulls `lookahead_params`, evaluates the gradient there, and
//!   `apply`s it.  This is the paper's single-worker baseline.
//! * [`BengioNag`] — the re-parameterized NAG (Eq 13/14): gradient is both
//!   computed on and applied to Θ.  Trajectory-equivalent to [`Nag`]
//!   (tested), and the basis of DANA-Slim.
//! * [`SyncSgd`] — SSGD: N per-worker gradients averaged into one
//!   Bengio-NAG step (the `DistributedDataParallel` baseline of §5.4).

use crate::math;

/// Vanilla SGD.
#[derive(Debug, Clone)]
pub struct Sgd {
    pub theta: Vec<f32>,
}

impl Sgd {
    pub fn new(theta0: &[f32]) -> Self {
        Sgd { theta: theta0.to_vec() }
    }

    pub fn apply(&mut self, g: &[f32], eta: f32) {
        math::apply_update(&mut self.theta, g, eta);
    }
}

/// Polyak heavy-ball momentum (Eq 2), gradient evaluated at θ.
#[derive(Debug, Clone)]
pub struct HeavyBall {
    pub theta: Vec<f32>,
    pub v: Vec<f32>,
}

impl HeavyBall {
    pub fn new(theta0: &[f32]) -> Self {
        HeavyBall { theta: theta0.to_vec(), v: vec![0.0; theta0.len()] }
    }

    pub fn apply(&mut self, g: &[f32], eta: f32, gamma: f32) {
        math::momentum_step(&mut self.theta, &mut self.v, g, gamma, eta);
    }
}

/// Nesterov's accelerated gradient, look-ahead form (Eq 3).
#[derive(Debug, Clone)]
pub struct Nag {
    pub theta: Vec<f32>,
    pub v: Vec<f32>,
}

impl Nag {
    pub fn new(theta0: &[f32]) -> Self {
        Nag { theta: theta0.to_vec(), v: vec![0.0; theta0.len()] }
    }

    /// θ̂ = θ − ηγv — where the gradient should be evaluated.
    pub fn lookahead_params(&self, out: &mut [f32], eta: f32, gamma: f32) {
        math::lookahead(out, &self.theta, &self.v, gamma, eta);
    }

    /// Look-ahead extrapolated `depth` *extra* momentum-only steps — where
    /// a gradient issued now lands when `depth` more of this worker's own
    /// steps settle first (the pipelined-driver case).  `depth = 0` is
    /// [`Self::lookahead_params`] bit-for-bit; `depth = D` equals `D`
    /// literal zero-gradient [`Self::apply`] calls followed by the plain
    /// look-ahead (pinned exactly in `rust/tests/pipeline.rs`).
    pub fn lookahead_extrapolated(&self, out: &mut [f32], eta: f32, gamma: f32, depth: usize) {
        math::lookahead_extrapolated(out, &self.theta, &self.v, gamma, eta, depth);
    }

    /// Apply a gradient computed at the look-ahead point.
    pub fn apply(&mut self, g: &[f32], eta: f32, gamma: f32) {
        math::momentum_step(&mut self.theta, &mut self.v, g, gamma, eta);
    }
}

/// Bengio-NAG (Eq 13/14): Θ-parameterization with no look-ahead pull.
#[derive(Debug, Clone)]
pub struct BengioNag {
    /// Θ = θ − ηγv (the trained representation).
    pub theta: Vec<f32>,
    pub v: Vec<f32>,
}

impl BengioNag {
    pub fn new(theta0: &[f32]) -> Self {
        BengioNag { theta: theta0.to_vec(), v: vec![0.0; theta0.len()] }
    }

    /// Θ ← Θ − η(γ·v_new + g) with v_new = γv + g (Eq 14).
    pub fn apply(&mut self, g: &[f32], eta: f32, gamma: f32) {
        for ((t, v), &g) in self.theta.iter_mut().zip(self.v.iter_mut()).zip(g) {
            let v_new = gamma * *v + g;
            *v = v_new;
            *t -= eta * (gamma * v_new + g);
        }
    }
}

/// Synchronous data-parallel SGD with Nesterov momentum: the barrier
/// baseline.  All N gradients (one per worker, same parameters) are
/// averaged, then a single Bengio-NAG step is taken.
#[derive(Debug, Clone)]
pub struct SyncSgd {
    inner: BengioNag,
    accum: Vec<f32>,
    pending: usize,
    n_workers: usize,
}

impl SyncSgd {
    pub fn new(theta0: &[f32], n_workers: usize) -> Self {
        assert!(n_workers > 0);
        SyncSgd {
            inner: BengioNag::new(theta0),
            accum: vec![0.0; theta0.len()],
            pending: 0,
            n_workers,
        }
    }

    pub fn theta(&self) -> &[f32] {
        &self.inner.theta
    }

    /// Contribute one worker's gradient; on the N-th the averaged NAG step
    /// fires.  Returns true when the barrier released (step applied).
    pub fn contribute(&mut self, g: &[f32], eta: f32, gamma: f32) -> bool {
        math::axpy(&mut self.accum, 1.0, g);
        self.pending += 1;
        if self.pending == self.n_workers {
            math::scale(&mut self.accum, 1.0 / self.n_workers as f32);
            let avg = std::mem::replace(&mut self.accum, vec![0.0; g.len()]);
            self.inner.apply(&avg, eta, gamma);
            self.pending = 0;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Quadratic J(x) = 0.5 xᵀ diag(k) x used across the tests.
    fn quad_grad(theta: &[f32], ks: &[f32]) -> Vec<f32> {
        theta.iter().zip(ks).map(|(&t, &k)| k * t).collect()
    }

    #[test]
    fn nag_equals_bengio_nag_in_theta_big() {
        // Eq 13: Θ_t = θ_t − ηγ v_{t-1}; both sequences must agree under
        // that change of variables at every step.
        let (eta, gamma) = (0.05f32, 0.9f32);
        let ks = [1.0f32, 4.0, 0.25];
        let mut nag = Nag::new(&[1.0, -1.0, 2.0]);
        let mut ben = BengioNag::new(&[1.0, -1.0, 2.0]);
        let mut hat = vec![0.0f32; 3];
        for _ in 0..100 {
            // NAG: gradient at the look-ahead point
            nag.lookahead_params(&mut hat, eta, gamma);
            let g = quad_grad(&hat, &ks);
            nag.apply(&g, eta, gamma);
            // Bengio: gradient at Θ itself
            let gb = quad_grad(&ben.theta, &ks);
            ben.apply(&gb, eta, gamma);
            // check Θ = θ − ηγ v
            for i in 0..3 {
                let theta_big = nag.theta[i] - eta * gamma * nag.v[i];
                assert!(
                    (theta_big - ben.theta[i]).abs() < 1e-5,
                    "{theta_big} vs {}",
                    ben.theta[i]
                );
            }
        }
    }

    #[test]
    fn momentum_accelerates_on_quadratic() {
        let ks = [1.0f32; 4];
        let mut sgd = Sgd::new(&[1.0; 4]);
        let mut hb = HeavyBall::new(&[1.0; 4]);
        for _ in 0..60 {
            let gs = quad_grad(&sgd.theta, &ks);
            sgd.apply(&gs, 0.05);
            let gh = quad_grad(&hb.theta, &ks);
            hb.apply(&gh, 0.05, 0.9);
        }
        let d_sgd: f64 = math::norm2_sq(&sgd.theta);
        let d_hb: f64 = math::norm2_sq(&hb.theta);
        assert!(d_hb < d_sgd, "heavy ball should be ahead: {d_hb} vs {d_sgd}");
    }

    #[test]
    fn ssgd_averages_before_stepping() {
        let mut sync = SyncSgd::new(&[0.0], 2);
        assert!(!sync.contribute(&[1.0], 1.0, 0.0));
        assert_eq!(sync.theta(), &[0.0]); // barrier not yet released
        assert!(sync.contribute(&[3.0], 1.0, 0.0));
        // avg = 2.0, gamma=0 -> theta = -2
        assert_eq!(sync.theta(), &[-2.0]);
    }

    #[test]
    fn ssgd_n1_is_sequential() {
        let mut sync = SyncSgd::new(&[1.0], 1);
        let mut seq = BengioNag::new(&[1.0]);
        for i in 0..20 {
            let g = [(i as f32).sin()];
            sync.contribute(&g, 0.1, 0.9);
            seq.apply(&g, 0.1, 0.9);
        }
        assert_eq!(sync.theta(), &seq.theta[..]);
    }
}

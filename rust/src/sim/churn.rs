//! Declarative cluster-churn schedules.
//!
//! A [`ChurnSchedule`] is a list of membership events pinned to fractions
//! of the run's master-step budget: workers join, leave, or suffer
//! straggler onset (their mean batch time is rescaled).  The schedule is
//! *declarative* — it names what happens and when, not which heap entries
//! to touch — and the event stream ([`super::AsyncSchedule`]) materializes
//! it deterministically: slot assignment follows the same
//! lowest-retired-else-append rule as the servers and algorithms
//! ([`crate::optim::claim_slot`]), and events that name no worker pick a
//! random *live* one from the schedule's seeded RNG.
//!
//! Why this matters here: "Asynchrony begets Momentum" (Mitliagkas et al.
//! 2016) shows the effective momentum of ASGD is a function of the number
//! of live workers, so membership changes silently re-parameterize the
//! optimization problem — exactly the regime in which DANA's per-worker
//! momentum decomposition must keep v⁰ = Σ live vᶦ intact.
//!
//! CLI grammar (comma-separated events):
//!
//! ```text
//! leave@0.3:2      worker 2 leaves at 30% of the run
//! leave@0.3        a random live worker leaves at 30%
//! join@0.5         a worker joins at 50% (slot: lowest retired, else new)
//! slow@0.6:0=4x    worker 0's mean batch time x4 at 60% (straggler onset)
//! slow@0.6=4x      same, random live victim
//! ```

/// One membership action.  `None` worker = pick a random live one at fire
/// time (seeded by the event stream's RNG).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ChurnAction {
    /// A worker joins the cluster.
    Join,
    /// A worker leaves the cluster.
    Leave(Option<usize>),
    /// Straggler onset: the worker's mean execution time is multiplied by
    /// the factor (>1 slower, <1 faster).
    SpeedChange(Option<usize>, f64),
}

/// One scheduled event: fire `action` once `at` of the run's master steps
/// have completed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChurnEvent {
    /// Fraction of the run's total master steps in [0, 1).
    pub at: f64,
    pub action: ChurnAction,
}

/// A declarative membership schedule (empty = fixed cluster, which is
/// guaranteed to reproduce the pre-elastic trajectories bit-for-bit).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ChurnSchedule {
    pub events: Vec<ChurnEvent>,
}

impl ChurnSchedule {
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Parse the CLI grammar (see module docs); `""` is the empty schedule.
    pub fn parse(spec: &str) -> anyhow::Result<ChurnSchedule> {
        let mut events = Vec::new();
        for tok in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (kind, rest) = tok
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("churn event {tok:?}: missing '@<frac>'"))?;
            // rest = frac[:worker][=factor[x]]
            let (head, factor) = match rest.split_once('=') {
                Some((h, f)) => {
                    let f = f.trim_end_matches(['x', 'X']);
                    (h, Some(f.parse::<f64>().map_err(|e| {
                        anyhow::anyhow!("churn event {tok:?}: bad factor {f:?}: {e}")
                    })?))
                }
                None => (rest, None),
            };
            let (frac, worker) = match head.split_once(':') {
                Some((f, w)) => (f, Some(w.parse::<usize>().map_err(|e| {
                    anyhow::anyhow!("churn event {tok:?}: bad worker {w:?}: {e}")
                })?)),
                None => (head, None),
            };
            let at: f64 = frac
                .parse()
                .map_err(|e| anyhow::anyhow!("churn event {tok:?}: bad fraction {frac:?}: {e}"))?;
            anyhow::ensure!(
                (0.0..1.0).contains(&at),
                "churn event {tok:?}: fraction {at} outside [0, 1)"
            );
            let action = match kind.to_ascii_lowercase().as_str() {
                "join" => {
                    anyhow::ensure!(
                        worker.is_none() && factor.is_none(),
                        "churn event {tok:?}: join takes no worker or factor \
                         (slots are assigned deterministically)"
                    );
                    ChurnAction::Join
                }
                "leave" => {
                    anyhow::ensure!(factor.is_none(), "churn event {tok:?}: leave takes no factor");
                    ChurnAction::Leave(worker)
                }
                "slow" => {
                    let f = factor
                        .ok_or_else(|| anyhow::anyhow!("churn event {tok:?}: slow needs '=<factor>[x]'"))?;
                    anyhow::ensure!(f > 0.0, "churn event {tok:?}: factor must be > 0");
                    ChurnAction::SpeedChange(worker, f)
                }
                other => anyhow::bail!("churn event {tok:?}: unknown kind {other:?} (join|leave|slow)"),
            };
            events.push(ChurnEvent { at, action });
        }
        Ok(ChurnSchedule { events })
    }

    /// Check the schedule can run over a cluster that starts with
    /// `initial_workers`: the live count (which is independent of *which*
    /// workers leave) must never reach zero, and explicitly named workers
    /// must fit the slot capacity possible at that point (initial workers
    /// plus joins fired so far — slots only grow on joins).  Which exact
    /// slot is live at fire time can depend on random-victim leaves, so
    /// the remaining fine-grained cases (e.g. leaving the same explicit
    /// worker twice) are skipped gracefully at runtime instead.
    pub fn validate(&self, initial_workers: usize) -> anyhow::Result<()> {
        let mut live = initial_workers as i64;
        let mut capacity = initial_workers;
        for e in self.sorted() {
            let named = match e.action {
                ChurnAction::Join => {
                    live += 1;
                    capacity += 1;
                    None
                }
                ChurnAction::Leave(w) => {
                    live -= 1;
                    anyhow::ensure!(
                        live >= 1,
                        "churn schedule empties the cluster at fraction {} \
                         (started with {initial_workers} workers)",
                        e.at
                    );
                    w
                }
                ChurnAction::SpeedChange(w, _) => w,
            };
            if let Some(w) = named {
                anyhow::ensure!(
                    w < capacity,
                    "churn event at fraction {} names worker {w}, but at most \
                     {capacity} slots can exist by then \
                     ({initial_workers} initial + joins so far)",
                    e.at
                );
            }
        }
        Ok(())
    }

    /// Events sorted by firing fraction (stable: same-fraction events keep
    /// their declaration order).
    pub fn sorted(&self) -> Vec<ChurnEvent> {
        let mut v = self.events.clone();
        v.sort_by(|a, b| a.at.total_cmp(&b.at));
        v
    }

    /// Translate fractions into absolute master-step thresholds for a run
    /// of `total_steps`, sorted ascending.  Thresholds are clamped to
    /// `total_steps - 1`: drivers only fire events strictly before the run
    /// completes, so a late fraction (e.g. `0.999` of a short run, which
    /// rounds up to the full budget) still fires before the final step
    /// instead of silently never firing.
    pub fn thresholds(&self, total_steps: u64) -> Vec<(u64, ChurnAction)> {
        let cap = total_steps.saturating_sub(1);
        self.sorted()
            .into_iter()
            .map(|e| (((e.at * total_steps as f64).round() as u64).min(cap), e.action))
            .collect()
    }
}

impl std::str::FromStr for ChurnSchedule {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        ChurnSchedule::parse(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_readme_spec() {
        let c = ChurnSchedule::parse("leave@0.3:2,join@0.5,slow@0.6:0=4x").unwrap();
        assert_eq!(
            c.events,
            vec![
                ChurnEvent { at: 0.3, action: ChurnAction::Leave(Some(2)) },
                ChurnEvent { at: 0.5, action: ChurnAction::Join },
                ChurnEvent { at: 0.6, action: ChurnAction::SpeedChange(Some(0), 4.0) },
            ]
        );
        // random-victim + no-x-suffix forms
        let c = ChurnSchedule::parse("leave@0.25, slow@0.5=2").unwrap();
        assert_eq!(c.events[0].action, ChurnAction::Leave(None));
        assert_eq!(c.events[1].action, ChurnAction::SpeedChange(None, 2.0));
        assert!(ChurnSchedule::parse("").unwrap().is_empty());
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "leave",           // no @
            "leave@1.5",       // frac out of range
            "join@0.5:3",      // join with explicit worker
            "slow@0.5",        // slow without factor
            "slow@0.5=0x",     // non-positive factor
            "nap@0.5",         // unknown kind
            "leave@x",         // unparsable frac
        ] {
            assert!(ChurnSchedule::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn validate_rejects_cluster_emptying() {
        let c = ChurnSchedule::parse("leave@0.2,leave@0.4").unwrap();
        assert!(c.validate(2).is_err());
        assert!(c.validate(3).is_ok());
        // a join in between rescues it
        let c = ChurnSchedule::parse("leave@0.2,join@0.3,leave@0.4").unwrap();
        assert!(c.validate(2).is_ok());
        // ordering is by fraction, not declaration order
        let c = ChurnSchedule::parse("leave@0.4,join@0.3,leave@0.2").unwrap();
        assert!(c.validate(2).is_ok());
    }

    #[test]
    fn validate_rejects_out_of_range_workers() {
        // worker 9 can never exist in a 4-worker cluster with no joins
        let c = ChurnSchedule::parse("slow@0.5:9=2x").unwrap();
        assert!(c.validate(4).is_err());
        let c = ChurnSchedule::parse("leave@0.5:4").unwrap();
        assert!(c.validate(4).is_err());
        // ...but a join raises the possible slot capacity
        let c = ChurnSchedule::parse("join@0.3,slow@0.5:4=2x").unwrap();
        assert!(c.validate(4).is_ok());
    }

    #[test]
    fn thresholds_scale_to_total_steps() {
        let c = ChurnSchedule::parse("join@0.5,leave@0.25:1").unwrap();
        let t = c.thresholds(200);
        assert_eq!(t[0], (50, ChurnAction::Leave(Some(1))));
        assert_eq!(t[1], (100, ChurnAction::Join));
    }

    #[test]
    fn late_fractions_clamp_below_the_final_step() {
        // 0.999 * 200 rounds to 200, which would never fire (drivers gate
        // on step < total); it must clamp to 199.
        let c = ChurnSchedule::parse("join@0.999").unwrap();
        assert_eq!(c.thresholds(200)[0].0, 199);
        assert_eq!(c.thresholds(0)[0].0, 0, "degenerate budget stays sane");
    }
}

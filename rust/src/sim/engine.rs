//! Event-driven cluster simulator.
//!
//! Drives the pull → compute(gamma-time) → push cycle of every worker on a
//! virtual clock and yields master-apply events in completion order — the
//! same methodology the paper uses for its §5.1/§5.2 simulations ("we
//! simulate the workers' execution time using a gamma-distributed model").
//! The [`crate::train::sim_trainer`] consumes these events and performs the
//! *real* gradient computation (via the PJRT runtime) for each one, so the
//! schedule is simulated but the learning dynamics are genuine.
//!
//! Synchronous mode (SSGD) implements the barrier: a round completes when
//! the slowest worker finishes, which is the mechanism behind Fig 12's
//! speedup comparison.

use super::gamma::ExecTimeModel;
use crate::util::rng::Rng;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One asynchronous completion: worker `worker` finishes a batch at `time`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Completion {
    pub time: f64,
    pub worker: usize,
}

// BinaryHeap is a max-heap; invert the order to pop the earliest event.
#[derive(Debug, Clone, Copy, PartialEq)]
struct HeapItem(Completion);

impl Eq for HeapItem {}

impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .0
            .time
            .total_cmp(&self.0.time)
            .then_with(|| other.0.worker.cmp(&self.0.worker))
    }
}

impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Asynchronous schedule generator: an infinite stream of completions.
pub struct AsyncSchedule {
    model: ExecTimeModel,
    rng: Rng,
    heap: BinaryHeap<HeapItem>,
    now: f64,
}

impl AsyncSchedule {
    pub fn new(model: ExecTimeModel, mut rng: Rng) -> Self {
        let mut heap = BinaryHeap::new();
        for w in 0..model.n_workers() {
            let t = model.sample(w, &mut rng);
            heap.push(HeapItem(Completion { time: t, worker: w }));
        }
        AsyncSchedule { model, rng, heap, now: 0.0 }
    }

    /// Simulated time of the most recent completion.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Pop the next completion and immediately re-dispatch that worker on
    /// its next batch (workers never idle in ASGD).
    pub fn next_completion(&mut self) -> Completion {
        let HeapItem(c) = self.heap.pop().expect("heap never empties");
        self.now = c.time;
        let dur = self.model.sample(c.worker, &mut self.rng);
        self.heap.push(HeapItem(Completion { time: c.time + dur, worker: c.worker }));
        c
    }

    /// Materialize the next `n` completions (for schedule-replay tests).
    pub fn take(&mut self, n: usize) -> Vec<Completion> {
        (0..n).map(|_| self.next_completion()).collect()
    }
}

/// The schedule is an infinite stream of completions; the iterator view
/// lets consumers drive adapters over it (the equivalence property suite
/// replays one gamma-model worker ordering into several servers).  Note
/// the inherent [`AsyncSchedule::take`] shadows `Iterator::take` on the
/// receiver itself — adapt through a borrow (`(&mut s).map(...)`) when the
/// iterator combinators are wanted.
impl Iterator for AsyncSchedule {
    type Item = Completion;

    fn next(&mut self) -> Option<Completion> {
        Some(self.next_completion())
    }
}

/// Synchronous schedule: rounds gated by the slowest worker.
pub struct SyncSchedule {
    model: ExecTimeModel,
    rng: Rng,
    now: f64,
}

impl SyncSchedule {
    pub fn new(model: ExecTimeModel, rng: Rng) -> Self {
        SyncSchedule { model, rng, now: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Run one barrier round; returns the round's wall time (max over
    /// workers) — every worker contributes exactly one batch.
    pub fn next_round(&mut self) -> f64 {
        let round = (0..self.model.n_workers())
            .map(|w| self.model.sample(w, &mut self.rng))
            .fold(0.0f64, f64::max);
        self.now += round;
        round
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gamma::Environment;

    fn model(env: Environment, n: usize, seed: u64) -> (ExecTimeModel, Rng) {
        let mut rng = Rng::new(seed);
        let m = ExecTimeModel::new(env, n, 128, &mut rng);
        (m, rng)
    }

    #[test]
    fn completions_are_time_ordered() {
        let (m, rng) = model(Environment::Homogeneous, 8, 3);
        let mut s = AsyncSchedule::new(m, rng);
        let evts = s.take(500);
        for w in evts.windows(2) {
            assert!(w[0].time <= w[1].time);
        }
    }

    #[test]
    fn all_workers_participate() {
        let (m, rng) = model(Environment::Homogeneous, 8, 4);
        let mut s = AsyncSchedule::new(m, rng);
        let evts = s.take(200);
        let mut seen = [0usize; 8];
        for e in &evts {
            seen[e.worker] += 1;
        }
        for (w, &c) in seen.iter().enumerate() {
            assert!(c > 10, "worker {w} starved: {c} completions");
        }
    }

    #[test]
    fn homo_throughput_is_near_linear() {
        // N workers deliver ~N completions per mean batch time.
        let (m, rng) = model(Environment::Homogeneous, 8, 5);
        let mut s = AsyncSchedule::new(m, rng);
        let k = 4000;
        let evts = s.take(k);
        let total_time = evts.last().unwrap().time;
        let throughput = k as f64 / total_time; // completions per unit time
        let ideal = 8.0 / 128.0;
        assert!(
            (throughput / ideal - 1.0).abs() < 0.1,
            "throughput {throughput} vs ideal {ideal}"
        );
    }

    #[test]
    fn sync_rounds_are_slower_than_async_mean() {
        // E[max of N gammas] > E[gamma]: the straggler penalty.
        let (m, rng) = model(Environment::Heterogeneous, 8, 6);
        let mut s = SyncSchedule::new(m, rng);
        let mut total = 0.0;
        for _ in 0..200 {
            total += s.next_round();
        }
        let mean_round = total / 200.0;
        assert!(mean_round > 128.0 * 1.1, "mean round {mean_round}");
    }

    #[test]
    fn iterator_view_matches_next_completion() {
        let (m1, r1) = model(Environment::Homogeneous, 4, 21);
        let (m2, r2) = model(Environment::Homogeneous, 4, 21);
        let mut a = AsyncSchedule::new(m1, r1);
        let mut b = AsyncSchedule::new(m2, r2);
        let via_iter: Vec<Completion> = Iterator::take(&mut a, 50).collect();
        let via_calls: Vec<Completion> = (0..50).map(|_| b.next_completion()).collect();
        assert_eq!(via_iter, via_calls);
    }

    #[test]
    fn deterministic_given_seed() {
        let (m1, r1) = model(Environment::Heterogeneous, 4, 9);
        let (m2, r2) = model(Environment::Heterogeneous, 4, 9);
        let a = AsyncSchedule::new(m1, r1).take(100);
        let b = AsyncSchedule::new(m2, r2).take(100);
        assert_eq!(a, b);
    }

    #[test]
    fn hetero_fast_workers_dominate() {
        let (m, rng) = model(Environment::Heterogeneous, 4, 11);
        let fastest = (0..4)
            .min_by(|&a, &b| m.machine_mean(a).total_cmp(&m.machine_mean(b)))
            .unwrap();
        let mut s = AsyncSchedule::new(m, rng);
        let evts = s.take(1000);
        let counts = evts.iter().filter(|e| e.worker == fastest).count();
        assert!(counts > 250, "fastest worker should exceed fair share: {counts}");
    }
}

//! Event-driven cluster simulator.
//!
//! Drives the pull → compute(gamma-time) → push cycle of every worker on a
//! virtual clock and yields master-apply events in completion order — the
//! same methodology the paper uses for its §5.1/§5.2 simulations ("we
//! simulate the workers' execution time using a gamma-distributed model").
//! The [`crate::train::sim_trainer`] consumes these events and performs the
//! *real* gradient computation (via the PJRT runtime) for each one, so the
//! schedule is simulated but the learning dynamics are genuine.
//!
//! [`AsyncSchedule`] is a *cluster-event* stream, not just a completion
//! stream: a declarative [`ChurnSchedule`] splices membership events —
//! [`ClusterEvent::Join`], [`ClusterEvent::Leave`], straggler onset via
//! [`ClusterEvent::SpeedChange`] — between completions, pinned to
//! fractions of the run's master-step budget.  With an empty churn
//! schedule the stream is bit-for-bit the pre-elastic completion stream
//! (no extra RNG draws, same heap order), which the churn equivalence
//! suite pins.
//!
//! Synchronous mode (SSGD) implements the barrier: a round completes when
//! the slowest worker finishes, which is the mechanism behind Fig 12's
//! speedup comparison.

use super::churn::{ChurnAction, ChurnSchedule};
use super::gamma::ExecTimeModel;
use crate::util::rng::Rng;
use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

/// One asynchronous completion: worker `worker` finishes a batch at `time`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Completion {
    pub time: f64,
    pub worker: usize,
}

/// One event of the simulated cluster, in virtual-time order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ClusterEvent {
    /// Worker finished a batch (the only event an empty churn schedule
    /// ever produces).
    Completion(Completion),
    /// A worker joined; `worker` is the slot the stream assigned (lowest
    /// retired, else a brand-new slot — the same rule the servers use).
    Join { time: f64, worker: usize },
    /// A worker left; its in-flight batch is discarded.
    Leave { time: f64, worker: usize },
    /// Straggler onset: `worker`'s mean batch time was multiplied by
    /// `factor` (future dispatches; the in-flight batch keeps its time).
    SpeedChange { time: f64, worker: usize, factor: f64 },
}

// BinaryHeap is a max-heap; invert the order to pop the earliest event.
// The dispatch generation rides along but does NOT participate in the
// ordering, keeping the pop order identical to the pre-elastic engine.
#[derive(Debug, Clone, Copy, PartialEq)]
struct HeapItem(Completion, u32);

impl Eq for HeapItem {}

impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .0
            .time
            .total_cmp(&self.0.time)
            .then_with(|| other.0.worker.cmp(&self.0.worker))
    }
}

impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Asynchronous cluster-event generator: an infinite stream of completions
/// interleaved with the (finite) churn events of a [`ChurnSchedule`].
pub struct AsyncSchedule {
    model: ExecTimeModel,
    rng: Rng,
    heap: BinaryHeap<HeapItem>,
    now: f64,
    /// Slot liveness; leaves retire slots, joins reuse the lowest retired.
    live: Vec<bool>,
    /// Dispatch generation per slot: bumped on leave so a stale in-flight
    /// completion is discarded even if the slot is later reused.
    gen: Vec<u32>,
    /// Completions emitted so far (drives churn thresholds).
    emitted: u64,
    /// Churn events still to fire, as (master-step threshold, action).
    pending: VecDeque<(u64, ChurnAction)>,
    /// Pull→params round-trip time (communication latency); 0 = the
    /// classic schedule where communication is free.
    rtt: f64,
    /// Per-worker ring of the issue times of outstanding pulls, oldest
    /// first (length = pipeline depth while a batch computes): batch n+1
    /// can start once it is both free AND `front + rtt` has passed —
    /// pipelining hides the round trip behind compute.  Unused when
    /// `rtt == 0` (the schedule is then bit-for-bit depth-independent).
    pull_ring: Vec<VecDeque<f64>>,
    /// Pipeline depth D (D+1 batches in flight per worker).
    depth: usize,
}

impl AsyncSchedule {
    pub fn new(model: ExecTimeModel, mut rng: Rng) -> Self {
        let mut heap = BinaryHeap::new();
        for w in 0..model.n_workers() {
            let t = model.sample(w, &mut rng);
            heap.push(HeapItem(Completion { time: t, worker: w }, 0));
        }
        let n = model.n_workers();
        AsyncSchedule {
            model,
            rng,
            heap,
            now: 0.0,
            live: vec![true; n],
            gen: vec![0; n],
            emitted: 0,
            pending: VecDeque::new(),
            rtt: 0.0,
            pull_ring: vec![VecDeque::new(); n],
            depth: 0,
        }
    }

    /// Model a pipelined worker runtime: each worker keeps `depth + 1`
    /// batches in flight and every pull costs `rtt` time units of
    /// communication.  With `rtt == 0` the completion stream is
    /// bit-for-bit the classic one at ANY depth (communication is free,
    /// and ASGD workers never idle); with `rtt > 0` a depth-0 worker
    /// stalls `rtt` per cycle (pull→compute→push round trips) while a
    /// deep-enough pipeline hides the latency behind compute entirely.
    /// Consumes no RNG.  Must be applied before any event is consumed.
    pub fn with_pipeline(mut self, depth: usize, rtt: f64) -> Self {
        assert_eq!(self.emitted, 0, "with_pipeline must precede event consumption");
        assert!(rtt >= 0.0 && rtt.is_finite(), "rtt must be finite and >= 0");
        self.depth = depth;
        self.rtt = rtt;
        if rtt > 0.0 {
            // the priming pulls (batches 1..=D+1) are all issued at t=0;
            // one is consumed by each worker's first dispatch, so the
            // ring holds D entries while batch 1 computes
            for ring in &mut self.pull_ring {
                ring.clear();
                for _ in 0..depth {
                    ring.push_back(0.0);
                }
            }
            // initial dispatches (drawn in `new`) wait for their primed
            // pull to arrive: shift every in-flight completion by rtt
            // (a uniform shift — heap order is unchanged)
            let items: Vec<HeapItem> = self.heap.drain().collect();
            self.heap = items
                .into_iter()
                .map(|HeapItem(c, g)| {
                    HeapItem(Completion { time: c.time + rtt, worker: c.worker }, g)
                })
                .collect();
        }
        self
    }

    /// Attach a churn schedule for a run of `total_steps` master steps.
    /// Validates that the cluster never empties.  Consumes no RNG, so an
    /// empty schedule leaves the stream bit-for-bit unchanged.
    pub fn with_churn(mut self, churn: &ChurnSchedule, total_steps: u64) -> anyhow::Result<Self> {
        churn.validate(self.live.iter().filter(|&&l| l).count())?;
        self.pending = churn.thresholds(total_steps).into();
        Ok(self)
    }

    /// Simulated time of the most recent completion.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Workers currently live in the simulated cluster.
    pub fn live_workers(&self) -> usize {
        self.live.iter().filter(|&&l| l).count()
    }

    /// Pick the i-th live worker for a random-victim churn event.
    fn random_live(&mut self) -> usize {
        let n = self.live_workers() as u64;
        debug_assert!(n > 0);
        let nth = self.rng.below(n) as usize;
        self.live
            .iter()
            .enumerate()
            .filter(|(_, &l)| l)
            .nth(nth)
            .map(|(w, _)| w)
            .expect("live worker exists")
    }

    /// Materialize one churn action.  Returns `None` when the action is a
    /// no-op at fire time — a leave or speed change naming a worker that is
    /// already retired/unknown (the coarse cases are caught up front by
    /// [`ChurnSchedule::validate`]; what remains is skipped with a note, in
    /// the same spirit as the servers' recoverable retired-worker pushes).
    fn fire_churn(&mut self, action: ChurnAction) -> Option<ClusterEvent> {
        match action {
            ChurnAction::Join => {
                let slot = crate::optim::claim_slot(&mut self.live);
                if slot == self.gen.len() {
                    self.gen.push(0);
                    self.pull_ring.push(VecDeque::new());
                    let m = self.model.add_machine(&mut self.rng);
                    debug_assert_eq!(m, slot);
                } else {
                    // a reused slot is new hardware: fresh machine mean, no
                    // inherited straggler rescale
                    self.model.reset_machine(slot, &mut self.rng);
                }
                // dispatch the joiner's first batch from `now` (after the
                // priming pull's round trip under an rtt model; its D
                // remaining primed pulls are also issued at `now`)
                let stall = if self.rtt > 0.0 {
                    let now = self.now;
                    let ring = &mut self.pull_ring[slot];
                    ring.clear();
                    for _ in 0..self.depth {
                        ring.push_back(now);
                    }
                    self.rtt
                } else {
                    0.0
                };
                let dur = self.model.sample(slot, &mut self.rng);
                self.heap.push(HeapItem(
                    Completion { time: self.now + stall + dur, worker: slot },
                    self.gen[slot],
                ));
                Some(ClusterEvent::Join { time: self.now, worker: slot })
            }
            ChurnAction::Leave(who) => {
                let w = match who {
                    Some(w) => {
                        if !self.live.get(w).copied().unwrap_or(false) {
                            eprintln!(
                                "churn: skipping leave of retired/unknown worker {w}"
                            );
                            return None;
                        }
                        w
                    }
                    None => self.random_live(),
                };
                self.live[w] = false;
                // invalidate the in-flight batch lazily via the generation
                self.gen[w] = self.gen[w].wrapping_add(1);
                Some(ClusterEvent::Leave { time: self.now, worker: w })
            }
            ChurnAction::SpeedChange(who, factor) => {
                let w = match who {
                    Some(w) => {
                        // a retired machine never dispatches (and a joiner
                        // reusing the slot gets a fresh one), so rescaling
                        // it would be a silent no-op — skip like Leave does
                        if !self.live.get(w).copied().unwrap_or(false) {
                            eprintln!(
                                "churn: skipping speed change of retired/unknown worker {w}"
                            );
                            return None;
                        }
                        w
                    }
                    None => self.random_live(),
                };
                self.model.rescale(w, factor);
                Some(ClusterEvent::SpeedChange { time: self.now, worker: w, factor })
            }
        }
    }

    /// The next cluster event: a due churn event if one has come up,
    /// otherwise the next completion (that worker is immediately
    /// re-dispatched — workers never idle in ASGD).
    pub fn next_event(&mut self) -> ClusterEvent {
        while let Some(&(at, action)) = self.pending.front() {
            if self.emitted < at {
                break;
            }
            self.pending.pop_front();
            if let Some(ev) = self.fire_churn(action) {
                return ev;
            }
        }
        loop {
            let HeapItem(c, g) = self
                .heap
                .pop()
                .expect("cluster has no live workers (churn validation should prevent this)");
            if !self.live[c.worker] || g != self.gen[c.worker] {
                continue; // stale: the worker left after this dispatch
            }
            self.now = c.time;
            // Pipeline/RTT model: the push for this batch (and the pull
            // for batch n+D+1) go out now; the next batch starts once its
            // own params — pulled at the ring's front — have arrived.
            // With rtt == 0 this is exactly the classic instant
            // re-dispatch, whatever the depth.
            let start = if self.rtt > 0.0 {
                let ring = &mut self.pull_ring[c.worker];
                ring.push_back(c.time);
                let pulled = ring.pop_front().unwrap_or(c.time);
                c.time.max(pulled + self.rtt)
            } else {
                c.time
            };
            let dur = self.model.sample(c.worker, &mut self.rng);
            self.heap
                .push(HeapItem(Completion { time: start + dur, worker: c.worker }, g));
            self.emitted += 1;
            return ClusterEvent::Completion(c);
        }
    }

    /// Pop the next *completion*, transparently applying any due churn
    /// events along the way (membership-agnostic consumers: speedup sims,
    /// the property suites).
    pub fn next_completion(&mut self) -> Completion {
        loop {
            if let ClusterEvent::Completion(c) = self.next_event() {
                return c;
            }
        }
    }

    /// Materialize the next `n` completions (for schedule-replay tests).
    /// Named `take_n` so it does not shadow `Iterator::take` on the
    /// receiver.
    pub fn take_n(&mut self, n: usize) -> Vec<Completion> {
        (0..n).map(|_| self.next_completion()).collect()
    }
}

/// The schedule is an infinite stream of completions; the iterator view
/// lets consumers drive adapters over it (the equivalence property suite
/// replays one gamma-model worker ordering into several servers).  Churn
/// events are applied transparently — use [`AsyncSchedule::next_event`]
/// to observe them.
impl Iterator for AsyncSchedule {
    type Item = Completion;

    fn next(&mut self) -> Option<Completion> {
        Some(self.next_completion())
    }
}

/// Synchronous schedule: rounds gated by the slowest worker.
pub struct SyncSchedule {
    model: ExecTimeModel,
    rng: Rng,
    now: f64,
}

impl SyncSchedule {
    pub fn new(model: ExecTimeModel, rng: Rng) -> Self {
        SyncSchedule { model, rng, now: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Run one barrier round; returns the round's wall time (max over
    /// workers) — every worker contributes exactly one batch.
    pub fn next_round(&mut self) -> f64 {
        let round = (0..self.model.n_workers())
            .map(|w| self.model.sample(w, &mut self.rng))
            .fold(0.0f64, f64::max);
        self.now += round;
        round
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gamma::Environment;

    fn model(env: Environment, n: usize, seed: u64) -> (ExecTimeModel, Rng) {
        let mut rng = Rng::new(seed);
        let m = ExecTimeModel::new(env, n, 128, &mut rng);
        (m, rng)
    }

    #[test]
    fn completions_are_time_ordered() {
        let (m, rng) = model(Environment::Homogeneous, 8, 3);
        let mut s = AsyncSchedule::new(m, rng);
        let evts = s.take_n(500);
        for w in evts.windows(2) {
            assert!(w[0].time <= w[1].time);
        }
    }

    #[test]
    fn all_workers_participate() {
        let (m, rng) = model(Environment::Homogeneous, 8, 4);
        let mut s = AsyncSchedule::new(m, rng);
        let evts = s.take_n(200);
        let mut seen = [0usize; 8];
        for e in &evts {
            seen[e.worker] += 1;
        }
        for (w, &c) in seen.iter().enumerate() {
            assert!(c > 10, "worker {w} starved: {c} completions");
        }
    }

    #[test]
    fn homo_throughput_is_near_linear() {
        // N workers deliver ~N completions per mean batch time.
        let (m, rng) = model(Environment::Homogeneous, 8, 5);
        let mut s = AsyncSchedule::new(m, rng);
        let k = 4000;
        let evts = s.take_n(k);
        let total_time = evts.last().unwrap().time;
        let throughput = k as f64 / total_time; // completions per unit time
        let ideal = 8.0 / 128.0;
        assert!(
            (throughput / ideal - 1.0).abs() < 0.1,
            "throughput {throughput} vs ideal {ideal}"
        );
    }

    #[test]
    fn sync_rounds_are_slower_than_async_mean() {
        // E[max of N gammas] > E[gamma]: the straggler penalty.
        let (m, rng) = model(Environment::Heterogeneous, 8, 6);
        let mut s = SyncSchedule::new(m, rng);
        let mut total = 0.0;
        for _ in 0..200 {
            total += s.next_round();
        }
        let mean_round = total / 200.0;
        assert!(mean_round > 128.0 * 1.1, "mean round {mean_round}");
    }

    #[test]
    fn iterator_view_matches_next_completion() {
        let (m1, r1) = model(Environment::Homogeneous, 4, 21);
        let (m2, r2) = model(Environment::Homogeneous, 4, 21);
        let mut a = AsyncSchedule::new(m1, r1);
        let mut b = AsyncSchedule::new(m2, r2);
        let via_iter: Vec<Completion> = Iterator::take(&mut a, 50).collect();
        let via_calls: Vec<Completion> = (0..50).map(|_| b.next_completion()).collect();
        assert_eq!(via_iter, via_calls);
    }

    #[test]
    fn deterministic_given_seed() {
        let (m1, r1) = model(Environment::Heterogeneous, 4, 9);
        let (m2, r2) = model(Environment::Heterogeneous, 4, 9);
        let a = AsyncSchedule::new(m1, r1).take_n(100);
        let b = AsyncSchedule::new(m2, r2).take_n(100);
        assert_eq!(a, b);
    }

    #[test]
    fn free_communication_pipeline_is_bit_for_bit_identical() {
        // rtt == 0: the completion stream is depth-independent and equals
        // the classic schedule exactly (no extra RNG, same heap order).
        let (m1, r1) = model(Environment::Heterogeneous, 4, 31);
        let (m2, r2) = model(Environment::Heterogeneous, 4, 31);
        let plain = AsyncSchedule::new(m1, r1).take_n(300);
        let piped = AsyncSchedule::new(m2, r2).with_pipeline(3, 0.0).take_n(300);
        assert_eq!(plain, piped);
    }

    #[test]
    fn pipelining_hides_the_round_trip() {
        // N=1, rtt far below the mean batch time: depth 0 pays rtt per
        // cycle, depth 1 pays it once (the priming pull) and then hides
        // it behind compute entirely.
        let n = 200;
        let rtt = 10.0;
        let runs: Vec<f64> = [None, Some(0), Some(1)]
            .into_iter()
            .map(|depth| {
                let (m, r) = model(Environment::Homogeneous, 1, 77);
                let mut s = match depth {
                    None => AsyncSchedule::new(m, r),
                    Some(d) => AsyncSchedule::new(m, r).with_pipeline(d, rtt),
                };
                s.take_n(n).last().unwrap().time
            })
            .collect();
        let (plain, d0, d1) = (runs[0], runs[1], runs[2]);
        let close = |a: f64, b: f64| (a - b).abs() < 1e-6 * (1.0 + b.abs());
        assert!(close(d0, plain + n as f64 * rtt), "depth 0 pays rtt per cycle: {d0} vs {plain}");
        assert!(close(d1, plain + rtt), "depth 1 hides all but the priming rtt: {d1} vs {plain}");
    }

    #[test]
    fn shallow_pipeline_pays_partial_stalls_when_compute_is_short() {
        // rtt ABOVE the mean batch time: depth 1 can only hide one batch
        // of compute, so throughput sits strictly between depth 0 and a
        // deep pipeline.
        let n = 400;
        let rtt = 300.0; // mean batch time is 128
        let time_at = |depth: usize| {
            let (m, r) = model(Environment::Homogeneous, 2, 13);
            AsyncSchedule::new(m, r)
                .with_pipeline(depth, rtt)
                .take_n(n)
                .last()
                .unwrap()
                .time
        };
        let (t0, t1, t4) = (time_at(0), time_at(1), time_at(4));
        assert!(t1 < t0 * 0.8, "depth 1 must hide a chunk of the rtt: {t1} vs {t0}");
        assert!(t4 < t1 * 0.8, "a deep pipeline must hide more: {t4} vs {t1}");
    }

    #[test]
    fn pipelined_churn_join_primes_from_now() {
        // a joiner under an rtt model must not complete before now + rtt
        let (m, rng) = model(Environment::Homogeneous, 2, 19);
        let churn = crate::sim::ChurnSchedule::parse("join@0.3").unwrap();
        let mut s = AsyncSchedule::new(m, rng)
            .with_pipeline(1, 50.0)
            .with_churn(&churn, 100)
            .unwrap();
        let mut join_at = None;
        let mut steps = 0;
        while steps < 100 {
            match s.next_event() {
                ClusterEvent::Completion(c) => {
                    steps += 1;
                    if let Some(at) = join_at {
                        if c.worker == 2 {
                            assert!(c.time >= at + 50.0, "joiner beat its priming pull");
                            join_at = None; // only the first completion matters
                        }
                    }
                }
                ClusterEvent::Join { time, worker } => {
                    assert_eq!(worker, 2);
                    join_at = Some(time);
                }
                other => panic!("unexpected event {other:?}"),
            }
        }
    }

    #[test]
    fn empty_churn_is_bit_for_bit_identical() {
        let (m1, r1) = model(Environment::Heterogeneous, 4, 13);
        let (m2, r2) = model(Environment::Heterogeneous, 4, 13);
        let mut plain = AsyncSchedule::new(m1, r1);
        let mut churned = AsyncSchedule::new(m2, r2)
            .with_churn(&crate::sim::ChurnSchedule::default(), 500)
            .unwrap();
        for _ in 0..500 {
            assert_eq!(
                ClusterEvent::Completion(plain.next_completion()),
                churned.next_event()
            );
        }
        assert_eq!(plain.now(), churned.now());
    }

    #[test]
    fn leave_discards_in_flight_and_silences_worker() {
        let (m, rng) = model(Environment::Homogeneous, 4, 17);
        let churn = crate::sim::ChurnSchedule::parse("leave@0.1:2").unwrap();
        let mut s = AsyncSchedule::new(m, rng).with_churn(&churn, 200).unwrap();
        let mut left_at = None;
        let mut steps = 0u64;
        while steps < 200 {
            match s.next_event() {
                ClusterEvent::Completion(c) => {
                    if left_at.is_some() {
                        assert_ne!(c.worker, 2, "retired worker completed a batch");
                    }
                    steps += 1;
                }
                ClusterEvent::Leave { worker, .. } => {
                    assert_eq!(worker, 2);
                    left_at = Some(steps);
                }
                other => panic!("unexpected event {other:?}"),
            }
        }
        assert_eq!(left_at, Some(20));
        assert_eq!(s.live_workers(), 3);
    }

    #[test]
    fn join_reuses_retired_slot_then_appends() {
        let (m, rng) = model(Environment::Homogeneous, 2, 19);
        let churn = crate::sim::ChurnSchedule::parse("leave@0.1:0,join@0.3,join@0.5").unwrap();
        let mut s = AsyncSchedule::new(m, rng).with_churn(&churn, 100).unwrap();
        let mut joins = Vec::new();
        let mut steps = 0;
        let mut seen_after_rejoin = false;
        while steps < 100 {
            match s.next_event() {
                ClusterEvent::Completion(c) => {
                    steps += 1;
                    if joins.len() == 2 && c.worker == 0 {
                        seen_after_rejoin = true;
                    }
                }
                ClusterEvent::Join { worker, .. } => joins.push(worker),
                ClusterEvent::Leave { .. } => {}
                other => panic!("unexpected event {other:?}"),
            }
        }
        assert_eq!(joins, vec![0, 2], "reuse slot 0, then append slot 2");
        assert_eq!(s.live_workers(), 3);
        assert!(seen_after_rejoin, "rejoined slot must produce completions");
    }

    #[test]
    fn straggler_onset_shrinks_completion_share() {
        let (m, rng) = model(Environment::Homogeneous, 4, 23);
        let churn = crate::sim::ChurnSchedule::parse("slow@0.5:0=8x").unwrap();
        let mut s = AsyncSchedule::new(m, rng).with_churn(&churn, 4000).unwrap();
        let (mut before, mut after) = (0usize, 0usize);
        let mut slowed = false;
        let mut steps = 0;
        while steps < 4000 {
            match s.next_event() {
                ClusterEvent::Completion(c) => {
                    steps += 1;
                    if c.worker == 0 {
                        if slowed {
                            after += 1;
                        } else {
                            before += 1;
                        }
                    }
                }
                ClusterEvent::SpeedChange { worker, factor, .. } => {
                    assert_eq!((worker, factor), (0, 8.0));
                    slowed = true;
                }
                other => panic!("unexpected event {other:?}"),
            }
        }
        // equal share (~500 of 2000) before; ~1/8 the throughput after
        assert!(before > 350, "before: {before}");
        assert!(after < before / 3, "straggler kept its share: {before} -> {after}");
    }

    #[test]
    fn emptying_churn_is_rejected_up_front() {
        let (m, rng) = model(Environment::Homogeneous, 2, 29);
        let churn = crate::sim::ChurnSchedule::parse("leave@0.1,leave@0.2").unwrap();
        assert!(AsyncSchedule::new(m, rng).with_churn(&churn, 100).is_err());
    }

    #[test]
    fn hetero_fast_workers_dominate() {
        let (m, rng) = model(Environment::Heterogeneous, 4, 11);
        let fastest = (0..4)
            .min_by(|&a, &b| m.machine_mean(a).total_cmp(&m.machine_mean(b)))
            .unwrap();
        let mut s = AsyncSchedule::new(m, rng);
        let evts = s.take_n(1000);
        let counts = evts.iter().filter(|e| e.worker == fastest).count();
        assert!(counts > 250, "fastest worker should exceed fair share: {counts}");
    }
}

//! Theoretical speedup analysis (paper Fig 12, Fig 10's speedup curve,
//! Table 1's time column).
//!
//! Pure timing simulation over the gamma execution-time model: how long do
//! N workers take to process K total batches asynchronously (no barrier)
//! versus synchronously (barrier per round)?  Communication overheads are
//! not modelled, exactly as the paper notes — which makes the reported
//! ASGD-over-SSGD advantage an *underestimate*.

use super::engine::{AsyncSchedule, SyncSchedule};
use super::gamma::{Environment, ExecTimeModel};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpeedupPoint {
    pub n_workers: usize,
    /// Speedup of async over 1 worker (batches/time normalized).
    pub async_speedup: f64,
    /// Speedup of sync over 1 worker.
    pub sync_speedup: f64,
}

/// Time for one worker to process `k` batches (the speedup baseline).
pub fn single_worker_time(env: Environment, batch: usize, k: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let m = ExecTimeModel::new(env, 1, batch, &mut rng);
    let mut total = 0.0;
    for _ in 0..k {
        total += m.sample(0, &mut rng);
    }
    total
}

/// Wall time for `n` async workers to deliver `k` total batches.
pub fn async_time(env: Environment, n: usize, batch: usize, k: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let m = ExecTimeModel::new(env, n, batch, &mut rng);
    let fork = rng.fork(1);
    let mut s = AsyncSchedule::new(m, fork);
    let mut last = 0.0;
    for _ in 0..k {
        last = s.next_completion().time;
    }
    last
}

/// Wall time for `n` sync workers to deliver `k` total batches
/// (ceil(k/n) barrier rounds).
pub fn sync_time(env: Environment, n: usize, batch: usize, k: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let m = ExecTimeModel::new(env, n, batch, &mut rng);
    let fork = rng.fork(1);
    let mut s = SyncSchedule::new(m, fork);
    let rounds = k.div_ceil(n);
    for _ in 0..rounds {
        s.next_round();
    }
    s.now()
}

/// Fig 12 sweep: speedup vs worker count, averaged over `seeds` cluster
/// instantiations.
pub fn speedup_sweep(
    env: Environment,
    worker_counts: &[usize],
    batch: usize,
    batches_per_worker: usize,
    seeds: u64,
) -> Vec<SpeedupPoint> {
    worker_counts
        .iter()
        .map(|&n| {
            let k = batches_per_worker * n;
            let mut asy = 0.0;
            let mut syn = 0.0;
            for seed in 0..seeds {
                // baseline processes the same k batches on one machine
                let base = single_worker_time(env, batch, k, 1000 + seed);
                asy += base / async_time(env, n, batch, k, seed);
                syn += base / sync_time(env, n, batch, k, seed);
            }
            SpeedupPoint {
                n_workers: n,
                async_speedup: asy / seeds as f64,
                sync_speedup: syn / seeds as f64,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn async_scales_near_linearly_homo() {
        let pts = speedup_sweep(Environment::Homogeneous, &[1, 8], 128, 40, 4);
        let s8 = pts[1].async_speedup;
        assert!(s8 > 7.0 && s8 < 9.0, "8-worker async speedup {s8}");
    }

    #[test]
    fn sync_lags_async() {
        for env in [Environment::Homogeneous, Environment::Heterogeneous] {
            let pts = speedup_sweep(env, &[8], 128, 40, 4);
            assert!(
                pts[0].async_speedup > pts[0].sync_speedup,
                "{env:?}: async {} <= sync {}",
                pts[0].async_speedup,
                pts[0].sync_speedup
            );
        }
    }

    #[test]
    fn hetero_gap_is_dramatic() {
        // Paper: ASGD up to ~6x faster than SSGD in heterogeneous clusters.
        let pts = speedup_sweep(Environment::Heterogeneous, &[16], 128, 30, 6);
        let ratio = pts[0].async_speedup / pts[0].sync_speedup;
        assert!(ratio > 1.5, "hetero async/sync ratio {ratio}");
    }

    #[test]
    fn single_worker_speedup_is_unity() {
        let pts = speedup_sweep(Environment::Homogeneous, &[1], 128, 50, 4);
        assert!((pts[0].async_speedup - 1.0).abs() < 0.2);
    }
}

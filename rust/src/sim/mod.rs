//! Cluster simulation: gamma execution-time model (Appendix A.4), event
//! engine, and the theoretical speedup analysis (Fig 12).

pub mod engine;
pub mod gamma;
pub mod speedup;

pub use engine::{AsyncSchedule, Completion, SyncSchedule};
pub use gamma::{Environment, ExecTimeModel};

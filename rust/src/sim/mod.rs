//! Cluster simulation: gamma execution-time model (Appendix A.4), the
//! cluster-event engine (completions + membership churn), declarative
//! churn schedules, and the theoretical speedup analysis (Fig 12).

pub mod churn;
pub mod engine;
pub mod gamma;
pub mod speedup;

pub use churn::{ChurnAction, ChurnEvent, ChurnSchedule};
pub use engine::{AsyncSchedule, ClusterEvent, Completion, SyncSchedule};
pub use gamma::{Environment, ExecTimeModel};

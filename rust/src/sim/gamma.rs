//! CVB task-execution-time model (Ali et al. 2000; paper Appendix A.4,
//! Algorithms 11 & 12).
//!
//! Batch execution times are gamma-distributed.  Two regimes:
//!
//! * **homogeneous** — all machines share one mean drawn once
//!   (Algorithm 11); stragglers are transient (a different machine is slow
//!   each epoch).  `V_mach = 0.1`.
//! * **heterogeneous** — every machine draws its own persistent mean
//!   (Algorithm 12); some machines are durably slow.  `V_mach = 0.6`.
//!
//! With `mu_task = mu_mach = B` the mean execution time is `B` simulated
//! time units (Fig 3: both pdfs centred at 128 for B=128), and the paper's
//! headline tail statistic — P(time > 1.25·mean) ≈ 1% homo vs 27.9% hetero —
//! emerges from the composition; `tests` below pin it.

use crate::util::rng::Rng;

/// Variance parameters (paper values).
pub const V_TASK: f64 = 0.1;
pub const V_MACH_HOMO: f64 = 0.1;
pub const V_MACH_HETERO: f64 = 0.6;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Environment {
    Homogeneous,
    Heterogeneous,
}

impl std::str::FromStr for Environment {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "homo" | "homogeneous" => Ok(Environment::Homogeneous),
            "hetero" | "heterogeneous" => Ok(Environment::Heterogeneous),
            other => anyhow::bail!("unknown environment {other:?} (homo|hetero)"),
        }
    }
}

/// Per-cluster execution-time sampler.
#[derive(Debug, Clone)]
pub struct ExecTimeModel {
    env: Environment,
    /// Mean batch time in simulated units (= batch size B).
    mu: f64,
    alpha_task: f64,
    alpha_mach: f64,
    /// Per-machine scale β_task[j] (heterogeneous) or the shared machine
    /// scale (homogeneous).  Mutated in place by [`ExecTimeModel::rescale`]
    /// (straggler onset).
    beta_task: Vec<f64>,
    /// The scale a *fresh* homogeneous machine receives (Alg 11's shared
    /// q/α_mach, kept pristine so a rescaled machine 0 does not leak its
    /// slowdown into joiners).  Unused in the heterogeneous regime, where
    /// joiners sample their own persistent mean (Alg 12).
    fresh_beta: f64,
}

impl ExecTimeModel {
    /// Build the model for `n_workers` machines and batch size `batch`
    /// (Algorithms 11/12 setup phase).
    pub fn new(env: Environment, n_workers: usize, batch: usize, rng: &mut Rng) -> Self {
        let mu = batch as f64;
        let v_mach = match env {
            Environment::Homogeneous => V_MACH_HOMO,
            Environment::Heterogeneous => V_MACH_HETERO,
        };
        let alpha_task = 1.0 / (V_TASK * V_TASK);
        let alpha_mach = 1.0 / (v_mach * v_mach);
        let (beta_task, fresh_beta) = match env {
            Environment::Homogeneous => {
                // Alg 11: q ~ G(alpha_task, mu/alpha_task) shared by all.
                let q = rng.gamma(alpha_task, mu / alpha_task);
                (vec![q / alpha_mach; n_workers], q / alpha_mach)
            }
            Environment::Heterogeneous => {
                // Alg 12: p[j] ~ G(alpha_mach, mu/alpha_mach) per machine.
                let b: Vec<f64> = (0..n_workers)
                    .map(|_| rng.gamma(alpha_mach, mu / alpha_mach) / alpha_task)
                    .collect();
                (b, 0.0)
            }
        };
        ExecTimeModel { env, mu, alpha_task, alpha_mach, beta_task, fresh_beta }
    }

    /// A machine joins the cluster: appends a new slot and returns its
    /// index.  Homogeneous: the joiner shares the cluster's mean (Alg 11);
    /// heterogeneous: it draws its own persistent mean (Alg 12) from `rng`.
    pub fn add_machine(&mut self, rng: &mut Rng) -> usize {
        let beta = match self.env {
            Environment::Homogeneous => self.fresh_beta,
            Environment::Heterogeneous => {
                rng.gamma(self.alpha_mach, self.mu / self.alpha_mach) / self.alpha_task
            }
        };
        self.beta_task.push(beta);
        self.beta_task.len() - 1
    }

    /// Straggler onset: multiply machine `j`'s mean execution time by
    /// `factor` (>1 slower, <1 faster).  Applies to all future samples.
    pub fn rescale(&mut self, j: usize, factor: f64) {
        self.beta_task[j] *= factor;
    }

    /// Replace machine `j` with a fresh one (a joiner reusing a retired
    /// slot is new hardware): homogeneous machines get the pristine shared
    /// mean — any straggler rescale the old occupant suffered does not
    /// leak — and heterogeneous ones draw a new persistent mean (Alg 12).
    pub fn reset_machine(&mut self, j: usize, rng: &mut Rng) {
        self.beta_task[j] = match self.env {
            Environment::Homogeneous => self.fresh_beta,
            Environment::Heterogeneous => {
                rng.gamma(self.alpha_mach, self.mu / self.alpha_mach) / self.alpha_task
            }
        };
    }

    pub fn env(&self) -> Environment {
        self.env
    }

    pub fn n_workers(&self) -> usize {
        self.beta_task.len()
    }

    /// Nominal mean batch time (B simulated units).
    pub fn mean(&self) -> f64 {
        self.mu
    }

    /// Persistent mean of machine `j` (heterogeneous: p[j]; homogeneous: q).
    pub fn machine_mean(&self, j: usize) -> f64 {
        match self.env {
            Environment::Homogeneous => self.beta_task[j] * self.alpha_mach,
            Environment::Heterogeneous => self.beta_task[j] * self.alpha_task,
        }
    }

    /// Sample the execution time of one batch on machine `j`
    /// (Alg 11/12 loop body).
    pub fn sample(&self, j: usize, rng: &mut Rng) -> f64 {
        match self.env {
            Environment::Homogeneous => rng.gamma(self.alpha_mach, self.beta_task[j]),
            Environment::Heterogeneous => rng.gamma(self.alpha_task, self.beta_task[j]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tail_prob(env: Environment, seeds: u64) -> (f64, f64) {
        // Returns (overall mean / B, P[time > 1.25 * B]) across many
        // cluster instantiations (the paper's Fig 3 statistic).
        let b = 128usize;
        let mut count = 0usize;
        let mut total = 0usize;
        let mut sum = 0.0;
        for seed in 0..seeds {
            let mut rng = Rng::new(seed);
            let m = ExecTimeModel::new(env, 8, b, &mut rng);
            for j in 0..8 {
                for _ in 0..100 {
                    let t = m.sample(j, &mut rng);
                    sum += t;
                    total += 1;
                    if t > 1.25 * b as f64 {
                        count += 1;
                    }
                }
            }
        }
        (sum / total as f64 / b as f64, count as f64 / total as f64)
    }

    #[test]
    fn homogeneous_mean_and_tail() {
        let (mean_ratio, tail) = tail_prob(Environment::Homogeneous, 40);
        assert!((mean_ratio - 1.0).abs() < 0.05, "mean ratio {mean_ratio}");
        // paper: ~1% of iterations exceed 1.25x the mean
        assert!(tail < 0.08, "homo tail {tail}");
    }

    #[test]
    fn heterogeneous_mean_and_tail() {
        let (mean_ratio, tail) = tail_prob(Environment::Heterogeneous, 40);
        assert!((mean_ratio - 1.0).abs() < 0.15, "mean ratio {mean_ratio}");
        // paper: 27.9% exceed 1.25x the mean — much heavier than homo
        assert!(tail > 0.15, "hetero tail {tail}");
    }

    #[test]
    fn hetero_machines_have_persistent_speeds() {
        let mut rng = Rng::new(1);
        let m = ExecTimeModel::new(Environment::Heterogeneous, 16, 128, &mut rng);
        let means: Vec<f64> = (0..16).map(|j| m.machine_mean(j)).collect();
        let spread = means.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            / means.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(spread > 1.2, "hetero machines should differ: spread {spread}");
    }

    #[test]
    fn homo_machines_share_one_mean() {
        let mut rng = Rng::new(1);
        let m = ExecTimeModel::new(Environment::Homogeneous, 4, 128, &mut rng);
        for j in 1..4 {
            assert_eq!(m.machine_mean(0), m.machine_mean(j));
        }
    }

    #[test]
    fn rescale_shifts_one_machine_mean() {
        let mut rng = Rng::new(4);
        let mut m = ExecTimeModel::new(Environment::Homogeneous, 4, 128, &mut rng);
        let m0 = m.machine_mean(0);
        m.rescale(0, 4.0);
        assert!((m.machine_mean(0) / m0 - 4.0).abs() < 1e-9);
        assert_eq!(m.machine_mean(1), m0, "other machines untouched");
        // empirical check: samples track the new mean
        let n = 4000;
        let mean: f64 = (0..n).map(|_| m.sample(0, &mut rng)).sum::<f64>() / n as f64;
        assert!((mean / (4.0 * m0) - 1.0).abs() < 0.1, "{mean} vs {}", 4.0 * m0);
    }

    #[test]
    fn joining_machines_follow_the_regime() {
        let mut rng = Rng::new(5);
        let mut homo = ExecTimeModel::new(Environment::Homogeneous, 2, 128, &mut rng);
        homo.rescale(0, 8.0); // must not leak into the joiner
        let j = homo.add_machine(&mut rng);
        assert_eq!(j, 2);
        assert_eq!(homo.n_workers(), 3);
        assert_eq!(homo.machine_mean(2), homo.machine_mean(1), "homo joiner shares the mean");
        let mut het = ExecTimeModel::new(Environment::Heterogeneous, 2, 128, &mut rng);
        let j = het.add_machine(&mut rng);
        let mean = het.machine_mean(j);
        assert!(mean > 0.0 && mean.is_finite());
    }

    #[test]
    fn samples_are_positive() {
        let mut rng = Rng::new(2);
        for env in [Environment::Homogeneous, Environment::Heterogeneous] {
            let m = ExecTimeModel::new(env, 2, 32, &mut rng);
            for _ in 0..1000 {
                assert!(m.sample(0, &mut rng) > 0.0);
            }
        }
    }

    #[test]
    fn env_parses() {
        assert_eq!("homo".parse::<Environment>().unwrap(), Environment::Homogeneous);
        assert_eq!("HETERO".parse::<Environment>().unwrap(), Environment::Heterogeneous);
        assert!("x".parse::<Environment>().is_err());
    }
}
